"""Edge-fleet simulation benchmark -> BENCH_sim.json.

Runs every (method, scenario) case of the event-driven fleet simulator
(``repro.sim``) on a small MLR testbed and records the quantities the
paper's edge-deployment story turns on:

    sim_seconds          simulated wall-clock for the whole run (compute
                         + bandwidth-limited transmission per round)
    time_to_target       simulated seconds until the loss first reaches
                         the no-fault-derived target (None = never)
    wire_bits            cumulative delivered payload bits
    epsilon              final (eps, delta)-DP spend under participation
                         amplification (q < 1 folds into the accountant)
    loss_gap_vs_no_fault graceful-degradation check: how much worse the
                         faulty scenario's final loss is than the same
                         method's no-fault run

Scenarios are the named presets (no-fault | straggler | dropout | churn);
methods compare the paper's SDM-DSGD against the dense DSGD baseline —
same fleet, same faults, so the sparse wire format's bandwidth advantage
shows up directly in simulated seconds.

Run via ``python -m benchmarks.run --only sim`` (writes BENCH_sim.json at
the repo root; CI uploads it next to BENCH_perf.json) or directly:
``python -m benchmarks.sim_edge``.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import PrivacyParams, SDMConfig, topology
from repro.data import classification_dataset, node_partitioned_batches
from repro.models import vision_small
from repro.sim import SCENARIOS, simulate

OUT_PATH = os.environ.get("BENCH_SIM_OUT", "BENCH_sim.json")

N_NODES = 8
ROUNDS = 60
BATCH_PER_NODE = 16
M_LOCAL = 2000 // N_NODES

_SDM_PRIVACY = PrivacyParams(G=5.0, m=M_LOCAL, tau=BATCH_PER_NODE / M_LOCAL,
                             p=0.4, sigma=1.0)

METHODS = {
    # label -> (algorithm, cfg, privacy): dsgd releases every coordinate
    # (p=1), SDM only p. sdm-dsgd+ov is the SAME wire format under the
    # overlapped transport: one-step-stale mixing, so each node's round
    # time is max(compute, transmit) instead of their sum — the simulated
    # seconds-to-target show what hiding the wire under compute buys.
    "sdm-dsgd": ("sdm-dsgd",
                 SDMConfig(p=0.4, theta=0.3, gamma=0.1, sigma=1.0,
                           clip_c=5.0),
                 _SDM_PRIVACY),
    "sdm-dsgd+ov": ("sdm-dsgd",
                    SDMConfig(p=0.4, theta=0.3, gamma=0.1, sigma=1.0,
                              clip_c=5.0, overlap=True),
                    _SDM_PRIVACY),
    "dsgd": ("dsgd",
             SDMConfig(p=1.0, theta=1.0, gamma=0.1, sigma=1.0, clip_c=5.0),
             PrivacyParams(G=5.0, m=M_LOCAL, tau=BATCH_PER_NODE / M_LOCAL,
                           p=1.0, sigma=1.0)),
}


def _testbed(seed=0):
    (x_tr, y_tr), _ = classification_dataset(64, 10, 2000, 200, seed=seed)
    params0 = vision_small.mlr_init(jax.random.PRNGKey(seed), 64, 10)
    stack = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (N_NODES,) + p.shape), params0)
    grad_fn = vision_small.make_stacked_grad_fn(vision_small.mlr_apply)
    batches = node_partitioned_batches(x_tr, y_tr, N_NODES, BATCH_PER_NODE,
                                       seed=seed)
    return stack, grad_fn, batches


def _one(method: str, scenario: str, target_loss=None):
    algorithm, cfg, pp = METHODS[method]
    stack, grad_fn, batches = _testbed()
    return simulate(topo=topology.ring(N_NODES), algorithm=algorithm,
                    sdm_cfg=cfg, params_stack=stack, grad_fn=grad_fn,
                    batches=batches, rounds=ROUNDS, scenario=scenario,
                    seed=0, privacy=pp, eps_target=1.0,
                    target_loss=target_loss)


def run(out_path: str = OUT_PATH) -> dict:
    cases = []
    for method in METHODS:
        # the no-fault run defines the method's target loss: 80% of the
        # way from the initial to the final no-fault loss
        base = _one(method, "no-fault")
        bl = base.result.losses
        target = bl[0] - 0.8 * (bl[0] - bl[-1])
        base = _one(method, "no-fault", target_loss=target)
        by_scenario = {"no-fault": base}
        for scenario in sorted(SCENARIOS):
            if scenario != "no-fault":
                by_scenario[scenario] = _one(method, scenario,
                                             target_loss=target)
        for scenario, res in by_scenario.items():
            r = res.result
            rec = {
                "method": method,
                "scenario": scenario,
                "rounds": res.rounds,
                "sim_seconds": round(res.sim_seconds, 6),
                "target_loss": round(target, 6),
                "time_to_target": (None if res.time_to_target is None
                                   else round(res.time_to_target, 6)),
                "rounds_to_target": res.rounds_to_target,
                "wire_bits": r.comm_bits[-1],
                "epsilon": (r.epsilons[-1] if r.epsilons else None),
                "final_loss": round(r.losses[-1], 6),
                "loss_gap_vs_no_fault": round(
                    r.losses[-1] - base.result.losses[-1], 6),
                "straggler_rounds": res.straggler_rounds,
                "dropout_rounds": res.dropout_rounds,
                "recompiles": res.recompiles,
                "wall_s": round(r.wall_s, 3),
            }
            cases.append(rec)
            tt = rec["time_to_target"]
            emit(f"sim_edge/{method}/{scenario}",
                 r.wall_s / max(res.rounds, 1) * 1e6,
                 f"t_sim={rec['sim_seconds']}s "
                 f"t_target={'never' if tt is None else f'{tt}s'} "
                 f"bits={rec['wire_bits']} eps={rec['epsilon']} "
                 f"gap={rec['loss_gap_vs_no_fault']}")

    report = {"n_nodes": N_NODES, "rounds": ROUNDS, "cases": cases}
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_path} ({len(cases)} cases)")
    return report


if __name__ == "__main__":
    run()
