"""Benchmark aggregator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per the repo convention.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig2,theory
"""
from __future__ import annotations

import argparse
import sys
import traceback


BENCHES = {
    "theory": ("benchmarks.theory_tradeoff",
               "Thm 4 m^4 scaling, Prop 5 1/p^2 gap, Lemma 1 terms"),
    "fig2": ("benchmarks.fig2_divergence",
             "Fig 2: DC-DSGD divergence at p=0.2 vs SDM-DSGD"),
    "fig3": ("benchmarks.fig3_comm_efficiency",
             "Fig 3: loss/accuracy vs communicated non-zero elements"),
    "table1": ("benchmarks.table1_privacy_accuracy",
               "Table 1: accuracy under (eps, delta)-DP budgets"),
    "kernels": ("benchmarks.kernel_bench", "Pallas kernel micro-benches"),
    "roofline": ("benchmarks.roofline",
                 "three-term roofline from the dry-run artifacts"),
    "perf": ("benchmarks.perf_wire",
             "wire-plane perf snapshot -> BENCH_perf.json (permutes/step, "
             "wire bits, sorts, fusion factor)"),
    "sim": ("benchmarks.sim_edge",
            "edge-fleet simulation -> BENCH_sim.json (simulated seconds-"
            "to-target, wire bits, epsilon per method x fault scenario)"),
    "serve": ("benchmarks.serve_bench",
              "serving snapshot -> BENCH_serve.json (continuous vs static "
              "tok/s, per-token latency, TTFT, paged-KV footprint, decode "
              "launches)"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args()
    names = list(BENCHES) if args.only is None else args.only.split(",")

    failures = []
    for name in names:
        module_name, desc = BENCHES[name]
        print(f"# === {name}: {desc}", flush=True)
        try:
            mod = __import__(module_name, fromlist=["run"])
            mod.run()
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"# FAILED: {failures}")
        sys.exit(1)
    print("# all benchmarks passed")


if __name__ == "__main__":
    main()
