"""Serving engine snapshot -> BENCH_serve.json.

One ragged-arrival workload (fixed seed, high budget variance — the
traffic shape continuous batching exists for) is served by both engines
after a warmup pass, and the continuous engine's jitted paged decode
step is compiled standalone to count kernel launches:

    tok_s               generated tokens / serve() wall-clock
    p50_ms / p95_ms     per-token decode latency percentiles
                        (step wall / tokens emitted that step)
    ttft_p50_ms / ttft_max_ms
                        submit -> first-token-available
    decode_steps        jitted decode steps executed for the workload
                        (continuous retires+admits mid-flight, so it
                        needs fewer than the static drain-the-batch loop)
    pages_peak / pages_dense / page_frac
                        paged-KV footprint vs the dense
                        max_batch x max_seq reservation (continuous only)
    decode_launches_flash / decode_launches_ref
                        ``hlo_analysis.launch_count`` of ONE compiled
                        decode step, flash (interpret-mode pallas paged
                        kernel) vs XLA gather reference path

Wall-clock here is CPU-host relative (static vs continuous under the
same conditions) — the structural numbers (decode_steps, launches,
pages) are the portable signal. ``benchmarks/baselines/serve.json`` pins
what CI regresses against (``python -m benchmarks.check_serve``).

Baseline refresh (intentional structure changes):
``BENCH_SERVE_OUT=benchmarks/baselines/serve.json python -m
benchmarks.serve_bench`` and commit the diff.
"""
from __future__ import annotations

import json
import os
import time

OUT_PATH = os.environ.get("BENCH_SERVE_OUT", "BENCH_serve.json")

ARCH = "phi3-medium-14b"
MAX_BATCH = 3
MAX_SEQ = 64
PAGE_SIZE = 8
N_REQUESTS = 12
BUDGETS = [16, 1, 2, 12, 1, 3, 16, 2, 8, 1, 4, 12]   # high variance
SEED = 7


def _requests(cfg):
    import numpy as np

    from repro.serving import Request
    rng = np.random.default_rng(SEED)
    lens = rng.integers(2, 17, size=N_REQUESTS).tolist()
    return [Request(prompt=rng.integers(0, cfg.vocab_size, n).tolist(),
                    max_new_tokens=m)
            for n, m in zip(lens, BUDGETS)]


def _percentile(xs, q):
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(int(q * len(xs)), len(xs) - 1)
    return xs[i]


def _engine_record(case, eng, cfg):
    import numpy as np
    eng.serve(_requests(cfg))               # warmup: compile all shapes
    t0 = time.monotonic()
    out = eng.serve(_requests(cfg))
    wall = time.monotonic() - t0
    stats = eng.last_stats
    per_tok = [w / max(t, 1) * 1e3
               for w, t in zip(stats.step_wall_s, stats.step_tokens)]
    tokens = sum(len(r.output) for r in out)
    rec = {
        "case": case,
        "tokens": tokens,
        "tok_s": round(tokens / wall, 1),
        "p50_ms": round(_percentile(per_tok, 0.50), 3),
        "p95_ms": round(_percentile(per_tok, 0.95), 3),
        "ttft_p50_ms": round(_percentile(stats.ttft_s, 0.50) * 1e3, 3),
        "ttft_max_ms": round(max(stats.ttft_s) * 1e3, 3),
        "decode_steps": stats.decode_steps,
    }
    if stats.pages_dense_equiv:
        rec["pages_peak"] = stats.pages_peak
        rec["pages_dense"] = stats.pages_dense_equiv
        rec["page_frac"] = round(
            stats.pages_peak / stats.pages_dense_equiv, 3)
    assert np.all([len(r.output) > 0 for r in out])
    return rec


def _decode_launches(cfg, params, *, use_flash):
    """launch_count of one compiled paged decode step."""
    import jax
    import jax.numpy as jnp

    from repro.launch import hlo_analysis
    from repro.models import transformer
    from repro.serving import PagedKVCache

    kv = PagedKVCache(cfg, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                      page_size=PAGE_SIZE)

    def step(p, tok, pages, tables, offsets, emit):
        return transformer.decode_step_paged(
            p, cfg, tok, pages, {}, tables, offsets, emit,
            use_flash=use_flash, interpret=True)

    tok = jnp.zeros((MAX_BATCH,), jnp.int32)
    offsets = jnp.ones((MAX_BATCH,), jnp.int32)
    emit = jnp.ones((MAX_BATCH,), bool)
    compiled = jax.jit(step).lower(params, tok, kv.pages, kv.tables(),
                                   offsets, emit).compile()
    return hlo_analysis.launch_count(compiled.as_text())


def run() -> None:
    import jax

    from repro import configs
    from repro.models import transformer
    from repro.serving import ServingEngine, StaticServingEngine

    cfg = configs.get_smoke_config(ARCH)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)

    records = [
        _engine_record("static", StaticServingEngine(
            cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ), cfg),
        _engine_record("continuous", ServingEngine(
            cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
            page_size=PAGE_SIZE), cfg),
    ]
    launches = {
        "decode_launches_flash": _decode_launches(cfg, params,
                                                  use_flash=True),
        "decode_launches_ref": _decode_launches(cfg, params,
                                                use_flash=False),
    }
    out = {
        "workload": {"arch": ARCH, "max_batch": MAX_BATCH,
                     "max_seq": MAX_SEQ, "page_size": PAGE_SIZE,
                     "n_requests": N_REQUESTS, "budgets": BUDGETS,
                     "seed": SEED},
        "records": records,
        **launches,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=1)
    for r in records:
        print(f"serve/{r['case']},{1e6 / max(r['tok_s'], 1e-9):.1f},"
              f"tok_s={r['tok_s']} p95_ms={r['p95_ms']} "
              f"steps={r['decode_steps']}")
    print(f"serve/launches,0,flash={launches['decode_launches_flash']} "
          f"ref={launches['decode_launches_ref']}")
    print(f"# wrote {OUT_PATH}")


if __name__ == "__main__":
    run()
