"""Theory benchmarks: Theorem 4's O(m^4) budget scaling (2 orders above the
O(m^2) prior art), Proposition 5's 1/p^2 gap for the reversed design, and
Lemma 1's four-term bound evaluated on the experimental topology.
"""
from __future__ import annotations


from benchmarks import common
from repro.core import privacy, theory, topology


def run():
    topo = topology.erdos_renyi(50, 0.35, seed=0)

    # Theorem 4: T_max(m) ~ m^4.
    ms = [100, 200, 400, 800]
    ts = [privacy.max_iterations(G=5.0, m=m, p=0.2, eps=1.0) for m in ms]
    ratios = [ts[i + 1] / ts[i] for i in range(len(ts) - 1)]
    assert all(abs(r - 16.0) < 0.5 for r in ratios), ratios

    # Proposition 5: reversed design pays 1/p^2.
    gaps = []
    for p in (0.1, 0.2, 0.5):
        params = privacy.PrivacyParams(G=5.0, m=500, tau=1 / 500, p=p,
                                       sigma=2.0)
        sdm = privacy.epsilon_sdm(params, 1000, 0.5) - 0.25
        alt = privacy.epsilon_alternative(params, 1000, 0.5) - 0.25
        gaps.append(alt / sdm)
        assert abs(alt / sdm - 1.0 / p ** 2) < 1e-6

    # Lemma 1 terms at the experimental operating point.
    x = theory.BoundInputs(
        n=50, m=200, d=7850, p=0.2,
        theta=min(0.55, 0.9 * theory.theta_upper_bound(
            0.2, topo.lambda_n, 0.05, 1.0)),
        gamma=0.05, beta=topo.beta, lambda_n=topo.lambda_n, sigma=1.0)
    terms = theory.lemma1_terms(x, T=10_000)
    dominant = max(terms, key=terms.get)

    # Corollary 3's rate decreases in T.
    r1, r2 = theory.corollary3_rate(50, 10_000), theory.corollary3_rate(50, 100_000)
    assert r2 < r1

    derived = (f"m4_ratios={[round(r, 2) for r in ratios]};"
               f"p2_gaps={[round(g, 1) for g in gaps]};"
               f"lemma1_dominant={dominant};"
               "terms=" + ",".join(f"{k}:{v:.3e}" for k, v in terms.items()))
    common.emit("theory_tradeoff", 0.0, derived)
    return terms


if __name__ == "__main__":
    run()
