"""§Perf hillclimbing harness: hypothesis -> change -> re-lower -> measure.

Three (arch x shape) pairs are hillclimbed (selection rationale in
EXPERIMENTS.md §Perf); every experiment below names its hypothesis and
re-derives the three roofline terms from a fresh lower+compile. Results
are written to experiments/perf/<pair>.json and printed as a
before/after table.

Run AFTER the baseline dry-run sweep:
  PYTHONPATH=src python -m benchmarks.perf_iterations [--pair qwen_train]
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.roofline import analyze_record

# (name, description/hypothesis, build_case kwargs)
EXPERIMENTS = {
    # ---------------------------------------------------------------
    # Pair 1: qwen1.5-32b x train_4k — the largest dense model; baseline
    # is memory-term dominated (3 param-size state buffers per node) and
    # carries the biggest absolute collective volume.
    # ---------------------------------------------------------------
    "qwen_train": dict(
        arch="qwen1.5-32b", shape="train_4k",
        variants=[
            ("paper_faithful_bernoulli",
             "BASELINE (paper-faithful): Bernoulli(p) masked DENSE gossip "
             "payloads — the masked tensor still moves d elements.",
             dict(algorithm="sdm_dsgd", gossip_mode="bernoulli")),
            ("packed_fixedk",
             "H1: seed-synced fixed-k packed payloads shrink gossip bytes "
             "by ~p (=0.1); predict collective-permute bytes ~10x lower, "
             "memory/compute unchanged.",
             dict(algorithm="sdm_dsgd", gossip_mode="fixedk_packed")),
            ("packed_plus_fused_state",
             "H2: fusing commit+advance drops the persistent d buffer "
             "(3 -> 2 param-size buffers); predict ~33% lower argument "
             "bytes/device, same collectives as packed.",
             dict(algorithm="sdm_dsgd_fused", gossip_mode="fixedk_packed")),
            ("rows_packed_fused",
             "H3 (iteration on H1's REFUTATION): flat-view packing forces "
             "GSPMD to all-gather model-sharded leaves around the "
             "gather/scatter — pack whole trailing-dim ROWS instead so "
             "the payload keeps its tensor-parallel sharding. Predict the "
             "originally-expected ~10x gossip-byte reduction appears.",
             dict(algorithm="sdm_dsgd_fused", gossip_mode="fixedk_rows")),
            ("dsgd_reference",
             "context: plain DSGD exchanges FULL states - the paper's "
             "communication baseline.",
             dict(algorithm="dsgd", gossip_mode="bernoulli")),
        ]),
    # ---------------------------------------------------------------
    # Pair 2: gemma2-2b x prefill_32k — the most collective-bound pair in
    # the baseline table (collective ~= memory >> compute).
    # ---------------------------------------------------------------
    "gemma_prefill": dict(
        arch="gemma2-2b", shape="prefill_32k",
        variants=[
            ("baseline",
             "BASELINE: batch over data axis, TP over model; activations "
             "replicated along seq.",
             dict(algorithm="sdm_dsgd", gossip_mode="fixedk_packed")),
            ("seq_sharded_activations",
             "H: with batch/data=2 seqs per group the residual stream is "
             "huge; shard the seq dim of activations over the model axis "
             "(Megatron sequence parallelism). Predict all-gather volume "
             "drops for norms/elementwise regions.",
             dict(algorithm="sdm_dsgd", gossip_mode="fixedk_packed",
                  rule_overrides={"seq": "model"})),
            ("no_chunked_attention",
             "H(ablate): q-chunked attention trades memory for re-reads; "
             "disabling it should RAISE peak memory at equal flops "
             "(negative control for the memory term).",
             dict(algorithm="sdm_dsgd", gossip_mode="fixedk_packed",
                  cfg_overrides={"attn_chunk_q": None})),
        ]),
    # ---------------------------------------------------------------
    # Pair 3: jamba-v0.1-52b x train_4k — the worst absolute roofline
    # (memory term) of the whole table AND the most representative of
    # the paper's technique (MoE + Mamba differentials dominate the
    # sparsified payload).
    # ---------------------------------------------------------------
    "jamba_train": dict(
        arch="jamba-v0.1-52b", shape="train_4k",
        variants=[
            ("baseline",
             "BASELINE: packed gossip, remat, fp32 mamba scan states.",
             dict(algorithm="sdm_dsgd", gossip_mode="fixedk_packed")),
            ("fused_state",
             "H1: drop the d buffer (2 instead of 3 param-size buffers); "
             "predict ~33% argument-bytes cut like pair 1.",
             dict(algorithm="sdm_dsgd_fused", gossip_mode="fixedk_packed")),
            ("bf16_mamba_scan",
             "H2: the (b,s,d_inner,d_state) discretized scan elements are "
             "the single largest activation tensor (4.3e9 elements/node); "
             "storing dA/dBx in bf16 halves that traffic; predict "
             "bytes-accessed drop with unchanged flops.",
             dict(algorithm="sdm_dsgd_fused", gossip_mode="fixedk_packed",
                  cfg_overrides={"mamba_scan_dtype": "bfloat16"})),
        ]),
}


def run_pair(pair: str, mesh: str = "single_pod",
             out_root: str = "experiments/perf") -> list:
    from repro.launch.dryrun import build_case

    spec = EXPERIMENTS[pair]
    # jamba's unrolled probe compiles are prohibitively slow on 1 CPU core;
    # its variants compare raw HLO counts + exact per-device memory instead.
    use_probes = pair != "jamba_train"
    rows = []
    for name, hypothesis, kw in spec["variants"]:
        rec = build_case(spec["arch"], spec["shape"], mesh,
                         kw.get("method", kw.get("algorithm", "sdm_dsgd")),
                         kw.get("gossip_mode", "fixedk_packed"),
                         out_root="", verbose=False, probes=use_probes,
                         sdm_overrides=kw.get("sdm_overrides"),
                         cfg_overrides=kw.get("cfg_overrides"),
                         rule_overrides=kw.get("rule_overrides"))
        row = analyze_record(rec)
        row["variant"] = name
        row["hypothesis"] = hypothesis
        row["collective_ops"] = rec["collective_ops"]
        # loop-corrected per-kind collective bytes (gossip vs TP breakdown)
        kinds = {}
        p1, p2 = rec.get("probe1"), rec.get("probe2")
        n = rec.get("n_periods", 1)
        for kind in ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute"):
            if p1 and p2:
                v1 = p1["collective_bytes"].get(kind, 0)
                v2 = p2["collective_bytes"].get(kind, 0)
                kinds[kind] = v1 + (n - 1) * (v2 - v1)
            else:
                kinds[kind] = rec["collective_bytes"].get(kind, 0)
        row["collective_bytes_by_kind"] = kinds
        row["argument_bytes_per_dev"] = rec["memory"].get(
            "argument_size_in_bytes")
        rows.append(row)
        print(f"  {name:28s} compute={row['compute_s']:.4f}s "
              f"memory={row['memory_s']:.4f}s "
              f"collective={row['collective_s']:.4f}s "
              f"args={row['argument_bytes_per_dev'] / 1e9:.2f}GB "
              f"dominant={row['dominant']}", flush=True)
    if out_root:
        os.makedirs(out_root, exist_ok=True)
        with open(os.path.join(out_root, f"{pair}.json"), "w") as f:
            json.dump(rows, f, indent=2)
    return rows


def run():
    for pair in EXPERIMENTS:
        print(f"# === perf pair {pair}")
        run_pair(pair)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default=None, choices=list(EXPERIMENTS))
    ap.add_argument("--mesh", default="single_pod")
    args = ap.parse_args()
    pairs = [args.pair] if args.pair else list(EXPERIMENTS)
    for pair in pairs:
        print(f"# === perf pair {pair}")
        run_pair(pair, mesh=args.mesh)


if __name__ == "__main__":
    main()
