"""Perf-smoke comparator: BENCH_perf.json vs the checked-in baseline.

CI runs ``python -m benchmarks.check_perf`` right after the wire-plane
perf snapshot. It fails the build when the compiled-HLO structure
regresses past threshold:

* ``permutes_per_step`` may NEVER grow — collectives serialize the
  wire; one extra permute per step is a real latency regression on any
  topology (exact match required, they are schedule-derived integers).
* ``launches`` may grow at most ``LAUNCH_TOL`` (relative) + slack —
  kernel-launch counts wobble by a couple of fusions across XLA
  versions, structural blowups (per-leaf loops, un-fused chains) don't.
* ``wire_bits_hlo`` may never grow for deterministic wire formats —
  payload bytes are the paper's whole point.

It also pins the FUSED-path wins so they cannot silently rot:

* ``qsgdf`` (fused single-buffer quantizer) must stay STRICTLY below
  its unfused qsgd counterpart on both launches and permutes_per_step;
* the fixed-k gather-pack path must stay at most its baseline count
  (on this CPU host the interpret-mode kernel inlines to the identical
  HLO, so equality — not reduction — is the honest gate there);
* every ``overlap=True`` record must report ``overlap_efficiency`` > 0.

Baseline refresh (intentional structure changes): run
``BENCH_PERF_OUT=benchmarks/baselines/perf_wire.json python -m
benchmarks.perf_wire`` and commit the diff with the PR that changes the
structure.
"""
from __future__ import annotations

import json
import os
import sys

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "perf_wire.json")
LAUNCH_TOL = 0.10          # +10%
LAUNCH_SLACK = 2           # plus two launches of absolute wobble

#: fused case -> unfused counterpart whose cost it must strictly beat
FUSED_BEATS = {
    "sdm-dsgd/ring/qsgdf:4": "sdm-dsgd/ring/qsgd:4",
}


def check(bench_path: str = "BENCH_perf.json",
          baseline_path: str = BASELINE) -> list:
    with open(baseline_path) as f:
        base = {r["case"]: r for r in json.load(f)["records"]}
    with open(bench_path) as f:
        bench = json.load(f)
    cur = {r["case"]: r for r in bench["records"]}

    failures = []
    for case, b in base.items():
        c = cur.get(case)
        if c is None:
            failures.append(f"{case}: present in baseline, missing from "
                            f"{bench_path}")
            continue
        if c["permutes_per_step"] > b["permutes_per_step"]:
            failures.append(
                f"{case}: permutes_per_step {c['permutes_per_step']} > "
                f"baseline {b['permutes_per_step']}")
        cap = int(b["launches"] * (1 + LAUNCH_TOL)) + LAUNCH_SLACK
        if c["launches"] > cap:
            failures.append(f"{case}: launches {c['launches']} > cap {cap} "
                            f"(baseline {b['launches']})")
        if c["wire_bits_hlo"] > b["wire_bits_hlo"] \
                and c["wire_bits_acc"] == b["wire_bits_acc"]:
            failures.append(
                f"{case}: wire_bits_hlo {c['wire_bits_hlo']} > baseline "
                f"{b['wire_bits_hlo']} at unchanged accounting")

    for fused, unfused in FUSED_BEATS.items():
        f_rec, u_rec = cur.get(fused), base.get(unfused)
        if f_rec is None or u_rec is None:
            failures.append(f"fused-beats pair missing: {fused} / {unfused}")
            continue
        if f_rec["launches"] >= u_rec["launches"]:
            failures.append(
                f"{fused}: launches {f_rec['launches']} not below unfused "
                f"{unfused} baseline {u_rec['launches']}")
        if f_rec["permutes_per_step"] >= u_rec["permutes_per_step"]:
            failures.append(
                f"{fused}: permutes_per_step {f_rec['permutes_per_step']} "
                f"not below unfused {u_rec['permutes_per_step']}")

    for case, c in cur.items():
        if c.get("overlap") and not c.get("overlap_efficiency", 0) > 0:
            failures.append(f"{case}: overlap=True but overlap_efficiency="
                            f"{c.get('overlap_efficiency')}")
    return failures


def main(argv: list) -> int:
    bench_path = argv[1] if len(argv) > 1 else "BENCH_perf.json"
    failures = check(bench_path)
    if failures:
        for msg in failures:
            print(f"PERF-REGRESSION {msg}")
        return 1
    print(f"perf-smoke OK: {bench_path} within {BASELINE} thresholds")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
