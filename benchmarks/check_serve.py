"""Serving comparator: BENCH_serve.json vs the checked-in baseline.

CI runs ``python -m benchmarks.check_serve`` right after the serving
snapshot. It fails the build when the continuous-batching engine loses
its reason to exist:

* ``continuous.tok_s`` must be >= ``static.tok_s`` on the ragged-arrival
  workload — mid-flight admission is the whole point; if draining static
  batches is faster, the scheduler regressed.
* ``continuous.decode_steps`` must stay STRICTLY below static's — the
  structural form of the same win (static decodes every batch until its
  slowest request finishes; continuous retires and refills). Unlike
  wall-clock this is deterministic, so it cannot flake.
* ``decode_launches_flash`` / ``decode_launches_ref`` (kernel launches
  of one compiled paged decode step, ``hlo_analysis.launch_count``) may
  grow at most ``LAUNCH_TOL`` + slack over the baseline — a per-layer
  gather loop or un-fused paged-attention chain shows up here long
  before anyone profiles a TPU.
* ``pages_peak`` must stay below the dense ``max_batch x max_seq``
  reservation (``page_frac`` < 1) — otherwise the paged cache is
  bookkeeping without the memory win.

Baseline refresh (intentional structure changes): run
``BENCH_SERVE_OUT=benchmarks/baselines/serve.json python -m
benchmarks.serve_bench`` and commit the diff with the PR.
"""
from __future__ import annotations

import json
import os
import sys

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "serve.json")
LAUNCH_TOL = 0.10          # +10%
LAUNCH_SLACK = 2           # plus two launches of absolute wobble


def check(bench_path: str = "BENCH_serve.json",
          baseline_path: str = BASELINE) -> list:
    with open(baseline_path) as f:
        base = json.load(f)
    with open(bench_path) as f:
        bench = json.load(f)
    cur = {r["case"]: r for r in bench["records"]}

    failures = []
    st, co = cur.get("static"), cur.get("continuous")
    if st is None or co is None:
        return [f"{bench_path}: missing static/continuous records"]

    if co["tok_s"] < st["tok_s"]:
        failures.append(
            f"continuous tok_s {co['tok_s']} < static {st['tok_s']} on "
            f"ragged arrivals — continuous batching must not lose")
    if co["decode_steps"] >= st["decode_steps"]:
        failures.append(
            f"continuous decode_steps {co['decode_steps']} not below "
            f"static {st['decode_steps']} — slot recycling regressed")
    if co.get("pages_peak", 0) >= co.get("pages_dense", 1):
        failures.append(
            f"pages_peak {co.get('pages_peak')} not below dense "
            f"reservation {co.get('pages_dense')}")

    for key in ("decode_launches_flash", "decode_launches_ref"):
        cap = int(base[key] * (1 + LAUNCH_TOL)) + LAUNCH_SLACK
        if bench.get(key, 1 << 30) > cap:
            failures.append(f"{key} {bench.get(key)} > cap {cap} "
                            f"(baseline {base[key]})")
    return failures


def main(argv: list) -> int:
    bench_path = argv[1] if len(argv) > 1 else "BENCH_serve.json"
    failures = check(bench_path)
    if failures:
        for msg in failures:
            print(f"SERVE-REGRESSION {msg}")
        return 1
    print(f"serve-smoke OK: {bench_path} within {BASELINE} thresholds")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
