"""Kernel micro-benchmarks (CPU interpret mode vs XLA reference).

Wall time on this container is NOT TPU-indicative (interpret mode runs
the kernel body in Python); the derived column reports the structural
quantities that matter for the TPU roofline: bytes moved per call and
the fusion factor (HBM passes saved vs the unfused op chain).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import compressor as compressor_mod, gossip, sparsifier
from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.sdm_update import ref as sdm_ref
from repro.kernels.sdm_update.sdm_update import LANE, sdm_update_pallas

GOSSIP_TOPOLOGIES = ("ring", "torus", "er:0.35", "star", "complete",
                     "dring", "der:0.35", "matchings:4")


def run_gossip_schedules(topologies=GOSSIP_TOPOLOGIES, n_nodes: int = 16,
                         d: int = 1 << 20, p: float = 0.1):
    """Structural cost of (Schedule-Sequence) gossip per topology.

    Wall time on CPU is meaningless for collectives; the quantities that
    matter on the ICI roofline are (a) collective-permute ROUNDS per
    gossip step (latency term: each round is a serialized permute) and
    (b) wire BYTES per node per step, dense vs packed fixed-k (bandwidth
    term — packed must be exactly the p-fraction of dense). mix_dense
    timing is the single-host reference cost for the same exchange.
    Directed graphs (dring/der, gradient-push) and time-varying matching
    sequences report the per-step MEAN degree over one cycle.
    """
    kb = sparsifier.num_kept(d, p)
    comp = compressor_mod.make("fixedk", p=p)
    # exact wire BITS per transmission: value bits + the index
    # side-channel at ceil(log2 d) per kept element; index_sync is the
    # repo's seed-regenerated transport (no index traffic).
    packed_bits_idx = comp.wire_bits((d,))
    packed_bits_sync = comp.wire_bits((d,), index_sync=True)
    for spec in topologies:
        seq = gossip.sequence_by_name(spec, n_nodes)
        wstack = seq.weights_stack()
        # per-step mean in-degree over one cycle (off-diagonal support)
        off = wstack - np.einsum("lij,ij->lij", wstack,
                                 np.eye(seq.n_nodes))
        mean_deg = float(np.mean((np.abs(off) > 1e-12).sum(axis=2)))
        dense = mean_deg * d * 4
        packed = mean_deg * kb * 4
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(n_nodes, 256)), jnp.float32)
        w = jnp.asarray(wstack[0], jnp.float32)
        us = common.timeit_us(jax.jit(lambda w, x: gossip.mix_dense(w, x)),
                              w, x, iters=50)
        common.emit(
            f"gossip_schedule_{seq.name}", us,
            f"rounds={seq.n_rounds};seq_len={seq.length};"
            f"mean_degree={mean_deg:.2f};"
            f"dense_bytes/node/step={dense:.0f};"
            f"packed_bytes/node/step={packed:.0f};"
            f"packed_fraction={packed / dense:.4f};"
            f"packed_bits/node/step={mean_deg * packed_bits_sync:.0f};"
            f"packed_bits_explicit_idx={mean_deg * packed_bits_idx:.0f};"
            "index_overhead_frac="
            f"{packed_bits_idx / packed_bits_sync - 1.0:.4f}")


def run():
    run_gossip_schedules()
    # sdm_update: 7 input + 3 output tensors, one pass each = 10 tensor
    # touches fused; the unfused chain touches ~22 (clip r/w, noise add,
    # mixing axpy chain, mask, scale, 3 state updates).
    rows = 64
    rng = np.random.default_rng(0)
    shape = (rows, LANE)
    f = lambda: jnp.asarray(rng.normal(size=shape), jnp.float32)
    bits = lambda: jnp.asarray(rng.integers(0, 2**32, size=shape,
                                            dtype=np.uint32))
    ops = (f(), f(), f(), f(), bits(), bits(), bits())
    kw = dict(p=0.25, theta=0.4, gamma=0.05, sigma=0.7, clip_c=1.5,
              self_w=1.0 / 3.0)

    us_ref = common.timeit_us(
        jax.jit(lambda *a: sdm_ref.sdm_update_ref(*a, **kw)), *ops, iters=50)
    bytes_moved = 10 * rows * LANE * 4
    common.emit("sdm_update_xla_ref", us_ref,
                f"bytes/call={bytes_moved};fused_tensor_touches=10_vs_22")
    us_k = common.timeit_us(
        lambda *a: sdm_update_pallas(*a, block_rows=32, interpret=True, **kw),
        *ops, iters=3)
    common.emit("sdm_update_pallas_interpret", us_k,
                "interpret-mode;correctness-path-only")

    # flash attention: streaming (block_q x block_k) tiles vs dense scores.
    b, s, h, dh = 1, 256, 4, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    us_ref = common.timeit_us(
        jax.jit(lambda q, k, v: flash_attention(q, k, v, use_kernel=False)),
        q, k, v, iters=50)
    dense_bytes = b * h * s * s * 4
    flash_vmem = 128 * 128 * 4
    common.emit("flash_attn_xla_ref", us_ref,
                f"dense_scores_bytes={dense_bytes};"
                f"flash_tile_bytes={flash_vmem}")
    us_k = common.timeit_us(
        lambda q, k, v: flash_attention(q, k, v, use_kernel=True,
                                        interpret=True), q, k, v, iters=2)
    common.emit("flash_attn_pallas_interpret", us_k,
                "interpret-mode;correctness-path-only")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default=None,
                    help="bench only this gossip topology "
                         "(default: the full sweep)")
    ap.add_argument("--nodes", type=int, default=16)
    args = ap.parse_args()
    if args.topology is not None:
        run_gossip_schedules((args.topology,), n_nodes=args.nodes)
    else:
        run()
