"""Roofline analysis from the dry-run artifacts (deliverable (g)).

Per (arch x shape x mesh) this derives the three roofline terms from the
compiled dry-run records written by repro.launch.dryrun:

    compute    = HLO_FLOPs_total   / (chips * 197e12 FLOP/s)
    memory     = HLO_bytes_total   / (chips * 819e9  B/s)
    collective = collective_bytes  / (chips * 50e9   B/s per ICI link)

Conventions (verified empirically on the host platform, see
EXPERIMENTS.md §Dry-run): cost_analysis() reports PER-DEVICE flops/bytes
for an SPMD module, and collective_bytes sums result shapes over the
whole module (also per-device program). MODEL_FLOPS = 6*N*D uses active
params for MoE.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # B/s per chip
ICI_BW = 50e9           # B/s per link

__all__ = ["analyze_record", "load_records", "summarize", "run"]


def _loop_corrected(rec: dict, key: str) -> float:
    """XLA cost_analysis counts while-loop (scan) bodies ONCE (verified
    empirically — see EXPERIMENTS.md §Dry-run). The dry-run therefore
    compiles two UNROLLED probe variants (1 and 2 layer-periods); the
    full-depth value is probe1 + (n_periods - 1) * (probe2 - probe1).
    Falls back to the raw value when probes are absent."""
    p1, p2 = rec.get("probe1"), rec.get("probe2")
    if not p1 or not p2:
        return _raw(rec, key)
    n = rec.get("n_periods", 1)
    v1, v2 = _raw_from(p1, key), _raw_from(p2, key)
    return v1 + (n - 1) * (v2 - v1)


def _raw_from(d: dict, key: str) -> float:
    if key == "collective":
        return float(d["collective_bytes"].get("total", 0))
    return float(d[key])


def _raw(rec: dict, key: str) -> float:
    return _raw_from(rec, key)


def analyze_record(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    flops_dev = _loop_corrected(rec, "flops")
    bytes_dev = _loop_corrected(rec, "bytes_accessed")
    coll_dev = _loop_corrected(rec, "collective")

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    if rec.get("algorithm") != "serve":
        model_flops = 6 * rec["model_params_active"] * rec["tokens_per_step"]
    else:
        # serving: 2*N*D per generated/prefilled token (forward only)
        model_flops = 2 * rec["model_params_active"] * rec["tokens_per_step"]
    hlo_total = flops_dev * chips
    useful = model_flops / hlo_total if hlo_total > 0 else float("nan")

    bound_time = max(terms.values())
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        algorithm=rec.get("algorithm"), chips=chips,
        **{k: round(v, 6) for k, v in terms.items()},
        dominant=dominant.replace("_s", ""),
        model_flops=model_flops, hlo_flops_total=hlo_total,
        useful_flop_ratio=round(useful, 4),
        roofline_step_s=round(bound_time, 6),
        peak_memory_per_dev=rec["memory"].get("peak_memory_in_bytes"),
    )


def load_records(root: str = "experiments/dryrun") -> List[dict]:
    out = []
    for mesh_name in sorted(os.listdir(root)) if os.path.isdir(root) else []:
        mdir = os.path.join(root, mesh_name)
        for arch in sorted(os.listdir(mdir)):
            adir = os.path.join(mdir, arch)
            for f in sorted(os.listdir(adir)):
                with open(os.path.join(adir, f)) as fh:
                    out.append(json.load(fh))
    return out


def summarize(root: str = "experiments/dryrun") -> List[dict]:
    rows = []
    for rec in load_records(root):
        row = analyze_record(rec)
        if row is not None:
            rows.append(row)
    return rows


def run(root: str = "experiments/dryrun"):
    rows = summarize(root)
    if not rows:
        print("roofline: no dry-run records found (run repro.launch.dryrun)")
        return []
    hdr = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
           "dominant", "useful_flop_ratio")
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r[h]) for h in hdr))
    # run.py CSV convention
    for r in rows:
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
              f"{r['roofline_step_s'] * 1e6:.1f},"
              f"dominant={r['dominant']};useful={r['useful_flop_ratio']}")
    return rows


if __name__ == "__main__":
    run()
