"""Figure 2: DC-DSGD diverges at p=0.2 (theta=1) while SDM-DSGD converges
with theta chosen inside Lemma 1's bound. Also verifies the paper's ER
consensus matrix gives lambda_n = 1/3 (so the theta bound is 2p/(2/3+gL)).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines, sdm_dsgd, theory
from repro.train.trainer import run_decentralized


def run(steps: int = 250, gamma: float = 0.05):
    topo, params, grad_fn, eval_fn, batches, m = common.make_mlr_testbed()
    results = {}

    # DC-DSGD: theta = 1 at p = 0.2 — below Remark 1's validity threshold.
    min_p = theory.dcdsgd_min_p(topo.lambda_n)
    assert 0.2 < min_p, (0.2, min_p)
    dc = baselines.dcdsgd_config(p=0.2, gamma=gamma)
    res_dc = run_decentralized(topo=topo, algorithm="dc-dsgd", sdm_cfg=dc,
                               params_stack=params, grad_fn=grad_fn,
                               batches=batches, steps=steps)
    results["dc_dsgd_p0.2"] = res_dc.losses

    # SDM-DSGD: theta=0.55 < 2p/(1 - lambda_n + gamma L) ~= 0.6.
    bound = theory.theta_upper_bound(0.2, topo.lambda_n, gamma, 1.0)
    sdm = sdm_dsgd.SDMConfig(p=0.2, theta=min(0.55, 0.9 * bound), gamma=gamma)
    sdm.validate_against(topo)
    res_sdm = run_decentralized(topo=topo, algorithm="sdm-dsgd", sdm_cfg=sdm,
                                params_stack=params, grad_fn=grad_fn,
                                batches=batches, steps=steps)
    results["sdm_dsgd_p0.2"] = res_sdm.losses

    dc_final = res_dc.losses[-1]
    sdm_final = res_sdm.losses[-1]
    dc_diverged = (not np.isfinite(dc_final)) or dc_final > 2 * res_dc.losses[0]
    sdm_converged = np.isfinite(sdm_final) and sdm_final < 0.8 * res_sdm.losses[0]
    derived = (f"lambda_n={topo.lambda_n:.3f};theta_bound={bound:.3f};"
               f"dc_final={dc_final:.3e};sdm_final={sdm_final:.4f};"
               f"dc_diverged={dc_diverged};sdm_converged={sdm_converged}")
    common.emit("fig2_divergence", res_sdm.wall_s * 1e6 / steps, derived)
    assert dc_diverged and sdm_converged, derived
    return results


if __name__ == "__main__":
    run()
