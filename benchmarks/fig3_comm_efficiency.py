"""Figure 3: training loss / test accuracy vs COMMUNICATED NON-ZERO
ELEMENTS for DSGD (p=1), DC-DSGD (p=0.5, theta=1) and SDM-DSGD
(p=0.2, theta<bound) — the paper's communication-efficiency headline:
under equal communication budget SDM-DSGD reaches lower loss / higher
accuracy.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines, sdm_dsgd, theory
from repro.train.trainer import run_decentralized


def run(comm_budget_elems: int = 60_000_000, gamma: float = 0.05,
        topology: str = "er:0.35"):
    topo, params, grad_fn, eval_fn, batches, m = common.make_mlr_testbed(
        topology_spec=topology)
    d = sum(int(x.size) for x in __import__("jax").tree.leaves(params)) \
        // topo.n_nodes

    runs = {
        "dsgd_p1.0": ("dsgd", sdm_dsgd.SDMConfig(p=1.0, theta=1.0,
                                                 gamma=gamma)),
        "dc_dsgd_p0.5": ("dc_dsgd", baselines.dcdsgd_config(p=0.5,
                                                            gamma=gamma)),
        "sdm_dsgd_p0.2": ("sdm_dsgd", sdm_dsgd.SDMConfig(
            p=0.2, theta=min(0.55, 0.9 * theory.theta_upper_bound(
                0.2, topo.lambda_n, gamma, 1.0)), gamma=gamma)),
    }
    curves = {}
    finals = {}
    for name, (algo, cfg) in runs.items():
        per_step = int(round(cfg.p * d)) * topo.n_nodes
        steps = max(10, comm_budget_elems // per_step)
        res = run_decentralized(topo=topo, algorithm=algo, sdm_cfg=cfg,
                                params_stack=params, grad_fn=grad_fn,
                                batches=batches, steps=steps,
                                eval_fn=eval_fn, eval_every=max(steps // 4, 1))
        curves[name] = (res.comm_elements, res.losses, res.eval_accuracy)
        finals[name] = (res.losses[-1], res.eval_accuracy[-1])

    # At the SAME communication budget, sparser methods take more steps and
    # end lower (the paper's Fig. 3 ordering).
    derived = f"topo={topo.name};" + ";".join(
        f"{k}:loss={v[0]:.4f},acc={v[1]:.4f}" for k, v in finals.items())
    common.emit("fig3_comm_efficiency", 0.0, derived)
    assert finals["sdm_dsgd_p0.2"][0] <= finals["dsgd_p1.0"][0] * 1.02, derived
    assert finals["sdm_dsgd_p0.2"][1] >= finals["dsgd_p1.0"][1] - 0.01, derived
    return curves


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="er:0.35",
                    help="gossip graph spec (topology.by_name syntax)")
    ap.add_argument("--comm-budget", type=int, default=60_000_000)
    args = ap.parse_args()
    run(comm_budget_elems=args.comm_budget, topology=args.topology)
