"""Figure 3: training loss / test accuracy vs COMMUNICATED NON-ZERO
ELEMENTS for DSGD (p=1), DC-DSGD (p=0.5, theta=1) and SDM-DSGD
(p=0.2, theta<bound) — the paper's communication-efficiency headline:
under equal communication budget SDM-DSGD reaches lower loss / higher
accuracy. ``--methods`` extends the sweep with any registry method
(e.g. gradient-push, evaluated on its directed graph).
"""
from __future__ import annotations


from benchmarks import common
from repro.core import baselines, method as method_mod, sdm_dsgd, theory
from repro.train.trainer import run_decentralized

# the paper's three curves; extra registry methods attach via --methods
PAPER_RUNS = ("dsgd", "dc-dsgd", "sdm-dsgd")


def _cfg_for(meth_name: str, topo, gamma: float):
    if meth_name == "dsgd":
        return sdm_dsgd.SDMConfig(p=1.0, theta=1.0, gamma=gamma)
    if meth_name == "dc-dsgd":
        return baselines.dcdsgd_config(p=0.5, gamma=gamma)
    if meth_name == "sdm-dsgd":
        lambda_n = topo.lambda_n if hasattr(topo, "lambda_n") else 1.0 / 3.0
        return sdm_dsgd.SDMConfig(
            p=0.2, theta=min(0.55, 0.9 * theory.theta_upper_bound(
                0.2, lambda_n, gamma, 1.0)), gamma=gamma)
    return sdm_dsgd.SDMConfig(p=1.0, theta=1.0, gamma=gamma)


def run(comm_budget_elems: int = 60_000_000, gamma: float = 0.05,
        topology: str = "er:0.35", methods=PAPER_RUNS):
    topo, params, grad_fn, eval_fn, batches, m = common.make_mlr_testbed(
        topology_spec=topology)
    import jax

    per_node = jax.tree.map(lambda x: x[0], params)
    curves = {}
    finals = {}
    for name in methods:
        meth = method_mod.get(name)
        raw = _cfg_for(meth.name, topo, gamma)
        cfg = meth.coerce_config(raw)
        per_step = method_mod.transmitted_elements(
            meth, per_node, cfg, seq=topo) * topo.n_nodes
        per_step_bits = method_mod.transmitted_bits(
            meth, per_node, cfg, seq=topo) * topo.n_nodes
        steps = max(10, comm_budget_elems // per_step)
        res = run_decentralized(topo=topo, algorithm=meth.name, sdm_cfg=cfg,
                                params_stack=params, grad_fn=grad_fn,
                                batches=batches, steps=steps,
                                eval_fn=eval_fn, eval_every=max(steps // 4, 1))
        key = meth.name.replace("-", "_")
        curves[key] = (res.comm_elements, res.comm_bits, res.losses,
                       res.eval_accuracy)
        finals[key] = (res.losses[-1], res.eval_accuracy[-1],
                       res.comm_bits[-1], per_step_bits)

    # At the SAME communication budget, sparser methods take more steps and
    # end lower (the paper's Fig. 3 ordering). Wire BITS per step are the
    # honest axis (index side-channels, quantized widths) next to the
    # paper's non-zero-element count.
    derived = f"topo={topo.name};" + ";".join(
        f"{k}:loss={v[0]:.4f},acc={v[1]:.4f},"
        f"bits={v[2]:.3e},bits/step={v[3]:.3e}"
        for k, v in finals.items())
    common.emit("fig3_comm_efficiency", 0.0, derived)
    if "sdm_dsgd" in finals and "dsgd" in finals:
        assert finals["sdm_dsgd"][0] <= finals["dsgd"][0] * 1.02, derived
        assert finals["sdm_dsgd"][1] >= finals["dsgd"][1] - 0.01, derived
    return curves


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default="er:0.35",
                    help="gossip graph spec (gossip.sequence_by_name syntax, "
                         "incl. dring/der/matchings:<L>)")
    ap.add_argument("--methods", default=",".join(PAPER_RUNS),
                    help="comma list of method registry names to sweep")
    ap.add_argument("--comm-budget", type=int, default=60_000_000)
    args = ap.parse_args()
    run(comm_budget_elems=args.comm_budget, topology=args.topology,
        methods=tuple(args.methods.split(",")))
