"""Table 1: test accuracy under a fixed (eps, delta=1e-5)-DP budget.

For each eps the Gaussian sigma comes from Corollary 2 and training stops
at Theorem 4's T_max — exactly the paper's procedure ("we keep track of
the privacy loss based on Theorem 1"). Claims verified:
  (i)  accuracy increases with the privacy budget eps;
  (ii) under the same budget SDM-DSGD >= DC-DSGD >= DSGD.
"""
from __future__ import annotations



from benchmarks import common
from repro.core import privacy, sdm_dsgd, theory
from repro.train.trainer import run_decentralized

G_CLIP = 5.0      # the paper's C = 5 coordinate clip
DELTA = 1e-5


def _sigma_and_T(eps: float, m: int, p: float, max_steps: int):
    """Corollary 2 + Theorem 4, capped for CPU runtime."""
    t_max = privacy.max_iterations(G=G_CLIP, m=m, p=p, eps=eps, delta=DELTA)
    t = min(t_max, max_steps)
    # T is capped below T_max for CPU runtime -> Corollary 2's sigma falls
    # below the amplification floor; clamp=True floors it (extra privacy).
    sigma = privacy.sigma_for_budget(G=G_CLIP, m=m, p=p, T=t, eps=eps,
                                     delta=DELTA, clamp=True)
    return sigma, t


def run(eps_grid=(0.03, 0.05, 0.1), max_steps: int = 1500,
        gamma: float = 0.05):
    # smaller local datasets (m=100) so Theorem 4's T_max = O(m^4 / p)
    # lands in CPU-runnable range; the p-dependence of T_max is the
    # paper's mechanism: sparser transmission -> more iterations allowed.
    topo, params, grad_fn, eval_fn, batches, m = common.make_mlr_testbed(
        n_train=5000)
    table = {}
    for eps in eps_grid:
        for name, (algo, p, theta) in {
            "dsgd": ("dsgd", 1.0, 1.0),
            "dc_dsgd": ("dc_dsgd", 0.5, 1.0),
            "sdm_dsgd": ("sdm_dsgd", 0.2, None),
        }.items():
            sigma, t = _sigma_and_T(eps, m, p, max_steps)
            if theta is None:
                theta = min(0.55, 0.9 * theory.theta_upper_bound(
                    p, topo.lambda_n, gamma, 1.0))
            cfg = sdm_dsgd.SDMConfig(p=p, theta=theta, gamma=gamma,
                                     sigma=sigma, clip_c=G_CLIP)
            pp = privacy.PrivacyParams(G=G_CLIP, m=m, tau=common.BATCH_PER_NODE / m,
                                       p=p, sigma=sigma, delta=DELTA)
            res = run_decentralized(topo=topo, algorithm=algo, sdm_cfg=cfg,
                                    params_stack=params, grad_fn=grad_fn,
                                    batches=batches, steps=t, privacy=pp,
                                    eps_target=eps, eval_fn=eval_fn,
                                    eval_every=t)
            table[(eps, name)] = res.eval_accuracy[-1]

    derived = ";".join(f"eps{e}/{n}={a:.4f}" for (e, n), a in table.items())
    common.emit("table1_privacy_accuracy", 0.0, derived)
    # claim (i): accuracy increases with eps for SDM-DSGD
    accs = [table[(e, "sdm_dsgd")] for e in eps_grid]
    assert accs[-1] >= accs[0] - 0.02, derived
    # claim (ii): SDM-DSGD at least matches baselines at the tightest budget
    e0 = eps_grid[0]
    assert table[(e0, "sdm_dsgd")] >= table[(e0, "dsgd")] - 0.02, derived
    return table


if __name__ == "__main__":
    run()
