"""Machine-readable wire-plane perf snapshot -> BENCH_perf.json.

Compiles one distributed step per (method, mode) case on an 8-node fake
CPU mesh with a MULTI-LEAF parameter tree and records the structural
quantities the wire-plane transport optimizes — the numbers future perf
PRs regress against:

    permutes_per_step   collective-permutes per compiled step (latency
                        serialization; == R per exchange on the plane
                        path, leaf-count-independent)
    sort_count          top-k/sort kernels per step (one batched draw
                        per plane, not per leaf/round)
    wire_bits_hlo       summed collective-permute payload bits per step
    wire_bits_acc       the static accounting's per-step prediction
    collective_bytes    hlo_analysis byte totals per step
    launches / fusion_factor
                        kernel-launch proxy (``hlo_analysis.launch_count``:
                        opcode-PARSED fusions + custom-calls + sorts +
                        collectives, async pairs counted once at the
                        ``-start``) and instructions-per-launch —
                        HLO-structural, CPU wall time is not TPU-indicative
    permute_starts / permute_dones
                        async collective-permute pair counts, reported
                        DISTINCTLY (both 0 when the scheduler emits the
                        sync form)
    overlap_efficiency  fraction of wire time hidden under compute for
                        the one-step-stale overlapped transport, under
                        the nominal edge-fleet machine model (cost-
                        analysis flops / permute payload bytes); 0.0 by
                        definition for overlap=off

Wall-clock is deliberately NOT recorded: this container runs interpret-
mode CPU; the HLO structure is the portable signal.

``benchmarks/baselines/perf_wire.json`` pins the snapshot CI regresses
against (``python -m benchmarks.check_perf``): launches and
permutes_per_step may not grow past threshold, and the fused wire paths
(qsgdf, the pallas gather-pack) must stay strictly below their unfused
counterparts.

Run via ``python -m benchmarks.run --only perf`` (writes BENCH_perf.json
at the repo root; CI uploads it as an artifact) or directly:
``python -m benchmarks.perf_wire``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

OUT_PATH = os.environ.get("BENCH_PERF_OUT", "BENCH_perf.json")

CASES = [
    # (method, topology, mode, overlap)
    ("sdm-dsgd", "ring", "fixedk_packed", False),
    ("sdm-dsgd", "ring", "bernoulli", False),
    ("sdm-dsgd", "ring", "qsgd:4", False),
    ("sdm-dsgd-fused", "ring", "fixedk_rows", False),
    ("dsgd", "ring", "-", False),
    ("gradient-push", "dring", "fixedk", False),
    # fused single-buffer quantizer: 1 payload leaf, 1 pallas pack
    # launch — must beat qsgd:4 on launches AND permutes_per_step
    ("sdm-dsgd", "ring", "qsgdf:4", False),
    # overlapped one-step-stale transport: same wire, hidden latency
    ("sdm-dsgd", "ring", "fixedk_packed", True),
    ("sdm-dsgd", "ring", "qsgdf:4", True),
]

# nominal edge-fleet machine model for the overlap_efficiency estimate
# (matches sim/fleet bandwidth scale): compute throughput and wire
# bandwidth used to convert HLO flops / payload bytes into time.
NOMINAL_FLOPS_PER_S = 1.0e12
NOMINAL_WIRE_BYTES_PER_S = 1.25e9          # 10 Gb/s edge uplink


def case_id(meth_name: str, topo_spec: str, mode: str,
            overlap: bool) -> str:
    return f"{meth_name}/{topo_spec}/{mode}" + ("+ov" if overlap else "")

# multi-leaf tree (the leaf-count-independence witness)
PARAM_SHAPES = {"emb": (9, 33), "w1": (64, 7), "b1": (71,),
                "w2": (3, 5, 11), "b2": (13,)}


def _emit() -> None:
    """Subprocess body: needs XLA_FLAGS set BEFORE jax import."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core import (baselines, gossip, gradient_push,
                            method as method_mod, plane as plane_mod,
                            sdm_dsgd, topology)
    from repro.launch import hlo_analysis

    n = 8
    records = []
    for meth_name, topo_spec, mode, overlap in CASES:
        meth = method_mod.get(meth_name)
        topo = topology.directed_ring(n) if topo_spec == "dring" \
            else topology.by_name(topo_spec, n)
        seq = gossip.ensure_sequence(gossip.schedule_from_topology(topo))
        if meth.config_cls is sdm_dsgd.SDMConfig:
            kw = dict(p=0.25, theta=0.15, gamma=0.1, overlap=overlap)
            cfg = meth.coerce_config(sdm_dsgd.SDMConfig(
                **(dict(kw, compressor=mode)
                   if mode.split(":")[0] in ("qsgd", "qsgdf")
                   else dict(kw, mode=mode))))
        elif meth.config_cls is gradient_push.GradientPushConfig:
            cfg = gradient_push.GradientPushConfig(
                gamma=0.1, compressor=None if mode == "-" else mode,
                p=0.25, overlap=overlap)
        else:
            cfg = baselines.DSGDConfig(gamma=0.1)

        rng = np.random.default_rng(0)
        is_shape = lambda v: isinstance(v, tuple) and all(
            isinstance(e, int) for e in v)
        p0 = jax.tree.map(
            lambda s: jnp.asarray(rng.normal(size=s) * 0.1, jnp.float32),
            PARAM_SHAPES, is_leaf=is_shape)
        stack = jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (n,) + v.shape), p0)

        mesh = compat.make_mesh((n,), ("data",))
        ex = meth.make_distributed(seq, cfg, "data")
        key = jax.random.PRNGKey(0)

        def one_step(stack):
            def inner(p):
                p = jax.tree.map(lambda v: jnp.squeeze(v, 0), p)
                me = jax.lax.axis_index("data")
                state = ex.init(p, me)

                # scan >= 2 steps so the exchanged differential is
                # data-dependent — XLA folds away collectives whose
                # operand is the constant-zero d_0 of a single unrolled
                # first step, which would under-count permutes/step.
                def body(state, _):
                    state, _ = ex.step(
                        state,
                        lambda pp: (jax.tree.map(lambda v: v * 0.01, pp),
                                    0.0),
                        base_key=key)
                    return state, None

                state, _ = jax.lax.scan(body, state, None, length=2)
                return jax.tree.map(lambda v: v[None], state.x)

            return compat.shard_map(inner, mesh=mesh, in_specs=(P("data"),),
                                    out_specs=P("data"), axis_names={"data"},
                                    check_vma=False)(stack)

        compiled = jax.jit(one_step).lower(stack).compile()
        hlo = compiled.as_text()
        payloads = hlo_analysis.permute_payloads(hlo)

        per_node = p0
        spec = plane_mod.ParamPlane.for_tree(per_node)
        if meth.config_cls is sdm_dsgd.SDMConfig:
            acc_bits = sdm_dsgd.transmitted_bits_per_step(per_node, cfg,
                                                          seq=seq)
        else:
            acc_bits = method_mod.transmitted_bits(meth, per_node, cfg,
                                                   seq=seq)
        # opcode-PARSED counts (the old string-match heuristic counted
        # operand references and fused-computation names as launches)
        instr = hlo_analysis.instruction_counts(hlo)
        n_instr = sum(instr.values())
        sorts = instr.get("sort", 0)
        launches = hlo_analysis.launch_count(hlo)
        pairs = hlo_analysis.async_collective_pairs(hlo).get(
            "collective-permute", {"sync": 0, "start": 0, "done": 0})

        # model-based overlap efficiency: wall time on this CPU host is
        # not TPU-indicative, so convert the compiled module's flops and
        # permute payload bytes into time under the nominal machine
        # model. With overlap the per-step wire cost is what compute
        # cannot hide: efficiency = min(1, t_compute / t_wire).
        wire_bytes = max(sum(p["bytes"] for p in payloads), 1)
        try:
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            flops = float(ca.get("flops", 0.0))
        except Exception:
            flops = 0.0
        flops = max(flops, float(n_instr))     # floor: never a 0 proxy
        t_compute = flops / NOMINAL_FLOPS_PER_S
        t_wire = wire_bytes / NOMINAL_WIRE_BYTES_PER_S
        overlap_eff = round(min(1.0, t_compute / t_wire), 4) \
            if overlap else 0.0

        records.append({
            "case": case_id(meth_name, topo_spec, mode, overlap),
            "overlap": overlap,
            "n_leaves": len(jax.tree.leaves(stack)),
            "plane_shapes": spec.plane_shapes(),
            "schedule_rounds": seq.schedules[0].n_rounds,
            "permutes_per_step": hlo_analysis.collective_permute_count(hlo),
            "permute_starts": pairs["start"],
            "permute_dones": pairs["done"],
            "sort_count": sorts,
            "wire_bits_hlo": sum(p["bits"] for p in payloads),
            "wire_bits_acc": acc_bits,
            "collective_bytes": hlo_analysis.collective_bytes(hlo),
            "hlo_instructions": n_instr,
            "launches": launches,
            "fusion_factor": round(n_instr / max(launches, 1), 2),
            "overlap_efficiency": overlap_eff,
        })
    print("BENCH_PERF_JSON " + json.dumps(
        {"n_nodes": n, "records": records}))


def run(out_path: str = OUT_PATH) -> dict:
    from benchmarks import common

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.perf_wire", "--emit"],
        capture_output=True, text=True, env=env, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"perf_wire subprocess failed:\n{out.stderr[-3000:]}")
    payload = next(line for line in out.stdout.splitlines()
                   if line.startswith("BENCH_PERF_JSON "))
    data = json.loads(payload[len("BENCH_PERF_JSON "):])
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    for rec in data["records"]:
        common.emit(
            "perf_wire_" + rec["case"].replace("/", "_"), 0.0,
            f"permutes/step={rec['permutes_per_step']};"
            f"rounds={rec['schedule_rounds']};"
            f"n_leaves={rec['n_leaves']};sorts={rec['sort_count']};"
            f"wire_bits_hlo={rec['wire_bits_hlo']};"
            f"wire_bits_acc={rec['wire_bits_acc']};"
            f"launches={rec['launches']};"
            f"perm_start={rec['permute_starts']};"
            f"perm_done={rec['permute_dones']};"
            f"overlap_eff={rec['overlap_efficiency']};"
            f"fusion_factor={rec['fusion_factor']}")
    print(f"# wrote {out_path}")
    return data


if __name__ == "__main__":
    if "--emit" in sys.argv:
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
        _emit()
    else:
        run()
