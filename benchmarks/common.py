"""Shared benchmark scaffolding: the paper's 50-node MLR test bed."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import gossip, topology
from repro.data import classification_dataset, node_partitioned_batches
from repro.models import vision_small

N_NODES = 50
N_FEATURES = 784          # MNIST-shaped
N_CLASSES = 10
N_TRAIN = 10_000
BATCH_PER_NODE = 16


def make_mlr_testbed(seed: int = 0, n_train: int = N_TRAIN,
                     topology_spec: str = "er:0.35"):
    """Paper §5 setup: ER(50, 0.35) graph + MLR on MNIST-shaped data.

    ``topology_spec`` swaps the gossip graph (gossip.sequence_by_name
    syntax) so every paper figure can be reproduced on ring/torus/star,
    the directed dring/der graphs (gradient-push), or a time-varying
    "matchings:<L>" sequence as well.
    """
    if topology_spec.startswith("matchings"):
        topo = gossip.sequence_by_name(topology_spec, N_NODES, seed=seed)
    else:
        topo = topology.by_name(topology_spec, N_NODES, seed=seed)
    (x_tr, y_tr), (x_te, y_te) = classification_dataset(
        N_FEATURES, N_CLASSES, n_train, 2000, seed=seed)
    params0 = vision_small.mlr_init(jax.random.PRNGKey(seed))
    params_stack = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (N_NODES,) + p.shape), params0)
    grad_fn = vision_small.make_stacked_grad_fn(vision_small.mlr_apply)
    eval_fn = vision_small.make_eval_fn(vision_small.mlr_apply,
                                        jnp.asarray(x_te), jnp.asarray(y_te))
    batches = node_partitioned_batches(x_tr, y_tr, N_NODES, BATCH_PER_NODE,
                                       seed=seed)
    m_local = n_train // N_NODES
    return topo, params_stack, grad_fn, eval_fn, batches, m_local


def timeit_us(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
