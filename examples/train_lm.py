"""End-to-end driver: train a ~100M-param transformer with SDM-DSGD.

A gemma2-family model (12 layers, d_model=512 -> ~104M params incl.
embeddings) trains for a few hundred steps on the synthetic token stream
across 4 simulated edge nodes (ring gossip, sparsified differentials,
Gaussian masking), with loss dropping well below the unigram floor.

  PYTHONPATH=src python examples/train_lm.py --steps 300    # full run
  PYTHONPATH=src python examples/train_lm.py --steps 20     # quick look
"""
import argparse
import dataclasses
import os
import time


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch-per-node", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=12)
    return ap.parse_args()


# device-count faking must precede the jax import
_ARGS = _parse_args()
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={_ARGS.nodes}")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import gemma2_2b  # noqa: E402
from repro.core.sdm_dsgd import SDMConfig  # noqa: E402
from repro.data import TokenStream  # noqa: E402
from repro.launch.mesh import make_mesh_by_name  # noqa: E402
from repro.train import steps as steps_mod  # noqa: E402


def main() -> None:
    args = _ARGS

    cfg = dataclasses.replace(
        gemma2_2b.config(), name="gemma2-100m",
        n_layers=args.layers, d_model=args.d_model, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=4 * args.d_model, vocab_size=32_768,
        sliding_window=128, attn_chunk_q=None)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  params={n_params / 1e6:.1f}M  "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    mesh = make_mesh_by_name(str(args.nodes))  # nodes-only mesh on CPU
    tc = steps_mod.DistributedTrainConfig(
        model=cfg,
        sdm=SDMConfig(p=0.25, theta=0.5, gamma=0.5, sigma=0.0, clip_c=1.0),
        method="sdm-dsgd", param_dtype=jnp.float32)

    state = steps_mod.init_distributed_state(tc, mesh, jax.random.PRNGKey(0))
    step_fn = jax.jit(steps_mod.make_distributed_train(tc, mesh))
    stream = TokenStream(vocab_size=cfg.vocab_size,
                         batch=args.nodes * args.batch_per_node,
                         seq_len=args.seq, seed=0)

    losses = []
    t_start = time.time()
    for t in range(args.steps):
        tokens, labels = stream.batch_at(t)
        t0 = time.time()
        state, loss = step_fn(state, jnp.asarray(tokens), jnp.asarray(labels))
        losses.append(float(loss))
        if t % 10 == 0 or t == args.steps - 1:
            print(f"step {t:4d} loss {losses[-1]:.4f} "
                  f"({time.time() - t0:.2f}s)", flush=True)
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"in {time.time() - t_start:.0f}s "
          f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
