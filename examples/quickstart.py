"""Quickstart: private + communication-efficient decentralized training.

Eight simulated edge nodes on a ring train a shared logistic-regression
model with SDM-DSGD: each node only ever transmits a Bernoulli(p)-
sparsified, Gaussian-masked differential to its two ring neighbours.
Prints loss, accuracy, the communicated element count, and the (eps,
delta)-DP spend from the Theorem-1 accountant.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (PrivacyParams, SDMConfig,
                        sdm_dsgd, topology)
from repro.data import classification_dataset, node_partitioned_batches
from repro.models import vision_small
from repro.train.trainer import run_decentralized

N_NODES, FEATURES, CLASSES = 8, 64, 10
STEPS = 300


def main() -> None:
    topo = topology.ring(N_NODES)
    cfg = SDMConfig(p=0.2, theta=0.25, gamma=0.05, sigma=1.0, clip_c=5.0)
    cfg.validate_against(topo)  # Lemma 1's theta bound

    (x_tr, y_tr), (x_te, y_te) = classification_dataset(
        FEATURES, CLASSES, 4000, 1000, seed=0)
    params0 = vision_small.mlr_init(jax.random.PRNGKey(0), FEATURES, CLASSES)
    params = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (N_NODES,) + p.shape), params0)
    grad_fn = vision_small.make_stacked_grad_fn(vision_small.mlr_apply)
    eval_fn = vision_small.make_eval_fn(vision_small.mlr_apply,
                                        jnp.asarray(x_te), jnp.asarray(y_te))
    batches = node_partitioned_batches(x_tr, y_tr, N_NODES, 16, seed=0)

    m = 4000 // N_NODES
    pp = PrivacyParams(G=5.0, m=m, tau=16 / m, p=cfg.p, sigma=cfg.sigma)
    res = run_decentralized(
        topo=topo, algorithm="sdm_dsgd", sdm_cfg=cfg, params_stack=params,
        grad_fn=grad_fn, batches=batches, steps=STEPS, privacy=pp,
        eps_target=1.0, eval_fn=eval_fn, eval_every=50, log_every=50)

    # compare against DSGD's cost on the SAME wire plane (the transport
    # ships the padded (rows, LANE) buffer, so both sides pad alike)
    full = sum(int(w.size) for w in sdm_dsgd.wire_shape_tree(params0))
    sent = sdm_dsgd.transmitted_elements_per_step(params0, cfg)
    print(f"\nfinal loss        : {res.losses[-1]:.4f}")
    print(f"test accuracy     : {res.eval_accuracy[-1]:.4f}")
    print(f"per-node traffic  : {sent}/{full} elements/iter "
          f"({100 * sent / full:.0f}% of DSGD)")
    print(f"privacy spent     : eps={res.epsilons[-1]:.3e} at delta=1e-5 "
          f"after {STEPS} steps")


if __name__ == "__main__":
    main()
