"""Serving example: batched requests against a small decoder LM.

Builds a reduced chatglm3-family model, enqueues a mixed batch of
requests (different lengths and token budgets), and serves them through
the static-batch prefill+decode engine.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer
from repro.serving import Request, ServingEngine


def main() -> None:
    cfg = configs.get_smoke_config("chatglm3-6b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, max_batch=4, max_seq=96)

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=n).tolist(),
                max_new_tokens=m)
        for n, m in [(8, 12), (8, 6), (8, 16), (8, 4), (16, 8), (16, 8)]
    ]
    t0 = time.time()
    engine.serve(requests)
    dt = time.time() - t0
    tokens = sum(len(r.output) for r in requests)
    print(f"served {len(requests)} requests / {tokens} new tokens "
          f"in {dt:.2f}s")
    for i, r in enumerate(requests):
        print(f"  req{i}: len(prompt)={len(r.prompt):2d} "
              f"budget={r.max_new_tokens:2d} -> {r.output}")
    assert all(len(r.output) <= r.max_new_tokens for r in requests)
    assert all(len(r.output) > 0 for r in requests)
    print("all requests satisfied within their budgets")


if __name__ == "__main__":
    main()
