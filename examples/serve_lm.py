"""Serving example: continuous batching against a small decoder LM.

Builds a reduced chatglm3-family model and serves a ragged mix of
requests (different prompt lengths and token budgets) through the
continuous-batching engine: requests stream through 4 slots backed by a
paged KV cache — a finished request frees its pages and the next queued
request is prefilled into the vacated slot mid-flight.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer
from repro.serving import Request, ServingEngine


def main() -> None:
    cfg = configs.get_smoke_config("chatglm3-6b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, max_batch=4, max_seq=96,
                           page_size=8)

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=n).tolist(),
                max_new_tokens=m)
        for n, m in [(8, 12), (5, 6), (11, 16), (8, 4), (16, 8), (3, 8),
                     (9, 2), (16, 8)]
    ]
    t0 = time.time()
    engine.serve(requests)
    dt = time.time() - t0
    tokens = sum(len(r.output) for r in requests)
    stats = engine.last_stats
    print(f"served {len(requests)} requests / {tokens} new tokens "
          f"in {dt:.2f}s")
    print(f"kv pages: peak {stats.pages_peak} vs dense-equivalent "
          f"{stats.pages_dense_equiv}")
    for i, r in enumerate(requests):
        print(f"  req{i}: len(prompt)={len(r.prompt):2d} "
              f"budget={r.max_new_tokens:2d} ttft={r.ttft_s:.3f}s "
              f"-> {r.output}")
    assert all(len(r.output) <= r.max_new_tokens for r in requests)
    assert all(len(r.output) > 0 for r in requests)
    print("all requests satisfied within their budgets")


if __name__ == "__main__":
    main()
