"""Privacy design-space sweep (the paper's §4.3 guidelines, executable).

Sweeps the sparsifier probability p and iteration budget T, printing:
  * the Gaussian sigma that Corollary 2 demands for (eps, delta),
  * Theorem 4's maximum iteration budget T_max = O(m^4),
  * the 1/p^2 penalty the REVERSED (sparsify-then-randomize) design pays
    (Proposition 5) — why randomize-then-sparsify is the right order.

  PYTHONPATH=src python examples/privacy_sweep.py
"""
from repro.core import privacy

G, M, DELTA = 5.0, 1000, 1e-5


def main() -> None:
    print(f"setup: G={G} m={M} delta={DELTA} tau=1/m\n")
    print("Theorem 4 budget T_max (eps=1):")
    for m in (250, 500, 1000, 2000):
        t = privacy.max_iterations(G=G, m=m, p=0.2, eps=1.0, delta=DELTA)
        print(f"  m={m:5d}  T_max={t:>14,}   (m^4 scaling; prior art ~m^2={m*m:,})")

    print("\nCorollary 2 sigma for (eps=1, delta=1e-5) at m=100, T=1e6:")
    for p in (0.05, 0.1, 0.2, 0.5, 1.0):
        try:
            s = privacy.sigma_for_budget(G=G, m=100, p=p, T=1_000_000,
                                         eps=1.0, delta=DELTA)
            print(f"  p={p:4.2f}  sigma={s:8.4f}  (smaller p -> less noise needed)")
        except ValueError as e:
            print(f"  p={p:4.2f}  infeasible: {e}")

    print("\nProposition 5: eps-part penalty of the reversed design:")
    for p in (0.05, 0.1, 0.2, 0.5):
        params = privacy.PrivacyParams(G=G, m=M, tau=1.0 / M, p=p, sigma=2.0,
                                       delta=DELTA)
        sdm = privacy.epsilon_sdm(params, 1000, 0.5) - 0.25
        alt = privacy.epsilon_alternative(params, 1000, 0.5) - 0.25
        print(f"  p={p:4.2f}  eps_reversed/eps_sdm = {alt / sdm:10.1f} "
              f"(= 1/p^2 = {1 / p**2:.1f})")
    print("\nconclusion: randomize-then-sparsify (the paper's order) wins.")


if __name__ == "__main__":
    main()
