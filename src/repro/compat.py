"""JAX version tolerance layer.

The repo targets the modern public API (`jax.shard_map`,
`jax.make_mesh(..., axis_types=...)`, `jax.sharding.AxisType`,
`AbstractMesh(axis_sizes, axis_names)`); older jaxlibs (0.4.x) expose
the same functionality under `jax.experimental.shard_map.shard_map`
with `check_rep`/`auto` instead of `check_vma`/`axis_names`, take no
`axis_types`, and build `AbstractMesh` from a zipped shape tuple. All
mesh/shard_map construction in this repo goes through these wrappers so
a single site absorbs the skew.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Set

import jax

__all__ = ["make_mesh", "abstract_mesh", "shard_map", "auto_axis_types",
           "get_abstract_mesh", "partial_auto_shard_map_broken"]

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")


def auto_axis_types(n: int):
    """(AxisType.Auto,) * n on new JAX, None (ignored) on old JAX."""
    if _HAS_AXIS_TYPE:
        return (jax.sharding.AxisType.Auto,) * n
    return None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              axis_types=None) -> jax.sharding.Mesh:
    """jax.make_mesh across versions (axis_types only where supported)."""
    if axis_types is None:
        axis_types = auto_axis_types(len(axis_names))
    try:
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    except TypeError:
        return jax.make_mesh(axis_shapes, axis_names)


def abstract_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """jax.sharding.AbstractMesh across versions."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_shapes),
                                         tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_shapes)))


def partial_auto_shard_map_broken(mesh, manual_axes) -> bool:
    """True where a partial-auto shard_map region cannot compile at all.

    Old jaxlibs fail XLA manual-subgroup checks when partitioning
    `lax.ppermute` collectives or while-loops traced under shard_map with
    leftover auto (GSPMD) axes. Callers should fall back to a FULL-manual
    region — every mesh axis manual, tensor-parallel axes replicated —
    which is semantically identical (and only slower on real TP meshes).
    Full-manual regions are unaffected on all versions.
    """
    if _HAS_JAX_SHARD_MAP:
        return False
    return any(a not in manual_axes for a in mesh.axis_names)


def cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() as a flat dict on every version.

    Old jaxlibs return a one-element list of per-program dicts; new ones
    return the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


def get_abstract_mesh():
    """The ambient abstract mesh, or None where the concept doesn't exist.

    Callers fall back to binding sharding constraints against the
    concrete mesh (the pre-abstract-mesh behaviour) on None.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    return fn() if fn is not None else None


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Set[str]] = None,
              check_vma: bool = False):
    """jax.shard_map across versions.

    ``axis_names`` lists the MANUAL axes (new-API semantics); remaining
    mesh axes stay auto/GSPMD. On old JAX this maps to the complementary
    ``auto=`` frozenset and ``check_vma`` to ``check_rep``.
    """
    if _HAS_JAX_SHARD_MAP:
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    auto: Any = frozenset()
    if axis_names is not None:
        auto = frozenset(a for a in mesh.axis_names if a not in axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)
