"""Identity tag primitives the static analyzer keys on.

The privacy argument (paper Theorem 1) is about the EMITTED computation:
what crosses a collective must be a clipped, Gaussian-masked, sparsified
differential. ``repro.analysis`` proves that over the jaxpr — but a
jaxpr has no notion of "this add was the DP mask"; these three
primitives give it one. Each is a semantic no-op (identity impl,
identity lowering, vectorized batching, linear AD) that survives
tracing into the jaxpr where the analyzer can see it:

* ``sanitize(tree)``    — applied by ``sdm_dsgd.masked_grad`` after the
  clip -> + sigma*normal mask (only when sigma > 0: an un-noised
  gradient is NOT sanitized). Clears data-taint in the analyzer.
* ``wire_payload(x)``   — applied by ``gossip`` to every ppermute
  operand: the single blessed transport layer. A ppermute whose operand
  is not tag-adjacent bypassed the vetted wire path — a finding.
* ``declared_release(x)`` — an explicitly acknowledged release of a
  data-derived aggregate (the training-loss pmean). Clears taint but is
  counted separately so the audit report lists every declared release.
* ``clip_bound(tree, bound=C)`` — applied by ``clipping.clip_tree``: the
  value is coordinate-clamped to [-C, C], carrying the DECLARED clip
  constant into the jaxpr so the sensitivity certifier can seed its
  norm-bound domain at C and cross-check the declared C against the
  config the accountant charges.
* ``pending_buffer(x)``  — applied to the overlapped transport's fresh
  double-buffer planes (``cfg.overlap``): this exchange result must ride
  the loop carry untouched until the NEXT round (one-step staleness).
  The overlap-hazard pass keys on it to prove write-before-read ordering
  statically.

XLA sees nothing: the lowering returns the operand unchanged, so tagged
and untagged programs compile to identical HLO.
"""
from __future__ import annotations

from typing import Any

import jax

try:  # jax >= 0.4.16 keeps Primitive importable from jax.extend
    from jax.extend.core import Primitive
except ImportError:  # pragma: no cover - older jax
    from jax.core import Primitive  # type: ignore[attr-defined,no-redef]

from jax.interpreters import ad, batching, mlir

PyTree = Any

SANITIZE = "privacy_sanitize"
WIRE = "wire_payload"
RELEASE = "declared_release"
CLIP = "clip_bound"
PENDING = "pending_buffer"

#: jaxpr-level names of every tag primitive (the analyzer's contract).
TAG_PRIMITIVES = frozenset({SANITIZE, WIRE, RELEASE, CLIP, PENDING})


def _identity_primitive(name: str) -> Primitive:
    prim = Primitive(name)
    prim.def_impl(lambda x, **params: x)
    prim.def_abstract_eval(lambda x, **params: x)
    mlir.register_lowering(prim, lambda ctx, x, **params: [x])
    batching.defvectorized(prim)
    ad.deflinear2(prim, lambda ct, x, **params: [ct])
    return prim


sanitize_p = _identity_primitive(SANITIZE)
wire_payload_p = _identity_primitive(WIRE)
declared_release_p = _identity_primitive(RELEASE)
clip_bound_p = _identity_primitive(CLIP)
pending_buffer_p = _identity_primitive(PENDING)


def sanitize(tree: PyTree, *, label: str = "gaussian_mask") -> PyTree:
    """Mark every leaf of ``tree`` as DP-sanitized (identity at runtime)."""
    return jax.tree.map(lambda v: sanitize_p.bind(v, label=label), tree)


def wire_payload(x: jax.Array, *, label: str = "gossip") -> jax.Array:
    """Mark ``x`` as a vetted wire buffer (identity at runtime)."""
    return wire_payload_p.bind(x, label=label)


def declared_release(tree: PyTree, *, label: str = "metric") -> PyTree:
    """Mark ``tree`` as a deliberate data-derived release (identity)."""
    return jax.tree.map(lambda v: declared_release_p.bind(v, label=label),
                        tree)


def clip_bound(tree: PyTree, *, bound: float) -> PyTree:
    """Declare every leaf coordinate-clamped to ``[-bound, bound]``.

    The ``bound`` param rides the jaxpr, so the sensitivity certifier
    both SEEDS its norm-bound domain at the declared C and cross-checks
    that C against the config's ``clip_c``.
    """
    return jax.tree.map(
        lambda v: clip_bound_p.bind(v, bound=float(bound)), tree)


def pending_buffer(tree: PyTree, *, label: str = "overlap") -> PyTree:
    """Mark ``tree`` as an overlap double-buffer write (identity).

    The tagged value is the FRESH exchange result under ``cfg.overlap``;
    the overlap-hazard pass proves it rides the loop carry untouched and
    is consumed exactly one round later.
    """
    return jax.tree.map(
        lambda v: pending_buffer_p.bind(v, label=label), tree)
