"""Gradient-push (stochastic gradient push / DP-CSGP-style) over DIRECTED graphs.

Undirected SDM-DSGD/DSGD need a symmetric doubly-stochastic W — impossible
to build locally on a directed graph (a node cannot normalize weights it
receives over links it does not know about). Push-sum (Kempe et al.;
Nedić–Olshevsky; Assran et al. SGP) fixes this with a COLUMN-stochastic
push matrix P (every sender splits its mass over its out-edges) plus a
scalar mass counter w that undergoes the same mixing, so the de-biased
ratio z = x / w converges to the true average even though P is not
row-stochastic:

    z_{i,t}     = x_{i,t} / w_{i,t}              # de-biased estimate
    x_{i,t+1/2} = x_{i,t} - gamma * g_i(z_{i,t}) # local (masked) step
    x_{i,t+1}   = sum_j P_ij(t) x_{j,t+1/2}      # push values
    w_{i,t+1}   = sum_j P_ij(t) w_{j,t}          # push mass

Column-stochasticity conserves total mass (sum_i x_i and sum_i w_i are
invariants), so sum x / sum w is exactly the running average — that is
the consensus quantity reported. Gaussian masking + clipping reuse the
shared ``sdm_dsgd.masked_grad`` (the DP flavour per arXiv:2512.13583).
Full state crosses the wire, so time-varying (B-strongly-connected)
sequences are exact, like DSGD.

Both executors compile from the same schedule object: the reference
mixes with ``ScheduleSequence.weights_stack()`` and the distributed
per-node step runs the identical ``gossip.exchange`` ppermute rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import gossip
from repro.core.sdm_dsgd import masked_grad

__all__ = ["GradientPushConfig", "GradientPushState", "GradientPushReference",
           "init_push_state", "gradient_push_distributed_step"]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GradientPushConfig:
    gamma: float = 0.01
    sigma: float = 0.0
    clip_c: float | None = None


class GradientPushState(NamedTuple):
    x: PyTree        # push numerator (per-node model mass)
    w: jax.Array     # push-sum weight (scalar per node; (n,) stacked)
    step: jax.Array


def _debias(x_tree: PyTree, w) -> PyTree:
    """z = x / w with w broadcast over each leaf's trailing dims."""
    def one(x):
        wb = jnp.reshape(w, w.shape + (1,) * (x.ndim - w.ndim))
        return (x / wb).astype(x.dtype)
    return jax.tree.map(one, x_tree)


class GradientPushReference:
    """Stacked single-host gradient-push, mirroring ReferenceSimulator."""

    def __init__(self, topo, cfg: GradientPushConfig):
        self.cfg = cfg
        self.seq = gossip.sequence_of(topo)
        self._wstack = jnp.asarray(self.seq.weights_stack(), jnp.float32)
        self.weights = self._wstack[0]

    def init(self, params_stack: PyTree) -> GradientPushState:
        n = jax.tree.leaves(params_stack)[0].shape[0]
        assert n == self.seq.n_nodes, (n, self.seq.n_nodes)
        return GradientPushState(x=params_stack, w=jnp.ones((n,), jnp.float32),
                                 step=jnp.zeros((), jnp.int32))

    def step(self, state: GradientPushState, grad_fn, batch_stack: PyTree,
             key: jax.Array) -> Tuple[GradientPushState, PyTree]:
        cfg = self.cfg
        z = _debias(state.x, state.w)
        grads, aux = grad_fn(z, batch_stack)
        g = masked_grad(grads, key, sigma=cfg.sigma, clip_c=cfg.clip_c)
        x_half = jax.tree.map(
            lambda x, gr: x - cfg.gamma * gr.astype(x.dtype), state.x, g)
        p_t = self._wstack[state.step % self.seq.length]
        x = jax.tree.map(lambda v: gossip.mix_dense(p_t, v), x_half)
        w = p_t @ state.w
        return GradientPushState(x=x, w=w, step=state.step + 1), aux

    def consensus_mean(self, state: GradientPushState) -> PyTree:
        """sum_i x_i / sum_i w_i — exact by mass conservation."""
        return jax.tree.map(
            lambda x: jnp.sum(x, axis=0) / jnp.sum(state.w), state.x)

    consensus = consensus_mean

    def eval_params(self, state: GradientPushState) -> PyTree:
        """Per-node de-biased estimates z_i (what training evaluates)."""
        return _debias(state.x, state.w)


def init_push_state(params: PyTree) -> GradientPushState:
    """Per-node state inside shard_map (params have NO node axis)."""
    return GradientPushState(x=params, w=jnp.ones((), jnp.float32),
                             step=jnp.zeros((), jnp.int32))


def gradient_push_distributed_step(state: GradientPushState, grads: PyTree, *,
                                   base_key: jax.Array, axis_name,
                                   cfg: GradientPushConfig,
                                   schedule=None,
                                   node_index=None) -> GradientPushState:
    """Per-node push step inside shard_map (grads evaluated at z = x / w).

    The scalar mass w rides the same ppermute schedule as the model
    leaves — one extra () payload per round, negligible on the wire.
    """
    seq = gossip.resolve_sequence(schedule, axis_name)
    me = gossip._me(axis_name, node_index)
    sw = seq.self_weight_of(me, state.step)
    noise_key = jax.random.fold_in(
        gossip.node_round_key(base_key, me, state.step), 0x5eed)
    g = masked_grad(grads, noise_key, sigma=cfg.sigma, clip_c=cfg.clip_c)

    x_half = jax.tree.map(
        lambda x, gr: x - cfg.gamma * gr.astype(x.dtype), state.x, g)
    x = jax.tree.map(
        lambda v: sw.astype(v.dtype) * v + gossip.exchange(
            seq, v, axis_name, node_index=node_index, step=state.step),
        x_half)
    w = sw * state.w + gossip.exchange(seq, state.w, axis_name,
                                       node_index=node_index,
                                       step=state.step)
    return GradientPushState(x=x, w=w, step=state.step + 1)
