"""Gradient-push (stochastic gradient push / DP-CSGP-style) over DIRECTED graphs.

Undirected SDM-DSGD/DSGD need a symmetric doubly-stochastic W — impossible
to build locally on a directed graph (a node cannot normalize weights it
receives over links it does not know about). Push-sum (Kempe et al.;
Nedić–Olshevsky; Assran et al. SGP) fixes this with a COLUMN-stochastic
push matrix P (every sender splits its mass over its out-edges) plus a
scalar mass counter w that undergoes the same mixing, so the de-biased
ratio z = x / w converges to the true average even though P is not
row-stochastic:

    z_{i,t}     = x_{i,t} / w_{i,t}              # de-biased estimate
    x_{i,t+1/2} = x_{i,t} - gamma * g_i(z_{i,t}) # local (masked) step
    x_{i,t+1}   = sum_j P_ij(t) x_{j,t+1/2}      # push values
    w_{i,t+1}   = sum_j P_ij(t) w_{j,t}          # push mass

Column-stochasticity conserves total mass (sum_i x_i and sum_i w_i are
invariants), so sum x / sum w is exactly the running average — that is
the consensus quantity reported. Gaussian masking + clipping reuse the
shared ``sdm_dsgd.masked_grad`` (the DP flavour per arXiv:2512.13583).

Compressed variant (``GradientPushConfig.compressor`` set): CHOCO/
DP-CSGP-style error-compensated push-sum, so directed graphs also get
the p-fraction wire cost. Each node keeps a PUBLIC copy xhat_i that all
its neighbours replicate, and transmits only the compressed differential

    delta_i = C_contr(x_{i,t+1/2} - xhat_i)           # the ONLY payload
    xhat_i <- xhat_i + delta_i                        # replicas advance
    x_{i,t+1} = x_{i,t+1/2} + chi * [(P - I) xhat]_i  # damped consensus
    w_{i,t+1} = w_{i,t}     + chi * [(P - I) w]_i     # mass, SAME operator

i.e. the consensus correction is computed on the public copies and
applied with the CHOCO step size ``chi``, while the local compression
residual (x_half - xhat) stays put and folds into the NEXT differential
(error compensation — nothing is ever lost, only delayed). Two design
points both of which are REQUIRED for stability (probed in
tests/test_compressor.py):

* ``C_contr`` is the CONTRACTIVE form of the selected compressor — the
  unbiased 1/p amplification is undone by scaling payload values by p
  (||x - C_contr(x)||^2 <= (1-p)||x||^2); error compensation repairs
  the bias, while unbiased scaling would amplify the residual loop by
  sqrt(1/p - 1) per step (divergent for p < 1/2) — the same finding
  tests/test_error_feedback.py records for SDM's EF extension, and the
  reason CHOCO-SGP assumes a contractive operator. Quantizers are
  already norm-contractive and ship unscaled.
* ``chi`` < 1 damps the consensus feedback of the compression error
  (undamped chi=1 diverges per-node at aggressive sparsity even with a
  contractive compressor); the mass w mixes with the SAME damped
  operator M = I + chi (P - I) so the ratio z = x / w stays de-biased.

M is column-stochastic for any chi (columns: 1 - chi + chi = 1), so
total mass is conserved exactly: sum x_{t+1} = sum x_half — the
``consensus`` = sum x / sum w invariant survives compression bit-exactly
and only the per-node de-bias z_i carries bounded compression noise.
On static schedules receivers track sum_{j != i} P_ij xhat_j
incrementally (the ``s`` buffer, exactly like SDM's neighbour sum):
s_i += sum_j P_ij delta_j as the weighted differentials arrive —
byte-for-byte the historical trajectories. On genuinely time-varying
B-connected sequences the increments instead land in per-neighbour
public-copy REPLICAS (``xhat_nb``, one slot per union-graph round, fed
over every union edge every round so replicas are exact by
construction) and s_i = sum_j P_ij(t) xhat_j is recomputed fresh with
the CURRENT round's weights — so mass conservation and the consensus
invariant hold on any P(t) sequence (the old code rejected the
combination). The uncompressed path is untouched (it is exactly chi = 1
with the identity compressor).

Both executors compile from the same schedule object: the reference
mixes with ``ScheduleSequence.weights_stack()`` and the distributed
per-node step runs the identical ``gossip.exchange`` /
``gossip.exchange_payload`` ppermute rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import compressor as compressor_mod, gossip
from repro.core import plane as plane_mod
from repro.core import tagging
from repro.core.sdm_dsgd import (_plane_payload_exchange, _replica_planes,
                                 masked_grad, sparsify_planes_stacked)

__all__ = ["GradientPushConfig", "GradientPushState", "GradientPushReference",
           "init_push_state", "init_compressed_push_state",
           "gradient_push_distributed_step"]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GradientPushConfig:
    """Push-sum hyper-parameters.

    ``compressor`` (a ``repro.core.compressor`` spec: 'bernoulli',
    'fixedk', 'block:<B>', 'qsgd:<bits>', ...) switches on the error-
    compensated compressed variant with transmit budget ``p``; ``chi``
    is the CHOCO consensus step size on the public copies (module
    docstring): chi = 1 recovers undamped mixing (fine for near-lossless
    quantizers, DIVERGES per-node at aggressive sparsity), the 0.3
    default is stable for every registered family at p >= 0.25 on the
    probed graphs.
    """

    gamma: float = 0.01
    sigma: float = 0.0
    clip_c: float | None = None
    compressor: str | None = None
    p: "float | Tuple[float, ...]" = 0.2
    chi: float = 0.3
    # Overlapped transport (one-step-stale, compressed variant only): the
    # differential payload exchanged at step t lands in a pending double
    # buffer and folds into the neighbour sum s at step t+1, so the
    # permutes can hide under the gradient computation. Only the PAYLOAD
    # planes go stale; the scalar mass w (a few bytes) stays synchronous,
    # so z = x / w de-biasing is unchanged. Mass conservation holds in
    # the delayed telescoping sense: the in-flight increments carry the
    # missing mass and land exactly one step later. Static (non-replica)
    # schedules only.
    overlap: bool = False

    def __post_init__(self) -> None:
        if isinstance(self.p, (list, tuple)):
            object.__setattr__(self, "p", tuple(float(v) for v in self.p))
        if not (0.0 < self.chi <= 1.0):
            raise ValueError("chi in (0, 1]")
        if self.overlap and self.compressor is None:
            raise ValueError(
                "overlap=True is a differential-transport feature: the "
                "uncompressed push mixes ABSOLUTE state, which has no "
                "S(0)=0 staleness invariant — set a compressor")
        if self.compressor is not None:
            compressor_mod.make(self.compressor, p=self.p)  # fail fast

    def make_compressor(self) -> "compressor_mod.Compressor | None":
        if self.compressor is None:
            return None
        return compressor_mod.make(self.compressor, p=self.p)


class GradientPushState(NamedTuple):
    x: PyTree        # push numerator (per-node model mass)
    w: jax.Array     # push-sum weight (scalar per node; (n,) stacked)
    step: jax.Array
    xhat: PyTree = None   # public copy (compressed variant only)
    s: PyTree = None      # sum_{j != i} P_ij xhat_j (compressed; incremental
    #                       on static schedules, recomputed from replicas on
    #                       time-varying ones)
    xhat_nb: PyTree = None  # per-neighbour replica stack (compressed AND
    #                         genuinely time-varying only; leading
    #                         (n_replicas,) axis per leaf)
    nb: PyTree = None  # overlapped-transport pending increments (cfg.overlap
    #                    only): last step's weighted differential deliveries,
    #                    folded into s one step late


def _debias(x_tree: PyTree, w) -> PyTree:
    """z = x / w with w broadcast over each leaf's trailing dims."""
    def one(x):
        wb = jnp.reshape(w, w.shape + (1,) * (x.ndim - w.ndim))
        return (x / wb).astype(x.dtype)
    return jax.tree.map(one, x_tree)


def _contraction_scale(comp: compressor_mod.Compressor, node=None):
    """Per-sender factor turning the unbiased compressor contractive.

    Sparsifiers scale kept values by ~1/p for unbiasedness; the error-
    compensated loop instead needs the contractive form, so the sender
    multiplies its payload VALUES by its own p before transmitting
    (receivers then decompress consistently — the factor rides inside
    the payload). Quantizers are already contractive: factor 1.
    """
    if isinstance(comp, compressor_mod.QSGDCompressor):
        return 1.0
    if isinstance(comp.p, tuple):
        return comp.p_of(node)
    return comp.p


def _contract_payload(comp, pl, node=None):
    scale = _contraction_scale(comp, node)
    if isinstance(scale, float) and scale == 1.0:
        return pl
    return dataclasses.replace(
        pl, values=(pl.values * scale).astype(pl.values.dtype))


class GradientPushReference:
    """Stacked single-host gradient-push, mirroring ReferenceSimulator."""

    def __init__(self, topo, cfg: GradientPushConfig):
        self.cfg = cfg
        self.seq = gossip.sequence_of(topo)
        self._wstack = jnp.asarray(self.seq.weights_stack(), jnp.float32)
        self.weights = self._wstack[0]
        self.comp = cfg.make_compressor()
        # genuinely time-varying P(t): recompute the neighbour sum fresh
        # from the (exact) public-copy stack each round instead of the
        # incremental frozen-weight sum (which is exact only when P is
        # round-invariant — and stays the byte-identical fast path there).
        self.replica_exact = (self.comp is not None
                              and gossip.needs_replicas(self.seq))
        if cfg.overlap and gossip.needs_replicas(self.seq):
            raise ValueError(
                "overlap=True needs a static (non-replica) schedule")

    def init(self, params_stack: PyTree) -> GradientPushState:
        n = jax.tree.leaves(params_stack)[0].shape[0]
        assert n == self.seq.n_nodes, (n, self.seq.n_nodes)
        base = GradientPushState(x=params_stack,
                                 w=jnp.ones((n,), jnp.float32),
                                 step=jnp.zeros((), jnp.int32))
        if self.comp is None:
            return base
        if self.replica_exact:
            # the neighbour sum is recomputed fresh from the public-copy
            # stack every step: no persistent s buffer (matching the
            # distributed replica-path state layout).
            return base._replace(xhat=params_stack)
        # Exact replica bookkeeping: s_0[i] = sum_{j != i} P_ij x_{j,0}.
        # (The distributed init assumes identical starts and reduces this
        # to rowsum_i * x_0 — the stacked reference needs no assumption.)
        s0 = jax.tree.map(
            lambda x: gossip.apply_weights_dense(
                self.weights, x, include_self=False).astype(x.dtype),
            params_stack)
        nb = jax.tree.map(jnp.zeros_like, params_stack) \
            if self.cfg.overlap else None
        return base._replace(xhat=params_stack, s=s0, nb=nb)

    def step(self, state: GradientPushState, grad_fn, batch_stack: PyTree,
             key: jax.Array) -> Tuple[GradientPushState, PyTree]:
        cfg = self.cfg
        z = _debias(state.x, state.w)
        grads, aux = grad_fn(z, batch_stack)
        g = masked_grad(grads, key, sigma=cfg.sigma, clip_c=cfg.clip_c)
        x_half = jax.tree.map(
            lambda x, gr: x - cfg.gamma * gr.astype(x.dtype), state.x, g)
        p_t = self._wstack[state.step % self.seq.length]
        if self.comp is None:
            x = jax.tree.map(lambda v: gossip.mix_dense(p_t, v), x_half)
            return GradientPushState(x=x, w=p_t @ state.w,
                                     step=state.step + 1), aux

        # -- compressed: transmit C_contr(x_half - xhat) only --------------
        n = self.seq.n_nodes
        comp = self.comp

        delta = jax.tree.map(jnp.subtract, x_half, state.xhat)
        # plane-granular draws (the wire transport's granularity), with
        # the contraction applied to each payload exactly as the
        # distributed executor ships it.
        delta_hat = sparsify_planes_stacked(
            comp, delta, key, state.step, n,
            transform=lambda pl, i: _contract_payload(comp, pl, node=i))
        xhat = jax.tree.map(jnp.add, state.xhat, delta_hat)
        if self.replica_exact:
            # exact W(t)-mixing: the stacked xhat IS every node's public
            # copy (what the distributed replicas reconstruct), so the
            # neighbour sum uses the CURRENT round's weights, fresh —
            # consumed by the x update below, never stored.
            s = jax.tree.map(
                lambda xh: gossip.apply_weights_dense(
                    p_t, xh, include_self=False).astype(xh.dtype), xhat)
        elif cfg.overlap:
            # one-step-stale: consume LAST step's pending weighted
            # increments; this step's deliveries wait in the double
            # buffer (weights of the round the payload crossed).
            s = jax.tree.map(jnp.add, state.s, state.nb)
            nb = tagging.pending_buffer(jax.tree.map(
                lambda dh, s_: gossip.apply_weights_dense(
                    p_t, dh, include_self=False).astype(s_.dtype),
                delta_hat, s))
        else:
            # incremental neighbour sum: the weights of the round the
            # differential was exchanged in (matches the distributed
            # executor; exact because the schedule is static here).
            s = jax.tree.map(
                lambda s_, dh: s_ + gossip.apply_weights_dense(
                    p_t, dh, include_self=False).astype(s_.dtype),
                state.s, delta_hat)
        diag = jnp.diag(p_t)
        # x <- x_half + chi ((P - I) xhat); mass mixes with the SAME
        # damped column-stochastic operator so z = x / w stays de-biased.
        x = jax.tree.map(
            lambda xh, xp, ss: xh + cfg.chi * (diag.reshape(
                (n,) + (1,) * (xh.ndim - 1)).astype(xh.dtype) * xp
                + ss - xp),
            x_half, xhat, s)
        w = state.w + cfg.chi * (p_t @ state.w - state.w)
        return GradientPushState(x=x, w=w, step=state.step + 1, xhat=xhat,
                                 s=None if self.replica_exact else s,
                                 nb=nb if cfg.overlap else None), aux

    def consensus_mean(self, state: GradientPushState) -> PyTree:
        """sum_i x_i / sum_i w_i — exact by mass conservation (the
        invariant survives compression, see module docstring)."""
        return jax.tree.map(
            lambda x: jnp.sum(x, axis=0) / jnp.sum(state.w), state.x)

    consensus = consensus_mean

    def eval_params(self, state: GradientPushState) -> PyTree:
        """Per-node de-biased estimates z_i (what training evaluates)."""
        return _debias(state.x, state.w)


def init_push_state(params: PyTree) -> GradientPushState:
    """Per-node state inside shard_map (params have NO node axis)."""
    return GradientPushState(x=params, w=jnp.ones((), jnp.float32),
                             step=jnp.zeros((), jnp.int32))




def init_compressed_push_state(params: PyTree, nb_row_sum,
                               n_replicas: int | None = None,
                               overlap: bool = False
                               ) -> GradientPushState:
    """Compressed-variant per-node state. ``nb_row_sum`` is the node's
    sum_{j != i} P_ij (from ``PermuteSchedule.neighbor_weight_sums()``;
    may be a traced gather on the node index). ``n_replicas`` (genuinely
    time-varying schedules) allocates the per-neighbour replica stack —
    every slot starts at the shared x_0, the same identical-start
    assumption s_0 relies on. ``xhat`` / ``s`` / ``xhat_nb`` live as
    WIRE PLANES (f32 (rows, LANE) buffers, see ``repro.core.plane``) —
    the shape the compressed differential transport consumes."""
    xp = plane_mod.ParamPlane.for_tree(params).pack(params)
    if n_replicas:
        if overlap:
            raise ValueError("overlap=True needs a static (non-replica) "
                             "schedule")
        # replica path: s is recomputed fresh from xhat_nb every step and
        # never read from state — drop the buffer (one model-size saving
        # per node on top of the replica stack).
        return GradientPushState(x=params, w=jnp.ones((), jnp.float32),
                                 step=jnp.zeros((), jnp.int32),
                                 xhat=xp, s=None,
                                 xhat_nb=_replica_planes(xp, n_replicas))
    s0 = tuple(nb_row_sum * p for p in xp)
    nb0 = tuple(jnp.zeros_like(p) for p in xp) if overlap else None
    return GradientPushState(x=params, w=jnp.ones((), jnp.float32),
                             step=jnp.zeros((), jnp.int32),
                             xhat=xp, s=s0, nb=nb0)


def gradient_push_distributed_step(state: GradientPushState, grads: PyTree, *,
                                   base_key: jax.Array, axis_name,
                                   cfg: GradientPushConfig,
                                   schedule=None,
                                   node_index=None) -> GradientPushState:
    """Per-node push step inside shard_map (grads evaluated at z = x / w).

    The scalar mass w rides the same ppermute schedule as the model
    leaves — one extra () payload per round, negligible on the wire.
    With ``cfg.compressor`` set only the compressed differential payload
    crosses the wire for the model leaves (``gossip.exchange_payload``);
    the mass stays exact.
    """
    seq = gossip.resolve_sequence(schedule, axis_name)
    me = gossip._me(axis_name, node_index)
    sw = seq.self_weight_of(me, state.step)
    comp = cfg.make_compressor()
    noise_key = jax.random.fold_in(
        gossip.node_round_key(base_key, me, state.step), 0x5eed)
    g = masked_grad(grads, noise_key, sigma=cfg.sigma, clip_c=cfg.clip_c)

    x_half = jax.tree.map(
        lambda x, gr: x - cfg.gamma * gr.astype(x.dtype), state.x, g)
    w_push = sw * state.w + gossip.exchange(seq, state.w, axis_name,
                                            node_index=node_index,
                                            step=state.step)
    spec = plane_mod.ParamPlane.for_tree(state.x)
    if comp is None:
        # full-state push rides the wire plane too: R permutes per
        # bucket per step, independent of the model's leaf count.
        hp = spec.pack(x_half)
        x = spec.unpack(tuple(
            sw * p + gossip.exchange(seq, p, axis_name,
                                     node_index=node_index,
                                     step=state.step)
            for p in hp))
        return GradientPushState(x=x, w=w_push, step=state.step + 1)

    delta = tuple(h - xh for h, xh in zip(spec.pack(x_half), state.xhat))
    contract = lambda pl: _contract_payload(comp, pl, node=me)
    if cfg.overlap and gossip.needs_replicas(seq):
        raise ValueError("overlap=True needs a static (non-replica) "
                         "schedule")
    if gossip.needs_replicas(seq):
        # replica-correct time-varying path: increments cross every UNION
        # edge every round (replicas exact by construction) and the
        # neighbour sum is recomputed fresh with P(t)'s weights.
        useq = gossip.union_schedule(seq)
        delta_hat, incr = _plane_payload_exchange(
            delta, comp, useq=useq, axis_name=axis_name, base_key=base_key,
            step=state.step, me=me, transform=contract)
        xhat = tuple(xh + dh for xh, dh in zip(state.xhat, delta_hat))
        xhat_nb = tuple(nb + inc for nb, inc in zip(state.xhat_nb, incr))
        wv = gossip.replica_recv_weights(useq, me, state.step)
        # the fresh neighbour sum is consumed by the x update below and
        # NOT stored: replica-path state carries s=None (dead buffer).
        s = tuple(jnp.tensordot(wv.astype(xh.dtype), xh, axes=([0], [0]))
                  for xh in xhat_nb)
        s_store = nb_store = None
    else:
        # the SAME plane payload transport (and key schedule) SDM's
        # qsgd path uses, contraction applied to each payload pre-wire.
        delta_hat, nb_sum = _plane_payload_exchange(
            delta, comp, schedule=seq, axis_name=axis_name,
            base_key=base_key, step=state.step, me=me,
            node_index=node_index, transform=contract)
        xhat = tuple(xh + dh for xh, dh in zip(state.xhat, delta_hat))
        xhat_nb = state.xhat_nb
        if cfg.overlap:
            # one-step-stale double buffer: consume last step's pending
            # deliveries; this step's exchange result feeds ONLY the loop
            # carry, so its permutes can fly under the next gradient.
            s = tuple(s_ + p_ for s_, p_ in zip(state.s, state.nb))
            nb_store = tagging.pending_buffer(nb_sum)
        else:
            s = tuple(s_ + nb for s_, nb in zip(state.s, nb_sum))
            nb_store = None
        s_store = s
    # x <- x_half + chi ((P - I) xhat); mass rides the same damped
    # operator M = I + chi (P - I) so z = x / w stays de-biased.
    corr = tuple(cfg.chi * (sw * xh + ss - xh) for xh, ss in zip(xhat, s))
    x = jax.tree.map(jnp.add, x_half, spec.unpack(corr))
    w = state.w + cfg.chi * (w_push - state.w)
    return GradientPushState(x=x, w=w, step=state.step + 1, xhat=xhat,
                             s=s_store, xhat_nb=xhat_nb, nb=nb_store)
