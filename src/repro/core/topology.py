"""Gossip-graph topologies and consensus matrices.

The paper (§4.2) requires a consensus matrix ``W`` that is (1) doubly
stochastic, (2) symmetric, and (3) has the network's sparsity pattern.
Its spectrum then lies in (-1, 1] with one eigenvalue equal to 1; the
convergence theory is driven by ``beta = max(|lambda_2|, |lambda_n|)``
and the smallest eigenvalue ``lambda_n``.

The experimental section builds ``W = I - 2/(3*lambda_max(L)) * L`` from
the graph Laplacian ``L`` (used for Erdős–Rényi graphs); we reproduce
that construction exactly and also provide closed-form ring / torus /
complete topologies that map directly onto TPU ICI neighbourhoods.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

__all__ = [
    "Topology",
    "DirectedTopology",
    "ring",
    "torus_2d",
    "complete",
    "erdos_renyi",
    "star",
    "directed_ring",
    "directed_erdos_renyi",
    "random_matchings",
    "masked_subgraph",
    "by_name",
    "placement_cost",
    "greedy_placement",
    "apply_placement",
    "laplacian_consensus_matrix",
    "metropolis_hastings_weights",
    "column_stochastic_weights",
    "shift_decomposition",
    "shift_receive_weights",
]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A gossip graph plus its consensus matrix and spectral summary."""

    name: str
    n_nodes: int
    adjacency: np.ndarray  # (n, n) 0/1, zero diagonal
    weights: np.ndarray  # (n, n) consensus matrix W

    def __post_init__(self) -> None:
        w = self.weights
        if not np.allclose(w, w.T, atol=1e-10):
            raise ValueError(f"{self.name}: W must be symmetric")
        if not np.allclose(w.sum(axis=0), 1.0, atol=1e-8):
            raise ValueError(f"{self.name}: W must be doubly stochastic")
        off_diag = w - np.diag(np.diag(w))
        support = np.abs(off_diag) > 1e-12
        if np.any(support & ~self.adjacency.astype(bool)):
            raise ValueError(f"{self.name}: W uses non-edges")

    # -- spectral quantities used throughout the paper's theory -----------
    @property
    def eigenvalues(self) -> np.ndarray:
        """Sorted descending: lambda_1 = 1 >= ... >= lambda_n > -1."""
        return np.sort(np.linalg.eigvalsh(self.weights))[::-1]

    @property
    def beta(self) -> float:
        """Second-largest eigenvalue magnitude (mixing rate)."""
        ev = self.eigenvalues
        return float(max(abs(ev[1]), abs(ev[-1])))

    @property
    def lambda_n(self) -> float:
        """Smallest eigenvalue of W (enters the theta bound)."""
        return float(self.eigenvalues[-1])

    @property
    def degree(self) -> np.ndarray:
        return self.adjacency.sum(axis=1).astype(np.int64)

    def neighbors(self, i: int) -> Sequence[int]:
        return np.nonzero(self.adjacency[i])[0].tolist()

    def mixed_with_theta(self, theta: float) -> np.ndarray:
        """The effective mixing matrix W_theta = (1-theta) I + theta W (Eq. 26)."""
        n = self.n_nodes
        return (1.0 - theta) * np.eye(n) + theta * self.weights


@dataclasses.dataclass(frozen=True)
class DirectedTopology:
    """A directed gossip graph with a COLUMN-stochastic push matrix.

    ``adjacency[i, j] = 1`` means node j pushes to node i; ``weights``
    is the push-sum matrix P with ``P[i, j]`` the share of j's mass sent
    to i, so each COLUMN sums to 1 (what a sender distributes sums to
    one) but rows need not — the asymmetry push-sum de-biasing corrects.
    Duck-type compatible with ``Topology`` for schedule compilation
    (``shift_decomposition`` / ``schedule_from_topology``): both read
    only ``name / n_nodes / adjacency / weights``.
    """

    name: str
    n_nodes: int
    adjacency: np.ndarray  # (n, n) 0/1, zero diagonal; [i, j] = edge j -> i
    weights: np.ndarray  # (n, n) column-stochastic P

    def __post_init__(self) -> None:
        w = self.weights
        if np.any(w < -1e-12):
            raise ValueError(f"{self.name}: P must be non-negative")
        if not np.allclose(w.sum(axis=0), 1.0, atol=1e-8):
            raise ValueError(f"{self.name}: P columns must sum to 1")
        off_diag = w - np.diag(np.diag(w))
        support = np.abs(off_diag) > 1e-12
        if np.any(support & ~self.adjacency.astype(bool)):
            raise ValueError(f"{self.name}: P uses non-edges")

    @property
    def degree(self) -> np.ndarray:
        """Out-degree per node (edges the node pushes along)."""
        return self.adjacency.sum(axis=0).astype(np.int64)

    def neighbors(self, i: int) -> Sequence[int]:
        """Out-neighbours of node i (nodes that receive i's pushes)."""
        return np.nonzero(self.adjacency[:, i])[0].tolist()


def column_stochastic_weights(adjacency: np.ndarray) -> np.ndarray:
    """The standard push-sum matrix: sender j splits its mass uniformly
    over its out-neighbours and itself, P[i, j] = 1 / (outdeg_j + 1)."""
    adjacency = np.asarray(adjacency)
    n = adjacency.shape[0]
    out_deg = adjacency.sum(axis=0)
    w = np.zeros((n, n))
    for j in range(n):
        share = 1.0 / (out_deg[j] + 1.0)
        w[np.nonzero(adjacency[:, j])[0], j] = share
        w[j, j] = share
    return w


def directed_ring(n: int, self_weight: float | None = None) -> DirectedTopology:
    """One-directional ring: node i pushes only to i+1 (mod n).

    The canonical asymmetric graph — its P is NOT doubly stochastic, so
    plain mixing is biased and push-sum correction is required.
    """
    if n < 2:
        raise ValueError("directed ring needs n >= 2")
    adj = np.zeros((n, n), dtype=np.int64)
    for i in range(n):
        adj[(i + 1) % n, i] = 1
    if self_weight is None:
        w = column_stochastic_weights(adj)
    else:
        w = np.eye(n) * self_weight
        for i in range(n):
            w[(i + 1) % n, i] = 1.0 - self_weight
    return DirectedTopology(name=f"dring{n}", n_nodes=n, adjacency=adj,
                            weights=w)


def directed_erdos_renyi(n: int, p_connect: float = 0.35,
                         seed: int = 0) -> DirectedTopology:
    """Directed ER graph, strongly connected by construction.

    Each ordered pair (j -> i), i != j, is an edge w.p. ``p_connect``; a
    directed ring is overlaid so the graph is always strongly connected
    (push-sum needs B-strong-connectivity). Weights are the uniform
    column-stochastic split.
    """
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < p_connect).astype(np.int64)
    np.fill_diagonal(adj, 0)
    for i in range(n):          # strong-connectivity backbone
        adj[(i + 1) % n, i] = 1
    return DirectedTopology(name=f"der{n}_pc{p_connect}_s{seed}", n_nodes=n,
                            adjacency=adj,
                            weights=column_stochastic_weights(adj))


def random_matchings(n: int, rounds: int, seed: int = 0,
                     self_weight: float = 0.5,
                     ensure_connected: bool = True) -> list[Topology]:
    """A B-connected time-varying sequence: one random matching per round.

    Each round pairs up a random shuffle of the nodes; a matched pair
    (a, b) mixes with W_aa = W_bb = ``self_weight`` and
    W_ab = W_ba = 1 - self_weight; unmatched nodes (odd n) keep W_ii = 1.
    Every round is symmetric doubly stochastic. With
    ``ensure_connected`` (and >= 2 rounds) the sequence is resampled
    until the UNION graph over one cycle is connected — the
    B-connectivity assumption time-varying consensus needs.
    """
    if n < 2:
        raise ValueError("matchings need n >= 2")

    def sample(rng) -> Tuple[list[Topology], np.ndarray]:
        out, union = [], np.zeros((n, n), dtype=np.int64)
        for r in range(rounds):
            order = rng.permutation(n)
            adj = np.zeros((n, n), dtype=np.int64)
            w = np.eye(n)
            for k in range(0, n - 1, 2):
                a, b = int(order[k]), int(order[k + 1])
                adj[a, b] = adj[b, a] = 1
                w[a, a] = w[b, b] = self_weight
                w[a, b] = w[b, a] = 1.0 - self_weight
            union |= adj
            out.append(Topology(name=f"matching{n}_r{r}", n_nodes=n,
                                adjacency=adj, weights=w))
        return out, union

    check = ensure_connected and rounds >= 2 and n > 2
    for attempt in range(1000):
        out, union = sample(np.random.default_rng(seed + attempt))
        if not check or _is_connected(union):
            return out
    raise RuntimeError(
        f"no connected union of {rounds} matchings on {n} nodes "
        "within 1000 reseeds")


def masked_subgraph(topo, active, name: str | None = None):
    """The induced partial-participation round graph on ``active`` nodes.

    The edge-fleet simulator samples an active subset per round; this
    builds that round's mixing graph WITHOUT renumbering: inactive nodes
    stay in the index space but become isolated (their W row/column is
    the identity row — they neither send nor receive, their parameters
    are untouched by the round), and the surviving active-active edges
    get weights recomputed ON THE INDUCED SUBGRAPH so the matrix stays
    valid whatever subset was drawn.

    Undirected topologies get Metropolis-Hastings weights (symmetric
    doubly stochastic for ANY induced adjacency, disconnected included);
    directed ones get the uniform column-stochastic push split (isolated
    senders keep all mass: P_jj = 1). The induced graph need not be
    connected — a single faulty round only slows mixing, and the
    B-connectivity the convergence theory needs is a property of the
    round SEQUENCE, not of each round.
    """
    n = topo.n_nodes
    mask = np.zeros(n, dtype=bool)
    mask[np.asarray(sorted(int(i) for i in active), dtype=np.int64)] = True
    label = name or f"{topo.name}_sub{int(mask.sum())}"
    if mask.all():
        # full participation keeps the base graph's OWN weights (ring
        # self-weights, Laplacian ER matrices, ...) so a no-fault round
        # mixes byte-identically to the lock-step trainer.
        return dataclasses.replace(topo, name=label)
    adj = (np.asarray(topo.adjacency) * np.outer(mask, mask)).astype(np.int64)
    if isinstance(topo, DirectedTopology):
        return DirectedTopology(name=label, n_nodes=n, adjacency=adj,
                                weights=column_stochastic_weights(adj))
    return Topology(name=label, n_nodes=n, adjacency=adj,
                    weights=metropolis_hastings_weights(adj))


def laplacian_consensus_matrix(adjacency: np.ndarray) -> np.ndarray:
    """The paper's experimental construction: W = I - 2/(3 lambda_max(L)) L."""
    adjacency = np.asarray(adjacency, dtype=np.float64)
    deg = np.diag(adjacency.sum(axis=1))
    lap = deg - adjacency
    lam_max = float(np.max(np.linalg.eigvalsh(lap)))
    if lam_max <= 0:
        raise ValueError("graph has no edges")
    return np.eye(adjacency.shape[0]) - (2.0 / (3.0 * lam_max)) * lap


def metropolis_hastings_weights(adjacency: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings weights: always doubly stochastic & symmetric."""
    adjacency = np.asarray(adjacency)
    n = adjacency.shape[0]
    deg = adjacency.sum(axis=1)
    w = np.zeros((n, n))
    for i in range(n):
        for j in np.nonzero(adjacency[i])[0]:
            w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
    np.fill_diagonal(w, 1.0 - w.sum(axis=1))
    return w


def _topology(name: str, adjacency: np.ndarray, weights: np.ndarray | None) -> Topology:
    if weights is None:
        weights = laplacian_consensus_matrix(adjacency)
    return Topology(name=name, n_nodes=adjacency.shape[0],
                    adjacency=np.asarray(adjacency), weights=np.asarray(weights))


def ring(n: int, self_weight: float | None = None) -> Topology:
    """Symmetric ring; maps to two `collective-permute`s on a TPU torus.

    ``self_weight`` defaults to 1/3 (uniform over {self, left, right}).
    """
    if n < 2:
        raise ValueError("ring needs n >= 2")
    adj = np.zeros((n, n), dtype=np.int64)
    for i in range(n):
        adj[i, (i + 1) % n] = 1
        adj[i, (i - 1) % n] = 1
    if n == 2:
        adj = np.array([[0, 1], [1, 0]], dtype=np.int64)
    if self_weight is None:
        self_weight = 1.0 / 3.0
    nb_weight = (1.0 - self_weight) / 2.0
    w = np.eye(n) * self_weight
    for i in range(n):
        w[i, (i + 1) % n] += nb_weight
        w[i, (i - 1) % n] += nb_weight
    return _topology(f"ring{n}", adj, w)


def torus_2d(rows: int, cols: int) -> Topology:
    """2-D torus: 4 neighbours per node (wraps); the native ICI shape."""
    n = rows * cols
    adj = np.zeros((n, n), dtype=np.int64)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                j = ((r + dr) % rows) * cols + (c + dc) % cols
                if j != i:
                    adj[i, j] = 1
    w = metropolis_hastings_weights(adj)
    return _topology(f"torus{rows}x{cols}", adj, w)


def complete(n: int) -> Topology:
    """Fully connected; W = (1/n) 11^T. beta = 0 (one-shot consensus)."""
    adj = np.ones((n, n), dtype=np.int64) - np.eye(n, dtype=np.int64)
    w = np.full((n, n), 1.0 / n)
    return _topology(f"complete{n}", adj, w)


def star(n: int) -> Topology:
    adj = np.zeros((n, n), dtype=np.int64)
    adj[0, 1:] = 1
    adj[1:, 0] = 1
    w = metropolis_hastings_weights(adj)
    return _topology(f"star{n}", adj, w)


def erdos_renyi(n: int, p_connect: float = 0.35, seed: int = 0,
                ensure_connected: bool = True) -> Topology:
    """The paper's experimental graph: ER(n, p_c=0.35), Laplacian weights."""
    rng = np.random.default_rng(seed)
    for attempt in range(1000):
        upper = rng.random((n, n)) < p_connect
        adj = np.triu(upper, k=1)
        adj = (adj | adj.T).astype(np.int64)
        if not ensure_connected or _is_connected(adj):
            return _topology(f"er{n}_pc{p_connect}_s{seed + attempt}", adj,
                             laplacian_consensus_matrix(adj))
        rng = np.random.default_rng(seed + attempt + 1)
    raise RuntimeError("could not sample a connected ER graph")


# --------------------------------------------------------------------------
# Schedule-aware placement: renumber nodes to hug the ICI ring.
# --------------------------------------------------------------------------
#
# A ppermute round moves each edge's payload across the PHYSICAL
# interconnect; on a 1-D ICI ring the payload between devices a and b
# traverses min(|a-b|, n-|a-b|) hops, and every hop beyond the first is
# a store-and-forward through an intermediate device (serialized
# latency + doubled link occupancy). The gossip graph is LOGICAL — the
# mapping of logical node i to physical device order[i] is ours to
# choose, so high-traffic shifts should land on nearest-neighbour
# permutations. ``greedy_placement`` hill-climbs over pairwise swaps of
# the assignment and by construction never returns a placement worse
# than the identity (ROADMAP's "schedule-aware placement" item).

def placement_cost(adjacency: np.ndarray,
                   order: np.ndarray | None = None) -> int:
    """Extra (non-nearest-neighbour) ICI ring hops per gossip step.

    ``order[i]`` is the physical device logical node i is placed on;
    identity when omitted. Each directed edge (j -> i) costs
    ``ring_distance(order[i], order[j]) - 1`` extra hops, so a graph
    whose every edge lands on physically adjacent devices costs 0.
    """
    adj = np.asarray(adjacency)
    n = adj.shape[0]
    pos = np.arange(n) if order is None else np.asarray(order)
    if sorted(pos.tolist()) != list(range(n)):
        raise ValueError("order must be a permutation of range(n)")
    rows, cols = np.nonzero(adj)
    dist = np.abs(pos[rows] - pos[cols])
    dist = np.minimum(dist, n - dist)
    return int(np.sum(dist - 1))


def greedy_placement(topo_or_adj, max_passes: int = 8) -> np.ndarray:
    """Greedy pairwise-swap renumbering minimizing ``placement_cost``.

    Accepts a Topology/DirectedTopology or a raw adjacency matrix.
    Hill-climbs: repeatedly applies the single swap with the best cost
    reduction until a pass finds none (or ``max_passes`` passes ran).
    Monotone by construction — the returned placement NEVER costs more
    than the identity, so already-optimal layouts (ring, torus rows on a
    matching ICI) are left at their optimum.
    """
    adj = np.asarray(getattr(topo_or_adj, "adjacency", topo_or_adj))
    n = adj.shape[0]
    order = np.arange(n)
    best = placement_cost(adj, order)
    for _ in range(max_passes):
        improved = False
        for a in range(n - 1):
            for b in range(a + 1, n):
                order[a], order[b] = order[b], order[a]
                cost = placement_cost(adj, order)
                if cost < best:
                    best = cost
                    improved = True
                else:
                    order[a], order[b] = order[b], order[a]
        if not improved or best == 0:
            break
    return order


def apply_placement(topo, order: np.ndarray):
    """Renumber a (Directed)Topology: logical node i -> index order[i].

    Returns the same topology type with adjacency and weights permuted
    consistently (A'[order[i], order[j]] = A[i, j]), so the spectrum —
    and therefore every convergence quantity — is untouched; only the
    cyclic-shift decomposition (and hence the ppermute hop pattern)
    changes.
    """
    order = np.asarray(order)
    n = topo.n_nodes
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)   # row/col gather: new index k holds old perm[k]
    adj = np.asarray(topo.adjacency)[np.ix_(perm, perm)]
    w = np.asarray(topo.weights)[np.ix_(perm, perm)]
    return dataclasses.replace(topo, name=f"{topo.name}_placed",
                               adjacency=adj, weights=w)


# --------------------------------------------------------------------------
# Cyclic-shift decomposition (feeds gossip.PermuteSchedule).
# --------------------------------------------------------------------------
#
# Any simple graph on nodes 0..n-1 splits its edge set by the cyclic
# difference s = (receiver - sender) mod n. For a fixed s the send pairs
# {(j, (j+s) % n)} have distinct sources and distinct destinations, so each
# class is a valid (partial) `jax.lax.ppermute` permutation: nodes missing
# from the destination list receive zeros. A graph therefore gossips in
# exactly |{distinct shifts}| collective-permute rounds — 2 for the
# symmetric ring, 4 for a 2-D torus with rows, cols > 2, up to n-1 for a
# dense Erdős–Rényi graph.

def shift_decomposition(adjacency: np.ndarray) -> dict[int, list[tuple[int, int]]]:
    """Group directed edges (sender j -> receiver (j+s) % n) by shift s.

    Returns {shift: [(src, dst), ...]} covering every ordered pair with
    ``adjacency[dst, src] != 0``; shifts with no edges are omitted.
    """
    adj = np.asarray(adjacency)
    n = adj.shape[0]
    rounds: dict[int, list[tuple[int, int]]] = {}
    for s in range(1, n):
        pairs = [(j, (j + s) % n) for j in range(n) if adj[(j + s) % n, j]]
        if pairs:
            rounds[s] = pairs
    return rounds


def shift_receive_weights(topo: "Topology", shift: int) -> np.ndarray:
    """Per-receiver weight vector for one shift round.

    ``out[r] = W[r, (r - shift) % n]`` when the edge exists, else 0 — the
    factor a receiver applies to the payload arriving from its shift-s
    sender (non-edges receive ppermute zeros and a zero weight).
    """
    n = topo.n_nodes
    out = np.zeros((n,), dtype=np.float64)
    for r in range(n):
        j = (r - shift) % n
        if topo.adjacency[r, j]:
            out[r] = topo.weights[r, j]
    return out


def by_name(spec: str, n_nodes: int, *, self_weight: float | None = None,
            seed: int = 0) -> "Topology | DirectedTopology":
    """Parse a CLI topology spec into a Topology on ``n_nodes`` nodes.

    Accepted forms: ``ring``, ``torus`` (auto-factored near-square),
    ``torusRxC``, ``er`` / ``er:<p_connect>``, ``star``, ``complete``,
    and the directed (column-stochastic, push-sum) graphs ``dring`` and
    ``der`` / ``der:<p_connect>``. On a single node every spec collapses
    to the degenerate ``complete(1)`` (W = [[1]], no gossip rounds) so
    1-device smoke meshes work for every method.
    """
    spec = spec.strip().lower()
    if n_nodes == 1:
        return complete(1)
    if spec == "dring":
        return directed_ring(n_nodes, self_weight)
    if spec.startswith("der"):
        p_connect = float(spec.split(":", 1)[1]) if ":" in spec else 0.35
        return directed_erdos_renyi(n_nodes, p_connect, seed=seed)
    if spec == "ring":
        return ring(n_nodes, self_weight)
    if spec.startswith("torus"):
        if spec == "torus":
            rows = next(r for r in range(int(np.sqrt(n_nodes)), 0, -1)
                        if n_nodes % r == 0)
            cols = n_nodes // rows
        else:
            rows, cols = (int(v) for v in spec[len("torus"):].split("x"))
            if rows * cols != n_nodes:
                raise ValueError(
                    f"torus {rows}x{cols} has {rows * cols} nodes, "
                    f"mesh has {n_nodes}")
        return torus_2d(rows, cols)
    if spec.startswith("er"):
        p_connect = float(spec.split(":", 1)[1]) if ":" in spec else 0.35
        return erdos_renyi(n_nodes, p_connect, seed=seed)
    if spec == "star":
        return star(n_nodes)
    if spec == "complete":
        return complete(n_nodes)
    raise ValueError(f"unknown topology spec {spec!r}")


def _is_connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        i = frontier.pop()
        for j in np.nonzero(adj[i])[0]:
            if j not in seen:
                seen.add(int(j))
                frontier.append(int(j))
    return len(seen) == n
