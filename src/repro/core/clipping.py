"""Coordinate-wise gradient clipping (paper §5, "Procedure for Privacy").

The paper writes ``Clip([g]_i) = sign([g]_i) * max{|[g]_i|, C}`` but states
"with this clipping, each coordinate of the gradient is bounded by C in
magnitude" — the formula is a typo for ``min`` (``max`` would *raise*
small coordinates). We implement the stated semantics:
``clip(g)_i = sign(g_i) * min(|g_i|, C)``, i.e. an element-wise clamp to
[-C, C]. With C = G/sqrt(d) this enforces Assumption 1(4) and hence the
l2-sensitivity bound ||g|| <= G used by Theorem 1.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import tagging

__all__ = ["clip_coordinates", "clip_tree", "sensitivity_G"]


def clip_coordinates(g: jax.Array, c: float) -> jax.Array:
    """Element-wise clamp of each coordinate to [-c, c]."""
    return jnp.clip(g, -c, c)


def clip_tree(grads: Any, c: float) -> Any:
    """Clamp every leaf to [-c, c] and declare the bound in the jaxpr.

    The ``clip_bound`` tag (identity at runtime) is what lets the
    sensitivity certifier seed its norm-bound domain at c instead of
    having to recognize XLA's clamp lowering, and what it cross-checks
    against the clip the accountant was told about.
    """
    clipped = jax.tree.map(lambda g: clip_coordinates(g, c), grads)
    return tagging.clip_bound(clipped, bound=c)


def sensitivity_G(c: float, d: int) -> float:
    """The l2-sensitivity bound implied by coordinate clip c over d coords.

    Coordinate-wise |g_i| <= c gives ||g||_2 <= c * sqrt(d); with the
    paper's parameterization c = G/sqrt(d) this returns G.
    """
    return c * math.sqrt(d)
