"""The Bernoulli sparsifier S(.) of Definition 2 plus the packed fixed-k variant.

Definition 2 (paper §3): for x in R^d and p in (0, 1],
    [S(x)]_i = x_i / p   with probability p
    [S(x)]_i = 0         with probability 1-p
so that E[S(x)] = x (unbiased) and Var = (1/p - 1) ||x||^2 (Lemma 1, §3).

Two realizations:

* ``bernoulli_sparsify`` — the paper-faithful i.i.d. per-coordinate mask.
  The output is a dense tensor with ~ (1-p) d zeros; this is what the
  paper's theory analyses and what the CPU experiments use.

* ``fixedk_*`` — the TPU "packed" adaptation (DESIGN.md §2): exactly
  k = ceil(p*d) coordinates are chosen uniformly at random from a seed
  both endpoints can regenerate, so only k values ever cross the wire
  (a static-shape `collective-permute` operand). Selection probability
  per coordinate is k/d = p and kept values are scaled by d/k = 1/p,
  so unbiasedness is preserved; coordinates are no longer independent
  (slightly *lower* variance than i.i.d. Bernoulli by negative
  correlation — strictly favourable for the Lemma-1 terms).

Everything here operates on flat vectors; pytree handling lives in
``sdm_dsgd.py`` (a single flat offset-map keeps masks consistent across
leaves).
"""
from __future__ import annotations

import decimal
import functools
import math
from fractions import Fraction
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "bernoulli_mask",
    "bernoulli_sparsify",
    "fixedk_indices",
    "fixedk_pack",
    "fixedk_unpack",
    "fixedk_sparsify",
    "sparsifier_variance",
    "num_kept",
    "block_view",
    "block_sparsify",
]


def bernoulli_mask(key: jax.Array, shape: Tuple[int, ...], p: float) -> jax.Array:
    """Boolean keep-mask with i.i.d. keep-probability p."""
    return jax.random.bernoulli(key, p=p, shape=shape)


def bernoulli_sparsify(key: jax.Array, x: jax.Array, p) -> jax.Array:
    """Paper-faithful S(x): keep each coordinate w.p. p, scale kept by 1/p.

    ``p`` is a python float (static) or a traced scalar — the latter
    carries a per-node transmit probability (heterogeneous sparsity
    budgets): the keep-mask is ``uniform < p`` either way, so a node's
    draws for equal p agree bit-for-bit between the two forms.
    """
    if isinstance(p, (int, float)):
        if not 0.0 < p <= 1.0:
            raise ValueError(f"p must be in (0, 1], got {p}")
        if p == 1.0:
            return x
    mask = bernoulli_mask(key, x.shape, p)
    return jnp.where(mask, x / p, jnp.zeros_like(x))


def sparsifier_variance(x: jax.Array, p: float) -> jax.Array:
    """Lemma 1 (§3): Var(S(x)) = (1/p - 1) ||x||_2^2 (total, summed over coords)."""
    return (1.0 / p - 1.0) * jnp.sum(jnp.square(x))


# --------------------------------------------------------------------------
# Fixed-count ("packed") sparsification: the communication-real variant.
# --------------------------------------------------------------------------

def fixedk_indices(key: jax.Array, d: int, k: int) -> jax.Array:
    """k distinct uniform indices into [0, d), regenerable from ``key``.

    Uses argtop-k of i.i.d. uniforms — equivalent to sampling without
    replacement, O(d log d) once per round (amortized: tiny vs model math).
    """
    scores = jax.random.uniform(key, (d,))
    _, idx = jax.lax.top_k(scores, k)
    return idx


def fixedk_pack(x_flat: jax.Array, idx: jax.Array, d: int) -> jax.Array:
    """Gather the selected coordinates and pre-scale by d/k (= 1/p_effective).

    The exact inclusion probability of each coordinate is k/d, so the
    unbiased scale is d/k (equals 1/p when p*d is integral). Shape (k,).
    """
    k = idx.shape[0]
    return jnp.take(x_flat, idx, axis=0) * (d / k)


def fixedk_unpack(values: jax.Array, idx: jax.Array, d: int) -> jax.Array:
    """Scatter packed values back to a dense (d,) vector of S(x)."""
    out = jnp.zeros((d,), dtype=values.dtype)
    return out.at[idx].set(values)


def fixedk_sparsify(key: jax.Array, x_flat: jax.Array, p: float) -> jax.Array:
    """Dense-output fixed-k sparsifier (for testing against the packed path)."""
    d = x_flat.shape[0]
    k = num_kept(d, p)
    idx = fixedk_indices(key, d, k)
    return fixedk_unpack(fixedk_pack(x_flat, idx, d), idx, d)


@functools.lru_cache(maxsize=None)
def num_kept(d: int, p: float) -> int:
    """k = ceil(p * d), at least 1, at most d.

    The ceiling is computed in EXACT arithmetic: naive ceil(d * p)
    overshoots whenever the float product lands epsilon above the true
    value (e.g. 100 * 0.07 == 7.000000000000001 -> 8), breaking the
    "exactly k = ceil(p*d)" contract and every byte-accounting consumer
    — and decimal-rounding workarounds fail again once d*p > ~2e7 where
    the float ulp exceeds the rounding threshold. ``repr(p)`` is the
    shortest decimal that round-trips to p, i.e. the number the caller
    actually wrote; the Fraction of that is exact at any scale. Cached,
    so the exact-arithmetic cost is paid once per (d, p).
    """
    p_exact = Fraction(decimal.Decimal(repr(p)))
    return min(d, max(1, math.ceil(p_exact * d)))


# --------------------------------------------------------------------------
# Block-granular fixed-k: transmit whole contiguous blocks of coordinates.
# --------------------------------------------------------------------------
#
# For billion-element leaves, element-granular top_k is both illegal
# (int32 index overflow beyond 2^31 elements) and wasteful (a giant sort
# per round). Real systems sparsify at bucket granularity; here blocks of
# ``block`` consecutive coordinates are kept/dropped together:
# inclusion probability per coordinate is k_blocks/n_blocks ~= p and the
# kept blocks are scaled by n_blocks/k_blocks, so Lemma 1's unbiasedness
# is preserved (coordinates within a block are fully correlated, across
# blocks negatively correlated). ``block=1`` reduces exactly to the
# element-granular scheme.

def block_view(x_flat: jax.Array, block: int) -> jax.Array:
    """Pad to a block multiple and reshape to (n_blocks, block)."""
    d = x_flat.shape[0]
    pad = (-d) % block
    if pad:
        x_flat = jnp.pad(x_flat, (0, pad))
    return x_flat.reshape(-1, block)


def block_sparsify(key: jax.Array, x_flat: jax.Array, p: float,
                   block: int) -> jax.Array:
    """Dense-output block-granular fixed-k sparsifier."""
    d = x_flat.shape[0]
    xb = block_view(x_flat, block)
    nb = xb.shape[0]
    kb = num_kept(nb, p)
    idx = fixedk_indices(key, nb, kb)
    vals = jnp.take(xb, idx, axis=0) * (nb / kb)
    out = jnp.zeros_like(xb).at[idx].set(vals)
    return out.reshape(-1)[:d]
