"""Gossip exchange primitives: dense-W reference and TPU mesh collectives.

Interchangeable realizations of "each node sends its (sparsified)
message to its graph neighbours":

* ``mix_dense``        — reference: einsum with the full (n, n) consensus
                         matrix over a node-stacked leading axis. Used by
                         the single-host simulator and all correctness
                         tests; supports arbitrary topologies (ER graphs).
* ``exchange``         — distributed, ANY static topology: a compiled
                         ``PermuteSchedule`` of `jax.lax.ppermute` rounds.
                         Lowers to TPU `collective-permute`. Dense payload
                         (paper-faithful Bernoulli-masked tensors).
* ``exchange_packed`` / ``exchange_packed_rows``
                       — distributed + communication-real: only the
                         k = ceil(p*d) selected values cross the wire;
                         the index set is regenerated on the receiver from
                         the (round, sender) seed. Collective bytes shrink
                         by exactly p. (DESIGN.md §2.)
* ``ring_exchange*``   — the original hand-written degree-2 symmetric-ring
                         specializations, kept as the minimal-latency fast
                         path and for backward compatibility.

Schedule design
---------------
``schedule_from_topology`` compiles a ``Topology`` into a static
``PermuteSchedule``: the graph's directed edges are grouped by cyclic
shift s = (receiver - sender) mod n (see
``topology.shift_decomposition``), and each shift class becomes one
partial ``ppermute`` whose sources/destinations are exactly that class's
edges. Receivers that are not a destination in a round get ppermute's
implicit zeros. Per-edge consensus weights W_ij are applied locally by
the receiver: round s carries a per-node weight vector
``w_s[r] = W[r, (r-s) % n]`` (zero on non-edges), embedded as a constant
and indexed by ``axis_index``. The weighted neighbour sum is therefore

    sum_s w_s[me] * ppermute_s(x)  ==  sum_{j in N_i} W_ij x_j,

with one collective-permute per distinct shift: 2 rounds for the
symmetric ring, 4 for a 2-D torus, up to n-1 for dense ER graphs — all
with static shapes, so packed fixed-k payloads work unchanged: the
shift-s sender of node ``me`` is ``(me - s) % n``, whose index set the
receiver regenerates from ``node_round_key`` exactly as the ring path
does. Self-weights W_ii may differ per node (Metropolis–Hastings
graphs); ``PermuteSchedule.self_weight_of(me)`` resolves them on-mesh.

All distributed functions must be called inside `jax.shard_map` with the
node axis manual.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from fractions import Fraction
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparsifier, tagging

# Fused sender-side fixed-k packing (kernels/wire_compress gather+scale
# pallas kernel) for the static scalar-p payload path. Bit-exact to the
# unfused jnp gather, so this is a launch-count knob, never a trajectory
# knob; REPRO_FUSED_PACK=0 is the escape hatch.
FUSED_PACK = os.environ.get("REPRO_FUSED_PACK", "1") != "0"

__all__ = [
    "mix_dense",
    "apply_weights_dense",
    "PermuteSchedule",
    "ScheduleRound",
    "ScheduleSequence",
    "UnionRound",
    "UnionSchedule",
    "union_schedule",
    "needs_replicas",
    "weight_invariant",
    "mean_out_degree",
    "replica_recv_weights",
    "schedule_from_topology",
    "sequence_from_topologies",
    "sequence_by_name",
    "ensure_sequence",
    "ring_schedule",
    "resolve_schedule",
    "resolve_sequence",
    "exchange",
    "exchange_payload",
    "exchange_packed",
    "exchange_packed_rows",
    "union_exchange",
    "union_exchange_payload",
    "union_exchange_packed",
    "union_exchange_packed_rows",
    "ring_exchange",
    "ring_weighted_neighbor_sum",
    "ring_exchange_packed",
    "node_round_key",
]


# --------------------------------------------------------------------------
# Reference (single-host, node-stacked) path.
# --------------------------------------------------------------------------

def mix_dense(weights: jax.Array, x_stack: jax.Array) -> jax.Array:
    """(W x)_i = sum_j W_ij x_j over the leading node axis."""
    return jnp.einsum("ij,j...->i...", weights, x_stack)


def apply_weights_dense(weights: jax.Array, msgs_stack: jax.Array,
                        include_self: bool = False) -> jax.Array:
    """Weighted neighbour sum sum_{j != i} W_ij msg_j (optionally + W_ii msg_i)."""
    w = weights if include_self else weights - jnp.diag(jnp.diag(weights))
    return jnp.einsum("ij,j...->i...", w, msgs_stack)


# --------------------------------------------------------------------------
# Static permute schedules: any Topology -> ppermute rounds.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScheduleRound:
    """One ppermute round: all edges with (receiver - sender) % n == shift."""

    shift: int
    perm: Tuple[Tuple[int, int], ...]       # (src, dst) pairs, partial perm
    recv_weights: Tuple[float, ...]         # (n,) W[r, (r-shift) % n] or 0


@dataclasses.dataclass(frozen=True)
class PermuteSchedule:
    """A Topology compiled to static collective-permute rounds.

    Hashable/static: safe to close over in jit/shard_map. ``rounds`` has
    one entry per distinct cyclic shift present in the adjacency;
    ``self_weights[i] = W_ii`` (may vary per node, e.g. MH weights).
    """

    name: str
    n_nodes: int
    self_weights: Tuple[float, ...]
    rounds: Tuple[ScheduleRound, ...]

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    def self_weight_of(self, me) -> jax.Array:
        """W_ii for the calling node (index with axis_index inside shard_map)."""
        return jnp.asarray(self.self_weights, jnp.float32)[me]

    def neighbor_weight_sums(self) -> Tuple[float, ...]:
        """Row sums minus the diagonal: sum_{j != i} W_ij per node.

        For doubly stochastic W this is 1 - W_ii; for column-stochastic
        push matrices rows do NOT sum to 1, so compressed push-sum init
        (s_0 = sum_{j != i} P_ij x_0) needs the true per-node row sum.
        """
        n = self.n_nodes
        sums = [0.0] * n
        for rnd in self.rounds:
            for r in range(n):
                sums[r] += rnd.recv_weights[r]
        return tuple(sums)

    def dense_weights(self) -> np.ndarray:
        """Reconstruct the full (n, n) consensus/push matrix W.

        Inverse of ``schedule_from_topology``: W_ii from ``self_weights``
        and W[r, (r - s) % n] from round s's receive weights. Reference
        executors mix with exactly this matrix, so both executors are
        built from the same schedule object.
        """
        n = self.n_nodes
        w = np.diag(np.asarray(self.self_weights, np.float64))
        for rnd in self.rounds:
            for r in range(n):
                if rnd.recv_weights[r]:
                    w[r, (r - rnd.shift) % n] = rnd.recv_weights[r]
        return w


@dataclasses.dataclass(frozen=True)
class ScheduleSequence:
    """A (possibly time-varying) gossip schedule: one PermuteSchedule per
    round, cycled by the iteration counter (B-connected sequences).

    Static graphs are the length-1 special case. Hashable/static like
    ``PermuteSchedule`` — safe to close over in jit/shard_map; the
    *traced* step counter picks the active schedule at runtime via
    ``lax.switch`` in the exchange helpers.
    """

    name: str
    n_nodes: int
    schedules: Tuple[PermuteSchedule, ...]

    def __post_init__(self) -> None:
        if not self.schedules:
            raise ValueError("ScheduleSequence needs >= 1 schedule")
        if any(s.n_nodes != self.n_nodes for s in self.schedules):
            raise ValueError("all schedules must share n_nodes")

    @property
    def length(self) -> int:
        return len(self.schedules)

    @property
    def n_rounds(self) -> int:
        """Worst-case collective-permute rounds per gossip step."""
        return max(s.n_rounds for s in self.schedules)

    def at(self, t: int) -> PermuteSchedule:
        """The schedule active at (python int) iteration t."""
        return self.schedules[int(t) % self.length]

    def self_weight_of(self, me, step=None) -> jax.Array:
        """W_ii(step) for the calling node; ``step`` may be traced."""
        if self.length == 1 or step is None:
            return self.schedules[0].self_weight_of(me)
        table = jnp.asarray([s.self_weights for s in self.schedules],
                            jnp.float32)          # (L, n)
        return table[step % self.length, me]

    def weights_stack(self) -> np.ndarray:
        """(L, n, n) stacked dense matrices (reference-executor mixing)."""
        return np.stack([s.dense_weights() for s in self.schedules])


def ensure_sequence(schedule) -> ScheduleSequence:
    """Wrap a single PermuteSchedule as a length-1 ScheduleSequence."""
    if isinstance(schedule, ScheduleSequence):
        return schedule
    return ScheduleSequence(name=schedule.name, n_nodes=schedule.n_nodes,
                            schedules=(schedule,))


def sequence_of(topo) -> ScheduleSequence:
    """Normalize ANY graph argument to a ScheduleSequence.

    Accepts a ScheduleSequence, a PermuteSchedule, or a (Directed)Topology
    — the single conversion every reference executor and the trainer use,
    so graph handling cannot drift between them.
    """
    if isinstance(topo, (PermuteSchedule, ScheduleSequence)):
        return ensure_sequence(topo)
    return ensure_sequence(schedule_from_topology(topo))


def schedule_from_topology(topo) -> PermuteSchedule:
    """Compile ``topo`` (a topology.Topology) into a PermuteSchedule."""
    from repro.core import topology as topology_mod

    adj = np.asarray(topo.adjacency)
    n = topo.n_nodes
    rounds = []
    for shift, pairs in sorted(topology_mod.shift_decomposition(adj).items()):
        rw = topology_mod.shift_receive_weights(topo, shift)
        rounds.append(ScheduleRound(
            shift=shift,
            perm=tuple((int(a), int(b)) for a, b in pairs),
            recv_weights=tuple(float(v) for v in rw)))
    return PermuteSchedule(
        name=topo.name, n_nodes=n,
        self_weights=tuple(float(topo.weights[i, i]) for i in range(n)),
        rounds=tuple(rounds))


def sequence_from_topologies(topos, name: str | None = None
                             ) -> ScheduleSequence:
    """Compile a list of topologies into a time-varying ScheduleSequence."""
    schedules = tuple(schedule_from_topology(t) for t in topos)
    return ScheduleSequence(
        name=name or "+".join(s.name for s in schedules)[:64],
        n_nodes=schedules[0].n_nodes, schedules=schedules)


def sequence_by_name(spec: str, n_nodes: int, *,
                     self_weight: float | None = None,
                     seed: int = 0, placement: bool = False
                     ) -> ScheduleSequence:
    """Parse a CLI spec into a ScheduleSequence.

    Static ``topology.by_name`` specs give a length-1 sequence;
    ``matchings`` / ``matchings:<L>`` gives L random per-round matchings
    (B-connected time-varying gossip), cycled by the step counter.

    ``placement=True`` renumbers the logical nodes with
    ``topology.greedy_placement`` before compiling, so high-traffic
    shifts land on nearest-neighbour ICI permutations (time-varying
    sequences place their UNION graph — one consistent renumbering for
    every round). Spectrum-preserving (``apply_placement`` permutes W
    symmetrically) and monotone: applied only when it strictly lowers
    the ring-hop cost, so optimal layouts compile byte-identically.
    """
    from repro.core import topology as topology_mod

    def placed(topos):
        if not placement:
            return topos
        union = np.zeros((n_nodes, n_nodes), dtype=np.int64)
        for t in topos:
            union |= np.asarray(t.adjacency, dtype=np.int64)
        order = topology_mod.greedy_placement(union)
        if topology_mod.placement_cost(union, order) < \
                topology_mod.placement_cost(union):
            return [topology_mod.apply_placement(t, order) for t in topos]
        return topos

    spec = spec.strip().lower()
    if spec.startswith("matchings") and n_nodes > 1:
        rounds = int(spec.split(":", 1)[1]) if ":" in spec else 4
        topos = placed(topology_mod.random_matchings(
            n_nodes, rounds, seed=seed,
            self_weight=0.5 if self_weight is None else self_weight))
        return sequence_from_topologies(
            topos, name=f"matchings{n_nodes}x{rounds}_s{seed}")
    if spec.startswith("matchings"):    # n_nodes == 1 degenerate
        spec = "complete"
    topo = topology_mod.by_name(spec, n_nodes, self_weight=self_weight,
                                seed=seed)
    [topo] = placed([topo])
    return ensure_sequence(schedule_from_topology(topo))


def sequence_from_active_sets(topo, active_sets, name: str | None = None
                              ) -> ScheduleSequence:
    """Compile a partial-participation trace into a ScheduleSequence.

    ``active_sets`` is one iterable of participating node indices per
    round (the edge-fleet simulator's sampled subgraphs); each round
    compiles the induced ``topology.masked_subgraph`` — inactive nodes
    isolated, active-active edges reweighted on the induced graph. The
    result is an ordinary (usually genuinely time-varying, hence
    replica-transported) sequence, so every executor and the analyzer
    matrix consume it like any other schedule.
    """
    active_sets = list(active_sets)
    if not active_sets:
        raise ValueError("need >= 1 active set")
    from repro.core import topology as topology_mod

    topos = [topology_mod.masked_subgraph(topo, a,
                                          name=f"{topo.name}_sub_r{t}")
             for t, a in enumerate(active_sets)]
    return sequence_from_topologies(
        topos, name=name or f"{topo.name}_part{len(active_sets)}")


# --------------------------------------------------------------------------
# Union schedules: the replica-correct transport for time-varying sequences.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UnionRound:
    """One ppermute round of the UNION graph of a schedule sequence.

    ``perm`` carries every directed edge with this cyclic shift that
    appears in ANY round of the sequence; ``recv_weights[t][r]`` is the
    weight W^{(t)}[r, (r - shift) % n] the edge carries at sequence
    position t (zero when the edge is inactive that round — the payload
    still crosses so the receiver's replica stays exact).
    """

    shift: int
    perm: Tuple[Tuple[int, int], ...]
    recv_weights: Tuple[Tuple[float, ...], ...]     # (L, n)


@dataclasses.dataclass(frozen=True)
class UnionSchedule:
    """The union graph of a ScheduleSequence compiled to ppermute rounds.

    The transport of the replica-correct time-varying executors: payloads
    cross EVERY union edge EVERY round (so receivers see every increment
    and per-neighbour public-copy replicas are exact by construction),
    while the mixing weights vary with the sequence position. Delivery is
    round-invariant, so no ``lax.switch`` is needed on this path — only
    the (step % L)-indexed weight gather depends on the traced step.

    Each round contributes at most one in-neighbour per node (the shift-s
    sender of ``me`` is ``(me - s) % n``), so ``n_replicas`` replica
    slots — one per union round, "tagged by sender round-position" —
    index every possible in-neighbour with one static shape.
    """

    name: str
    n_nodes: int
    length: int
    rounds: Tuple[UnionRound, ...]

    @property
    def n_replicas(self) -> int:
        """Replica slots per node: one per union shift round."""
        return len(self.rounds)

    def mean_out_degree(self) -> Fraction:
        """Mean (over nodes) union out-degree — payload transmissions per
        node per gossip step on the replica transport (same every round)."""
        edges = sum(len(rnd.perm) for rnd in self.rounds)
        return Fraction(edges, self.n_nodes)


@functools.lru_cache(maxsize=None)
def union_schedule(seq: ScheduleSequence) -> UnionSchedule:
    """Compile the union graph of ``seq`` with per-position edge weights."""
    seq = ensure_sequence(seq)
    n = seq.n_nodes
    edges_by_shift: dict = {}
    for sched in seq.schedules:
        shifts = [rnd.shift for rnd in sched.rounds]
        if len(shifts) != len(set(shifts)):
            # the per-position weight table below keys on (shift, t); two
            # same-shift rounds in one schedule would silently drop one
            # round's weights (the static executors SUM deliveries per
            # round, so they accept such schedules — we must not diverge
            # silently). Factory schedules (shift_decomposition) are safe.
            raise ValueError(
                f"union_schedule: schedule {sched.name!r} has duplicate "
                f"shifts {shifts}; merge same-shift rounds first")
        for rnd in sched.rounds:
            edges_by_shift.setdefault(rnd.shift, set()).update(rnd.perm)
    rounds = []
    for shift in sorted(edges_by_shift):
        rw = []
        for sched in seq.schedules:
            w_t = (0.0,) * n
            for rnd in sched.rounds:
                if rnd.shift == shift:
                    w_t = rnd.recv_weights
            rw.append(tuple(w_t))
        rounds.append(UnionRound(
            shift=shift,
            perm=tuple(sorted(edges_by_shift[shift])),
            recv_weights=tuple(rw)))
    return UnionSchedule(name=f"union({seq.name})", n_nodes=n,
                         length=seq.length, rounds=tuple(rounds))


@functools.lru_cache(maxsize=None)
def weight_invariant(seq: ScheduleSequence) -> bool:
    """True when every round of the sequence mixes with the SAME dense W.

    Then incremental neighbour-sum bookkeeping is exact (the weights an
    increment was folded with never differ from the current round's) and
    the replica transport is unnecessary.
    """
    ws = seq.weights_stack()
    return all(np.array_equal(ws[0], w) for w in ws[1:])


def needs_replicas(seq) -> bool:
    """Whether differential methods need per-neighbour replicas on ``seq``.

    Static schedules (and weight-invariant sequences) keep the
    incremental-``s`` fast path — byte-for-byte the pre-replica
    trajectories; genuinely time-varying weights need exact public-copy
    replicas for true W(t)-mixing.
    """
    seq = ensure_sequence(seq)
    return seq.length > 1 and not weight_invariant(seq)


def mean_out_degree(seq, *, union: bool = False,
                    node: "int | None" = None) -> Fraction:
    """Mean-over-rounds directed out-degree of the transport.

    The per-link wire-accounting factor: how many copies of its payload a
    node puts on the wire per gossip step — 2 for the symmetric ring, 1
    for perfect-matching rounds, the union-graph degree for the replica
    transport (``union=True``: every union edge carries the payload every
    round). ``node=None`` averages over nodes (the network-mean
    accounting convention); ``node=i`` counts node i's OWN out-edges
    (out-degree varies per node on e.g. star graphs). Exact Fraction so
    tree-level accounting can round ONCE.
    """
    seq = ensure_sequence(seq)

    def count(perm) -> int:
        if node is None:
            return len(perm)
        return sum(1 for src, _ in perm if src == node)

    denom = 1 if node is not None else seq.n_nodes
    if union:
        u = union_schedule(seq)
        return Fraction(sum(count(rnd.perm) for rnd in u.rounds), denom)
    total = sum(sum(count(rnd.perm) for rnd in s.rounds)
                for s in seq.schedules)
    return Fraction(total, denom * seq.length)


def replica_recv_weights(useq: UnionSchedule, me, step) -> jax.Array:
    """(n_replicas,) weights W_{me, sender_k}(step) for the replica slots.

    ``me`` and ``step`` may be traced; the (R, L, n) weight table is a
    closed-over constant, so this lowers to one gather — no collectives,
    no ``lax.switch``.
    """
    table = jnp.asarray([rnd.recv_weights for rnd in useq.rounds],
                        jnp.float32)            # (R, L, n)
    return table[:, step % useq.length, me]


def union_exchange(useq: UnionSchedule, x: jax.Array, axis_name) -> jax.Array:
    """ppermute ``x`` over every union round; (n_replicas, *x.shape) stack.

    Row k is the increment received from the shift-s_k sender (ppermute's
    implicit zeros where the union graph has no such in-edge — the slot's
    weight is zero at every sequence position, so the unused replica is
    never read).
    """
    return jnp.stack([_wire_ppermute(x, axis_name, rnd.perm)
                      for rnd in useq.rounds])


def union_exchange_payload(useq: UnionSchedule, payload, decompress,
                           axis_name) -> jax.Array:
    """Decompressed per-slot increments of a compressor payload.

    The replica-transport sibling of ``exchange_payload``: the payload
    pytree crosses every union round and the receiver decompresses each
    round's delivery SEPARATELY (tagged by round position) instead of
    folding a weighted sum — the caller adds row k onto replica slot k.
    """
    outs = []
    for rnd in useq.rounds:
        recv = jax.tree.map(
            lambda v: _wire_ppermute(v, axis_name, rnd.perm), payload)
        outs.append(decompress(recv))
    return jnp.stack(outs)


def _union_packed_exchange(useq: UnionSchedule, db: jax.Array, unpack, *,
                           axis_name, base_key: jax.Array, step: jax.Array,
                           p, node_index) -> Tuple[jax.Array, jax.Array]:
    """Packed replica transport on a (2-D block view of a) leaf.

    Selection/packing/scaling share ``_packed_selection`` with the
    static ``_packed_exchange`` transport (same keys, same pad-to-max-k
    heterogeneous-p payloads), but each union round's received values
    are unpacked into their OWN increment row instead of a weighted sum
    — one batched sender top_k per (leaf, step) regardless of sequence
    length.
    """
    nb_blocks = db.shape[0]
    me = _me(axis_name, node_index)
    kb, my_idx, my_vals = _packed_selection(db, p, me, base_key=base_key,
                                            step=step)
    own_sparse = unpack(my_vals, my_idx)

    sender_idx = _batched_sender_indices(
        useq, me, base_key=base_key, step=step, nb=nb_blocks, kb=kb)
    incr = jnp.stack([
        unpack(_wire_ppermute(my_vals, axis_name, rnd.perm),
               sender_idx[i])
        for i, rnd in enumerate(useq.rounds)])
    return own_sparse, incr


def union_exchange_packed(useq: UnionSchedule, d_flat: jax.Array, *,
                          axis_name, base_key: jax.Array, step: jax.Array,
                          p, block: int = 1,
                          node_index=None) -> Tuple[jax.Array, jax.Array]:
    """Replica-transport packed gossip; returns (own_sparse, (R, dim) incr)."""
    dim = d_flat.shape[0]
    db = sparsifier.block_view(d_flat, block)
    unpack = lambda vals, idx: jnp.zeros_like(db).at[idx].set(
        vals).reshape(-1)[:dim]
    return _union_packed_exchange(useq, db, unpack, axis_name=axis_name,
                                  base_key=base_key, step=step, p=p,
                                  node_index=node_index)


def union_exchange_packed_rows(useq: UnionSchedule, d: jax.Array, *,
                               axis_name, base_key: jax.Array,
                               step: jax.Array, p,
                               node_index=None
                               ) -> Tuple[jax.Array, jax.Array]:
    """Sharding-aligned packed replica transport (blocks = rows)."""
    shape = d.shape
    cols = shape[-1] if d.ndim > 1 else 1
    rows = d.size // cols
    db = d.reshape(rows, cols)
    unpack = lambda vals, idx: jnp.zeros_like(db).at[idx].set(
        vals).reshape(shape)
    return _union_packed_exchange(useq, db, unpack, axis_name=axis_name,
                                  base_key=base_key, step=step, p=p,
                                  node_index=node_index)


@functools.lru_cache(maxsize=None)
def ring_schedule(n: int, self_weight: float | None = None) -> PermuteSchedule:
    """The symmetric ring as a schedule (2 rounds: shifts +1 and n-1)."""
    from repro.core import topology as topology_mod

    return schedule_from_topology(topology_mod.ring(n, self_weight))


def resolve_schedule(schedule: PermuteSchedule | None, axis_name,
                     self_weight: float | None = None) -> PermuteSchedule:
    """Back-compat shim: default to the ring over the full node axis.

    Legacy callers pass scalar (self_weight, neighbor_weight) instead of a
    schedule; the axis size is static under shard_map tracing, so the ring
    schedule can be built on the fly.
    """
    if schedule is not None:
        if isinstance(schedule, ScheduleSequence):
            if schedule.length != 1:
                raise ValueError(
                    "time-varying sequence passed where a single static "
                    "schedule is required; use resolve_sequence")
            return schedule.schedules[0]
        return schedule
    n = int(jax.lax.psum(1, axis_name))
    return ring_schedule(n, self_weight)


def resolve_sequence(schedule, axis_name,
                     self_weight: float | None = None) -> ScheduleSequence:
    """Normalize PermuteSchedule | ScheduleSequence | None to a sequence.

    ``None`` keeps the legacy behaviour: the symmetric ring over the
    full node axis with scalar ``self_weight``.
    """
    if schedule is None:
        n = int(jax.lax.psum(1, axis_name))
        schedule = ring_schedule(n, self_weight)
    return ensure_sequence(schedule)


def _me(axis_name, node_index):
    """The caller's node index: explicit operand, or axis_index collective."""
    if node_index is not None:
        return node_index
    return jax.lax.axis_index(axis_name)


def _wire_ppermute(x: jax.Array, axis_name, perm) -> jax.Array:
    """The ONE ppermute call site of the transport layer.

    Every buffer this module puts on the wire goes through here, tagged
    ``tagging.wire_payload`` so ``repro.analysis`` can prove (a) no
    collective-permute bypasses the vetted transport and (b) the operand
    carries no unsanitized data-taint. Identity at runtime.
    """
    return jax.lax.ppermute(tagging.wire_payload(x), axis_name, perm)


def _round_weight(rnd: ScheduleRound, me, dtype) -> jax.Array:
    return jnp.asarray(rnd.recv_weights, jnp.float32)[me].astype(dtype)


def exchange(schedule, x: jax.Array, axis_name,
             node_index=None, step=None) -> jax.Array:
    """Weighted neighbour sum sum_{j in N_i(t)} W_ij(t) x_j, dense payload.

    One ppermute per schedule round; receivers with no shift-s in-edge get
    ppermute zeros and a zero weight, so the sum is exact on any graph.
    ``schedule`` may be a single PermuteSchedule or a time-varying
    ScheduleSequence — the latter needs the (possibly traced) ``step``
    counter, and lowers to a ``lax.switch`` over the per-round branches so
    only the active round's permutes execute. ``node_index`` overrides
    `axis_index` where that collective cannot lower (partial-auto
    shard_map on older jaxlibs).
    """
    seq = ensure_sequence(schedule)
    me = _me(axis_name, node_index)

    def one(sched: PermuteSchedule, v: jax.Array) -> jax.Array:
        total = jnp.zeros_like(v)
        for rnd in sched.rounds:
            recv = _wire_ppermute(v, axis_name, rnd.perm)
            total = total + _round_weight(rnd, me, v.dtype) * recv
        return total

    if seq.length == 1:
        return one(seq.schedules[0], x)
    if step is None:
        raise ValueError("time-varying ScheduleSequence needs step=")
    return jax.lax.switch(step % seq.length,
                          [functools.partial(one, s) for s in seq.schedules],
                          x)


def exchange_payload(schedule, payload, decompress, axis_name, *,
                     step=None, node_index=None) -> jax.Array:
    """Weighted neighbour sum of DECOMPRESSED compressor payloads.

    The generic transport behind ``repro.core.compressor``: ``payload``
    is any shape-static pytree (a ``compressor.Payload`` — values,
    explicit indices, scale scalar), and every leaf crosses the wire
    as-is via one ppermute per schedule round; the receiver runs
    ``decompress(recv_payload)`` and weighs locally. Nothing is
    regenerated from shared seeds, so ANY registered compressor works —
    packed fixed-k with explicit indices, int8 quantized values, dense
    masks — at the cost of shipping the index/scale side-channels
    (``exchange_packed*`` stays the seed-synchronized fast path for the
    SDM fixed-k modes). Non-destination receivers get ppermute's implicit
    zero payloads and a zero weight, so the sum is exact on any graph;
    time-varying sequences index by the traced ``step``.
    """
    seq = ensure_sequence(schedule)
    me = _me(axis_name, node_index)
    template = decompress(payload)   # shares work with the caller's own
    #                                  decompress via CSE; defines shape/dtype

    def one(sched: PermuteSchedule, pl) -> jax.Array:
        total = jnp.zeros_like(template)
        for rnd in sched.rounds:
            recv = jax.tree.map(
                lambda v: _wire_ppermute(v, axis_name, rnd.perm), pl)
            w = _round_weight(rnd, me, total.dtype)
            total = total + w * decompress(recv)
        return total

    if seq.length == 1:
        return one(seq.schedules[0], payload)
    if step is None:
        raise ValueError("time-varying ScheduleSequence needs step=")
    return jax.lax.switch(step % seq.length,
                          [functools.partial(one, s) for s in seq.schedules],
                          payload)


def _batched_sender_indices(schedule: PermuteSchedule, me, *,
                            base_key: jax.Array, step: jax.Array,
                            nb: int, kb: int) -> jax.Array:
    """All this-step senders' index sets from ONE shared uniform draw.

    Every shift round of a step exchanges the same leaf, so the per-step
    draw is shared: one (R, nb) batched uniform + one batched top_k
    replaces R separate draw+sort dispatches (one per round). Bit-equal
    to the per-round regeneration — vmapped PRNG draws and row-batched
    top_k match the scalar calls exactly — so trajectories are unchanged.
    Returns (n_rounds, kb) indices, row i for the shift of round i.
    """
    n = schedule.n_nodes
    shifts = jnp.asarray([rnd.shift for rnd in schedule.rounds], jnp.int32)
    senders = jnp.mod(me - shifts, n)
    keys = jax.vmap(lambda j: node_round_key(base_key, j, step))(senders)
    scores = jax.vmap(lambda k: jax.random.uniform(k, (nb,)))(keys)
    _, idx = jax.lax.top_k(scores, kb)
    return idx


def _packed_selection(db: jax.Array, p, me, *, base_key: jax.Array,
                      step: jax.Array) -> Tuple[int, jax.Array, jax.Array]:
    """Sender-side packed payload selection: (kb, my_idx, my_vals).

    The ONE implementation shared by the static (``_packed_exchange``)
    and the replica/union (``_union_packed_exchange``) transports, so
    their bit-equality contract (same keys, same pad-to-max-k payloads)
    cannot desynchronize.

    ``p`` may be a per-node tuple: the payload then pads to
    k_max = max_i ceil(p_i * n_blocks) — every node draws k_max top-k
    indices from its seed, zeroes value rows beyond its OWN k_i and
    scales kept rows by n_blocks/k_i. Top-k indices are distinct, so the
    zero pad rows scatter onto coordinates the sender did not select
    (already zero in S(d)) and receivers need no masking: the wire keeps
    ONE static shape while each node transmits its own budget.
    """
    nb_blocks = db.shape[0]
    if isinstance(p, tuple):
        k_table = tuple(sparsifier.num_kept(nb_blocks, pi) for pi in p)
        kb = max(k_table)
        kb_me = jnp.asarray(k_table, jnp.int32)[me]
        scale = (nb_blocks / kb_me.astype(jnp.float32)) \
            * (jnp.arange(kb)[:, None] < kb_me)
    else:
        kb = sparsifier.num_kept(nb_blocks, p)
        scale = nb_blocks / kb
    my_idx = sparsifier.fixedk_indices(
        node_round_key(base_key, me, step), nb_blocks, kb)
    if FUSED_PACK and not isinstance(p, tuple) and db.ndim == 2 \
            and db.dtype == jnp.float32:
        # fused sender-side pack: gather + contraction scale in ONE
        # pallas launch (bit-exact to the jnp pair below, so enabling
        # it never changes a trajectory). The het-p path keeps the jnp
        # ops: its scale is a traced per-node mask, not a static scalar.
        from repro.kernels import wire_compress   # lazy: core -> kernels
        my_vals = wire_compress.fixedk_gather_pack(db, my_idx, scale=scale)
    else:
        my_vals = (jnp.take(db, my_idx, axis=0) * scale).astype(db.dtype)
    return kb, my_idx, my_vals


def _packed_exchange(seq: ScheduleSequence, db: jax.Array, unpack, *,
                     axis_name, base_key: jax.Array, step: jax.Array,
                     p, node_index) -> Tuple[jax.Array, jax.Array]:
    """Shared engine for packed gossip on a (2-D block view of a) leaf.

    ``unpack(vals, idx)`` densifies a packed payload back to the leaf's
    original shape. Payload selection/packing (``_packed_selection``) is
    hoisted OUT of the schedule branches (it depends only on (me, step)),
    so time-varying sequences pay one packing + one switch over nb-sum
    branches.
    """
    nb_blocks = db.shape[0]
    me = _me(axis_name, node_index)
    kb, my_idx, my_vals = _packed_selection(db, p, me, base_key=base_key,
                                            step=step)
    own_sparse = unpack(my_vals, my_idx)

    def nb_for(sched: PermuteSchedule, vals_out: jax.Array) -> jax.Array:
        nb_sum = jnp.zeros_like(own_sparse)
        if not sched.rounds:
            return nb_sum
        sender_idx = _batched_sender_indices(
            sched, me, base_key=base_key, step=step, nb=nb_blocks, kb=kb)
        for i, rnd in enumerate(sched.rounds):
            # Wire traffic: only the packed (kb, block) values move.
            vals = _wire_ppermute(vals_out, axis_name, rnd.perm)
            w = _round_weight(rnd, me, own_sparse.dtype)
            nb_sum = nb_sum + w * unpack(vals, sender_idx[i])
        return nb_sum

    if seq.length == 1:
        return own_sparse, nb_for(seq.schedules[0], my_vals)
    return own_sparse, jax.lax.switch(
        step % seq.length,
        [functools.partial(nb_for, s) for s in seq.schedules], my_vals)


def exchange_packed(schedule, d_flat: jax.Array, *,
                    axis_name, base_key: jax.Array, step: jax.Array,
                    p, block: int = 1,
                    node_index=None) -> Tuple[jax.Array, jax.Array]:
    """One packed gossip round on any schedule; returns (own_sparse, nb_sum).

    Per round s only the sender's packed (kb, block) values cross the
    wire; the receiver regenerates the shift-s sender's index set from
    ``node_round_key(base_key, (me - s) % n, step)`` (one batched draw
    per step shared across rounds) and scatters + weighs locally.
    ``nb_sum = sum_{j in N_i} W_ij S(d_j)`` densified. Accepts a
    time-varying ScheduleSequence (round picked by ``step``).
    """
    dim = d_flat.shape[0]
    db = sparsifier.block_view(d_flat, block)
    unpack = lambda vals, idx: jnp.zeros_like(db).at[idx].set(
        vals).reshape(-1)[:dim]
    return _packed_exchange(ensure_sequence(schedule), db, unpack,
                            axis_name=axis_name, base_key=base_key,
                            step=step, p=p, node_index=node_index)


def exchange_packed_rows(schedule, d: jax.Array, *,
                         axis_name, base_key: jax.Array, step: jax.Array,
                         p,
                         node_index=None) -> Tuple[jax.Array, jax.Array]:
    """Sharding-aligned packed gossip on any schedule (blocks = rows).

    Same selection semantics as ``ring_exchange_packed_rows`` — the packed
    payload keeps each leaf's model-axis sharding — generalized to every
    schedule round and to time-varying sequences.
    """
    shape = d.shape
    cols = shape[-1] if d.ndim > 1 else 1
    rows = d.size // cols
    db = d.reshape(rows, cols)
    unpack = lambda vals, idx: jnp.zeros_like(db).at[idx].set(
        vals).reshape(shape)
    return _packed_exchange(ensure_sequence(schedule), db, unpack,
                            axis_name=axis_name, base_key=base_key,
                            step=step, p=p, node_index=node_index)


# --------------------------------------------------------------------------
# Distributed ring path (inside shard_map, node axis manual).
# --------------------------------------------------------------------------

def _perm(n: int, shift: int) -> Sequence[Tuple[int, int]]:
    return [(i, (i + shift) % n) for i in range(n)]


def ring_exchange(x, axis_name) -> Tuple[jax.Array, jax.Array]:
    """Send ``x`` to both ring neighbours; returns (from_left, from_right).

    ``from_left[i] = x[i-1]`` and ``from_right[i] = x[i+1]``.
    """
    n = jax.lax.psum(1, axis_name)
    from_left = _wire_ppermute(x, axis_name, _perm(n, +1))
    from_right = _wire_ppermute(x, axis_name, _perm(n, -1))
    return from_left, from_right


def ring_weighted_neighbor_sum(x, axis_name, neighbor_weight: float) -> jax.Array:
    """sum_{j in N_i} W_ij x_j for the symmetric ring (both neighbours weight w)."""
    from_left, from_right = ring_exchange(x, axis_name)
    return neighbor_weight * (from_left + from_right)


# --------------------------------------------------------------------------
# Packed (fixed-k) ring path.
# --------------------------------------------------------------------------

def node_round_key(base_key: jax.Array, node_index, step) -> jax.Array:
    """Sparsifier seed both endpoints can regenerate: f(base, node, round)."""
    return jax.random.fold_in(jax.random.fold_in(base_key, node_index), step)


def ring_exchange_packed(d_flat: jax.Array, *, axis_name, base_key: jax.Array,
                         step: jax.Array, p: float, neighbor_weight: float,
                         block: int = 1) -> Tuple[jax.Array, jax.Array]:
    """One SDM-DSGD gossip round with packed payloads.

    Each node i:
      1. draws its round-key K_i = f(base, i, step) and a block index set,
      2. packs the selected (k_blocks, block) values scaled by 1/p_eff —
         the ONLY wire payload, ppermuted to both ring neighbours,
      3. regenerates its neighbours' index sets from K_{i-1}, K_{i+1}
         locally and scatters the received values,
      4. returns (own_sparse, weighted_neighbor_sum) where
         own_sparse = S(d_i) densified and weighted_neighbor_sum =
         w * (S(d_{i-1}) + S(d_{i+1})).

    The wire cost per node per round is 2 * k * itemsize bytes instead of
    2 * d * itemsize — exactly the paper's p-fraction, realized in HLO.
    ``block > 1`` transmits contiguous blocks (bucket sparsification; see
    sparsifier.block_sparsify) — required beyond ~2^31-element leaves and
    DMA-friendly on TPU.
    """
    dim = d_flat.shape[0]
    db = sparsifier.block_view(d_flat, block)
    nb_blocks = db.shape[0]
    kb = sparsifier.num_kept(nb_blocks, p)
    scale = nb_blocks / kb
    n = jax.lax.psum(1, axis_name)
    me = jax.lax.axis_index(axis_name)

    my_key = node_round_key(base_key, me, step)
    my_idx = sparsifier.fixedk_indices(my_key, nb_blocks, kb)
    my_vals = jnp.take(db, my_idx, axis=0) * scale   # (kb, block)

    # Wire traffic: only the packed (kb, block) values move.
    vals_from_left = _wire_ppermute(my_vals, axis_name, _perm(n, +1))
    vals_from_right = _wire_ppermute(my_vals, axis_name, _perm(n, -1))

    # Receivers regenerate sender index sets (no index traffic).
    left_idx = sparsifier.fixedk_indices(
        node_round_key(base_key, (me - 1) % n, step), nb_blocks, kb)
    right_idx = sparsifier.fixedk_indices(
        node_round_key(base_key, (me + 1) % n, step), nb_blocks, kb)

    unpack = lambda vals, idx: jnp.zeros_like(db).at[idx].set(
        vals).reshape(-1)[:dim]
    own_sparse = unpack(my_vals, my_idx)
    nb_sum = unpack(vals_from_left, left_idx) + \
        unpack(vals_from_right, right_idx)
    return own_sparse, neighbor_weight * nb_sum


def ring_exchange_packed_rows(d: jax.Array, *, axis_name, base_key: jax.Array,
                              step: jax.Array, p: float,
                              neighbor_weight: float
                              ) -> Tuple[jax.Array, jax.Array]:
    """Sharding-aligned packed gossip: blocks = trailing-dim rows.

    ``ring_exchange_packed`` flattens the leaf, which destroys the tensor-
    parallel layout of model-sharded dims and makes GSPMD all-gather the
    whole leaf around the gather/scatter (measured: +23% collective bytes
    on qwen1.5-32b train instead of the predicted 10x drop). Here the
    block unit is a whole trailing-dim row: the gather indexes only the
    UNsharded leading dims, each packed row keeps the leaf's model-axis
    sharding, and the ppermute payload is itself tensor-parallel.

    Selection semantics equal ``sparsifier.block_sparsify`` with
    block = leaf.shape[-1] (row-major): inclusion probability k/rows ~= p,
    scale rows/k — unbiasedness intact.
    """
    shape = d.shape
    cols = shape[-1] if d.ndim > 1 else 1
    rows = d.size // cols
    db = d.reshape(rows, cols)
    kb = sparsifier.num_kept(rows, p)
    scale = rows / kb
    n = jax.lax.psum(1, axis_name)
    me = jax.lax.axis_index(axis_name)

    my_idx = sparsifier.fixedk_indices(
        node_round_key(base_key, me, step), rows, kb)
    my_vals = jnp.take(db, my_idx, axis=0) * scale      # (kb, cols)

    vals_from_left = _wire_ppermute(my_vals, axis_name, _perm(n, +1))
    vals_from_right = _wire_ppermute(my_vals, axis_name, _perm(n, -1))

    left_idx = sparsifier.fixedk_indices(
        node_round_key(base_key, (me - 1) % n, step), rows, kb)
    right_idx = sparsifier.fixedk_indices(
        node_round_key(base_key, (me + 1) % n, step), rows, kb)

    unpack = lambda vals, idx: jnp.zeros_like(db).at[idx].set(
        vals).reshape(shape)
    own_sparse = unpack(my_vals, my_idx)
    nb_sum = unpack(vals_from_left, left_idx) + \
        unpack(vals_from_right, right_idx)
    return own_sparse, neighbor_weight * nb_sum
