"""Gossip exchange primitives: dense-W reference and TPU ring collectives.

Three interchangeable realizations of "each node sends its (sparsified)
message to its graph neighbours":

* ``mix_dense``        — reference: einsum with the full (n, n) consensus
                         matrix over a node-stacked leading axis. Used by
                         the single-host simulator and all correctness
                         tests; supports arbitrary topologies (ER graphs).
* ``ring_exchange``    — distributed: two `jax.lax.ppermute`s over a named
                         mesh axis (the node axis). Lowers to TPU
                         `collective-permute`, nearest-neighbour on the
                         ICI torus. Dense payload (paper-faithful
                         Bernoulli-masked tensors).
* ``ring_exchange_packed`` — distributed + communication-real: only the
                         k = ceil(p*d) selected values cross the wire;
                         the index set is regenerated on the receiver from
                         the (round, sender) seed. Collective bytes shrink
                         by exactly p. (DESIGN.md §2.)

All distributed functions must be called inside `jax.shard_map` with the
node axis manual.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import sparsifier

__all__ = [
    "mix_dense",
    "apply_weights_dense",
    "ring_exchange",
    "ring_weighted_neighbor_sum",
    "ring_exchange_packed",
    "node_round_key",
]


# --------------------------------------------------------------------------
# Reference (single-host, node-stacked) path.
# --------------------------------------------------------------------------

def mix_dense(weights: jax.Array, x_stack: jax.Array) -> jax.Array:
    """(W x)_i = sum_j W_ij x_j over the leading node axis."""
    return jnp.einsum("ij,j...->i...", weights, x_stack)


def apply_weights_dense(weights: jax.Array, msgs_stack: jax.Array,
                        include_self: bool = False) -> jax.Array:
    """Weighted neighbour sum sum_{j != i} W_ij msg_j (optionally + W_ii msg_i)."""
    w = weights if include_self else weights - jnp.diag(jnp.diag(weights))
    return jnp.einsum("ij,j...->i...", w, msgs_stack)


# --------------------------------------------------------------------------
# Distributed ring path (inside shard_map, node axis manual).
# --------------------------------------------------------------------------

def _perm(n: int, shift: int) -> Sequence[Tuple[int, int]]:
    return [(i, (i + shift) % n) for i in range(n)]


def ring_exchange(x, axis_name) -> Tuple[jax.Array, jax.Array]:
    """Send ``x`` to both ring neighbours; returns (from_left, from_right).

    ``from_left[i] = x[i-1]`` and ``from_right[i] = x[i+1]``.
    """
    n = jax.lax.psum(1, axis_name)
    from_left = jax.lax.ppermute(x, axis_name, _perm(n, +1))
    from_right = jax.lax.ppermute(x, axis_name, _perm(n, -1))
    return from_left, from_right


def ring_weighted_neighbor_sum(x, axis_name, neighbor_weight: float) -> jax.Array:
    """sum_{j in N_i} W_ij x_j for the symmetric ring (both neighbours weight w)."""
    from_left, from_right = ring_exchange(x, axis_name)
    return neighbor_weight * (from_left + from_right)


# --------------------------------------------------------------------------
# Packed (fixed-k) ring path.
# --------------------------------------------------------------------------

def node_round_key(base_key: jax.Array, node_index, step) -> jax.Array:
    """Sparsifier seed both endpoints can regenerate: f(base, node, round)."""
    return jax.random.fold_in(jax.random.fold_in(base_key, node_index), step)


def ring_exchange_packed(d_flat: jax.Array, *, axis_name, base_key: jax.Array,
                         step: jax.Array, p: float, neighbor_weight: float,
                         block: int = 1) -> Tuple[jax.Array, jax.Array]:
    """One SDM-DSGD gossip round with packed payloads.

    Each node i:
      1. draws its round-key K_i = f(base, i, step) and a block index set,
      2. packs the selected (k_blocks, block) values scaled by 1/p_eff —
         the ONLY wire payload, ppermuted to both ring neighbours,
      3. regenerates its neighbours' index sets from K_{i-1}, K_{i+1}
         locally and scatters the received values,
      4. returns (own_sparse, weighted_neighbor_sum) where
         own_sparse = S(d_i) densified and weighted_neighbor_sum =
         w * (S(d_{i-1}) + S(d_{i+1})).

    The wire cost per node per round is 2 * k * itemsize bytes instead of
    2 * d * itemsize — exactly the paper's p-fraction, realized in HLO.
    ``block > 1`` transmits contiguous blocks (bucket sparsification; see
    sparsifier.block_sparsify) — required beyond ~2^31-element leaves and
    DMA-friendly on TPU.
    """
    dim = d_flat.shape[0]
    db = sparsifier.block_view(d_flat, block)
    nb_blocks = db.shape[0]
    kb = sparsifier.num_kept(nb_blocks, p)
    scale = nb_blocks / kb
    n = jax.lax.psum(1, axis_name)
    me = jax.lax.axis_index(axis_name)

    my_key = node_round_key(base_key, me, step)
    my_idx = sparsifier.fixedk_indices(my_key, nb_blocks, kb)
    my_vals = jnp.take(db, my_idx, axis=0) * scale   # (kb, block)

    # Wire traffic: only the packed (kb, block) values move.
    vals_from_left = jax.lax.ppermute(my_vals, axis_name, _perm(n, +1))
    vals_from_right = jax.lax.ppermute(my_vals, axis_name, _perm(n, -1))

    # Receivers regenerate sender index sets (no index traffic).
    left_idx = sparsifier.fixedk_indices(
        node_round_key(base_key, (me - 1) % n, step), nb_blocks, kb)
    right_idx = sparsifier.fixedk_indices(
        node_round_key(base_key, (me + 1) % n, step), nb_blocks, kb)

    unpack = lambda vals, idx: jnp.zeros_like(db).at[idx].set(
        vals).reshape(-1)[:dim]
    own_sparse = unpack(my_vals, my_idx)
    nb_sum = unpack(vals_from_left, left_idx) + \
        unpack(vals_from_right, right_idx)
    return own_sparse, neighbor_weight * nb_sum


def ring_exchange_packed_rows(d: jax.Array, *, axis_name, base_key: jax.Array,
                              step: jax.Array, p: float,
                              neighbor_weight: float
                              ) -> Tuple[jax.Array, jax.Array]:
    """Sharding-aligned packed gossip: blocks = trailing-dim rows.

    ``ring_exchange_packed`` flattens the leaf, which destroys the tensor-
    parallel layout of model-sharded dims and makes GSPMD all-gather the
    whole leaf around the gather/scatter (measured: +23% collective bytes
    on qwen1.5-32b train instead of the predicted 10x drop). Here the
    block unit is a whole trailing-dim row: the gather indexes only the
    UNsharded leading dims, each packed row keeps the leaf's model-axis
    sharding, and the ppermute payload is itself tensor-parallel.

    Selection semantics equal ``sparsifier.block_sparsify`` with
    block = leaf.shape[-1] (row-major): inclusion probability k/rows ~= p,
    scale rows/k — unbiasedness intact.
    """
    shape = d.shape
    cols = shape[-1] if d.ndim > 1 else 1
    rows = d.size // cols
    db = d.reshape(rows, cols)
    kb = sparsifier.num_kept(rows, p)
    scale = rows / kb
    n = jax.lax.psum(1, axis_name)
    me = jax.lax.axis_index(axis_name)

    my_idx = sparsifier.fixedk_indices(
        node_round_key(base_key, me, step), rows, kb)
    my_vals = jnp.take(db, my_idx, axis=0) * scale      # (kb, cols)

    vals_from_left = jax.lax.ppermute(my_vals, axis_name, _perm(n, +1))
    vals_from_right = jax.lax.ppermute(my_vals, axis_name, _perm(n, -1))

    left_idx = sparsifier.fixedk_indices(
        node_round_key(base_key, (me - 1) % n, step), rows, kb)
    right_idx = sparsifier.fixedk_indices(
        node_round_key(base_key, (me + 1) % n, step), rows, kb)

    unpack = lambda vals, idx: jnp.zeros_like(db).at[idx].set(
        vals).reshape(shape)
    own_sparse = unpack(my_vals, my_idx)
    nb_sum = unpack(vals_from_left, left_idx) + \
        unpack(vals_from_right, right_idx)
    return own_sparse, neighbor_weight * nb_sum
