"""Executable convergence theory of SDM-DSGD (Lemma 1, Corollary 3, Remark 1).

These calculators back the theory benchmarks: they evaluate the paper's
convergence bound terms for concrete (n, p, theta, gamma, beta,
lambda_n, ...) choices so the experiments can check parameter validity
(theta bound, DC-DSGD p-threshold) and plot predicted-vs-measured error.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = [
    "BoundInputs",
    "theta_upper_bound",
    "default_theta",
    "default_gamma",
    "dcdsgd_min_p",
    "lemma1_terms",
    "lemma1_bound",
    "corollary3_rate",
    "min_iterations_for_rate",
]


@dataclasses.dataclass(frozen=True)
class BoundInputs:
    """Everything Lemma 1 needs.

    Attributes:
      n: number of nodes.  m: local dataset size.  d: parameter dimension.
      p: sparsifier transmit probability.  theta, gamma: step parameters.
      beta: second-largest |eigenvalue| of W.  lambda_n: smallest eigenvalue.
      L: gradient Lipschitz constant.  G: gradient bound (Assumption 1(4)).
      sigma: Gaussian masking std.  sigma_tilde: stochastic-gradient std.
      tau: subsampling rate.  C1: f(0) - f*.
    """

    n: int
    m: int
    d: int
    p: float
    theta: float
    gamma: float
    beta: float
    lambda_n: float
    L: float = 1.0
    G: float = 1.0
    sigma: float = 1.0
    sigma_tilde: float = 1.0
    tau: float = 1.0
    C1: float = 1.0

    @property
    def C2(self) -> float:
        """C2 = n*sigma_tilde^2/(m*tau) + n*d*sigma^2."""
        return self.n * self.sigma_tilde ** 2 / (self.m * self.tau) + \
            self.n * self.d * self.sigma ** 2

    @property
    def C3(self) -> float:
        """C3 = (n G)^2 + (n d sigma)^2."""
        return (self.n * self.G) ** 2 + (self.n * self.d * self.sigma) ** 2


def theta_upper_bound(p: float, lambda_n: float, gamma: float, L: float) -> float:
    """Lemma 1's validity condition: theta < 2p / (1 - lambda_n + gamma L)."""
    return 2.0 * p / (1.0 - lambda_n + gamma * L)


def default_theta(p: float, lambda_n: float, gamma: float, L: float) -> float:
    """Corollary 3 / Theorem 4 choice: theta = min{p/(1-lambda_n+gamma L), p/2}."""
    return min(p / (1.0 - lambda_n + gamma * L), p / 2.0)


def default_gamma(n: int, T: int, c: float = 1.0) -> float:
    """Corollary 3 step size: gamma = c sqrt(n log(T) / T)."""
    if T < 2:
        raise ValueError("T must be >= 2")
    return c * math.sqrt(n * math.log(T) / T)


def dcdsgd_min_p(lambda_n: float) -> float:
    """Remark 1: DC-DSGD (theta = 1) needs
    p > 4(1-lambda_n)^2 / (4(1-lambda_n)^2 + (1-|lambda_n|)^2).

    SDM-DSGD's theta removes this restriction — the generalization claim.
    """
    a = 4.0 * (1.0 - lambda_n) ** 2
    b = (1.0 - abs(lambda_n)) ** 2
    return a / (a + b)


def lemma1_terms(x: BoundInputs, T: int) -> dict:
    """The four error terms (I)-(IV) of Lemma 1 (Eq. 7)."""
    th, g, p, n = x.theta, x.gamma, x.p, x.n
    one_m_beta = 1.0 - x.beta
    lip_v = 1.0 - x.lambda_n + x.gamma * x.L  # Lipschitz const of grad V
    denom = 2.0 * p - lip_v * th
    if denom <= 0:
        raise ValueError(
            f"theta={th} violates Lemma 1 bound {theta_upper_bound(p, x.lambda_n, g, x.L):.4g}")
    term1 = 2.0 * x.C1 / (th * g * T)
    term2 = 2.0 * x.L * x.C3 / x.n * (g / one_m_beta) ** 2
    term3 = (2.0 * th * g ** 2 * x.L * x.C2 / (n * one_m_beta)) * (1.0 / p - 1.0) + \
        x.L * th * g * x.C2 / (n ** 2 * p)
    term4 = (2.0 * g * x.L / (n * one_m_beta) + x.L / n ** 2) * (1.0 / p - 1.0) * (
        2.0 * p * n * x.C1 / (denom * T) + lip_v * th ** 2 * g * x.C2 / denom)
    return {"I": term1, "II": term2, "III": term3, "IV": term4}


def lemma1_bound(x: BoundInputs, T: int) -> float:
    """min_t ||grad f(xbar_t)||^2 <= (I)+(II)+(III)+(IV)."""
    return sum(lemma1_terms(x, T).values())


def corollary3_rate(n: int, T: int) -> float:
    """The headline rate O(sqrt(log(T)/(n T)))."""
    return math.sqrt(math.log(T) / (n * T))


def min_iterations_for_rate(n: int, beta: float) -> float:
    """Corollary 3 requires T > n^5 / (1-beta)^4 for the clean rate."""
    return n ** 5 / (1.0 - beta) ** 4
