"""Baselines the paper compares against (§5): DSGD and DC-DSGD.

* DSGD (Lian et al. 2017; also Nedic-Ozdaglar, Yuan-Ling-Yin):
      x_{i,t+1} = sum_j W_ij x_{j,t} - gamma * g(x_{i,t})
  exchanges the FULL uncompressed state x_i with neighbours every
  iteration — communication cost d elements/node/iter. Because the full
  state crosses the wire, DSGD is EXACT on time-varying (B-connected)
  schedule sequences: each step mixes with W(t) directly.

* DC-DSGD (Tang et al. 2018, "Communication compression for decentralized
  training"): communicates compressed differentials like SDM-DSGD but has
  no mixing parameter theta — it is exactly ``SDMConfig(theta=1.0)``
  (Remark 1 / §5). Remark 1 shows it requires
  p > 4(1-lambda_n)^2/(4(1-lambda_n)^2 + (1-|lambda_n|)^2) to converge;
  Figure 2 demonstrates divergence at p=0.2. In the method registry
  (repro.core.method) DC-DSGD is literally the SDM-DSGD registration
  with theta pinned to 1 — no separate implementation exists.

For the §5 "fair comparison", both baselines can also be run with the
same Gaussian masking noise (``sigma > 0``) and clipping as SDM-DSGD,
through the shared ``sdm_dsgd.masked_grad`` helper (the former
``DSGDConfig.as_sdm`` config-conversion shim is gone).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import gossip
from repro.core import plane as plane_mod
from repro.core.sdm_dsgd import SDMConfig, masked_grad

__all__ = ["DSGDConfig", "DSGDState", "DSGDReference",
           "dcdsgd_config", "dsgd_distributed_step"]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DSGDConfig:
    gamma: float = 0.01
    sigma: float = 0.0
    clip_c: float | None = None


def dcdsgd_config(p: float, gamma: float, sigma: float = 0.0,
                  clip_c: float | None = None) -> SDMConfig:
    """DC-DSGD == SDM-DSGD with theta fixed to 1 (no state mixing)."""
    return SDMConfig(p=p, theta=1.0, gamma=gamma, sigma=sigma, clip_c=clip_c)


class DSGDState(NamedTuple):
    x: PyTree
    step: jax.Array


class DSGDReference:
    """Stacked single-host DSGD, mirroring ReferenceSimulator's API.

    Accepts a Topology, PermuteSchedule, or time-varying
    ScheduleSequence — full-state mixing is exact on every round's W(t).
    """

    def __init__(self, topo, cfg: DSGDConfig):
        self.cfg = cfg
        self.seq = gossip.sequence_of(topo)
        self._wstack = jnp.asarray(self.seq.weights_stack(), jnp.float32)
        self.weights = self._wstack[0]

    def init(self, params_stack: PyTree) -> DSGDState:
        return DSGDState(x=params_stack, step=jnp.zeros((), jnp.int32))

    def step(self, state: DSGDState, grad_fn, batch_stack: PyTree,
             key: jax.Array) -> Tuple[DSGDState, PyTree]:
        grads, aux = grad_fn(state.x, batch_stack)
        g = masked_grad(grads, key, sigma=self.cfg.sigma,
                        clip_c=self.cfg.clip_c)
        w_t = self._wstack[state.step % self.seq.length]
        x = jax.tree.map(
            lambda xs, gs: gossip.mix_dense(w_t, xs)
            - self.cfg.gamma * gs.astype(xs.dtype),
            state.x, g)
        return DSGDState(x=x, step=state.step + 1), aux

    def consensus_mean(self, state: DSGDState) -> PyTree:
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), state.x)

    consensus = consensus_mean

    def eval_params(self, state: DSGDState) -> PyTree:
        return state.x


def dsgd_distributed_step(state: DSGDState, grads: PyTree, *, base_key: jax.Array,
                          axis_name, cfg: DSGDConfig,
                          schedule=None, self_weight: float | None = None,
                          neighbor_weight: float | None = None,
                          node_index=None) -> DSGDState:
    """Per-node DSGD step inside shard_map: FULL-state gossip exchange.

    This is the communication baseline for the roofline comparison:
    collective bytes per round = deg * d * itemsize (vs p * that for
    SDM-DSGD packed mode). ``schedule`` selects the gossip graph — a
    PermuteSchedule or a time-varying ScheduleSequence indexed by the
    state's step counter; legacy scalar (self_weight, neighbor_weight)
    callers get the symmetric ring.
    """
    del neighbor_weight
    seq = gossip.resolve_sequence(schedule, axis_name, self_weight)
    me = gossip._me(axis_name, node_index)
    sw = seq.self_weight_of(me, state.step)
    noise_key = jax.random.fold_in(
        gossip.node_round_key(base_key, me, state.step), 0x5eed)
    g = masked_grad(grads, noise_key, sigma=cfg.sigma, clip_c=cfg.clip_c)

    # Full-state gossip over the WIRE PLANE (repro.core.plane): the whole
    # tree crosses as one contiguous buffer per bucket, so the compiled
    # step issues R collective-permutes per exchange regardless of the
    # model's leaf count.
    spec = plane_mod.ParamPlane.for_tree(state.x)
    mixed_tree = spec.unpack(tuple(
        sw * p + gossip.exchange(seq, p, axis_name,
                                 node_index=node_index, step=state.step)
        for p in spec.pack(state.x)))
    x = jax.tree.map(lambda m, gr: m - cfg.gamma * gr.astype(m.dtype),
                     mixed_tree, g)
    return DSGDState(x=x, step=state.step + 1)
