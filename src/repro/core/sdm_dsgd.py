"""SDM-DSGD (Algorithm 1) — reference simulator and distributed TPU step.

The algorithm, per node i, per iteration t (paper Eq. (3)):

    x_t = x_{t-1} + S(d_{t-1})                 # everyone advances public copies
    y_t = (1-theta) x_t
          + theta * (W~ x_t - gamma (grad f(x_t; batch) + eta)),  eta~N(0, sigma^2 I)
    d_t = y_t - x_t

Each node transmits only S(d_i); neighbours maintain exact replicas of
the *public* copies x_j (they advance them with the received S(d_j)),
so the distributed state per node is:

    x — the node's own public copy (identical to what neighbours hold),
    s — the running weighted neighbour sum  sum_{j in N_i} W_ij x_j,
    d — the differential awaiting transmission next round.

Two implementations, bit-for-bit testable against each other:

* ``ReferenceSimulator`` — all n nodes stacked on a leading axis on one
  host, gossip by dense einsum with any Topology (used for the paper's
  CPU-scale experiments: MNIST/CIFAR-style models, ER graphs).
* ``distributed_advance`` / ``distributed_commit`` — per-node code to run
  inside `jax.shard_map` with the node axis manual; ring gossip via
  `collective-permute`, optionally packed fixed-k payloads.

Baselines (DSGD, DC-DSGD) live in ``baselines.py``; DC-DSGD is exactly
``SDMConfig(theta=1.0, sigma=0.0)`` — the generalization claim.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import clipping, gossip, sparsifier
from repro.core.topology import Topology

__all__ = ["SDMConfig", "SDMState", "ReferenceSimulator",
           "init_distributed_state", "distributed_advance",
           "distributed_commit", "transmitted_elements_per_step"]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SDMConfig:
    """Hyper-parameters of Algorithm 1.

    mode:
      'bernoulli'     — paper-faithful i.i.d. Bernoulli(p) masking, dense payloads.
      'fixedk_packed' — seed-synchronized fixed-k packed payloads over flat
                        pack_block-coordinate blocks (TPU adaptation).
      'fixedk_rows'   — packed payloads over trailing-dim rows: keeps the
                        tensor-parallel sharding of every leaf intact
                        (the production choice; see EXPERIMENTS.md §Perf).
    """

    p: float = 0.2
    theta: float = 0.6
    gamma: float = 0.01
    sigma: float = 0.0
    clip_c: float | None = None
    mode: str = "bernoulli"
    pack_block: int = 1   # fixedk granularity (coords per transmitted block)
    # BEYOND-PAPER extension (off by default = paper-faithful): carry the
    # unsent compression residual e = d - S(d) into the next round's
    # differential (error feedback a la Stich et al. [20], which the paper
    # cites but does not use). FINDING (tests/test_error_feedback.py): EF
    # requires a contractive compressor, and p-scaling the differential
    # slows the CONSENSUS correction inside d until disagreement outruns
    # it — long-horizon drift. Structural evidence for the paper's
    # unbiasedness requirement; keep off for real training.
    error_feedback: bool = False

    def __post_init__(self) -> None:
        if not (0.0 < self.p <= 1.0):
            raise ValueError("p in (0,1]")
        if not (0.0 < self.theta <= 1.0):
            raise ValueError("theta in (0,1]")
        if self.mode not in ("bernoulli", "fixedk_packed", "fixedk_rows"):
            raise ValueError(f"unknown mode {self.mode}")

    def validate_against(self, topo: Topology, L: float = 1.0) -> None:
        """Assert Lemma 1's theta < 2p/(1 - lambda_n + gamma L)."""
        bound = 2.0 * self.p / (1.0 - topo.lambda_n + self.gamma * L)
        if self.theta >= bound:
            raise ValueError(
                f"theta={self.theta} >= Lemma-1 bound {bound:.4g} "
                f"(p={self.p}, lambda_n={topo.lambda_n:.4g})")


class SDMState(NamedTuple):
    x: PyTree       # public copy (stacked (n, ...) in reference; per-node distributed)
    s: PyTree       # weighted neighbour sum (distributed only; zeros-like in reference)
    d: PyTree       # differential pending transmission
    step: jax.Array  # iteration counter (int32)
    e: PyTree = None  # error-feedback residual (only when cfg.error_feedback)


def _tree_zeros_like(t: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, t)


def _leaf_keys(key: jax.Array, tree: PyTree) -> PyTree:
    """One independent key per leaf, stable in tree-flatten order."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = [jax.random.fold_in(key, i) for i in range(len(leaves))]
    return jax.tree.unflatten(treedef, keys)


def _noise_like(key: jax.Array, tree: PyTree, sigma: float) -> PyTree:
    ks = _leaf_keys(key, tree)
    return jax.tree.map(
        lambda k, x: sigma * jax.random.normal(k, x.shape, jnp.float32).astype(x.dtype),
        ks, tree)


def _masked_grad(grads: PyTree, key: jax.Array, cfg: SDMConfig) -> PyTree:
    """clip (optional, §5 procedure) then Gaussian-mask: g_hat = clip(g) + eta."""
    if cfg.clip_c is not None:
        grads = clipping.clip_tree(grads, cfg.clip_c)
    if cfg.sigma > 0.0:
        noise = _noise_like(key, grads, cfg.sigma)
        grads = jax.tree.map(jnp.add, grads, noise)
    return grads


def transmitted_elements_per_step(params: PyTree, cfg: SDMConfig) -> int:
    """Expected non-zero elements each node transmits per iteration.

    The paper's Figure-3 communication metric ("non-zero digits"). For
    fixedk mode this is exact; for bernoulli it is the expectation p*d.
    """
    d = sum(int(x.size) for x in jax.tree.leaves(params))
    if cfg.mode == "fixedk_packed":
        b = cfg.pack_block
        # kb * b can exceed the leaf size when block_view pads the last
        # block; pad coordinates are never real payload, so clamp.
        return sum(
            min(sparsifier.num_kept(-(-int(x.size) // b), cfg.p) * b,
                int(x.size))
            for x in jax.tree.leaves(params))
    if cfg.mode == "fixedk_rows":
        total = 0
        for x in jax.tree.leaves(params):
            cols = x.shape[-1] if x.ndim > 1 else 1
            rows = int(x.size) // cols
            total += sparsifier.num_kept(rows, cfg.p) * cols
        return total
    return int(round(cfg.p * d))


# ==========================================================================
# Reference simulator: n nodes stacked on axis 0, dense-W gossip.
# ==========================================================================

class ReferenceSimulator:
    """Single-host n-node simulator for any Topology (paper's experiments)."""

    def __init__(self, topo: Topology, cfg: SDMConfig):
        self.topo = topo
        self.cfg = cfg
        self.weights = jnp.asarray(topo.weights, jnp.float32)

    def init(self, params_stack: PyTree) -> SDMState:
        """params_stack leaves have leading dim n (one slice per node)."""
        n = jax.tree.leaves(params_stack)[0].shape[0]
        assert n == self.topo.n_nodes, (n, self.topo.n_nodes)
        e = _tree_zeros_like(params_stack) if self.cfg.error_feedback else None
        return SDMState(x=params_stack, s=_tree_zeros_like(params_stack),
                        d=_tree_zeros_like(params_stack),
                        step=jnp.zeros((), jnp.int32), e=e)

    # -- phase 1: everyone transmits S(d) and advances public copies ------
    def advance(self, state: SDMState, key: jax.Array) -> Tuple[SDMState, PyTree]:
        """Returns (state with x <- x + S(d), the S(d) stack)."""
        cfg = self.cfg
        n = self.topo.n_nodes

        if cfg.error_feedback:
            # fold the residual from the previous round into what we send.
            # EF requires the CONTRACTIVE (unscaled) compressor mask*d —
            # the unbiased 1/p amplification would make the residual loop
            # explosive; error feedback is what repairs the bias instead
            # (Stich et al.). Implemented by undoing the 1/p scale below.
            d_in = jax.tree.map(jnp.add, state.d, state.e)
        else:
            d_in = state.d
        ef_scale = cfg.p if cfg.error_feedback else 1.0

        def sparsify_stack(leaf_key: jax.Array, d_stack: jax.Array) -> jax.Array:
            node_keys = jax.vmap(
                lambda i: gossip.node_round_key(leaf_key, i, state.step))(jnp.arange(n))
            if cfg.mode == "bernoulli":
                fn = lambda k, v: sparsifier.bernoulli_sparsify(k, v, cfg.p)
            elif cfg.mode == "fixedk_rows":
                fn = lambda k, v: sparsifier.block_sparsify(
                    k, v.reshape(-1), cfg.p,
                    v.shape[-1] if v.ndim > 1 else 1).reshape(v.shape)
            else:
                fn = lambda k, v: sparsifier.block_sparsify(
                    k, v.reshape(-1), cfg.p, cfg.pack_block).reshape(v.shape)
            return jax.vmap(fn)(node_keys, d_stack)

        sd = jax.tree.map(sparsify_stack, _leaf_keys(key, d_in), d_in)
        if cfg.error_feedback and ef_scale != 1.0:
            sd = jax.tree.map(lambda v: v * ef_scale, sd)
        x = jax.tree.map(jnp.add, state.x, sd)
        new_e = jax.tree.map(jnp.subtract, d_in, sd) \
            if cfg.error_feedback else state.e
        return state._replace(x=x, e=new_e), sd

    # -- phase 2: local gradient + masking + generalized mixing -----------
    def commit(self, state: SDMState, grads_stack: PyTree,
               key: jax.Array) -> SDMState:
        cfg = self.cfg
        g = _masked_grad(grads_stack, key, cfg)
        mixed = jax.tree.map(lambda x: gossip.mix_dense(self.weights, x), state.x)
        y = jax.tree.map(
            lambda x, m, gr: (1.0 - cfg.theta) * x + cfg.theta * (m - cfg.gamma * gr),
            state.x, mixed, g)
        d = jax.tree.map(jnp.subtract, y, state.x)
        return state._replace(d=d, step=state.step + 1)

    def step(self, state: SDMState, grad_fn, batch_stack: PyTree,
             key: jax.Array) -> Tuple[SDMState, PyTree]:
        """Convenience: advance -> grads at new x -> commit.

        grad_fn(params_stack, batch_stack) -> grads_stack, aux.
        Returns (new_state, aux).
        """
        k_sp, k_noise = jax.random.split(key)
        state, _ = self.advance(state, k_sp)
        grads, aux = grad_fn(state.x, batch_stack)
        state = self.commit(state, grads, k_noise)
        return state, aux

    def consensus_mean(self, state: SDMState) -> PyTree:
        """xbar_t = (1/n) sum_i x_{i,t} — the quantity Lemma 1 bounds."""
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), state.x)


# ==========================================================================
# Distributed per-node step (inside shard_map; node axis manual).
# ==========================================================================

def init_distributed_state(params: PyTree, self_weight) -> SDMState:
    """Per-node state. ``params`` has NO node axis here (each shard owns one).

    All nodes must start from IDENTICAL params (standard same-seed init);
    then the initial neighbour sum is s_0 = (1 - W_ii) * x_0, since
    sum_{j != i} W_ij = 1 - W_ii and x_{j,0} = x_0. (The paper starts at
    x_0 = 0, a special case.) ``self_weight`` may be a python float or a
    traced scalar (``schedule.self_weight_of(me)`` inside shard_map, for
    topologies whose W_ii varies per node).
    """
    s0 = jax.tree.map(lambda x: ((1.0 - self_weight) * x).astype(x.dtype),
                      params)
    return SDMState(x=params, s=s0, d=_tree_zeros_like(params),
                    step=jnp.zeros((), jnp.int32))


def _sparse_exchange_leaves(d_tree: PyTree, *, schedule, axis_name,
                            base_key: jax.Array, step: jax.Array,
                            cfg: SDMConfig,
                            node_index=None) -> Tuple[PyTree, PyTree]:
    """Packed per-leaf exchange on a schedule: (own S(d), weighted nb sum)."""
    d_leaves, treedef = jax.tree.flatten(d_tree)
    own, nb = [], []
    for i, d in enumerate(d_leaves):
        leaf_key = jax.random.fold_in(base_key, i)
        if cfg.mode == "fixedk_rows":
            own_sparse, nb_sum = gossip.exchange_packed_rows(
                schedule, d, axis_name=axis_name, base_key=leaf_key,
                step=step, p=cfg.p, node_index=node_index)
        else:
            own_sparse, nb_sum = gossip.exchange_packed(
                schedule, d.reshape(-1), axis_name=axis_name,
                base_key=leaf_key, step=step, p=cfg.p, block=cfg.pack_block,
                node_index=node_index)
        own.append(own_sparse.reshape(d.shape).astype(d.dtype))
        nb.append(nb_sum.reshape(d.shape).astype(d.dtype))
    return jax.tree.unflatten(treedef, own), jax.tree.unflatten(treedef, nb)


def distributed_advance(state: SDMState, *, base_key: jax.Array, axis_name,
                        cfg: SDMConfig,
                        schedule: gossip.PermuteSchedule | None = None,
                        self_weight: float | None = None,
                        neighbor_weight: float | None = None,
                        node_index=None) -> SDMState:
    """Phase 1 on the mesh: sparsify d, schedule-exchange, update x and s.

    ``schedule`` selects the gossip graph; legacy scalar
    (self_weight, neighbor_weight) callers get the symmetric ring.
    ``node_index`` (optional sharded operand) replaces the axis_index
    collective where partial-auto shard_map cannot lower it.
    """
    del neighbor_weight  # ring default is fully described by self_weight
    schedule = gossip.resolve_schedule(schedule, axis_name, self_weight)
    me = gossip._me(axis_name, node_index)

    if cfg.mode in ("fixedk_packed", "fixedk_rows"):
        own, nb = _sparse_exchange_leaves(
            state.d, schedule=schedule, axis_name=axis_name,
            base_key=base_key, step=state.step, cfg=cfg,
            node_index=node_index)
        x = jax.tree.map(jnp.add, state.x, own)
        s = jax.tree.map(jnp.add, state.s, nb)
    else:
        # Key schedule fold(fold(fold(base, leaf), node), step) — identical
        # to ReferenceSimulator.advance so the two paths are bit-equal.
        leaf_keys = jax.tree.map(
            lambda k: gossip.node_round_key(k, me, state.step),
            _leaf_keys(base_key, state.d))
        sd = jax.tree.map(
            lambda k, d: sparsifier.bernoulli_sparsify(k, d, cfg.p),
            leaf_keys, state.d)
        x = jax.tree.map(jnp.add, state.x, sd)
        s = jax.tree.map(
            lambda s_, v: s_ + gossip.exchange(schedule, v, axis_name,
                                               node_index=node_index),
            state.s, sd)
    return state._replace(x=x, s=s)


class SDMFusedState(NamedTuple):
    """Two-buffer state for the fused step (see distributed_step_fused)."""
    x: PyTree
    s: PyTree
    step: jax.Array


def init_fused_state(params: PyTree, self_weight) -> SDMFusedState:
    s0 = jax.tree.map(lambda x: ((1.0 - self_weight) * x).astype(x.dtype),
                      params)
    return SDMFusedState(x=params, s=s0, step=jnp.zeros((), jnp.int32))


def distributed_step_fused(state: SDMFusedState, grads: PyTree, *,
                           base_key: jax.Array, axis_name, cfg: SDMConfig,
                           schedule: gossip.PermuteSchedule | None = None,
                           self_weight: float | None = None,
                           neighbor_weight: float | None = None,
                           node_index=None) -> SDMFusedState:
    """Memory-optimized whole-iteration step: commit_t + advance_{t+1} fused.

    Identical algorithm to (distributed_advance; grads; distributed_commit)
    with the step boundary shifted by half an iteration: the differential
    d_t only lives INSIDE the step (computed from this step's gradient,
    sparsified, exchanged, and folded into (x, s) immediately), so the
    persistent state drops from 3 parameter buffers (x, s, d) to 2 —
    a 1/3 cut of the dominant memory term. Gradient must be evaluated at
    state.x BEFORE calling (x is already post-advance).
    """
    del neighbor_weight
    schedule = gossip.resolve_schedule(schedule, axis_name, self_weight)
    me = gossip._me(axis_name, node_index)
    sw = schedule.self_weight_of(me)
    noise_key = jax.random.fold_in(
        gossip.node_round_key(base_key, me, state.step), 0x5eed)
    g = _masked_grad(grads, noise_key, cfg)
    d = jax.tree.map(
        lambda x, s, gr: (cfg.theta * (sw.astype(x.dtype) * x + s
                                       - cfg.gamma * gr.astype(x.dtype))
                          - cfg.theta * x),
        state.x, state.s, g)

    # immediately sparsify + exchange + fold in (the next round's advance).
    # Sparsifier keys use counter step+1: in the unfused flow d_t is
    # sparsified by the NEXT iteration's advance (bit-equality preserved).
    sp_step = state.step + 1
    if cfg.mode in ("fixedk_packed", "fixedk_rows"):
        own, nb = _sparse_exchange_leaves(
            d, schedule=schedule, axis_name=axis_name,
            base_key=base_key, step=sp_step, cfg=cfg,
            node_index=node_index)
        x = jax.tree.map(jnp.add, state.x, own)
        s = jax.tree.map(jnp.add, state.s, nb)
    else:
        leaf_keys = jax.tree.map(
            lambda k: gossip.node_round_key(k, me, sp_step),
            _leaf_keys(base_key, d))
        sd = jax.tree.map(
            lambda k, dd: sparsifier.bernoulli_sparsify(k, dd, cfg.p),
            leaf_keys, d)
        x = jax.tree.map(jnp.add, state.x, sd)
        s = jax.tree.map(
            lambda s_, v: s_ + gossip.exchange(schedule, v, axis_name,
                                               node_index=node_index),
            state.s, sd)
    return SDMFusedState(x=x, s=s, step=state.step + 1)


def distributed_commit(state: SDMState, grads: PyTree, *, base_key: jax.Array,
                       axis_name, cfg: SDMConfig,
                       schedule: gossip.PermuteSchedule | None = None,
                       self_weight: float | None = None,
                       node_index=None) -> SDMState:
    """Phase 2 on the mesh: masked gradient + generalized mixing update."""
    schedule = gossip.resolve_schedule(schedule, axis_name, self_weight)
    me = gossip._me(axis_name, node_index)
    sw = schedule.self_weight_of(me)
    noise_key = jax.random.fold_in(
        gossip.node_round_key(base_key, me, state.step), 0x5eed)
    g = _masked_grad(grads, noise_key, cfg)
    # W~ x for node i = W_ii x_i + s_i  (s maintained incrementally).
    y = jax.tree.map(
        lambda x, s, gr: ((1.0 - cfg.theta) * x
                          + cfg.theta * (sw.astype(x.dtype) * x + s
                                         - cfg.gamma * gr.astype(x.dtype))),
        state.x, state.s, g)
    d = jax.tree.map(jnp.subtract, y, state.x)
    return state._replace(d=d, step=state.step + 1)
