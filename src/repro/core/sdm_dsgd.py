"""SDM-DSGD (Algorithm 1) — reference simulator and distributed TPU step.

The algorithm, per node i, per iteration t (paper Eq. (3)):

    x_t = x_{t-1} + S(d_{t-1})                 # everyone advances public copies
    y_t = (1-theta) x_t
          + theta * (W~ x_t - gamma (grad f(x_t; batch) + eta)),  eta~N(0, sigma^2 I)
    d_t = y_t - x_t

Each node transmits only S(d_i); neighbours maintain exact replicas of
the *public* copies x_j (they advance them with the received S(d_j)),
so the distributed state per node is:

    x — the node's own public copy (identical to what neighbours hold),
    s — the running weighted neighbour sum  sum_{j in N_i} W_ij x_j,
    d — the differential awaiting transmission next round.

On a STATIC graph the replicas never need to be materialized: with
time-invariant weights the weighted sum folds incrementally
(s += sum_j W_ij S(d_j)), which is what the two/three-buffer state
above exploits. On a genuinely time-varying schedule sequence the
increments must instead land in EXPLICIT per-neighbour replicas
(``SDMState.xhat``, one slot per union-graph round, fed over every
union edge every round so receivers see every increment) and s is
recomputed fresh with the CURRENT round's W(t) — exact W(t)-mixing on
B-connected sequences, at deg_union x model extra state per node.

Two implementations, bit-for-bit testable against each other:

* ``ReferenceSimulator`` — all n nodes stacked on a leading axis on one
  host, gossip by dense einsum with any Topology (used for the paper's
  CPU-scale experiments: MNIST/CIFAR-style models, ER graphs).
* ``distributed_advance`` / ``distributed_commit`` — per-node code to run
  inside `jax.shard_map` with the node axis manual; ring gossip via
  `collective-permute`, optionally packed fixed-k payloads.

Wire-plane transport (PR 5): the whole differential is bucketized into
contiguous ``repro.core.plane`` wire planes and the compressor draw /
top-k / ppermute rounds run ONCE PER PLANE instead of once per pytree
leaf — a compiled distributed step issues exactly R collective-permutes
per exchange regardless of the model's leaf count, and the distributed
state carries ``s`` / ``d`` (and the replica stack ``xhat``) as
plane-shaped f32 buffers. Both executors draw sparsifier/quantizer bits
at PLANE granularity (one draw over the zero-padded (rows, LANE) buffer
per bucket, keyed ``fold_in(base, bucket)``), so trajectories CHANGED at
this PR relative to the per-leaf draws — exactly like the PR-1 break
when mask draws moved to the canonical LANE-padded shape. Reference and
distributed were rewired together, so the parity sweep stays tight.

Baselines (DSGD, DC-DSGD) live in ``baselines.py``; DC-DSGD is exactly
``SDMConfig(theta=1.0, sigma=0.0)`` — the generalization claim.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import clipping, compressor as compressor_mod, gossip
from repro.core import plane as plane_mod, tagging
from repro.core.topology import Topology

__all__ = ["SDMConfig", "SDMState", "ReferenceSimulator", "masked_grad",
           "init_distributed_state", "distributed_advance",
           "distributed_commit", "compressor_of", "wire_shape_tree",
           "sparsify_planes_stacked",
           "transmitted_elements_per_step", "transmitted_bits_per_step"]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SDMConfig:
    """Hyper-parameters of Algorithm 1.

    ``compressor``, when set, is a ``repro.core.compressor`` spec that
    SELECTS the wire format by name (the preferred axis; ``mode`` is
    derived from it): 'bernoulli' | 'fixedk[:block]' | 'block:<B>' |
    'rows' | 'qsgd[:bits]'. ``compressor_of(cfg)`` resolves either
    spelling to the Compressor object that owns sensitivity and
    wire-cost accounting.

    mode (legacy spelling, still accepted):
      'bernoulli'     — paper-faithful i.i.d. Bernoulli(p) masking, dense payloads.
      'fixedk_packed' — seed-synchronized fixed-k packed payloads over flat
                        pack_block-coordinate blocks (TPU adaptation).
      'fixedk_rows'   — packed payloads over trailing-dim rows: keeps the
                        tensor-parallel sharding of every leaf intact
                        (the production choice; see EXPERIMENTS.md §Perf).
      'qsgd'          — QSGD stochastic quantization of the differential
                        (qsgd_bits levels; int8 wire payload via the
                        generic gossip.exchange_payload transport).

    ``p`` may be a per-node tuple (heterogeneous sparsity budgets, e.g.
    degree-weighted): node i then transmits with probability p[i].
    Supported in 'bernoulli' and 'fixedk_packed' modes — fixed-k wire
    payloads pad to the max-k across nodes (zero rows beyond a node's
    own k), so one static ppermute shape serves every budget. The
    privacy accountant uses the worst-case (max-p) node; Lemma-1's theta
    bound the most restrictive (min-p).
    """

    p: "float | Tuple[float, ...]" = 0.2
    theta: float = 0.6
    gamma: float = 0.01
    sigma: float = 0.0
    clip_c: float | None = None
    mode: str = "bernoulli"
    pack_block: int = 1   # fixedk granularity (coords per transmitted block)
    compressor: str | None = None   # compressor spec; overrides mode
    qsgd_bits: int = 8    # quantizer levels (mode='qsgd')
    # BEYOND-PAPER extension (off by default = paper-faithful): carry the
    # unsent compression residual e = d - S(d) into the next round's
    # differential (error feedback a la Stich et al. [20], which the paper
    # cites but does not use). FINDING (tests/test_error_feedback.py): EF
    # requires a contractive compressor, and p-scaling the differential
    # slows the CONSENSUS correction inside d until disagreement outruns
    # it — long-horizon drift. Structural evidence for the paper's
    # unbiasedness requirement; keep off for real training.
    error_feedback: bool = False
    # Overlapped transport (one-step-stale gossip): the exchange issued
    # at step t is NOT waited on inside step t — its weighted neighbour
    # increments land in a pending double buffer (``SDMState.nb``) and
    # are folded into s at step t+1, so the collective-permute can fly
    # under the whole gradient computation instead of serializing with
    # the mixing update. Because d_0 = 0 (S(0) = 0, the same invariant
    # PR 7's withhold/defer staleness machinery relies on), neighbours
    # always mix a one-step-stale but EXACT public copy — a principled,
    # deterministic trajectory change, not a race. overlap=False is
    # byte-identical to the historical step. Static (non-replica)
    # schedules only.
    overlap: bool = False

    def __post_init__(self) -> None:
        if self.compressor is not None:
            # single source of truth: parse through the registry factories
            # and read (mode, pack_block, qsgd_bits) off the object, so
            # per-family defaults cannot drift from compressor.make.
            comp = compressor_mod.make(self.compressor, p=self.p)
            if isinstance(comp, compressor_mod.FusedQSGDCompressor):
                # MUST precede the QSGDCompressor check (it is a
                # subclass): the fused single-buffer format rides the
                # generic payload transport, and mapping it to
                # mode="qsgd" would make compressor_of rebuild a plain
                # QSGDCompressor — silently dropping the fused wire.
                object.__setattr__(self, "mode", "payload")
                object.__setattr__(self, "qsgd_bits", comp.bits)
            elif isinstance(comp, compressor_mod.QSGDCompressor):
                object.__setattr__(self, "mode", "qsgd")
                object.__setattr__(self, "qsgd_bits", comp.bits)
            elif isinstance(comp, compressor_mod.RowsCompressor):
                object.__setattr__(self, "mode", "fixedk_rows")
            elif isinstance(comp, compressor_mod.FixedKCompressor):
                object.__setattr__(self, "mode", "fixedk_packed")
                object.__setattr__(self, "pack_block", comp.block)
            elif isinstance(comp, compressor_mod.BernoulliCompressor):
                object.__setattr__(self, "mode", "bernoulli")
            else:
                # any other registered family rides the generic
                # exchange_payload transport — "adding a compressor"
                # needs no SDM-side mapping.
                object.__setattr__(self, "mode", "payload")
        if self.error_feedback and self.mode in ("qsgd", "payload"):
            # EF undoes the sparsifiers' 1/p amplification by scaling the
            # transmitted update by p; quantizers/generic payloads have
            # no such factor, so the scale would silently discard (1-p)
            # of every update.
            raise ValueError("error_feedback is a sparsifier-path "
                             f"extension; unsupported with mode={self.mode!r}")
        if isinstance(self.p, (list, tuple)):
            object.__setattr__(self, "p", tuple(float(v) for v in self.p))
            if not self.p:
                raise ValueError("per-node p must be non-empty")
            if any(not (0.0 < v <= 1.0) for v in self.p):
                raise ValueError("every per-node p must be in (0,1]")
            if self.mode not in ("bernoulli", "fixedk_packed"):
                raise ValueError(
                    "heterogeneous per-node p needs mode='bernoulli' or "
                    "'fixedk_packed' (pad-to-max-k payloads); "
                    f"got mode={self.mode!r}")
            if self.error_feedback:
                raise ValueError(
                    "error_feedback with per-node p is unsupported")
        elif not (0.0 < self.p <= 1.0):
            raise ValueError("p in (0,1]")
        if not (0.0 < self.theta <= 1.0):
            raise ValueError("theta in (0,1]")
        if self.mode not in ("bernoulli", "fixedk_packed", "fixedk_rows",
                             "qsgd", "payload"):
            raise ValueError(f"unknown mode {self.mode}")
        if self.mode == "payload" and not self.compressor:
            raise ValueError("mode='payload' needs a compressor spec")

    @property
    def p_min(self) -> float:
        """Most restrictive (sparsest) node's p — drives Lemma-1 bounds."""
        return min(self.p) if isinstance(self.p, tuple) else self.p

    @property
    def p_max(self) -> float:
        """Worst-case (densest) node's p — drives the privacy accountant."""
        return max(self.p) if isinstance(self.p, tuple) else self.p

    def p_of(self, node):
        """Node's transmit probability: the scalar, or p[node] (traceable)."""
        if isinstance(self.p, tuple):
            return jnp.asarray(self.p, jnp.float32)[node]
        return self.p

    def validate_against(self, topo: Topology, L: float = 1.0) -> None:
        """Assert Lemma 1's theta < 2p/(1 - lambda_n + gamma L).

        With per-node p the bound must hold for every node, i.e. for
        min(p).
        """
        bound = 2.0 * self.p_min / (1.0 - topo.lambda_n + self.gamma * L)
        if self.theta >= bound:
            raise ValueError(
                f"theta={self.theta} >= Lemma-1 bound {bound:.4g} "
                f"(p={self.p}, lambda_n={topo.lambda_n:.4g})")


class SDMState(NamedTuple):
    x: PyTree       # public copy (stacked (n, ...) in reference; per-node distributed)
    s: PyTree       # weighted neighbour sum. In the DISTRIBUTED executor
    #                 this is a tuple of f32 wire planes (one (rows, LANE)
    #                 buffer per sharding bucket — see repro.core.plane);
    #                 the reference keeps the stacked tree.
    d: PyTree       # differential pending transmission (planes distributed)
    step: jax.Array  # iteration counter (int32)
    e: PyTree = None  # error-feedback residual (only when cfg.error_feedback)
    # Per-neighbour public-copy replicas (distributed executor, genuinely
    # time-varying schedules only): each PLANE gains a leading
    # (n_replicas,) axis — slot k tracks the union-round-k sender's
    # public copy x_j exactly, so s is recomputed FRESH with the current
    # round's weights (true W(t)-mixing). Memory cost: deg_union x model.
    xhat: PyTree = None
    # Overlapped transport double buffer (cfg.overlap only): the weighted
    # neighbour increments received by the exchange issued THIS step,
    # pending until the NEXT step folds them into s (one-step-stale
    # gossip). Planes in the distributed executor; stacked tree in the
    # reference.
    nb: PyTree = None


def _tree_zeros_like(t: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, t)


def _leaf_keys(key: jax.Array, tree: PyTree) -> PyTree:
    """One independent key per leaf, stable in tree-flatten order."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = [jax.random.fold_in(key, i) for i in range(len(leaves))]
    return jax.tree.unflatten(treedef, keys)


def _noise_like(key: jax.Array, tree: PyTree, sigma: float) -> PyTree:
    ks = _leaf_keys(key, tree)
    return jax.tree.map(
        lambda k, x: sigma * jax.random.normal(k, x.shape, jnp.float32).astype(x.dtype),
        ks, tree)


def check_per_node_p(cfg, n_nodes: int) -> None:
    """Reject a per-node p tuple whose length mismatches the graph.

    Must be called wherever a config first meets a schedule: a too-short
    tuple would otherwise CLAMP on the distributed gather (every extra
    node silently reusing the last p — the wrong sparsity AND privacy
    budget) while the stacked reference vmap would crash, so the two
    executors would not even agree the config is valid.
    """
    if isinstance(getattr(cfg, "p", None), tuple) and len(cfg.p) != n_nodes:
        raise ValueError(
            f"per-node p has {len(cfg.p)} entries for {n_nodes} nodes")


def compressor_of(cfg) -> compressor_mod.Compressor:
    """The Compressor object a config's wire format resolves to.

    Whether the config was built with ``compressor='...'`` or the legacy
    ``mode=`` spelling, this is the single point where sdm_dsgd selects
    a compressor BY NAME from the registry — sensitivity
    (``release_probability``) and wire-cost (``wire_elements`` /
    ``wire_bits``) accounting live on the returned object.
    """
    if cfg.mode == "bernoulli":
        return compressor_mod.BernoulliCompressor(p=cfg.p)
    if cfg.mode == "fixedk_packed":
        return compressor_mod.FixedKCompressor(p=cfg.p, block=cfg.pack_block)
    if cfg.mode == "fixedk_rows":
        return compressor_mod.RowsCompressor(p=cfg.p)
    if cfg.mode == "qsgd":
        return compressor_mod.QSGDCompressor(bits=cfg.qsgd_bits)
    if cfg.mode == "payload":   # any registered family, generic transport
        return compressor_mod.make(cfg.compressor, p=cfg.p)
    raise ValueError(f"unknown mode {cfg.mode}")


def masked_grad(grads: PyTree, key: jax.Array, *, sigma: float,
                clip_c: float | None) -> PyTree:
    """clip (optional, §5 procedure) then Gaussian-mask: g_hat = clip(g) + eta.

    The single noise/clipping implementation every method (SDM-DSGD,
    DSGD, DC-DSGD, gradient-push) shares — baselines used to rebuild an
    SDMConfig just to reach this (``DSGDConfig.as_sdm``, now gone).
    """
    if clip_c is not None:
        grads = clipping.clip_tree(grads, clip_c)
    if sigma > 0.0:
        noise = _noise_like(key, grads, sigma)
        grads = jax.tree.map(jnp.add, grads, noise)
        # the analyzer-visible sanitizer mark: ONLY the clipped+noised
        # gradient counts as DP-sanitized (sigma == 0 stays tainted).
        grads = tagging.sanitize(grads)
    return grads


def _masked_grad(grads: PyTree, key: jax.Array, cfg) -> PyTree:
    return masked_grad(grads, key, sigma=cfg.sigma, clip_c=cfg.clip_c)


def sparsify_planes_stacked(comp: compressor_mod.Compressor,
                            tree_stacked: PyTree, key: jax.Array, step,
                            n: int, transform=None) -> PyTree:
    """Plane-granular compressor roundtrip of a node-stacked tree.

    The ONE reference-executor implementation of "what each node puts on
    the wire": each bucket's zero-padded plane is compressed whole with
    key ``node_round_key(fold_in(key, bucket), node, step)`` — the exact
    key schedule and draw shape of the distributed plane transport.
    ``transform(payload, node)`` optionally rewrites the payload before
    the roundtrip (compressed push-sum's contraction scaling).
    """
    spec = plane_mod.ParamPlane.for_stacked(tree_stacked)
    planes = spec.pack_stacked(tree_stacked)
    out = []
    for b, dpl in enumerate(planes):
        bkey = jax.random.fold_in(key, b)
        node_keys = jax.vmap(
            lambda i: gossip.node_round_key(bkey, i, step))(jnp.arange(n))

        def one(i, k, v):
            pl = comp.compress(k, v, node=i)
            if transform is not None:
                pl = transform(pl, i)
            return comp.decompress(pl)

        out.append(jax.vmap(one)(jnp.arange(n), node_keys, dpl))
    return spec.unpack_stacked(tuple(out))


def schedule_degree_factor(seq, node: "int | None" = None) -> Fraction:
    """Payload transmissions per node per step on ``seq`` (exact Fraction).

    The per-link wire-accounting factor for the SDM transport: the mean
    (over the L rounds of the sequence) out-degree — 2 on the static
    symmetric ring, 1 on perfect-matching rounds; ``node=i`` uses node
    i's OWN out-degree where it differs (star hubs). Genuinely
    time-varying sequences run the replica transport (payloads cross
    every UNION edge every round), so their factor is the union-graph
    degree. ``seq=None`` callers keep the schedule-free legacy
    convention: one payload per step (factor 1).
    """
    if seq is None:
        return Fraction(1)
    seq = gossip.sequence_of(seq)
    return gossip.mean_out_degree(seq, union=gossip.needs_replicas(seq),
                                  node=node)


def wire_shape_tree(params: PyTree) -> Tuple[jax.ShapeDtypeStruct, ...]:
    """The plane-shaped tree the wire accounting runs over.

    The transport compresses the zero-padded (rows, LANE) planes, not
    the raw leaves, so cost accounting charges the PLANE geometry: one
    ``num_kept`` ceil over the whole plane per bucket (the round-once
    convention, now exact by construction) and plane-padded coordinate
    counts for dense/quantized payloads — byte-for-byte what the HLO
    collective-permutes actually move.

    Bucket-sensitive like the transport itself: ``ParamPlane.for_tree``
    consults the ``plane.use_buckets`` context, so accounting for a
    TP-bucketed run must be computed under the same context the step
    was traced in (``steps.plane_bucket_tree`` owns the policy); with
    no context both sides use the single flat bucket.
    """
    return plane_mod.ParamPlane.for_tree(params).shape_dtype()


def transmitted_elements_per_step(params: PyTree, cfg: SDMConfig,
                                  node: int | None = None, *,
                                  seq=None) -> int:
    """Expected non-zero elements one node transmits per iteration.

    The paper's Figure-3 communication metric ("non-zero digits"),
    charged at wire-plane granularity (see ``wire_shape_tree``): for
    fixedk modes this is exact; for bernoulli it is the expectation
    p * plane_size. With heterogeneous per-node p, ``node`` selects
    whose budget to count; ``node=None`` returns the across-node mean
    (exact-Fraction mean, rounded once — network total = mean *
    n_nodes). ``seq`` makes the count schedule-aware (per-link): the
    payload cost multiplies by the mean out-degree over the sequence's
    rounds (union-graph degree on the replica transport); ``seq=None``
    keeps the legacy one-payload-per-step convention.
    """
    comp = compressor_of(cfg)
    wire = wire_shape_tree(params)
    if isinstance(cfg.p, tuple) and cfg.mode != "qsgd" and node is None:
        exact = compressor_mod.node_mean_exact(
            cfg.p, lambda i: compressor_mod.tree_wire_elements_exact(
                comp, wire, node=i))
    else:
        exact = compressor_mod.tree_wire_elements_exact(comp, wire,
                                                        node=node)
    return int(round(exact * schedule_degree_factor(seq, node)))


def transmitted_bits_per_step(params: PyTree, cfg: SDMConfig,
                              node: int | None = None, *,
                              value_bits: int = 32,
                              index_sync: bool = True,
                              seq=None) -> int:
    """Exact WIRE BITS one node transmits per iteration.

    The honest companion to the element count, at wire-plane granularity
    (``wire_shape_tree`` — what the HLO payload actually is): packed
    formats also need an index side-channel at ceil(log2 d) bits per
    kept element — unless both endpoints regenerate index sets from the
    shared seed (``index_sync=True``, the repo's gossip transport),
    which removes index traffic entirely; quantizers ship every plane
    coordinate but at qsgd_bits instead of ``value_bits`` (sub-byte
    levels packed into u8 lanes, so the HLO bytes match too).
    ``node=None`` with per-node p returns the across-node mean
    (exact-Fraction mean, rounded once). ``seq`` applies the same
    per-link degree factor as the element count.
    """
    comp = compressor_of(cfg)
    wire = wire_shape_tree(params)
    kw = dict(value_bits=value_bits, index_sync=index_sync)
    if isinstance(cfg.p, tuple) and cfg.mode != "qsgd" and node is None:
        exact = compressor_mod.node_mean_exact(
            cfg.p, lambda i: compressor_mod.tree_wire_bits_exact(
                comp, wire, node=i, **kw))
    else:
        exact = compressor_mod.tree_wire_bits_exact(comp, wire, node=node,
                                                    **kw)
    return int(round(exact * schedule_degree_factor(seq, node)))


# ==========================================================================
# Reference simulator: n nodes stacked on axis 0, dense-W gossip.
# ==========================================================================

class ReferenceSimulator:
    """Single-host n-node stacked simulator (the paper's experiments).

    Accepts a ``Topology`` / ``DirectedTopology``, a ``PermuteSchedule``,
    or a time-varying ``ScheduleSequence`` — the reference executor and
    the distributed executor are built from the SAME schedule object, so
    their mixing matrices can never diverge.

    Static graphs (and weight-invariant sequences) mix with the exact
    dense W via the incremental neighbour sum ``s`` — byte-for-byte the
    historical trajectories. Genuinely time-varying sequences mix with
    the exact dense W(t) of the CURRENT round: the stacked public copies
    ``x`` are precisely what the distributed executor's per-neighbour
    replicas reconstruct, so ``commit`` computes W(t) x fresh each round
    — true W(t)-mixing, bit-comparable to an explicit dense simulator.
    Full-state methods (DSGD, gradient-push) stay exact on time-varying
    graphs by construction.
    """

    def __init__(self, topo, cfg: SDMConfig):
        self.cfg = cfg
        self.seq = gossip.sequence_of(topo)
        self.topo = None if isinstance(
            topo, (gossip.PermuteSchedule, gossip.ScheduleSequence)) else topo
        check_per_node_p(cfg, self.seq.n_nodes)
        # replica-exact: genuinely time-varying weights -> mix with the
        # full dense W(t) each round; otherwise the incremental-s fast
        # path (exact there, and byte-identical to the historical code).
        self.replica_exact = gossip.needs_replicas(self.seq)
        self.time_varying = self.seq.length > 1 and not self.replica_exact
        if cfg.overlap and self.replica_exact:
            raise ValueError(
                "overlap=True is a static-schedule (non-replica) transport: "
                "genuinely time-varying weights recompute s from replicas "
                "every round and cannot consume increments one step late")
        wstack = self.seq.weights_stack()
        self._wstack = jnp.asarray(wstack, jnp.float32)   # (L, n, n)
        self.weights = self._wstack[0]

    @property
    def n_nodes(self) -> int:
        return self.seq.n_nodes

    def _weights_at(self, step) -> jax.Array:
        return self._wstack[step % self.seq.length]

    def init(self, params_stack: PyTree) -> SDMState:
        """params_stack leaves have leading dim n (one slice per node)."""
        n = jax.tree.leaves(params_stack)[0].shape[0]
        assert n == self.seq.n_nodes, (n, self.seq.n_nodes)
        e = _tree_zeros_like(params_stack) if self.cfg.error_feedback else None
        if self.replica_exact:
            # commit mixes the full dense W(t) fresh each round: the
            # reference replica path carries NO neighbour-sum buffer.
            s = None
        elif self.time_varying or self.cfg.overlap:
            # incremental-s bookkeeping starts from the round-0 weights
            # (the distributed init does the same with (1 - W_ii(0)) x_0).
            # The overlapped transport maintains s incrementally even on
            # static graphs — the pending double buffer is an increment.
            s = jax.tree.map(
                lambda x: gossip.apply_weights_dense(
                    self._wstack[0], x, include_self=False).astype(x.dtype),
                params_stack)
        else:
            s = _tree_zeros_like(params_stack)
        nb = _tree_zeros_like(params_stack) if self.cfg.overlap else None
        return SDMState(x=params_stack, s=s,
                        d=_tree_zeros_like(params_stack),
                        step=jnp.zeros((), jnp.int32), e=e, nb=nb)

    # -- phase 1: everyone transmits S(d) and advances public copies ------
    def advance(self, state: SDMState, key: jax.Array) -> Tuple[SDMState, PyTree]:
        """Returns (state with x <- x + S(d), the S(d) stack)."""
        cfg = self.cfg
        n = self.seq.n_nodes

        if cfg.error_feedback:
            # fold the residual from the previous round into what we send.
            # EF requires the CONTRACTIVE (unscaled) compressor mask*d —
            # the unbiased 1/p amplification would make the residual loop
            # explosive; error feedback is what repairs the bias instead
            # (Stich et al.). Implemented by undoing the 1/p scale below.
            d_in = jax.tree.map(jnp.add, state.d, state.e)
        else:
            d_in = state.d
        ef_scale = cfg.p if cfg.error_feedback else 1.0

        # The compressor roundtrip (compress -> decompress) IS the
        # sparsifier S(.) each node applies before transmitting. Draws
        # happen at WIRE-PLANE granularity — one compress over each
        # bucket's zero-padded plane, exactly what the distributed
        # executor puts on the wire — so the two executors' bits can
        # never diverge (pad coordinates are zero and stay zero).
        sd = sparsify_planes_stacked(compressor_of(cfg), d_in, key,
                                     state.step, n)
        if cfg.error_feedback and ef_scale != 1.0:
            sd = jax.tree.map(lambda v: v * ef_scale, sd)
        x = jax.tree.map(jnp.add, state.x, sd)
        new_e = jax.tree.map(jnp.subtract, d_in, sd) \
            if cfg.error_feedback else state.e
        if cfg.overlap:
            # one-step-stale: fold the increments received LAST step into
            # s; this step's weighted increments (weights of the round
            # the payload crossed) wait in the pending buffer until the
            # next advance — exactly the distributed double buffer.
            w_t = self._weights_at(state.step)
            s = jax.tree.map(jnp.add, state.s, state.nb)
            nb = tagging.pending_buffer(jax.tree.map(
                lambda v, s_: gossip.apply_weights_dense(
                    w_t, v, include_self=False).astype(s_.dtype),
                sd, s))
            return state._replace(x=x, s=s, e=new_e, nb=nb), sd
        if self.time_varying:
            # fold this round's weighted increments into s — the weights
            # of the round the increment was EXCHANGED in, exactly what
            # the distributed executor accumulates.
            w_t = self._weights_at(state.step)
            s = jax.tree.map(
                lambda s_, v: s_ + gossip.apply_weights_dense(
                    w_t, v, include_self=False).astype(s_.dtype),
                state.s, sd)
            return state._replace(x=x, s=s, e=new_e), sd
        return state._replace(x=x, e=new_e), sd

    # -- phase 2: local gradient + masking + generalized mixing -----------
    def commit(self, state: SDMState, grads_stack: PyTree,
               key: jax.Array) -> SDMState:
        cfg = self.cfg
        g = _masked_grad(grads_stack, key, cfg)
        if self.replica_exact:
            # exact W(t)-mixing: the stacked x IS every node's public
            # copy, so mix with the CURRENT round's full dense matrix —
            # what the distributed executor reconstructs from replicas.
            mixed = jax.tree.map(
                lambda x: gossip.mix_dense(self._weights_at(state.step), x),
                state.x)
        elif self.time_varying or cfg.overlap:
            # W~(t) x for node i = W_ii(t) x_i + s_i (s incremental; under
            # overlap s carries the neighbours' one-step-STALE public
            # copies — the delayed-W-mixing semantics).
            diag_w = jnp.diagonal(self._weights_at(state.step))
            mixed = jax.tree.map(
                lambda x, s: diag_w.reshape(
                    (self.seq.n_nodes,) + (1,) * (x.ndim - 1)
                ).astype(x.dtype) * x + s,
                state.x, state.s)
        else:
            mixed = jax.tree.map(
                lambda x: gossip.mix_dense(self.weights, x), state.x)
        y = jax.tree.map(
            lambda x, m, gr: (1.0 - cfg.theta) * x + cfg.theta * (m - cfg.gamma * gr),
            state.x, mixed, g)
        d = jax.tree.map(jnp.subtract, y, state.x)
        return state._replace(d=d, step=state.step + 1)

    def step(self, state: SDMState, grad_fn, batch_stack: PyTree,
             key: jax.Array) -> Tuple[SDMState, PyTree]:
        """Convenience: advance -> grads at new x -> commit.

        grad_fn(params_stack, batch_stack) -> grads_stack, aux.
        Returns (new_state, aux).
        """
        k_sp, k_noise = jax.random.split(key)
        state, _ = self.advance(state, k_sp)
        grads, aux = grad_fn(state.x, batch_stack)
        state = self.commit(state, grads, k_noise)
        return state, aux

    def consensus_mean(self, state: SDMState) -> PyTree:
        """xbar_t = (1/n) sum_i x_{i,t} — the quantity Lemma 1 bounds."""
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), state.x)

    # Method-protocol surface (repro.core.method): ``consensus`` is the
    # per-method consensus estimate, ``eval_params`` the per-node
    # parameter view evaluation should run on.
    consensus = consensus_mean

    def eval_params(self, state: SDMState) -> PyTree:
        return state.x


# ==========================================================================
# Distributed per-node step (inside shard_map; node axis manual).
# ==========================================================================

def _replica_planes(planes: Tuple[jax.Array, ...], n_replicas: int
                    ) -> Tuple[jax.Array, ...]:
    """Per-neighbour public-copy replica planes, all starting at x_0.

    Valid under the same identical-start assumption the s_0 formula uses:
    every neighbour's public copy begins at the shared x_0, and from then
    on slot k advances by exactly the increments the union-round-k sender
    transmits — so each slot stays an exact copy of x_{j,t} (as a plane).
    """
    return tuple(jnp.broadcast_to(p[None], (n_replicas,) + p.shape)
                 for p in planes)


def init_distributed_state(params: PyTree, self_weight,
                           n_replicas: int | None = None,
                           overlap: bool = False) -> SDMState:
    """Per-node state. ``params`` has NO node axis here (each shard owns one).

    All nodes must start from IDENTICAL params (standard same-seed init);
    then the initial neighbour sum is s_0 = (1 - W_ii) * x_0, since
    sum_{j != i} W_ij = 1 - W_ii and x_{j,0} = x_0. (The paper starts at
    x_0 = 0, a special case.) ``self_weight`` may be a python float or a
    traced scalar (``schedule.self_weight_of(me)`` inside shard_map, for
    topologies whose W_ii varies per node). ``n_replicas`` (genuinely
    time-varying schedules only) allocates the per-neighbour public-copy
    replica stack — deg_union extra plane buffers per node.

    ``s``, ``d`` (and ``xhat``) live as WIRE PLANES — f32 (rows, LANE)
    buffers, one per sharding bucket — because that is the shape the
    exchange consumes and produces; only ``x`` keeps the parameter tree
    (gradients are evaluated there).
    """
    spec = plane_mod.ParamPlane.for_tree(params)
    xp = spec.pack(params)
    s0 = tuple((1.0 - self_weight) * p for p in xp)
    d0 = tuple(jnp.zeros_like(p) for p in xp)
    xhat = _replica_planes(xp, n_replicas) if n_replicas else None
    if overlap and n_replicas:
        raise ValueError("overlap=True needs a static (non-replica) "
                         "schedule")
    nb0 = tuple(jnp.zeros_like(p) for p in xp) if overlap else None
    return SDMState(x=params, s=s0, d=d0,
                    step=jnp.zeros((), jnp.int32), xhat=xhat, nb=nb0)


def _plane_payload_exchange(planes: Tuple[jax.Array, ...],
                            comp: compressor_mod.Compressor, *,
                            axis_name, base_key: jax.Array, step, me,
                            schedule=None, useq=None, node_index=None,
                            transform=None):
    """Compressor-payload transport over wire planes — the ONE copy.

    One compress per bucket plane (key ``node_round_key(fold_in(base,
    bucket), me, step)`` — the schedule ``sparsify_planes_stacked``
    mirrors in the reference); the payload crosses the static schedule's
    R rounds (``useq=None``, weighted sum) or every union round
    (``useq`` set, per-slot increment stacks). ``transform`` rewrites
    each payload pre-wire (compressed push-sum's contraction). Shared by
    the SDM qsgd/payload modes AND compressed gradient-push, so the key
    schedule and contraction point cannot desynchronize between them.
    Returns (own decompressed planes, received planes).
    """
    own, recv = [], []
    for b, dp in enumerate(planes):
        key = gossip.node_round_key(
            jax.random.fold_in(base_key, b), me, step)
        pl = comp.compress(key, dp, node=me)
        if transform is not None:
            pl = transform(pl)
        own.append(comp.decompress(pl))
        if useq is not None:
            recv.append(gossip.union_exchange_payload(
                useq, pl, comp.decompress, axis_name))
        else:
            recv.append(gossip.exchange_payload(
                schedule, pl, comp.decompress, axis_name, step=step,
                node_index=node_index))
    return tuple(own), tuple(recv)


def _plane_exchange(d_planes: Tuple[jax.Array, ...], *, schedule, axis_name,
                    base_key: jax.Array, step: jax.Array, cfg: SDMConfig,
                    me, node_index=None) -> Tuple[Tuple[jax.Array, ...],
                                                  Tuple[jax.Array, ...]]:
    """Plane-granular exchange: (own S(d) planes, weighted nb-sum planes).

    The ONE static-schedule transport behind every SDM mode: each
    bucket's plane is compressed/drawn/top-k'd ONCE (key
    ``fold_in(base, bucket)`` — the schedule the reference's
    ``sparsify_planes_stacked`` mirrors) and crosses the wire in exactly
    R collective-permutes per bucket, independent of the model's leaf
    count.
    """
    comp = compressor_of(cfg)
    if cfg.mode in ("qsgd", "payload"):
        return _plane_payload_exchange(
            d_planes, comp, axis_name=axis_name, base_key=base_key,
            step=step, me=me, schedule=schedule, node_index=node_index)
    own, nb = [], []
    for b, dp in enumerate(d_planes):
        bkey = jax.random.fold_in(base_key, b)
        if cfg.mode == "fixedk_rows":
            o, s = gossip.exchange_packed_rows(
                schedule, dp, axis_name=axis_name, base_key=bkey,
                step=step, p=cfg.p, node_index=node_index)
        elif cfg.mode == "fixedk_packed":
            o, s = gossip.exchange_packed(
                schedule, dp.reshape(-1), axis_name=axis_name,
                base_key=bkey, step=step, p=cfg.p, block=cfg.pack_block,
                node_index=node_index)
            o, s = o.reshape(dp.shape), s.reshape(dp.shape)
        else:   # bernoulli: dense masked plane payload
            key = gossip.node_round_key(bkey, me, step)
            o = comp.decompress(comp.compress(key, dp, node=me))
            s = gossip.exchange(schedule, o, axis_name,
                                node_index=node_index, step=step)
        own.append(o)
        nb.append(s)
    return tuple(own), tuple(nb)


def _replica_plane_exchange(d_planes: Tuple[jax.Array, ...], *,
                            useq, axis_name, base_key: jax.Array,
                            step: jax.Array, cfg: SDMConfig, me,
                            node_index=None, transform=None
                            ) -> Tuple[Tuple[jax.Array, ...],
                                       Tuple[jax.Array, ...]]:
    """Replica (union) plane transport: (own planes, per-slot increments).

    Same selection/keys as ``_plane_exchange``; each union round's
    delivery lands in its OWN (n_replicas, rows, lane) row instead of a
    weighted sum — one batched sender draw per bucket regardless of
    sequence length.
    """
    comp = compressor_of(cfg)
    if cfg.mode in ("qsgd", "payload"):
        return _plane_payload_exchange(
            d_planes, comp, axis_name=axis_name, base_key=base_key,
            step=step, me=me, useq=useq, transform=transform)
    own, incr = [], []
    for b, dp in enumerate(d_planes):
        bkey = jax.random.fold_in(base_key, b)
        if cfg.mode == "fixedk_rows":
            o, inc = gossip.union_exchange_packed_rows(
                useq, dp, axis_name=axis_name, base_key=bkey,
                step=step, p=cfg.p, node_index=node_index)
        elif cfg.mode == "fixedk_packed":
            o, inc = gossip.union_exchange_packed(
                useq, dp.reshape(-1), axis_name=axis_name, base_key=bkey,
                step=step, p=cfg.p, block=cfg.pack_block,
                node_index=node_index)
            o = o.reshape(dp.shape)
            inc = inc.reshape((inc.shape[0],) + dp.shape)
        else:
            key = gossip.node_round_key(bkey, me, step)
            o = comp.decompress(comp.compress(key, dp, node=me))
            inc = gossip.union_exchange(useq, o, axis_name)
        own.append(o)
        incr.append(inc)
    return tuple(own), tuple(incr)


def _replica_advance_exchange(d_planes: Tuple[jax.Array, ...],
                              xhat: Tuple[jax.Array, ...], *,
                              seq, axis_name, base_key: jax.Array,
                              step: jax.Array, cfg: SDMConfig, me,
                              node_index=None):
    """Shared replica-transport advance: (own planes, new xhat, fresh s).

    Every union in-neighbour's increment arrives tagged by round
    position, advances its replica slot, and the weighted neighbour sum
    is recomputed FRESH with the CURRENT round's weights — exact
    W(t)-mixing on B-connected sequences.
    """
    useq = gossip.union_schedule(seq)
    own, incr = _replica_plane_exchange(
        d_planes, useq=useq, axis_name=axis_name, base_key=base_key,
        step=step, cfg=cfg, me=me, node_index=node_index)
    new_xhat = tuple(xh + inc for xh, inc in zip(xhat, incr))
    wv = gossip.replica_recv_weights(useq, me, step)     # (R,)
    s = tuple(jnp.tensordot(wv.astype(xh.dtype), xh, axes=([0], [0]))
              for xh in new_xhat)
    return own, new_xhat, s


def distributed_advance(state: SDMState, *, base_key: jax.Array, axis_name,
                        cfg: SDMConfig,
                        schedule=None,
                        self_weight: float | None = None,
                        neighbor_weight: float | None = None,
                        node_index=None) -> SDMState:
    """Phase 1 on the mesh: sparsify d, schedule-exchange, update x and s.

    ``schedule`` selects the gossip graph — a PermuteSchedule or a
    time-varying ScheduleSequence (indexed by the state's step counter);
    legacy scalar (self_weight, neighbor_weight) callers get the
    symmetric ring. ``node_index`` (optional sharded operand) replaces
    the axis_index collective where partial-auto shard_map cannot lower
    it. ``state.s`` / ``state.d`` (and ``state.xhat``) are wire planes.
    """
    del neighbor_weight  # ring default is fully described by self_weight
    seq = gossip.resolve_sequence(schedule, axis_name, self_weight)
    check_per_node_p(cfg, seq.n_nodes)
    me = gossip._me(axis_name, node_index)
    spec = plane_mod.ParamPlane.for_tree(state.x)

    if gossip.needs_replicas(seq):
        # genuinely time-varying weights: replica-correct advance (exact
        # W(t)-mixing; state.xhat must have been allocated at init).
        if cfg.overlap:
            raise ValueError("overlap=True needs a static (non-replica) "
                             "schedule")
        own, xhat, s = _replica_advance_exchange(
            state.d, state.xhat, seq=seq, axis_name=axis_name,
            base_key=base_key, step=state.step, cfg=cfg, me=me,
            node_index=node_index)
        x = jax.tree.map(jnp.add, state.x, spec.unpack(own))
        return state._replace(x=x, s=s, xhat=xhat)

    own, nb = _plane_exchange(
        state.d, schedule=seq, axis_name=axis_name, base_key=base_key,
        step=state.step, cfg=cfg, me=me, node_index=node_index)
    x = jax.tree.map(jnp.add, state.x, spec.unpack(own))
    if cfg.overlap:
        # Overlapped transport: this step's mixing consumes the PENDING
        # buffer (last step's exchange result) and the fresh exchange
        # lands in the double buffer for the next step. Nothing after
        # this point in the step reads ``nb``, so the permute's data
        # dependency ends at the loop carry — XLA's async scheduler is
        # free to issue collective-permute-start here and sink the
        # matching -done past the entire gradient computation of the
        # next iteration.
        s = tuple(s_ + p_ for s_, p_ in zip(state.s, state.nb))
        return state._replace(x=x, s=s, nb=tagging.pending_buffer(nb))
    s = tuple(s_ + nb_ for s_, nb_ in zip(state.s, nb))
    return state._replace(x=x, s=s)


class SDMFusedState(NamedTuple):
    """Two-buffer state for the fused step (see distributed_step_fused).

    On genuinely time-varying schedules the replica stack ``xhat`` rides
    along (deg_union extra buffers) — the price of exact W(t)-mixing.
    """
    x: PyTree
    s: PyTree
    step: jax.Array
    xhat: PyTree = None
    nb: PyTree = None   # overlap double buffer (see SDMState.nb)


def init_fused_state(params: PyTree, self_weight,
                     n_replicas: int | None = None,
                     overlap: bool = False) -> SDMFusedState:
    xp = plane_mod.ParamPlane.for_tree(params).pack(params)
    s0 = tuple((1.0 - self_weight) * p for p in xp)
    xhat = _replica_planes(xp, n_replicas) if n_replicas else None
    if overlap and n_replicas:
        raise ValueError("overlap=True needs a static (non-replica) "
                         "schedule")
    nb0 = tuple(jnp.zeros_like(p) for p in xp) if overlap else None
    return SDMFusedState(x=params, s=s0, step=jnp.zeros((), jnp.int32),
                         xhat=xhat, nb=nb0)


def distributed_step_fused(state: SDMFusedState, grads: PyTree, *,
                           base_key: jax.Array, axis_name, cfg: SDMConfig,
                           schedule=None,
                           self_weight: float | None = None,
                           neighbor_weight: float | None = None,
                           node_index=None) -> SDMFusedState:
    """Memory-optimized whole-iteration step: commit_t + advance_{t+1} fused.

    Identical algorithm to (distributed_advance; grads; distributed_commit)
    with the step boundary shifted by half an iteration: the differential
    d_t only lives INSIDE the step (computed from this step's gradient,
    sparsified, exchanged, and folded into (x, s) immediately), so the
    persistent state drops from 3 parameter buffers (x, s, d) to 2 —
    a 1/3 cut of the dominant memory term. Gradient must be evaluated at
    state.x BEFORE calling (x is already post-advance).
    """
    del neighbor_weight
    seq = gossip.resolve_sequence(schedule, axis_name, self_weight)
    check_per_node_p(cfg, seq.n_nodes)
    me = gossip._me(axis_name, node_index)
    sw = seq.self_weight_of(me, state.step)
    noise_key = jax.random.fold_in(
        gossip.node_round_key(base_key, me, state.step), 0x5eed)
    g = _masked_grad(grads, noise_key, cfg)
    spec = plane_mod.ParamPlane.for_tree(state.x)
    xp = spec.pack(state.x)
    gp = spec.pack(g)
    d = tuple(cfg.theta * (sw * x_ + s_ - cfg.gamma * g_) - cfg.theta * x_
              for x_, s_, g_ in zip(xp, state.s, gp))

    # immediately sparsify + exchange + fold in (the next round's advance).
    # Sparsifier keys use counter step+1: in the unfused flow d_t is
    # sparsified by the NEXT iteration's advance (bit-equality preserved;
    # for a time-varying sequence the exchange likewise runs on the
    # NEXT round's graph).
    sp_step = state.step + 1
    if gossip.needs_replicas(seq):
        if cfg.overlap:
            raise ValueError("overlap=True needs a static (non-replica) "
                             "schedule")
        own, xhat, s = _replica_advance_exchange(
            d, state.xhat, seq=seq, axis_name=axis_name, base_key=base_key,
            step=sp_step, cfg=cfg, me=me, node_index=node_index)
        x = jax.tree.map(jnp.add, state.x, spec.unpack(own))
        return SDMFusedState(x=x, s=s, step=state.step + 1, xhat=xhat)
    own, nb = _plane_exchange(
        d, schedule=seq, axis_name=axis_name, base_key=base_key,
        step=sp_step, cfg=cfg, me=me, node_index=node_index)
    x = jax.tree.map(jnp.add, state.x, spec.unpack(own))
    if cfg.overlap:
        # one-step-stale double buffer (see distributed_advance).
        s = tuple(s_ + p_ for s_, p_ in zip(state.s, state.nb))
        return SDMFusedState(x=x, s=s, step=state.step + 1,
                             nb=tagging.pending_buffer(nb))
    s = tuple(s_ + nb_ for s_, nb_ in zip(state.s, nb))
    return SDMFusedState(x=x, s=s, step=state.step + 1)


def distributed_commit(state: SDMState, grads: PyTree, *, base_key: jax.Array,
                       axis_name, cfg: SDMConfig,
                       schedule=None,
                       self_weight: float | None = None,
                       node_index=None) -> SDMState:
    """Phase 2 on the mesh: masked gradient + generalized mixing update.

    Runs on the wire planes: x and the masked gradient are packed once
    (cheap reshape/concat, fused by XLA) and the differential is
    produced directly in plane form — ready for the next advance's
    single-draw exchange.
    """
    seq = gossip.resolve_sequence(schedule, axis_name, self_weight)
    me = gossip._me(axis_name, node_index)
    sw = seq.self_weight_of(me, state.step)
    noise_key = jax.random.fold_in(
        gossip.node_round_key(base_key, me, state.step), 0x5eed)
    g = _masked_grad(grads, noise_key, cfg)
    spec = plane_mod.ParamPlane.for_tree(state.x)
    xp = spec.pack(state.x)
    gp = spec.pack(g)
    # W~ x for node i = W_ii x_i + s_i  (s maintained incrementally on
    # static schedules, recomputed from the exact replicas on
    # time-varying ones — either way it carries this round's weights).
    y = tuple((1.0 - cfg.theta) * x_
              + cfg.theta * (sw * x_ + s_ - cfg.gamma * g_)
              for x_, s_, g_ in zip(xp, state.s, gp))
    d = tuple(y_ - x_ for y_, x_ in zip(y, xp))
    return state._replace(d=d, step=state.step + 1)
