"""RDP-based differential-privacy accountant for SDM-DSGD.

Implements, as executable functions, exactly the quantities the paper
proves:

* Lemma 2 (subsampled Gaussian RDP, from Wang-Balle-Kasiviswanathan):
  per-step `(alpha, 4*alpha*(tau*G / (m*sigma))^2)`-RDP; the sparsifier
  multiplies the *expected* RDP order by p (Theorem 1), because only the
  active coordinates `C_{1,t}` (a Binomial(d, p) subset) are released.
* Theorem 1: T-step composition is
  `(4*alpha*p*T*(tau*G/(m*sigma))^2 + eps/2, delta)`-DP in expectation
  with `alpha = 2*log(1/delta)/eps + 1`.
* Corollary 2: the noise level needed for a target (eps, delta):
  `sigma^2 = 8*p*T*G^2*(2*log(1/delta) + eps) / (m^4 * eps^2)`,
  valid while `sigma^2 >= 1/1.25` and `eps <= 10*p*T*G^2/m^4`.
* Theorem 4: the training-privacy trade-off
  `T_max = m^4 * eps^2 / (20 * G^2 * log(1/delta) * p) = O(m^4)` —
  two orders of magnitude better than the O(m^2) prior art.
* Proposition 5: the reversed design ("sparsify-then-randomize") pays a
  `1/p^2` factor in the eps-part — the co-design insight of §4.3.

The accountant is pure Python/NumPy (it runs on the host, once per run,
and is consumed by the training loop for online budget tracking).
"""
from __future__ import annotations

import dataclasses
import math

__all__ = [
    "PrivacyParams",
    "SIGMA_SQ_MIN",
    "rdp_alpha",
    "per_step_rdp",
    "epsilon_sdm",
    "epsilon_alternative",
    "sigma_sq_for_epsilon",
    "sigma_for_budget",
    "max_iterations",
    "PrivacyAccountant",
]

# Lower bound sigma^2 >= 1/1.25 required for the subsampled-RDP
# amplification (Theorem 1 / Remark 2, following Wang et al. 2018).
SIGMA_SQ_MIN = 1.0 / 1.25


@dataclasses.dataclass(frozen=True)
class PrivacyParams:
    """Static privacy configuration of a run.

    Attributes:
      G: l2-sensitivity bound of a single-example gradient (Assumption 1(4)
         gives coordinate-wise G/sqrt(d), hence ||grad|| <= G).
      m: local dataset size per node.
      tau: subsampling rate (batch fraction); the paper's headline results
         use tau = 1/m (one sample per step).
      p: sparsifier transmit probability — a scalar, or a per-node tuple
         for heterogeneous sparsity budgets. Theorem 1's per-step RDP is
         linear in p, so with per-node budgets the accountant charges
         every node the WORST-CASE (max-p) node's leakage: the reported
         epsilon upper-bounds each node's true spend.
      sigma: Gaussian masking noise std-dev (per coordinate).
      delta: target delta.
      participation_q: per-round node participation fraction. With
         partial participation (the edge-fleet simulator samples an
         active subgraph of expected size q*n per round) a node's data
         enters a release only in rounds it participates in, and the
         participation sampling composes with the paper's data
         subsampling: the effective subsampled-Gaussian rate is q*tau,
         so the per-step RDP picks up a q^2 amplification factor
         (Wang-Balle-Kasiviswanathan, same lemma that gives the tau^2).
         q = 1 (default) is full participation and changes nothing.
    """

    G: float
    m: int
    tau: float
    p: "float | tuple"
    sigma: float
    delta: float = 1e-5
    participation_q: float = 1.0

    def __post_init__(self) -> None:
        if isinstance(self.p, (list, tuple)):
            object.__setattr__(self, "p", tuple(float(v) for v in self.p))
            if not self.p:
                raise ValueError("per-node p must be non-empty")
            if any(not (0.0 < v <= 1.0) for v in self.p):
                raise ValueError("every per-node p must be in (0, 1]")
        elif not (0.0 < self.p <= 1.0):
            raise ValueError("p must be in (0, 1]")
        if not (0.0 < self.tau <= 1.0):
            raise ValueError("tau must be in (0, 1]")
        if not (0.0 < self.participation_q <= 1.0):
            raise ValueError(
                f"participation_q must be in (0, 1], got {self.participation_q!r}: "
                "q is a sampling fraction — q=0 means no node ever "
                "participates (nothing is released, but nothing trains "
                "either) and q>1 is not a probability")
        if not self.sigma > 0.0:
            raise ValueError(
                f"sigma must be > 0, got {self.sigma!r}: the accountant's "
                "per-step RDP is (tau*G/(m*sigma))^2 — sigma=0 claims no "
                "privacy and every downstream epsilon would be inf/NaN")
        if not (0.0 < self.delta < 1.0):
            raise ValueError("delta must be in (0, 1)")
        if not self.G > 0.0:
            raise ValueError(f"G (sensitivity bound) must be > 0, got {self.G!r}")
        if self.m < 1:
            raise ValueError(f"m (local dataset size) must be >= 1, got {self.m!r}")

    @classmethod
    def from_compressor(cls, comp, *, G: float, m: int, tau: float,
                        sigma: float, delta: float = 1e-5,
                        participation_q: float = 1.0
                        ) -> "PrivacyParams":
        """Accountant parameters with the release probability READ OFF
        the compressor (``repro.core.compressor``).

        Sparsifying compressors release each coordinate w.p. p — the
        factor Theorem 1 multiplies into the per-step RDP; quantizers
        (qsgd) release every coordinate (``release_probability == 1``),
        so quantization buys wire bits but no subsampling amplification.
        Per-node tuples pass through: the accountant charges the
        worst-case (max-p) node as always.
        """
        return cls(G=G, m=m, tau=tau, p=comp.release_probability,
                   sigma=sigma, delta=delta, participation_q=participation_q)

    @property
    def p_worst(self) -> float:
        """The accountant's p: the max-p node dominates the RDP spend."""
        return max(self.p) if isinstance(self.p, tuple) else self.p

    @property
    def p_sparsest(self) -> float:
        """min-p node: dominates the REVERSED design's 1/p leakage."""
        return min(self.p) if isinstance(self.p, tuple) else self.p


def _check_eps_target(eps: float) -> None:
    if not eps > 0.0:
        raise ValueError(
            f"eps_target must be > 0, got {eps!r}: Theorem 1's Rényi order "
            "alpha = 2*log(1/delta)/eps + 1 diverges at eps=0")


def rdp_alpha(eps: float, delta: float) -> float:
    """Theorem 1's Rényi order: alpha = 2 log(1/delta)/eps + 1."""
    _check_eps_target(eps)
    if not (0.0 < delta < 1.0):
        raise ValueError("delta must be in (0, 1)")
    return 2.0 * math.log(1.0 / delta) / eps + 1.0


def _theorem1_K(alpha: float, *, G: float, m: int, tau: float, p: float,
                participation_q: float = 1.0) -> float:
    """Theorem 1's per-step RDP with sigma^2 factored out.

    K(alpha) = 4 * alpha * p * (q * tau * G / m)^2, so a step is
    K/sigma^2-RDP at order alpha. This single coefficient is the ONLY
    place the sigma <-> epsilon trade-off lives: ``per_step_rdp`` (and
    hence ``epsilon_sdm``) divides it by sigma^2, and
    ``sigma_sq_for_epsilon`` inverts it — so the forward accountant and
    Corollary 2's calibration can never drift apart.
    """
    return 4.0 * alpha * p * (participation_q * tau * G / m) ** 2


def per_step_rdp(params: PrivacyParams, alpha: float) -> float:
    """Expected per-step RDP of the released S(d_t) (Theorem 1 proof).

    rho_t = 4 * alpha * p * (q * tau * G / (m * sigma))^2, with p the
    worst-case (max) node budget when p is per-node and q the per-round
    participation fraction: partial participation composes with the
    data subsampling into an effective subsampled-Gaussian rate q*tau,
    so q < 1 amplifies privacy quadratically (subsampled RDP, same
    Wang-Balle-Kasiviswanathan lemma as the tau^2 factor). q = 1
    recovers Theorem 1 verbatim.
    Requires sigma^2 >= 1/1.25 for the subsampling amplification.
    """
    return _theorem1_K(
        alpha, G=params.G, m=params.m, tau=params.tau, p=params.p_worst,
        participation_q=params.participation_q) / params.sigma ** 2


def epsilon_sdm(params: PrivacyParams, T: int, eps_target: float) -> float:
    """Theorem 1: total epsilon after T iterations of SDM-DSGD.

    eps_total = 4*alpha*p*T*(tau*G/(m*sigma))^2 + eps_target/2, with
    alpha = 2*log(1/delta)/eps_target + 1. Returns +inf when the
    sigma^2 >= 1/1.25 precondition fails.
    """
    if params.sigma ** 2 < SIGMA_SQ_MIN:
        return math.inf
    alpha = rdp_alpha(eps_target, params.delta)
    return T * per_step_rdp(params, alpha) + eps_target / 2.0


def epsilon_alternative(params: PrivacyParams, T: int, eps_target: float) -> float:
    """Proposition 5: epsilon of the reversed sparsify-then-randomize design.

    eps_alt = 4*alpha*T*(tau*G)^2 / (m^2 * sigma^2 * p) + eps_target/2.
    The eps-part exceeds Theorem 1's by exactly 1/p^2 — the paper's
    co-design argument for randomize-then-sparsify. Leakage here scales
    as 1/p, so with per-node budgets the SPARSEST (min-p) node is the
    worst case.
    """
    if params.sigma ** 2 < SIGMA_SQ_MIN:
        return math.inf
    alpha = rdp_alpha(eps_target, params.delta)
    rho = 4.0 * alpha * (params.tau * params.G) ** 2 / (
        params.m ** 2 * params.sigma ** 2 * params.p_sparsest)
    return T * rho + eps_target / 2.0


def sigma_sq_for_epsilon(*, G: float, m: int, tau: float, p: float, T: int,
                         eps: float, delta: float,
                         participation_q: float = 1.0) -> float:
    """Exact inversion of Theorem 1 for sigma^2 at a total budget eps.

    Theorem 1 reads eps_total = T*K(alpha)/sigma^2 + eps/2 with
    alpha = rdp_alpha(eps, delta); solving eps_total = eps gives
    sigma^2 = 2*T*K(alpha)/eps. Because this uses the SAME
    ``_theorem1_K`` the forward accountant divides by sigma^2, feeding
    the returned sigma back through ``epsilon_sdm`` reproduces eps
    identically (up to float round-off) — the round-trip
    ``tests/test_core_privacy.py`` asserts.
    """
    _check_eps_target(eps)
    alpha = rdp_alpha(eps, delta)
    return 2.0 * T * _theorem1_K(
        alpha, G=G, m=m, tau=tau, p=p, participation_q=participation_q) / eps


def sigma_for_budget(G: float, m: int, p: float, T: int, eps: float,
                     delta: float = 1e-5, clamp: bool = False) -> float:
    """Corollary 2: sigma so that T iterations are (eps, delta)-DP.

    sigma^2 = 8*p*T*G^2*(2 log(1/delta) + eps) / (m^4 * eps^2), using the
    paper's headline subsampling rate tau = 1/m — the closed form is
    exactly ``sigma_sq_for_epsilon`` at tau = 1/m, which is how it is
    computed here. Raises if the resulting sigma^2 violates the 1/1.25
    amplification precondition, which the paper guarantees whenever
    eps <= 10*p*T*G^2/m^4.

    With ``clamp=True`` (for budgets with T below Theorem 4's T_max) the
    returned sigma is floored at sqrt(1/1.25): strictly MORE noise than
    Corollary 2 asks, so the run is at least (eps, delta)-DP and the
    amplification lemma stays valid.
    """
    _check_eps_target(eps)
    if not (0.0 < p <= 1.0):
        raise ValueError(f"p must be in (0, 1], got {p!r}")
    if not G > 0.0:
        raise ValueError(f"G must be > 0, got {G!r}")
    if T < 1:
        raise ValueError(f"T must be >= 1, got {T!r}")
    sigma_sq = sigma_sq_for_epsilon(G=G, m=m, tau=1.0 / m, p=p, T=T,
                                    eps=eps, delta=delta)
    if sigma_sq < SIGMA_SQ_MIN:
        if clamp:
            return math.sqrt(SIGMA_SQ_MIN)
        raise ValueError(
            f"Corollary 2 precondition violated: sigma^2={sigma_sq:.4g} < 1/1.25. "
            "Increase T or decrease eps (need eps <~ 10*p*T*G^2/m^4 = "
            f"{10.0 * p * T * G**2 / m**4:.4g}).")
    return math.sqrt(sigma_sq)


def max_iterations(G: float, m: int, p: float, eps: float,
                   delta: float = 1e-5) -> int:
    """Theorem 4: T = m^4 eps^2 / (20 G^2 log(1/delta) p) = O(m^4).

    The maximum iteration count under a fixed (eps, delta) budget. The
    state of the art prior to this paper scaled as O(m^2) (Remark 5).
    """
    _check_eps_target(eps)
    if not (0.0 < p <= 1.0):
        raise ValueError(f"p must be in (0, 1], got {p!r}")
    return max(1, int(m ** 4 * eps ** 2 / (20.0 * G ** 2 * math.log(1.0 / delta) * p)))


def convergence_at_budget(G: float, m: int, n: int, p: float, eps: float,
                          delta: float = 1e-5) -> float:
    """Theorem 4's rate: min_t ||grad f||^2 = O(sqrt(20 G^2 log(1/delta) p) / (sqrt(n) m^2 eps))."""
    return math.sqrt(20.0 * G ** 2 * math.log(1.0 / delta) * p) / (
        math.sqrt(n) * m ** 2 * eps)


class PrivacyAccountant:
    """Online tracker: accumulates per-step RDP and reports (eps, delta)-DP.

    Mirrors the paper's "we keep track of the privacy loss based on
    Theorem 1" experimental procedure (§5).
    """

    def __init__(self, params: PrivacyParams, eps_target: float):
        self.params = params
        self.eps_target = eps_target
        self.alpha = rdp_alpha(eps_target, params.delta)
        self._rho = 0.0
        self.steps = 0

    def step(self, n_steps: int = 1) -> None:
        self._rho += n_steps * per_step_rdp(self.params, self.alpha)
        self.steps += n_steps

    @property
    def rdp(self) -> float:
        return self._rho

    @property
    def epsilon(self) -> float:
        """Lemma 4 conversion: eps = rho + log(1/delta)/(alpha - 1)."""
        if self.params.sigma ** 2 < SIGMA_SQ_MIN:
            return math.inf
        return self._rho + math.log(1.0 / self.params.delta) / (self.alpha - 1.0)

    def exhausted(self, eps_budget: float) -> bool:
        return self.epsilon >= eps_budget
