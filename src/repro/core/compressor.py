"""Pluggable Compressor layer: what actually goes on the wire.

The paper's thesis is that the WIRE PAYLOAD — sparse differential
Gaussian-masked messages — is the single lever for both privacy and
communication efficiency. This module makes that payload a first-class
object: a small registry of compressors, each defining

    compress(key, x, node=...) -> Payload     # what a node transmits
    decompress(payload)        -> x_hat       # what a receiver rebuilds
    wire_elements / wire_bits  -> int         # exact cost accounting

where ``Payload`` is a SHAPE-STATIC pytree (values + indices + scale)
that ``gossip.exchange_payload`` can ppermute generically — no
hand-packed flat buffers per call site. Static shapes are what make the
payload a legal `collective-permute` operand; heterogeneous per-node
sparsity budgets therefore pad to the max-k across nodes (rows beyond a
node's own k carry zero values, so scatter-adding them is a no-op).

Registered families (``make`` parses CLI-style specs):

    bernoulli        paper-faithful i.i.d. Bernoulli(p) masking; dense
                     tensor on the wire, expected p*d informative coords.
    fixedk           seed-synchronized fixed-k packing: exactly
                     k = ceil(p*d) coordinates, padded to max-k when p is
                     a per-node tuple.
    block / block:B  fixed-k at B-coordinate block granularity (DMA-
                     friendly; required beyond 2^31-element leaves).
    rows             fixed-k over trailing-dim rows (keeps each leaf's
                     tensor-parallel sharding intact — the production
                     SDM mode).
    qsgd / qsgd:b    QSGD-style stochastic quantizer (Alistarh et al.;
                     cf. Layered Randomized Quantization, arXiv:2312.07060):
                     per-leaf l2 norm + b-bit stochastic levels in int8.
                     Every coordinate ships, but at b bits instead of 32.

Accounting conventions: ``wire_elements`` counts INFORMATIVE non-zero
elements (the paper's Fig-3 "non-zero digits" metric; pad rows excluded).
``wire_bits`` charges value bits plus, for packed formats, the index
side-channel at ceil(log2 d) bits per kept element — pass
``index_sync=True`` when both endpoints regenerate index sets from a
shared seed (the repo's gossip transport), which removes index traffic.
``release_probability`` is what the RDP accountant needs: the per-
coordinate probability that a coordinate of the masked message is
released at all (1.0 for quantizers — they release every coordinate).
"""
from __future__ import annotations

import dataclasses
import math
from fractions import Fraction
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparsifier

__all__ = [
    "Payload",
    "Compressor",
    "BernoulliCompressor",
    "FixedKCompressor",
    "RowsCompressor",
    "QSGDCompressor",
    "FusedQSGDCompressor",
    "make",
    "names",
    "register",
    "index_bits",
    "node_mean_exact",
    "tree_wire_elements",
    "tree_wire_bits",
    "tree_wire_elements_exact",
    "tree_wire_bits_exact",
]


def index_bits(d: int) -> int:
    """Bits to address one of d coordinates: ceil(log2 d) (0 for d <= 1)."""
    return max(0, math.ceil(math.log2(d))) if d > 1 else 0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Payload:
    """Shape-static wire format: the pytree a node actually transmits.

    ``values`` is the packed/masked/quantized data, ``indices`` the
    explicit coordinate side-channel (None when dense or implicit via
    seed regeneration), ``scale`` an optional per-payload scalar (e.g.
    the QSGD norm). ``shape`` and ``meta`` are STATIC aux data (identical
    on every node) so the payload can cross `jax.lax.ppermute` leaf by
    leaf and be decompressed on the receiver without renegotiation.
    """

    values: Any
    indices: Any = None
    scale: Any = None
    shape: Tuple[int, ...] = ()
    meta: Tuple = ()

    def tree_flatten(self):
        return (self.values, self.indices, self.scale), (self.shape, self.meta)

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, indices, scale = children
        return cls(values=values, indices=indices, scale=scale,
                   shape=aux[0], meta=aux[1])


def _as_p_tuple_or_float(p):
    if isinstance(p, (list, tuple)):
        p = tuple(float(v) for v in p)
        if not p:
            raise ValueError("per-node p must be non-empty")
        if any(not (0.0 < v <= 1.0) for v in p):
            raise ValueError("every per-node p must be in (0, 1]")
        return p
    if not (0.0 < float(p) <= 1.0):
        raise ValueError(f"p must be in (0, 1], got {p}")
    return float(p)


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base: a transmit-probability-parameterized compressor.

    Frozen/hashable — safe to close over in jit/shard_map. ``p`` may be
    a per-node tuple; ``compress(..., node=i)`` then resolves node i's
    budget (``node`` may be a traced index).
    """

    p: "float | Tuple[float, ...]" = 0.2

    def __post_init__(self) -> None:
        object.__setattr__(self, "p", _as_p_tuple_or_float(self.p))

    # -- per-node budget helpers ------------------------------------------
    @property
    def p_max(self) -> float:
        return max(self.p) if isinstance(self.p, tuple) else self.p

    @property
    def p_min(self) -> float:
        return min(self.p) if isinstance(self.p, tuple) else self.p

    def p_of(self, node):
        """Transmit probability of ``node`` (traceable gather for tuples)."""
        if isinstance(self.p, tuple):
            if node is None:
                raise ValueError(
                    f"{self.name}: per-node p needs an explicit node=")
            return jnp.asarray(self.p, jnp.float32)[node]
        return self.p

    @property
    def release_probability(self):
        """Per-coordinate release probability for the RDP accountant.

        Sparsifiers release a coordinate w.p. p (Theorem 1's factor);
        quantizers release every coordinate (override with 1.0).
        """
        return self.p

    # -- interface ---------------------------------------------------------
    name: str = dataclasses.field(default="", init=False, repr=False)

    def compress(self, key: jax.Array, x: jax.Array, *, node=None) -> Payload:
        raise NotImplementedError

    def decompress(self, payload: Payload) -> jax.Array:
        raise NotImplementedError

    def wire_elements(self, shape: Tuple[int, ...], node: int | None = None
                      ) -> int:
        """Informative non-zero elements per transmission of one leaf."""
        raise NotImplementedError

    def wire_bits(self, shape: Tuple[int, ...], *, value_bits: int = 32,
                  index_sync: bool = False, node: int | None = None) -> int:
        """Exact wire bits per transmission of one leaf.

        Packed formats charge ``index_bits(d)`` per kept element unless
        ``index_sync`` (seed-regenerated index sets, no index traffic).
        """
        raise NotImplementedError

    # -- static-accounting helpers ----------------------------------------
    def _p_static(self, node: int | None) -> float:
        """Python-float budget for host-side accounting (worst node when
        p is a tuple and no node is named)."""
        if isinstance(self.p, tuple):
            return self.p[node] if node is not None else self.p_max
        return self.p

    # Exact (possibly fractional) per-leaf expectations, so tree-level
    # accounting rounds ONCE over the whole tree instead of per leaf
    # (round(p*d_total), the paper's Fig-3 convention) — deterministic
    # compressors just return their integer counts; probabilistic ones
    # return exact ``Fraction``s (p parsed via repr, so 0.3 * d is 3d/10
    # and cross-node means in het-p accounting cannot drift by float
    # rounding).
    def wire_elements_exact(self, shape, node=None) -> "Fraction | float":
        return float(self.wire_elements(shape, node=node))

    def wire_bits_exact(self, shape, *, value_bits=32, index_sync=False,
                        node=None) -> "Fraction | float":
        return float(self.wire_bits(shape, value_bits=value_bits,
                                    index_sync=index_sync, node=node))

    # -- sensitivity-transfer declaration ---------------------------------
    def coord_sensitivity_transfer(self, beta: float,
                                   shape: Tuple[int, ...]) -> float:
        """Worst-case coordinate bound after a compress -> decompress
        roundtrip of a tensor whose coordinates are bounded by ``beta``.

        The privacy certifier (``repro.analysis.sensitivity``) consumes
        this declaration: unbiased compressors inflate magnitudes (the
        1/p rescale, QSGD's norm-coupled levels), and the certificate
        records by how much so the released-value range is a proved
        constant, not folklore. Families that do not declare a transfer
        are conservatively unbounded — a new compressor MUST override
        this to enter the audited matrix (analyzer contract).
        ``tests/test_sensitivity_domain.py`` property-checks each
        declaration against the concrete roundtrip.
        """
        del shape
        return math.inf if beta > 0.0 else 0.0


# ==========================================================================
# Bernoulli (the paper's Definition-2 sparsifier; dense payload).
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class BernoulliCompressor(Compressor):
    """S(x): keep each coordinate w.p. p, scale kept by 1/p (Definition 2).

    The payload is the dense masked tensor — what the paper's theory
    analyses. Wire accounting counts the expected p*d informative
    coordinates (a sparse encoding would ship value + index per nnz).
    """

    name: str = dataclasses.field(default="bernoulli", init=False, repr=False)

    def compress(self, key, x, *, node=None) -> Payload:
        vals = sparsifier.bernoulli_sparsify(key, x, self.p_of(node)
                                             if isinstance(self.p, tuple)
                                             else self.p)
        return Payload(values=vals, shape=tuple(x.shape),
                       meta=("bernoulli",))

    def decompress(self, payload: Payload) -> jax.Array:
        return payload.values

    def wire_elements_exact(self, shape, node=None) -> Fraction:
        return Fraction(repr(self._p_static(node))) * math.prod(shape)

    def wire_elements(self, shape, node=None) -> int:
        return int(round(self.wire_elements_exact(shape, node)))

    def wire_bits_exact(self, shape, *, value_bits=32, index_sync=False,
                        node=None) -> Fraction:
        d = int(math.prod(shape))
        per = value_bits + (0 if index_sync else index_bits(d))
        return self.wire_elements_exact(shape, node) * per

    def wire_bits(self, shape, *, value_bits=32, index_sync=False,
                  node=None) -> int:
        return int(round(self.wire_bits_exact(
            shape, value_bits=value_bits, index_sync=index_sync, node=node)))

    def coord_sensitivity_transfer(self, beta, shape):
        # kept coordinates are rescaled by 1/p; the sparsest node's
        # budget is the worst case under per-node p.
        del shape
        return beta / self.p_min


# ==========================================================================
# Fixed-k packing (element blocks); the pad-to-max-k payload format.
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class FixedKCompressor(Compressor):
    """Exactly k = ceil(p * n_blocks) blocks, packed (values, indices).

    With per-node p the payload pads to k_max = max_i k_i: every node
    draws k_max top-k block indices from its seed, zeroes the value rows
    beyond its own k_i, and scales kept rows by n_blocks/k_i. Indices are
    distinct (top-k), so scatter-adding the zero pad rows is a no-op and
    the SAME static payload shape serves every node — the property the
    ppermute transport requires (ROADMAP's "heterogeneous p in fixed-k
    modes" item).
    """

    block: int = 1
    name: str = dataclasses.field(default="fixedk", init=False, repr=False)

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.block < 1:
            raise ValueError("block must be >= 1")

    def _k_table(self, nb: int):
        if isinstance(self.p, tuple):
            return tuple(sparsifier.num_kept(nb, pi) for pi in self.p)
        return None

    def k_max(self, nb: int) -> int:
        kt = self._k_table(nb)
        return max(kt) if kt else sparsifier.num_kept(nb, self.p)

    def _block_view(self, x: jax.Array) -> jax.Array:
        return sparsifier.block_view(x.reshape(-1), self.block)

    def compress(self, key, x, *, node=None) -> Payload:
        xb = self._block_view(x)
        nb = xb.shape[0]
        kt = self._k_table(nb)
        kmax = self.k_max(nb)
        idx = sparsifier.fixedk_indices(key, nb, kmax)
        vals = jnp.take(xb, idx, axis=0)
        if kt is None:
            vals = vals * (nb / kmax)
        else:
            if node is None:
                raise ValueError("per-node p needs node=")
            kb = jnp.asarray(kt, jnp.int32)[node]
            keep = (jnp.arange(kmax) < kb)[:, None]
            vals = vals * (nb / kb.astype(jnp.float32)) \
                * keep.astype(vals.dtype)
        return Payload(values=vals.astype(xb.dtype), indices=idx,
                       shape=tuple(x.shape), meta=("fixedk", self.block))

    def decompress(self, payload: Payload) -> jax.Array:
        block = payload.meta[1]
        d = int(math.prod(payload.shape))
        nb = -(-d // block)
        out = jnp.zeros((nb, block), payload.values.dtype)
        # .add (not .set): pad rows and ppermute-zeroed payloads carry
        # zero values at possibly colliding indices — adding is a no-op.
        out = out.at[payload.indices].add(payload.values)
        return out.reshape(-1)[:d].reshape(payload.shape)

    def wire_elements(self, shape, node=None) -> int:
        d = int(math.prod(shape))
        nb = -(-d // self.block)
        kb = sparsifier.num_kept(nb, self._p_static(node))
        return min(kb * self.block, d)   # pad coords are never payload

    def wire_bits(self, shape, *, value_bits=32, index_sync=False,
                  node=None) -> int:
        d = int(math.prod(shape))
        nb = -(-d // self.block)
        kb = sparsifier.num_kept(nb, self._p_static(node))
        bits = min(kb * self.block, d) * value_bits
        if not index_sync:
            bits += kb * index_bits(nb)
        return bits

    def coord_sensitivity_transfer(self, beta, shape):
        # kept blocks are rescaled by nb/kb; min-p (fewest kept blocks)
        # maximizes the rescale. Distinct top-k indices mean scatter-add
        # never stacks two kept blocks on one coordinate.
        d = int(math.prod(shape))
        nb = -(-d // self.block)
        kb = sparsifier.num_kept(nb, self.p_min)
        return beta * nb / kb


@dataclasses.dataclass(frozen=True)
class RowsCompressor(Compressor):
    """Fixed-k over trailing-dim rows: blocks = whole rows of the leaf.

    Equivalent to ``FixedKCompressor(block=leaf.shape[-1])`` per leaf,
    but resolved from each leaf's own shape so every packed row keeps the
    leaf's model-axis sharding (the production fixedk_rows mode).
    """

    name: str = dataclasses.field(default="rows", init=False, repr=False)

    def _rows_cols(self, shape: Tuple[int, ...]) -> Tuple[int, int]:
        d = int(math.prod(shape))
        cols = shape[-1] if len(shape) > 1 else 1
        return d // cols, cols

    def compress(self, key, x, *, node=None) -> Payload:
        rows, cols = self._rows_cols(tuple(x.shape))
        xb = x.reshape(rows, cols)
        if isinstance(self.p, tuple):
            raise ValueError("rows compressor does not support per-node p "
                             "(use fixedk/block for pad-to-max-k payloads)")
        kb = sparsifier.num_kept(rows, self.p)
        idx = sparsifier.fixedk_indices(key, rows, kb)
        vals = jnp.take(xb, idx, axis=0) * (rows / kb)
        return Payload(values=vals.astype(xb.dtype), indices=idx,
                       shape=tuple(x.shape), meta=("rows",))

    def decompress(self, payload: Payload) -> jax.Array:
        rows, cols = self._rows_cols(payload.shape)
        out = jnp.zeros((rows, cols), payload.values.dtype)
        out = out.at[payload.indices].add(payload.values)
        return out.reshape(payload.shape)

    def wire_elements(self, shape, node=None) -> int:
        rows, cols = self._rows_cols(tuple(shape))
        return sparsifier.num_kept(rows, self._p_static(node)) * cols

    def wire_bits(self, shape, *, value_bits=32, index_sync=False,
                  node=None) -> int:
        rows, cols = self._rows_cols(tuple(shape))
        kb = sparsifier.num_kept(rows, self._p_static(node))
        bits = kb * cols * value_bits
        if not index_sync:
            bits += kb * index_bits(rows)
        return bits

    def coord_sensitivity_transfer(self, beta, shape):
        rows, _ = self._rows_cols(tuple(shape))
        kb = sparsifier.num_kept(rows, self.p_min)
        return beta * rows / kb


# ==========================================================================
# QSGD-style stochastic quantizer (second compressor family).
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class QSGDCompressor(Compressor):
    """Q(x): per-leaf l2 norm + stochastic b-bit levels (sign-magnitude).

    With s = 2^(b-1) - 1 levels, coordinate x_i maps to
    ``sign(x_i) * round_stoch(|x_i| * s / ||x||)``, and decompresses to
    ``||x|| / s * q_i`` — unbiased (E[Q(x)] = x), like the Bernoulli
    sparsifier, so it slots behind the same interface. Every coordinate
    ships (release probability 1 for the accountant) but at b value bits
    instead of 32.

    Wire realization: b = 8 ships int8 (a 4x byte cut in HLO); SUB-BYTE
    levels (b in {2, 4}) are offset-encoded (level + s, in [0, 2s] <
    2^b) and PACKED 8/b per uint8 lane, so the HLO payload bytes
    actually shrink to ceil(d * b / 8) — the accounting's exact-b-bits
    charge is realized on the wire, closing ROADMAP's sub-byte item.
    Odd widths (3/5/6/7) keep the unpacked int8 payload. ``p`` is
    unused by the mechanism and kept only so quantizers share the
    registry construction path.
    """

    bits: int = 8
    name: str = dataclasses.field(default="qsgd", init=False, repr=False)

    def __post_init__(self) -> None:
        super().__post_init__()
        if not 2 <= self.bits <= 8:
            raise ValueError("qsgd bits must be in [2, 8] (int8 wire)")

    @property
    def levels(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def pack_factor(self) -> int:
        """Levels per uint8 wire lane (1 = unpacked int8)."""
        return 8 // self.bits if self.bits in (2, 4) else 1

    @property
    def release_probability(self):
        return 1.0   # every coordinate is released at every step

    def compress(self, key, x, *, node=None) -> Payload:
        s = float(self.levels)
        xf = x.astype(jnp.float32)
        norm = jnp.sqrt(jnp.sum(jnp.square(xf)))
        ratio = jnp.abs(xf) * (s / jnp.maximum(norm, 1e-30))
        level = jnp.floor(ratio)
        frac = ratio - level
        level = level + (jax.random.uniform(key, x.shape) < frac)
        q = (jnp.sign(xf) * jnp.minimum(level, s)).astype(jnp.int32)
        k = self.pack_factor
        if k == 1:
            return Payload(values=q.astype(jnp.int8), scale=norm,
                           shape=tuple(x.shape), meta=("qsgd", self.bits))
        # offset-encode to [0, 2s] (< 2^bits) and pack k levels per u8.
        off = (q + int(s)).reshape(-1)
        pad = (-off.shape[0]) % k
        if pad:
            off = jnp.pad(off, (0, pad))
        groups = off.reshape(-1, k)
        byte = jnp.zeros((groups.shape[0],), jnp.int32)
        for j in range(k):
            byte = byte | (groups[:, j] << (j * self.bits))
        return Payload(values=byte.astype(jnp.uint8), scale=norm,
                       shape=tuple(x.shape),
                       meta=("qsgd", self.bits, "u8pack"))

    def decompress(self, payload: Payload) -> jax.Array:
        bits = payload.meta[1]
        s = float(2 ** (bits - 1) - 1)
        if len(payload.meta) > 2 and payload.meta[2] == "u8pack":
            k = 8 // bits
            mask = (1 << bits) - 1
            v = payload.values.astype(jnp.int32)          # (m,) bytes
            parts = [(v >> (j * bits)) & mask for j in range(k)]
            d = int(math.prod(payload.shape))
            flat = jnp.stack(parts, axis=1).reshape(-1)[:d] - int(s)
            q = flat.reshape(payload.shape).astype(jnp.float32)
        else:
            q = payload.values.astype(jnp.float32)
        return (payload.scale / s) * q

    def wire_elements(self, shape, node=None) -> int:
        return int(math.prod(shape))   # every coordinate ships

    def wire_bits(self, shape, *, value_bits=32, index_sync=False,
                  node=None) -> int:
        del value_bits, index_sync   # quantized values, no index channel
        d = int(math.prod(shape))
        if self.pack_factor > 1:     # u8-packed lanes: exact wire bytes
            return -(-d // self.pack_factor) * 8 + 32   # + the norm scalar
        return d * self.bits + 32

    def coord_sensitivity_transfer(self, beta, shape):
        # a decompressed coordinate is (||x||/s) * q with |q| <= s, so it
        # is bounded by the leaf l2 norm <= beta * sqrt(d): the quantizer
        # can concentrate the whole norm budget on one coordinate.
        return beta * math.sqrt(int(math.prod(shape)))


@dataclasses.dataclass(frozen=True)
class FusedQSGDCompressor(QSGDCompressor):
    """QSGD with the quantize+pack chain fused into ONE pallas launch
    and the norm EMBEDDED in the byte payload (single wire leaf).

    Same mechanism, same bits-on-the-wire accounting as ``qsgd`` — the
    stochastic levels are BIT-IDENTICAL (uniforms drawn at the canonical
    plane shape outside the kernel; see kernels/wire_compress) — but the
    wire format changes in two launch-count-relevant ways:

    * the multi-kernel XLA quantize/offset/shift-or chain collapses into
      one ``kernels.wire_compress.qsgd_pack`` pallas call per plane;
    * the f32 norm rides as 4 bitcast bytes appended to the value
      buffer, so the payload is ONE u8 leaf instead of (values, scale) —
      halving collective-permutes per gossip round. ``wire_bits`` is
      inherited unchanged: ceil(d/k)*8 + 32 packed (k = 8/bits) and
      d*8 + 32 for bits=8 are exactly the single-buffer byte count.

    bits=8 consequently ships OFFSET-encoded u8 (q + s) rather than
    int8; roundtrip values stay bit-identical to ``qsgd:8``. Odd widths
    have no exact byte image, hence bits in {2, 4, 8} only.
    """

    name: str = dataclasses.field(default="qsgdf", init=False, repr=False)

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.bits not in (2, 4, 8):
            raise ValueError(
                "qsgdf bits must be in {2, 4, 8}: the fused single-buffer "
                "format needs an exact byte image")

    def compress(self, key, x, *, node=None) -> Payload:
        from repro.kernels import wire_compress   # lazy: core -> kernels
        xf = x.astype(jnp.float32)
        norm = jnp.sqrt(jnp.sum(jnp.square(xf)))
        u = jax.random.uniform(key, x.shape)   # canonical-shape draw
        data = wire_compress.qsgd_pack(xf, u, norm, bits=self.bits)
        tail = jax.lax.bitcast_convert_type(norm, jnp.uint8)   # (4,) bytes
        return Payload(values=jnp.concatenate([data, tail]),
                       shape=tuple(x.shape), meta=("qsgdf", self.bits))

    def decompress(self, payload: Payload) -> jax.Array:
        bits = payload.meta[1]
        s = float(2 ** (bits - 1) - 1)
        v = payload.values
        # scalar-indexed little-endian reassembly of the f32 norm: stays
        # an elementwise graph the consumers can fuse (a (4,)u8 -> f32
        # bitcast lowers to its own reduce-style fusion on CPU).
        w32 = sum(v[i - 4].astype(jnp.uint32) << (8 * (i))
                  for i in range(4))
        norm = jax.lax.bitcast_convert_type(w32, jnp.float32)
        d = int(math.prod(payload.shape))
        k = 8 // bits if bits in (2, 4) else 1
        shape = payload.shape
        if k == 1:
            q = (v[:d].astype(jnp.int32) - int(s)).reshape(shape)
        elif len(shape) == 2 and shape[-1] % k == 0:
            # lane-aligned planes (the wire transport's only shape):
            # unpack AT the output shape via a broadcast shift — element
            # (r, c) is byte (r, c//k) >> ((c % k) * bits). Fuses into
            # one loop fusion with the scale multiply, unlike the
            # stack/reshape/slice chain of the generic path.
            rows, cols = shape
            b2 = v[:d // k].astype(jnp.int32).reshape(rows, cols // k)
            sh = jnp.asarray((np.arange(cols) % k) * bits, jnp.int32)
            rep = jnp.broadcast_to(b2[:, :, None],
                                   (rows, cols // k, k)).reshape(rows, cols)
            q = ((rep >> sh[None, :]) & ((1 << bits) - 1)) - int(s)
        else:
            mask = (1 << bits) - 1
            data = v[:-4].astype(jnp.int32)
            parts = [(data >> (j * bits)) & mask for j in range(k)]
            q = (jnp.stack(parts, axis=1).reshape(-1)[:d]
                 - int(s)).reshape(shape)
        return (norm / s) * q.astype(jnp.float32)


# ==========================================================================
# Registry + CLI spec parsing.
# ==========================================================================

_FAMILIES: Dict[str, Callable[..., Compressor]] = {}


def register(family: str, factory: Callable[..., Compressor]) -> None:
    """Register a compressor family under ``family`` (spec prefix)."""
    _FAMILIES[family] = factory


def names() -> Tuple[str, ...]:
    return tuple(sorted(_FAMILIES))


register("bernoulli", lambda p, arg=None: BernoulliCompressor(p=p))
register("fixedk", lambda p, arg=None: FixedKCompressor(
    p=p, block=int(arg) if arg else 1))
register("block", lambda p, arg=None: FixedKCompressor(
    p=p, block=int(arg) if arg else 128))
register("rows", lambda p, arg=None: RowsCompressor(p=p))
register("qsgd", lambda p, arg=None: QSGDCompressor(
    p=p, bits=int(arg) if arg else 8))
register("qsgdf", lambda p, arg=None: FusedQSGDCompressor(
    p=p, bits=int(arg) if arg else 4))


def make(spec: str, p: "float | Tuple[float, ...]" = 0.2) -> Compressor:
    """Parse a CLI compressor spec: ``family`` or ``family:<arg>``.

    ``bernoulli`` | ``fixedk`` | ``fixedk:<block>`` | ``block:<B>`` |
    ``rows`` | ``qsgd:<bits>``. ``p`` is the transmit budget (scalar or
    per-node tuple) for the sparsifying families.
    """
    spec = spec.strip().lower()
    family, _, arg = spec.partition(":")
    if family not in _FAMILIES:
        raise ValueError(
            f"unknown compressor {spec!r}; registered: {', '.join(names())}")
    return _FAMILIES[family](p, arg or None)


# ==========================================================================
# Tree-level accounting helpers.
# ==========================================================================

def node_mean_exact(p, per_node_fn) -> "Fraction | float":
    """Across-node EXACT mean of per-node accounting expectations.

    The het-p Fig-3 convention (network total = mean * n_nodes), shared
    by SDM and push-sum accounting: with a per-node ``p`` tuple the mean
    is taken over the UNrounded per-node values so the caller can fold
    in further exact factors (schedule degree) and round ONCE — a
    per-node round followed by a rounded mean can drift +-1 element from
    the tree-level round(p * d_total) convention. Scalar ``p`` calls
    ``per_node_fn(None)`` straight through.
    """
    if isinstance(p, tuple):
        vals = [per_node_fn(i) for i in range(len(p))]
        return sum(vals) / len(vals)
    return per_node_fn(None)


def tree_wire_elements_exact(comp: Compressor, params,
                             node: int | None = None) -> "Fraction | float":
    """UNrounded informative elements per step over a pytree.

    Fractional expectations (bernoulli) sum EXACTLY across leaves
    (Fractions); callers fold in any further exact factors (across-node
    het-p means, per-link schedule degree) before rounding ONCE.
    """
    return sum(comp.wire_elements_exact(tuple(x.shape), node=node)
               for x in jax.tree.leaves(params))


def tree_wire_elements(comp: Compressor, params, node: int | None = None
                       ) -> int:
    """Informative elements one node transmits per step over a pytree.

    Rounds the exact sum once — round(p * d_total), the paper's Fig-3
    convention — while packed/quantized counts are already integers.
    """
    return int(round(tree_wire_elements_exact(comp, params, node=node)))


def tree_wire_bits_exact(comp: Compressor, params, *, value_bits: int = 32,
                         index_sync: bool = False,
                         node: int | None = None) -> "Fraction | float":
    """UNrounded wire bits per step over a pytree (see elements variant)."""
    return sum(
        comp.wire_bits_exact(tuple(x.shape), value_bits=value_bits,
                             index_sync=index_sync, node=node)
        for x in jax.tree.leaves(params))


def tree_wire_bits(comp: Compressor, params, *, value_bits: int = 32,
                   index_sync: bool = False, node: int | None = None) -> int:
    """Exact wire bits one node transmits per step over a pytree."""
    return int(round(tree_wire_bits_exact(comp, params,
                                          value_bits=value_bits,
                                          index_sync=index_sync, node=node)))
