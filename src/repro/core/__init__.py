"""SDM-DSGD core: the paper's contribution as composable JAX modules."""
from repro.core.sdm_dsgd import (SDMConfig, SDMState, ReferenceSimulator,
                                 init_distributed_state, distributed_advance,
                                 distributed_commit,
                                 transmitted_elements_per_step)
from repro.core.baselines import (DSGDConfig, DSGDReference, dcdsgd_config,
                                  dsgd_distributed_step)
from repro.core.gossip import PermuteSchedule, schedule_from_topology
from repro.core.privacy import (PrivacyParams, PrivacyAccountant, epsilon_sdm,
                                epsilon_alternative, sigma_for_budget,
                                max_iterations, SIGMA_SQ_MIN)
from repro.core import topology, theory, sparsifier, gossip, clipping

__all__ = [
    "SDMConfig", "SDMState", "ReferenceSimulator", "init_distributed_state",
    "distributed_advance", "distributed_commit",
    "transmitted_elements_per_step", "DSGDConfig", "DSGDReference",
    "dcdsgd_config", "dsgd_distributed_step", "PermuteSchedule",
    "schedule_from_topology", "PrivacyParams",
    "PrivacyAccountant", "epsilon_sdm", "epsilon_alternative",
    "sigma_for_budget", "max_iterations", "SIGMA_SQ_MIN", "topology",
    "theory", "sparsifier", "gossip", "clipping",
]
