"""SDM-DSGD core: the paper's contribution as composable JAX modules.

``repro.core.method`` is the unified algorithm surface: a string
registry of Method objects, each carrying its own config dataclass and
both a stacked reference executor and a shard_map distributed executor
built from the same (possibly time-varying) gossip schedule.
"""
from repro.core.sdm_dsgd import (SDMConfig, SDMState, ReferenceSimulator,
                                 init_distributed_state, distributed_advance,
                                 distributed_commit, masked_grad,
                                 compressor_of,
                                 transmitted_elements_per_step,
                                 transmitted_bits_per_step)
from repro.core.baselines import (DSGDConfig, DSGDReference, dcdsgd_config,
                                  dsgd_distributed_step)
from repro.core.gradient_push import (GradientPushConfig, GradientPushState,
                                      GradientPushReference)
from repro.core.gossip import (PermuteSchedule, ScheduleSequence,
                               schedule_from_topology, sequence_by_name,
                               sequence_from_topologies)
from repro.core.privacy import (PrivacyParams, PrivacyAccountant, epsilon_sdm,
                                epsilon_alternative, sigma_for_budget,
                                max_iterations, SIGMA_SQ_MIN)
from repro.core import (topology, theory, sparsifier, gossip, clipping,
                        compressor, method, plane)

__all__ = [
    "SDMConfig", "SDMState", "ReferenceSimulator", "init_distributed_state",
    "distributed_advance", "distributed_commit", "masked_grad",
    "compressor_of", "transmitted_elements_per_step",
    "transmitted_bits_per_step", "DSGDConfig", "DSGDReference",
    "dcdsgd_config", "dsgd_distributed_step", "GradientPushConfig",
    "GradientPushState", "GradientPushReference", "PermuteSchedule",
    "ScheduleSequence", "schedule_from_topology", "sequence_by_name",
    "sequence_from_topologies", "PrivacyParams",
    "PrivacyAccountant", "epsilon_sdm", "epsilon_alternative",
    "sigma_for_budget", "max_iterations", "SIGMA_SQ_MIN", "topology",
    "theory", "sparsifier", "gossip", "clipping", "compressor", "method",
    "plane",
]
