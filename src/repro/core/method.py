"""Unified pluggable ``Method`` API: one registry for every algorithm.

The repo grew three divergent algorithm surfaces — ``ReferenceSimulator``,
the ``distributed_advance/commit/step_fused`` free functions, and the
``DSGDReference``/``dsgd_distributed_step`` baseline path — and every
caller (trainer, train steps, dryrun, benchmarks) wired them differently.
This module collapses them behind one protocol:

    meth = method.get("sdm-dsgd")           # registry lookup (aliases ok)
    cfg  = meth.coerce_config(cfg_like)     # each method owns its config
    sim  = meth.make_reference(seq, cfg)    # stacked single-host executor
    ex   = meth.make_distributed(seq, cfg, axis_name)   # shard_map executor

Both executors are built from the SAME schedule object (a
``gossip.ScheduleSequence`` — static graphs are the length-1 case,
time-varying B-connected sequences index by the traced step counter), so
their mixing matrices can never diverge, and reference-vs-distributed
parity is testable uniformly across methods x topologies.

Reference executors (stacked, leading node axis) expose::

    init(params_stack) -> state
    step(state, grad_fn, batch_stack, key) -> (state, aux)
    consensus(state) -> tree          # the method's consensus estimate
    eval_params(state) -> tree        # per-node params evaluation runs on

(SDM-style methods additionally expose advance/commit — the two phases
of Algorithm 1 — which ``step`` composes.)

Distributed executors run INSIDE ``jax.shard_map`` with the node axis
manual and expose::

    init(params, me) -> state                         # per-node state
    step(state, grads_at, *, base_key, node_index) -> (state, aux)

``grads_at(params) -> (grads, aux)`` lets each method pick WHERE the
gradient is evaluated (post-advance x for SDM-DSGD, the de-biased
z = x / w for gradient-push, ...).

Registered methods:

    sdm-dsgd        the paper's Algorithm 1 (3-buffer x/s/d state)
    sdm-dsgd-fused  same algorithm, commit+advance fused (2 buffers)
    dc-dsgd         derived from sdm-dsgd with theta pinned to 1
    dsgd            full-state gossip baseline (noise/clip shared via
                    ``masked_grad`` — the old as_sdm shim is gone)
    gradient-push   push-sum over DIRECTED column-stochastic graphs
    allreduce       conventional data parallelism (non-gossip bound)

Adding a method = one ``Method(...)`` + ``register(...)`` call; the
train step factory, trainer, dryrun, CLI ``--method`` axis, and the
parity test sweep pick it up automatically.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (baselines, compressor as compressor_mod, gossip,
                        gradient_push, plane as plane_mod, sdm_dsgd)

__all__ = ["Method", "DistributedExecutor", "register", "get", "names",
           "normalize", "PARAM", "SCALAR", "COUNTER", "PLANE", "REPLICA",
           "state_fields_of", "state_shape_dtype", "state_shardings",
           "transmitted_elements", "transmitted_bits",
           "stale_capable", "withhold_differential", "defer_differential",
           "select_node_rows"]

PyTree = Any

# State-field kinds: drive the generic ShapeDtypeStruct / sharding
# builders in train.steps without per-method special cases.
PARAM = "param"      # shaped like the parameter tree
SCALAR = "scalar"    # one f32 per node
COUNTER = "counter"  # one i32 per node (the iteration counter)
PLANE = "plane"      # wire-plane buffers (repro.core.plane): a tuple of
#                      f32 (rows, LANE) planes per node — one per
#                      sharding bucket — stacked to (n, rows, LANE).
#                      What the distributed executors carry for the
#                      neighbour sum s, the differential d, and the
#                      compressed push public copy xhat.
REPLICA = "replica"  # per-neighbour public-copy stack: each wire PLANE
#                      gains a leading (n_replicas,) axis (replicated on
#                      the mesh; the node axis still shards dim 0 of the
#                      stacked state). Memory cost: deg_union x model per
#                      node — the price of exact W(t)-mixing on genuinely
#                      time-varying schedules.


@dataclasses.dataclass(frozen=True)
class DistributedExecutor:
    """The per-node (inside shard_map) face of a method."""

    init: Callable[[PyTree, Any], Any]          # (params, me) -> state
    step: Callable[..., Tuple[Any, Any]]        # (state, grads_at, *, base_key, node_index)


@dataclasses.dataclass(frozen=True)
class Method:
    """A registered decentralized-learning method (see module docstring)."""

    name: str
    config_cls: type
    state_cls: type
    state_fields: Tuple[Tuple[str, str], ...]
    coerce_config: Callable[[Any], Any]
    make_reference: Callable[[Any, Any], Any]
    make_distributed: Callable[[gossip.ScheduleSequence, Any, Any],
                               DistributedExecutor]
    init_stacked: Callable[[PyTree, gossip.ScheduleSequence, Any], Any]
    # (params, cfg, seq=None) -> int; ``seq`` makes the count per-link
    # schedule-aware (mean out-degree over the sequence's rounds).
    transmitted_elements: Callable[..., int]
    directed: bool = False       # meaningful on directed (push) graphs
    description: str = ""
    # Optional (config, schedule)-dependent state layout (compressed
    # gradient-push adds xhat/s buffers; genuinely time-varying schedules
    # add the REPLICA stack); None means ``state_fields`` always.
    state_fields_for: \
        "Callable[[Any, Any], Tuple[Tuple[str, str], ...]] | None" = None
    # Optional exact wire-bit accounting (params, cfg, seq=None) -> int;
    # None falls back to transmitted_elements * value_bits (full-precision
    # dense payloads).
    transmitted_bits_fn: "Callable[..., int] | None" = None


_REGISTRY: Dict[str, Method] = {}

_ALIASES = {
    "dcdsgd": "dc-dsgd",
    "push-sum": "gradient-push",
    "sgp": "gradient-push",
    "all-reduce": "allreduce",
}


def normalize(name: str) -> str:
    """Canonical registry key: lower-case, '_' -> '-', aliases resolved."""
    key = name.strip().lower().replace("_", "-")
    return _ALIASES.get(key, key)


def register(meth: Method) -> Method:
    _REGISTRY[meth.name] = meth
    return meth


def get(name: str) -> Method:
    key = normalize(name)
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown method {name!r}; registered: {', '.join(names())}")
    return _REGISTRY[key]


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# Generic state-template builders (used by train.steps and launch.dryrun).
# --------------------------------------------------------------------------

def state_fields_of(meth: Method, cfg=None,
                    seq=None) -> Tuple[Tuple[str, str], ...]:
    """The method's state layout, possibly config/schedule-dependent.

    Compressed gradient-push carries two extra PARAM buffers (public
    copy + incremental neighbour sum) only when a compressor is
    configured; genuinely time-varying schedules additionally grow a
    REPLICA stack (per-neighbour public copies — see the REPLICA kind).
    ``cfg=None`` keeps the static default layout.
    """
    if meth.state_fields_for is not None and cfg is not None:
        return meth.state_fields_for(cfg, seq)
    return meth.state_fields


def transmitted_elements(meth: Method, params: PyTree, cfg, seq=None) -> int:
    """Elements one node transmits per step, per-link when ``seq`` given.

    With a schedule the count multiplies by the mean out-degree over the
    sequence's rounds (2 for the static ring, 1 for perfect-matching
    rounds, the union-graph degree on the replica transport) — matching
    what the compiled ppermute rounds actually move. ``seq=None`` keeps
    the legacy one-payload-per-step convention.
    """
    return meth.transmitted_elements(params, cfg, seq=seq)


def transmitted_bits(meth: Method, params: PyTree, cfg,
                     value_bits: int = 32, seq=None) -> int:
    """Exact wire bits one node transmits per step (Fig-3's honest axis).

    Methods without a registered bits accountant ship full-precision
    dense payloads: elements * value_bits. Same per-link ``seq``
    convention as ``transmitted_elements``.
    """
    if meth.transmitted_bits_fn is not None:
        return meth.transmitted_bits_fn(params, cfg, value_bits=value_bits,
                                        seq=seq)
    return meth.transmitted_elements(params, cfg, seq=seq) * value_bits


# --------------------------------------------------------------------------
# Stale-gossip (straggler) semantics over the stacked reference executors.
# --------------------------------------------------------------------------
#
# The edge-fleet simulator (repro.sim) needs one-step-stale delivery: a
# node that misses the round deadline transmits NOTHING this round, so its
# neighbours mix with its one-step-stale public copy, and the withheld
# update goes out (merged) next round. Differential methods encode the
# pending transmission explicitly — the accumulator ``d`` whose sparsified
# S(d) is the wire payload — so staleness is exact state surgery: zero a
# straggler's d before the step (S(0) = 0 crosses the wire; its public
# copies everywhere stay put) and add the withheld d back afterwards (the
# differential is late, never lost — Σ of transmitted increments is
# preserved). Methods that transmit ABSOLUTE state (dsgd, gradient-push,
# allreduce) have no pending-payload buffer to defer; for them stragglers
# degrade to round non-participation (the masked-subgraph path).

def stale_capable(meth: Method) -> bool:
    """Whether one-step-stale straggler semantics are exact for ``meth``.

    True iff the method's wire payload is a deferred differential (a
    ``d`` accumulator in its state) rather than absolute state.
    """
    return any(fname == "d" for fname, _ in meth.state_fields)


def withhold_differential(meth: Method, state, send_mask):
    """Suppress the outgoing payload of masked-out nodes for one step.

    ``send_mask`` is a (n,) bool vector — True where the node makes the
    round deadline. Returns ``(state', withheld)``: straggler rows of the
    differential zeroed (so the sparsifier transmits exactly nothing for
    them), plus the withheld rows to merge back via
    ``defer_differential`` after the step.
    """
    if not stale_capable(meth):
        raise ValueError(
            f"{meth.name} transmits absolute state — no differential to "
            "defer; treat stragglers as non-participants instead")
    mask = jnp.asarray(send_mask, bool)
    d = state.d
    bcast = lambda v: mask.reshape((mask.shape[0],) + (1,) * (v.ndim - 1))
    masked = jax.tree.map(lambda v: jnp.where(bcast(v), v, 0), d)
    withheld = jax.tree.map(lambda v: jnp.where(bcast(v), 0, v), d)
    return state._replace(d=masked), withheld


def defer_differential(meth: Method, state, withheld):
    """Merge a withheld differential back: it transmits next round."""
    return state._replace(
        d=jax.tree.map(jnp.add, state.d, withheld))


def select_node_rows(active_mask, on_state, off_state):
    """Per-node row select across every state leaf (freeze semantics).

    Node i's slice comes from ``on_state`` where ``active_mask[i]`` and
    from ``off_state`` (its pre-round state — the node did nothing)
    otherwise. Leaves without a leading node axis (the shared scalar
    step counter) advance with the round unconditionally.
    """
    mask = jnp.asarray(active_mask, bool)
    n = mask.shape[0]

    def pick(on, off):
        if getattr(on, "ndim", 0) >= 1 and on.shape[0] == n:
            return jnp.where(mask.reshape((n,) + (1,) * (on.ndim - 1)),
                             on, off)
        return on

    return jax.tree.map(pick, on_state, off_state)


def _n_replicas(seq) -> int:
    return gossip.union_schedule(gossip.ensure_sequence(seq)).n_replicas


def _plane_spec_stacked(x_stack: PyTree) -> plane_mod.ParamPlane:
    """Wire-plane layout of the per-node parameter tree (leading axis
    stripped). Bucket keys come from the ``plane.use_buckets`` context —
    callers (train.steps) install it around templates AND tracing so the
    layouts can never diverge."""
    return plane_mod.ParamPlane.for_stacked(x_stack)


def state_shape_dtype(meth: Method, x_stack: PyTree, cfg=None, seq=None):
    """Stacked-state ShapeDtypeStructs from the stacked params template.

    PLANE fields are tuples of (n, rows, lane) f32 planes (one per
    sharding bucket); REPLICA fields additionally need the schedule:
    each plane grows to (n, n_replicas, rows, lane), one slot per
    union-graph round.
    """
    n = jax.tree.leaves(x_stack)[0].shape[0]
    spec = _plane_spec_stacked(x_stack)
    kw = {}
    for fname, kind in state_fields_of(meth, cfg, seq):
        if kind == PARAM:
            kw[fname] = x_stack
        elif kind == PLANE:
            kw[fname] = tuple(
                jax.ShapeDtypeStruct((n,) + b.shape, jnp.float32)
                for b in spec.buckets)
        elif kind == REPLICA:
            r = _n_replicas(seq)
            kw[fname] = tuple(
                jax.ShapeDtypeStruct((n, r) + b.shape, jnp.float32)
                for b in spec.buckets)
        elif kind == SCALAR:
            kw[fname] = jax.ShapeDtypeStruct((n,), jnp.float32)
        else:
            kw[fname] = jax.ShapeDtypeStruct((n,), jnp.int32)
    return meth.state_cls(**kw)


def _plane_sharding(mesh, lead, bucket: plane_mod.PlaneBucket,
                    n_lead_axes: int = 1) -> NamedSharding:
    """Stacked plane sharding: node axis on dim 0, bucket mesh axis (if
    any — TP buckets carry ``(mesh_axis, cols)`` keys) on the lane dim,
    everything else replicated. ``n_lead_axes=2`` inserts the replicated
    replica axis."""
    mid = (None,) * n_lead_axes
    lane_axis = bucket.key[0] if bucket.key is not None else None
    return NamedSharding(mesh, P(lead, *mid[1:], None, lane_axis))


def state_shardings(meth: Method, x_shardings: PyTree, node_vec_sharding,
                    cfg=None, seq=None, template: PyTree = None):
    """Stacked-state NamedShardings from the params-tree shardings.

    ``template`` is the stacked params ShapeDtype tree — required to
    derive the plane layout for PLANE/REPLICA fields (shardings alone
    carry no shapes). Methods without plane state may omit it; a
    plane-state method with no template raises.
    """
    mesh = node_vec_sharding.mesh
    lead = tuple(node_vec_sharding.spec)[0] \
        if tuple(node_vec_sharding.spec) else None
    spec = _plane_spec_stacked(template) if template is not None else None
    kw = {}
    for fname, kind in state_fields_of(meth, cfg, seq):
        if kind in (PLANE, REPLICA) and spec is None:
            raise ValueError(
                f"state_shardings: field {fname!r} of {meth.name} is "
                "plane-shaped; pass template= (the stacked params "
                "ShapeDtype tree) so the plane layout can be derived")
        if kind == PARAM:
            kw[fname] = x_shardings
        elif kind == PLANE:
            kw[fname] = tuple(_plane_sharding(mesh, lead, b)
                              for b in spec.buckets)
        elif kind == REPLICA:
            kw[fname] = tuple(_plane_sharding(mesh, lead, b, n_lead_axes=2)
                              for b in spec.buckets)
        else:
            kw[fname] = node_vec_sharding
    return meth.state_cls(**kw)


def _stacked_counter(n: int) -> jax.Array:
    return jnp.zeros((n,), jnp.int32)


# --------------------------------------------------------------------------
# SDM-DSGD (and its derivations: fused layout, DC-DSGD).
# --------------------------------------------------------------------------

def _coerce_sdm(cfg) -> sdm_dsgd.SDMConfig:
    if isinstance(cfg, sdm_dsgd.SDMConfig):
        return cfg
    raise TypeError(f"sdm-dsgd needs an SDMConfig, got {type(cfg).__name__}")


def _sdm_fields(cfg, seq=None) -> Tuple[Tuple[str, str], ...]:
    if seq is not None and gossip.needs_replicas(seq):
        return _SDM_FIELDS + (("xhat", REPLICA),)
    if cfg is not None and getattr(cfg, "overlap", False):
        # overlapped transport: pending-received double buffer (one-step
        # -stale neighbour increments, consumed by the NEXT advance).
        return _SDM_FIELDS + (("nb", PLANE),)
    return _SDM_FIELDS


def _fused_fields(cfg, seq=None) -> Tuple[Tuple[str, str], ...]:
    base = (("x", PARAM), ("s", PLANE), ("step", COUNTER))
    if seq is not None and gossip.needs_replicas(seq):
        return base + (("xhat", REPLICA),)
    if cfg is not None and getattr(cfg, "overlap", False):
        return base + (("nb", PLANE),)
    return base


def _stacked_plane_replicas(planes, seq) -> Tuple[jax.Array, ...]:
    """(n, n_replicas, rows, lane) replica planes, every slot at x_0."""
    r = _n_replicas(seq)
    return tuple(
        jnp.broadcast_to(p[:, None], (p.shape[0], r) + p.shape[1:])
        for p in planes)


def _sdm_init_stacked(stack: PyTree, seq: gossip.ScheduleSequence, cfg
                      ) -> sdm_dsgd.SDMState:
    n = jax.tree.leaves(stack)[0].shape[0]
    sw = np.asarray(seq.schedules[0].self_weights, np.float32)
    xp = _plane_spec_stacked(stack).pack_stacked(stack)
    w = jnp.asarray((1.0 - sw).reshape((n, 1, 1)), jnp.float32)
    s = tuple(w * p for p in xp)
    xhat = _stacked_plane_replicas(xp, seq) if gossip.needs_replicas(seq) \
        else None
    nb = tuple(jnp.zeros_like(p) for p in xp) \
        if getattr(cfg, "overlap", False) else None
    return sdm_dsgd.SDMState(
        x=stack, s=s, d=tuple(jnp.zeros_like(p) for p in xp),
        step=_stacked_counter(n), xhat=xhat, nb=nb)


def _sdm_distributed(seq: gossip.ScheduleSequence, cfg, axis_name
                     ) -> DistributedExecutor:
    n_rep = _n_replicas(seq) if gossip.needs_replicas(seq) else None

    def init(params, me):
        return sdm_dsgd.init_distributed_state(
            params, seq.self_weight_of(me, 0), n_replicas=n_rep,
            overlap=getattr(cfg, "overlap", False))

    def step(state, grads_at, *, base_key, node_index=None):
        state = sdm_dsgd.distributed_advance(
            state, base_key=base_key, axis_name=axis_name, cfg=cfg,
            schedule=seq, node_index=node_index)
        grads, aux = grads_at(state.x)
        state = sdm_dsgd.distributed_commit(
            state, grads, base_key=base_key, axis_name=axis_name, cfg=cfg,
            schedule=seq, node_index=node_index)
        return state, aux

    return DistributedExecutor(init=init, step=step)


def _fused_init_stacked(stack, seq, cfg) -> sdm_dsgd.SDMFusedState:
    full = _sdm_init_stacked(stack, seq, cfg)
    return sdm_dsgd.SDMFusedState(x=full.x, s=full.s, step=full.step,
                                  xhat=full.xhat, nb=full.nb)


def _fused_distributed(seq, cfg, axis_name) -> DistributedExecutor:
    n_rep = _n_replicas(seq) if gossip.needs_replicas(seq) else None

    def init(params, me):
        return sdm_dsgd.init_fused_state(params, seq.self_weight_of(me, 0),
                                         n_replicas=n_rep,
                                         overlap=getattr(cfg, "overlap",
                                                         False))

    def step(state, grads_at, *, base_key, node_index=None):
        grads, aux = grads_at(state.x)
        state = sdm_dsgd.distributed_step_fused(
            state, grads, base_key=base_key, axis_name=axis_name, cfg=cfg,
            schedule=seq, node_index=node_index)
        return state, aux

    return DistributedExecutor(init=init, step=step)


# --------------------------------------------------------------------------
# DSGD (full-state baseline) and allreduce (non-gossip upper bound).
# --------------------------------------------------------------------------

def _coerce_dsgd(cfg) -> baselines.DSGDConfig:
    if isinstance(cfg, baselines.DSGDConfig):
        return cfg
    if isinstance(cfg, sdm_dsgd.SDMConfig):
        # The single conversion point (sparsity disabled): replaces the
        # old per-callsite DSGDConfig.as_sdm shim.
        return baselines.DSGDConfig(gamma=cfg.gamma, sigma=cfg.sigma,
                                    clip_c=cfg.clip_c)
    raise TypeError(f"dsgd needs DSGDConfig/SDMConfig, got {type(cfg).__name__}")


def _dsgd_init_stacked(stack, seq, cfg) -> baselines.DSGDState:
    n = jax.tree.leaves(stack)[0].shape[0]
    return baselines.DSGDState(x=stack, step=_stacked_counter(n))


def _dsgd_distributed(seq, cfg, axis_name) -> DistributedExecutor:
    def init(params, me):
        return baselines.DSGDState(x=params, step=jnp.zeros((), jnp.int32))

    def step(state, grads_at, *, base_key, node_index=None):
        grads, aux = grads_at(state.x)
        state = baselines.dsgd_distributed_step(
            state, grads, base_key=base_key, axis_name=axis_name, cfg=cfg,
            schedule=seq, node_index=node_index)
        return state, aux

    return DistributedExecutor(init=init, step=step)


class AllreduceReference:
    """Stacked conventional data parallelism: SGD on the mean gradient."""

    def __init__(self, topo, cfg: baselines.DSGDConfig):
        del topo  # no gossip graph
        self.cfg = cfg

    def init(self, params_stack: PyTree) -> baselines.DSGDState:
        return baselines.DSGDState(x=params_stack,
                                   step=jnp.zeros((), jnp.int32))

    def step(self, state, grad_fn, batch_stack, key):
        del key  # the non-private upper bound: no masking
        grads, aux = grad_fn(state.x, batch_stack)
        gbar = jax.tree.map(
            lambda g: jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True),
                                       g.shape), grads)
        x = jax.tree.map(
            lambda x, g: x - self.cfg.gamma * g.astype(x.dtype),
            state.x, gbar)
        return baselines.DSGDState(x=x, step=state.step + 1), aux

    def consensus_mean(self, state):
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), state.x)

    consensus = consensus_mean

    def eval_params(self, state):
        return state.x


def _allreduce_distributed(seq, cfg, axis_name) -> DistributedExecutor:
    def init(params, me):
        return baselines.DSGDState(x=params, step=jnp.zeros((), jnp.int32))

    def step(state, grads_at, *, base_key, node_index=None):
        grads, aux = grads_at(state.x)
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis_name), grads)
        x = jax.tree.map(
            lambda p, g: p - cfg.gamma * g.astype(p.dtype), state.x, grads)
        return baselines.DSGDState(x=x, step=state.step + 1), aux

    return DistributedExecutor(init=init, step=step)


# --------------------------------------------------------------------------
# Gradient-push (directed graphs, push-sum de-biasing).
# --------------------------------------------------------------------------

def _coerce_push(cfg) -> gradient_push.GradientPushConfig:
    if isinstance(cfg, gradient_push.GradientPushConfig):
        return cfg
    if isinstance(cfg, sdm_dsgd.SDMConfig):
        # An explicit compressor spec on the SDM bag carries over (the
        # --compressor CLI axis); the legacy mode= spelling does not.
        return gradient_push.GradientPushConfig(
            gamma=cfg.gamma, sigma=cfg.sigma, clip_c=cfg.clip_c,
            compressor=cfg.compressor, p=cfg.p,
            overlap=cfg.overlap and cfg.compressor is not None)
    if isinstance(cfg, baselines.DSGDConfig):
        return gradient_push.GradientPushConfig(
            gamma=cfg.gamma, sigma=cfg.sigma, clip_c=cfg.clip_c)
    raise TypeError(
        f"gradient-push needs GradientPushConfig, got {type(cfg).__name__}")


def _push_fields(cfg, seq=None) -> Tuple[Tuple[str, str], ...]:
    base = (("x", PARAM), ("w", SCALAR), ("step", COUNTER))
    if getattr(cfg, "compressor", None):
        if seq is not None and gossip.needs_replicas(seq):
            # replica path recomputes the neighbour sum fresh every step:
            # no persistent s buffer, the replica stack replaces it.
            return base + (("xhat", PLANE), ("xhat_nb", REPLICA))
        base = base + (("xhat", PLANE), ("s", PLANE))
        if getattr(cfg, "overlap", False):
            base = base + (("nb", PLANE),)
    return base


def _push_init_stacked(stack, seq, cfg) -> gradient_push.GradientPushState:
    n = jax.tree.leaves(stack)[0].shape[0]
    base = gradient_push.GradientPushState(
        x=stack, w=jnp.ones((n,), jnp.float32), step=_stacked_counter(n))
    if not getattr(cfg, "compressor", None):
        return base
    xp = _plane_spec_stacked(stack).pack_stacked(stack)
    if gossip.needs_replicas(seq):
        return base._replace(xhat=xp,
                             xhat_nb=_stacked_plane_replicas(xp, seq))
    w0 = seq.schedules[0]
    rs = jnp.asarray(w0.neighbor_weight_sums(), jnp.float32)
    s0 = tuple(rs.reshape((n, 1, 1)) * p for p in xp)
    nb = tuple(jnp.zeros_like(p) for p in xp) \
        if getattr(cfg, "overlap", False) else None
    return base._replace(xhat=xp, s=s0, nb=nb)


def _push_distributed(seq, cfg, axis_name) -> DistributedExecutor:
    n_rep = _n_replicas(seq) if (getattr(cfg, "compressor", None)
                                 and gossip.needs_replicas(seq)) else None

    def init(params, me):
        if not getattr(cfg, "compressor", None):
            return gradient_push.init_push_state(params)
        rs = jnp.asarray(seq.schedules[0].neighbor_weight_sums(),
                         jnp.float32)[me]
        return gradient_push.init_compressed_push_state(
            params, rs, n_replicas=n_rep,
            overlap=getattr(cfg, "overlap", False))

    def step(state, grads_at, *, base_key, node_index=None):
        z = gradient_push._debias(state.x, state.w)
        grads, aux = grads_at(z)
        state = gradient_push.gradient_push_distributed_step(
            state, grads, base_key=base_key, axis_name=axis_name, cfg=cfg,
            schedule=seq, node_index=node_index)
        return state, aux

    return DistributedExecutor(init=init, step=step)


def _push_degree_factors(seq, compressed: bool):
    """(payload, mass) per-link factors for push-sum accounting.

    The mass scalar always rides the current round's graph (mean
    out-degree over the L rounds); compressed payloads ride the union
    graph when the sequence genuinely varies (replica transport).
    """
    if seq is None:
        return Fraction(1), Fraction(1)
    seq = gossip.sequence_of(seq)
    mass = gossip.mean_out_degree(seq)
    payload = gossip.mean_out_degree(
        seq, union=compressed and gossip.needs_replicas(seq))
    return payload, mass


def _push_elements(params: PyTree, cfg, seq=None) -> int:
    comp = cfg.make_compressor() if hasattr(cfg, "make_compressor") else None
    payload_deg, mass_deg = _push_degree_factors(seq, comp is not None)
    if comp is None:
        return int(round(_full_state_elements(params, cfg) * payload_deg
                         + mass_deg))   # + push-sum mass w
    wire = sdm_dsgd.wire_shape_tree(params)
    payload = compressor_mod.node_mean_exact(
        comp.p, lambda i: compressor_mod.tree_wire_elements_exact(
            comp, wire, node=i))
    return int(round(payload * payload_deg + mass_deg))


def _push_bits(params: PyTree, cfg, seq=None, value_bits: int = 32) -> int:
    comp = cfg.make_compressor() if hasattr(cfg, "make_compressor") else None
    payload_deg, mass_deg = _push_degree_factors(seq, comp is not None)
    if comp is None:
        return int(round((_full_state_elements(params, cfg) * payload_deg
                          + mass_deg) * value_bits))
    # exchange_payload ships explicit indices (no seed regeneration).
    wire = sdm_dsgd.wire_shape_tree(params)
    payload = compressor_mod.node_mean_exact(
        comp.p, lambda i: compressor_mod.tree_wire_bits_exact(
            comp, wire, value_bits=value_bits, index_sync=False, node=i))
    return int(round(payload * payload_deg + mass_deg * value_bits))


# --------------------------------------------------------------------------
# Default registrations.
# --------------------------------------------------------------------------

def _full_state_elements(params: PyTree, cfg, seq=None) -> int:
    # full-state methods gossip the packed wire plane, so the wire count
    # is the plane-PADDED size (what the HLO permutes actually move).
    d = plane_mod.ParamPlane.for_tree(params).padded_size
    if seq is None:
        return d
    return int(round(d * gossip.mean_out_degree(gossip.sequence_of(seq))))


def _allreduce_elements(params: PyTree, cfg, seq=None) -> int:
    del seq   # no gossip graph: the all-reduce cost is schedule-free
    return sum(int(x.size) for x in jax.tree.leaves(params))


_SDM_FIELDS = (("x", PARAM), ("s", PLANE), ("d", PLANE), ("step", COUNTER))

_SDM = register(Method(
    name="sdm-dsgd",
    config_cls=sdm_dsgd.SDMConfig,
    state_cls=sdm_dsgd.SDMState,
    state_fields=_SDM_FIELDS,
    state_fields_for=_sdm_fields,
    coerce_config=_coerce_sdm,
    make_reference=sdm_dsgd.ReferenceSimulator,
    make_distributed=_sdm_distributed,
    init_stacked=_sdm_init_stacked,
    transmitted_elements=sdm_dsgd.transmitted_elements_per_step,
    transmitted_bits_fn=sdm_dsgd.transmitted_bits_per_step,
    description="Algorithm 1: sparse differential Gaussian-masking DSGD"))

register(dataclasses.replace(
    _SDM,
    name="sdm-dsgd-fused",
    state_cls=sdm_dsgd.SDMFusedState,
    state_fields=(("x", PARAM), ("s", PLANE), ("step", COUNTER)),
    state_fields_for=_fused_fields,
    make_distributed=_fused_distributed,
    init_stacked=_fused_init_stacked,
    description="SDM-DSGD with commit+advance fused (2 state buffers)"))

# DC-DSGD is DERIVED from the SDM registration — theta pinned to 1, no
# separate implementation (Remark 1: SDM-DSGD generalizes DC-DSGD).
register(dataclasses.replace(
    _SDM,
    name="dc-dsgd",
    coerce_config=lambda cfg: dataclasses.replace(_coerce_sdm(cfg), theta=1.0),
    description="DC-DSGD = SDM-DSGD with theta = 1 (Tang et al. 2018)"))

register(Method(
    name="dsgd",
    config_cls=baselines.DSGDConfig,
    state_cls=baselines.DSGDState,
    state_fields=(("x", PARAM), ("step", COUNTER)),
    coerce_config=_coerce_dsgd,
    make_reference=baselines.DSGDReference,
    make_distributed=_dsgd_distributed,
    init_stacked=_dsgd_init_stacked,
    transmitted_elements=_full_state_elements,
    description="full-state gossip DSGD (Lian et al. 2017)"))

register(Method(
    name="gradient-push",
    config_cls=gradient_push.GradientPushConfig,
    state_cls=gradient_push.GradientPushState,
    state_fields=(("x", PARAM), ("w", SCALAR), ("step", COUNTER)),
    state_fields_for=_push_fields,
    coerce_config=_coerce_push,
    make_reference=gradient_push.GradientPushReference,
    make_distributed=_push_distributed,
    init_stacked=_push_init_stacked,
    transmitted_elements=_push_elements,
    transmitted_bits_fn=_push_bits,
    directed=True,
    description="push-sum gradient-push over directed column-stochastic "
                "graphs (SGP / DP-CSGP-style); --compressor switches on "
                "CHOCO-style error-compensated compressed payloads"))

register(Method(
    name="allreduce",
    config_cls=baselines.DSGDConfig,
    state_cls=baselines.DSGDState,
    state_fields=(("x", PARAM), ("step", COUNTER)),
    coerce_config=_coerce_dsgd,
    make_reference=AllreduceReference,
    make_distributed=_allreduce_distributed,
    init_stacked=_dsgd_init_stacked,
    transmitted_elements=_allreduce_elements,
    description="conventional all-reduce data parallelism (upper bound)"))
