"""Wire plane: the whole differential as one contiguous padded buffer.

The paper's communication claim is about wire VOLUME, but a pytree-shaped
transport pays a per-leaf latency tax the paper never models: compressing
and ppermuting each parameter leaf separately serializes
``num_leaves x R`` collective-permutes (plus one sort / PRNG draw per
leaf) per gossip step — hundreds of small collectives on a real
transformer. The standard fix (cf. cpSGD's fixed-budget wire encoding and
DDP gradient bucketing) is to flatten the whole tree into one contiguous
**wire plane** and run the compressor / top-k / exchange ONCE per plane:

    ParamPlane.for_tree(tree)   ->  static layout spec (hashable)
    spec.pack(tree)             ->  tuple of (rows, LANE) f32 planes
    spec.unpack(planes)         ->  tree (original shapes/dtypes)

so a compiled distributed step issues exactly R collective-permutes per
exchange **independent of the model's leaf count**, and one top-k over
the whole plane replaces per-leaf ``num_kept`` ceils.

Buckets
-------
A plane is ``(rows, lane)`` with leaves concatenated flat (row-major) and
zero-padded up to a ``lane * row_multiple`` multiple. Flattening destroys
tensor-parallel layouts, so leaves may carry a BUCKET key (see
``use_buckets``): leaves whose key is ``None`` join the default flat
bucket (lane = ``LANE``); leaves with key ``(mesh_axis, cols)`` — i.e.
their trailing dim is model-sharded — group into one plane per distinct
(key, trailing-dim) whose lane IS that trailing dim, packed as stacked
rows ``(size // cols, cols)``. Dim 1 of such a plane keeps the leaf's
model-axis sharding (exactly the old ``fixedk_rows`` trick, hoisted to
the plane level), so the ppermute payload stays tensor-parallel — one
plane per distinct inner sharding, like DDP gradient buckets. The bucket
policy is owned by the train-step factory (``repro.train.steps``), which
installs the key tree around tracing via ``use_buckets``; everything else
sees buckets only as "multiple planes".

The layout spec is frozen/hashable and cached per (treedef, shapes,
dtypes, bucket keys, lane, row_multiple) — safe to close over in
jit/shard_map, and both executors of a method derive the SAME spec from
the same parameter template, so draw granularity cannot diverge.

The kernel wrapper (``repro.kernels.sdm_update.ops``) reuses this exact
machinery with ``lane=1024, row_multiple=block_rows`` — the former
private ``_flatten`` there is gone.

Padding note: pad coordinates are zero on entry and stay zero through
every exchange (compressors scale zeros to zeros; ppermute delivers
zeros), so they are never informative — but they DO ride the wire, which
is why the wire accounting in ``sdm_dsgd.transmitted_*_per_step`` charges
plane-padded shapes (that is what the HLO payload actually is).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["LANE", "PlaneBucket", "ParamPlane", "use_buckets",
           "current_bucket_keys", "bucket_keys_from_axes"]

PyTree = Any

# Wire-plane lane width: one TPU vector lane. Small enough that the zero
# pad is negligible against real models (< LANE * row_multiple elements
# per bucket), wide enough that (rows, LANE) planes are layout-friendly.
LANE = 128


# --------------------------------------------------------------------------
# Bucket-key context: the train-step factory owns the sharding policy.
# --------------------------------------------------------------------------

_STATE = threading.local()


# A bucket-key LEAF is None (default flat bucket) or a tuple key like
# ('model', cols) — container nodes (dicts, lists, ...) keep recursing,
# so key trees stay congruent with arbitrarily nested parameter trees.
_is_key_leaf = lambda v: v is None or isinstance(v, tuple)


def _flatten_keys(keys_tree):
    return jax.tree.flatten(keys_tree, is_leaf=_is_key_leaf)


@contextlib.contextmanager
def use_buckets(keys_tree: "PyTree | None"):
    """Install a per-leaf bucket-key tree for ``ParamPlane.for_tree``.

    ``keys_tree`` is congruent with the parameter tree; each leaf is a
    TUPLE bucket key (e.g. ``('model', cols)``) or ``None`` (default
    flat bucket) — see ``_is_key_leaf``. Installed around TRACING (it is
    static metadata), typically by ``steps.make_distributed_train`` and
    the matching state-template builders so the executor and the
    templates agree on the layout.
    """
    prev = getattr(_STATE, "buckets", None)
    if keys_tree is None:
        _STATE.buckets = None
    else:
        leaves, treedef = _flatten_keys(keys_tree)
        _STATE.buckets = (treedef, tuple(leaves))
    try:
        yield
    finally:
        _STATE.buckets = prev


def current_bucket_keys(treedef) -> "Tuple | None":
    """The installed key tuple when it matches ``treedef``, else None."""
    ctx = getattr(_STATE, "buckets", None)
    if ctx is not None and ctx[0] == treedef:
        return ctx[1]
    return None


def bucket_keys_from_axes(axes_tree: PyTree, shapes_tree: PyTree,
                          mapping) -> PyTree:
    """Derive bucket keys from logical-axis tuples (the steps.py policy).

    A leaf whose LAST logical axis maps to a mesh axis (e.g. 'model')
    gets key ``(mesh_axis, trailing_dim)`` — its plane rows keep the TP
    sharding; every other leaf joins the default flat bucket (``None``).
    """
    is_axes = lambda v: isinstance(v, tuple) and all(
        isinstance(e, (str, type(None))) for e in v)

    def one(axes, shape):
        if not axes or not shape:
            return None
        mesh_axis = mapping.get(axes[-1]) if axes[-1] is not None else None
        if mesh_axis is None:
            return None
        if isinstance(mesh_axis, (tuple, list)):
            mesh_axis = tuple(mesh_axis)
        return (mesh_axis, int(shape[-1]))

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes)


# --------------------------------------------------------------------------
# The layout spec.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlaneBucket:
    """One plane of the layout: leaves sharing a sharding bucket."""

    key: Any                       # None = default flat bucket
    lane: int                      # plane width (cols)
    leaves: Tuple[int, ...]        # member leaf indices (tree-flatten order)
    sizes: Tuple[int, ...]         # flat element count per member
    rows: int                      # padded row count

    @property
    def size(self) -> int:
        """Unpadded element count (sum of member sizes)."""
        return sum(self.sizes)

    @property
    def padded_size(self) -> int:
        return self.rows * self.lane

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.rows, self.lane)


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


_SPECS: dict = {}
_SPECS_LOCK = threading.Lock()


@dataclasses.dataclass(frozen=True)
class ParamPlane:
    """Static flatten/unflatten layout for a parameter pytree.

    Frozen + hashable (the treedef and all geometry are static), so specs
    can be closed over in jit/shard_map and memoized. ``pack`` casts to
    f32 — the wire dtype — and ``unpack`` restores each leaf's shape and
    dtype.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    lane: int
    row_multiple: int
    buckets: Tuple[PlaneBucket, ...]

    # -- construction ------------------------------------------------------
    @classmethod
    def for_tree(cls, tree: PyTree, *, lane: int = LANE,
                 row_multiple: int = 1,
                 buckets: "PyTree | str" = "auto") -> "ParamPlane":
        """The (cached) layout spec of ``tree``.

        ``tree`` may hold arrays or ShapeDtypeStructs (only shape/dtype
        are read). ``buckets='auto'`` consults the ``use_buckets``
        context (no context -> one flat bucket); pass an explicit key
        tree or ``None`` to override.
        """
        leaves, treedef = jax.tree.flatten(tree)
        shapes = tuple(tuple(int(d) for d in l.shape) for l in leaves)
        dtypes = tuple(jnp.dtype(l.dtype).name for l in leaves)
        if buckets == "auto":
            keys = current_bucket_keys(treedef) or (None,) * len(leaves)
        elif buckets is None:
            keys = (None,) * len(leaves)
        else:
            keys = tuple(_flatten_keys(buckets)[0])
            if len(keys) != len(leaves):
                raise ValueError(
                    f"bucket key tree has {len(keys)} leaves for "
                    f"{len(leaves)} parameter leaves")
        cache_key = (treedef, shapes, dtypes, keys, lane, row_multiple)
        with _SPECS_LOCK:
            spec = _SPECS.get(cache_key)
            if spec is None:
                spec = cls._build(treedef, shapes, dtypes, keys, lane,
                                  row_multiple)
                _SPECS[cache_key] = spec
        return spec

    @classmethod
    def for_stacked(cls, stack: PyTree, **kw) -> "ParamPlane":
        """Spec of a node-stacked tree: leaves lose their leading axis."""
        per_node = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct(tuple(v.shape[1:]), v.dtype),
            stack)
        return cls.for_tree(per_node, **kw)

    @classmethod
    def _build(cls, treedef, shapes, dtypes, keys, lane, row_multiple
               ) -> "ParamPlane":
        groups: dict = {}
        order = []
        for i, (shape, key) in enumerate(zip(shapes, keys)):
            size = 1
            for d in shape:
                size *= d
            if key is not None:
                cols = shape[-1] if shape else 1
                if cols < 1 or size % cols:
                    raise ValueError(
                        f"bucket {key!r}: leaf {i} shape {shape} has no "
                        "whole trailing-dim rows")
                gkey = ("k", key, cols)
            else:
                gkey = ("flat",)
            if gkey not in groups:
                groups[gkey] = []
                order.append(gkey)
            groups[gkey].append((i, size))
        buckets = []
        for gkey in order:
            members = groups[gkey]
            idxs = tuple(i for i, _ in members)
            sizes = tuple(s for _, s in members)
            total = sum(sizes)
            if gkey[0] == "flat":
                b_lane = lane
                rows = _ceil_to(total, lane * row_multiple) // lane
                rows = max(rows, row_multiple)
                bkey = None
            else:
                _, bkey, b_lane = gkey
                rows = max(_ceil_to(total // b_lane, row_multiple),
                           row_multiple)
            buckets.append(PlaneBucket(key=bkey, lane=b_lane, leaves=idxs,
                                       sizes=sizes, rows=rows))
        return cls(treedef=treedef, shapes=shapes, dtypes=dtypes, lane=lane,
                   row_multiple=row_multiple, buckets=tuple(buckets))

    # -- geometry ----------------------------------------------------------
    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def total_size(self) -> int:
        """Unpadded element count over the whole tree."""
        return sum(b.size for b in self.buckets)

    @property
    def padded_size(self) -> int:
        """Wire element count: what the planes actually carry."""
        return sum(b.padded_size for b in self.buckets)

    def plane_shapes(self) -> Tuple[Tuple[int, int], ...]:
        return tuple(b.shape for b in self.buckets)

    def shape_dtype(self, dtype=jnp.float32) -> Tuple[jax.ShapeDtypeStruct, ...]:
        """Plane templates — also the tree wire accounting runs over."""
        return tuple(jax.ShapeDtypeStruct(b.shape, dtype)
                     for b in self.buckets)

    def zeros(self) -> Tuple[jax.Array, ...]:
        return tuple(jnp.zeros(b.shape, jnp.float32) for b in self.buckets)

    # -- pack / unpack -----------------------------------------------------
    def _leaves_of(self, tree: PyTree) -> list:
        leaves, treedef = jax.tree.flatten(tree)
        if len(leaves) != len(self.shapes):
            raise ValueError(
                f"tree has {len(leaves)} leaves, spec expects "
                f"{len(self.shapes)}")
        return leaves

    def pack(self, tree: PyTree) -> Tuple[jax.Array, ...]:
        """Concatenate the tree into the plane tuple (f32, zero-padded)."""
        leaves = self._leaves_of(tree)
        out = []
        for b in self.buckets:
            if b.key is None:
                parts = [leaves[i].reshape(-1).astype(jnp.float32)
                         for i in b.leaves]
                flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
                pad = b.padded_size - b.size
                if pad:
                    flat = jnp.pad(flat, (0, pad))
                out.append(flat.reshape(b.rows, b.lane))
            else:
                parts = [leaves[i].reshape(-1, b.lane).astype(jnp.float32)
                         for i in b.leaves]
                mat = parts[0] if len(parts) == 1 else \
                    jnp.concatenate(parts, axis=0)
                pad = b.rows - b.size // b.lane
                if pad:
                    mat = jnp.pad(mat, ((0, pad), (0, 0)))
                out.append(mat)
        return tuple(out)

    def unpack(self, planes: Tuple[jax.Array, ...]) -> PyTree:
        """Slice the planes back into the original tree (shapes + dtypes)."""
        if len(planes) != len(self.buckets):
            raise ValueError(
                f"{len(planes)} planes for {len(self.buckets)} buckets")
        leaves: list = [None] * len(self.shapes)
        for b, plane in zip(self.buckets, planes):
            flat = plane.reshape(-1)[:b.size]
            off = 0
            for i, size in zip(b.leaves, b.sizes):
                leaves[i] = flat[off:off + size].reshape(
                    self.shapes[i]).astype(self.dtypes[i])
                off += size
        return jax.tree.unflatten(self.treedef, leaves)

    # Stacked (leading node axis) variants for the reference executors.
    def pack_stacked(self, stack: PyTree) -> Tuple[jax.Array, ...]:
        """Per-node pack of a node-stacked tree -> (n, rows, lane) planes."""
        return jax.vmap(self.pack)(stack)

    def unpack_stacked(self, planes: Tuple[jax.Array, ...]) -> PyTree:
        return jax.vmap(self.unpack)(planes)
