"""Logical-axis sharding: MaxText-style named-axis rules, mesh-agnostic models.

Model code annotates activations with ``logical(x, 'batch', 'seq', 'embed')``
and parameters carry logical axis tuples. The launcher installs a
``MeshRules`` mapping logical names -> mesh axes; with no rules installed
(CPU tests) every annotation is a no-op.

Divisibility fallback: if a dimension is not divisible by the mapped mesh
axis size (e.g. 4 KV heads over a 16-wide model axis), that dimension is
silently replicated — the standard behaviour production frameworks use
for small GQA heads.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

__all__ = ["MeshRules", "use_rules", "current_rules", "logical",
           "logical_sharding", "tree_shardings"]

_STATE = threading.local()


class MeshRules:
    """mesh + {logical axis name -> mesh axis (str | tuple | None)}."""

    def __init__(self, mesh: Mesh, mapping: Mapping[str, Any]):
        self.mesh = mesh
        self.mapping = dict(mapping)

    def _axis_size(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, (tuple, list)):
            out = 1
            for a in axis:
                out *= self.mesh.shape[a]
            return out
        return self.mesh.shape[axis]

    def spec(self, axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec from logical names, with divisibility fallback."""
        used: set = set()
        parts = []
        for i, name in enumerate(axes):
            mesh_axis = self.mapping.get(name) if name is not None else None
            if mesh_axis is None:
                parts.append(None)
                continue
            flat = tuple(mesh_axis) if isinstance(mesh_axis, (tuple, list)) \
                else (mesh_axis,)
            if any(a not in self.mesh.shape for a in flat):
                parts.append(None)  # mesh without this axis (debug meshes)
                continue
            if any(a in used for a in flat):
                parts.append(None)  # each mesh axis at most once per spec
                continue
            if shape is not None and shape[i] % self._axis_size(mesh_axis) != 0:
                parts.append(None)  # replicate non-divisible dims
                continue
            used.update(flat)
            parts.append(tuple(flat) if len(flat) > 1 else flat[0])
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding(self, axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))


def current_rules() -> Optional[MeshRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def logical(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain activation sharding by logical names (no-op without rules).

    Inside a shard_map manual region the constraint must bind to the
    ambient *abstract* mesh (whose manual axes are typed Manual), not the
    concrete mesh the rules were built with — we rebuild the NamedSharding
    against the current abstract mesh when one is active.
    """
    rules = current_rules()
    if rules is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    spec = rules.spec(axes, x.shape)
    abstract = compat.get_abstract_mesh()
    if abstract is not None and abstract.shape_tuple:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(abstract, spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def logical_sharding(axes: Sequence[Optional[str]],
                     shape: Sequence[int]) -> Optional[NamedSharding]:
    rules = current_rules()
    if rules is None:
        return None
    return rules.sharding(axes, shape)


def tree_shardings(rules: MeshRules, axes_tree: Any, shape_tree: Any) -> Any:
    """NamedSharding tree from parallel (axes, shapes) trees."""
    return jax.tree.map(
        lambda axes, shape: rules.sharding(axes, shape),
        axes_tree, shape_tree,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            isinstance(e, (str, type(None))) for e in v))
