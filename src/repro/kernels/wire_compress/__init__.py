"""Fused wire-compressor pipeline (pallas): quantize+pack, gather+pack."""
from .ops import fixedk_gather_pack, qsgd_pack
from .ref import fixedk_gather_pack_ref, qsgd_decode_ref, qsgd_quantize_pack_ref
from .wire_compress import (LANE, fixedk_gather_pack_pallas, pack_factor,
                            qsgd_pack_pallas)

__all__ = [
    "LANE",
    "pack_factor",
    "qsgd_pack",
    "qsgd_pack_pallas",
    "qsgd_decode_ref",
    "qsgd_quantize_pack_ref",
    "fixedk_gather_pack",
    "fixedk_gather_pack_pallas",
    "fixedk_gather_pack_ref",
]
