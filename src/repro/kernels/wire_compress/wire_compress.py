"""Fused wire-compressor kernels (quantize+pack, gather+pack) per plane.

The unfused QSGD wire path is a multi-launch XLA chain per plane —
abs/scale/floor/stochastic-round/clamp/sign ~6 elementwise kernels, then
an offset-encode + k strided shift/or packing steps, then a SEPARATE f32
scale leaf on the wire. ``qsgd_pack_pallas`` fuses the whole
quantize → offset-encode → sub-byte-pack chain into ONE kernel over the
(rows, 128) wire plane, emitting the u8 byte image directly; the caller
appends the 4 norm bytes so scale and values share a single wire buffer
(one collective-permute per round instead of two).

Two stages deliberately stay OUTSIDE the kernel:

* the uniform draw — ``jax.random.uniform(key, plane.shape)`` at the
  CANONICAL plane-spec shape, so the PRNG-hygiene lint (analyzer
  contract rule 3) sees the draw and the bits are bit-identical to the
  unfused ``QSGDCompressor``;
* the l2 norm — one whole-plane reduction whose in-kernel grid
  accumulation would change the reduction ORDER vs XLA and break
  bit-equality. The kernel receives 1/norm pre-scaled (``inv``).

``fixedk_gather_pack_pallas`` fuses the fixed-k sender-side payload
packing (gather kept blocks + contraction scale) into one launch — the
``jnp.take * scale`` pair in ``gossip._packed_selection``. Bit-exact to
the unfused ops, so trajectories are unchanged wherever it is enabled.

Both kernels default to ``interpret=True`` (CPU CI); the byte image the
pack kernel writes is lane-packed ``out[r, cb] = OR_j enc[r, cb*k+j] <<
(j*bits)`` — exactly the unfused row-major flat byte order, asserted
bit-for-bit in tests/test_plane.py. On real TPUs the sub-128-lane u8
output tile and the strided lane slice are the known mosaic rough edges;
a production port would pack ``k`` planes per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.plane import LANE

__all__ = ["qsgd_pack_pallas", "fixedk_gather_pack_pallas", "LANE",
           "pack_factor"]


def pack_factor(bits: int) -> int:
    """u8 lanes per byte: 8/bits for sub-byte widths, else unpacked."""
    return 8 // bits if bits in (2, 4) else 1


def _qsgd_kernel(x_ref, u_ref, inv_ref, out_ref, *, bits: int):
    s = float(2 ** (bits - 1) - 1)
    xf = x_ref[...]
    # the EXACT unfused arithmetic (compressor.QSGDCompressor.compress):
    # floor + stochastic carry + clamp + sign, fused into one pass.
    ratio = jnp.abs(xf) * inv_ref[0, 0]
    level = jnp.floor(ratio)
    level = level + (u_ref[...] < (ratio - level))
    q = (jnp.sign(xf) * jnp.minimum(level, s)).astype(jnp.int32)
    off = q + int(s)              # offset-encode to [0, 2s] < 2^bits
    k = pack_factor(bits)
    if k == 1:
        out_ref[...] = off.astype(jnp.uint8)
        return
    # byte (r, cb) holds elements (r, cb*k + j), j in [0, k) — the
    # unfused row-major flat pack order. The reshape is layout-free and
    # the minor-axis picks fuse (a j::k strided slice would lower to a
    # gather on CPU and break the single-loop fusion).
    rows_blk = off.shape[0]
    off3 = off.reshape(rows_blk, off.shape[1] // k, k)
    byte = jnp.zeros(out_ref.shape, jnp.int32)
    for j in range(k):
        byte = byte | (off3[:, :, j] << (j * bits))
    out_ref[...] = byte.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def qsgd_pack_pallas(xf: jax.Array, u: jax.Array, inv: jax.Array, *,
                     bits: int, interpret: bool = True) -> jax.Array:
    """(rows, LANE) f32 plane + uniforms + (1, 1) 1/norm -> packed u8.

    Output is (rows, LANE // pack_factor) u8 — the exact byte image the
    unfused packer produces in row-major flat order (offset-encoded
    q + s for bits=8).
    """
    rows, lane = xf.shape
    assert lane == LANE, (xf.shape,)
    k = pack_factor(bits)
    block_rows = 8 if rows % 8 == 0 else 1
    grid = (rows // block_rows,)
    blk_in = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    kernel = functools.partial(_qsgd_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk_in, blk_in,
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, LANE // k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE // k), jnp.uint8),
        interpret=interpret,
    )(xf, u, inv)


def _gather_kernel(db_ref, idx_ref, out_ref, *, scale: float):
    idx = idx_ref[...][:, 0]
    out_ref[...] = jnp.take(db_ref[...], idx, axis=0) * scale


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def fixedk_gather_pack_pallas(db: jax.Array, idx: jax.Array, *,
                              scale: float,
                              interpret: bool = True) -> jax.Array:
    """(nb, block) plane view + (kb,) i32 indices -> (kb, block) payload.

    One launch for the sender-side fixed-k pack: gather the kept blocks
    and apply the (static, scalar-p) unbiasedness scale — bit-exact to
    ``jnp.take(db, idx, axis=0) * scale``. Whole-plane VMEM block (our
    planes are small); the PrefetchScalarGridSpec one-row-per-grid-step
    variant is the production TPU layout.
    """
    kb = idx.shape[0]
    kernel = functools.partial(_gather_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((kb, db.shape[1]), db.dtype),
        interpret=interpret,
    )(db, idx.reshape(kb, 1))
