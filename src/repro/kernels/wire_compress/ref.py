"""Pure-jnp oracle for the fused wire-compressor kernels.

Bit-identical to both the pallas kernels and the unfused
``compressor.QSGDCompressor`` chain — the property tests pin all three
to the same byte image.
"""
from __future__ import annotations

import jax.numpy as jnp

from .wire_compress import pack_factor


def qsgd_quantize_pack_ref(xf, u, inv, *, bits: int):
    """Quantize + offset-encode + sub-byte-pack, any input shape.

    ``inv`` must be the pre-computed ``s / max(norm, 1e-30)`` scalar so
    the multiply matches the unfused arithmetic exactly. Returns the
    FLAT u8 byte vector (row-major pack order, zero-padded to a whole
    byte), excluding the norm tail.
    """
    s = float(2 ** (bits - 1) - 1)
    ratio = jnp.abs(xf) * inv
    level = jnp.floor(ratio)
    level = level + (u < (ratio - level))
    q = (jnp.sign(xf) * jnp.minimum(level, s)).astype(jnp.int32)
    off = (q + int(s)).reshape(-1)
    k = pack_factor(bits)
    if k == 1:
        return off.astype(jnp.uint8)
    pad = (-off.shape[0]) % k
    if pad:
        off = jnp.pad(off, (0, pad))
    groups = off.reshape(-1, k)
    byte = jnp.zeros((groups.shape[0],), jnp.int32)
    for j in range(k):
        byte = byte | (groups[:, j] << (j * bits))
    return byte.astype(jnp.uint8)


def qsgd_decode_ref(buf, shape, *, bits: int):
    """Decode the fused single-buffer payload back to f32 (oracle for
    ``FusedQSGDCompressor.decompress``)."""
    import jax

    s = float(2 ** (bits - 1) - 1)
    import math as _math
    d = int(_math.prod(shape))
    k = pack_factor(bits)
    norm = jax.lax.bitcast_convert_type(buf[-4:], jnp.float32)
    data = buf[:-4].astype(jnp.int32)
    if k == 1:
        flat = data[:d] - int(s)
    else:
        mask = (1 << bits) - 1
        parts = [(data >> (j * bits)) & mask for j in range(k)]
        flat = jnp.stack(parts, axis=1).reshape(-1)[:d] - int(s)
    return (norm / s) * flat.reshape(shape).astype(jnp.float32)


def fixedk_gather_pack_ref(db, idx, *, scale: float):
    """The unfused sender-side fixed-k pack: gather + contraction scale."""
    return jnp.take(db, idx, axis=0) * scale
