"""Public jit-friendly entry points for the fused wire-compressor.

``qsgd_pack`` is the quantize+pack stage of the fused QSGD wire format
("qsgdf"): callers draw the stochastic-rounding uniforms at the
CANONICAL plane shape and pass the raw l2 norm; this wrapper derives the
``s / max(norm, eps)`` scalar exactly as the unfused compressor does and
routes lane-aligned planes through the pallas kernel (pure-jnp oracle
otherwise / when ``use_kernel=False``). Output is the flat u8 byte
image, bit-identical across kernel, oracle and the unfused
``QSGDCompressor`` packer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .wire_compress import (LANE, fixedk_gather_pack_pallas, pack_factor,
                            qsgd_pack_pallas)

__all__ = ["qsgd_pack", "fixedk_gather_pack"]


@functools.partial(jax.jit,
                   static_argnames=("bits", "use_kernel", "interpret"))
def qsgd_pack(xf: jax.Array, u: jax.Array, norm: jax.Array, *, bits: int,
              use_kernel: bool = True, interpret: bool = True) -> jax.Array:
    """f32 tensor + uniforms + scalar norm -> flat packed u8 bytes."""
    xf = xf.astype(jnp.float32)
    s = float(2 ** (bits - 1) - 1)
    inv = s / jnp.maximum(norm, 1e-30)   # EXACT unfused scale arithmetic
    plane_like = (xf.ndim == 2 and xf.shape[1] == LANE
                  and xf.shape[0] % 1 == 0)
    if use_kernel and plane_like and bits in (2, 4, 8):
        out = qsgd_pack_pallas(xf, u, inv.reshape(1, 1), bits=bits,
                               interpret=interpret)
        return out.reshape(-1)
    return ref.qsgd_quantize_pack_ref(xf, u, inv, bits=bits)


def fixedk_gather_pack(db: jax.Array, idx: jax.Array, *, scale: float,
                       use_kernel: bool = True,
                       interpret: bool = True) -> jax.Array:
    """Sender-side fixed-k pack: one-launch gather + unbiasedness scale."""
    if use_kernel:
        return fixedk_gather_pack_pallas(db, idx, scale=float(scale),
                                         interpret=interpret)
    return ref.fixedk_gather_pack_ref(db, idx, scale=scale)
