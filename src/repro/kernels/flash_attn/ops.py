"""Public GQA-aware wrapper over the flash attention kernel.

Accepts the model's (b, s, h, dh) / (b, s, kv, dh) layout, repeats KV
heads for GQA, pads head_dim to a 128 multiple (MXU lane width), and
dispatches to the Pallas kernel (or the dense oracle with
``use_kernel=False``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.flash_attn import flash_attention_pallas
from repro.kernels.flash_attn.ref import attention_ref


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None, block_q: int = 128,
                    block_k: int = 128, use_kernel: bool = True,
                    interpret: bool = True) -> jax.Array:
    """q: (b, sq, h, dh); k/v: (b, skv, kv_heads, dh) -> (b, sq, h, dh)."""
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    to_bh = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, t.shape[1], dh)
    qf, kf, vf = to_bh(q), to_bh(k), to_bh(v)

    pad_d = (-dh) % 128
    if use_kernel and pad_d:
        padd = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad_d)))
        # zero-padding head_dim changes q.k by nothing; rescale the softmax
        # scale to account for the padded dh used inside the kernel.
        scale_fix = ((dh + pad_d) / dh) ** 0.5
        qf = padd(qf) * scale_fix
        kf, vf = padd(kf), padd(vf)

    if use_kernel:
        out = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                     softcap=softcap, block_q=block_q,
                                     block_k=block_k, interpret=interpret)
        out = out[..., :dh]
    else:
        out = attention_ref(qf, kf, vf, causal=causal, window=window,
                            softcap=softcap)
    return out.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
