"""Dense softmax-attention oracle for the flash kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  softcap: float | None = None) -> jax.Array:
    """q: (bh, sq, dh); k/v: (bh, skv, dh)."""
    _, sq, dh = q.shape
    skv = k.shape[1]
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dh)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
