"""Blockwise online-softmax (flash) attention for TPU prefill.

Canonical Pallas structure: grid = (batch*heads, q_blocks, k_blocks) with
the innermost k dimension accumulating into VMEM scratch (m, l, acc) that
persists across the sequential innermost grid steps on TPU. Supports
causal masking, sliding windows (gemma2 local layers), and gemma2-style
score soft-capping. MXU alignment: block_q x head_dim and
block_k x head_dim tiles, head_dim padded to 128 multiples by ops.py.

On-TPU refinement (not needed for interpret-mode validation): fully
masked k-blocks under causal/window masking could be skipped by shrinking
the k grid per q index; XLA-level cost is identical for the roofline.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, softcap, block_q, block_k, kv_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                          # (block_q, dh)
    k = k_ref[0]                          # (block_k, dh)
    v = v_ref[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # renormalize previous accumulator, accumulate this block
    p = jnp.exp(scores - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           softcap: float | None = None, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (bh, sq, dh); k/v: (bh, skv, dh) — heads pre-flattened into bh.

    sq % block_q == 0; skv is padded to block_k internally (masked).
    """
    bh, sq, dh = q.shape
    skv = k.shape[1]
    assert sq % block_q == 0, (sq, block_q)
    pad_k = (-skv) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    skv_pad = skv + pad_k
    grid = (bh, sq // block_q, skv_pad // block_k)
    scale = 1.0 / math.sqrt(dh)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, kv_len=skv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
