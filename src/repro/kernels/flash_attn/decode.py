"""Paged flash decode: block-table-aware single-token attention.

The serving engine's paged KV cache stores keys/values in fixed-size
pages (``repro.serving.kv_cache``); a per-slot block table maps logical
block j of request row b to a physical page id. This kernel reads the
cache THROUGH the table — pages are never gathered into a contiguous
buffer — using the canonical TPU structure: the block table and the
per-row valid lengths ride scalar prefetch, so each k-block's DMA source
index is computed before the kernel body runs.

grid = (batch, kv_heads, n_blocks); the innermost block dimension
accumulates into VMEM scratch (m, l, acc) exactly like the prefill
kernel in ``flash_attn.py``. GQA is handled by processing all ``group``
query heads of one kv head per program. Like ``wire_compress``, the
kernel runs in interpret mode on CPU hosts and a pure-jnp reference
path (``paged_attention_ref``) serves odd shapes / ``use_kernel=False``;
on a real TPU the (group, head_dim) tiles should be padded to (8, 128)
sublane/lane multiples — the ops wrapper pads head_dim, group padding is
left to the caller's head layout.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention", "paged_attention_ref",
           "paged_flash_decode_pallas"]

NEG_INF = -1e30


def _kernel(tbl_ref, seq_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale, window, softcap, page_size):
    bi = pl.program_id(0)
    ji = pl.program_id(2)
    nb = pl.num_programs(2)

    @pl.when(ji == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                      # (group, dh)
    k = k_ref[0, :, 0, :]                # (page_size, dh)
    v = v_ref[0, :, 0, :]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)

    seq_len = seq_ref[bi]                # valid tokens incl. current
    k_pos = ji * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (q.shape[0], page_size), 1)
    mask = k_pos < seq_len
    if window is not None:
        mask &= k_pos > (seq_len - 1) - window
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    # explicit re-mask: on a fully-masked block m_new == NEG_INF and
    # exp(scores - m_new) would resurrect every entry as exp(0) == 1
    p = jnp.where(mask, jnp.exp(scores - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ji == nb - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "softcap", "interpret"))
def paged_flash_decode_pallas(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, block_table: jax.Array,
                              seq_lens: jax.Array, *,
                              window: int | None = None,
                              softcap: float | None = None,
                              interpret: bool = True) -> jax.Array:
    """q: (b, kvh, group, dh); pages: (n_pages, page, kvh, dh);
    block_table: (b, n_blocks) int32; seq_lens: (b,) int32 ->
    (b, kvh, group, dh).

    Rows with seq_len == 0 (empty slots) produce zeros: every k position
    masks out, l stays 0 and the finalize divides the zero accumulator
    by the epsilon floor.
    """
    b, kvh, group, dh = q.shape
    _, page, _, _ = k_pages.shape
    n_blocks = block_table.shape[1]
    scale = 1.0 / math.sqrt(dh)
    grid = (b, kvh, n_blocks)
    kernel = functools.partial(_kernel, scale=scale, window=window,
                               softcap=softcap, page_size=page)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,     # block_table, seq_lens
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, dh),
                         lambda bi, hi, ji, tbl, seq: (bi, hi, 0, 0)),
            pl.BlockSpec((1, page, 1, dh),
                         lambda bi, hi, ji, tbl, seq: (tbl[bi, ji], 0, hi, 0)),
            pl.BlockSpec((1, page, 1, dh),
                         lambda bi, hi, ji, tbl, seq: (tbl[bi, ji], 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh),
                               lambda bi, hi, ji, tbl, seq: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),    # running max m
            pltpu.VMEM((group, 1), jnp.float32),    # running sum l
            pltpu.VMEM((group, dh), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, group, dh), q.dtype),
        interpret=interpret,
    )(block_table, seq_lens, q, k_pages, v_pages)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        block_table: jax.Array, seq_lens: jax.Array, *,
                        window: int | None = None,
                        softcap: float | None = None) -> jax.Array:
    """Dense oracle: gather pages through the table, masked softmax.

    q: (b, h, dh) -> (b, h, dh). Materializes the (b, n_blocks*page)
    contiguous view — the XLA fallback path on hosts where the Pallas
    kernel only interprets.
    """
    b, h, dh = q.shape
    _, page, kvh, _ = k_pages.shape
    group = h // kvh
    k = k_pages[block_table]             # (b, nb, page, kvh, dh)
    v = v_pages[block_table]
    nb = k.shape[1]
    k = k.reshape(b, nb * page, kvh, dh)
    v = v.reshape(b, nb * page, kvh, dh)
    qg = q.reshape(b, kvh, group, dh)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k) / math.sqrt(dh)
    scores = scores.astype(jnp.float32)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    pos = jnp.arange(nb * page)
    mask = pos[None, :] < seq_lens[:, None]
    if window is not None:
        mask &= pos[None, :] > (seq_lens[:, None] - 1) - window
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v)
    # empty rows (seq_len 0): fully-masked softmax degenerates to uniform;
    # zero them so both paths agree that a dead slot contributes nothing.
    out = jnp.where((seq_lens > 0)[:, None, None, None], out, 0.0)
    return out.reshape(b, h, dh)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_table: jax.Array, seq_lens: jax.Array, *,
                    window: int | None = None, softcap: float | None = None,
                    use_kernel: bool = True,
                    interpret: bool = True) -> jax.Array:
    """GQA-aware public entry. q: (b, h, dh) single decode token per row;
    k/v_pages: (n_pages, page, kv_heads, dh); block_table (b, n_blocks);
    seq_lens (b,) valid tokens per row (incl. the current one).
    """
    b, h, dh = q.shape
    kvh = k_pages.shape[2]
    group = h // kvh
    if not use_kernel:
        return paged_attention_ref(q, k_pages, v_pages, block_table,
                                   seq_lens, window=window, softcap=softcap)
    qg = q.reshape(b, kvh, group, dh)
    pad_d = (-dh) % 128
    if pad_d:
        # zero-padding head_dim adds nothing to q.k; rescale so the
        # kernel's 1/sqrt(dh_padded) matches 1/sqrt(dh).
        scale_fix = ((dh + pad_d) / dh) ** 0.5
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad_d))) * scale_fix
        k_pages = jnp.pad(k_pages, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
        v_pages = jnp.pad(v_pages, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
    out = paged_flash_decode_pallas(qg, k_pages, v_pages,
                                    block_table.astype(jnp.int32),
                                    seq_lens.astype(jnp.int32),
                                    window=window, softcap=softcap,
                                    interpret=interpret)
    return out[..., :dh].reshape(b, h, dh)
