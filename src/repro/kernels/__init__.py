"""Pallas TPU kernels for the paper's per-iteration hot loop and attention.

Each kernel ships as <name>/<name>.py (pl.pallas_call + BlockSpec),
<name>/ops.py (jit'd public wrapper), <name>/ref.py (pure-jnp oracle).
Kernels target TPU; correctness is validated with interpret=True on CPU.
"""
