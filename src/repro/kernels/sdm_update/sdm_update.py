"""Fused SDM-DSGD iteration update kernel (Algorithm 1's elementwise core).

One HBM pass over the flat parameter vector fuses what would otherwise be
~9 separate elementwise kernels (clip, noise synth, axpy chain, mask,
scale, three state updates):

    s      = s_prev + nb_sum                        (gossip accumulation)
    g_hat  = clip(g, +-clip_c) + sigma * N(0,1)     (Gaussian masking)
    y      = (1-theta)*x + theta*(w_self*x + s - gamma*g_hat)
    d_new  = y - x
    sd     = bernoulli_mask(p) * d_new / p          (sparsifier S(.))
    x_new  = x + sd

The Gaussian is synthesized IN-KERNEL from two uniform u32 bit streams
via Box-Muller, and the Bernoulli mask from a third — so the random bits
(cheap int32) are the only extra traffic and the f32 noise tensors never
touch HBM. On real TPUs the bits themselves can come from the hardware
PRNG (``use_device_prng=True`` in ops.py); that path cannot execute in
CPU interpret mode (no ``prng_seed`` lowering — verified), so validation
feeds explicit bits.

Tiling: the flat vector is padded and reshaped to (rows, 1024) f32 —
1024 = 8 VREG lanes x 128 sublanes; each grid step processes a
(block_rows, 1024) VMEM tile (block_rows=256 -> 1 MiB per operand tile,
7 inputs + 3 outputs ~= 10 MiB of VMEM, inside the ~16 MiB budget).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["sdm_update_pallas", "LANE", "DEFAULT_BLOCK_ROWS"]

LANE = 1024
DEFAULT_BLOCK_ROWS = 256

_TWO_PI = 2.0 * math.pi
_INV24 = 1.0 / (1 << 24)


def _uniform01(bits: jax.Array) -> jax.Array:
    """Top-24-bit uniform in (0, 1]; never 0 so log() is safe."""
    u = (bits >> 8).astype(jnp.float32) * _INV24
    return jnp.maximum(u, _INV24)


def _kernel(x_ref, s_ref, nb_ref, g_ref, mbits_ref, n1_ref, n2_ref,
            xo_ref, so_ref, sd_ref, *, p, theta, gamma, sigma, clip_c,
            self_w):
    x = x_ref[...]
    s = s_ref[...] + nb_ref[...]
    g = g_ref[...]
    if clip_c is not None:
        g = jnp.clip(g, -clip_c, clip_c)
    if sigma > 0.0:
        u1 = _uniform01(n1_ref[...])
        u2 = _uniform01(n2_ref[...])
        gauss = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(_TWO_PI * u2)
        g = g + sigma * gauss
    y = (1.0 - theta) * x + theta * (self_w * x + s - gamma * g)
    d = y - x
    keep = _uniform01(mbits_ref[...]) < p
    sd = jnp.where(keep, d * (1.0 / p), 0.0)
    xo_ref[...] = x + sd
    so_ref[...] = s
    sd_ref[...] = sd


@functools.partial(jax.jit, static_argnames=(
    "p", "theta", "gamma", "sigma", "clip_c", "self_w", "block_rows",
    "interpret"))
def sdm_update_pallas(x: jax.Array, s: jax.Array, nb_sum: jax.Array,
                      g: jax.Array, mask_bits: jax.Array, n1_bits: jax.Array,
                      n2_bits: jax.Array, *, p: float, theta: float,
                      gamma: float, sigma: float, clip_c: float | None,
                      self_w: float,
                      block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: bool = True
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """All operands (rows, LANE) f32 / u32, rows % block_rows == 0.

    Returns (x_new, s_new, sd).
    """
    rows, lane = x.shape
    assert lane == LANE and rows % block_rows == 0, (x.shape, block_rows)
    grid = (rows // block_rows,)
    blk = lambda: pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    kernel = functools.partial(_kernel, p=p, theta=theta, gamma=gamma,
                               sigma=sigma, clip_c=clip_c, self_w=self_w)
    out_shape = [jax.ShapeDtypeStruct(x.shape, x.dtype)] * 3
    return tuple(pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk() for _ in range(7)],
        out_specs=[blk() for _ in range(3)],
        out_shape=out_shape,
        interpret=interpret,
    )(x, s, nb_sum, g, mask_bits, n1_bits, n2_bits))
