"""Public wrapper: flat-pytree SDM-DSGD fused update.

Flattens a parameter pytree into the kernel's (rows, 1024) layout,
generates the three uniform bit streams with jax.random (or, on real
TPU hardware, leaves generation to the in-kernel PRNG), runs the fused
kernel, and unflattens. Drop-in replacement for the unfused
distributed_commit+advance pair's elementwise work.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.sdm_update.sdm_update import (LANE, DEFAULT_BLOCK_ROWS,
                                                 sdm_update_pallas)
from repro.kernels.sdm_update import ref as ref_mod

PyTree = Any


def _flatten(tree: PyTree, block_rows: int):
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    n = flat.shape[0]
    tile = LANE * block_rows
    pad = (-n) % tile
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANE), (treedef, [l.shape for l in leaves],
                                    [l.dtype for l in leaves], n)


def _unflatten(mat: jax.Array, meta) -> PyTree:
    treedef, shapes, dtypes, n = meta
    flat = mat.reshape(-1)[:n]
    out, off = [], 0
    for shp, dt in zip(shapes, dtypes):
        size = 1
        for d in shp:
            size *= d
        out.append(flat[off:off + size].reshape(shp).astype(dt))
        off += size
    return jax.tree.unflatten(treedef, out)


def sdm_update(x_tree: PyTree, s_tree: PyTree, nb_tree: PyTree,
               g_tree: PyTree, key: jax.Array, *, p: float, theta: float,
               gamma: float, sigma: float, clip_c: float | None,
               self_w: float, block_rows: int = DEFAULT_BLOCK_ROWS,
               use_kernel: bool = True, interpret: bool = True
               ) -> Tuple[PyTree, PyTree, PyTree]:
    """Returns (x_new, s_new, sd) trees. ``key`` drives mask+noise bits."""
    x, meta = _flatten(x_tree, block_rows)
    s, _ = _flatten(s_tree, block_rows)
    nb, _ = _flatten(nb_tree, block_rows)
    g, _ = _flatten(g_tree, block_rows)
    kb, k1, k2 = jax.random.split(key, 3)
    # Draw bits at the canonical LANE-padded size, NOT x.shape: threefry
    # output depends on the total draw size, so tying the draw to the
    # block_rows tile padding would make the mask (and the whole
    # trajectory) change with the kernel's tiling parameter.
    n_rows = -(-meta[3] // LANE)

    def bits(k: jax.Array) -> jax.Array:
        b = jax.random.bits(k, (n_rows, LANE), jnp.uint32)
        return jnp.pad(b, ((0, x.shape[0] - n_rows), (0, 0)))
    fn = sdm_update_pallas if use_kernel else _ref_adapter
    x2, s2, sd = fn(x, s, nb, g, bits(kb), bits(k1), bits(k2), p=p,
                    theta=theta, gamma=gamma, sigma=sigma, clip_c=clip_c,
                    self_w=self_w,
                    **({"block_rows": block_rows, "interpret": interpret}
                       if use_kernel else {}))
    return (_unflatten(x2, meta), _unflatten(s2, meta), _unflatten(sd, meta))


def _ref_adapter(x, s, nb, g, mb, n1, n2, **kw):
    return ref_mod.sdm_update_ref(x, s, nb, g, mb, n1, n2, **kw)
