"""Public wrapper: flat-pytree SDM-DSGD fused update.

Flattens a parameter pytree into the kernel's (rows, 1024) layout via
the SHARED wire-plane machinery (``repro.core.plane.ParamPlane`` with
``lane=1024, row_multiple=block_rows`` — the former private ``_flatten``
here is gone, and the layout spec is computed ONCE instead of once per
operand), generates the three uniform bit streams with jax.random (or,
on real TPU hardware, leaves generation to the in-kernel PRNG), runs the
fused kernel, and unflattens. Drop-in replacement for the unfused
distributed_commit+advance pair's elementwise work.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.plane import ParamPlane
from repro.kernels.sdm_update.sdm_update import (LANE, DEFAULT_BLOCK_ROWS,
                                                 sdm_update_pallas)
from repro.kernels.sdm_update import ref as ref_mod

PyTree = Any


def sdm_update(x_tree: PyTree, s_tree: PyTree, nb_tree: PyTree,
               g_tree: PyTree, key: jax.Array, *, p: float, theta: float,
               gamma: float, sigma: float, clip_c: float | None,
               self_w: float, block_rows: int = DEFAULT_BLOCK_ROWS,
               use_kernel: bool = True, interpret: bool = True
               ) -> Tuple[PyTree, PyTree, PyTree]:
    """Returns (x_new, s_new, sd) trees. ``key`` drives mask+noise bits."""
    spec = ParamPlane.for_tree(x_tree, lane=LANE, row_multiple=block_rows,
                               buckets=None)
    assert spec.n_buckets == 1, "kernel plane is bucket-free by construction"
    x = spec.pack(x_tree)[0]
    s = spec.pack(s_tree)[0]
    nb = spec.pack(nb_tree)[0]
    g = spec.pack(g_tree)[0]
    kb, k1, k2 = jax.random.split(key, 3)
    # Draw bits at the canonical LANE-padded size, NOT x.shape: threefry
    # output depends on the total draw size, so tying the draw to the
    # block_rows tile padding would make the mask (and the whole
    # trajectory) change with the kernel's tiling parameter.
    n_rows = -(-spec.total_size // LANE)

    def bits(k: jax.Array) -> jax.Array:
        b = jax.random.bits(k, (n_rows, LANE), jnp.uint32)
        return jnp.pad(b, ((0, x.shape[0] - n_rows), (0, 0)))
    fn = sdm_update_pallas if use_kernel else _ref_adapter
    x2, s2, sd = fn(x, s, nb, g, bits(kb), bits(k1), bits(k2), p=p,
                    theta=theta, gamma=gamma, sigma=sigma, clip_c=clip_c,
                    self_w=self_w,
                    **({"block_rows": block_rows, "interpret": interpret}
                       if use_kernel else {}))
    return (spec.unpack((x2,)), spec.unpack((s2,)), spec.unpack((sd,)))


def _ref_adapter(x, s, nb, g, mb, n1, n2, **kw):
    return ref_mod.sdm_update_ref(x, s, nb, g, mb, n1, n2, **kw)
