"""Pure-jnp oracle for the fused SDM-DSGD update kernel.

Bit-identical math to the kernel given the same uniform bit streams.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

_TWO_PI = 2.0 * math.pi
_INV24 = 1.0 / (1 << 24)


def _uniform01(bits: jax.Array) -> jax.Array:
    u = (bits >> 8).astype(jnp.float32) * _INV24
    return jnp.maximum(u, _INV24)


def sdm_update_ref(x, s, nb_sum, g, mask_bits, n1_bits, n2_bits, *, p,
                   theta, gamma, sigma, clip_c, self_w
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    s = s + nb_sum
    if clip_c is not None:
        g = jnp.clip(g, -clip_c, clip_c)
    if sigma > 0.0:
        u1 = _uniform01(n1_bits)
        u2 = _uniform01(n2_bits)
        gauss = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(_TWO_PI * u2)
        g = g + sigma * gauss
    y = (1.0 - theta) * x + theta * (self_w * x + s - gamma * g)
    d = y - x
    keep = _uniform01(mask_bits) < p
    sd = jnp.where(keep, d / p, 0.0)
    return x + sd, s, sd
