"""The paper's own experimental models (§5): MLR, small CNN, ResNet-20.

Pure-JAX functional implementations matching the paper's descriptions:
  * MLR — multi-class logistic regression (784 -> 10).
  * CNN — two 3x3x16 conv layers, each + 2x2 max-pool, ReLU, then a fully
    connected layer with softmax output.
  * ResNet-20 — the standard CIFAR-10 ResNet (3 stages x 3 basic blocks),
    batch-norm replaced by group norm (decentralized training keeps no
    shared batch statistics across nodes).
Inputs arrive flat (784 / 3072) and are reshaped internally.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# --------------------------------------------------------------------------
# MLR
# --------------------------------------------------------------------------

def mlr_init(key: jax.Array, n_features: int = 784,
             n_classes: int = 10) -> PyTree:
    return {"w": jnp.zeros((n_features, n_classes), jnp.float32),
            "b": jnp.zeros((n_classes,), jnp.float32)}


def mlr_apply(params: PyTree, x: jax.Array) -> jax.Array:
    return x @ params["w"] + params["b"]


# --------------------------------------------------------------------------
# CNN (paper's MNIST/CIFAR model)
# --------------------------------------------------------------------------

def _conv_init(key, kh, kw, cin, cout):
    std = 1.0 / math.sqrt(kh * kw * cin)
    return std * jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)


def cnn_init(key: jax.Array, image_hw: Tuple[int, int, int]) -> PyTree:
    h, w, c = image_hw
    k1, k2, k3 = jax.random.split(key, 3)
    flat = (h // 4) * (w // 4) * 16
    return {
        "conv1": _conv_init(k1, 3, 3, c, 16),
        "b1": jnp.zeros((16,), jnp.float32),
        "conv2": _conv_init(k2, 3, 3, 16, 16),
        "b2": jnp.zeros((16,), jnp.float32),
        "fc": (1.0 / math.sqrt(flat)) * jax.random.normal(
            k3, (flat, 10), jnp.float32),
        "fc_b": jnp.zeros((10,), jnp.float32),
    }


def _conv(x, w, b):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + b


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


def cnn_apply(params: PyTree, x_flat: jax.Array,
              image_hw: Tuple[int, int, int]) -> jax.Array:
    h, w, c = image_hw
    x = x_flat.reshape(-1, h, w, c)
    x = _maxpool2(jax.nn.relu(_conv(x, params["conv1"], params["b1"])))
    x = _maxpool2(jax.nn.relu(_conv(x, params["conv2"], params["b2"])))
    x = x.reshape(x.shape[0], -1)
    return x @ params["fc"] + params["fc_b"]


# --------------------------------------------------------------------------
# ResNet-20 (CIFAR-10), group-norm variant
# --------------------------------------------------------------------------

def _gn(x, gamma, beta, groups=8, eps=1e-5):
    n, h, w, c = x.shape
    g = x.reshape(n, h, w, groups, c // groups)
    mean = g.mean(axis=(1, 2, 4), keepdims=True)
    var = g.var(axis=(1, 2, 4), keepdims=True)
    g = (g - mean) * jax.lax.rsqrt(var + eps)
    return g.reshape(n, h, w, c) * gamma + beta


def resnet20_init(key: jax.Array) -> PyTree:
    keys = iter(jax.random.split(key, 64))
    params: Dict[str, Any] = {
        "stem": _conv_init(next(keys), 3, 3, 3, 16),
        "stem_g": jnp.ones((16,)), "stem_b": jnp.zeros((16,)),
    }
    cin = 16
    for stage, cout in enumerate((16, 32, 64)):
        for block in range(3):
            pre = f"s{stage}b{block}"
            params[f"{pre}_c1"] = _conv_init(next(keys), 3, 3, cin, cout)
            params[f"{pre}_g1"] = jnp.ones((cout,))
            params[f"{pre}_b1"] = jnp.zeros((cout,))
            params[f"{pre}_c2"] = _conv_init(next(keys), 3, 3, cout, cout)
            params[f"{pre}_g2"] = jnp.ones((cout,))
            params[f"{pre}_b2"] = jnp.zeros((cout,))
            if cin != cout:
                params[f"{pre}_proj"] = _conv_init(next(keys), 1, 1, cin, cout)
            cin = cout
    params["fc"] = (1.0 / 8.0) * jax.random.normal(next(keys), (64, 10))
    params["fc_b"] = jnp.zeros((10,))
    return params


def resnet20_apply(params: PyTree, x_flat: jax.Array) -> jax.Array:
    x = x_flat.reshape(-1, 32, 32, 3)
    x = jax.nn.relu(_gn(_conv(x, params["stem"], 0.0), params["stem_g"],
                        params["stem_b"]))
    for stage, cout in enumerate((16, 32, 64)):
        for block in range(3):
            pre = f"s{stage}b{block}"
            stride = 2 if (stage > 0 and block == 0) else 1
            h = jax.lax.conv_general_dilated(
                x, params[f"{pre}_c1"], (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            h = jax.nn.relu(_gn(h, params[f"{pre}_g1"], params[f"{pre}_b1"]))
            h = _conv(h, params[f"{pre}_c2"], 0.0)
            h = _gn(h, params[f"{pre}_g2"], params[f"{pre}_b2"])
            sc = x
            if f"{pre}_proj" in params:
                sc = jax.lax.conv_general_dilated(
                    x, params[f"{pre}_proj"], (stride, stride), "SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(h + sc)
    x = x.mean(axis=(1, 2))
    return x @ params["fc"] + params["fc_b"]


# --------------------------------------------------------------------------
# Shared loss/grad helpers for the decentralized trainers
# --------------------------------------------------------------------------

def make_stacked_grad_fn(apply_fn):
    """(params_stack, (x_stack, y_stack)) -> (grads_stack, mean_loss)."""

    def node_loss(params, xy):
        x, y = xy
        logits = apply_fn(params, x)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
        return nll

    def grad_fn(params_stack, batch_stack):
        losses, grads = jax.vmap(
            lambda p, xy: jax.value_and_grad(node_loss)(p, xy)
        )(params_stack, batch_stack)
        return grads, losses.mean()

    return grad_fn


def make_eval_fn(apply_fn, x_test, y_test):
    @jax.jit
    def eval_fn(params_stack):
        params = jax.tree.map(lambda p: p.mean(axis=0), params_stack)
        logits = apply_fn(params, x_test)
        return (jnp.argmax(logits, -1) == y_test).mean()

    return eval_fn
