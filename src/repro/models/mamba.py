"""Mamba (S6) block as used by Jamba — selective state-space mixer.

Training/prefill: the recurrence h_t = A_t * h_{t-1} + B_t x_t is computed
with ``jax.lax.associative_scan`` over the sequence (parallel prefix —
the TPU-friendly formulation; the CUDA "selective scan" kernel has no
warp-level trick we need to port, the associativity IS the algorithm).
Decode: a single O(1) recurrence step carrying (conv_state, ssm_state).

Shapes follow Jamba: d_inner = 2*d_model, d_state = 16, d_conv = 4,
dt_rank = d_model/16.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec, rms_norm
from repro.sharding import logical

__all__ = ["MambaState", "mamba_specs", "mamba_apply", "mamba_decode_step",
           "init_mamba_state"]


class MambaState(NamedTuple):
    conv: jax.Array  # (b, d_conv - 1, d_inner) — last inputs for the causal conv
    ssm: jax.Array   # (b, d_inner, d_state)


def mamba_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, di = cfg.d_model, cfg.mamba_d_inner
    ds, dc, dtr = cfg.mamba_d_state, cfg.mamba_d_conv, cfg.mamba_dt_rank
    return {
        "norm": ParamSpec((d,), ("embed",), "ones"),
        "w_in": ParamSpec((d, 2 * di), ("embed", "mlp")),      # x and z branches
        "conv_w": ParamSpec((dc, di), (None, "mlp")),
        "conv_b": ParamSpec((di,), ("mlp",), "zeros"),
        "w_x_dbc": ParamSpec((di, dtr + 2 * ds), ("mlp", None)),
        "w_dt": ParamSpec((dtr, di), (None, "mlp")),
        "dt_bias": ParamSpec((di,), ("mlp",), "zeros"),
        "a_log": ParamSpec((di, ds), ("mlp", None), "ones"),    # A = -exp(a_log)
        "d_skip": ParamSpec((di,), ("mlp",), "ones"),
        "w_out": ParamSpec((di, d), ("mlp", "embed")),
    }


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    return MambaState(
        conv=jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner), dtype),
        ssm=jnp.zeros((batch, cfg.mamba_d_inner, cfg.mamba_d_state), jnp.float32))


def _ssm_params(params, cfg: ModelConfig, u: jax.Array):
    """Input-dependent (dt, B, C) and continuous A. u: (b, s, di)."""
    ds, dtr = cfg.mamba_d_state, cfg.mamba_dt_rank
    dbc = jnp.einsum("bsi,ir->bsr", u, params["w_x_dbc"])
    dt_in, B, C = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_in, params["w_dt"]) + params["dt_bias"])
    A = -jnp.exp(params["a_log"].astype(jnp.float32))           # (di, ds)
    return dt, B, C, A


def _discretize(dt, A, B, u, scan_dtype=jnp.float32):
    """ZOH-ish discretization: Abar = exp(dt A), Bbar x = dt * B * x.

    ``scan_dtype`` controls the storage dtype of the (b, s, d_inner,
    d_state) scan elements — by far the largest activation tensor of a
    Mamba layer; bf16 halves its HBM traffic (a §Perf lever).
    """
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A).astype(scan_dtype)
    dBx = (dt[..., None] * B[:, :, None, :] * u[..., None]).astype(scan_dtype)
    return dA, dBx


def mamba_apply(params: Dict[str, jax.Array], cfg: ModelConfig,
                x: jax.Array, return_state: bool = False):
    """Full-sequence mixer. x: (b, s, d) -> (b, s, d) [, final MambaState]."""
    b, s, d = x.shape
    di, dc = cfg.mamba_d_inner, cfg.mamba_d_conv
    residual = x
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    xz = jnp.einsum("bsd,de->bse", h, params["w_in"])
    xz = logical(xz, "batch", "seq", "mlp")
    u_raw, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv over seq (kernel dc)
    u_pad = jnp.pad(u_raw, ((0, 0), (dc - 1, 0), (0, 0)))
    conv = sum(u_pad[:, i:i + s, :] * params["conv_w"][i] for i in range(dc))
    u = jax.nn.silu(conv + params["conv_b"])

    dt, B, C, A = _ssm_params(params, cfg, u)
    scan_dtype = jnp.dtype(cfg.mamba_scan_dtype)
    dA, dBx = _discretize(dt, A, B, u, scan_dtype)

    # parallel prefix over the sequence: h_t = dA_t h_{t-1} + dBx_t
    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_r * a_l, a_r * b_l + b_r

    _, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("bsin,bsn->bsi", hs.astype(jnp.float32),
                   C.astype(jnp.float32))
    y = y.astype(u.dtype) + params["d_skip"] * u
    y = y * jax.nn.silu(z)
    y = logical(y, "batch", "seq", "mlp")
    out = jnp.einsum("bsi,id->bsd", y, params["w_out"])
    out = residual + logical(out, "batch", "seq", "embed")
    if not return_state:
        return out
    state = MambaState(conv=u_raw[:, s - (dc - 1):, :],
                       ssm=hs[:, -1].astype(jnp.float32))
    return out, state


def mamba_decode_step(params: Dict[str, jax.Array], cfg: ModelConfig,
                      x: jax.Array, state: MambaState
                      ) -> Tuple[jax.Array, MambaState]:
    """One-token step. x: (b, 1, d); O(1) in sequence length."""
    b, _, d = x.shape
    residual = x
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    xz = jnp.einsum("bsd,de->bse", h, params["w_in"])
    u, z = jnp.split(xz, 2, axis=-1)                            # (b, 1, di)

    conv_hist = jnp.concatenate([state.conv, u], axis=1)        # (b, dc, di)
    conv = jnp.einsum("bci,ci->bi", conv_hist, params["conv_w"])[:, None, :]
    u = jax.nn.silu(conv + params["conv_b"])

    dt, B, C, A = _ssm_params(params, cfg, u)
    dA, dBx = _discretize(dt, A, B, u)                          # (b, 1, di, ds)
    ssm = dA[:, 0] * state.ssm + dBx[:, 0]                      # (b, di, ds)
    y = jnp.einsum("bin,bn->bi", ssm, C[:, 0].astype(jnp.float32))[:, None, :]
    y = y.astype(u.dtype) + params["d_skip"] * u
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, params["w_out"])
    new_state = MambaState(conv=conv_hist[:, 1:], ssm=ssm)
    return residual + out, new_state
