"""Model zoo: composable decoder/enc-dec/SSM/MoE transformer backbones."""
from repro.models.config import LayerSpec, ModelConfig
from repro.models import transformer

__all__ = ["LayerSpec", "ModelConfig", "transformer"]
