"""Mixture-of-Experts layer: top-k router + capacity-based gather dispatch.

Gather/scatter dispatch (not one-hot einsum) so the compiled FLOPs reflect
real expert work — important for the roofline analysis. Expert weights are
stacked on a leading ``experts`` axis and shard expert-parallel over the
``model`` mesh axis (8 experts/chip for qwen3-moe on a 16-wide axis).

Capacity: c = ceil(top_k * tokens / n_experts * capacity_factor); tokens
beyond an expert's capacity are dropped (their combine weight is 0) — the
standard GShard/Switch behaviour. Aux load-balance loss included.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec, rms_norm, _activation
from repro.sharding import logical

__all__ = ["moe_specs", "moe_apply"]


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, fe, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    specs = {
        "router": ParamSpec((d, e), ("embed", None)),
        "w_up": ParamSpec((e, d, fe), ("experts", "embed", "mlp")),
        "w_down": ParamSpec((e, fe, d), ("experts", "mlp", "embed")),
        "norm": ParamSpec((d,), ("embed",),
                          "zeros" if cfg.post_block_norm else "ones"),
    }
    if cfg.glu:
        specs["w_gate"] = ParamSpec((e, d, fe), ("experts", "embed", "mlp"))
    if cfg.post_block_norm:
        specs["post_norm"] = ParamSpec((d,), ("embed",), "zeros")
    return specs


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.top_k * n_tokens * cfg.capacity_factor / cfg.n_experts)
    return max(cfg.top_k, min(c, n_tokens))


def moe_apply(params: Dict[str, jax.Array], cfg: ModelConfig,
              x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss). x: (b, s, d)."""
    b, s, d = x.shape
    residual = x
    h = rms_norm(x, params["norm"], cfg.norm_eps, plus_one=cfg.post_block_norm)
    h = logical(h, "batch", "seq", "embed")

    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(cfg, t)
    xt = h.reshape(t, d)

    # --- routing ----------------------------------------------------------
    router_logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32), params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)          # (t, e)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (t, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style aux loss: e * sum_e fraction_tokens_e * mean_prob_e.
    onehot = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
    aux = e * jnp.sum(jnp.mean(onehot, axis=0) * jnp.mean(probs, axis=0))

    # --- slotting: position of each (token, k) within its expert ----------
    # Sort-based ranking instead of a cumsum over the (t*k, e) one-hot:
    # same token-priority semantics, but O(n log n) work and no (t*k, e)
    # intermediate (the cumsum's windowed cost also poisoned the roofline
    # compute term under XLA's cost model).
    flat_expert = expert_ids.reshape(-1)                    # (t*k,)
    tk = flat_expert.shape[0]
    order = jnp.argsort(flat_expert, stable=True)           # groups experts,
    sorted_e = flat_expert[order]                           # keeps token order
    group_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    seg_pos = jnp.arange(tk, dtype=jnp.int32) - group_start.astype(jnp.int32)
    pos = jnp.zeros((tk,), jnp.int32).at[order].set(seg_pos)
    keep = pos < cap
    token_of = jnp.repeat(jnp.arange(t), k)

    # slot -> token map; dropped slots point at a padding row (index t).
    slot_token = jnp.full((e, cap), t, dtype=jnp.int32)
    write_pos = jnp.where(keep, pos, cap)  # cap = out-of-bounds -> dropped
    slot_token = slot_token.at[flat_expert, write_pos].set(token_of, mode="drop")
    slot_gate = jnp.zeros((e, cap), dtype=jnp.float32)
    slot_gate = slot_gate.at[flat_expert, write_pos].set(
        gate_vals.reshape(-1), mode="drop")

    # --- expert compute ----------------------------------------------------
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = jnp.take(xt_pad, slot_token, axis=0)               # (e, cap, d)
    xe = logical(xe, "experts", None, "embed")
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    if cfg.glu:
        gate = _activation(
            jnp.einsum("ecd,edf->ecf", xe, params["w_gate"]), cfg.act)
        up = gate * up
    else:
        up = _activation(up, cfg.act)
    up = logical(up, "experts", None, "mlp")
    ye = jnp.einsum("ecf,efd->ecd", up, params["w_down"])   # (e, cap, d)
    ye = ye * slot_gate[..., None].astype(ye.dtype)

    # --- combine -----------------------------------------------------------
    out = jnp.zeros((t + 1, d), ye.dtype)
    out = out.at[slot_token.reshape(-1)].add(ye.reshape(-1, d), mode="drop")
    out = out[:t].reshape(b, s, d)
    out = logical(out, "batch", "seq", "embed")
    if cfg.post_block_norm:
        out = rms_norm(out, params["post_norm"], cfg.norm_eps, plus_one=True)
    return residual + out, aux.astype(jnp.float32)
