"""RWKV-6 "Finch" block: attention-free time-mix with data-dependent decay.

Per head (head size N): state S in R^{N x N} evolves as

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (diag(u) k_t^T v_t + S_{t-1})

with data-dependent decay w_t = exp(-exp(wproj(x_t))) (Finch's dynamic
decay — the paper's headline change vs RWKV-5). Training runs the
recurrence with ``lax.scan`` over the sequence (O(s) state updates);
decode carries (shift, state) with O(1) per-token work — this is why
rwkv6-3b runs the long_500k shape.

Simplifications vs the reference implementation (noted in DESIGN.md):
token-shift uses a plain lerp with learned mix vectors (no LoRA on the
mix weights), and the output gate is SiLU instead of the learned
group-norm + gate stack. Structure/FLOP shape is faithful.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec, rms_norm
from repro.sharding import logical

__all__ = ["RWKVState", "rwkv_time_mix_specs", "rwkv_channel_mix_specs",
           "rwkv_time_mix", "rwkv_channel_mix", "init_rwkv_state",
           "rwkv_time_mix_step", "rwkv_channel_mix_step"]


class RWKVState(NamedTuple):
    att_shift: jax.Array   # (b, d) last token's x at the time-mix input
    ffn_shift: jax.Array   # (b, d) last token's x at the channel-mix input
    wkv: jax.Array         # (b, heads, N, N) fp32 recurrent state


def rwkv_time_mix_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    n = cfg.rwkv_head_size
    h = cfg.rwkv_n_heads
    return {
        "norm": ParamSpec((d,), ("embed",), "ones"),
        "mix_r": ParamSpec((d,), ("embed",), "half"),
        "mix_k": ParamSpec((d,), ("embed",), "half"),
        "mix_v": ParamSpec((d,), ("embed",), "half"),
        "mix_w": ParamSpec((d,), ("embed",), "half"),
        "mix_g": ParamSpec((d,), ("embed",), "half"),
        "w_r": ParamSpec((d, h * n), ("embed", "heads_flat")),
        "w_k": ParamSpec((d, h * n), ("embed", "heads_flat")),
        "w_v": ParamSpec((d, h * n), ("embed", "heads_flat")),
        "w_g": ParamSpec((d, d), ("embed", "mlp")),
        "w_decay": ParamSpec((d, h * n), ("embed", "heads_flat"), scale=0.1),
        "decay_bias": ParamSpec((h, n), ("heads", None), "zeros"),
        "bonus_u": ParamSpec((h, n), ("heads", None), "zeros"),
        "w_out": ParamSpec((d, d), ("mlp", "embed")),
    }


def rwkv_channel_mix_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "norm": ParamSpec((d,), ("embed",), "ones"),
        "mix_k": ParamSpec((d,), ("embed",), "half"),
        "mix_r": ParamSpec((d,), ("embed",), "half"),
        "w_k": ParamSpec((d, f), ("embed", "mlp")),
        "w_v": ParamSpec((f, d), ("mlp", "embed")),
        "w_r": ParamSpec((d, d), ("embed", "mlp")),
    }


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> RWKVState:
    d, h, n = cfg.d_model, cfg.rwkv_n_heads, cfg.rwkv_head_size
    return RWKVState(att_shift=jnp.zeros((batch, d), dtype),
                     ffn_shift=jnp.zeros((batch, d), dtype),
                     wkv=jnp.zeros((batch, h, n, n), jnp.float32))


def _shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """Token shift: x_{t-1} sequence (prev fills t=0). x: (b, s, d)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _time_mix_projections(params, cfg, x, x_prev):
    b, s, _ = x.shape
    h, n = cfg.rwkv_n_heads, cfg.rwkv_head_size
    lerp = lambda mix, a, bb: a + (bb - a) * mix
    xr = lerp(params["mix_r"], x, x_prev)
    xk = lerp(params["mix_k"], x, x_prev)
    xv = lerp(params["mix_v"], x, x_prev)
    xw = lerp(params["mix_w"], x, x_prev)
    xg = lerp(params["mix_g"], x, x_prev)
    heads = lambda t: t.reshape(b, s, h, n)
    r = heads(jnp.einsum("bsd,de->bse", xr, params["w_r"]))
    k = heads(jnp.einsum("bsd,de->bse", xk, params["w_k"]))
    v = heads(jnp.einsum("bsd,de->bse", xv, params["w_v"]))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, params["w_g"]))
    # Finch data-dependent decay in (0, 1): exp(-exp(.)) of a projection.
    wlog = heads(jnp.einsum("bsd,de->bse", xw, params["w_decay"])) \
        + params["decay_bias"]
    w = jnp.exp(-jnp.exp(wlog.astype(jnp.float32)))
    return r, k, v, g, w


def rwkv_time_mix(params: Dict[str, jax.Array], cfg: ModelConfig,
                  x: jax.Array, state: RWKVState
                  ) -> Tuple[jax.Array, RWKVState]:
    """Full-sequence time-mix. x: (b, s, d)."""
    b, s, d = x.shape
    residual = x
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    h = logical(h, "batch", "seq", "embed")
    x_prev = _shift(h, state.att_shift)
    r, k, v, g, w = _time_mix_projections(params, cfg, h, x_prev)
    u = params["bonus_u"].astype(jnp.float32)

    def step(S, rkvw):
        r_t, k_t, v_t, w_t = rkvw                    # (b, h, n) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t).astype(jnp.float32)
        o = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                       S + u[None, :, :, None] * kv)
        S = w_t.astype(jnp.float32)[..., None] * S + kv
        return S, o

    seq_first = lambda a: a.transpose(1, 0, 2, 3)
    S, outs = jax.lax.scan(
        step, state.wkv, (seq_first(r), seq_first(k), seq_first(v),
                          seq_first(w)))
    o = outs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    o = o * g
    out = jnp.einsum("bsd,de->bse", o, params["w_out"])
    new_state = state._replace(att_shift=h[:, -1, :], wkv=S)
    return residual + logical(out, "batch", "seq", "embed"), new_state


def rwkv_time_mix_step(params, cfg: ModelConfig, x: jax.Array,
                       state: RWKVState) -> Tuple[jax.Array, RWKVState]:
    """One-token decode step; O(1) state update. x: (b, 1, d)."""
    residual = x
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    x_prev = state.att_shift[:, None, :]
    r, k, v, g, w = _time_mix_projections(params, cfg, h, x_prev)
    u = params["bonus_u"].astype(jnp.float32)
    r1, k1, v1, w1 = (a[:, 0] for a in (r, k, v, w))
    kv = jnp.einsum("bhk,bhv->bhkv", k1, v1).astype(jnp.float32)
    o = jnp.einsum("bhk,bhkv->bhv", r1.astype(jnp.float32),
                   state.wkv + u[None, :, :, None] * kv)
    S = w1.astype(jnp.float32)[..., None] * state.wkv + kv
    o = o.reshape(x.shape[0], 1, -1).astype(x.dtype) * g
    out = jnp.einsum("bsd,de->bse", o, params["w_out"])
    new_state = state._replace(att_shift=h[:, 0, :], wkv=S)
    return residual + out, new_state


def rwkv_channel_mix(params, cfg: ModelConfig, x: jax.Array,
                     state: RWKVState) -> Tuple[jax.Array, RWKVState]:
    residual = x
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    x_prev = _shift(h, state.ffn_shift)
    lerp = lambda mix, a, b: a + (b - a) * mix
    xk = lerp(params["mix_k"], h, x_prev)
    xr = lerp(params["mix_r"], h, x_prev)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, params["w_k"])))
    k = logical(k, "batch", "seq", "mlp")
    kv = jnp.einsum("bsf,fd->bsd", k, params["w_v"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["w_r"]))
    new_state = state._replace(ffn_shift=h[:, -1, :])
    return residual + logical(r * kv, "batch", "seq", "embed"), new_state


def rwkv_channel_mix_step(params, cfg: ModelConfig, x: jax.Array,
                          state: RWKVState) -> Tuple[jax.Array, RWKVState]:
    residual = x
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    x_prev = state.ffn_shift[:, None, :]
    lerp = lambda mix, a, b: a + (b - a) * mix
    xk = lerp(params["mix_k"], h, x_prev)
    xr = lerp(params["mix_r"], h, x_prev)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, params["w_k"])))
    kv = jnp.einsum("bsf,fd->bsd", k, params["w_v"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["w_r"]))
    new_state = state._replace(ffn_shift=h[:, 0, :])
    return residual + r * kv, new_state
