"""Model composition: embeddings + scanned layer periods + heads.

The layer stack is expressed as a repeating *period* of LayerSpecs
(config.py). Parameters for each slot in the period are stacked over a
leading ``layers`` axis (n_periods entries) and the whole stack runs
under one ``jax.lax.scan`` — a single compiled layer body regardless of
depth, which keeps HLO small at 64 layers / 512 devices.

Supports: train forward, prefill (builds caches), single-token decode.
Encoder-decoder (whisper) and VLM cross-attention take pre-computed
``context`` embeddings (the modality frontends are stubs per the brief).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import (KVCache, ParamSpec, attention_apply,
                                 attention_decode_paged, attention_specs,
                                 axes_of, init_tree, mlp_apply, mlp_specs,
                                 rms_norm, shapes_of, softcap)
from repro.sharding import logical

__all__ = ["model_specs", "init_params", "param_axes", "param_shapes",
           "forward", "lm_loss", "init_cache", "prefill", "decode_step",
           "decode_step_paged", "Cache"]

PyTree = Any


# --------------------------------------------------------------------------
# Parameter specs
# --------------------------------------------------------------------------

def _slot_specs(cfg: ModelConfig, spec: LayerSpec) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if spec.mixer in ("attn", "attn_local"):
        out["attn"] = attention_specs(cfg)
    elif spec.mixer == "mamba":
        out["mamba"] = mamba_mod.mamba_specs(cfg)
    elif spec.mixer == "rwkv":
        out["time_mix"] = rwkv_mod.rwkv_time_mix_specs(cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        out["cross"] = attention_specs(cfg, cross=True)
    if spec.ffn == "mlp":
        out["mlp"] = mlp_specs(cfg)
    elif spec.ffn == "moe":
        out["moe"] = moe_mod.moe_specs(cfg)
    elif spec.ffn == "rwkv_ffn":
        out["channel_mix"] = rwkv_mod.rwkv_channel_mix_specs(cfg)
    elif spec.ffn is not None:
        raise ValueError(spec.ffn)
    return out


def _stack_specs(specs: PyTree, n: int) -> PyTree:
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init,
                            s.scale),
        specs, is_leaf=lambda v: isinstance(v, ParamSpec))


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.padded_vocab, d), ("vocab", "embed")),
        "final_norm": ParamSpec((d,), ("embed",), "ones"),
        "blocks": {
            str(i): _stack_specs(_slot_specs(cfg, s), cfg.n_periods)
            for i, s in enumerate(cfg.period)
        },
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, cfg.padded_vocab), ("embed", "vocab"))
    if cfg.pos_embedding == "learned":
        specs["pos_embed"] = ParamSpec(
            (cfg.max_position_embeddings, d), (None, "embed"), scale=0.02)
    if cfg.has_encoder:
        enc_layer = {
            "attn": attention_specs(cfg),
            "mlp": mlp_specs(cfg),
        }
        specs["encoder"] = {
            "layers": _stack_specs(enc_layer, cfg.n_encoder_layers),
            "final_norm": ParamSpec((d,), ("embed",), "ones"),
        }
    return specs


def init_params(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> PyTree:
    return init_tree(key, model_specs(cfg), dtype)


def param_axes(cfg: ModelConfig) -> PyTree:
    return axes_of(model_specs(cfg))


def param_shapes(cfg: ModelConfig) -> PyTree:
    return shapes_of(model_specs(cfg))


# --------------------------------------------------------------------------
# Caches
# --------------------------------------------------------------------------

class Cache(NamedTuple):
    """Per-slot caches, each stacked over the period axis (n_periods, ...)."""
    slots: Dict[str, Any]
    offset: jax.Array  # () int32 — number of tokens already in the cache


def _slot_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int,
                dtype) -> Any:
    n = cfg.n_periods
    if spec.mixer in ("attn", "attn_local"):
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        shape = (n, batch, max_len, kv, hd)
        return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))
    if spec.mixer == "mamba":
        st = mamba_mod.init_mamba_state(cfg, batch, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), st)
    if spec.mixer == "rwkv":
        st = rwkv_mod.init_rwkv_state(cfg, batch, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), st)
    raise ValueError(spec.mixer)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Cache:
    return Cache(
        slots={str(i): _slot_cache(cfg, s, batch, max_len, dtype)
               for i, s in enumerate(cfg.period)},
        offset=jnp.zeros((), jnp.int32))


def cache_logical_axes(cfg: ModelConfig) -> Cache:
    """Logical axes tree matching init_cache's structure."""
    def slot_axes(spec: LayerSpec):
        if spec.mixer in ("attn", "attn_local"):
            a = ("layers", "batch", "cache_seq", "kv_heads", None)
            return KVCache(k=a, v=a)
        if spec.mixer == "mamba":
            return mamba_mod.MambaState(
                conv=("layers", "batch", None, "mlp"),
                ssm=("layers", "batch", "mlp", None))
        if spec.mixer == "rwkv":
            return rwkv_mod.RWKVState(
                att_shift=("layers", "batch", "embed"),
                ffn_shift=("layers", "batch", "embed"),
                wkv=("layers", "batch", "heads", None, None))
        raise ValueError(spec.mixer)

    return Cache(slots={str(i): slot_axes(s)
                        for i, s in enumerate(cfg.period)},
                 offset=())


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------

def _embed_tokens(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return logical(x, "batch", "seq", "embed")


def _logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = softcap(logits, cfg.logit_softcap)
    return logical(logits, "batch", "seq", "vocab")


def encode_context(params, cfg: ModelConfig,
                   context: Optional[jax.Array]) -> Optional[jax.Array]:
    """Public: pre-encode context once for serving (see decode_step)."""
    return _encode_context(params, cfg, context)


def _encode_context(params, cfg: ModelConfig,
                    context: Optional[jax.Array]) -> Optional[jax.Array]:
    """Whisper: run the encoder stack over stub frame embeddings.
    VLM: pass the stub patch embeddings straight through."""
    if context is None or not cfg.has_encoder:
        return context
    enc = params["encoder"]
    b, s, _ = context.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, layer):
        x, _ = attention_apply(layer["attn"], cfg, x, positions=positions,
                               causal=False, use_rope=False)
        x = mlp_apply(layer["mlp"], cfg, x)
        return x, None

    if cfg.unroll_layers:
        x = context
        for i in range(cfg.n_encoder_layers):
            x, _ = body(x, jax.tree.map(lambda v: v[i], enc["layers"]))
    else:
        x, _ = jax.lax.scan(body, context, enc["layers"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def _apply_slot_full(cfg: ModelConfig, spec: LayerSpec, slot_params,
                     x: jax.Array, positions: jax.Array,
                     context: Optional[jax.Array],
                     init_state, want_state: bool):
    """One layer slot over a full sequence. Returns (x, aux, new_state)."""
    aux = jnp.zeros((), jnp.float32)
    state = None
    if spec.mixer in ("attn", "attn_local"):
        if want_state:
            # prefill: write this call's k/v into the provided cache
            x, state = attention_apply(
                slot_params["attn"], cfg, x, positions=positions,
                layer_kind=spec.mixer, cache=init_state,
                cache_offset=jnp.zeros((), jnp.int32))
        else:
            x, _ = attention_apply(slot_params["attn"], cfg, x,
                                   positions=positions, layer_kind=spec.mixer)
    elif spec.mixer == "mamba":
        if want_state:
            x, state = mamba_mod.mamba_apply(slot_params["mamba"], cfg, x,
                                             return_state=True)
        else:
            x = mamba_mod.mamba_apply(slot_params["mamba"], cfg, x)
    elif spec.mixer == "rwkv":
        rstate = init_state if init_state is not None else \
            rwkv_mod.init_rwkv_state(cfg, x.shape[0], x.dtype)
        x, rstate = rwkv_mod.rwkv_time_mix(slot_params["time_mix"], cfg, x,
                                           rstate)
        state = rstate

    if spec.cross_attn and context is not None:
        x, _ = attention_apply(slot_params["cross"], cfg, x,
                               positions=positions, kv_source=context)

    if spec.ffn == "mlp":
        x = mlp_apply(slot_params["mlp"], cfg, x)
    elif spec.ffn == "moe":
        x, aux = moe_mod.moe_apply(slot_params["moe"], cfg, x)
    elif spec.ffn == "rwkv_ffn":
        x, state = rwkv_mod.rwkv_channel_mix(slot_params["channel_mix"], cfg,
                                             x, state)
    return x, aux, state



def _scan_periods(cfg: ModelConfig, body, init_carry, xs):
    """lax.scan over stacked periods, or a python loop when
    cfg.unroll_layers (exact cost_analysis: XLA counts while-loop bodies
    once regardless of trip count, so cost probes must unroll)."""
    if not cfg.unroll_layers:
        return jax.lax.scan(body, init_carry, xs)
    carry = init_carry
    ys = []
    for i in range(cfg.n_periods):
        carry, y = body(carry, jax.tree.map(lambda v: v[i], xs))
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *vs: jnp.stack(vs, axis=0), *ys)
    return carry, stacked


def forward(params: PyTree, cfg: ModelConfig, tokens: jax.Array, *,
            context: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Training forward. tokens: (b, s) -> (logits (b, s, V), aux_loss)."""
    b, s = tokens.shape
    x = _embed_tokens(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if cfg.pos_embedding == "learned":
        x = x + params["pos_embed"][:s][None]
    ctx = _encode_context(params, cfg, context)

    def period_body(x, period_params):
        aux = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(cfg.period):
            x, a, _ = _apply_slot_full(cfg, spec, period_params[str(i)], x,
                                       positions, ctx, None, False)
            aux = aux + a
        return x, aux

    if cfg.remat:
        period_body = jax.checkpoint(
            period_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, auxs = _scan_periods(cfg, period_body, x, params["blocks"])
    return _logits(params, cfg, x), jnp.sum(auxs)


def lm_loss(logits: jax.Array, labels: jax.Array, vocab_size: int,
            aux: jax.Array = 0.0, aux_weight: float = 0.01) -> jax.Array:
    """Next-token cross entropy; the padded vocab tail is masked out."""
    v = logits.shape[-1]
    pad_mask = jnp.arange(v) >= vocab_size
    logits = jnp.where(pad_mask, -1e30, logits.astype(jnp.float32))
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold) + aux_weight * aux


# --------------------------------------------------------------------------
# Prefill & decode
# --------------------------------------------------------------------------

def prefill(params: PyTree, cfg: ModelConfig, tokens: jax.Array,
            cache: Cache, *, context: Optional[jax.Array] = None,
            last_index: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Cache]:
    """Process a prompt, filling ``cache``. Returns (last-token logits, cache).

    ``cache`` must be created by init_cache with max_len >= prompt + new.
    ``last_index`` (b,) selects each row's OWN last real token for the
    returned logits — required for right-padded unequal-length prompts,
    where the final column is padding for the shorter rows (causal
    masking already keeps their hidden states exact; only the readout
    position differs).
    """
    b, s = tokens.shape
    x = _embed_tokens(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if cfg.pos_embedding == "learned":
        x = x + params["pos_embed"][:s][None]
    ctx = _encode_context(params, cfg, context)

    def period_body(x, scanned):
        period_params, period_cache = scanned
        new_cache = {}
        for i, spec in enumerate(cfg.period):
            x, _, st = _apply_slot_full(cfg, spec, period_params[str(i)], x,
                                        positions, ctx,
                                        period_cache[str(i)], True)
            new_cache[str(i)] = st if st is not None else period_cache[str(i)]
        return x, new_cache

    x, new_slots = _scan_periods(cfg, period_body, x,
                                 (params["blocks"], cache.slots))
    if last_index is None:
        x_last = x[:, -1:, :]
    else:
        x_last = jnp.take_along_axis(x, last_index[:, None, None], axis=1)
    logits = _logits(params, cfg, x_last)
    return logits[:, 0, :], Cache(slots=new_slots,
                                  offset=jnp.asarray(s, jnp.int32))


def decode_step(params: PyTree, cfg: ModelConfig, token: jax.Array,
                cache: Cache, *, context: Optional[jax.Array] = None,
                offsets: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Cache]:
    """One greedy-decode step. token: (b,) int32 -> (logits (b, V), cache).

    ``context`` must be PRE-ENCODED (encode_context) — the encoder runs
    once per request, never per decoded token.
    ``offsets`` (b,) makes the step RAGGED-aware: each row writes its
    token at its own cache position, takes its own RoPE phase, and
    attends only its own valid prefix. Without it every row shares the
    scalar ``cache.offset`` (the legacy equal-length path, unchanged).
    """
    b = token.shape[0]
    x = _embed_tokens(params, cfg, token[:, None])
    if offsets is not None:
        positions = offsets[:, None]
    else:
        positions = jnp.broadcast_to(cache.offset[None, None], (b, 1))
    if cfg.pos_embedding == "learned":
        if offsets is not None:
            x = x + jnp.take(params["pos_embed"], offsets, axis=0)[:, None]
        else:
            x = x + jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], cache.offset, 1, axis=0)[None]
    ctx = context

    def period_body(x, scanned):
        period_params, period_cache = scanned
        new_cache = {}
        for i, spec in enumerate(cfg.period):
            sp = period_params[str(i)]
            pc = period_cache[str(i)]
            if spec.mixer in ("attn", "attn_local"):
                x, kvc = attention_apply(sp["attn"], cfg, x,
                                         positions=positions,
                                         layer_kind=spec.mixer, cache=pc,
                                         cache_offset=cache.offset,
                                         cache_offsets=offsets)
                new_cache[str(i)] = kvc
            elif spec.mixer == "mamba":
                x, mst = mamba_mod.mamba_decode_step(sp["mamba"], cfg, x, pc)
                new_cache[str(i)] = mst
            elif spec.mixer == "rwkv":
                x, rst = rwkv_mod.rwkv_time_mix_step(sp["time_mix"], cfg, x, pc)
                new_cache[str(i)] = rst
            if spec.cross_attn and ctx is not None:
                x, _ = attention_apply(sp["cross"], cfg, x,
                                       positions=positions, kv_source=ctx)
            if spec.ffn == "mlp":
                x = mlp_apply(sp["mlp"], cfg, x)
            elif spec.ffn == "moe":
                x, _ = moe_mod.moe_apply(sp["moe"], cfg, x)
            elif spec.ffn == "rwkv_ffn":
                x, rst2 = rwkv_mod.rwkv_channel_mix_step(
                    sp["channel_mix"], cfg, x, new_cache[str(i)])
                new_cache[str(i)] = rst2
        return x, new_cache

    x, new_slots = _scan_periods(cfg, period_body, x,
                                 (params["blocks"], cache.slots))
    logits = _logits(params, cfg, x)
    return logits[:, 0, :], Cache(slots=new_slots, offset=cache.offset + 1)


def decode_step_paged(params: PyTree, cfg: ModelConfig, token: jax.Array,
                      pages: Dict[str, Any], rec: Dict[str, Any],
                      block_tables: jax.Array, offsets: jax.Array,
                      write_enabled: jax.Array, *,
                      context: Optional[jax.Array] = None,
                      use_flash: bool = False, interpret: bool = True
                      ) -> Tuple[jax.Array, Dict[str, Any], Dict[str, Any]]:
    """One decode step over a PAGED KV cache (continuous-batching engine).

    ``pages``: {period-slot index -> (k_pages, v_pages)} for attention
    slots, each array (n_periods, n_pages, page_size, kv_heads, head_dim)
    — one shared physical page pool per layer slot, scanned over the
    period axis alongside the parameters. ``rec``: {period-slot index ->
    recurrent state} for mamba/rwkv slots (dense per-row state; paging
    only applies to KV). ``block_tables`` (b, n_blocks) and ``offsets``
    (b,) are per-REQUEST-slot; ``write_enabled`` (b,) masks finished /
    empty rows so their writes land on the trash page.

    Returns (logits (b, V), new_pages, new_rec). The whole step is one
    jitted function with no host round-trips — the serving engine's
    done-mask bookkeeping composes around it on device.
    """
    b = token.shape[0]
    x = _embed_tokens(params, cfg, token[:, None])
    positions = offsets[:, None]
    if cfg.pos_embedding == "learned":
        x = x + jnp.take(params["pos_embed"], offsets, axis=0)[:, None]
    ctx = context

    def period_body(x, scanned):
        period_params, period_pages, period_rec = scanned
        new_pages: Dict[str, Any] = {}
        new_rec: Dict[str, Any] = {}
        for i, spec in enumerate(cfg.period):
            si = str(i)
            sp = period_params[si]
            if spec.mixer in ("attn", "attn_local"):
                x, new_pages[si] = attention_decode_paged(
                    sp["attn"], cfg, x, pages=period_pages[si],
                    block_table=block_tables, offsets=offsets,
                    write_enabled=write_enabled, layer_kind=spec.mixer,
                    use_flash=use_flash, interpret=interpret)
            elif spec.mixer == "mamba":
                x, new_rec[si] = mamba_mod.mamba_decode_step(
                    sp["mamba"], cfg, x, period_rec[si])
            elif spec.mixer == "rwkv":
                x, new_rec[si] = rwkv_mod.rwkv_time_mix_step(
                    sp["time_mix"], cfg, x, period_rec[si])
            if spec.cross_attn and ctx is not None:
                x, _ = attention_apply(sp["cross"], cfg, x,
                                       positions=positions, kv_source=ctx)
            if spec.ffn == "mlp":
                x = mlp_apply(sp["mlp"], cfg, x)
            elif spec.ffn == "moe":
                x, _ = moe_mod.moe_apply(sp["moe"], cfg, x)
            elif spec.ffn == "rwkv_ffn":
                x, new_rec[si] = rwkv_mod.rwkv_channel_mix_step(
                    sp["channel_mix"], cfg, x, new_rec[si])
        return x, (new_pages, new_rec)

    x, (new_pages, new_rec) = _scan_periods(
        cfg, period_body, x, (params["blocks"], pages, rec))
    logits = _logits(params, cfg, x)
    return logits[:, 0, :], new_pages, new_rec
