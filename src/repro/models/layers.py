"""Parameter specs and core transformer layers (norms, RoPE, attention, MLP).

All modules are pure functions over dict pytrees. Every parameter is
declared through a ``ParamSpec`` carrying its logical sharding axes, so
``init_params`` / ``axes_of`` / shardings always agree by construction.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding import logical

__all__ = ["ParamSpec", "init_tree", "axes_of", "shapes_of",
           "rms_norm", "rope", "attention_specs", "attention_apply",
           "attention_decode_paged", "mlp_specs", "mlp_apply", "KVCache",
           "softcap"]

PyTree = Any


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones
    scale: float = 1.0        # stddev multiplier for normal init


def _is_spec(v) -> bool:
    return isinstance(v, ParamSpec)


def init_tree(key: jax.Array, specs: PyTree, dtype=jnp.float32) -> PyTree:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            vals.append(jnp.zeros(s.shape, dtype))
        elif s.init == "ones":
            vals.append(jnp.ones(s.shape, dtype))
        elif s.init == "half":
            vals.append(jnp.full(s.shape, 0.5, dtype))
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = s.scale / math.sqrt(max(fan_in, 1))
            vals.append((std * jax.random.normal(k, s.shape)).astype(dtype))
    return jax.tree.unflatten(treedef, vals)


def axes_of(specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def shapes_of(specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.shape, specs, is_leaf=_is_spec)


# --------------------------------------------------------------------------
# Elementary ops
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float,
             plus_one: bool = False) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    x32 = x32 * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:
        w = 1.0 + w
    return (x32 * w).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 soft capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x: jax.Array, positions: jax.Array, theta: float,
         fraction: float = 1.0) -> jax.Array:
    """Rotary embedding on the first ``fraction`` of the head dim.

    x: (b, s, heads, head_dim); positions: (b, s) int32.
    ``fraction=0.5`` reproduces ChatGLM's half-rotary ("2d") scheme.
    """
    head_dim = x.shape[-1]
    rot = int(head_dim * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (b, s, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array  # (b, max_seq, kv_heads, head_dim)
    v: jax.Array


def attention_specs(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamSpec]:
    """Projections use the fused (d_model, heads*head_dim) layout so the
    output dim shards over the model axis even when n_heads itself is not
    divisible by it (40 heads on 16-way TP -> 5120 columns shard fine);
    GSPMD then picks the attention-math sharding by propagation."""
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    specs = {
        "wq": ParamSpec((d, h * hd), ("embed", "heads_flat")),
        "wk": ParamSpec((d, kv * hd), ("embed", "kv_flat")),
        "wv": ParamSpec((d, kv * hd), ("embed", "kv_flat")),
        "wo": ParamSpec((h * hd, d), ("heads_flat", "embed")),
        "norm": ParamSpec((d,), ("embed",),
                          "zeros" if cfg.post_block_norm else "ones"),
    }
    if cfg.qkv_bias and not cross:
        specs["bq"] = ParamSpec((h * hd,), ("heads_flat",), "zeros")
        specs["bk"] = ParamSpec((kv * hd,), ("kv_flat",), "zeros")
        specs["bv"] = ParamSpec((kv * hd,), ("kv_flat",), "zeros")
    if cfg.post_block_norm:
        specs["post_norm"] = ParamSpec((d,), ("embed",), "zeros")
    return specs


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
          q_positions: jax.Array, kv_positions: jax.Array,
          causal: bool, window: Optional[int],
          softcap_val: Optional[float],
          kv_valid_len: Optional[jax.Array] = None) -> jax.Array:
    """Grouped-query scaled dot-product attention.

    q: (b, sq, h, hd); k/v: (b, skv, kv, hd). positions give absolute token
    indices for masking (decode: q_position = current pos).
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    group = h // kvh
    q = q.reshape(b, sq, kvh, group, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k) / math.sqrt(hd)
    scores = softcap(scores.astype(jnp.float32), softcap_val)

    mask = jnp.ones((b, sq, k.shape[1]), bool)
    if causal:
        mask &= kv_positions[:, None, :] <= q_positions[:, :, None]
    if window is not None:
        mask &= kv_positions[:, None, :] > q_positions[:, :, None] - window
    if kv_valid_len is not None:
        mask &= kv_positions[:, None, :] < kv_valid_len[:, None, None]
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, hd)


def _sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  q_positions: jax.Array, kv_positions: jax.Array,
                  causal: bool, window: Optional[int],
                  softcap_val: Optional[float],
                  kv_valid_len: Optional[jax.Array],
                  chunk: int) -> jax.Array:
    """Query-chunked attention: scans q in blocks so the (sq, skv) score
    matrix never materializes whole. XLA analogue of the Pallas flash
    kernel (used where Pallas cannot lower, e.g. CPU dry-runs)."""
    b, sq, h, hd = q.shape
    n_chunks = sq // chunk
    assert sq % chunk == 0, (sq, chunk)
    qc = q.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pc = q_positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(_, qp):
        q_i, pos_i = qp
        out = _sdpa(q_i, k, v, q_positions=pos_i, kv_positions=kv_positions,
                    causal=causal, window=window, softcap_val=softcap_val,
                    kv_valid_len=kv_valid_len)
        return None, out

    _, outs = jax.lax.scan(body, None, (qc, pc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def _attend(q, k, v, *, chunk_q: Optional[int] = None, **kw) -> jax.Array:
    sq = q.shape[1]
    if chunk_q is not None and sq > chunk_q and sq % chunk_q == 0:
        return _sdpa_chunked(q, k, v, chunk=chunk_q, **kw)
    return _sdpa(q, k, v, **kw)


def _project_qkv(params: Dict[str, jax.Array], cfg: ModelConfig,
                 x: jax.Array, *, positions: jax.Array,
                 kv_source: Optional[jax.Array] = None,
                 use_rope: bool = True):
    """Shared pre-attention stage: norm, fused projections, head split,
    RoPE. Returns (residual, q, k, v) with q: (b, s, h, hd) and
    k/v: (b, skv, kv, hd)."""
    residual = x
    h = rms_norm(x, params["norm"], cfg.norm_eps,
                 plus_one=cfg.post_block_norm)
    h = logical(h, "batch", "seq", "embed")

    kv_in = kv_source if kv_source is not None else h
    n_heads = params["wq"].shape[1] // cfg.resolved_head_dim
    n_kv = params["wk"].shape[1] // cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", h, params["wq"])
    k = jnp.einsum("bsd,de->bse", kv_in, params["wk"])
    v = jnp.einsum("bsd,de->bse", kv_in, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = logical(q, "batch", "seq", "heads_flat")
    k = logical(k, "batch", "seq", "kv_flat")
    v = logical(v, "batch", "seq", "kv_flat")
    hd = cfg.resolved_head_dim
    q = q.reshape(*q.shape[:2], n_heads, hd)
    k = k.reshape(*k.shape[:2], n_kv, hd)
    v = v.reshape(*v.shape[:2], n_kv, hd)

    if use_rope and kv_source is None and cfg.pos_embedding == "rope":
        q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return residual, q, k, v


def _project_out(params: Dict[str, jax.Array], cfg: ModelConfig,
                 out: jax.Array, residual: jax.Array) -> jax.Array:
    """Shared post-attention stage: head merge, output projection,
    optional post-block norm, residual add."""
    out = out.reshape(*out.shape[:2], -1)
    out = logical(out, "batch", "seq", "heads_flat")
    out = jnp.einsum("bse,ed->bsd", out, params["wo"])
    out = logical(out, "batch", "seq", "embed")
    if cfg.post_block_norm:
        out = rms_norm(out, params["post_norm"], cfg.norm_eps, plus_one=True)
    return residual + out


def attention_apply(params: Dict[str, jax.Array], cfg: ModelConfig,
                    x: jax.Array, *,
                    positions: jax.Array,
                    layer_kind: str = "attn",
                    cache: Optional[KVCache] = None,
                    cache_offset: Optional[jax.Array] = None,
                    cache_offsets: Optional[jax.Array] = None,
                    kv_source: Optional[jax.Array] = None,
                    causal: bool = True,
                    use_rope: bool = True,
                    ) -> Tuple[jax.Array, Optional[KVCache]]:
    """Self- or cross-attention with optional KV cache.

    Train/prefill: ``cache is None`` (prefill builds and returns a fresh
    cache when ``cache_offset`` is not None... see transformer.py).
    Decode: pass ``cache`` + ``cache_offset`` (current length); x has sq=1.
    Ragged decode: pass ``cache_offsets`` (b,) instead — each row writes
    its token at its OWN next position and attends only its own valid
    prefix, so right-padded unequal-length prompts stay exact.
    Cross-attention: pass ``kv_source`` (encoder / image states).
    """
    residual, q, k, v = _project_qkv(params, cfg, x, positions=positions,
                                     kv_source=kv_source, use_rope=use_rope)

    window = cfg.sliding_window if layer_kind == "attn_local" else None
    new_cache = None
    if kv_source is not None:
        # cross-attention: keys/values span the full encoder sequence.
        skv = k.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(skv), (x.shape[0], skv))
        out = _attend(q, k, v, chunk_q=cfg.attn_chunk_q,
                      q_positions=positions, kv_positions=kv_pos,
                      causal=False, window=None,
                      softcap_val=cfg.attn_softcap, kv_valid_len=None)
    elif cache is None:
        kv_pos = positions
        out = _attend(q, k, v, chunk_q=cfg.attn_chunk_q,
                      q_positions=positions, kv_positions=kv_pos,
                      causal=causal, window=window,
                      softcap_val=cfg.attn_softcap, kv_valid_len=None)
    else:
        # decode: insert this step's k/v, attend over the cache.
        b, max_seq = cache.k.shape[0], cache.k.shape[1]
        if cache_offsets is not None:
            # ragged path: row i writes at its own offset and sees only
            # its own offsets[i]+1 valid positions (sq == 1 here).
            rows = jnp.arange(b)
            k_cache = cache.k.at[rows, cache_offsets].set(
                k[:, 0].astype(cache.k.dtype))
            v_cache = cache.v.at[rows, cache_offsets].set(
                v[:, 0].astype(cache.v.dtype))
            valid = cache_offsets + 1
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), cache_offset, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), cache_offset, axis=1)
            valid = jnp.full((b,), cache_offset + x.shape[1])
        new_cache = KVCache(k_cache, v_cache)
        kv_pos = jnp.broadcast_to(jnp.arange(max_seq), (b, max_seq))
        out = _attend(q, k_cache, v_cache, chunk_q=cfg.attn_chunk_q,
                      q_positions=positions, kv_positions=kv_pos,
                      causal=True, window=window,
                      softcap_val=cfg.attn_softcap, kv_valid_len=valid)

    return _project_out(params, cfg, out, residual), new_cache


def attention_decode_paged(params: Dict[str, jax.Array], cfg: ModelConfig,
                           x: jax.Array, *,
                           pages: Tuple[jax.Array, jax.Array],
                           block_table: jax.Array,
                           offsets: jax.Array,
                           write_enabled: jax.Array,
                           layer_kind: str = "attn",
                           use_flash: bool = False,
                           interpret: bool = True,
                           ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Single-token self-attention over a PAGED KV cache.

    x: (b, 1, d). ``pages`` is this layer's (k_pages, v_pages), each
    (n_pages, page_size, kv_heads, head_dim); ``block_table`` (b,
    n_blocks) maps row b's logical block j to a physical page;
    ``offsets`` (b,) is each row's next write position (tokens already
    cached); ``write_enabled`` (b,) routes finished / empty slots' writes
    to the reserved trash page 0 (see repro.serving.kv_cache) so a
    recycled page is never corrupted by a dead row.
    """
    from repro.kernels.flash_attn.decode import paged_attention

    b = x.shape[0]
    residual, q, k, v = _project_qkv(params, cfg, x,
                                     positions=offsets[:, None])
    k_pages, v_pages = pages
    page = k_pages.shape[1]
    rows = jnp.arange(b)
    blk = jnp.clip(offsets // page, 0, block_table.shape[1] - 1)
    page_id = jnp.where(write_enabled, block_table[rows, blk], 0)
    in_page = jnp.where(write_enabled, offsets % page, 0)
    k_pages = k_pages.at[page_id, in_page].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[page_id, in_page].set(v[:, 0].astype(v_pages.dtype))

    # a row that did not write must not read its (absent) current token
    seq_lens = offsets + write_enabled.astype(offsets.dtype)
    window = cfg.sliding_window if layer_kind == "attn_local" else None
    out = paged_attention(q[:, 0], k_pages, v_pages, block_table, seq_lens,
                          window=window, softcap=cfg.attn_softcap,
                          use_kernel=use_flash, interpret=interpret)
    return (_project_out(params, cfg, out[:, None], residual),
            (k_pages, v_pages))


# --------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain)
# --------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    specs = {
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
        "norm": ParamSpec((d,), ("embed",),
                          "zeros" if cfg.post_block_norm else "ones"),
    }
    if cfg.glu:
        specs["w_gate"] = ParamSpec((d, f), ("embed", "mlp"))
    if cfg.post_block_norm:
        specs["post_norm"] = ParamSpec((d,), ("embed",), "zeros")
    return specs


def _activation(x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(act)


def mlp_apply(params: Dict[str, jax.Array], cfg: ModelConfig,
              x: jax.Array) -> jax.Array:
    residual = x
    h = rms_norm(x, params["norm"], cfg.norm_eps, plus_one=cfg.post_block_norm)
    h = logical(h, "batch", "seq", "embed")
    up = jnp.einsum("bsd,df->bsf", h, params["w_up"])
    if cfg.glu:
        gate = _activation(jnp.einsum("bsd,df->bsf", h, params["w_gate"]),
                           cfg.act)
        up = gate * up
    else:
        up = _activation(up, cfg.act)
    up = logical(up, "batch", "seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", up, params["w_down"])
    out = logical(out, "batch", "seq", "embed")
    if cfg.post_block_norm:
        out = rms_norm(out, params["post_norm"], cfg.norm_eps, plus_one=True)
    return residual + out
