"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "LayerSpec"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating period.

    mixer: 'attn' | 'attn_local' | 'mamba' | 'rwkv'
    ffn:   'mlp' | 'moe' | None (rwkv has its own channel-mix; use 'rwkv_ffn')
    cross_attn: insert a cross-attention sub-block (enc-dec / VLM layers).
    """

    mixer: str = "attn"
    ffn: str = "mlp"
    cross_attn: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # defaults to d_model // n_heads

    # repeating layer structure; n_layers % len(period) == 0
    period: Tuple[LayerSpec, ...] = (LayerSpec(),)

    # attention details
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0       # chatglm3 rotates only half the head dim
    qkv_bias: bool = False           # qwen1.5
    attn_softcap: Optional[float] = None   # gemma2: 50.0
    logit_softcap: Optional[float] = None  # gemma2: 30.0
    sliding_window: Optional[int] = None   # gemma2 local layers: 4096
    post_block_norm: bool = False    # gemma2 post-norms
    attn_chunk_q: Optional[int] = None     # q-chunked attention block size

    # MLP
    act: str = "silu"                # silu (SwiGLU) | gelu (GeGLU / plain)
    glu: bool = True

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_group_size: int = 128     # tokens per dispatch group

    # Mamba (jamba defaults)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_scan_dtype: str = "float32"  # dtype of the discretized scan elems

    # RWKV6
    rwkv_head_size: int = 64

    # enc-dec (whisper): encoder stack config
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # post-conv audio frames (stub input)

    # VLM: number of image tokens from the (stubbed) vision tower
    n_image_tokens: int = 0

    # embedding details
    tie_embeddings: bool = True
    scale_embeddings: bool = False   # gemma2 multiplies by sqrt(d_model)
    pos_embedding: str = "rope"      # rope | learned | none
    max_position_embeddings: int = 65536  # learned-pos table size (whisper)

    # numeric
    norm_eps: float = 1e-6
    vocab_pad_multiple: int = 256
    remat: bool = False              # gradient-checkpoint each layer period
    unroll_layers: bool = False      # python-loop the periods (cost probes)

    def __post_init__(self) -> None:
        if self.n_layers % len(self.period) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"period length {len(self.period)}")

    # ---- derived ---------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_dt_rank(self) -> int:
        return max(1, self.d_model // 16)

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    @property
    def is_attention_free(self) -> bool:
        return all(s.mixer in ("mamba", "rwkv") for s in self.period)

    @property
    def supports_long_context(self) -> bool:
        """True when decode memory/compute is sub-quadratic-safe at 500k:
        SSM/hybrid state-space layers, or sliding-window local attention."""
        kinds = {s.mixer for s in self.period}
        if kinds <= {"mamba", "rwkv"}:
            return True
        if "mamba" in kinds or "rwkv" in kinds:
            return True  # hybrid: only a fraction of layers hold a cache
        return "attn_local" in kinds  # sliding-window variants

    @property
    def has_encoder(self) -> bool:
        return self.n_encoder_layers > 0

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS = 6ND)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.padded_vocab * d  # embed
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        for spec in self.period * self.n_periods:
            if spec.mixer in ("attn", "attn_local"):
                total += d * (self.n_heads + 2 * self.n_kv_heads) * hd
                total += self.n_heads * hd * d
            elif spec.mixer == "mamba":
                di = self.mamba_d_inner
                total += d * 2 * di + di * self.mamba_d_conv
                total += di * (self.mamba_dt_rank + 2 * self.mamba_d_state)
                total += self.mamba_dt_rank * di + di * d + di
            elif spec.mixer == "rwkv":
                total += 6 * d * d  # r,k,v,g,o,w projections (approx)
            if spec.cross_attn:
                total += d * (self.n_heads + 2 * self.n_kv_heads) * hd
                total += self.n_heads * hd * d
            if spec.ffn == "mlp":
                total += d * self.d_ff * (3 if self.glu else 2)
            elif spec.ffn == "moe":
                total += self.n_experts * d * self.d_ff_expert * (3 if self.glu else 2)
                total += d * self.n_experts
            elif spec.ffn == "rwkv_ffn":
                total += int(d * d * 3.5 * 2)
        if self.has_encoder:
            per_layer = 4 * d * d + 2 * d * self.d_ff
            total += self.n_encoder_layers * per_layer
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE counts top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        total = self.param_count()
        moe_layers = sum(1 for s in self.period if s.ffn == "moe") * self.n_periods
        full = self.n_experts * self.d_model * self.d_ff_expert * (3 if self.glu else 2)
        active = self.top_k * self.d_model * self.d_ff_expert * (3 if self.glu else 2)
        return total - moe_layers * (full - active)
