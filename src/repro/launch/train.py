"""Production training launcher.

On real TPU pods this process runs once per host (jax.distributed
auto-init); in this container it drives the same code over N simulated
nodes. Selects architecture / algorithm / gossip parameters from the CLI
and runs the distributed SDM-DSGD train step built by
``repro.train.steps.make_distributed_train``.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
      --steps 5 --mesh 1x2            # reduced config, 2-device debug mesh
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--method", default=None,
                    help="method registry name (repro.core.method): "
                         "sdm-dsgd | sdm-dsgd-fused | dc-dsgd | dsgd | "
                         "gradient-push | allreduce")
    ap.add_argument("--algorithm", default=None,
                    help="deprecated alias of --method")
    ap.add_argument("--p", type=float, default=0.2)
    ap.add_argument("--theta", type=float, default=0.5)
    ap.add_argument("--gamma", type=float, default=1e-2)
    ap.add_argument("--sigma", type=float, default=0.0)
    ap.add_argument("--clip-c", type=float, default=None)
    ap.add_argument("--gossip-mode", default="bernoulli",
                    choices=["bernoulli", "fixedk_packed", "fixedk_rows",
                             "qsgd"])
    ap.add_argument("--compressor", default=None,
                    help="wire compressor spec (repro.core.compressor): "
                         "bernoulli | fixedk[:block] | block:<B> | rows | "
                         "qsgd[:bits]; overrides --gossip-mode; for "
                         "gradient-push switches on error-compensated "
                         "compressed push-sum")
    ap.add_argument("--topology", default="ring",
                    help="gossip graph over the node axis: ring | torus | "
                         "torusRxC | er | er:<p_c> | star | complete | "
                         "dring | der:<p_c> (directed, for gradient-push) | "
                         "matchings:<L> (time-varying random matchings) "
                         "(paper §5 uses er:0.35)")
    ap.add_argument("--topology-seed", type=int, default=0,
                    help="ER graph / matching sampling seed")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.checkpoint import save_checkpoint
    from repro.core import method as method_mod
    from repro.core.sdm_dsgd import SDMConfig
    from repro.data import TokenStream
    from repro.launch.mesh import make_mesh_by_name, node_axis_names
    from repro.train import steps as steps_mod

    meth_name = method_mod.normalize(
        args.method or args.algorithm or "sdm-dsgd")
    method_mod.get(meth_name)   # fail fast on unknown registrations
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = make_mesh_by_name(args.mesh)
    node_axes = node_axis_names(mesh)
    n_nodes = 1
    for a in node_axes:
        n_nodes *= mesh.shape[a]

    batch = args.global_batch or max(n_nodes, 2 * n_nodes)
    seq = args.seq_len or 64 if args.smoke else 4096

    sdm_cfg = SDMConfig(p=args.p, theta=args.theta, gamma=args.gamma,
                        sigma=args.sigma, clip_c=args.clip_c,
                        mode=args.gossip_mode, compressor=args.compressor)
    tc = steps_mod.DistributedTrainConfig(
        model=cfg,
        sdm=sdm_cfg,
        topology=args.topology,
        topology_seed=args.topology_seed,
        method=meth_name,
        param_dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    sched = steps_mod.gossip_schedule(tc, mesh)

    print(f"arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"nodes={n_nodes} method={meth_name} p={args.p} theta={args.theta} "
          f"compressor={args.compressor or sdm_cfg.mode} "
          f"topology={sched.name} gossip_rounds={sched.n_rounds}"
          + (f" time_varying_L={sched.length}" if sched.length > 1 else ""))

    state = steps_mod.init_distributed_state(tc, mesh,
                                             jax.random.PRNGKey(args.seed))
    step_fn = jax.jit(steps_mod.make_distributed_train(tc, mesh))
    stream = TokenStream(vocab_size=cfg.vocab_size, batch=batch, seq_len=seq,
                         seed=args.seed)

    has_ctx = cfg.family in ("audio", "vlm")
    for t in range(args.steps):
        tokens, labels = stream.batch_at(t)
        fn_args = [state, jnp.asarray(tokens), jnp.asarray(labels)]
        if has_ctx:
            shape = (batch, cfg.encoder_seq if cfg.family == "audio"
                     else cfg.n_image_tokens, cfg.d_model)
            fn_args.append(jnp.full(shape, 0.01, tc.param_dtype))
        t0 = time.time()
        state, loss = step_fn(*fn_args)
        print(f"step {t:4d} loss {float(loss):.4f} "
              f"({time.time() - t0:.2f}s)", flush=True)

    if args.checkpoint_dir:
        save_checkpoint(args.checkpoint_dir, args.steps, state)
        print(f"checkpoint written to {args.checkpoint_dir}")


if __name__ == "__main__":
    main()
