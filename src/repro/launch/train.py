"""Production training launcher.

On real TPU pods this process runs once per host (jax.distributed
auto-init); in this container it drives the same code over N simulated
nodes. Selects architecture / algorithm / gossip parameters from the CLI
and runs the distributed SDM-DSGD train step built by
``repro.train.steps.make_distributed_train``.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
      --steps 5 --mesh 1x2            # reduced config, 2-device debug mesh
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--mesh", default="single_pod")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--method", default=None,
                    help="method registry name (repro.core.method): "
                         "sdm-dsgd | sdm-dsgd-fused | dc-dsgd | dsgd | "
                         "gradient-push | allreduce")
    ap.add_argument("--algorithm", default=None,
                    help="deprecated alias of --method")
    ap.add_argument("--p", type=float, default=0.2)
    ap.add_argument("--theta", type=float, default=0.5)
    ap.add_argument("--gamma", type=float, default=1e-2)
    ap.add_argument("--sigma", type=float, default=0.0)
    ap.add_argument("--clip-c", type=float, default=None)
    ap.add_argument("--gossip-mode", default="bernoulli",
                    choices=["bernoulli", "fixedk_packed", "fixedk_rows",
                             "qsgd"])
    ap.add_argument("--compressor", default=None,
                    help="wire compressor spec (repro.core.compressor): "
                         "bernoulli | fixedk[:block] | block:<B> | rows | "
                         "qsgd[:bits] | qsgdf[:bits] (fused single-buffer "
                         "quantizer, bits in {2,4,8}); overrides "
                         "--gossip-mode; for gradient-push switches on "
                         "error-compensated compressed push-sum")
    ap.add_argument("--overlap", action="store_true",
                    help="overlapped transport: exchange the next round's "
                         "wire planes under this round's compute "
                         "(one-step-stale neighbour mixing; static "
                         "topologies only — not matchings:<L>)")
    ap.add_argument("--topology", default="ring",
                    help="gossip graph over the node axis: ring | torus | "
                         "torusRxC | er | er:<p_c> | star | complete | "
                         "dring | der:<p_c> (directed, for gradient-push) | "
                         "matchings:<L> (time-varying random matchings) "
                         "(paper §5 uses er:0.35)")
    ap.add_argument("--topology-seed", type=int, default=0,
                    help="ER graph / matching sampling seed")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sim", default=None,
                    help="run under the event-driven edge-fleet simulator "
                         "instead of the lock-step distributed step: a "
                         "preset (no-fault | straggler | dropout | churn) "
                         "or a scenario spec like "
                         "'q=0.8,deadline=1.5,straggle=0.25x8,dropout=0.05,"
                         "churn=0.02:5' (see repro.sim.fleet)")
    ap.add_argument("--sim-rounds", type=int, default=None,
                    help="global rounds to simulate (defaults to --steps)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.checkpoint import save_checkpoint
    from repro.core import method as method_mod
    from repro.core.sdm_dsgd import SDMConfig
    from repro.data import TokenStream
    from repro.launch.mesh import make_mesh_by_name, node_axis_names
    from repro.train import steps as steps_mod

    meth_name = method_mod.normalize(
        args.method or args.algorithm or "sdm-dsgd")
    method_mod.get(meth_name)   # fail fast on unknown registrations
    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = make_mesh_by_name(args.mesh)
    node_axes = node_axis_names(mesh)
    n_nodes = 1
    for a in node_axes:
        n_nodes *= mesh.shape[a]

    batch = args.global_batch or max(n_nodes, 2 * n_nodes)
    seq = args.seq_len or 64 if args.smoke else 4096

    if args.overlap and args.topology.startswith("matchings"):
        ap.error("--overlap needs a static topology: the double-buffered "
                 "transport has no replica (time-varying) delivery path")
    sdm_cfg = SDMConfig(p=args.p, theta=args.theta, gamma=args.gamma,
                        sigma=args.sigma, clip_c=args.clip_c,
                        mode=args.gossip_mode, compressor=args.compressor,
                        overlap=args.overlap)
    tc = steps_mod.DistributedTrainConfig(
        model=cfg,
        sdm=sdm_cfg,
        topology=args.topology,
        topology_seed=args.topology_seed,
        method=meth_name,
        param_dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    sched = steps_mod.gossip_schedule(tc, mesh)

    print(f"arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"nodes={n_nodes} method={meth_name} p={args.p} theta={args.theta} "
          f"compressor={args.compressor or sdm_cfg.mode} "
          f"topology={sched.name} gossip_rounds={sched.n_rounds}"
          + (f" time_varying_L={sched.length}" if sched.length > 1 else "")
          + (" overlap=on" if args.overlap else ""))

    if args.sim:
        _run_simulated(args, cfg, sdm_cfg, meth_name, n_nodes, batch, seq)
        return

    state = steps_mod.init_distributed_state(tc, mesh,
                                             jax.random.PRNGKey(args.seed))
    step_fn = jax.jit(steps_mod.make_distributed_train(tc, mesh))
    stream = TokenStream(vocab_size=cfg.vocab_size, batch=batch, seq_len=seq,
                         seed=args.seed)

    has_ctx = cfg.family in ("audio", "vlm")
    for t in range(args.steps):
        tokens, labels = stream.batch_at(t)
        fn_args = [state, jnp.asarray(tokens), jnp.asarray(labels)]
        if has_ctx:
            shape = (batch, cfg.encoder_seq if cfg.family == "audio"
                     else cfg.n_image_tokens, cfg.d_model)
            fn_args.append(jnp.full(shape, 0.01, tc.param_dtype))
        t0 = time.time()
        state, loss = step_fn(*fn_args)
        print(f"step {t:4d} loss {float(loss):.4f} "
              f"({time.time() - t0:.2f}s)", flush=True)

    if args.checkpoint_dir:
        save_checkpoint(args.checkpoint_dir, args.steps, state)
        print(f"checkpoint written to {args.checkpoint_dir}")


def _run_simulated(args, cfg, sdm_cfg, meth_name, n_nodes,
                   batch, seq) -> None:
    """The --sim axis: event-driven edge-fleet run on the reference
    executor (stacked single host), simulated wall-clock per round."""
    import jax
    import jax.numpy as jnp

    from repro.data import TokenStream
    from repro.models import transformer
    from repro.sim import Fleet, parse_scenario, simulate

    if cfg.family in ("audio", "vlm"):
        raise SystemExit("--sim supports text models only")
    if n_nodes < 2:
        raise SystemExit("--sim needs a >= 2-node mesh (e.g. --mesh 4x1)")

    rounds = args.sim_rounds or args.steps
    per_node = max(batch // n_nodes, 1)
    stream = TokenStream(vocab_size=cfg.vocab_size, batch=n_nodes * per_node,
                         seq_len=seq, seed=args.seed)
    params = transformer.init_params(jax.random.PRNGKey(args.seed), cfg,
                                     jnp.float32)
    stack = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_nodes,) + p.shape), params)

    def one_loss(p, tokens, labels):
        logits, aux = transformer.forward(p, cfg, tokens)
        return transformer.lm_loss(logits, labels, cfg.vocab_size, aux)

    def grad_fn(params_stack, batch_stack):
        tokens, labels = batch_stack
        losses, grads = jax.vmap(jax.value_and_grad(one_loss))(
            params_stack, tokens, labels)
        return grads, jnp.mean(losses)

    def batches():
        t = 0
        while True:
            tokens, labels = stream.batch_at(t)
            yield (jnp.asarray(tokens).reshape(n_nodes, per_node, -1),
                   jnp.asarray(labels).reshape(n_nodes, per_node, -1))
            t += 1

    spec = parse_scenario(args.sim)
    print("sim fleet: " + Fleet(n_nodes, spec, seed=args.seed).describe())
    res = simulate(topo=args.topology, algorithm=meth_name, sdm_cfg=sdm_cfg,
                   params_stack=stack, grad_fn=grad_fn, batches=batches(),
                   rounds=rounds, scenario=spec, seed=args.seed)
    r = res.result
    for t in range(len(r.losses)):
        print(f"round {t:4d} t_sim {r.sim_time_s[t]:9.3f}s "
              f"loss {r.losses[t]:.4f} "
              f"wire_bits {r.comm_bits[t]}", flush=True)
    print(f"sim done: rounds={res.rounds} t_sim={res.sim_seconds:.3f}s "
          f"stragglers={res.straggler_rounds} dropouts={res.dropout_rounds} "
          f"recompiles={res.recompiles} events={len(res.trace)}")


if __name__ == "__main__":
    main()
