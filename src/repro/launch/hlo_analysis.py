"""Parse collective traffic out of compiled HLO text.

``cost_analysis()`` has no collective-bytes entry, so the roofline's
collective term is derived here: sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction (async ``-start`` forms counted once; ``-done`` skipped).

``permute_payloads`` / ``collective_permute_count`` additionally expose
per-instruction collective-permute payloads (dtype-aware bits) — the
wire-plane transport's acceptance surface: a compiled distributed step
must emit exactly R permutes per exchange, independent of the model's
pytree leaf count, and the payload bits must match the static wire-bit
accounting (including packed sub-byte qsgd u8 lanes).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List

__all__ = ["collective_bytes", "count_ops", "permute_payloads",
           "collective_permute_count", "instruction_counts",
           "launch_count", "async_collective_pairs", "DTYPE_BYTES",
           "LAUNCH_OPS"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  %x = bf16[2,512]{1,0} all-reduce(...)  or tuple results
_INSTR_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes moved per collective kind (result-shape convention), plus total.

    Skips `-done` ops (the matching `-start` already carries the shape).
    """
    out: Dict[str, int] = defaultdict(int)
    for shapes_str, kind, _start in _INSTR_RE.findall(hlo_text):
        out[kind] += _shape_bytes(shapes_str)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_ops(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for _, kind, _start in _INSTR_RE.findall(hlo_text):
        counts[kind] += 1
    return dict(counts)


def collective_permute_count(hlo_text: str) -> int:
    """Collective-permute instructions in the module (`-done` skipped).

    The wire-plane latency metric: one permute per schedule round per
    plane bucket per exchange — NOT per pytree leaf.
    """
    return count_ops(hlo_text).get("collective-permute", 0)


# Any HLO instruction line: `%name = <shape> opcode(operands), attrs`
# where <shape> is `dtype[dims]{layout}` or a paren tuple of such (no
# nested parens inside tuple shapes, so [^)]* is safe).
_OPCODE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"([a-z][a-z0-9-]*)\(")

# What counts as a dispatched kernel launch for the perf-smoke metric:
# fused elementwise kernels, opaque library calls, sorts (top-k), and
# collectives. Async `-done` forms are completion markers of an already
# counted `-start`, so they are excluded from the launch sum (but
# reported distinctly by ``async_collective_pairs``).
LAUNCH_OPS = ("fusion", "custom-call", "sort") + COLLECTIVES + tuple(
    c + "-start" for c in COLLECTIVES)


def instruction_counts(hlo_text: str) -> Dict[str, int]:
    """Opcode -> instruction count over the whole module, PARSED from
    instruction lines (not substring matches — operand references and
    metadata cannot inflate the counts)."""
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OPCODE_RE.search(line)
        if m:
            counts[m.group(1)] += 1
    return dict(counts)


def launch_count(hlo_text: str) -> int:
    """Dispatched-kernel proxy: fusions + custom-calls + sorts +
    collectives (async pairs counted once, at the ``-start``)."""
    counts = instruction_counts(hlo_text)
    return sum(counts.get(op, 0) for op in LAUNCH_OPS)


def async_collective_pairs(hlo_text: str) -> Dict[str, Dict[str, int]]:
    """Per collective kind: sync instruction count and async start/done
    counts, reported DISTINCTLY.

    A well-formed module has start == done for every kind; the overlap
    transport's acceptance check is that the pair count matches
    ``expected_permutes`` exactly, same as the sync form.
    """
    counts = instruction_counts(hlo_text)
    out: Dict[str, Dict[str, int]] = {}
    for kind in COLLECTIVES:
        sync = counts.get(kind, 0)
        start = counts.get(kind + "-start", 0)
        done = counts.get(kind + "-done", 0)
        if sync or start or done:
            out[kind] = {"sync": sync, "start": start, "done": done}
    return out


_PERMUTE_OPS = (" collective-permute(", " collective-permute-start(")


def permute_payloads(hlo_text: str) -> List[Dict]:
    """Per collective-permute payload stats, in instruction order.

    Each entry: ``{"bits": int, "bytes": int, "elems": {dtype: count}}``
    parsed from the result shapes (async ``-start`` tuple forms counted
    once, ``-done`` skipped). Dtype-aware, so packed sub-byte payloads
    (u8 lanes) and index side-channels (s32) are visible separately.
    """
    out: List[Dict] = []
    for line in hlo_text.splitlines():
        for op in _PERMUTE_OPS:
            if op not in line:
                continue
            result_part = line.split(op)[0]
            shapes = []
            for dtype, dims in _SHAPE_RE.findall(result_part):
                if dtype not in DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                shapes.append((dtype, n))
            if op.endswith("-start("):
                # async tuple result is (operand, result, u32 context...):
                # drop the scalar context words and the operand mirror so
                # the payload is counted ONCE, like the sync form.
                shapes = [s for s in shapes if s != ("u32", 1)]
                shapes = shapes[: len(shapes) // 2]
            elems: Dict[str, int] = defaultdict(int)
            bits = 0
            for dtype, n in shapes:
                elems[dtype] += n
                bits += n * DTYPE_BYTES[dtype] * 8
            out.append({"bits": bits, "bytes": bits // 8,
                        "elems": dict(elems)})
            break
    return out
