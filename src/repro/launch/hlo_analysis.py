"""Parse collective traffic out of compiled HLO text.

``cost_analysis()`` has no collective-bytes entry, so the roofline's
collective term is derived here: sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction (async ``-start`` forms counted once; ``-done`` skipped).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict

__all__ = ["collective_bytes", "count_ops", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  %x = bf16[2,512]{1,0} all-reduce(...)  or tuple results
_INSTR_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes moved per collective kind (result-shape convention), plus total.

    Skips `-done` ops (the matching `-start` already carries the shape).
    """
    out: Dict[str, int] = defaultdict(int)
    for shapes_str, kind, _start in _INSTR_RE.findall(hlo_text):
        out[kind] += _shape_bytes(shapes_str)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_ops(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for _, kind, _start in _INSTR_RE.findall(hlo_text):
        counts[kind] += 1
    return dict(counts)
