"""Serving launcher: continuous-batching greedy decoding.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --requests 8 --max-new 12

Serve a trained decentralized checkpoint (the trainer's npz holds all n
node replicas; they are consensus-averaged into one model at load):

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-medium-14b \
      --smoke --checkpoint runs/ck --requests 8
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--checkpoint", default=None,
                    help="trainer checkpoint file or directory; the "
                         "stacked node replicas are consensus-averaged "
                         "into the serving model")
    ap.add_argument("--checkpoint-step", type=int, default=None)
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--ragged", action="store_true",
                    help="vary prompt lengths in [1, prompt-len]")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--flash-decode", action="store_true",
                    help="route decode attention through the paged "
                         "pallas kernel (interpret mode off-TPU)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import configs
    from repro.models import transformer
    from repro.serving import Request, ServingEngine, StaticServingEngine
    from repro.serving.ingest import ingest_checkpoint

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if args.checkpoint:
        params, report = ingest_checkpoint(args.checkpoint, cfg,
                                           step=args.checkpoint_step)
        print(report)
    else:
        params = transformer.init_params(jax.random.PRNGKey(args.seed), cfg)

    max_seq = args.prompt_len + args.max_new + 8
    if args.engine == "static":
        engine = StaticServingEngine(cfg, params,
                                     max_batch=args.max_batch,
                                     max_seq=max_seq)
    else:
        engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                               max_seq=max_seq, page_size=args.page_size,
                               use_flash=args.flash_decode)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for _ in range(args.requests):
        plen = (int(rng.integers(1, args.prompt_len + 1)) if args.ragged
                else args.prompt_len)
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, size=plen).tolist(),
            max_new_tokens=args.max_new))

    context = None
    if cfg.family == "audio":
        context = jnp.full((args.max_batch, cfg.encoder_seq, cfg.d_model),
                           0.01, jnp.float32)
    elif cfg.family == "vlm":
        context = jnp.full((args.max_batch, cfg.n_image_tokens, cfg.d_model),
                           0.01, jnp.float32)

    t0 = time.time()
    engine.serve(reqs, context=context)
    dt = time.time() - t0
    total_new = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s)")
    stats = getattr(engine, "last_stats", None)
    if stats is not None:
        print(f"  kv pages peak {stats.pages_peak} / dense-equivalent "
              f"{stats.pages_dense_equiv}; prefills {stats.prefills}, "
              f"decode steps {stats.decode_steps}")
    for i, r in enumerate(reqs[:4]):
        print(f"  req{i}: prompt[:4]={r.prompt[:4]} -> out={r.output}")


if __name__ == "__main__":
    main()
