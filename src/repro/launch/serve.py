"""Serving launcher: batched greedy decoding with the ServingEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --requests 8 --max-new 12
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import configs
    from repro.models import transformer
    from repro.serving import Request, ServingEngine

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    params = transformer.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           max_seq=args.prompt_len + args.max_new + 8)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(
        0, cfg.vocab_size, size=args.prompt_len).tolist(),
        max_new_tokens=args.max_new) for _ in range(args.requests)]

    context = None
    if cfg.family == "audio":
        context = jnp.full((args.max_batch, cfg.encoder_seq, cfg.d_model),
                           0.01, jnp.float32)
    elif cfg.family == "vlm":
        context = jnp.full((args.max_batch, cfg.n_image_tokens, cfg.d_model),
                           0.01, jnp.float32)

    t0 = time.time()
    engine.serve(reqs, context=context)
    dt = time.time() - t0
    total_new = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s)")
    for i, r in enumerate(reqs[:4]):
        print(f"  req{i}: prompt[:4]={r.prompt[:4]} -> out={r.output}")


if __name__ == "__main__":
    main()
