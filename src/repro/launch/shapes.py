"""Input shapes and ShapeDtypeStruct stand-ins for every (arch x shape).

The four assigned input shapes:
    train_4k     seq=4096,   global_batch=256   (training)
    prefill_32k  seq=32768,  global_batch=32    (inference-prefill)
    decode_32k   seq=32768,  global_batch=128   (inference-decode: 1 token,
                                                 32k KV cache)
    long_500k    seq=524288, global_batch=1     (long-context decode;
                                                 sub-quadratic archs only)

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs —
no device allocation — exactly what ``jax.jit(...).lower(**specs)`` needs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig

__all__ = ["SHAPES", "ShapeCase", "input_specs", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524_288, 1, "decode"),
}

# long_500k eligibility (DESIGN.md §4): SSM / hybrid / sliding-window only.
LONG_OK = {"rwkv6-3b", "jamba-v0.1-52b", "gemma2-2b"}


def skip_reason(cfg: ModelConfig, case: ShapeCase) -> Optional[str]:
    if case.name == "long_500k" and cfg.name not in LONG_OK:
        if cfg.family == "audio":
            return ("encoder-decoder audio model: a 500k-token decoder "
                    "cache has no audio meaning")
        return ("pure full-attention architecture without a sliding-window "
                "variant; 500k dense KV cache excluded by the brief")
    return None


def _context_spec(cfg: ModelConfig, batch: int, dtype):
    if cfg.family == "audio":
        return jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model),
                                    dtype)
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.n_image_tokens, cfg.d_model),
                                    dtype)
    return None


def input_specs(cfg: ModelConfig, case: ShapeCase,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStructs for one step of the given kind."""
    b, s = case.global_batch, case.seq_len
    tok = jnp.int32
    if case.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), tok),
            "labels": jax.ShapeDtypeStruct((b, s), tok),
        }
        ctx = _context_spec(cfg, b, dtype)
        if ctx is not None:
            out["context"] = ctx
        return out
    if case.kind == "prefill":
        cache = jax.eval_shape(
            lambda: transformer.init_cache(cfg, b, s, dtype))
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), tok),
            "cache": cache,
        }
        ctx = _context_spec(cfg, b, dtype)
        if ctx is not None:
            out["context"] = ctx
        return out
    if case.kind == "decode":
        cache = jax.eval_shape(
            lambda: transformer.init_cache(cfg, b, s, dtype))
        out = {
            "token": jax.ShapeDtypeStruct((b,), tok),
            "cache": cache,
        }
        ctx = _context_spec(cfg, b, dtype)
        if ctx is not None:
            out["context"] = ctx
        return out
    raise ValueError(case.kind)
