import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each combination this prints/records:
  * compiled.memory_analysis()  — per-device bytes (proves it fits),
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes parsed from the HLO (all-gather/all-reduce/
    reduce-scatter/all-to-all/collective-permute),
and writes one JSON per case under experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
      --shape train_4k --mesh single_pod --algorithm sdm_dsgd
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback


def _memory_dict(mem) -> dict:
    out = {k: getattr(mem, k) for k in dir(mem)
           if k.endswith("_in_bytes") and not k.startswith("host_")}
    if "peak_memory_in_bytes" not in out:
        # older jaxlibs report only the component sizes; their sum upper-
        # bounds the true peak, which is what fits-on-device checks need.
        out["peak_memory_in_bytes"] = sum(
            out.get(k, 0) for k in ("argument_size_in_bytes",
                                    "output_size_in_bytes",
                                    "temp_size_in_bytes"))
    return out


def _probe_cfg(cfg, k: int):
    """Config with k unrolled periods (and k encoder layers) for exact
    cost probes — XLA counts while-loop bodies once, so the full-depth
    numbers are reconstructed as probe1 + (n_periods-1)*(probe2-probe1)."""
    kw = dict(n_layers=k * len(cfg.period), unroll_layers=True)
    if cfg.has_encoder:
        kw["n_encoder_layers"] = k
    return dataclasses.replace(cfg, **kw)


def build_case(arch: str, shape_name: str, mesh_name: str, method: str,
               gossip_mode: str, out_root: str, verbose: bool = True,
               probes: bool = True, sdm_overrides: dict | None = None,
               cfg_overrides: dict | None = None,
               rule_overrides: dict | None = None, smoke: bool = False,
               topology: str = "ring",
               compressor: str | None = None) -> dict:
    import jax

    from repro import configs
    from repro.core import method as method_mod
    from repro.launch import shapes as shapes_mod
    from repro.launch.mesh import make_mesh_by_name, node_axis_names

    method = method_mod.normalize(method)
    method_mod.get(method)   # unknown registrations fail before compiling
    case = shapes_mod.SHAPES[shape_name]
    cfg = configs.get_smoke_config(arch) if smoke else configs.get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    skip = shapes_mod.skip_reason(cfg, case)
    if skip is not None:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": skip}

    mesh = make_mesh_by_name(mesh_name)
    node_axes = node_axis_names(mesh)
    n_nodes = 1
    for a in node_axes:
        n_nodes *= mesh.shape[a]

    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "algorithm": method if case.kind == "train" else "serve",
              "n_devices": mesh.size, "status": "ok",
              "n_periods": cfg.n_periods}
    record.update(_measure(cfg, case, mesh, node_axes, method,
                           gossip_mode, shape_name, sdm_overrides,
                           rule_overrides=rule_overrides, topology=topology,
                           compressor=compressor))
    if probes:
        p1 = _measure(_probe_cfg(cfg, 1), case, mesh, node_axes, method,
                      gossip_mode, shape_name, sdm_overrides, cost_only=True,
                      rule_overrides=rule_overrides, topology=topology,
                      compressor=compressor)
        p2 = _measure(_probe_cfg(cfg, 2), case, mesh, node_axes, method,
                      gossip_mode, shape_name, sdm_overrides, cost_only=True,
                      rule_overrides=rule_overrides, topology=topology,
                      compressor=compressor)
        record["probe1"] = p1
        record["probe2"] = p2
    record["model_params"] = cfg.param_count()
    record["model_params_active"] = cfg.active_param_count()
    record["n_nodes"] = n_nodes
    record["per_node_batch"] = case.global_batch // max(n_nodes, 1) \
        if case.kind == "train" else None
    record["tokens_per_step"] = case.global_batch * case.seq_len \
        if case.kind == "train" else case.global_batch

    if verbose:
        print(f"[{arch} | {shape_name} | {mesh_name}] "
              f"compile={record['compile_s']}s "
              f"flops={record['flops']:.3e} "
              f"coll={record['collective_bytes'].get('total', 0):.3e}B")
        print("  memory:", record["memory"])

    if out_root:
        d = os.path.join(out_root, mesh_name, arch)
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{shape_name}.json"), "w") as f:
            json.dump(record, f, indent=2)
    return record


def _measure(cfg, case, mesh, node_axes, method: str, gossip_mode: str,
             shape_name: str, sdm_overrides: dict | None = None,
             cost_only: bool = False,
             rule_overrides: dict | None = None,
             topology: str = "ring",
             compressor: str | None = None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.core.sdm_dsgd import SDMConfig
    from repro.launch import hlo_analysis, shapes as shapes_mod
    from repro.models import transformer
    from repro.sharding import MeshRules, tree_shardings
    from repro.train import steps as steps_mod

    record = {}
    t0 = time.time()
    if case.kind == "train":
        cfg = dataclasses.replace(cfg, remat=True)
        sdm_kw = dict(p=0.1, theta=0.25, gamma=1e-3, sigma=1.0,
                      clip_c=5.0, mode=gossip_mode, pack_block=1024,
                      compressor=compressor)
        sdm_kw.update(sdm_overrides or {})
        tc = steps_mod.DistributedTrainConfig(
            model=cfg, sdm=SDMConfig(**sdm_kw), method=method,
            topology=topology)
        step = steps_mod.make_distributed_train(tc, mesh)
        state_sds = steps_mod.state_shape_dtype(tc, mesh)
        state_shards = steps_mod.state_shardings(tc, mesh)
        specs = shapes_mod.input_specs(cfg, case)
        data_shard = jax.NamedSharding(
            mesh, jax.sharding.PartitionSpec(
                node_axes if len(node_axes) > 1 else node_axes[0]))
        args = [state_sds, specs["tokens"], specs["labels"]]
        in_sh = [state_shards, data_shard, data_shard]
        if "context" in specs:
            args.append(specs["context"])
            in_sh.append(data_shard)
        jf = jax.jit(step, in_shardings=tuple(in_sh))
        lowered = jf.lower(*args)
    else:
        rules_map = steps_mod.serving_rules(
            node_axes, shard_cache_seq=(shape_name == "long_500k"),
            decode=(case.kind == "decode"))
        rules_map.update(rule_overrides or {})
        rules = MeshRules(mesh, rules_map)
        specs = shapes_mod.input_specs(cfg, case)
        # params: bf16 serving weights sharded by logical axes
        pshapes = transformer.param_shapes(cfg)
        paxes = transformer.param_axes(cfg)
        is_shape = lambda v: isinstance(v, tuple) and all(
            isinstance(e, int) for e in v)
        params_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(tuple(s), jnp.bfloat16), pshapes,
            is_leaf=is_shape)
        params_sh = tree_shardings(rules, paxes, pshapes)
        cache_axes = transformer.cache_logical_axes(cfg)
        cache_sh = jax.tree.map(
            lambda sds, ax: rules.sharding(ax, sds.shape) if ax != () else
            jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            specs["cache"], cache_axes,
            is_leaf=lambda v: isinstance(v, tuple) and all(
                isinstance(e, (str, type(None))) for e in v) or v == ())
        batch_sh = rules.sharding(("batch",), (case.global_batch,))

        if case.kind == "prefill":
            fn, _ = steps_mod.make_prefill_fn(
                cfg, mesh, shard_cache_seq=(shape_name == "long_500k"),
                rule_overrides=rule_overrides)
            args = [params_sds, specs["tokens"], specs["cache"]]
            in_sh = [params_sh,
                     rules.sharding(("batch", None),
                                    (case.global_batch, case.seq_len)),
                     cache_sh]
        else:
            fn, _ = steps_mod.make_decode_fn(
                cfg, mesh, shard_cache_seq=(shape_name == "long_500k"),
                rule_overrides=rule_overrides)
            args = [params_sds, specs["token"], specs["cache"]]
            in_sh = [params_sh, batch_sh, cache_sh]
        if "context" in specs:
            args.append(specs["context"])
            in_sh.append(rules.sharding(
                ("batch", None, None), specs["context"].shape))
        jf = jax.jit(fn, in_shardings=tuple(in_sh))
        lowered = jf.lower(*args)

    record["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t1, 2)

    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    record["flops"] = float(cost.get("flops", -1.0))
    record["bytes_accessed"] = float(cost.get("bytes accessed", -1.0))
    record["collective_bytes"] = hlo_analysis.collective_bytes(hlo)
    record["collective_ops"] = hlo_analysis.count_ops(hlo)
    if not cost_only:
        record["memory"] = _memory_dict(compiled.memory_analysis())
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single_pod,multi_pod")
    ap.add_argument("--method", default=None,
                    help="method registry name (repro.core.method); "
                         "legacy --algorithm spellings accepted")
    ap.add_argument("--algorithm", default=None,
                    help="deprecated alias of --method")
    ap.add_argument("--gossip-mode", default="fixedk_packed",
                    choices=["bernoulli", "fixedk_packed", "fixedk_rows",
                             "qsgd"])
    ap.add_argument("--compressor", default=None,
                    help="wire compressor spec (repro.core.compressor); "
                         "overrides --gossip-mode, reaches gradient-push")
    ap.add_argument("--topology", default="ring",
                    help="gossip graph spec (gossip.sequence_by_name)")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke configs (CI registration "
                         "smoke: compiles in seconds)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--keep-going", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the unrolled cost-probe compiles")
    args = ap.parse_args()

    from repro import configs
    from repro.launch import shapes as shapes_mod

    method = args.method or args.algorithm or "sdm-dsgd"
    arches = sorted(configs.ALIASES) if args.arch == "all" \
        else args.arch.split(",")
    shape_names = list(shapes_mod.SHAPES) if args.shape == "all" \
        else args.shape.split(",")
    meshes = args.mesh.split(",")

    failures = []
    for mesh_name in meshes:
        for arch in arches:
            for shape_name in shape_names:
                try:
                    build_case(arch, shape_name, mesh_name, method,
                               args.gossip_mode, args.out,
                               probes=not args.no_probes,
                               smoke=args.smoke, topology=args.topology,
                               compressor=args.compressor)
                except Exception:
                    failures.append((arch, shape_name, mesh_name))
                    traceback.print_exc()
                    if not args.keep_going:
                        return 1
    if failures:
        print("FAILURES:", failures)
        return 1
    print("dry-run complete: all combinations lowered and compiled.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
