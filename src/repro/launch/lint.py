"""Launcher wiring for the static auditor: ``python -m repro.launch.lint``
is ``python -m repro.analysis`` (same flags, same LINT_report.json) —
kept next to ``dryrun``/``bench`` so the launch surface lists every CI
entry point in one place.
"""
from __future__ import annotations

import sys

from repro.analysis.__main__ import main

if __name__ == "__main__":
    sys.exit(main())
