"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module touches no jax device state — required for the dry-run's
XLA_FLAGS ordering and for tests that run on 1 CPU device.
"""
from __future__ import annotations

import jax

from repro import compat

__all__ = ["make_production_mesh", "make_mesh_by_name", "node_axis_names"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh_by_name(name: str) -> jax.sharding.Mesh:
    if name in ("single_pod", "16x16"):
        return make_production_mesh(multi_pod=False)
    if name in ("multi_pod", "2x16x16"):
        return make_production_mesh(multi_pod=True)
    # small debug meshes, e.g. "2x4"
    dims = tuple(int(d) for d in name.split("x"))
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return compat.make_mesh(dims, axes)


def node_axis_names(mesh: jax.sharding.Mesh):
    return tuple(a for a in mesh.axis_names if a != "model")
