"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352, RoPE + SwiGLU + GQA. [arXiv:2404.14219]
"""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = (LayerSpec(mixer="attn", ffn="mlp"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
        d_ff=17920, vocab_size=100_352,
        period=_PERIOD, attn_chunk_q=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        period=_PERIOD, vocab_pad_multiple=16,
    )
