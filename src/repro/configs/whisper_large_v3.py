"""whisper-large-v3 [audio]: 32L(enc)+32L(dec) d_model=1280 20H d_ff=5120
vocab=51866, encoder-decoder. [arXiv:2212.04356]

The mel-spectrogram + conv frontend is a STUB per the brief:
``input_specs`` feeds precomputed 1500-frame embeddings (b, 1500, 1280)
to the encoder; we implement the transformer backbone (bidirectional
encoder + causal decoder with cross-attention, learned positions).
"""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = (LayerSpec(mixer="attn", ffn="mlp", cross_attn=True),)


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
        d_ff=5120, vocab_size=51_866,
        period=_PERIOD,
        n_encoder_layers=32, encoder_seq=1500,
        pos_embedding="learned", act="gelu", glu=False,
        tie_embeddings=True, attn_chunk_q=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512,
        period=_PERIOD,
        n_encoder_layers=2, encoder_seq=16,
        pos_embedding="learned", act="gelu", glu=False,
        max_position_embeddings=2048, vocab_pad_multiple=16,
    )
