"""qwen1.5-32b [dense]: 64L d_model=5120 40H (kv=40, MHA) d_ff=27392
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-0.5B scaled per assignment]
"""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = (LayerSpec(mixer="attn", ffn="mlp"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
        d_ff=27392, vocab_size=152_064,
        period=_PERIOD, qkv_bias=True,
        attn_chunk_q=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512,
        period=_PERIOD, qkv_bias=True, vocab_pad_multiple=16,
    )
