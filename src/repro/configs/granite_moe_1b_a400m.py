"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = (LayerSpec(mixer="attn", ffn="moe"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab_size=49_155,
        period=_PERIOD,
        n_experts=32, top_k=8, d_ff_expert=512,
        attn_chunk_q=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=64, vocab_size=512,
        period=_PERIOD,
        n_experts=4, top_k=2, d_ff_expert=64, vocab_pad_multiple=16, capacity_factor=16.0,
    )
