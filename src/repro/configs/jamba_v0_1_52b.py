"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba:attn 7:1 interleave, MoE 16e top-2 every other layer.
[arXiv:2403.19887]

Period of 8 layers (4 periods): attention at slot 3 (mid-period, matching
the Jamba block layout), Mamba elsewhere; MoE replaces the MLP on every
odd slot (e:2 spacing).
"""
from repro.models.config import LayerSpec, ModelConfig


def _period(moe: bool):
    slots = []
    for i in range(8):
        mixer = "attn" if i == 3 else "mamba"
        ffn = "moe" if (moe and i % 2 == 1) else "mlp"
        slots.append(LayerSpec(mixer=mixer, ffn=ffn))
    return tuple(slots)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=65_536,
        period=_period(moe=True),
        n_experts=16, top_k=2, d_ff_expert=14336,
        pos_embedding="none",  # Jamba uses no positional encoding
        attn_chunk_q=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        n_layers=8, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        period=_period(moe=True),
        n_experts=4, top_k=2, d_ff_expert=128,
        pos_embedding="none", vocab_pad_multiple=16, capacity_factor=16.0,
    )
