"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B]
"""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = (LayerSpec(mixer="attn", ffn="moe"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab_size=151_936,
        period=_PERIOD,
        n_experts=128, top_k=8, d_ff_expert=768,
        attn_chunk_q=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=64, vocab_size=512,
        period=_PERIOD,
        n_experts=4, top_k=2, d_ff_expert=64, vocab_pad_multiple=16, capacity_factor=16.0,
    )
