"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024, 2d (half-dim) RoPE, GQA. [arXiv:2406.12793]
"""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = (LayerSpec(mixer="attn", ffn="mlp"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
        d_ff=13696, vocab_size=65_024,
        period=_PERIOD,
        rope_fraction=0.5,  # ChatGLM rotates half of each head dim
        attn_chunk_q=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        period=_PERIOD, rope_fraction=0.5, vocab_pad_multiple=16,
    )
