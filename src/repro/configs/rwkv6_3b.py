"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
Finch — data-dependent decay. [arXiv:2404.05892]
"""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = (LayerSpec(mixer="rwkv", ffn="rwkv_ffn"),)


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
        d_ff=8960, vocab_size=65_536,
        period=_PERIOD,
        rwkv_head_size=64, pos_embedding="none",
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512,
        period=_PERIOD,
        rwkv_head_size=32, pos_embedding="none",
        tie_embeddings=False, vocab_pad_multiple=16,
    )
