"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256, cross-attention image layers. [hf:meta-llama/Llama-3.2-11B-Vision]

The ViT vision tower + projector are STUBS per the brief: ``input_specs``
provides post-projector patch embeddings (b, n_image_tokens, 4096). The
language backbone has a cross-attention layer every 5th layer (8 of 40),
matching the model card's interleave.
"""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = (LayerSpec(mixer="attn", ffn="mlp", cross_attn=True),
           LayerSpec(mixer="attn", ffn="mlp"),
           LayerSpec(mixer="attn", ffn="mlp"),
           LayerSpec(mixer="attn", ffn="mlp"),
           LayerSpec(mixer="attn", ffn="mlp"))


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=128_256,
        period=_PERIOD,
        n_image_tokens=1024, rope_theta=500_000.0,
        tie_embeddings=False, attn_chunk_q=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke", family="vlm",
        n_layers=5, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        period=_PERIOD,
        n_image_tokens=16, rope_theta=500_000.0,
        tie_embeddings=False, vocab_pad_multiple=16,
    )
