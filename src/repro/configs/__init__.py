"""Architecture registry: one module per assigned arch, ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCH_IDS = [
    "gemma2_2b",
    "granite_moe_1b_a400m",
    "qwen1_5_32b",
    "jamba_v0_1_52b",
    "qwen3_moe_30b_a3b",
    "whisper_large_v3",
    "llama3_2_vision_11b",
    "phi3_medium_14b",
    "rwkv6_3b",
    "chatglm3_6b",
]

# public ids (with dashes/dots) -> module name
ALIASES = {
    "gemma2-2b": "gemma2_2b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen1.5-32b": "qwen1_5_32b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "whisper-large-v3": "whisper_large_v3",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "phi3-medium-14b": "phi3_medium_14b",
    "rwkv6-3b": "rwkv6_3b",
    "chatglm3-6b": "chatglm3_6b",
}


def _module(arch: str):
    name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in sorted(ALIASES)}
