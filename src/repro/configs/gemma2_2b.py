"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

Local(4096-window)+global alternating attention, attn/logit soft-capping,
post-block norms, GeGLU, embedding scaling. [arXiv:2408.00118]
"""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = (LayerSpec(mixer="attn_local", ffn="mlp"),
           LayerSpec(mixer="attn", ffn="mlp"))


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=9216, vocab_size=256_000,
        period=_PERIOD,
        sliding_window=4096, attn_softcap=50.0, logit_softcap=30.0,
        post_block_norm=True, act="gelu", glu=True,
        scale_embeddings=True, attn_chunk_q=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b-smoke", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        period=_PERIOD,
        sliding_window=16, attn_softcap=50.0, logit_softcap=30.0,
        post_block_norm=True, act="gelu", glu=True,
        scale_embeddings=True, vocab_pad_multiple=16,
    )
