from repro.optim.optimizers import (OptState, sgd, momentum, adamw,
                                    cosine_schedule, global_norm_clip)

__all__ = ["OptState", "sgd", "momentum", "adamw", "cosine_schedule",
           "global_norm_clip"]
