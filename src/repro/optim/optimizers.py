"""Minimal optimizer library (optax is not installed in this container).

SGD is the paper's optimizer (SDM-DSGD is an SGD-family method); AdamW is
provided for the non-private training examples. All follow a tiny
(init, update) protocol over pytrees.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array
    mu: Optional[PyTree] = None
    nu: Optional[PyTree] = None


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], Tuple[PyTree, OptState]]


def sgd(lr: float | Callable[[jax.Array], jax.Array]) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        step_lr = lr_fn(state.step)
        new = jax.tree.map(lambda p, g: p - step_lr * g.astype(p.dtype),
                           params, grads)
        return new, OptState(step=state.step + 1)

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params):
        mu = jax.tree.map(lambda m, g: beta * m + g.astype(m.dtype),
                          state.mu, grads)
        step_lr = lr_fn(state.step)
        new = jax.tree.map(lambda p, m: p - step_lr * m, params, mu)
        return new, OptState(step=state.step + 1, mu=mu)

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda t: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), t)
        return OptState(step=jnp.zeros((), jnp.int32), mu=z(params),
                        nu=z(params))

    def update(grads, state, params):
        t = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        step_lr = lr_fn(state.step)

        def upd(p, m, n):
            mhat = m / bc1
            nhat = n / bc2
            delta = mhat / (jnp.sqrt(nhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_lr * delta).astype(p.dtype)

        return jax.tree.map(upd, params, mu, nu), OptState(t, mu, nu)

    return Optimizer(init, update)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        progress = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(math.pi * progress)))
        return jnp.where(s < warmup, warm, cos)

    return fn


def global_norm_clip(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
