from repro.serving.engine import (Request, ServeStats, ServingEngine,
                                  StaticServingEngine)
from repro.serving.kv_cache import PagedKVCache

__all__ = ["ServingEngine", "StaticServingEngine", "Request", "ServeStats",
           "PagedKVCache"]
