"""Paged KV cache: fixed-size page pool + per-slot block tables.

The dense serving cache reserves ``max_batch x max_seq`` per layer no
matter how long requests actually run. Here KV storage is a pool of
fixed-size pages — one pool per attention period-slot, shaped
``(n_periods, n_pages, page_size, kv_heads, head_dim)`` — and each
request slot owns a BLOCK TABLE row mapping its logical block j to a
physical page id. A slot is charged exactly
``ceil((prompt + budget) / page_size)`` pages at admission and returns
them at retirement, so the pool sizes to the live token footprint, not
to ``max_batch x max_seq``.

Conventions:

* **page 0 is the trash page**: never allocated, and the decode step
  routes writes of finished / empty rows there (see
  ``layers.attention_decode_paged``). A freed slot's table row is reset
  to all-zeros, so a stale table can never alias a page that has been
  handed to another slot.
* the same physical page id indexes every layer's pool (the page axis
  is shared across ``n_periods`` and across period-slots), so one
  allocation covers the whole depth of the model.
* allocation is host-side (a simple LIFO free list — recycled pages are
  reused immediately, which the leak property-test exploits); the pools
  and tables live on device and flow through the jitted decode step.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

__all__ = ["PagedKVCache", "TRASH_PAGE"]

TRASH_PAGE = 0

# Each attention period-slot's pool is a plain ``(k_pages, v_pages)``
# tuple, both (n_periods, n_pages+1, page_size, kv_heads, head_dim).
# Plain tuples (not a NamedTuple) on purpose: the decode step returns
# plain tuples, and a pytree-type flip between host bookkeeping and the
# jitted step would force a retrace at every admit/retire boundary.


def _attn_slots(cfg: ModelConfig) -> List[str]:
    return [str(i) for i, s in enumerate(cfg.period)
            if s.mixer in ("attn", "attn_local")]


class PagedKVCache:
    """Host-side manager for the device page pools + block tables.

    ``n_pages`` counts usable pages EXCLUDING the trash page (the device
    arrays carry n_pages + 1 physical pages). The default pool is sized
    for a full dense reservation — callers running ragged traffic pass a
    smaller pool and rely on admission-time backpressure
    (``can_admit``)."""

    def __init__(self, cfg: ModelConfig, *, max_batch: int, max_seq: int,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.page_size = page_size
        self.n_blocks = math.ceil(max_seq / page_size)
        if n_pages is None:
            n_pages = max_batch * self.n_blocks
        self.n_pages = n_pages
        self.dtype = dtype
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        shape = (cfg.n_periods, n_pages + 1, page_size, kv, hd)
        self.pages: Dict[str, Tuple[jax.Array, jax.Array]] = {
            si: (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
            for si in _attn_slots(cfg)
        }
        self._tables = np.zeros((max_batch, self.n_blocks), np.int32)
        self._tables_dev: Optional[jax.Array] = None
        self._free: List[int] = list(range(n_pages, 0, -1))  # LIFO, 1-based
        self._owned: Dict[int, List[int]] = {}               # slot -> pages
        self.peak_in_use = 0

    # ---------------- allocation ----------------

    def pages_needed(self, n_tokens: int) -> int:
        return math.ceil(max(n_tokens, 1) / self.page_size)

    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_needed(n_tokens) <= len(self._free)

    def alloc(self, slot: int, n_tokens: int) -> None:
        """Charge ``slot`` enough pages for ``n_tokens`` and build its
        table row. Raises if the pool is exhausted (check ``can_admit``)
        or the slot already holds pages."""
        if slot in self._owned:
            raise ValueError(f"slot {slot} already holds pages")
        need = self.pages_needed(n_tokens)
        if n_tokens > self.max_seq:
            raise ValueError(f"{n_tokens} tokens > max_seq {self.max_seq}")
        if need > len(self._free):
            raise ValueError(f"pool exhausted: need {need}, "
                             f"free {len(self._free)}")
        got = [self._free.pop() for _ in range(need)]
        self._owned[slot] = got
        row = np.zeros(self.n_blocks, np.int32)
        row[:need] = got
        self._tables[slot] = row
        self._tables_dev = None
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use())

    def release(self, slot: int) -> None:
        """Return ``slot``'s pages to the free list and zero its table
        row (all blocks point at the trash page again)."""
        got = self._owned.pop(slot, None)
        if got is None:
            return
        self._free.extend(reversed(got))
        self._tables[slot] = 0
        self._tables_dev = None

    def owned(self, slot: int) -> Tuple[int, ...]:
        return tuple(self._owned.get(slot, ()))

    def tables(self) -> jax.Array:
        """Device copy of the block tables (cached until the next
        alloc/release)."""
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self._tables)
        return self._tables_dev

    # ---------------- device writes / reads ----------------

    def write_prompt(self, slot: int, dense: Dict[str, Any],
                     length: int) -> None:
        """Scatter a prefilled DENSE cache into ``slot``'s pages.

        ``dense``: {period-slot -> KVCache-like (k, v)} with k/v shaped
        (n_periods, 1, L, kv_heads, head_dim) from a single-request
        prefill; only the first ``length`` positions are real — padded
        tail positions are routed to the trash page, so bucket-padded
        prefills stay page-clean.
        """
        if not dense:   # pure-recurrent model: nothing paged to write
            return
        Lp = next(iter(dense.values()))[0].shape[2]
        pos = np.arange(Lp)
        row = self._tables[slot]
        real = pos < length
        page_id = np.where(real, row[np.minimum(pos // self.page_size,
                                                self.n_blocks - 1)],
                           TRASH_PAGE)
        in_page = np.where(real, pos % self.page_size, 0)
        page_id = jnp.asarray(page_id, jnp.int32)
        in_page = jnp.asarray(in_page, jnp.int32)
        for si, (k_dense, v_dense) in dense.items():
            kp, vp = self.pages[si]
            self.pages[si] = (_scatter_prompt(kp, k_dense, page_id, in_page),
                              _scatter_prompt(vp, v_dense, page_id, in_page))

    def gather_dense(self, slot: int, length: int) -> Dict[str, Any]:
        """Debug/test read-back: ``slot``'s first ``length`` cached
        tokens as dense (n_periods, length, kv, hd) arrays per layer."""
        row = self._tables[slot]
        pos = np.arange(length)
        page_id = jnp.asarray(row[pos // self.page_size], jnp.int32)
        in_page = jnp.asarray(pos % self.page_size, jnp.int32)
        out = {}
        for si, (kp, vp) in self.pages.items():
            out[si] = (kp[:, page_id, in_page], vp[:, page_id, in_page])
        return out

    def dense_equivalent_pages(self) -> int:
        """What a dense max_batch x max_seq reservation costs, in pages."""
        return self.max_batch * self.n_blocks


@jax.jit
def _scatter_prompt(pages: jax.Array, dense: jax.Array, page_id: jax.Array,
                    in_page: jax.Array) -> jax.Array:
    # pages (n_periods, n_pages+1, P, kv, hd); dense (n_periods, 1, L, kv, hd)
    return pages.at[:, page_id, in_page].set(dense[:, 0])
