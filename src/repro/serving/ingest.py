"""Checkpoint ingest: trainer npz -> one consensus serving model.

The decentralized trainer checkpoints the WHOLE algorithm state
(SDMState / DSGDState / GradientPushState ...) with the n node replicas
stacked on a leading ``(n, ...)`` axis under the ``x`` field. Serving
wants a single parameter tree, so ingest:

1. locates the params subtree inside the flat checkpoint (the shortest
   key prefix — ``x`` for every trainer state, ``''`` for a raw params
   checkpoint — under which EVERY model parameter path exists),
2. de-biases push-sum mass if the state carries per-node weights
   (``z_i = x_i / w_i``; gradient-push tracks the model as a ratio),
3. consensus-averages the replicas into one model, and
4. reports the max cross-node disagreement — how far the fleet was from
   consensus when the snapshot was taken. A large value means the serving
   model is NOT what any node was actually running; surface it.

``ingest_checkpoint`` accepts either a checkpoint file or a trainer
checkpoint directory (picks the latest step).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_flat
from repro.models import transformer
from repro.models.config import ModelConfig

__all__ = ["IngestReport", "consensus_from_flat", "ingest_checkpoint"]

PyTree = Any


@dataclasses.dataclass
class IngestReport:
    path: str
    prefix: str              # key prefix the params were found under
    n_nodes: int             # replicas averaged (1 = raw params ckpt)
    debiased: bool           # push-sum x/w de-bias applied
    max_disagreement: float  # max_i,leaf |z_i - mean| across the fleet
    rms_disagreement: float
    worst_leaf: str          # param path attaining max_disagreement

    def __str__(self) -> str:
        return (f"ingested {self.path} [prefix={self.prefix!r} "
                f"n_nodes={self.n_nodes} debias={self.debiased}] "
                f"disagreement max={self.max_disagreement:.3e} "
                f"(rms={self.rms_disagreement:.3e}, at {self.worst_leaf})")


def _reinterpret(arr: np.ndarray, itemwidth_dtypes={2: "bfloat16"}):
    """np.load returns raw void bytes for ml_dtypes leaves."""
    if arr.dtype.kind != "V":
        return arr
    import ml_dtypes
    name = itemwidth_dtypes.get(arr.dtype.itemsize)
    if name is None:
        raise ValueError(f"cannot reinterpret opaque dtype {arr.dtype}")
    return arr.view(np.dtype(getattr(ml_dtypes, name)))


def _find_prefix(flat: Dict[str, np.ndarray], param_keys) -> str:
    """Shortest prefix P such that P/k exists for every param key k
    ('' means the checkpoint IS a raw params tree)."""
    k0 = param_keys[0]
    cands = set()
    if k0 in flat:
        cands.add("")
    for key in flat:
        if key.endswith("/" + k0):
            cands.add(key[: -len(k0) - 1])
    full = lambda p, k: k if p == "" else f"{p}/{k}"
    cands = [p for p in cands if all(full(p, k) in flat for k in param_keys)]
    if not cands:
        raise KeyError(
            f"checkpoint holds none of the model's parameters (looked for "
            f"{k0!r} under any prefix; checkpoint keys start "
            f"{sorted(flat)[:4]})")
    # 'x' (trainer state) and '' (raw params) are the expected layouts;
    # both sort first by length. 's'/'xhat' replicas lose the tie-break.
    cands.sort(key=lambda p: (len(p), p != "x", p))
    return cands[0]


def consensus_from_flat(flat: Dict[str, np.ndarray], cfg: ModelConfig, *,
                        dtype=jnp.float32, path: str = "<flat>"
                        ) -> Tuple[PyTree, IngestReport]:
    """Average the stacked node replicas in a flat checkpoint dict into
    one serving parameter tree. Returns (params, IngestReport)."""
    shapes = transformer.param_shapes(cfg)
    # shape tuples are themselves pytrees — flatten with them as leaves
    is_shape = lambda x: isinstance(x, tuple) and \
        all(isinstance(i, int) for i in x)
    flat_shapes, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=is_shape)
    param_keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path) for path, _ in flat_shapes]
    prefix = _find_prefix(flat, param_keys)
    full = lambda k: k if prefix == "" else f"{prefix}/{k}"

    first = _reinterpret(flat[full(param_keys[0])])
    want0 = tuple(flat_shapes[0][1])
    if tuple(first.shape) == want0:
        n = 1
    elif first.ndim == len(want0) + 1 and tuple(first.shape[1:]) == want0:
        n = first.shape[0]
    else:
        raise ValueError(
            f"param {param_keys[0]!r} has shape {first.shape}, expected "
            f"{want0} or (n,)+{want0} — wrong --arch for this checkpoint?")

    w = None
    if n > 1 and prefix == "x" and "w" in flat:
        wr = np.asarray(_reinterpret(flat["w"]), np.float64).reshape(-1)
        if wr.shape == (n,):     # push-sum: the model estimate is x/w
            w = wr

    leaves, max_d, sq_sum, sq_n, worst = [], 0.0, 0.0, 0, "-"
    for key, (_, want) in zip(param_keys, flat_shapes):
        arr = np.asarray(_reinterpret(flat[full(key)]), np.float64)
        if n == 1:
            mean = arr if tuple(arr.shape) == tuple(want) else arr[0]
        else:
            if arr.shape[0] != n:
                raise ValueError(f"param {key!r}: replica axis "
                                 f"{arr.shape[0]} != {n}")
            z = arr / w.reshape((n,) + (1,) * (arr.ndim - 1)) \
                if w is not None else arr
            mean = z.mean(axis=0)
            d = np.abs(z - mean)
            dm = float(d.max())
            if dm > max_d:
                max_d, worst = dm, key
            sq_sum += float((d * d).sum())
            sq_n += d.size
        if tuple(mean.shape) != tuple(want):
            raise ValueError(f"param {key!r}: shape {mean.shape} != {want}")
        leaves.append(jnp.asarray(mean, dtype))
    params = jax.tree_util.tree_unflatten(treedef, leaves)
    report = IngestReport(
        path=path, prefix=prefix, n_nodes=n, debiased=w is not None,
        max_disagreement=max_d,
        rms_disagreement=(sq_sum / sq_n) ** 0.5 if sq_n else 0.0,
        worst_leaf=worst)
    return params, report


def ingest_checkpoint(path: str, cfg: ModelConfig, *,
                      step: Optional[int] = None, dtype=jnp.float32
                      ) -> Tuple[PyTree, IngestReport]:
    """Load a trainer checkpoint (file, or directory of step_*.npz) and
    consensus-average it into a single serving model."""
    if os.path.isdir(path):
        s = step if step is not None else latest_step(path)
        if s is None:
            raise FileNotFoundError(f"no checkpoints in {path}")
        path = os.path.join(path, f"step_{s:08d}.npz")
    return consensus_from_flat(load_flat(path), cfg, dtype=dtype, path=path)
