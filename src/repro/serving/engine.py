"""Batched serving engine: static-batch prefill + greedy decode loop.

Small but real: request queue, padded batch assembly, prompt prefill into
a shared KV cache, per-slot EOS tracking, detokenized (id-list) output.
Used by examples/serve_lm.py and the serving integration test.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: Optional[List[int]] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256, dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.dtype = dtype
        self._prefill = jax.jit(
            lambda p, t, c, ctx: transformer.prefill(p, cfg, t, c,
                                                     context=ctx))
        self._decode = jax.jit(
            lambda p, t, c, ctx: transformer.decode_step(p, cfg, t, c,
                                                         context=ctx))
        self._encode = jax.jit(
            lambda p, ctx: transformer.encode_context(p, cfg, ctx))

    def serve(self, requests: List[Request],
              context: Optional[jax.Array] = None) -> List[Request]:
        """Serve a list of requests in static batches of max_batch."""
        for i in range(0, len(requests), self.max_batch):
            self._serve_batch(requests[i:i + self.max_batch], context)
        return requests

    def _serve_batch(self, batch: List[Request],
                     context: Optional[jax.Array]) -> None:
        b = len(batch)
        # left-pad-free assembly: right-pad prompts to the longest, track
        # true lengths; decode starts from each prompt's last real token.
        plen = max(len(r.prompt) for r in batch)
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(batch):
            prompts[i, :len(r.prompt)] = r.prompt
        max_new = max(r.max_new_tokens for r in batch)
        assert plen + max_new <= self.max_seq, "increase max_seq"

        ctx = None
        if context is not None:
            ctx = self._encode(self.params, context[:b])

        cache = transformer.init_cache(self.cfg, b, self.max_seq, self.dtype)
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      cache, ctx)
        # NOTE: with right-padded prompts of unequal length the simple
        # static-batch engine conditions each row on its padded prompt;
        # equal-length prompts (the common bench case) are exact.
        next_tok = jnp.argmax(logits, axis=-1)
        outs = [[] for _ in range(b)]
        done = [False] * b
        for _ in range(max_new):
            for i in range(b):
                if not done[i]:
                    outs[i].append(int(next_tok[i]))
                    r = batch[i]
                    if (r.eos_id is not None and outs[i][-1] == r.eos_id) or \
                            len(outs[i]) >= r.max_new_tokens:
                        done[i] = True
            if all(done):
                break
            logits, cache = self._decode(self.params, next_tok, cache, ctx)
            next_tok = jnp.argmax(logits, axis=-1)
        for r, o in zip(batch, outs):
            r.output = o
