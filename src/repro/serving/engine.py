"""Serving engines: continuous batching (default) + static batch baseline.

``ServingEngine`` is a slot-based continuous-batching scheduler over the
paged KV cache (``kv_cache.py``): finished requests free their slot and
their pages, queued requests are admitted mid-flight (a single-request
prefill lands in the freed slot, decode resumes the next step), and the
decode step is ONE jitted function carrying a device-side done-mask and
token buffer — per-token host work is a single small done-mask poll; all
real bookkeeping (prefill, page alloc/free, output read-back) happens
only at admission/retirement boundaries.

``StaticServingEngine`` is the seed's static-batch engine kept as the
benchmark baseline, with its ragged-prompt bug FIXED: right-padded
unequal-length prompts now read each row's logits at its own last real
token and decode at per-row cache offsets / RoPE phases (causal masking
already isolates rows during prefill, so batched == one-at-a-time —
pinned in tests/test_serving_engine.py). Models with recurrent mixers
(mamba/rwkv) are grouped into equal-length sub-batches instead: a
recurrent state that has consumed right-padding cannot be repaired by
masking.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving.kv_cache import PagedKVCache

__all__ = ["Request", "ServingEngine", "StaticServingEngine", "ServeStats"]


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: Optional[List[int]] = None
    ttft_s: Optional[float] = None     # submit -> first token available
    finish_s: Optional[float] = None   # submit -> retirement


@dataclasses.dataclass
class ServeStats:
    """Per-``serve()`` call instrumentation (consumed by serve_bench)."""
    wall_s: float = 0.0
    tokens: int = 0
    step_wall_s: List[float] = dataclasses.field(default_factory=list)
    step_tokens: List[int] = dataclasses.field(default_factory=list)
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    pages_peak: int = 0
    pages_dense_equiv: int = 0
    prefills: int = 0
    decode_steps: int = 0


class _DecodeState(NamedTuple):
    """Device-resident continuous-batching state (one row per slot)."""
    pages: Dict[str, Any]     # {period-slot -> (k_pages, v_pages)}
    rec: Dict[str, Any]       # {period-slot -> recurrent state (n, B, ...)}
    offsets: jax.Array        # (B,) tokens already cached per slot
    last_tok: jax.Array       # (B,) token to feed next
    out_buf: jax.Array        # (B, max_out) generated tokens
    n_out: jax.Array          # (B,)
    budget: jax.Array         # (B,) max_new_tokens per slot
    eos: jax.Array            # (B,) eos id or -1
    active: jax.Array         # (B,) bool: slot holds a live request
    done: jax.Array           # (B,) bool: finished, awaiting retirement


def _is_recurrent(cfg: ModelConfig) -> bool:
    return any(s.mixer not in ("attn", "attn_local") for s in cfg.period)


def _bucket(n: int, cap: int) -> int:
    """Next power-of-two prefill length (bounds jit retraces)."""
    return min(max(8, 1 << (n - 1).bit_length()), cap)


class ServingEngine:
    """Continuous-batching engine over a paged KV cache.

    ``n_pages`` sizes the shared page pool (default: the dense
    equivalent ``max_batch * ceil(max_seq/page_size)``; ragged traffic
    runs fine far below that — admission applies backpressure).
    ``use_flash`` routes decode attention through the paged flash
    kernel (interpret-mode Pallas off-TPU); the default XLA gather path
    computes identical logits (tested) and is the fast path on CPU
    hosts. ``sync_every`` decode steps run between done-mask polls.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256, dtype=jnp.float32, page_size: int = 16,
                 n_pages: Optional[int] = None, use_flash: bool = False,
                 interpret: bool = True, sync_every: int = 1):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.dtype = dtype
        self.page_size = page_size
        self.n_pages = n_pages
        self.use_flash = use_flash
        self.interpret = interpret
        self.sync_every = max(1, sync_every)
        self.recurrent = _is_recurrent(cfg)
        self.last_stats: Optional[ServeStats] = None

        self._attn_slots = [str(i) for i, s in enumerate(cfg.period)
                            if s.mixer in ("attn", "attn_local")]
        self._rec_slots = [str(i) for i, s in enumerate(cfg.period)
                           if s.mixer not in ("attn", "attn_local")]

        self._encode = jax.jit(
            lambda p, c: transformer.encode_context(p, cfg, c))

        def _prefill(p, toks, last_index, ctx):
            cache = transformer.init_cache(cfg, 1, toks.shape[1], dtype)
            logits, cache = transformer.prefill(p, cfg, toks, cache,
                                                context=ctx,
                                                last_index=last_index)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache.slots

        self._prefill = jax.jit(_prefill)

        def _step(p, st: _DecodeState, tables, ctx):
            emit = st.active & ~st.done
            logits, pages, rec = transformer.decode_step_paged(
                p, cfg, st.last_tok, st.pages, st.rec, tables, st.offsets,
                emit, context=ctx, use_flash=self.use_flash,
                interpret=self.interpret)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            rows = jnp.arange(st.out_buf.shape[0])
            idx = jnp.clip(st.n_out, 0, st.out_buf.shape[1] - 1)
            out_buf = st.out_buf.at[rows, idx].set(
                jnp.where(emit, nxt, st.out_buf[rows, idx]))
            n_out = st.n_out + emit
            done = st.done | (emit & ((nxt == st.eos) | (n_out >= st.budget)))
            return st._replace(pages=pages, rec=rec,
                               offsets=st.offsets + emit,
                               last_tok=jnp.where(emit, nxt, st.last_tok),
                               out_buf=out_buf, n_out=n_out, done=done)

        self._step = jax.jit(_step, donate_argnums=(1,))

        def _admit(st: _DecodeState, rec_new, slot, length, first_tok,
                   budget, eos):
            rec = jax.tree.map(lambda a, u: a.at[:, slot].set(u[:, 0]),
                               st.rec, rec_new)
            done0 = (budget <= 1) | (first_tok == eos)
            return st._replace(
                rec=rec,
                offsets=st.offsets.at[slot].set(length),
                last_tok=st.last_tok.at[slot].set(first_tok),
                out_buf=st.out_buf.at[slot].set(0).at[slot, 0].set(first_tok),
                n_out=st.n_out.at[slot].set(1),
                budget=st.budget.at[slot].set(budget),
                eos=st.eos.at[slot].set(eos),
                active=st.active.at[slot].set(True),
                done=st.done.at[slot].set(done0))

        self._admit_fn = jax.jit(_admit, donate_argnums=(0,))

        def _retire(st: _DecodeState, slot):
            return st._replace(active=st.active.at[slot].set(False),
                               done=st.done.at[slot].set(False),
                               offsets=st.offsets.at[slot].set(0))

        self._retire_fn = jax.jit(_retire, donate_argnums=(0,))

    # ---------------- serve ----------------

    def serve(self, requests: List[Request],
              context: Optional[jax.Array] = None) -> List[Request]:
        """Serve all requests with continuous batching; returns them with
        ``output`` (and timing fields) filled, in the original order."""
        if not requests:
            return requests
        t0 = time.monotonic()
        stats = ServeStats()
        B = self.max_batch
        max_out = max(r.max_new_tokens for r in requests)

        ctx1 = None
        if context is not None:
            ctx1 = self._encode(self.params, context[:1])
        ctx_b = None if ctx1 is None else jnp.broadcast_to(
            ctx1, (B,) + ctx1.shape[1:])

        kv = PagedKVCache(self.cfg, max_batch=B, max_seq=self.max_seq,
                          page_size=self.page_size, n_pages=self.n_pages,
                          dtype=self.dtype)
        rec0 = {}
        if self._rec_slots:
            slots = transformer.init_cache(self.cfg, B, 1, self.dtype).slots
            rec0 = {si: slots[si] for si in self._rec_slots}
        st = _DecodeState(
            pages=kv.pages, rec=rec0,
            offsets=jnp.zeros((B,), jnp.int32),
            last_tok=jnp.zeros((B,), jnp.int32),
            out_buf=jnp.zeros((B, max_out), jnp.int32),
            n_out=jnp.zeros((B,), jnp.int32),
            budget=jnp.ones((B,), jnp.int32),
            eos=jnp.full((B,), -1, jnp.int32),
            active=jnp.zeros((B,), bool),
            done=jnp.zeros((B,), bool))

        queue = deque(requests)
        submit = {id(r): t0 for r in requests}
        free = list(range(B - 1, -1, -1))
        live: Dict[int, Request] = {}

        def admit_ready() -> bool:
            return bool(queue) and bool(free) and \
                kv.can_admit(len(queue[0].prompt) +
                             queue[0].max_new_tokens)

        while queue or live:
            while admit_ready():
                req = queue.popleft()
                slot = free.pop()
                need = len(req.prompt) + req.max_new_tokens
                kv.alloc(slot, need)
                st = self._prefill_into(st, kv, slot, req, ctx1)
                live[slot] = req
                req.ttft_s = time.monotonic() - submit[id(req)]
                stats.ttft_s.append(req.ttft_s)
                stats.prefills += 1
            if not live:
                need = kv.pages_needed(len(queue[0].prompt) +
                                       queue[0].max_new_tokens)
                raise RuntimeError(
                    f"request needs {need} pages but the pool only has "
                    f"{kv.n_pages}; raise n_pages or max_seq")

            done_np = np.asarray(st.done & st.active)
            if not done_np.any():
                emit_n = int(np.asarray(st.active & ~st.done).sum())
                ts = time.monotonic()
                tables = kv.tables()
                for _ in range(self.sync_every):
                    st = self._step(self.params, st, tables, ctx_b)
                    stats.decode_steps += 1
                done_np = np.asarray(st.done & st.active)  # forces the step
                dt = time.monotonic() - ts
                stats.step_wall_s.append(dt)
                # exact for sync_every=1; a row finishing mid-window
                # overcounts by at most sync_every-1 tokens
                stats.step_tokens.append(emit_n * self.sync_every)

            for slot in np.nonzero(done_np)[0].tolist():
                req = live.pop(slot)
                n = int(st.n_out[slot])
                req.output = np.asarray(st.out_buf[slot][:n]).tolist()
                req.finish_s = time.monotonic() - submit[id(req)]
                kv.release(slot)
                st = self._retire_fn(st, slot)
                free.append(slot)

        kv.pages = st.pages  # final buffers back onto the manager
        stats.pages_peak = kv.peak_in_use
        stats.pages_dense_equiv = kv.dense_equivalent_pages()
        stats.tokens = sum(len(r.output) for r in requests)
        stats.wall_s = time.monotonic() - t0
        self.last_stats = stats
        return requests

    def _prefill_into(self, st: _DecodeState, kv: PagedKVCache, slot: int,
                      req: Request, ctx1) -> _DecodeState:
        L = len(req.prompt)
        if L < 1:
            raise ValueError("empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # recurrent mixers must see the EXACT prompt (a right-padded
        # tail would contaminate their state); attention models prefill
        # at a power-of-two bucket to bound retraces — causal masking +
        # last_index keep the padded prefill exact.
        Lp = L if self.recurrent else _bucket(L, self.max_seq)
        toks = np.zeros((1, Lp), np.int32)
        toks[0, :L] = req.prompt
        first, slots_cache = self._prefill(
            self.params, jnp.asarray(toks),
            jnp.asarray([L - 1], jnp.int32), ctx1)
        # paged write happens against the CURRENT pool buffers
        kv.pages = st.pages
        kv.write_prompt(slot, {si: (slots_cache[si].k, slots_cache[si].v)
                               for si in self._attn_slots}, L)
        rec_new = {si: slots_cache[si] for si in self._rec_slots}
        st = st._replace(pages=kv.pages)
        return self._admit_fn(st, rec_new, slot, L, int(first[0]),
                              req.max_new_tokens,
                              -1 if req.eos_id is None else req.eos_id)


# --------------------------------------------------------------------------
# Static-batch baseline (seed engine, ragged bug fixed)
# --------------------------------------------------------------------------

class StaticServingEngine:
    """Static batches of ``max_batch``: prefill together, decode until
    EVERY row in the batch is finished, then start the next batch. Kept
    as the throughput baseline the continuous engine must beat
    (benchmarks/check_serve.py); per-token bookkeeping is host-side by
    design."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256, dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.dtype = dtype
        self.recurrent = _is_recurrent(cfg)
        self.last_stats: Optional[ServeStats] = None
        self._prefill = jax.jit(
            lambda p, t, c, ctx, li: transformer.prefill(
                p, cfg, t, c, context=ctx, last_index=li))
        self._decode = jax.jit(
            lambda p, t, c, ctx, offs: transformer.decode_step(
                p, cfg, t, c, context=ctx, offsets=offs))
        self._encode = jax.jit(
            lambda p, ctx: transformer.encode_context(p, cfg, ctx))

    def serve(self, requests: List[Request],
              context: Optional[jax.Array] = None) -> List[Request]:
        """Serve requests in static batches of max_batch (recurrent-mixer
        models additionally split into equal-prompt-length groups)."""
        t0 = time.monotonic()
        stats = ServeStats()
        if self.recurrent:
            by_len: Dict[int, List[Request]] = {}
            for r in requests:
                by_len.setdefault(len(r.prompt), []).append(r)
            groups = [g for _, g in sorted(by_len.items())]
        else:
            groups = [requests]
        for group in groups:
            for i in range(0, len(group), self.max_batch):
                self._serve_batch(group[i:i + self.max_batch], context,
                                  t0, stats)
        stats.tokens = sum(len(r.output) for r in requests)
        stats.wall_s = time.monotonic() - t0
        self.last_stats = stats
        return requests

    def _serve_batch(self, batch: List[Request],
                     context: Optional[jax.Array], t0: float,
                     stats: ServeStats) -> None:
        b = len(batch)
        # right-pad prompts to the longest; track true lengths. Causal
        # masking keeps each row's prefix exact; the row's first token
        # reads at its OWN last real position and decode continues from
        # its OWN length (the seed engine conditioned on the padding).
        lens = np.array([len(r.prompt) for r in batch], np.int32)
        plen = int(lens.max())
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(batch):
            prompts[i, :len(r.prompt)] = r.prompt
        max_new = max(r.max_new_tokens for r in batch)
        assert plen + max_new <= self.max_seq, "increase max_seq"

        ctx = None
        if context is not None:
            ctx = self._encode(self.params, context[:b])

        cache = transformer.init_cache(self.cfg, b, self.max_seq, self.dtype)
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      cache, ctx,
                                      jnp.asarray(lens - 1))
        next_tok = jnp.argmax(logits, axis=-1)
        np.asarray(next_tok)              # first tokens now materialized
        ttft = time.monotonic() - t0
        stats.prefills += 1
        for r in batch:
            r.ttft_s = ttft
            stats.ttft_s.append(ttft)
        offsets = jnp.asarray(lens)
        outs = [[] for _ in range(b)]
        done = [False] * b
        for _ in range(max_new):
            emitted = 0
            for i in range(b):
                if not done[i]:
                    outs[i].append(int(next_tok[i]))
                    emitted += 1
                    r = batch[i]
                    if (r.eos_id is not None and outs[i][-1] == r.eos_id) or \
                            len(outs[i]) >= r.max_new_tokens:
                        done[i] = True
            if all(done):
                break
            ts = time.monotonic()
            logits, cache = self._decode(self.params, next_tok, cache, ctx,
                                         offsets)
            offsets = offsets + 1
            next_tok = jnp.argmax(logits, axis=-1)
            np.asarray(next_tok)
            stats.step_wall_s.append(time.monotonic() - ts)
            stats.step_tokens.append(emitted)
            stats.decode_steps += 1
        now = time.monotonic() - t0
        for r, o in zip(batch, outs):
            r.output = o
            r.finish_s = now
