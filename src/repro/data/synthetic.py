"""Synthetic data pipeline (the container is offline; see DESIGN.md §7).

Two generators:

* ``TokenStream`` — deterministic synthetic LM token stream with Zipfian
  unigram statistics and a Markov bigram structure, so the LM loss has
  real signal (a model that learns beats the unigram entropy floor).
* ``classification_dataset`` — Gaussian-mixture classification standing in
  for MNIST / CIFAR-10 in the paper's experiments (same shapes: 784-dim /
  3072-dim inputs, 10 classes), with a train/test split.

Both are seeded and sliced per node: node i receives shard i of every
batch, matching the paper's "each node holds a local dataset D_i".
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

__all__ = ["TokenStream", "classification_dataset",
           "node_partitioned_batches"]


@dataclasses.dataclass
class TokenStream:
    """Deterministic LM batches: (tokens, labels) with labels = shift-left."""

    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # Zipf unigram + low-rank bigram transition for learnable structure.
        unigram = 1.0 / np.arange(1, v + 1) ** 1.1
        self._unigram = unigram / unigram.sum()
        rank = min(16, v)
        self._emb = rng.normal(size=(v, rank)).astype(np.float32)
        self._out = rng.normal(size=(rank, v)).astype(np.float32)

    def batches(self, start_step: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s, v = self.batch, self.seq_len, self.vocab_size
        toks = np.empty((b, s + 1), np.int64)
        toks[:, 0] = rng.choice(v, size=b, p=self._unigram)
        # historical warm-up draw: keeps the rng stream (and every
        # pinned batch downstream) identical across revisions
        _ = rng.random((b, s)).astype(np.float32)
        for t in range(s):
            logits = self._emb[toks[:, t]] @ self._out  # (b, v)
            logits = logits / 2.0 + np.log(self._unigram)[None, :]
            # Gumbel-max sampling, vectorized over batch
            g = -np.log(-np.log(
                rng.random((b, v)).astype(np.float32) + 1e-9) + 1e-9)
            toks[:, t + 1] = np.argmax(logits + g, axis=-1)
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)


def classification_dataset(n_features: int, n_classes: int, n_train: int,
                           n_test: int, seed: int = 0,
                           class_sep: float = 2.0):
    """Gaussian-mixture stand-in for MNIST (784) / CIFAR-10 (3072)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_classes, n_features)).astype(np.float32)
    centers *= class_sep / np.linalg.norm(centers, axis=1, keepdims=True)

    def sample(n, s):
        r = np.random.default_rng((seed, s))
        ys = r.integers(0, n_classes, size=n)
        xs = centers[ys] + r.normal(size=(n, n_features)).astype(np.float32)
        return xs.astype(np.float32), ys.astype(np.int32)

    return sample(n_train, 1), sample(n_test, 2)


def node_partitioned_batches(xs: np.ndarray, ys: np.ndarray, n_nodes: int,
                             batch_per_node: int, seed: int = 0
                             ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yield (n_nodes, batch, ...) stacks; node i only ever sees shard i.

    The dataset is split into n_nodes static shards (the paper's local
    datasets D_i with |D_i| = m); every step each node subsamples its own
    shard — the subsampling that drives Theorem 1's tau.
    """
    n = xs.shape[0] // n_nodes
    shards_x = xs[: n * n_nodes].reshape(n_nodes, n, *xs.shape[1:])
    shards_y = ys[: n * n_nodes].reshape(n_nodes, n)
    step = 0
    while True:
        r = np.random.default_rng((seed, step))
        idx = r.integers(0, n, size=(n_nodes, batch_per_node))
        bx = np.take_along_axis(
            shards_x, idx.reshape(n_nodes, -1, *([1] * (xs.ndim - 1))), axis=1)
        by = np.take_along_axis(shards_y, idx, axis=1)
        yield bx, by
        step += 1
