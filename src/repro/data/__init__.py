from repro.data.synthetic import (TokenStream, classification_dataset,
                                  node_partitioned_batches)

__all__ = ["TokenStream", "classification_dataset",
           "node_partitioned_batches"]
