"""Edge-fleet model: heterogeneous compute, bandwidth, faults, churn.

A ``FleetSpec`` is parsed from a scenario spec string (the ``--sim`` CLI
axis and the benchmark scenarios use the same grammar):

    key=value[,key=value...]     e.g.
    "q=0.8,deadline=1.5,straggle=0.25x8,dropout=0.05,churn=0.02:5"

Keys (all optional; omitted keys mean "no such fault"):

    compute=<dist>      per-node seconds of local compute per round
                        (default lognormal:-2.5:0.4 ~ 80ms median)
    bw=<dist>           per-node uplink bandwidth, bits/second, drawn
                        once per node at fleet build
                        (default lognormal:16:0.5 ~ 9 Mbit/s median)
    q=<f>               participation fraction: each up node is sampled
                        into the round independently w.p. q (default 1)
    deadline=<f>        round deadline in seconds; participants whose
                        compute+transmit finishes later are STRAGGLERS —
                        their payload is withheld (one-step-stale gossip)
                        (default none: the round waits for everyone)
    straggle=<f>x<m>    fraction f of nodes are permanent stragglers with
                        compute time multiplied by m
    dropout=<f>         per-round probability a sampled participant dies
                        mid-round (contributes nothing; its compute and
                        any partial transmission are wasted time)
    churn=<f>[:<r>]     per-round per-node probability of a membership
                        flip; a leaving node stays down >= r rounds
                        (default 3) before it may rejoin. Membership
                        changes RECOMPILE the gossip schedule segment.

Distribution specs: ``const:v`` | ``uniform:lo:hi`` | ``exp:mean`` |
``lognormal:mu:sigma`` (mu/sigma of log). Every draw flows through PRNG
streams spawned from the fleet seed — same (seed, spec) gives the same
fleet, faults, and participation trace, bit for bit.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["Distribution", "FleetSpec", "Fleet", "SCENARIOS",
           "parse_scenario", "effective_participation_q"]


@dataclasses.dataclass(frozen=True)
class Distribution:
    """A tiny seedable sampler parsed from ``kind:arg[:arg]`` specs."""

    kind: str
    args: Tuple[float, ...]

    @classmethod
    def parse(cls, spec: "str | float | Distribution") -> "Distribution":
        if isinstance(spec, Distribution):
            return spec
        if isinstance(spec, (int, float)):
            return cls("const", (float(spec),))
        parts = str(spec).split(":")
        kind, args = parts[0], tuple(float(a) for a in parts[1:])
        arity = {"const": 1, "uniform": 2, "exp": 1, "lognormal": 2}
        if kind not in arity:
            raise ValueError(
                f"unknown distribution {spec!r}; use one of {sorted(arity)}")
        if len(args) != arity[kind]:
            raise ValueError(
                f"{kind} takes {arity[kind]} arg(s), got {spec!r}")
        return cls(kind, args)

    def sample(self, rng: np.random.Generator, size=None) -> np.ndarray:
        a = self.args
        if self.kind == "const":
            return np.full(size, a[0]) if size else a[0]
        if self.kind == "uniform":
            return rng.uniform(a[0], a[1], size=size)
        if self.kind == "exp":
            return rng.exponential(a[0], size=size)
        return rng.lognormal(a[0], a[1], size=size)


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Parsed scenario knobs (see module docstring for the grammar)."""

    compute: Distribution = Distribution("lognormal", (-2.5, 0.4))
    bandwidth: Distribution = Distribution("lognormal", (16.0, 0.5))
    participation_q: float = 1.0
    deadline: Optional[float] = None
    straggler_frac: float = 0.0
    straggler_slowdown: float = 1.0
    dropout: float = 0.0
    churn: float = 0.0
    churn_min_down: int = 3

    def __post_init__(self) -> None:
        if not (0.0 < self.participation_q <= 1.0):
            raise ValueError(
                f"q must be in (0, 1], got {self.participation_q!r}")
        for fname in ("straggler_frac", "dropout", "churn"):
            v = getattr(self, fname)
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"{fname} must be in [0, 1], got {v!r}")
        if self.straggler_slowdown < 1.0:
            raise ValueError("straggler slowdown must be >= 1")
        if self.deadline is not None and self.deadline <= 0.0:
            raise ValueError("deadline must be > 0 seconds")
        if self.churn_min_down < 1:
            raise ValueError("churn min-down must be >= 1 round")

    @property
    def faulty(self) -> bool:
        return (self.participation_q < 1.0 or self.deadline is not None
                or self.dropout > 0.0 or self.churn > 0.0)


def parse_scenario(spec: "str | FleetSpec") -> FleetSpec:
    """Parse a scenario spec string (or named preset) into a FleetSpec."""
    if isinstance(spec, FleetSpec):
        return spec
    spec = spec.strip()
    if spec.lower() in SCENARIOS:
        return SCENARIOS[spec.lower()]
    kw = {}
    for item in filter(None, (s.strip() for s in spec.split(","))):
        if "=" not in item:
            raise ValueError(f"scenario items are key=value, got {item!r}")
        k, v = (s.strip() for s in item.split("=", 1))
        if k == "compute":
            kw["compute"] = Distribution.parse(v)
        elif k == "bw":
            kw["bandwidth"] = Distribution.parse(v)
        elif k == "q":
            kw["participation_q"] = float(v)
        elif k == "deadline":
            kw["deadline"] = float(v)
        elif k == "straggle":
            frac, _, slow = v.partition("x")
            kw["straggler_frac"] = float(frac)
            kw["straggler_slowdown"] = float(slow) if slow else 4.0
        elif k == "dropout":
            kw["dropout"] = float(v)
        elif k == "churn":
            rate, _, min_down = v.partition(":")
            kw["churn"] = float(rate)
            if min_down:
                kw["churn_min_down"] = int(min_down)
        else:
            raise ValueError(f"unknown scenario key {k!r} in {spec!r}")
    return FleetSpec(**kw)


# Named presets: the benchmark's scenario axis and handy --sim shorthands.
SCENARIOS = {
    "no-fault": FleetSpec(),
    "straggler": FleetSpec(straggler_frac=0.25, straggler_slowdown=6.0,
                           deadline=0.6),
    "dropout": FleetSpec(participation_q=0.8, dropout=0.1),
    "churn": FleetSpec(churn=0.05, churn_min_down=4),
}


class Fleet:
    """A concrete fleet: per-node rates plus the fault/membership processes.

    All stochastic decisions flow through independent PRNG streams spawned
    from one ``np.random.SeedSequence`` so adding draws to one process
    never perturbs another (the determinism contract).
    """

    def __init__(self, n_nodes: int, spec: "str | FleetSpec",
                 seed: int = 0) -> None:
        if n_nodes < 2:
            raise ValueError("a fleet needs >= 2 nodes")
        self.n_nodes = n_nodes
        self.spec = parse_scenario(spec)
        ss = np.random.SeedSequence(seed)
        (self._rng_build, self._rng_compute, self._rng_part,
         self._rng_drop, self._rng_churn) = (
            np.random.default_rng(s) for s in ss.spawn(5))

        self.bandwidth = np.maximum(
            self.spec.bandwidth.sample(self._rng_build, n_nodes), 1.0)
        n_strag = int(round(self.spec.straggler_frac * n_nodes))
        self.stragglers = np.zeros(n_nodes, dtype=bool)
        if n_strag:
            idx = self._rng_build.choice(n_nodes, size=n_strag,
                                         replace=False)
            self.stragglers[idx] = True
        self.up = np.ones(n_nodes, dtype=bool)       # current membership
        self._down_until = np.zeros(n_nodes, dtype=np.int64)

    # -- per-round processes ------------------------------------------------
    def compute_time(self, node: int) -> float:
        t = float(self.spec.compute.sample(self._rng_compute))
        if self.stragglers[node]:
            t *= self.spec.straggler_slowdown
        return max(t, 1e-6)

    def transmit_time(self, node: int, bits: int) -> float:
        return float(bits) / float(self.bandwidth[node])

    def sample_participants(self) -> np.ndarray:
        """(n,) bool: up nodes sampled into this round w.p. q (>= 2 kept).

        When the Bernoulli draw leaves fewer than two participants the
        smallest-index up nodes are forced in — a 1-node "round" has no
        gossip semantics at all.
        """
        q = self.spec.participation_q
        part = self.up & (self._rng_part.random(self.n_nodes) < q)
        deficit = 2 - int(part.sum())
        if deficit > 0:
            for i in np.nonzero(self.up & ~part)[0][:deficit]:
                part[i] = True
        return part

    def sample_dropouts(self, participants: np.ndarray) -> np.ndarray:
        """(n,) bool: participants that die mid-round (no contribution)."""
        if self.spec.dropout <= 0.0:
            return np.zeros(self.n_nodes, dtype=bool)
        dead = participants & (
            self._rng_drop.random(self.n_nodes) < self.spec.dropout)
        # never kill the whole round
        alive = participants & ~dead
        if int(alive.sum()) < 2:
            for i in np.nonzero(dead)[0][:2 - int(alive.sum())]:
                dead[i] = False
        return dead

    def churn_step(self, round_index: int) -> List[Tuple[int, str]]:
        """Advance membership one round; returns [(node, "join"|"leave")].

        Leaves keep >= churn_min_down rounds of downtime; at most
        n_nodes - 2 nodes may be down at once.
        """
        events: List[Tuple[int, str]] = []
        if self.spec.churn <= 0.0:
            return events
        flips = self._rng_churn.random(self.n_nodes) < self.spec.churn
        for i in range(self.n_nodes):
            if self.up[i] and flips[i]:
                if int(self.up.sum()) <= 2:
                    continue
                self.up[i] = False
                self._down_until[i] = round_index + self.spec.churn_min_down
                events.append((i, "leave"))
            elif not self.up[i] and flips[i] and \
                    round_index >= self._down_until[i]:
                self.up[i] = True
                events.append((i, "join"))
        return events

    def mean_bandwidth(self) -> float:
        return float(np.mean(self.bandwidth))

    def describe(self) -> str:
        s = self.spec
        bits = [f"n={self.n_nodes}", f"q={s.participation_q}"]
        if s.deadline is not None:
            bits.append(f"deadline={s.deadline}s")
        if s.straggler_frac:
            bits.append(f"straggle={s.straggler_frac}x{s.straggler_slowdown}")
        if s.dropout:
            bits.append(f"dropout={s.dropout}")
        if s.churn:
            bits.append(f"churn={s.churn}:{s.churn_min_down}")
        bits.append(f"bw~{self.mean_bandwidth() / 1e6:.1f}Mbit/s")
        return " ".join(bits)


def effective_participation_q(fleet: Fleet) -> float:
    """The q the privacy accountant should amplify with.

    Participation sampling, mid-round dropout, and churn downtime all
    REDUCE how often a node's data is released, but only the sampling is
    adversary-independent randomness the subsampled-RDP lemma can use;
    charging q alone (ignoring dropout/churn) is the conservative bound.
    """
    return fleet.spec.participation_q


