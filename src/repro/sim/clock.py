"""Deterministic virtual clock + event queue for the edge-fleet simulator.

Time is simulated seconds (float); nothing here ever reads a wall clock.
Determinism contract: events at equal timestamps order by their insertion
sequence number, so a (seed, scenario) pair replays to a bit-identical
event trace on any host — the property ``tests/test_sim.py`` pins.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, List, Tuple

__all__ = ["Event", "EventQueue", "VirtualClock", "trace_signature"]


@dataclasses.dataclass(frozen=True, order=True)
class Event:
    """One simulator event, totally ordered by (time, seq).

    ``kind`` is a short tag ("compute-done", "send-done", "round-close",
    "join", "leave", ...), ``node`` the subject node (or -1 for fleet-wide
    events), ``data`` a sorted tuple of (key, value) pairs — tuples, not
    dicts, so the trace is hashable and comparable across runs.
    """

    time: float
    seq: int
    kind: str = dataclasses.field(compare=False)
    node: int = dataclasses.field(compare=False, default=-1)
    data: Tuple[Tuple[str, Any], ...] = dataclasses.field(
        compare=False, default=())


class EventQueue:
    """Min-heap of Events with a deterministic same-time tiebreak."""

    def __init__(self) -> None:
        self._heap: List[Event] = []
        self._seq = 0

    def push(self, time: float, kind: str, node: int = -1,
             **data: Any) -> Event:
        ev = Event(time=float(time), seq=self._seq, kind=kind, node=node,
                   data=tuple(sorted(data.items())))
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class VirtualClock:
    """Monotone simulated time; also records the popped-event trace."""

    def __init__(self) -> None:
        self.now = 0.0
        self.trace: List[Event] = []

    def advance_to(self, t: float) -> None:
        if t < self.now - 1e-12:
            raise ValueError(f"clock moved backwards: {t} < {self.now}")
        self.now = max(self.now, float(t))

    def record(self, ev: Event) -> Event:
        self.advance_to(ev.time)
        self.trace.append(ev)
        return ev

    def drain(self, queue: EventQueue, until: float) -> List[Event]:
        """Pop + record every event with time <= until (in order)."""
        out: List[Event] = []
        while queue and queue._heap[0].time <= until + 1e-12:
            out.append(self.record(queue.pop()))
        return out


def trace_signature(trace) -> Tuple:
    """A hashable, comparison-stable rendering of an event trace."""
    return tuple((round(ev.time, 9), ev.seq, ev.kind, ev.node, ev.data)
                 for ev in trace)
