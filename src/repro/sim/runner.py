"""Event-driven edge-fleet runner: simulated wall-clock-to-accuracy.

Drives any registered Method's stacked reference executor under a
``Fleet``'s time model. Each global round:

  1. the fleet samples participants (participation fraction q), mid-round
     dropouts, and membership churn;
  2. every participant is charged its compute time plus its exact wire
     payload (``method.transmitted_bits`` — plane-padded, compressor- and
     index-channel-exact) pushed through its sampled uplink bandwidth;
  3. a round deadline (when configured) turns late finishers into
     STRAGGLERS: differential methods withhold their payload — neighbours
     mix with one-step-stale public copies and the update merges into the
     next round's differential (``method.withhold_differential`` /
     ``defer_differential``); methods that transmit absolute state treat
     them as non-participants;
  4. the round's mixing graph is the induced subgraph on contributors
     (``topology.masked_subgraph`` — inactive rows are identity), compiled
     per membership segment into an ordinary ``ScheduleSequence``, so the
     executors see nothing but a (time-varying) schedule; membership churn
     ends the segment and RECOMPILES under the new fleet.

Everything stochastic flows through the fleet's spawned PRNG streams plus
one fold_in-derived jax key per round: a (seed, scenario) pair replays to
a bit-identical event trace and final parameters.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, List, Optional

import jax
import numpy as np

from repro.core import PrivacyAccountant, gossip, method as method_mod
from repro.core import topology as topology_mod
from repro.sim.clock import EventQueue, VirtualClock
from repro.sim.fleet import Fleet, parse_scenario
from repro.train.trainer import TrainResult

PyTree = Any

__all__ = ["SimResult", "simulate"]


@dataclasses.dataclass
class SimResult:
    """Outcome of one simulated run (``result`` reuses TrainResult)."""

    result: TrainResult          # losses/comm/epsilons + sim_time_s column
    trace: tuple                 # full ordered event trace (determinism)
    final_params: PyTree         # per-node parameter stack at the end
    rounds: int                  # global rounds executed
    recompiles: int              # schedule recompilations (churn segments)
    straggler_rounds: int        # (node, round) pairs past the deadline
    dropout_rounds: int          # (node, round) pairs dead mid-round
    sim_seconds: float           # final virtual-clock time
    time_to_target: Optional[float] = None   # seconds to target_loss
    rounds_to_target: Optional[int] = None

    @property
    def trace_signature(self):
        from repro.sim.clock import trace_signature
        return trace_signature(self.trace)


def _out_degree(topo) -> np.ndarray:
    """Per-node payload count on a round graph (col sums when directed)."""
    adj = np.asarray(topo.adjacency)
    if isinstance(topo, topology_mod.DirectedTopology):
        return adj.sum(axis=0).astype(np.int64)
    return adj.sum(axis=1).astype(np.int64)


def simulate(
    *,
    topo,                              # base Topology | spec string
    algorithm: str,
    sdm_cfg: Any,
    params_stack: PyTree,
    grad_fn: Callable,
    batches: Iterator,
    rounds: int,
    scenario: "str | Any" = "no-fault",
    seed: int = 0,
    privacy=None,                      # PrivacyParams; q is folded in here
    eps_target: float = 1.0,
    eval_fn: Optional[Callable] = None,
    eval_every: int = 0,
    target_loss: Optional[float] = None,
    max_segment: int = 64,
) -> SimResult:
    """Run ``rounds`` simulated global rounds and return the SimResult.

    ``topo`` is the FULL-fleet base graph; per-round participation masks
    it. ``privacy`` (when given) is amplified with the scenario's
    participation fraction q (subsampled RDP — see
    ``PrivacyParams.participation_q``) before accounting. ``target_loss``
    records simulated seconds-to-target without stopping the run early.
    """
    n = jax.tree.leaves(params_stack)[0].shape[0]
    if isinstance(topo, str):
        topo = topology_mod.by_name(topo, n)
    if topo.n_nodes != n:
        raise ValueError(f"stack has {n} nodes, topology {topo.n_nodes}")
    spec = parse_scenario(scenario)
    fleet = Fleet(n, spec, seed=seed)

    meth = method_mod.get(algorithm)
    cfg = meth.coerce_config(sdm_cfg)
    stale_ok = method_mod.stale_capable(meth)
    # overlapped transport (cfg.overlap): the wire carries the previous
    # round's payload while this round's gradient computes, so a node's
    # round-ready time is max(compute, transmit) instead of their sum.
    overlap = bool(getattr(cfg, "overlap", False))
    per_node = jax.tree.map(lambda x: x[0], params_stack)
    # exact per-EDGE payload (seq=None: one payload); timing and comm
    # charges then scale by each node's own out-degree per round graph.
    edge_elems = method_mod.transmitted_elements(meth, per_node, cfg)
    edge_bits = method_mod.transmitted_bits(meth, per_node, cfg)

    if privacy is not None and spec.participation_q < 1.0:
        privacy = dataclasses.replace(
            privacy, participation_q=spec.participation_q)
    accountant = PrivacyAccountant(privacy, eps_target) if privacy else None

    wall0 = time.time()
    clock = VirtualClock()
    queue = EventQueue()
    base_key = jax.random.PRNGKey(seed)

    losses: List[float] = []
    comm: List[int] = []
    bits_l: List[int] = []
    epss: List[float] = []
    accs: List[float] = []
    sim_times: List[float] = []
    total_elems = 0
    total_bits = 0
    recompiles = 0
    straggler_rounds = 0
    dropout_rounds = 0
    time_to_target = None
    rounds_to_target = None

    state = None
    carried_x = params_stack
    carried_d = None
    carried_e = None
    t_global = 0

    while t_global < rounds:
        # ---- sample one membership segment's draws (fixed fleet.up) ------
        # draws are collected FIRST, then plans derive from them as a pure
        # function of the overlap flag — so a segment can be re-planned
        # with the serialized wire without re-consuming any PRNG stream.
        seg_draws = []
        seg_start = t_global
        while len(seg_draws) < min(max_segment, rounds - seg_start):
            t = seg_start + len(seg_draws)
            participants = fleet.sample_participants()
            dead = fleet.sample_dropouts(participants)
            # out-degrees on the participant graph: what each node *plans*
            # to push this round (dead nodes still occupy airtime).
            plan_topo = topology_mod.masked_subgraph(
                topo, np.nonzero(participants)[0], name=f"{topo.name}_plan")
            outdeg = _out_degree(plan_topo)
            comp_tx = {
                int(i): (fleet.compute_time(int(i)),
                         fleet.transmit_time(int(i),
                                             edge_bits * int(outdeg[i])))
                for i in np.nonzero(participants)[0]}
            churn = fleet.churn_step(t)
            seg_draws.append(dict(t=t, participants=participants, dead=dead,
                                  outdeg=outdeg, comp_tx=comp_tx,
                                  churn=churn))
            if churn:
                break           # membership changed: recompile next segment

        def build_plans(use_overlap):
            plans, active_sets = [], []
            for dr in seg_draws:
                participants, dead = dr["participants"], dr["dead"]
                contributors = participants & ~dead
                # overlapped transport: the wire rides under compute, so a
                # node is round-ready at max(compute, tx), not their sum.
                times = {i: (c, max(c, tx) if use_overlap else c + tx)
                         for i, (c, tx) in dr["comp_tx"].items()}
                finishes = {i: f for i, (_, f) in times.items()
                            if contributors[i]}
                close = max(finishes.values()) if finishes else 0.0
                if spec.deadline is not None:
                    close = min(close, spec.deadline)
                stragglers = np.zeros(n, dtype=bool)
                if spec.deadline is not None:
                    for i, f in finishes.items():
                        if f > spec.deadline + 1e-12:
                            stragglers[i] = True
                if stale_ok:
                    # stragglers stay IN the round graph (their edges keep
                    # weights), their payload is withheld: one-step-stale.
                    round_active = contributors
                    withhold = stragglers
                else:
                    # absolute-state methods: a straggler's stale payload
                    # has no deferral buffer — degrade to non-participation.
                    round_active = contributors & ~stragglers
                    withhold = np.zeros(n, dtype=bool)
                    if int(round_active.sum()) < 2:
                        round_active = contributors
                        stragglers = np.zeros(n, dtype=bool)
                plans.append(dict(
                    t=dr["t"], participants=participants, dead=dead,
                    contributors=contributors, stragglers=stragglers,
                    withhold=withhold, round_active=round_active,
                    times=times, close=close, outdeg=dr["outdeg"],
                    churn=dr["churn"]))
                active_sets.append(np.nonzero(round_active)[0])
            return plans, active_sets

        seg_plans, seg_active_sets = build_plans(overlap)
        seq = gossip.sequence_from_active_sets(
            topo, seg_active_sets,
            name=f"{topo.name}_seg{seg_start}x{len(seg_active_sets)}")
        seg_cfg = cfg
        if overlap and gossip.needs_replicas(seq):
            # varying membership inside the segment compiles to a replica
            # (time-varying) schedule, which the double-buffered transport
            # cannot ride — degrade THIS segment to the serialized wire
            # (both the executor and the round clock).
            seg_plans, seg_active_sets = build_plans(False)
            seq = gossip.sequence_from_active_sets(
                topo, seg_active_sets,
                name=f"{topo.name}_seg{seg_start}x{len(seg_active_sets)}")
            seg_cfg = dataclasses.replace(cfg, overlap=False)

        # ---- compile the segment schedule + executor ---------------------
        sim = meth.make_reference(seq, seg_cfg)
        state = sim.init(carried_x)
        if carried_d is not None and hasattr(state, "d"):
            state = state._replace(d=carried_d)
        if carried_e is not None and getattr(state, "e", None) is not None:
            state = state._replace(e=carried_e)
        if seg_start > 0:
            recompiles += 1
            queue.push(clock.now, "recompile",
                       n_up=int(fleet.up.sum()), rounds=len(seg_plans))

        step_fn = jax.jit(
            lambda state, batch, key: sim.step(state, grad_fn, batch, key))

        # ---- execute the segment ------------------------------------------
        for plan in seg_plans:
            t = plan["t"]
            t0 = clock.now
            for i, (c, f) in sorted(plan["times"].items()):
                if plan["dead"][i]:
                    queue.push(t0 + min(f, plan["close"]), "drop", node=i)
                    dropout_rounds += 1
                elif plan["stragglers"][i]:
                    queue.push(t0 + plan["close"], "deadline-miss", node=i,
                               late_by=round(f - plan["close"], 9))
                    straggler_rounds += 1
                else:
                    queue.push(t0 + c, "compute-done", node=i)
                    queue.push(t0 + f, "send-done", node=i,
                               bits=edge_bits * int(plan["outdeg"][i]))
            round_close = t0 + plan["close"]
            clock.drain(queue, round_close)
            clock.advance_to(round_close)

            key = jax.random.fold_in(base_key, t)
            batch = next(batches)
            prev_state = state
            stepped_in = state
            withheld = None
            if plan["withhold"].any():
                stepped_in, withheld = method_mod.withhold_differential(
                    meth, state, send_mask=~plan["withhold"])
            state, loss = step_fn(stepped_in, batch, key)
            if withheld is not None:
                state = method_mod.defer_differential(meth, state, withheld)
            # frozen nodes (non-participants, dropouts, down members — and
            # excluded stragglers on absolute-state methods) did nothing:
            # revert their rows wholesale (keeps their pending d too).
            frozen = ~(plan["round_active"]
                       | (plan["stragglers"] & stale_ok))
            if frozen.any():
                state = method_mod.select_node_rows(~frozen, state,
                                                    prev_state)

            losses.append(float(loss))
            delivered = plan["round_active"] & ~plan["withhold"]
            edges = int(plan["outdeg"][delivered].sum()) if delivered.any() \
                else 0
            # charge only DELIVERED payloads (withheld/late bits never
            # complete; partial straggler airtime is wasted time, not comm)
            total_elems += edge_elems * edges
            total_bits += edge_bits * edges
            comm.append(total_elems)
            bits_l.append(total_bits)
            sim_times.append(clock.now)
            if accountant is not None:
                accountant.step()
                epss.append(accountant.epsilon)
            if eval_fn is not None and eval_every and \
                    (t + 1) % eval_every == 0:
                accs.append(float(eval_fn(sim.eval_params(state))))
            if target_loss is not None and time_to_target is None and \
                    losses[-1] <= target_loss:
                time_to_target = clock.now
                rounds_to_target = t + 1
            queue.push(round_close, "round-close", t=t,
                       active=int(plan["round_active"].sum()))
            clock.drain(queue, round_close)
            for node_i, kind in plan["churn"]:
                queue.push(clock.now, kind, node=node_i)
            clock.drain(queue, clock.now)
            t_global = t + 1

        carried_x = state.x
        carried_d = getattr(state, "d", None)
        carried_e = getattr(state, "e", None)

    result = TrainResult(losses=losses, comm_elements=comm,
                         comm_bits=bits_l, epsilons=epss,
                         eval_accuracy=accs, wall_s=time.time() - wall0,
                         sim_time_s=sim_times)
    return SimResult(result=result, trace=tuple(clock.trace),
                     final_params=state.x, rounds=t_global,
                     recompiles=recompiles,
                     straggler_rounds=straggler_rounds,
                     dropout_rounds=dropout_rounds,
                     sim_seconds=clock.now,
                     time_to_target=time_to_target,
                     rounds_to_target=rounds_to_target)
