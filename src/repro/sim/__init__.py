"""Event-driven edge-fleet simulator (see sim.runner module docstring).

Public surface::

    from repro.sim import Fleet, FleetSpec, SCENARIOS, simulate, SimResult
"""
from repro.sim.clock import (Event, EventQueue, VirtualClock,   # noqa: F401
                             trace_signature)
from repro.sim.fleet import (Distribution, Fleet, FleetSpec,    # noqa: F401
                             SCENARIOS, parse_scenario,
                             effective_participation_q)
from repro.sim.runner import SimResult, simulate                # noqa: F401

__all__ = ["Event", "EventQueue", "VirtualClock", "trace_signature",
           "Distribution", "Fleet", "FleetSpec", "SCENARIOS",
           "parse_scenario", "effective_participation_q",
           "SimResult", "simulate"]
