from repro.train.steps import (DistributedTrainConfig, make_distributed_train,
                               make_prefill_fn, make_decode_fn)

__all__ = ["DistributedTrainConfig", "make_distributed_train",
           "make_prefill_fn", "make_decode_fn"]
