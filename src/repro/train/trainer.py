"""Single-host trainer for the paper's experiments (CPU-scale models).

Drives any registered method's stacked reference executor
(``repro.core.method``) over node-partitioned batches, tracks the
paper's two metrics — communicated non-zero elements (Fig. 3's x-axis,
method-aware: full state for DSGD/gradient-push, the sparse fraction
for SDM-DSGD, heterogeneous per-node budgets supported) and the
(eps, delta) privacy spend (Table 1) — and handles eval + checkpointing.
Used by the examples and the paper-figure benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, List, Optional

import jax

from repro.checkpoint import save_checkpoint
from repro.core import PrivacyAccountant, PrivacyParams, method as method_mod
from repro.core import gossip

PyTree = Any


@dataclasses.dataclass
class TrainResult:
    losses: List[float]
    comm_elements: List[int]     # cumulative non-zero elements transmitted
    comm_bits: List[int]         # cumulative wire bits (compressor-exact:
    #                              index side-channels, quantized widths)
    epsilons: List[float]
    eval_accuracy: List[float]
    wall_s: float
    # simulated seconds at the END of each round — filled by the
    # edge-fleet simulator (repro.sim.runner), which charges compute-time
    # and bandwidth-limited transmission per node; empty for the lock-step
    # trainer below, whose rounds have no time model.
    sim_time_s: List[float] = dataclasses.field(default_factory=list)


def run_decentralized(
    *,
    topo,                            # Topology | ScheduleSequence | spec str
    algorithm: str,                  # method registry name ('sdm_dsgd', ...)
    sdm_cfg: Any,                    # hyper-params; coerced per method
    params_stack: PyTree,
    grad_fn: Callable,               # (params_stack, batch) -> (grads, loss)
    batches: Iterator,
    steps: int,
    seed: int = 0,
    privacy: Optional[PrivacyParams] = None,
    eps_target: float = 1.0,
    eval_fn: Optional[Callable] = None,   # params_stack -> accuracy
    eval_every: int = 50,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    log_every: int = 0,
) -> TrainResult:
    """Generic decentralized training loop over a stacked-node executor.

    ``algorithm`` is any ``repro.core.method`` registry name (legacy
    underscore spellings normalize). ``topo`` may be a Topology /
    DirectedTopology, a ScheduleSequence, or a spec string ("ring",
    "er:0.35", "dring", "matchings:4", ...); the node count is then read
    off the params stack.
    """
    t0 = time.time()
    n_nodes = jax.tree.leaves(params_stack)[0].shape[0]
    if isinstance(topo, str):
        seq = gossip.sequence_by_name(topo, n_nodes, seed=seed)
    else:
        seq = gossip.sequence_of(topo)

    meth = method_mod.get(algorithm)
    cfg = meth.coerce_config(sdm_cfg)
    sim = meth.make_reference(seq, cfg)
    per_node = jax.tree.map(lambda x: x[0], params_stack)
    # per-link schedule-aware accounting: payload size x the mean
    # out-degree over the sequence's rounds (union-graph degree on the
    # replica transport), so time-varying runs are charged what their
    # ppermute rounds actually move.
    per_step_elems = method_mod.transmitted_elements(meth, per_node, cfg,
                                                     seq=seq)
    per_step_bits = method_mod.transmitted_bits(meth, per_node, cfg, seq=seq)

    state = sim.init(params_stack)
    key = jax.random.PRNGKey(seed)
    accountant = PrivacyAccountant(privacy, eps_target) if privacy else None

    @jax.jit
    def step_fn(state, batch, key):
        return sim.step(state, grad_fn, batch, key)

    losses, comm, bits, epss, accs = [], [], [], [], []
    total_elems = 0
    total_bits = 0
    for t in range(steps):
        key, sub = jax.random.split(key)
        batch = next(batches)
        state, loss = step_fn(state, batch, sub)
        losses.append(float(loss))
        total_elems += per_step_elems * n_nodes
        total_bits += per_step_bits * n_nodes
        comm.append(total_elems)
        bits.append(total_bits)
        if accountant is not None:
            accountant.step()
            epss.append(accountant.epsilon)
        if eval_fn is not None and (t + 1) % eval_every == 0:
            accs.append(float(eval_fn(sim.eval_params(state))))
        if checkpoint_dir and checkpoint_every and \
                (t + 1) % checkpoint_every == 0:
            save_checkpoint(checkpoint_dir, t + 1, state)
        if log_every and (t + 1) % log_every == 0:
            msg = f"step {t + 1:5d} loss {losses[-1]:.4f}"
            if epss:
                msg += f" eps {epss[-1]:.3e}"
            if accs:
                msg += f" acc {accs[-1]:.4f}"
            print(msg, flush=True)
    return TrainResult(losses=losses, comm_elements=comm, comm_bits=bits,
                       epsilons=epss, eval_accuracy=accs,
                       wall_s=time.time() - t0)
