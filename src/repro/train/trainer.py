"""Single-host trainer for the paper's experiments (CPU-scale models).

Drives ReferenceSimulator / DSGDReference over node-partitioned batches,
tracks the paper's two metrics — communicated non-zero elements (Fig. 3's
x-axis) and the (eps, delta) privacy spend (Table 1) — and handles eval +
checkpointing. Used by the examples and the paper-figure benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.core import (DSGDConfig, DSGDReference, PrivacyAccountant,
                        PrivacyParams, ReferenceSimulator, SDMConfig,
                        sdm_dsgd)
from repro.core import topology as topology_mod
from repro.core.topology import Topology

PyTree = Any


@dataclasses.dataclass
class TrainResult:
    losses: List[float]
    comm_elements: List[int]     # cumulative non-zero elements transmitted
    epsilons: List[float]
    eval_accuracy: List[float]
    wall_s: float


def run_decentralized(
    *,
    topo: Topology | str,            # Topology, or a topology.by_name spec
    algorithm: str,                  # 'sdm_dsgd' | 'dc_dsgd' | 'dsgd'
    sdm_cfg: SDMConfig,
    params_stack: PyTree,
    grad_fn: Callable,               # (params_stack, batch) -> (grads, loss)
    batches: Iterator,
    steps: int,
    seed: int = 0,
    privacy: Optional[PrivacyParams] = None,
    eps_target: float = 1.0,
    eval_fn: Optional[Callable] = None,   # params_stack -> accuracy
    eval_every: int = 50,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    log_every: int = 0,
) -> TrainResult:
    """Generic decentralized training loop over a stacked-node simulator.

    ``topo`` may be a spec string ("ring", "er:0.35", "torus", "star",
    "complete"); the node count is then read off the params stack.
    """
    t0 = time.time()
    if isinstance(topo, str):
        n_nodes = jax.tree.leaves(params_stack)[0].shape[0]
        topo = topology_mod.by_name(topo, n_nodes, seed=seed)
    if algorithm == "dsgd":
        sim = DSGDReference(topo, DSGDConfig(gamma=sdm_cfg.gamma,
                                             sigma=sdm_cfg.sigma,
                                             clip_c=sdm_cfg.clip_c))
        per_step_elems = sum(int(x.size) for x in
                             jax.tree.leaves(params_stack)) // topo.n_nodes
    else:
        # dc_dsgd is SDM with theta=1 — caller encodes it in sdm_cfg.
        sim = ReferenceSimulator(topo, sdm_cfg)
        per_node = jax.tree.map(lambda x: x[0], params_stack)
        per_step_elems = sdm_dsgd.transmitted_elements_per_step(
            per_node, sdm_cfg)

    state = sim.init(params_stack)
    key = jax.random.PRNGKey(seed)
    accountant = PrivacyAccountant(privacy, eps_target) if privacy else None

    @jax.jit
    def step_fn(state, batch, key):
        return sim.step(state, grad_fn, batch, key)

    losses, comm, epss, accs = [], [], [], []
    total_elems = 0
    for t in range(steps):
        key, sub = jax.random.split(key)
        batch = next(batches)
        state, loss = step_fn(state, batch, sub)
        losses.append(float(loss))
        total_elems += per_step_elems * topo.n_nodes
        comm.append(total_elems)
        if accountant is not None:
            accountant.step()
            epss.append(accountant.epsilon)
        if eval_fn is not None and (t + 1) % eval_every == 0:
            accs.append(float(eval_fn(state.x)))
        if checkpoint_dir and checkpoint_every and \
                (t + 1) % checkpoint_every == 0:
            save_checkpoint(checkpoint_dir, t + 1, state)
        if log_every and (t + 1) % log_every == 0:
            msg = f"step {t + 1:5d} loss {losses[-1]:.4f}"
            if epss:
                msg += f" eps {epss[-1]:.3e}"
            if accs:
                msg += f" acc {accs[-1]:.4f}"
            print(msg, flush=True)
    return TrainResult(losses=losses, comm_elements=comm, epsilons=epss,
                       eval_accuracy=accs, wall_s=time.time() - t0)
