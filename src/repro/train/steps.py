"""Production train / prefill / decode step factories.

``make_distributed_train`` assembles the paper's algorithm at pod scale:

  * the node axis (``('pod','data')`` flattened) is MANUAL under
    `jax.shard_map` — each shard-group is one SDM-DSGD edge node running
    ring gossip with `lax.ppermute` (collective-permute on ICI);
  * the ``model`` axis stays AUTO — GSPMD tensor-partitions each node's
    model from the logical sharding rules;
  * per-node gradient -> coordinate clip -> Gaussian mask -> generalized
    theta-mixing -> sparse differential exchange, exactly Algorithm 1.

The per-node algorithm is METHOD-GENERIC: ``DistributedTrainConfig.method``
names a ``repro.core.method`` registry entry (sdm-dsgd, sdm-dsgd-fused,
dc-dsgd, dsgd, gradient-push, allreduce, ...), and this factory runs its
shard_map distributed executor — all methods share the same factory so
the roofline benchmarks compare like-for-like, and adding a method means
registering it, not editing this file.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import gossip, method as method_mod, plane as plane_mod
from repro.core import tagging
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.sharding import MeshRules, use_rules

PyTree = Any

# Logical-axis -> mesh-axis mapping used INSIDE the node-manual shard_map
# (node axes are manual there, so only 'model' appears) ...
INNER_RULES: Mapping[str, Any] = {
    "heads": "model", "kv_heads": "model", "mlp": "model",
    "heads_flat": "model", "kv_flat": "model",
    "vocab": "model", "experts": "model",
    "batch": None, "seq": None, "embed": None, "layers": None,
    "cache_seq": None,
}


def outer_rules(node_axes: Tuple[str, ...]) -> dict:
    """Rules for plain-jit (serving) steps and for jit-level in_shardings."""
    rules = dict(INNER_RULES)
    rules["batch"] = node_axes if len(node_axes) > 1 else node_axes[0]
    return rules


def serving_rules(node_axes: Tuple[str, ...], *, shard_cache_seq: bool,
                  decode: bool = False) -> dict:
    rules = outer_rules(node_axes)
    if decode:
        # flash-decoding layout: the KV cache's sequence dim shards over
        # the model axis (idle during decode attention); softmax over the
        # sharded length costs only tiny max/sum psums per layer.
        rules["cache_seq"] = "model"
    if shard_cache_seq:
        # long-context decode: batch=1 cannot shard; spread the cache's
        # sequence dim over BOTH data and model axes instead.
        rules["cache_seq"] = ("data", "model")
        rules["batch"] = None
    return rules


@dataclasses.dataclass(frozen=True)
class DistributedTrainConfig:
    """Production train-step configuration.

    ``method`` names a ``repro.core.method`` registry entry (legacy
    underscore spellings like "sdm_dsgd" normalize transparently).
    ``sdm`` is the hyper-parameter bag; each method coerces it to its
    own config dataclass (e.g. DSGD keeps only gamma/sigma/clip_c).
    """

    model: ModelConfig
    sdm: Any
    topology: str = "ring"              # spec for gossip.sequence_by_name
    topology_seed: int = 0              # ER graph / matching sampling seed
    self_weight: float = 1.0 / 3.0      # ring W_ii; neighbours get (1-W_ii)/2
    method: str = "sdm-dsgd"            # method registry name
    param_dtype: Any = jnp.bfloat16

    def resolved(self):
        """(Method, method-native config) for this run."""
        meth = method_mod.get(self.method)
        return meth, meth.coerce_config(self.sdm)


def _node_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _n_nodes(mesh: Mesh) -> int:
    n = 1
    for a in _node_axes(mesh):
        n *= mesh.shape[a]
    return n


@functools.lru_cache(maxsize=None)
def _compiled_schedule(spec: str, seed: int, self_weight: float,
                       n_nodes: int) -> gossip.ScheduleSequence:
    return gossip.sequence_by_name(
        spec, n_nodes,
        self_weight=self_weight if spec == "ring" else None, seed=seed,
        placement=True)


def gossip_schedule(tc: DistributedTrainConfig, mesh: Mesh
                    ) -> gossip.ScheduleSequence:
    """Compile the configured gossip graph for this mesh's node count.

    Memoized: the launcher banner, init_distributed_state, and
    make_distributed_train all resolve to the SAME schedule object, so
    ER resampling + the Laplacian eigendecomposition run once and the
    s_0 self-weights can never desynchronize from the train step's.
    Time-varying specs ("matchings:<L>") give a length-L sequence.

    Placement-aware: the node count is read off the mesh's ICI shape and
    ``topology.greedy_placement`` renumbers the logical nodes before
    compiling whenever that strictly lowers the ring-hop cost, so e.g.
    a sampled ER graph's hottest shifts land on physically adjacent
    devices. Spectrum-preserving — beta / lambda_n and every convergence
    bound are untouched (asserted in tests/test_core_topology.py).
    """
    return _compiled_schedule(tc.topology, tc.topology_seed,
                              tc.self_weight, _n_nodes(mesh))


def plane_bucket_tree(tc: DistributedTrainConfig, mesh: Mesh):
    """The wire-plane bucket policy for this run (this file owns it).

    On a tensor-parallel mesh with a working partial-auto shard_map,
    leaves whose TRAILING logical axis maps to the model axis get their
    own plane bucket keyed ``('model', cols)`` — the plane's lane dim
    keeps the TP sharding (DDP-gradient-bucket style); everything else
    rides the default flat bucket. On the full-manual fallback (old
    jaxlibs) or meshes without a model axis, everything is replicated
    inside the region anyway, so one flat plane is optimal: return None.
    """
    node_axes = _node_axes(mesh)
    if compat.partial_auto_shard_map_broken(mesh, node_axes):
        return None
    if "model" not in mesh.shape or mesh.shape["model"] == 1:
        return None
    return plane_mod.bucket_keys_from_axes(
        transformer.param_axes(tc.model), transformer.param_shapes(tc.model),
        INNER_RULES)


def _bucket_ctx(tc: DistributedTrainConfig, mesh: Mesh):
    return plane_mod.use_buckets(plane_bucket_tree(tc, mesh))


def state_shape_dtype(tc: DistributedTrainConfig, mesh: Mesh):
    """ShapeDtypeStructs of the stacked method state (dry-run lowering).

    Schedule-aware: genuinely time-varying gossip specs grow the
    per-neighbour REPLICA leaves (one slot per union-graph round).
    """
    n_nodes = _n_nodes(mesh)
    meth, mcfg = tc.resolved()
    shapes = transformer.param_shapes(tc.model)
    mk = lambda s: jax.ShapeDtypeStruct((n_nodes,) + tuple(s), tc.param_dtype)
    x = jax.tree.map(mk, shapes,
                     is_leaf=lambda v: isinstance(v, tuple) and
                     all(isinstance(e, int) for e in v))
    with _bucket_ctx(tc, mesh):
        return method_mod.state_shape_dtype(meth, x, mcfg,
                                            seq=gossip_schedule(tc, mesh))


def state_shardings(tc: DistributedTrainConfig, mesh: Mesh):
    """NamedShardings for the stacked distributed state."""
    node_axes = _node_axes(mesh)
    meth, mcfg = tc.resolved()
    rules = MeshRules(mesh, outer_rules(node_axes))
    axes = transformer.param_axes(tc.model)
    shapes = transformer.param_shapes(tc.model)
    is_axes = lambda v: isinstance(v, tuple) and all(
        isinstance(e, (str, type(None))) for e in v)

    def leaf_sharding(a, s):
        return rules.sharding(("batch",) + a, (0,) + tuple(s))

    x = jax.tree.map(leaf_sharding, axes, shapes, is_leaf=is_axes)
    node_vec = NamedSharding(mesh, P(node_axes if len(node_axes) > 1
                                     else node_axes[0]))
    n_nodes = _n_nodes(mesh)
    is_shape = lambda v: isinstance(v, tuple) and all(
        isinstance(e, int) for e in v)
    template = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_nodes,) + tuple(s),
                                       tc.param_dtype),
        shapes, is_leaf=is_shape)
    with _bucket_ctx(tc, mesh):
        return method_mod.state_shardings(meth, x, node_vec, mcfg,
                                          seq=gossip_schedule(tc, mesh),
                                          template=template)


def init_distributed_state(tc: DistributedTrainConfig, mesh: Mesh,
                           key: jax.Array):
    """Materialize the stacked state (same init on every node).

    Method-generic: e.g. SDM's s_0[i] = (1 - W_ii(0)) x_0 with the
    node's OWN self-weight (W_ii varies per node on Metropolis–Hastings
    graphs), gradient-push's mass w_0 = 1.
    """
    n_nodes = _n_nodes(mesh)
    meth, cfg = tc.resolved()
    params = transformer.init_params(key, tc.model, tc.param_dtype)
    stack = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_nodes,) + p.shape), params)
    with _bucket_ctx(tc, mesh):
        return meth.init_stacked(stack, gossip_schedule(tc, mesh), cfg)


def make_distributed_train(tc: DistributedTrainConfig, mesh: Mesh,
                           base_key: Optional[jax.Array] = None
                           ) -> Callable:
    """Returns train_step(state, tokens, labels[, context]) -> (state, metrics).

    tokens/labels: (global_batch, seq) sharded over the node axes.
    """
    cfg = tc.model
    node_axes = _node_axes(mesh)
    # Old jaxlibs cannot partition ppermute/scan inside a partial-auto
    # region: run the whole node step fully manual there, replicating the
    # model axis (no TP) instead of GSPMD-sharding it.
    full_manual = compat.partial_auto_shard_map_broken(mesh, node_axes)
    manual_axes = set(mesh.axis_names) if full_manual else set(node_axes)
    axis = node_axes if len(node_axes) > 1 else node_axes[0]
    inner = None if full_manual else MeshRules(mesh, INNER_RULES)
    meth, mcfg = tc.resolved()
    seq = gossip_schedule(tc, mesh)
    if getattr(mcfg, "overlap", False) and gossip.needs_replicas(seq):
        # fail at build time with the run's own topology spec, not deep
        # inside the executor: the double-buffered overlap transport has
        # no replica (time-varying) delivery path.
        raise ValueError(
            f"overlap=True needs a static topology; {tc.topology!r} "
            f"compiles to a replica (time-varying) schedule")
    executor = meth.make_distributed(seq, mcfg, axis)
    if base_key is None:
        base_key = jax.random.PRNGKey(0)

    def local_grads(params, tokens, labels, context):
        def loss_fn(p):
            logits, aux = transformer.forward(p, cfg, tokens, context=context)
            return transformer.lm_loss(logits, labels, cfg.vocab_size, aux)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return grads, loss

    def node_step(state, tokens, labels, context, node_ids):
        """Per-node body; runs under shard_map with `axis` manual.

        state leaves arrive as (1, ...) (node-stacked, one per shard group);
        tokens/labels/context arrive as the node's local batch slice.
        node_ids arrives as the node's (1,)-slice of arange(n_nodes) — the
        node index as DATA, because `axis_index` cannot lower in
        partial-auto shard_map on older jaxlibs (PartitionId).
        """
        squeeze = lambda t: jax.tree.map(lambda v: jnp.squeeze(v, 0), t)
        me = jnp.squeeze(node_ids, 0)

        # bucket keys are static trace-time metadata: the SAME policy the
        # state templates above were built under, so the executor's plane
        # layout cannot diverge from the state it receives.
        with use_rules(inner), _bucket_ctx(tc, mesh):
            state = squeeze(state)
            state, loss = executor.step(
                state,
                lambda p: local_grads(p, tokens, labels, context),
                base_key=base_key, node_index=me)

        # the training loss IS data-derived; averaging it over nodes is a
        # deliberate release (the metric), declared so the taint auditor
        # reports it instead of flagging the psum.
        loss = jax.lax.pmean(tagging.declared_release(loss, label="loss"),
                             axis)
        unsqueeze = lambda t: jax.tree.map(lambda v: v[None], t)
        return unsqueeze(state), loss

    state_specs = jax.tree.map(lambda _: P(axis), state_shape_dtype(tc, mesh))
    data_spec = P(axis)

    has_context = cfg.family in ("audio", "vlm")
    in_specs = (state_specs, data_spec, data_spec,
                data_spec if has_context else None, P(axis))
    node_ids = jnp.arange(_n_nodes(mesh), dtype=jnp.int32)

    def train_step(state, tokens, labels, context=None):
        fn = compat.shard_map(
            node_step, mesh=mesh,
            in_specs=in_specs,
            out_specs=(state_specs, P()),
            axis_names=manual_axes, check_vma=False)
        return fn(state, tokens, labels, context, node_ids)

    return train_step


# --------------------------------------------------------------------------
# Serving steps (plain GSPMD; no node semantics)
# --------------------------------------------------------------------------

def make_prefill_fn(cfg: ModelConfig, mesh: Mesh, *,
                    shard_cache_seq: bool = False,
                    rule_overrides=None) -> Callable:
    node_axes = _node_axes(mesh)
    rules_map = serving_rules(node_axes, shard_cache_seq=shard_cache_seq,
                              decode=False)
    rules_map.update(rule_overrides or {})
    rules = MeshRules(mesh, rules_map)

    def prefill_step(params, tokens, cache, context=None):
        with use_rules(rules):
            return transformer.prefill(params, cfg, tokens, cache,
                                       context=context)

    return prefill_step, rules


def make_decode_fn(cfg: ModelConfig, mesh: Mesh, *,
                   shard_cache_seq: bool = False,
                   rule_overrides=None) -> Callable:
    node_axes = _node_axes(mesh)
    rules_map = serving_rules(node_axes, shard_cache_seq=shard_cache_seq,
                              decode=True)
    rules_map.update(rule_overrides or {})
    rules = MeshRules(mesh, rules_map)

    def decode_fn(params, token, cache, context=None):
        with use_rules(rules):
            return transformer.decode_step(params, cfg, token, cache,
                                           context=context)

    return decode_fn, rules
