"""Flat-key .npz pytree checkpointing (orbax is not available offline).

Keys are '/'-joined tree paths; the treedef is rebuilt from an exemplar
pytree on restore, so save/restore round-trips arbitrary nested
dict/tuple/NamedTuple states (optimizer + params + algorithm state).
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"step_(\d+)\.npz$")


def _flatten_with_paths(tree: PyTree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: PyTree) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    flat = _flatten_with_paths(tree)
    # atomic write: tmp + rename
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def restore_checkpoint(directory: str, exemplar: PyTree,
                       step: Optional[int] = None) -> PyTree:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    with np.load(path) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(exemplar)
        leaves = []
        for p, leaf in flat:
            key = "/".join(_path_str(q) for q in p)
            arr = data[key]
            if hasattr(leaf, "dtype"):
                if arr.dtype.kind == "V":
                    # np.load hands back raw void bytes for ml_dtypes
                    # leaves (bfloat16, float8, ...): reinterpret with
                    # the exemplar's dtype before casting.
                    want = np.dtype(leaf.dtype)
                    if want.itemsize != arr.dtype.itemsize:
                        raise ValueError(
                            f"checkpoint leaf {key!r} has opaque dtype "
                            f"{arr.dtype} ({arr.dtype.itemsize} B) but the "
                            f"exemplar expects {want} ({want.itemsize} B)")
                    arr = arr.view(want)
                arr = arr.astype(leaf.dtype)
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_flat(path: str) -> dict:
    """Load a checkpoint as its raw flat {'/'-joined key -> np.ndarray}
    dict, no exemplar needed. Opaque (void) dtypes are returned as-is —
    callers that know the logical dtype reinterpret with ``.view``."""
    with np.load(path) as data:
        return {k: data[k] for k in data.files}


def tree_keys(tree: PyTree) -> list:
    """The '/'-joined flat keys of ``tree``, in flatten order (the same
    keys ``save_checkpoint`` writes)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(_path_str(p) for p in path) for path, _ in flat]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := _STEP_RE.search(f))]
    return max(steps) if steps else None
