from repro.checkpoint.npz import (latest_step, load_flat, restore_checkpoint,
                                  save_checkpoint, tree_keys)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "load_flat", "tree_keys"]
