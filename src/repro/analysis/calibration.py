"""Noise-calibration cross-check + overlap double-buffer hazard pass.

Calibration
-----------
The accountant (``core.privacy``) charges epsilon for a Gaussian mask of
std sigma; ``masked_grad`` is SUPPOSED to add exactly that sigma. A
miscalibrated wiring — sigma_for_budget computed for one batch size and
applied at another, a stray scale factor on the noise — keeps every
test green and silently reports a wrong epsilon. This pass extracts the
CONCRETE noise std from the compiled jaxpr at each ``sanitize`` site
and cross-checks it against the sigma the config's accountant charges.

Extraction rides jax's own lowering of ``jax.random.normal``: uniform
bits -> ``erf_inv`` -> ``* sqrt(2)`` -> ``* sigma``. The abstract value
is the SET of Gaussian stds a value carries: ``erf_inv`` output is a
std-``1/sqrt(2)`` Gaussian (of U(-1,1) input), scalar-literal muls
scale every std in the set, adds/structural ops union, and any other op
clears (a squared Gaussian is not a Gaussian). At a ``sanitize`` site
the operand is clipped-data + noise, so its std set must contain the
accountant's sigma.

Overlap hazards
---------------
``cfg.overlap`` double-buffers the wire planes: the fresh exchange
result (tagged ``pending_buffer``) must ride the scan carry UNTOUCHED
and be consumed exactly one round later — one-step staleness, the
delayed-mixing semantics the dense oracle pins. This pass proves that
ordering statically with a token-propagation walk over each training
scan body:

* ``pending-not-carried``      — the tagged buffer never reaches a
  carry slot (the exchange result is dropped or consumed same-round);
* ``pending-same-round-read``  — the fresh buffer leaks into a scan
  output or a SECOND carry slot (same-round read: staleness 0);
* ``pending-self-dependence``  — the new pending buffer depends on the
  old one (staleness would exceed one round);
* ``pending-dropped``          — last round's buffer is never consumed;
* ``overlap-untagged``         — an overlap config whose jaxpr shows no
  pending tag at all (the double buffer got optimized out or bypassed);
* ``overlap-replica-schedule`` — overlap on a replica (time-varying)
  schedule, rejected statically instead of at trace time.
"""
from __future__ import annotations

import math
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.analysis import jaxpr_walk
from repro.core import tagging

__all__ = ["analyze_calibration", "analyze_overlap", "GAUSS_ERF_INV_STD"]

#: std of erf_inv(U(-1, 1)): jax's normal is erf_inv(u) * sqrt(2).
GAUSS_ERF_INV_STD = 1.0 / math.sqrt(2.0)

# ops through which "this value contains a Gaussian of std s" survives:
# adds (independent offsets), layout ops, dtype casts, data movement.
_UNION_PRIMS = frozenset({
    "add", "sub", "neg", "broadcast_in_dim", "reshape", "transpose",
    "squeeze", "expand_dims", "slice", "concatenate", "pad", "rev",
    "convert_element_type", "reduce_precision", "copy", "gather",
    "dynamic_slice", "dynamic_update_slice", "select_n",
    "optimization_barrier", "stop_gradient",
})

_CONTROL = frozenset({"scan", "while", "cond", "switch", "pallas_call"})

Stds = FrozenSet[float]


def _round_std(v: float) -> float:
    return float(f"{v:.12g}")


def _literal_scalar(var) -> Optional[float]:
    if not jaxpr_walk._is_literal(var):
        return None
    val = var.val
    try:
        if hasattr(val, "shape") and val.shape not in ((), (1,)):
            return None
        return float(val.item() if hasattr(val, "item") else val)
    except Exception:
        return None


class _NoiseInterp(jaxpr_walk.JaxprInterpreter):
    def __init__(self):
        self.sanitize_sites: Dict[tuple, dict] = {}
        self.clip_sites: Dict[tuple, dict] = {}

    def bottom(self) -> Stds:
        return frozenset()

    def join(self, a: Stds, b: Stds) -> Stds:
        return a | b

    def on_eqn(self, eqn, in_vals, ctx, def_prim):
        name = eqn.primitive.name
        if name == "erf_inv":
            return [frozenset({_round_std(GAUSS_ERF_INV_STD)})]
        if name == tagging.SANITIZE:
            key = (id(eqn), ctx.path, ctx.branch)
            rec = self.sanitize_sites.setdefault(
                key, {"site": jaxpr_walk.format_site(eqn),
                      "stds": frozenset()})
            rec["stds"] = rec["stds"] | in_vals[0]
            return [frozenset()]
        if name == tagging.CLIP:
            key = (id(eqn), ctx.path, ctx.branch)
            self.clip_sites.setdefault(
                key, {"site": jaxpr_walk.format_site(eqn),
                      "bound": float(eqn.params.get("bound", float("nan")))})
            return [in_vals[0]]
        if name in tagging.TAG_PRIMITIVES:
            return [in_vals[0]]
        if name in ("mul", "div"):
            lit0 = _literal_scalar(eqn.invars[0])
            lit1 = _literal_scalar(eqn.invars[1])
            if name == "mul" and lit0 is not None:
                return [frozenset(_round_std(s * abs(lit0))
                                  for s in in_vals[1])]
            if lit1 is not None and lit1 != 0.0:
                c = abs(lit1) if name == "mul" else 1.0 / abs(lit1)
                return [frozenset(_round_std(s * c) for s in in_vals[0])]
            return [frozenset()]
        if name in _UNION_PRIMS:
            return None   # default join-of-inputs = union
        if name in _CONTROL or name in jaxpr_walk._ALIGNED_CALLS:
            return None   # boundary recursion
        if any(hasattr(v, "eqns") or hasattr(v, "jaxpr")
               for v in eqn.params.values()):
            return None
        # any other op destroys Gaussian-ness (squares, norms, compares).
        return [frozenset() for _ in eqn.outvars]


def analyze_calibration(closed_jaxpr, *, expected_sigma: float,
                        expected_clip: float | None,
                        check: bool = True, rel_tol: float = 1e-4) -> dict:
    """Extract per-``sanitize``-site noise stds and cross-check them
    against the accountant's sigma (and the declared clip against the
    config's C). ``check=False`` still returns the extracted constants
    for the certificate."""
    interp = _NoiseInterp()
    jaxpr, _ = jaxpr_walk._unpack(closed_jaxpr)
    interp.run(closed_jaxpr, [frozenset()] * len(jaxpr.invars))

    findings: List[dict] = []
    sites = []
    for rec in interp.sanitize_sites.values():
        stds = sorted(rec["stds"])
        matched = [s for s in stds
                   if math.isclose(s, expected_sigma, rel_tol=rel_tol)]
        sites.append({"site": rec["site"], "stds": stds,
                      "extracted_sigma": matched[0] if matched
                      else (stds[-1] if stds else None)})
        if not check:
            continue
        if not stds:
            findings.append({
                "kind": "noise-scale-unextracted", "site": rec["site"],
                "detail": "sanitize operand carries no recognizable "
                          "Gaussian noise term"})
        elif not matched:
            findings.append({
                "kind": "noise-scale-mismatch", "site": rec["site"],
                "jaxpr_sigma": stds, "accountant_sigma": expected_sigma})
    if check and expected_sigma > 0.0 and not interp.sanitize_sites:
        findings.append({
            "kind": "missing-noise",
            "detail": f"config charges sigma={expected_sigma} but the "
                      "jaxpr has no sanitize site"})
    # clip-bound cross-checking lives in the sensitivity pass (it owns
    # the bound domain); the sites are recorded here only for the cert.
    del expected_clip
    clip_rows = [{"site": rec["site"], "bound": rec["bound"]}
                 for rec in interp.clip_sites.values()]
    return {"findings": findings, "sanitize_sites": sites,
            "clip_sites": clip_rows}


# ==========================================================================
# Overlap double-buffer hazards (token propagation over scan bodies).
# ==========================================================================

class _TokenInterp(jaxpr_walk.JaxprInterpreter):
    """Propagates frozensets of provenance tokens; ``pending_buffer``
    tags mint a fresh token in addition to passing their inputs."""

    def __init__(self):
        self.pending: List[Tuple[tuple, str]] = []   # (token, site)
        self._uids: Dict[tuple, tuple] = {}

    def bottom(self) -> frozenset:
        return frozenset()

    def join(self, a, b):
        return a | b

    def on_eqn(self, eqn, in_vals, ctx, def_prim):
        if eqn.primitive.name == tagging.PENDING:
            key = (id(eqn), ctx.path, ctx.branch)
            tok = self._uids.get(key)
            if tok is None:
                tok = ("pend", len(self._uids))
                self._uids[key] = tok
                self.pending.append((tok, jaxpr_walk.format_site(eqn)))
            return [in_vals[0] | {tok}]
        return None


def _iter_scans(jaxpr, consts):
    """Yield every (scan eqn, body jaxpr, body consts) anywhere in the
    program (train loops live under pjit/shard_map)."""
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            sub, sub_consts = jaxpr_walk._unpack(eqn.params["jaxpr"])
            yield eqn, sub, sub_consts
            yield from _iter_scans(sub, sub_consts)
            continue
        for v in eqn.params.values():
            if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                sub, sub_consts = jaxpr_walk._unpack(v)
                yield from _iter_scans(sub, sub_consts)
        if name in ("cond", "switch"):
            for br in eqn.params.get("branches", ()):
                sub, sub_consts = jaxpr_walk._unpack(br)
                yield from _iter_scans(sub, sub_consts)


def analyze_overlap(closed_jaxpr, *, overlap: bool,
                    needs_replicas: bool = False) -> dict:
    """Statically verify the overlap double-buffer discipline (see
    module docstring). Non-overlap configs verify vacuously (verdict
    ``n/a``) but still reject stray pending tags."""
    findings: List[dict] = []
    if overlap and needs_replicas:
        findings.append({
            "kind": "overlap-replica-schedule",
            "detail": "overlap=True requires a static (non-replica) "
                      "schedule; replica delivery would consume the "
                      "pending buffer at unbounded staleness"})
    jaxpr, consts = jaxpr_walk._unpack(closed_jaxpr)
    n_pending = 0
    loops = []
    for eqn, sub, sub_consts in _iter_scans(jaxpr, consts):
        interp = _TokenInterp()
        nc = eqn.params.get("num_consts", 0)
        ncar = eqn.params.get("num_carry", 0)
        n_xs = len(sub.invars) - nc - ncar
        carry_in = [frozenset({("carry", j)}) for j in range(ncar)]
        seed = [frozenset()] * nc + carry_in + [frozenset()] * n_xs
        # ONE body evaluation, not a fixpoint: the hazard question is
        # about the single-iteration dataflow new_carry = f(old_carry).
        ctx = jaxpr_walk.Ctx(loop_depth=1, path=(id(eqn),))
        outs = interp._eval(sub, sub_consts, seed, ctx)
        carry_out, ys = outs[:ncar], outs[ncar:]
        if not interp.pending:
            continue
        n_pending += len(interp.pending)
        for tok, site in interp.pending:
            slots = [j for j, c in enumerate(carry_out) if tok in c]
            if not slots:
                findings.append({"kind": "pending-not-carried",
                                 "site": site})
            if any(tok in y for y in ys) or len(slots) > 1:
                findings.append({
                    "kind": "pending-same-round-read", "site": site,
                    "detail": "fresh exchange result read in the round "
                              "that produced it (staleness 0, not 1)"})
            for j in slots:
                if ("carry", j) in carry_out[j]:
                    findings.append({
                        "kind": "pending-self-dependence", "site": site,
                        "detail": "new pending buffer depends on the "
                                  "old one: staleness exceeds one round"})
                consumed = any(("carry", j) in out
                               for k, out in enumerate(outs) if k != j)
                if not consumed:
                    findings.append({
                        "kind": "pending-dropped", "site": site,
                        "detail": "last round's pending buffer is never "
                                  "consumed by the update"})
            loops.append({"site": site, "carry_slots": slots})
    if overlap and n_pending == 0:
        findings.append({
            "kind": "overlap-untagged",
            "detail": "overlap config but no pending_buffer tag in any "
                      "training scan (double buffer bypassed?)"})
    if not overlap and n_pending > 0:
        findings.append({
            "kind": "pending-without-overlap",
            "detail": "pending_buffer tag in a non-overlap config"})
    verdict = "n/a" if not overlap else (
        "ok" if not findings else "hazard")
    return {"findings": findings, "verdict": verdict,
            "n_pending": n_pending, "buffers": loops}
