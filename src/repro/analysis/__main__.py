"""``python -m repro.analysis``: sweep the audit matrix, emit
``LINT_report.json``, exit nonzero on any NEW violation.

Must configure the fake host mesh BEFORE jax initializes, so all the
jax-touching imports live inside ``main``. Findings already listed in
the suppression baseline (``baseline.json`` next to this module, or
``--baseline``) are reported but do not fail the run — the mechanism
for landing the auditor before a pre-existing violation is fixed, kept
EMPTY on a clean main.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys


#: report keys holding per-pass finding lists, in PASSES order
FINDING_KEYS = ("taint", "prng", "wire", "sensitivity", "calibration",
                "range", "overlap")


def _fingerprint(config_id: str, finding: dict) -> str:
    kind = finding.get("kind", "?")
    detail = finding.get("key") or finding.get("primitive") \
        or finding.get("label") or finding.get("site") or ""
    return f"{config_id}|{kind}|{detail}"


def _row_findings(row: dict):
    for key in FINDING_KEYS:
        yield from row.get(key, [])


def _parse_shard(spec: str):
    i, _, n = spec.partition("/")
    i, n = int(i), int(n)
    if not (n >= 1 and 1 <= i <= n):
        raise SystemExit(f"--shard wants i/N with 1 <= i <= N, got {spec!r}")
    return i, n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr taint / PRNG / wire auditor + privacy certifier")
    ap.add_argument("--out", default="LINT_report.json",
                    help="report path ('' to skip writing)")
    ap.add_argument("--baseline", default=None,
                    help="suppression baseline json (default: bundled)")
    ap.add_argument("--filter", "--only", dest="filter", default="",
                    help="only configs whose id contains this substring")
    ap.add_argument("--pass", dest="passes", action="append", default=None,
                    metavar="NAME",
                    help="run only this audit pass (repeatable); default all")
    ap.add_argument("--shard", default=None, metavar="i/N",
                    help="run the i-th of N strided matrix shards (1-based)")
    ap.add_argument("--quick", action="store_true",
                    help="smoke subset of the matrix")
    ap.add_argument("--devices", type=int, default=8,
                    help="fake host device count (>= mesh nodes)")
    args = ap.parse_args(argv)

    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import jax

    from repro.analysis import wire_audit

    base_path = pathlib.Path(args.baseline) if args.baseline else \
        pathlib.Path(__file__).parent / "baseline.json"
    suppressions = set()
    if base_path.exists():
        suppressions = set(json.loads(base_path.read_text())
                           .get("suppressions", []))

    passes = tuple(args.passes) if args.passes else wire_audit.PASSES
    unknown = set(passes) - set(wire_audit.PASSES)
    if unknown:
        raise SystemExit(f"unknown --pass {sorted(unknown)}; "
                         f"choose from {list(wire_audit.PASSES)}")

    configs = [ac for ac in wire_audit.MATRIX if args.filter in ac.id]
    if args.quick:
        configs = [ac for ac in configs if ac.id in wire_audit.QUICK_IDS]
    if args.shard:
        i, n = _parse_shard(args.shard)
        configs = configs[i - 1::n]

    rows, new_violations = [], []
    for ac in configs:
        try:
            row = wire_audit.audit_config(ac, passes=passes)
        except Exception as e:                          # audit must not crash
            row = {"id": ac.id, "status": "error", "error": repr(e),
                   **{k: [] for k in FINDING_KEYS}}
            new_violations.append(f"{ac.id}|audit-error|{e!r}")
        for finding in _row_findings(row):
            fp = _fingerprint(row["id"], finding)
            if fp in suppressions:
                finding["suppressed"] = True
            else:
                new_violations.append(fp)
        rows.append(row)
        n_bad = sum(1 for f in _row_findings(row)
                    if not f.get("suppressed"))
        print(f"AUDIT {row['id']:55s} {row['status']:5s}"
              f" findings={n_bad}", flush=True)

    report = {
        "jax": jax.__version__,
        "n_configs": len(rows),
        "passes": list(passes),
        "shard": args.shard,
        "suppression_baseline": sorted(suppressions),
        "new_violations": new_violations,
        "configs": rows,
        "summary": {
            "pass": sum(r["status"] == "pass" for r in rows),
            "fail": sum(r["status"] == "fail" for r in rows),
            "error": sum(r["status"] == "error" for r in rows),
        },
    }
    if args.out:
        pathlib.Path(args.out).write_text(json.dumps(report, indent=2))
        print(f"wrote {args.out}")
    print(f"SUMMARY pass={report['summary']['pass']} "
          f"fail={report['summary']['fail']} "
          f"error={report['summary']['error']} "
          f"new_violations={len(new_violations)}")
    return 1 if new_violations else 0


if __name__ == "__main__":
    sys.exit(main())
