"""Quantitative sensitivity certifier: norm-bound propagation + integer
ranges over a compiled train-step jaxpr.

The taint pass (PR 6) proves the QUALITATIVE shape of the privacy
argument — data reaches a collective only through ``sanitize``. This
module proves the QUANTITATIVE half Theorem 1 actually needs: that the
value the Gaussian mask is added to really is coordinate-bounded by the
clip constant C, i.e. l2-sensitivity <= C * sqrt(d) = G, and that the
integer wire encodings can never leave their representable range.

Norm-bound domain
-----------------
Abstract value: a float ``beta`` per jaxpr value, meaning the value
decomposes as ``u + w`` with ``u`` data-INdependent and every
coordinate of the data-dependent part bounded, ``|w_i| <= beta``.
``beta = 0`` is "provably data-independent" (constants, PRNG draws,
sanitized values), ``inf`` is "no bound known". Join is max.

Transfer rules are chosen for this decomposition semantics:

* ``clip_bound`` tag (from ``clipping.clip_tree``): out = min(in, C) —
  whatever entered, the clamped value itself is a valid ``w`` with
  ``u = 0``;
* add/sub: beta_a + beta_b (decompositions add);
* mul/div by a scalar LITERAL c: beta * |c| (resp. / |c|) — a
  non-literal factor has unknown magnitude, so a data-dependent operand
  goes to inf;
* 1-Lipschitz ops (min/max/clamp/abs/tanh/erf/...): max of inputs;
* structural ops (reshape/concat/pad/slice/transpose/gather with
  data-independent indices): max of inputs — every output coordinate IS
  some input coordinate (pads are literals);
* reduce_sum over k elements: k * beta; reduce_max/min: beta;
* everything else: 0 if ALL inputs are 0 (a function of data-independent
  values is data-independent), else inf.

``sanitize`` clears the bound to 0 — the accountant charges that
release — but first RECORDS the pre-noise bound: the certifier's main
check is ``bound(sanitize operand) <= C``. ``wire_payload`` operands
are checked to carry bound 0 in privacy-claiming configs (everything on
the wire is post-sanitize). Unknown-op conservatism means a finding
here is "cannot prove", not "proved leaking" — but on this codebase the
clean configs all prove, so CI gates at zero findings.

Integer-range certificate
-------------------------
``qsgd_range_certificate`` re-derives the qsgd/qsgdf wire encoding
symbolically with ``Interval`` arithmetic: levels q in [-s, s], offset
encode q+s in [0, 2s] subset [0, 2^b - 1], OR-packed byte <= 255, and
the 4 bitcast norm tail bytes — proving no representable-range overflow
for any input (the groundwork for the mod-Q secure-aggregation plane).
``tests/test_sensitivity_domain.py`` property-checks both the transfer
functions and the interval chain against concrete values.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.analysis import jaxpr_walk
from repro.core import tagging

__all__ = [
    "Interval",
    "analyze_sensitivity",
    "qsgd_range_certificate",
    "clip_transfer",
    "add_transfer",
    "scale_transfer",
    "concat_transfer",
    "pad_transfer",
    "reduce_sum_transfer",
]

INF = math.inf

# relative slack on bound <= C comparisons (f32 literal round-off).
_TOL = 1e-5


# ==========================================================================
# Transfer functions (module-level so the property tests drive the exact
# code the interpreter runs).
# ==========================================================================

def clip_transfer(beta: float, c: float) -> float:
    """Bound after clamping to [-c, c]: the clamp output itself is a
    valid data-dependent part, so min(beta, c)."""
    return min(beta, c)


def add_transfer(beta_a: float, beta_b: float) -> float:
    return beta_a + beta_b


def scale_transfer(beta: float, c: float) -> float:
    """Bound after multiplying by a known scalar constant c."""
    return beta * abs(c)


def concat_transfer(*betas: float) -> float:
    """Concat/stack/select with static predicate: every output
    coordinate is some input coordinate."""
    return max(betas) if betas else 0.0


def pad_transfer(beta: float, pad_bound: float = 0.0) -> float:
    return max(beta, pad_bound)


def reduce_sum_transfer(beta: float, reduced: int) -> float:
    return beta * float(reduced)


# ops whose output coordinates are each a single input coordinate
# (possibly permuted/duplicated/dropped) — bound is max of inputs.
_STRUCTURAL = frozenset({
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "expand_dims",
    "slice", "rev", "concatenate", "copy", "convert_element_type",
    "reduce_precision", "stop_gradient", "real", "imag", "ppermute",
    "all_to_all", "get", "swap", "optimization_barrier",
})

# 1-Lipschitz elementwise ops: |f(u+w) - f(u)| <= |w|.
_LIPSCHITZ1 = frozenset({
    "max", "min", "abs", "neg", "tanh", "erf", "sin", "cos", "logistic",
    "clamp", "real", "imag",
})

# elementwise ops with a bounded output range: even a data-dependent
# input yields a bounded data-dependent part (u = 0 decomposition).
_RANGE_BOUNDED = {
    "sign": 1.0, "eq": 1.0, "ne": 1.0, "lt": 1.0, "le": 1.0, "gt": 1.0,
    "ge": 1.0, "and": 1.0, "or": 1.0, "xor": 1.0, "not": 1.0,
    "is_finite": 1.0,
}

_CONTROL = frozenset({"scan", "while", "cond", "switch", "pallas_call"})


def _literal_scalar(var) -> Optional[float]:
    if not jaxpr_walk._is_literal(var):
        return None
    val = var.val
    try:
        if hasattr(val, "shape") and val.shape not in ((), (1,)):
            return None
        return float(val.item() if hasattr(val, "item") else val)
    except Exception:
        return None


def _numel(var) -> int:
    try:
        return int(math.prod(var.aval.shape))
    except Exception:
        return 1


class _SensInterp(jaxpr_walk.JaxprInterpreter):
    """The norm-bound abstract interpreter (see module docstring)."""

    def __init__(self):
        # site key -> max bound observed across fixpoint re-evaluations
        self.sanitize_sites: Dict[tuple, dict] = {}
        self.wire_sites: Dict[tuple, dict] = {}
        self.clip_sites: Dict[tuple, dict] = {}

    # lattice -------------------------------------------------------------
    def bottom(self) -> float:
        return 0.0

    def join(self, a: float, b: float) -> float:
        return max(a, b)

    # transfer ------------------------------------------------------------
    def _site_key(self, eqn, ctx) -> tuple:
        return (id(eqn), ctx.path, ctx.branch)

    def on_eqn(self, eqn, in_vals, ctx, def_prim):
        name = eqn.primitive.name
        if name == tagging.CLIP:
            c = float(eqn.params.get("bound", INF))
            rec = self.clip_sites.setdefault(
                self._site_key(eqn, ctx),
                {"site": jaxpr_walk.format_site(eqn), "bound": c})
            rec["bound"] = c
            return [clip_transfer(in_vals[0], c)]
        if name == tagging.SANITIZE:
            rec = self.sanitize_sites.setdefault(
                self._site_key(eqn, ctx),
                {"site": jaxpr_walk.format_site(eqn), "bound": 0.0,
                 "numel": _numel(eqn.invars[0])})
            rec["bound"] = max(rec["bound"], in_vals[0])
            return [0.0]   # the accountant charges this release
        if name == tagging.RELEASE:
            return [0.0]   # declared release: listed by the taint pass
        if name == tagging.WIRE:
            rec = self.wire_sites.setdefault(
                self._site_key(eqn, ctx),
                {"site": jaxpr_walk.format_site(eqn), "bound": 0.0,
                 "label": eqn.params.get("label", "")})
            rec["bound"] = max(rec["bound"], in_vals[0])
            return [in_vals[0]]
        if name == tagging.PENDING:
            return [in_vals[0]]
        if name in _CONTROL or name in jaxpr_walk._ALIGNED_CALLS:
            return None    # boundary recursion in the base class
        subs = [v for v in eqn.params.values()
                if hasattr(v, "eqns") or hasattr(v, "jaxpr")]
        if subs:
            return None    # conservative subjaxpr recursion
        return self._transfer(name, eqn, in_vals)

    def _transfer(self, name, eqn, in_vals) -> List[float]:
        n_out = len(eqn.outvars)
        if not in_vals or all(v == 0.0 for v in in_vals):
            # a function of data-independent values is data-independent
            # (jaxprs are pure; PRNG draws consume only key bits).
            return [0.0] * n_out
        if name in ("add", "sub"):
            return [add_transfer(in_vals[0], in_vals[1])] * n_out
        if name in ("mul", "div"):
            lit0 = _literal_scalar(eqn.invars[0])
            lit1 = _literal_scalar(eqn.invars[1])
            if name == "mul":
                if lit0 is not None:
                    return [scale_transfer(in_vals[1], lit0)] * n_out
                if lit1 is not None:
                    return [scale_transfer(in_vals[0], lit1)] * n_out
            elif lit1 is not None and lit1 != 0.0:
                return [scale_transfer(in_vals[0], 1.0 / lit1)] * n_out
            return [INF] * n_out
        if name == "clamp":
            lo = _literal_scalar(eqn.invars[0])
            hi = _literal_scalar(eqn.invars[2])
            out = concat_transfer(*in_vals)
            if lo is not None and hi is not None:
                out = min(out, hi - lo)
            return [out] * n_out
        if name in _LIPSCHITZ1:
            return [concat_transfer(*in_vals)] * n_out
        if name in _STRUCTURAL:
            return [concat_transfer(*in_vals)] * n_out
        if name == "pad":
            return [pad_transfer(in_vals[0],
                                 in_vals[1] if len(in_vals) > 1 else 0.0)
                    ] * n_out
        if name == "select_n":
            if in_vals[0] == 0.0:   # data-independent predicate
                return [concat_transfer(*in_vals[1:])] * n_out
            return [INF] * n_out
        if name in ("gather", "take", "dynamic_slice"):
            idx_dep = any(v != 0.0 for v in in_vals[1:])
            return [in_vals[0] if not idx_dep else INF] * n_out
        if name == "dynamic_update_slice":
            if any(v != 0.0 for v in in_vals[2:]):
                return [INF] * n_out
            return [concat_transfer(in_vals[0], in_vals[1])] * n_out
        if name == "reduce_sum":
            out_n = _numel(eqn.outvars[0])
            in_n = _numel(eqn.invars[0])
            reduced = max(1, in_n // max(1, out_n))
            return [reduce_sum_transfer(in_vals[0], reduced)] * n_out
        if name in ("reduce_max", "reduce_min"):
            return [in_vals[0]] * n_out
        if name in ("floor", "round", "ceil"):
            # |floor(u+w) - floor(u)| <= |w| + 1
            return [in_vals[0] + 1.0] * n_out
        if name in _RANGE_BOUNDED:
            return [_RANGE_BOUNDED[name]] * n_out
        # unknown op over a data-dependent input: no bound.
        return [INF] * n_out


def _fmt_bound(b: float):
    return None if math.isinf(b) else b


def analyze_sensitivity(closed_jaxpr, source_labels: Dict[int, str], *,
                        clip_c: float | None, check: bool = True) -> dict:
    """Run the norm-bound pass over a train-step jaxpr.

    ``source_labels`` marks top-level invar positions holding raw data
    (seeded at bound inf); every other input — params, keys, step
    counters — seeds at 0 (data-independent). ``clip_c`` is the clip
    constant the config (and hence the accountant) declares; ``check``
    emits findings (off for negative-control configs, which still get a
    certificate).

    Findings:
      * ``unclipped-sanitize``      — noise added to an UNBOUNDED value;
      * ``sensitivity-exceeds-clip``— bounded, but above the declared C;
      * ``clip-bound-mismatch``     — clip_tree tagged a different C
        than the config claims;
      * ``wire-sensitivity``        — a wire buffer with nonzero bound
        (pre-noise data on the wire).
    """
    interp = _SensInterp()
    jaxpr, _ = jaxpr_walk._unpack(closed_jaxpr)
    in_vals = [INF if i in source_labels else 0.0
               for i in range(len(jaxpr.invars))]
    interp.run(closed_jaxpr, in_vals)

    findings: List[dict] = []
    sanitize_rows = []
    for rec in interp.sanitize_sites.values():
        b = rec["bound"]
        l2 = None if math.isinf(b) else b * math.sqrt(rec["numel"])
        sanitize_rows.append({"site": rec["site"], "coord_bound":
                              _fmt_bound(b), "l2_bound": l2,
                              "numel": rec["numel"]})
        if not check or clip_c is None:
            continue
        if math.isinf(b):
            findings.append({
                "kind": "unclipped-sanitize", "site": rec["site"],
                "detail": "noise added to a value with no provable "
                          "coordinate bound (unclipped data path)"})
        elif b > clip_c * (1.0 + _TOL):
            findings.append({
                "kind": "sensitivity-exceeds-clip", "site": rec["site"],
                "bound": b, "clip_c": clip_c})
    clip_rows = []
    for rec in interp.clip_sites.values():
        clip_rows.append({"site": rec["site"], "bound": rec["bound"]})
        if check and clip_c is not None and not math.isclose(
                rec["bound"], clip_c, rel_tol=1e-6):
            findings.append({
                "kind": "clip-bound-mismatch", "site": rec["site"],
                "declared": rec["bound"], "config": clip_c})
    wire_bound = 0.0
    for rec in interp.wire_sites.values():
        wire_bound = max(wire_bound, rec["bound"])
        if check and rec["bound"] > 0.0:
            findings.append({
                "kind": "wire-sensitivity", "site": rec["site"],
                "bound": _fmt_bound(rec["bound"]),
                "detail": "wire payload carries un-sanitized "
                          "data-dependent content"})
    return {
        "findings": findings,
        "sanitize_sites": sorted(sanitize_rows, key=lambda r: r["site"]),
        "clip_sites": sorted(clip_rows, key=lambda r: r["site"]),
        "wire_coord_bound": _fmt_bound(wire_bound),
    }


# ==========================================================================
# Interval arithmetic + the qsgd/qsgdf integer-range certificate.
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class Interval:
    """Closed interval [lo, hi] over the reals (ints are exact floats
    well below 2^53 here)."""

    lo: float
    hi: float

    def __post_init__(self):
        if self.lo > self.hi:
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    def add(self, other: "Interval") -> "Interval":
        return Interval(self.lo + other.lo, self.hi + other.hi)

    def scale(self, c: float) -> "Interval":
        a, b = self.lo * c, self.hi * c
        return Interval(min(a, b), max(a, b))

    def clamp(self, lo: float, hi: float) -> "Interval":
        return Interval(min(max(self.lo, lo), hi),
                        min(max(self.hi, lo), hi))

    def join(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))

    def shift_left(self, bits: int) -> "Interval":
        return self.scale(float(1 << bits))

    def or_disjoint(self, other: "Interval") -> "Interval":
        """OR of non-negative fields with disjoint set-bit ranges — the
        sub-byte pack. For disjoint fields OR == ADD, which is how the
        pack stays exactly invertible."""
        if self.lo < 0 or other.lo < 0:
            raise ValueError("or_disjoint needs non-negative fields")
        return self.add(other)

    def within(self, lo: float, hi: float) -> bool:
        return self.lo >= lo and self.hi <= hi

    def as_list(self) -> List[float]:
        return [self.lo, self.hi]


def qsgd_range_certificate(bits: int, *, fused: bool, plane_elems: int,
                           levels: int | None = None) -> dict:
    """Symbolically re-derive the qsgd/qsgdf wire encoding and prove
    every intermediate stays in its representable range.

    Mirrors ``QSGDCompressor.compress`` / ``wire_compress.qsgd_pack``
    step for step: stochastic level in [0, s] after the min, signed
    q in [-s, s], offset encode q + s in [0, 2s], k = 8/bits fields
    OR-packed per u8 byte, plus the 4 bitcast norm tail bytes (fused).
    ``levels`` overrides s = 2^(bits-1) - 1 for tests that need to see
    the certificate FAIL.
    """
    s = levels if levels is not None else 2 ** (bits - 1) - 1
    findings: List[dict] = []
    # ratio = |x| * s / max(norm, eps) >= 0; floor + stochastic carry
    # keeps it >= 0; min(level, s) clamps the top.
    level = Interval(0.0, INF).clamp(0.0, float(s))
    # q = sign(x) * level in [-s, s]
    q = level.join(level.scale(-1.0))
    off = q.add(Interval(float(s), float(s)))       # offset encode
    if not off.within(0.0, float(2 ** bits - 1)):
        findings.append({
            "kind": "int-range-overflow", "stage": "offset",
            "range": off.as_list(), "repr": [0, 2 ** bits - 1]})
    pack = 8 // bits if bits in (2, 4) else 1
    if pack > 1:
        byte = Interval(0.0, 0.0)
        for j in range(pack):
            byte = byte.or_disjoint(off.shift_left(j * bits))
        wire_dtype = "u8"
    elif fused:
        byte = off                                   # qsgdf:8 ships q+s u8
        wire_dtype = "u8"
    else:
        byte = q                                     # qsgd:8 ships int8
        wire_dtype = "s8"
    repr_lo, repr_hi = (-128.0, 127.0) if wire_dtype == "s8" \
        else (0.0, 255.0)
    if not byte.within(repr_lo, repr_hi):
        findings.append({
            "kind": "int-range-overflow", "stage": "wire-byte",
            "range": byte.as_list(), "repr": [repr_lo, repr_hi]})
    payload_bytes = -(-plane_elems // pack) + (4 if fused else 0)
    return {
        "bits": bits, "levels": s, "fused": fused,
        "q_range": q.as_list(), "offset_range": off.as_list(),
        "byte_range": byte.as_list(), "wire_dtype": wire_dtype,
        "norm_tail_bytes": 4 if fused else 0,
        "payload_bytes": payload_bytes,
        "findings": findings,
    }
