"""Registry-wide wire/privacy audit: compile every configuration, prove
the invariants, execute nothing.

For each ``AuditConfig`` in ``MATRIX`` (method x compressor x topology
on a 4-node host mesh) the auditor builds the same tiny least-squares
distributed train step the parity sweep uses, traces it to a jaxpr and
compiles it to HLO, then checks:

* **taint** (``jaxpr_taint``): privacy-claiming configs (sigma > 0 on a
  method that applies ``masked_grad``) must have NO un-sanitized
  data->collective path; known-non-private configs (``expect_taint``,
  e.g. allreduce's raw-gradient pmean, or sigma=0) must be FLAGGED —
  an empty report there means the analyzer lost its teeth, which is
  itself a failure.
* **prng** (``prng_lint``): no key reuse, no scan-invariant key, no
  kernel-padded draw shapes — on every config.
* **wire** (this module): ``collective_permute_count`` equals the
  schedule-derived expectation (leaf-count independence, PR 5); on
  static schedules the summed HLO permute payload bits equal the
  static accounting (``transmitted_bits``) exactly for deterministic
  wire formats; on time-varying schedules the payload-sized permutes
  equal the union-graph round count (the branch-free replica
  transport). The "every permute operand is Payload-derived" half is
  enforced at the jaxpr level by the taint pass's ``untagged-wire``
  rule (every operand must come through ``gossip._wire_ppermute``).

Needs >= 4 visible devices: run via ``python -m repro.analysis`` (which
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=...`` before
importing jax) or from a test subprocess that does the same.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.analysis import calibration, jaxpr_taint, prng_lint, sensitivity
from repro.core import (baselines, clipping, compressor as compressor_mod,
                        gossip, gradient_push, method as method_mod,
                        plane as plane_mod, privacy, sdm_dsgd, tagging,
                        topology)
from repro.kernels.sdm_update.sdm_update import LANE as KERNEL_LANE
from repro.launch import hlo_analysis

__all__ = ["AuditConfig", "MATRIX", "PASSES", "audit_config",
           "expected_permutes", "allowed_draw_shapes"]

#: every audit pass, in report order; ``--pass`` selects a subset.
PASSES = ("taint", "prng", "wire", "sensitivity", "calibration", "range",
          "overlap")

N_NODES = 4
DIM = 2 * plane_mod.LANE          # one (2, 128) wire plane
STEPS = 3                         # scan length: exercises the loop rules
BATCH = 8


@dataclasses.dataclass(frozen=True)
class AuditConfig:
    method: str                   # registry name ("sdm-dsgd", ...)
    topo: str                     # "ring4" | "dring4" | "matchings4x2"
    mode: str                     # gossip mode / compressor spec, "-" = dense
    sigma: float = 1.0
    expect_taint: bool = False    # True: the config is KNOWN non-private
    overlap: bool = False         # one-step-stale overlapped transport

    @property
    def id(self) -> str:
        tag = "dirty" if self.expect_taint else f"sigma{self.sigma:g}"
        mode = self.mode + "+ov" if self.overlap else self.mode
        return f"{self.method}/{self.topo}/{mode}/{tag}"


#: the audited registry sweep: every method, every compressor family,
#: static + directed + genuinely time-varying schedules — plus two
#: known-dirty negative controls proving the taint pass has teeth.
MATRIX: Tuple[AuditConfig, ...] = (
    AuditConfig("sdm-dsgd", "ring4", "bernoulli"),
    AuditConfig("sdm-dsgd", "ring4", "fixedk_packed"),
    AuditConfig("sdm-dsgd", "ring4", "fixedk_rows"),
    AuditConfig("sdm-dsgd", "ring4", "qsgd:8"),
    AuditConfig("sdm-dsgd", "ring4", "qsgd:4"),
    # fused single-buffer quantizer (kernels/wire_compress): 1 payload
    # leaf -> half the permutes of qsgd, same exact-bits contract
    AuditConfig("sdm-dsgd", "ring4", "qsgdf:4"),
    # overlapped one-step-stale transport: same permute count, same
    # payload bits, zero findings — staleness is a trajectory property,
    # not a wire property
    AuditConfig("sdm-dsgd", "ring4", "fixedk_packed", overlap=True),
    AuditConfig("sdm-dsgd", "ring4", "qsgdf:4", overlap=True),
    AuditConfig("gradient-push", "dring4", "fixedk", overlap=True),
    AuditConfig("sdm-dsgd", "matchings4x2", "bernoulli"),
    AuditConfig("sdm-dsgd", "matchings4x2", "fixedk_packed"),
    AuditConfig("sdm-dsgd-fused", "ring4", "fixedk_packed"),
    AuditConfig("sdm-dsgd-fused", "matchings4x2", "fixedk_packed"),
    AuditConfig("dc-dsgd", "ring4", "bernoulli"),
    AuditConfig("dsgd", "ring4", "-"),
    AuditConfig("dsgd", "matchings4x2", "-"),
    AuditConfig("gradient-push", "dring4", "-"),
    AuditConfig("gradient-push", "dring4", "fixedk"),
    AuditConfig("gradient-push", "dring4", "qsgd"),
    AuditConfig("gradient-push", "matchings4x2", "fixedk"),
    # partial-participation (edge-fleet simulator) schedules: per-round
    # masked induced subgraphs, q=0.75 participation trace — the sim's
    # round graphs must satisfy the same taint/prng/wire contract
    AuditConfig("sdm-dsgd", "subring4x3", "fixedk_packed"),
    AuditConfig("sdm-dsgd", "subring4x3", "bernoulli"),
    AuditConfig("dsgd", "subring4x3", "-"),
    AuditConfig("gradient-push", "subdring4x3", "fixedk"),
    # negative controls: the analyzer MUST flag these
    AuditConfig("allreduce", "ring4", "-", expect_taint=True),
    AuditConfig("sdm-dsgd", "ring4", "fixedk_packed", sigma=0.0,
                expect_taint=True),
)

#: the quick subset for smoke runs (--quick)
QUICK_IDS = frozenset({
    "sdm-dsgd/ring4/fixedk_packed/sigma1",
    "sdm-dsgd/ring4/qsgd:4/sigma1",
    "sdm-dsgd/matchings4x2/fixedk_packed/sigma1",
    "dsgd/ring4/-/sigma1",
    "gradient-push/dring4/fixedk/sigma1",
    "sdm-dsgd/subring4x3/fixedk_packed/sigma1",
    "sdm-dsgd/ring4/fixedk_packed+ov/sigma1",
    "allreduce/ring4/-/dirty",
})


def parse_topo(spec: str) -> gossip.ScheduleSequence:
    if spec == "ring4":
        return gossip.ensure_sequence(
            gossip.schedule_from_topology(topology.ring(N_NODES)))
    if spec == "dring4":
        return gossip.ensure_sequence(gossip.schedule_from_topology(
            topology.directed_ring(N_NODES)))
    if spec == "matchings4x2":
        return gossip.sequence_from_topologies(
            topology.random_matchings(N_NODES, 2, seed=0), name=spec)
    if spec in ("subring4x3", "subdring4x3"):
        # the edge-fleet simulator's partial-participation schedule: a
        # q=0.75 Bernoulli participation trace (the sim's own fleet PRNG,
        # so the audited graphs are exactly what a sim run compiles)
        # masking the base ring / directed ring per round
        from repro.sim.fleet import Fleet

        base = (topology.directed_ring(N_NODES) if spec == "subdring4x3"
                else topology.ring(N_NODES))
        fleet = Fleet(N_NODES, "q=0.75", seed=0)
        sets = [np.nonzero(fleet.sample_participants())[0]
                for _ in range(3)]
        return gossip.sequence_from_active_sets(base, sets, name=spec)
    raise ValueError(f"unknown audit topology {spec!r}")


def make_cfg(ac: AuditConfig, meth):
    if meth.config_cls is sdm_dsgd.SDMConfig:
        kw = dict(p=0.25, theta=0.15, gamma=0.2, sigma=ac.sigma,
                  clip_c=1.0, overlap=ac.overlap)
        if ac.mode.split(":")[0] in ("qsgd", "qsgdf"):
            return meth.coerce_config(
                sdm_dsgd.SDMConfig(compressor=ac.mode, **kw))
        return meth.coerce_config(sdm_dsgd.SDMConfig(mode=ac.mode, **kw))
    if meth.config_cls is gradient_push.GradientPushConfig:
        return gradient_push.GradientPushConfig(
            gamma=0.2, sigma=ac.sigma, clip_c=1.0,
            compressor=None if ac.mode == "-" else ac.mode, p=0.25,
            overlap=ac.overlap)
    return baselines.DSGDConfig(gamma=0.2, sigma=ac.sigma, clip_c=1.0)


def expected_permutes(meth_name: str, mode: str, seq) -> int:
    """Collective-permutes per compiled step on the plane transport.

    R schedule rounds x wire leaves per payload (1 for dense/packed, 2
    for compressor payloads: values + scale|indices), + R for the
    push-sum mass scalar. Leaf-count-INDEPENDENT: this is the PR-5
    tentpole, now the analyzer's canonical contract (the parity sweep
    imports this).
    """
    r = seq.schedules[0].n_rounds
    base_mode = mode.split(":")[0]
    if mode == "-":
        leaves = 0 if meth_name == "allreduce" else 1
    elif base_mode in ("qsgd", "fixedk", "block"):
        # exchange_payload pytrees: values + scale (qsgd) / indices
        leaves = 2 if (meth_name == "gradient-push"
                       or base_mode == "qsgd") else 1
    else:
        # includes "qsgdf": the fused single-buffer format embeds the
        # norm in the byte payload, so ONE leaf — half of qsgd's wire
        leaves = 1
    extra = r if meth_name == "gradient-push" else 0
    return r * leaves + extra


def allowed_draw_shapes(per_node) -> frozenset:
    """Canonical (rows, lane) shapes mask/noise draws may use: the wire
    plane spec per bucket, plus the fused kernel's LANE-padded plane.
    Anything 2-D on a known lane but taller is kernel-tile padding — the
    PR-1 bug class."""
    spec = plane_mod.ParamPlane.for_tree(per_node)
    shapes = set(spec.plane_shapes())
    total = spec.total_size
    shapes.add((-(-total // KERNEL_LANE), KERNEL_LANE))
    return frozenset(shapes)


def _build(ac: AuditConfig):
    """Trace + compile ``ac``'s distributed train step (never executed)."""
    meth = method_mod.get(ac.method)
    seq = parse_topo(ac.topo)
    n = seq.n_nodes
    cfg = make_cfg(ac, meth)

    rng = np.random.default_rng(0)
    a_stack = jnp.asarray(rng.normal(size=(n, BATCH, DIM)) / 4.0, jnp.float32)
    b_stack = jnp.asarray(rng.normal(size=(n, BATCH)), jnp.float32)
    params0 = jnp.asarray(rng.normal(size=(DIM,)) * 0.1, jnp.float32)
    params_stack = {"w": jnp.broadcast_to(params0, (n, DIM))}
    base_key = jax.random.PRNGKey(42)

    mesh = compat.make_mesh((n,), ("data",))
    ex = meth.make_distributed(seq, cfg, "data")

    def dist_train(params_stack, a_st, b_st):
        def inner(p, a, b):
            p = jax.tree.map(lambda v: jnp.squeeze(v, 0), p)
            a, b = jnp.squeeze(a, 0), jnp.squeeze(b, 0)
            me = jax.lax.axis_index("data")
            state = ex.init(p, me)

            def grads_at(tree):
                r = a @ tree["w"] - b
                return {"w": a.T @ r / a.shape[0]}, jnp.mean(r * r)

            def body(state, _):
                state, aux = ex.step(state, grads_at, base_key=base_key)
                return state, aux

            state, losses = jax.lax.scan(body, state, None, length=STEPS)
            # the metric release every real train step performs
            loss = jax.lax.pmean(
                tagging.declared_release(losses[-1], label="loss"), "data")
            return jax.tree.map(lambda v: v[None], state.x), loss[None]

        return compat.shard_map(inner, mesh=mesh,
                                in_specs=(P("data"), P("data"), P("data")),
                                out_specs=(P("data"), P("data")),
                                axis_names={"data"},
                                check_vma=False)(params_stack, a_st, b_st)

    args = (params_stack, a_stack, b_stack)
    jaxpr = jax.make_jaxpr(dist_train)(*args)
    hlo = jax.jit(dist_train).lower(*args).compile().as_text()
    per_node = jax.tree.map(lambda v: v[0], params_stack)
    return meth, seq, cfg, jaxpr, hlo, per_node


def _exact_bits(meth, meth_name: str, mode: str, cfg, per_node, seq
                ) -> Optional[int]:
    """Static accounting where it equals the HLO payload bits EXACTLY.

    Deterministic wire formats only: fixed-k / rows / qsgd ship a known
    payload every round. Bernoulli's accounting is the EXPECTED p*d
    (paper convention) while the wire carries the dense masked plane, so
    equality is structurally impossible there (checked by payload shape
    instead). Mass-scalar bits for push-sum ride the same accounting.
    """
    base = mode.split(":")[0]
    if meth_name.startswith("sdm-dsgd") or meth_name == "dc-dsgd":
        if base in ("fixedk_packed", "fixedk_rows", "qsgd", "qsgdf"):
            return int(sdm_dsgd.transmitted_bits_per_step(
                per_node, cfg, seq=seq))
        return None
    if meth_name == "dsgd":
        return int(method_mod.transmitted_bits(meth, per_node, cfg, seq=seq))
    return None


def _wire_findings(ac: AuditConfig, meth, seq, cfg, hlo, per_node) -> List:
    findings: List[dict] = []
    payloads = hlo_analysis.permute_payloads(hlo)
    cperm = hlo_analysis.collective_permute_count(hlo)
    # async overlap lowering must keep start/done pairs balanced — an
    # unmatched start is a permute whose result is never consumed
    for kind, pair in hlo_analysis.async_collective_pairs(hlo).items():
        if pair["start"] != pair["done"]:
            findings.append({"kind": "async-pair-imbalance", "op": kind,
                             "got": pair})
    spec = plane_mod.ParamPlane.for_tree(per_node)
    (p_rows, p_lane), = spec.plane_shapes()
    plane_elems = p_rows * p_lane

    if seq.length == 1:
        exp = expected_permutes(ac.method, ac.mode, seq)
        if cperm != exp:
            findings.append({"kind": "permute-count", "got": cperm,
                             "expected": exp})
        exact = _exact_bits(meth, ac.method, ac.mode, cfg, per_node, seq)
        if exact is not None:
            hlo_bits = sum(pl["bits"] for pl in payloads)
            if hlo_bits != exact:
                findings.append({"kind": "payload-bits", "got": hlo_bits,
                                 "expected": exact})
        if ac.mode == "bernoulli":
            # dense masked plane: every payload permute ships the full
            # plane, one per round
            dense = [pl for pl in payloads
                     if pl["elems"].get("f32", 0) == plane_elems]
            r = seq.schedules[0].n_rounds
            if len(dense) != r:
                findings.append({"kind": "dense-payload-rounds",
                                 "got": len(dense), "expected": r})
    else:
        # replica transport: branch-free payload over every union round
        useq = gossip.union_schedule(seq)
        base = ac.mode.split(":")[0]
        if base == "qsgd":
            pperms = sum(1 for pl in payloads
                         if pl["bits"] >= plane_elems * 8)
        elif ac.mode == "bernoulli":
            pperms = sum(1 for pl in payloads
                         if pl["elems"].get("f32", 0) == plane_elems)
        elif ac.mode == "-":
            pperms = sum(1 for pl in payloads
                         if pl["elems"].get("f32", 0) == plane_elems)
        else:
            from repro.core import sparsifier
            k = sparsifier.num_kept(plane_elems, 0.25)
            pperms = sum(1 for pl in payloads
                         if pl["elems"].get("f32", 0) == k)
        if ac.method == "dsgd":
            # dense full-state exchange lowers to a lax.switch over the
            # L per-round branches (only the live round executes), so the
            # compiled graph carries EVERY branch's permutes — unlike the
            # branch-free union replica transport of the masked payloads.
            expected = sum(s.n_rounds for s in seq.schedules)
        else:
            expected = useq.n_replicas
        if pperms != expected:
            findings.append({"kind": "union-payload-rounds", "got": pperms,
                             "expected": expected})
    return findings


def _compressor_for(meth, cfg) -> Optional[compressor_mod.Compressor]:
    if meth.config_cls is sdm_dsgd.SDMConfig:
        return sdm_dsgd.compressor_of(cfg)
    if meth.config_cls is gradient_push.GradientPushConfig:
        return cfg.make_compressor()
    return None


def accountant_view(ac: AuditConfig, meth, cfg, per_node) -> dict:
    """The privacy constants the RDP accountant charges for this config
    — the certificate column the jaxpr-extracted constants are checked
    against (the other direction lives in ``analyze_calibration``)."""
    d_total = sum(int(np.prod(v.shape))
                  for v in jax.tree.leaves(per_node))
    clip_c = float(getattr(cfg, "clip_c", 0.0) or 0.0) or None
    G = clipping.sensitivity_G(clip_c, d_total) if clip_c else None
    comp = _compressor_for(meth, cfg)
    p_rel = comp.release_probability if comp is not None else 1.0
    view = {
        "sigma": ac.sigma,
        "clip_c": clip_c,
        "d": d_total,
        "G": G,
        "release_p": list(p_rel) if isinstance(p_rel, tuple) else p_rel,
        "sigma_times_c": (ac.sigma * clip_c) if clip_c else None,
        "compressor": comp.name if comp is not None else None,
        "coord_inflation_at_c":
            comp.coord_sensitivity_transfer(clip_c, (DIM,))
            if (comp is not None and clip_c) else None,
    }
    if ac.sigma > 0.0 and clip_c:
        try:
            params = privacy.PrivacyParams(
                G=G, m=BATCH, tau=1.0 / BATCH, p=p_rel, sigma=ac.sigma)
            view["epsilon_at_T"] = privacy.epsilon_sdm(
                params, STEPS, eps_target=0.5)
        except ValueError:
            view["epsilon_at_T"] = None
    return view


def _range_certificate(ac: AuditConfig, meth, cfg, hlo, per_node
                       ) -> Tuple[List[dict], Optional[dict]]:
    """Integer-range pass: only quantized wire formats have integer
    planes to certify; everything else is trivially in-range f32."""
    comp = _compressor_for(meth, cfg)
    if not isinstance(comp, compressor_mod.QSGDCompressor):
        return [], None
    spec = plane_mod.ParamPlane.for_tree(per_node)
    (p_rows, p_lane), = spec.plane_shapes()
    fused = isinstance(comp, compressor_mod.FusedQSGDCompressor)
    cert = sensitivity.qsgd_range_certificate(
        comp.bits, fused=fused, plane_elems=p_rows * p_lane)
    findings = list(cert.pop("findings"))
    # the proved wire dtype must actually appear in the HLO permute
    # payloads — a silent widening to f32 would void the range proof.
    payloads = hlo_analysis.permute_payloads(hlo)
    if not any(pl["elems"].get(cert["wire_dtype"]) for pl in payloads):
        findings.append({
            "kind": "wire-dtype-missing", "dtype": cert["wire_dtype"],
            "detail": "no collective-permute payload ships the certified "
                      "integer dtype"})
    return findings, cert


def audit_config(ac: AuditConfig, passes=PASSES) -> dict:
    """Run the selected audit passes on one configuration.

    ``passes`` (an iterable of ``PASSES`` names) lets CI shards and
    local debugging run one pass without the rest; the report row always
    carries every key, with unselected passes empty and their
    certificate fields ``None``.
    """
    passes = frozenset(passes)
    meth, seq, cfg, jaxpr, hlo, per_node = _build(ac)
    source_labels = {1: "data", 2: "data"}

    taint = jaxpr_taint.analyze_taint(jaxpr, source_labels) \
        if "taint" in passes else None
    prng = prng_lint.analyze_prng(
        jaxpr, allowed_shapes=allowed_draw_shapes(per_node)) \
        if "prng" in passes else None
    wire = _wire_findings(ac, meth, seq, cfg, hlo, per_node) \
        if "wire" in passes else []

    # negative-control configs get certificates but no certifier gates:
    # their whole point is that the QUALITATIVE pass flags them.
    claims = (not ac.expect_taint) and ac.sigma > 0.0
    clip_c = float(getattr(cfg, "clip_c", 0.0) or 0.0) or None
    sens = sensitivity.analyze_sensitivity(
        jaxpr, source_labels, clip_c=clip_c, check=claims) \
        if "sensitivity" in passes else None
    calib = calibration.analyze_calibration(
        jaxpr, expected_sigma=ac.sigma, expected_clip=clip_c,
        check=claims) if "calibration" in passes else None
    rng_findings, rng_cert = _range_certificate(
        ac, meth, cfg, hlo, per_node) if "range" in passes else ([], None)
    ovl = calibration.analyze_overlap(
        jaxpr, overlap=ac.overlap,
        needs_replicas=gossip.needs_replicas(seq)) \
        if "overlap" in passes else None

    taint_findings = list(taint["findings"]) if taint else []
    if taint and ac.expect_taint:
        if taint_findings:
            taint_findings = []     # expected dirt, analyzer has teeth
        else:
            taint_findings = [{"kind": "expected-taint-missing",
                               "detail": "known-non-private config produced "
                                         "no taint finding"}]
    sens_findings = sens["findings"] if sens else []
    calib_findings = calib["findings"] if calib else []
    ovl_findings = ovl["findings"] if ovl else []
    prng_findings = prng["findings"] if prng else []
    violations = (taint_findings + prng_findings + wire + sens_findings
                  + calib_findings + rng_findings + ovl_findings)
    certificate = {
        "accountant": accountant_view(ac, meth, cfg, per_node),
        "sanitize_bounds": sens["sanitize_sites"] if sens else None,
        "wire_coord_bound": sens["wire_coord_bound"] if sens else None,
        "clip_sites": sens["clip_sites"] if sens else None,
        "extracted_noise": calib["sanitize_sites"] if calib else None,
        "integer_ranges": rng_cert,
        "overlap": ({"verdict": ovl["verdict"],
                     "n_pending": ovl["n_pending"]} if ovl else None),
    }
    return {
        "id": ac.id,
        "expect_taint": ac.expect_taint,
        "passes": sorted(passes & set(PASSES)),
        "taint": taint_findings,
        "prng": prng_findings,
        "wire": wire,
        "sensitivity": sens_findings,
        "calibration": calib_findings,
        "range": rng_findings,
        "overlap": ovl_findings,
        "certificate": certificate,
        "releases": taint["releases"] if taint else [],
        "n_draws": prng["n_draws"] if prng else 0,
        "n_sanitize_sites": taint["n_sanitize_sites"] if taint else 0,
        "status": "fail" if violations else "pass",
    }
