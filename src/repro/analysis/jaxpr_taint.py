"""Interprocedural privacy-taint analysis over a train-step jaxpr.

Threat model (paper §4 / Theorem 1): the adversary sees everything that
crosses the wire, and the private object is the local sample. So taint
SOURCES are the designated data inputs (batches); everything computed
from them — loss, raw gradients — carries the taint; the one SANITIZER
is the ``tagging.sanitize`` mark that ``sdm_dsgd.masked_grad`` applies
after clip -> + sigma*normal (only when sigma > 0, i.e. when the config
actually claims privacy); SINKS are the cross-node collectives
(``ppermute``, ``psum``, ``all_gather``, ``all_to_all``). Any
sanitizer-free source->sink path is a finding.

Two more jaxpr-level invariants ride along:

* every ``ppermute`` operand must be the direct output of a
  ``tagging.wire_payload`` mark — i.e. the buffer went through the one
  vetted transport layer in ``repro.core.gossip`` (finding kind
  ``untagged-wire`` otherwise);
* ``tagging.declared_release`` clears taint but is recorded, so the
  report lists every deliberate data-derived release (the loss metric)
  instead of silently blessing it.

Abstract value: ``(labels, wire_tagged)`` where ``labels`` is a
frozenset of source labels and ``wire_tagged`` marks the direct output
of a wire tag (not propagated through any other op — adjacency is the
property being checked).
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Tuple

from repro.analysis import jaxpr_walk
from repro.core import tagging

__all__ = ["analyze_taint", "TaintFinding"]

Val = Tuple[FrozenSet[str], bool]

#: collectives whose operands leave the node
SINKS = frozenset({"ppermute", "psum", "all_gather", "all_to_all",
                   "pmax", "pmin", "reduce_scatter"})


class TaintFinding(dict):
    """dict with stable keys: kind, primitive, labels, site."""


class _TaintInterp(jaxpr_walk.JaxprInterpreter):
    def __init__(self):
        self.findings: List[TaintFinding] = []
        self.releases: List[Dict] = []
        self.sanitized_sites: List[str] = []
        self._seen = set()

    # lattice -------------------------------------------------------------
    def bottom(self) -> Val:
        return (frozenset(), False)

    def join(self, a: Val, b: Val) -> Val:
        return (a[0] | b[0], a[1] and b[1])

    # transfer ------------------------------------------------------------
    def default_out(self, eqn, in_vals, ctx):
        labels = frozenset().union(*(v[0] for v in in_vals)) \
            if in_vals else frozenset()
        return [(labels, False) for _ in eqn.outvars]

    def _emit(self, **kw):
        fp = tuple(sorted((k, str(v)) for k, v in kw.items()))
        if fp not in self._seen:
            self._seen.add(fp)
            self.findings.append(TaintFinding(kw))

    def on_eqn(self, eqn, in_vals, ctx, def_prim):
        name = eqn.primitive.name
        if name == tagging.SANITIZE:
            self.sanitized_sites.append(jaxpr_walk.format_site(eqn))
            return [(frozenset(), False)]
        if name == tagging.RELEASE:
            if in_vals[0][0]:
                self.releases.append({
                    "label": eqn.params.get("label", "?"),
                    "labels": sorted(in_vals[0][0]),
                    "site": jaxpr_walk.format_site(eqn)})
            return [(frozenset(), False)]
        if name == tagging.WIRE:
            return [(in_vals[0][0], True)]
        if name in SINKS:
            site = jaxpr_walk.format_site(eqn)
            for v in in_vals:
                if v[0]:
                    self._emit(kind="tainted-collective", primitive=name,
                               labels=sorted(v[0]), site=site)
            if name == "ppermute" and not all(v[1] for v in in_vals):
                self._emit(kind="untagged-wire", primitive=name, site=site)
            # received values carry the peers' (identically-labelled) taint
            labels = frozenset().union(*(v[0] for v in in_vals)) \
                if in_vals else frozenset()
            return [(labels, False) for _ in eqn.outvars]
        return None


def analyze_taint(closed_jaxpr, source_labels: Dict[int, str]):
    """Run the taint pass.

    ``source_labels`` maps top-level invar positions to a label (e.g.
    ``{1: "data", 2: "data"}``). Returns a dict with ``findings`` (list
    of TaintFinding), ``releases`` (declared data releases seen) and
    ``n_sanitize_sites``.
    """
    interp = _TaintInterp()
    jaxpr, _ = jaxpr_walk._unpack(closed_jaxpr)
    in_vals: List[Val] = []
    for i, _var in enumerate(jaxpr.invars):
        lbl = source_labels.get(i)
        in_vals.append((frozenset([lbl]) if lbl else frozenset(), False))
    interp.run(closed_jaxpr, in_vals)
    return {"findings": interp.findings,
            "releases": interp.releases,
            "n_sanitize_sites": len(interp.sanitized_sites)}
