"""Shared abstract-interpretation machinery over (Closed)Jaxprs.

``JaxprInterpreter`` walks a jaxpr and recurses through every call
boundary jax emits on this toolchain — ``pjit``, ``closed_call``,
``scan`` (to carry fixpoint), ``while``, ``cond``/``switch`` branches,
``shard_map``, ``custom_jvp/vjp_call`` and ``remat`` — propagating one
abstract value per jaxpr variable. Subclasses define the lattice
(``bottom``/``join``), per-primitive transfer functions (``rules``),
and may observe every equation (``on_eqn``) to record findings.

The walk is context-aware: ``Ctx`` carries the enclosing scan depth
(loops that actually iterate, ``length > 1``) and a branch path of
``(cond_eqn_uid, branch_index)`` pairs so clients can tell apart two
events that are mutually exclusive (different branches of one
``lax.switch``) from two events on one execution path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

__all__ = ["Ctx", "JaxprInterpreter", "format_site"]


def _is_literal(v) -> bool:
    return not hasattr(v, "count") and hasattr(v, "val")


def _unpack(j) -> Tuple[Any, Sequence[Any]]:
    """Jaxpr | ClosedJaxpr -> (open jaxpr, consts)."""
    if hasattr(j, "jaxpr"):
        return j.jaxpr, j.consts
    return j, ()


def format_site(eqn) -> str:
    """Best-effort user-frame 'file:line' for a finding."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            return f"{frame.file_name}:{frame.start_line}"
    except Exception:
        pass
    return "?"


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Where in the program the interpreter currently is."""

    loop_depth: int = 0                       # enclosing scans with length>1
    branch: Tuple[Tuple[int, int], ...] = ()  # (cond_uid, branch_idx) path
    path: Tuple[int, ...] = ()                # enclosing call-eqn uids

    def in_loop(self) -> bool:
        return self.loop_depth > 0


# call-like primitives with a single positionally-aligned subjaxpr
_ALIGNED_CALLS = {
    "pjit", "closed_call", "core_call", "xla_call", "remat2", "checkpoint",
    "remat", "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "custom_jvp_call_jaxpr", "shard_map", "custom_partitioning",
}
_SUB_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")

_MAX_FIXPOINT = 32


class JaxprInterpreter:
    """Abstract interpreter base; subclass and override the hooks."""

    # ---- lattice ---------------------------------------------------------
    def bottom(self):
        raise NotImplementedError

    def join(self, a, b):
        raise NotImplementedError

    def literal(self, lit, ctx: Ctx):
        return self.bottom()

    def const(self, val, ctx: Ctx):
        return self.bottom()

    # ---- transfer --------------------------------------------------------
    def on_eqn(self, eqn, in_vals, ctx: Ctx, def_prim: Dict) -> "List | None":
        """Observe/replace an equation. Return out_vals to OVERRIDE the
        default transfer, or None to fall through (boundary handling or
        the default join-of-inputs rule)."""
        return None

    def default_out(self, eqn, in_vals, ctx: Ctx) -> List:
        joined = self.bottom()
        for v in in_vals:
            joined = self.join(joined, v)
        return [joined for _ in eqn.outvars]

    def loop_carry_seed(self, val, ctx: Ctx):
        """Abstract value for a loop-carried input as seen by the body
        (hook for marking loop-variance)."""
        return val

    # ---- driver ----------------------------------------------------------
    def run(self, closed_jaxpr, in_vals: Sequence) -> List:
        jaxpr, consts = _unpack(closed_jaxpr)
        ctx = Ctx()
        return self._eval(jaxpr, consts, list(in_vals), ctx)

    def _read(self, env, v, ctx: Ctx):
        if _is_literal(v):
            return self.literal(v, ctx)
        return env.get(v, self.bottom())

    def _eval(self, jaxpr, consts, in_vals: List, ctx: Ctx) -> List:
        env: Dict = {}
        def_prim: Dict = {}
        for var, c in zip(jaxpr.constvars, consts):
            env[var] = self.const(c, ctx)
        n = min(len(jaxpr.invars), len(in_vals))
        # tail-align: extra leading operands (e.g. custom_vjp consts) get
        # dropped; missing ones default to bottom.
        for var, val in zip(jaxpr.invars[-n:] if n else [], in_vals[-n:]):
            env[var] = val
        for var in jaxpr.invars[:len(jaxpr.invars) - n]:
            env.setdefault(var, self.bottom())
        for eqn in jaxpr.eqns:
            in_vals_e = [self._read(env, v, ctx) for v in eqn.invars]
            outs = self.on_eqn(eqn, in_vals_e, ctx, def_prim)
            if outs is None:
                outs = self._eval_eqn(eqn, in_vals_e, ctx)
            for var, val in zip(eqn.outvars, outs):
                env[var] = val
                def_prim[var] = eqn.primitive.name
        return [self._read(env, v, ctx) for v in jaxpr.outvars]

    # ---- boundaries ------------------------------------------------------
    def _eval_eqn(self, eqn, in_vals: List, ctx: Ctx) -> List:
        name = eqn.primitive.name
        params = eqn.params
        if name == "scan":
            return self._eval_scan(eqn, in_vals, ctx)
        if name == "while":
            return self._eval_while(eqn, in_vals, ctx)
        if name in ("cond", "switch"):
            return self._eval_cond(eqn, in_vals, ctx)
        if name == "pallas_call":
            return self._eval_pallas(eqn, in_vals, ctx)
        if name in _ALIGNED_CALLS:
            for key in _SUB_KEYS:
                if key in params:
                    sub, consts = _unpack(params[key])
                    sub_ctx = dataclasses.replace(
                        ctx, path=ctx.path + (id(eqn),))
                    outs = self._eval(sub, consts, in_vals, sub_ctx)
                    return self._fit(outs, len(eqn.outvars), in_vals)
        # unknown primitive carrying subjaxprs: conservative recursion
        subs = [v for v in params.values()
                if hasattr(v, "eqns") or hasattr(v, "jaxpr")]
        if subs:
            joined_in = self.bottom()
            for v in in_vals:
                joined_in = self.join(joined_in, v)
            acc = joined_in
            for s in subs:
                sub, consts = _unpack(s)
                for o in self._eval(sub, consts,
                                    [joined_in] * len(sub.invars), ctx):
                    acc = self.join(acc, o)
            return [acc for _ in eqn.outvars]
        return self.default_out(eqn, in_vals, ctx)

    def _fit(self, outs: List, n: int, in_vals: List) -> List:
        if len(outs) == n:
            return outs
        joined = self.bottom()
        for v in list(outs) + list(in_vals):
            joined = self.join(joined, v)
        return [joined for _ in range(n)]

    def _eval_pallas(self, eqn, in_vals: List, ctx: Ctx) -> List:
        """Recurse into a pallas kernel body with HEAD-aligned refs.

        The kernel jaxpr's invars are ``[in_refs..., out_refs...]`` Ref
        avals — the eqn's operands map onto the FIRST invars and the
        remaining out-refs seed at bottom (generic tail-alignment would
        mis-map operands onto out-refs). The kernel reads/writes refs via
        ``get``/``swap``, which the default join-of-inputs transfer
        already propagates through, so key identity and taint survive
        into the kernel body. Kernel outputs are whatever the out-refs
        can't tell us here, so the eqn outputs conservatively join the
        kernel's formal outputs (usually none) with the eqn operands.
        """
        sub, consts = _unpack(eqn.params["jaxpr"])
        sub_ctx = dataclasses.replace(ctx, path=ctx.path + (id(eqn),))
        vals = list(in_vals[:len(sub.invars)])
        vals += [self.bottom()] * (len(sub.invars) - len(vals))
        outs = self._eval(sub, consts, vals, sub_ctx)
        return self._fit(outs, len(eqn.outvars), in_vals)

    def _eval_scan(self, eqn, in_vals: List, ctx: Ctx) -> List:
        params = eqn.params
        sub, consts = _unpack(params["jaxpr"])
        nc = params.get("num_consts", 0)
        ncar = params.get("num_carry", 0)
        length = params.get("length", 2) or 2
        body_ctx = dataclasses.replace(
            ctx, loop_depth=ctx.loop_depth + (1 if length > 1 else 0),
            path=ctx.path + (id(eqn),))
        carry = [self.loop_carry_seed(v, body_ctx)
                 for v in in_vals[nc:nc + ncar]]
        xs = [self.loop_carry_seed(v, body_ctx) for v in in_vals[nc + ncar:]]
        outs: List = []
        for _ in range(_MAX_FIXPOINT):
            outs = self._eval(sub, consts, in_vals[:nc] + carry + xs,
                              body_ctx)
            new_carry = [self.join(a, b) for a, b in zip(carry, outs[:ncar])]
            if all(a == b for a, b in zip(new_carry, carry)):
                break
            carry = new_carry
        return self._fit(outs, len(eqn.outvars), in_vals)

    def _eval_while(self, eqn, in_vals: List, ctx: Ctx) -> List:
        params = eqn.params
        cond_sub, cond_consts = _unpack(params["cond_jaxpr"])
        body_sub, body_consts = _unpack(params["body_jaxpr"])
        cn = params.get("cond_nconsts", 0)
        bn = params.get("body_nconsts", 0)
        body_ctx = dataclasses.replace(ctx, loop_depth=ctx.loop_depth + 1,
                                       path=ctx.path + (id(eqn),))
        carry = [self.loop_carry_seed(v, body_ctx) for v in in_vals[cn + bn:]]
        for _ in range(_MAX_FIXPOINT):
            self._eval(cond_sub, cond_consts, in_vals[:cn] + carry, body_ctx)
            outs = self._eval(body_sub, body_consts,
                              in_vals[cn:cn + bn] + carry, body_ctx)
            new_carry = [self.join(a, b) for a, b in zip(carry, outs)]
            if all(a == b for a, b in zip(new_carry, carry)):
                break
            carry = new_carry
        return self._fit(carry, len(eqn.outvars), in_vals)

    def _eval_cond(self, eqn, in_vals: List, ctx: Ctx) -> List:
        branches = eqn.params["branches"]
        n_out = len(eqn.outvars)
        acc = [self.bottom() for _ in range(n_out)]
        for idx, br in enumerate(branches):
            sub, consts = _unpack(br)
            br_ctx = dataclasses.replace(
                ctx, branch=ctx.branch + ((id(eqn), idx),),
                path=ctx.path + (id(eqn),))
            outs = self._fit(self._eval(sub, consts, in_vals[1:], br_ctx),
                             n_out, in_vals)
            acc = [self.join(a, b) for a, b in zip(acc, outs)]
        return acc


def branch_compatible(a: Tuple[Tuple[int, int], ...],
                      b: Tuple[Tuple[int, int], ...]) -> bool:
    """True unless the two branch paths take DIFFERENT branches of the
    same cond — mutually exclusive events can't co-occur at runtime."""
    da, db = dict(a), dict(b)
    return all(db[uid] == idx for uid, idx in da.items() if uid in db)
