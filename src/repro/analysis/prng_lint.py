"""PRNG-hygiene lint over a train-step jaxpr.

Tracks key provenance by VALUE NUMBERING: every typed key gets an
interned identity built from its derivation chain —

    root(const | invar)  --fold_in(data)-->  ('fold', parent, data)
                         --split-->          ('split', parent) [i]

where ``data`` is the literal value when static and a stable symbolic
id of the operand variable otherwise. Two keys with the same identity
hold the same bits, however independently the Python code rebuilt them
(the seed-synced transport reconstructs peers' keys this way on
purpose — with DIFFERENT node operands, which is what keeps them
distinct here).

Consumption events are ``random_bits`` draws (every ``jax.random``
sampler bottoms out there on this toolchain) and ``random_split``.
Findings:

* ``key-reuse``       — one key identity consumed by two events that can
  co-occur at runtime (draw+draw, draw+split, split+split). Mutually
  exclusive ``lax.switch`` branches are NOT co-occurring.
* ``scan-invariant-key`` — a draw inside a ``lax.scan`` body (length>1)
  whose key does not depend on any loop-carried value: the same bits
  every iteration, which silently voids the DP accounting (the PR-1
  bug class, generalized).
* ``padded-draw-shape``  — a (rows, lane) draw at a kernel-padded plane
  shape instead of the canonical plane-spec shape: the threefry
  trajectory would depend on a tiling parameter (the literal PR-1 bug).

``fold_in`` is a non-consuming derivation (jax's fold_in never reveals
the parent's bits), so deriving many children from one root is clean.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Tuple

from repro.analysis import jaxpr_walk
from repro.analysis.jaxpr_walk import branch_compatible

__all__ = ["analyze_prng"]

# abstract value: (key_id | None, loop_varying)
Val = Tuple[Optional[int], bool]

_DRAW_PRIMS = frozenset({"random_bits", "threefry2x32"})
_LANES = (128, 1024)


class _Interner:
    def __init__(self):
        self._tab: Dict[tuple, int] = {}
        self._names: List[tuple] = []

    def __call__(self, key: tuple) -> int:
        if key not in self._tab:
            self._tab[key] = len(self._names)
            self._names.append(key)
        return self._tab[key]

    def name(self, i: int) -> str:
        kind = self._names[i][0]
        return f"{kind}#{i}"


def _lit(v):
    if jaxpr_walk._is_literal(v):
        val = v.val
        try:
            return ("lit", val.item() if hasattr(val, "item") else val)
        except Exception:
            return ("lit", str(val))
    return None


class _PrngInterp(jaxpr_walk.JaxprInterpreter):
    def __init__(self, allowed_shapes):
        self.intern = _Interner()
        self.events: List[dict] = []   # key_id, kind, shape, site, branch, in_loop, loopvar
        self.findings: List[dict] = []
        self.allowed_shapes = allowed_shapes
        self._var_uid = itertools.count()
        self._var_ids: Dict[int, int] = {}
        # fixpoint re-evaluations replay the same eqn on the same call
        # path: one runtime event, recorded once. Two distinct call
        # sites of a shared subjaxpr differ in ctx.path and are kept.
        self._event_keys = set()

    # lattice -------------------------------------------------------------
    def bottom(self) -> Val:
        return (None, False)

    def join(self, a: Val, b: Val) -> Val:
        key = a[0] if a[0] == b[0] else None
        return (key, a[1] or b[1])

    def const(self, c, ctx) -> Val:
        return (self.intern(("const", id(c))), False)

    def loop_carry_seed(self, val: Val, ctx) -> Val:
        return (val[0], True)

    # helpers -------------------------------------------------------------
    def _sym(self, var) -> tuple:
        uid = self._var_ids.setdefault(id(var), next(self._var_uid))
        return ("var", uid)

    def _data_repr(self, var, val: Val) -> tuple:
        lit = _lit(var)
        if lit is not None:
            return lit
        if val[0] is not None:
            return ("id", val[0])
        return self._sym(var)

    def _record(self, kind, key_val: Val, eqn, ctx, shape=None):
        if key_val[0] is None:
            return
        dedup = (key_val[0], kind, id(eqn), ctx.branch, ctx.path)
        if dedup in self._event_keys:
            return
        self._event_keys.add(dedup)
        self.events.append({
            "key_id": key_val[0], "kind": kind, "shape": shape,
            "site": jaxpr_walk.format_site(eqn), "branch": ctx.branch,
            "in_loop": ctx.in_loop(), "loopvar": key_val[1]})

    # transfer ------------------------------------------------------------
    def default_out(self, eqn, in_vals, ctx):
        loopvar = any(v[1] for v in in_vals)
        return [(None, loopvar) for _ in eqn.outvars]

    def on_eqn(self, eqn, in_vals, ctx, def_prim):
        name = eqn.primitive.name
        if name in ("random_wrap", "random_unwrap"):
            v = in_vals[0]
            if v[0] is None:
                v = (self.intern(("root",) + self._sym(eqn.invars[0])), v[1])
            return [v]
        if name == "random_fold_in":
            parent, data = in_vals[0], in_vals[1]
            if parent[0] is None:
                parent = (self.intern(("root",) + self._sym(eqn.invars[0])),
                          parent[1])
            kid = self.intern(("fold", parent[0],
                               self._data_repr(eqn.invars[1], data)))
            return [(kid, parent[1] or data[1])]
        if name == "random_split":
            parent = in_vals[0]
            self._record("split", parent, eqn, ctx)
            if parent[0] is None:
                return None
            return [(self.intern(("split", parent[0])), parent[1])]
        if name in _DRAW_PRIMS:
            key = in_vals[0]
            shape = None
            try:
                shape = tuple(eqn.outvars[0].aval.shape)
            except Exception:
                pass
            self._record("draw", key, eqn, ctx, shape=shape)
            self._check_shape(shape, eqn)
            return None
        if name in ("get", "swap"):
            # pallas kernel ref read/write: a key stored in a Ref keeps
            # its identity through the load, so stochastic-rounding draws
            # INSIDE a kernel body join the same reuse/shape accounting
            # as host-side draws (the walker head-aligns kernel refs).
            return [in_vals[0]]
        if name in ("slice", "squeeze", "dynamic_slice"):
            # key extraction from a split-array: ('split', p) -> child
            src = in_vals[0]
            if src[0] is not None:
                base = self.intern._names[src[0]]
                if base[0] == "split":
                    if name == "squeeze":
                        return [src]
                    idx = eqn.params.get("start_indices")
                    if idx is None:   # dynamic: symbolic index operand
                        idx = self._data_repr(eqn.invars[1],
                                              in_vals[1] if len(in_vals) > 1
                                              else (None, False))
                    kid = self.intern(("split_child", src[0], str(idx)))
                    return [(kid, src[1])]
            return None
        return None

    def _check_shape(self, shape, eqn):
        if (shape and len(shape) == 2 and shape[1] in _LANES
                and shape not in self.allowed_shapes):
            canon = {s for s in self.allowed_shapes
                     if len(s) == 2 and s[1] == shape[1]}
            if any(shape[0] > s[0] for s in canon):
                self.findings.append({
                    "kind": "padded-draw-shape", "shape": list(shape),
                    "allowed": sorted(map(list, canon)),
                    "site": jaxpr_walk.format_site(eqn)})


def _conflicts(a: dict, b: dict) -> bool:
    return branch_compatible(a["branch"], b["branch"])


def analyze_prng(closed_jaxpr, key_roots: Dict[int, str] | None = None,
                 allowed_shapes=()):
    """Run the PRNG pass.

    ``key_roots`` maps top-level invar positions holding PRNG keys to a
    name (unnamed keys are rooted lazily at first wrap/fold).
    ``allowed_shapes`` is the set of canonical (rows, lane) plane shapes
    random draws are allowed to use; 2-D draws at a LARGER row count on
    a known lane are the padded-shape bug class.
    """
    interp = _PrngInterp(frozenset(tuple(s) for s in allowed_shapes))
    jaxpr, _ = jaxpr_walk._unpack(closed_jaxpr)
    in_vals: List[Val] = []
    for i, var in enumerate(jaxpr.invars):
        if key_roots and i in key_roots:
            in_vals.append((interp.intern(("root", "arg", key_roots[i])),
                            False))
        else:
            in_vals.append((None, False))
    interp.run(closed_jaxpr, in_vals)

    findings = list(interp.findings)
    by_key: Dict[int, List[dict]] = {}
    for ev in interp.events:
        by_key.setdefault(ev["key_id"], []).append(ev)
    for key_id, evs in by_key.items():
        for i in range(len(evs)):
            for j in range(i + 1, len(evs)):
                a, b = evs[i], evs[j]
                if _conflicts(a, b):
                    findings.append({
                        "kind": "key-reuse",
                        "key": interp.intern.name(key_id),
                        "events": [f"{a['kind']}@{a['site']}",
                                   f"{b['kind']}@{b['site']}"]})
    for ev in interp.events:
        if ev["kind"] == "draw" and ev["in_loop"] and not ev["loopvar"]:
            findings.append({
                "kind": "scan-invariant-key",
                "key": interp.intern.name(ev["key_id"]),
                "site": ev["site"]})
    return {"findings": findings, "n_draws": sum(
        1 for e in interp.events if e["kind"] == "draw")}
