"""Static analysis over compiled train steps (no execution).

Seven passes, one CLI (``python -m repro.analysis``; select with
``--pass``):

* ``jaxpr_taint``  — interprocedural data-taint: no un-sanitized
  data-derived tensor may reach a collective (``ppermute``/``psum``),
  where "sanitized" means it passed the ``tagging.sanitize`` mark that
  ``masked_grad`` applies after clip -> + sigma*normal (sigma > 0).
* ``prng_lint``    — PRNG hygiene: no key consumed twice (draw+draw,
  draw+split), no scan-iteration-invariant key drawn inside the
  training loop, no mask/noise draw at a kernel-padded plane shape.
* ``wire_audit``   — registry-wide HLO invariants: collective-permute
  count == schedule rounds (leaf-count-independent), payload bits ==
  the static wire accounting, every permute operand wire-tagged.
* ``sensitivity``  — QUANTITATIVE certifier: norm-bound abstract
  interpretation from the ``clip_bound`` tag proves the sanitize
  operand's coordinate bound <= C and wire buffers post-noise, plus
  the ``qsgd_range_certificate`` interval proofs for integer wire
  encodings.
* ``calibration``  — extracts the concrete Gaussian std from the jaxpr
  at every sanitize site and cross-checks the accountant's sigma;
  ``analyze_overlap`` token-checks the ``pending_buffer`` double
  buffer for exactly-one-round staleness.

The passes run over the method x compressor x topology matrix on a
4-node host mesh (see ``wire_audit.MATRIX``); each config's report row
carries a machine-readable privacy certificate.
"""
__all__ = ["analyze_taint", "analyze_prng", "analyze_sensitivity",
           "analyze_calibration", "analyze_overlap",
           "qsgd_range_certificate", "audit_config", "MATRIX", "PASSES",
           "expected_permutes"]

_EXPORTS = {
    "analyze_taint": "repro.analysis.jaxpr_taint",
    "analyze_prng": "repro.analysis.prng_lint",
    "analyze_sensitivity": "repro.analysis.sensitivity",
    "qsgd_range_certificate": "repro.analysis.sensitivity",
    "analyze_calibration": "repro.analysis.calibration",
    "analyze_overlap": "repro.analysis.calibration",
    "audit_config": "repro.analysis.wire_audit",
    "MATRIX": "repro.analysis.wire_audit",
    "PASSES": "repro.analysis.wire_audit",
    "expected_permutes": "repro.analysis.wire_audit",
}


def __getattr__(name):
    # lazy: wire_audit builds meshes at import, keep `import repro.analysis`
    # cheap for callers that only want one pass.
    if name in _EXPORTS:
        import importlib
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(name)
