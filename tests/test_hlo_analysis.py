"""Collective-byte parser unit tests over hand-written HLO snippets."""
from repro.launch import hlo_analysis


HLO = """
ENTRY main {
  %p0 = bf16[2,512]{1,0} parameter(0)
  %ar = bf16[2,512]{1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[4,128]{1,0} all-gather(%p0), dimensions={0}
  %cp = bf16[1024]{0} collective-permute(%ar), source_target_pairs={{0,1}}
  %ars = (bf16[2,512]{1,0}, bf16[2,512]{1,0}) all-reduce-start(%p0)
  %ard = bf16[2,512]{1,0} all-reduce-done(%ars)
  %a2a = f32[8,64]{1,0} all-to-all(%ag), dimensions={0}
  %rs = f32[2,64]{1,0} reduce-scatter(%ag), dimensions={0}
}
"""


def test_collective_bytes_by_kind():
    out = hlo_analysis.collective_bytes(HLO)
    assert out["all-reduce"] == 2 * 512 * 2 + 2 * (2 * 512 * 2)  # ar + start tuple
    assert out["all-gather"] == 4 * 128 * 4
    assert out["collective-permute"] == 1024 * 2
    assert out["all-to-all"] == 8 * 64 * 4
    assert out["reduce-scatter"] == 2 * 64 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_done_ops_not_double_counted():
    counts = hlo_analysis.count_ops(HLO)
    assert counts["all-reduce"] == 2  # plain + start, not done


def test_empty_and_garbage():
    assert hlo_analysis.collective_bytes("") == {"total": 0}
    assert hlo_analysis.collective_bytes("add(f32[2] x, y)") == {"total": 0}


def test_shape_bytes_direct():
    """The low-level shape parser every helper rests on."""
    sb = hlo_analysis._shape_bytes
    assert sb("f32[2,512]") == 2 * 512 * 4
    assert sb("bf16[1024]") == 1024 * 2
    assert sb("f32[]") == 4                      # scalar: empty dims = 1 elem
    assert sb("(f32[8], s32[8])") == 8 * 4 + 8 * 4
    assert sb("u8[512]") == 512                  # packed qsgd wire lane
    assert sb("pred[16]") == 16                  # bool mask plane
    assert sb("token[]") == 0                    # unknown dtype skipped
    assert sb("") == 0
    # byte-floor convention for sub-byte element types
    assert sb("u4[32]") == 32 * hlo_analysis.DTYPE_BYTES["u4"]


def test_permute_payloads_mixed_dtype_tuple():
    """Compressed payload wire: f32 values + s32 indices ride one permute
    (sync tuple form) — the parser must keep the dtypes separate so the
    index side-channel is visible in the accounting."""
    hlo = """
ENTRY main {
  %cp = (f32[51]{0}, s32[51]{0}) collective-permute(%v, %i), source_target_pairs={{0,1}}
}
"""
    pls = hlo_analysis.permute_payloads(hlo)
    assert len(pls) == 1
    assert pls[0]["elems"] == {"f32": 51, "s32": 51}
    assert pls[0]["bits"] == 51 * 32 + 51 * 32


def test_permute_payloads_async_mixed_tuple_counted_once():
    """Async -start with a 2-leaf payload: the tuple is (operands...,
    results..., u32 context words). Context dropped, mirror halved —
    payload counted ONCE, exactly like the sync form."""
    hlo = """
ENTRY main {
  %cps = (f32[51]{0}, s32[51]{0}, f32[51]{0}, s32[51]{0}, u32[], u32[]) collective-permute-start(%v, %i)
  %cpd = (f32[51]{0}, s32[51]{0}) collective-permute-done(%cps)
}
"""
    pls = hlo_analysis.permute_payloads(hlo)
    assert len(pls) == 1                          # done skipped
    assert pls[0]["elems"] == {"f32": 51, "s32": 51}
    assert pls[0]["bits"] == 51 * 32 + 51 * 32
    assert hlo_analysis.collective_permute_count(hlo) == 1


def test_collective_bytes_counts_permute_start_result_shape():
    """collective_bytes uses the raw result-shape convention (roofline
    traffic), so the async tuple's operand mirror IS counted there —
    permute_payloads is the one-payload-once view."""
    hlo = """
ENTRY main {
  %cps = (f32[64]{0}, f32[64]{0}, u32[], u32[]) collective-permute-start(%x)
  %cpd = f32[64]{0} collective-permute-done(%cps)
}
"""
    out = hlo_analysis.collective_bytes(hlo)
    assert out["collective-permute"] == 2 * 64 * 4 + 2 * 4
    assert hlo_analysis.permute_payloads(hlo)[0]["bits"] == 64 * 32


def test_permute_payloads_sync_and_async():
    """The wire-plane acceptance surface: per-permute payload bits,
    dtype-aware, with async -start tuple forms (operand mirror + u32
    context words) counted ONCE like the sync lowering."""
    hlo = """
ENTRY main {
  %cp = f32[8,128]{1,0} collective-permute(%x), source_target_pairs={{0,1}}
  %q = u8[512]{0} collective-permute(%y), source_target_pairs={{0,1}}
  %cps = (f32[8,128]{1,0}, f32[8,128]{1,0}, u32[], u32[]) collective-permute-start(%x)
  %cpd = f32[8,128]{1,0} collective-permute-done(%cps)
}
"""
    pls = hlo_analysis.permute_payloads(hlo)
    assert [p["bits"] for p in pls] == [8 * 128 * 32, 512 * 8, 8 * 128 * 32]
    assert pls[0]["elems"] == {"f32": 1024}
    assert pls[1]["elems"] == {"u8": 512}       # sub-byte qsgd u8 lanes
    assert pls[2]["elems"] == {"f32": 1024}     # start counted once
    assert hlo_analysis.collective_permute_count(hlo) == 3  # done skipped


def test_instruction_counts_and_launch_count():
    """The perf-smoke counting surface: per-opcode instruction counts
    parsed from HLO text, and the launch sum over LAUNCH_OPS (fusions,
    custom-calls, sorts, collectives incl. async -start forms)."""
    hlo = """
ENTRY main {
  %f0 = f32[8,128]{1,0} fusion(%a), kind=kLoop, calls=%fused_computation
  %f1 = f32[8,128]{1,0} fusion(%b), kind=kInput, calls=%fused_computation.1
  %s = f32[64]{0} sort(%c), dimensions={0}
  %cc = f32[8]{0} custom-call(%d), custom_call_target="foo"
  %cp = f32[8,128]{1,0} collective-permute(%e), source_target_pairs={{0,1}}
  %cps = (f32[64]{0}, f32[64]{0}, u32[], u32[]) collective-permute-start(%e)
  %cpd = f32[64]{0} collective-permute-done(%cps)
  %add = f32[8,128]{1,0} add(%f0, %f1)
}
"""
    counts = hlo_analysis.instruction_counts(hlo)
    assert counts["fusion"] == 2
    assert counts["sort"] == 1
    assert counts["custom-call"] == 1
    assert counts["collective-permute"] == 1
    assert counts["collective-permute-start"] == 1
    assert counts["collective-permute-done"] == 1
    assert counts["add"] == 1
    # launches: 2 fusion + sort + custom-call + permute + permute-start;
    # the -done retires an in-flight op, it is NOT a new launch
    assert hlo_analysis.launch_count(hlo) == 6


def test_async_collective_pairs():
    """Overlap audit surface: -start/-done pairing per collective kind
    (an imbalance means a dangling async op in the compiled step)."""
    hlo = """
ENTRY main {
  %cp = f32[8]{0} collective-permute(%x), source_target_pairs={{0,1}}
  %cps = (f32[8]{0}, f32[8]{0}, u32[], u32[]) collective-permute-start(%x)
  %cpd = f32[8]{0} collective-permute-done(%cps)
  %ars = f32[8]{0} all-reduce-start(%y), to_apply=%sum
  %ard = f32[8]{0} all-reduce-done(%ars)
}
"""
    pairs = hlo_analysis.async_collective_pairs(hlo)
    assert pairs["collective-permute"] == {"sync": 1, "start": 1, "done": 1}
    assert pairs["all-reduce"] == {"sync": 0, "start": 1, "done": 1}


def test_launch_count_empty_and_garbage():
    assert hlo_analysis.launch_count("") == 0
    assert hlo_analysis.instruction_counts("not hlo at all") == {}
