"""Convergence-theory calculators: Lemma 1, Corollary 3, Remark 1."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import theory, topology


TOPO = topology.ring(8)


def _inputs(**kw):
    base = dict(n=8, m=100, d=64, p=0.5, theta=0.2, gamma=0.05,
                beta=TOPO.beta, lambda_n=TOPO.lambda_n)
    base.update(kw)
    return theory.BoundInputs(**base)


def test_theta_bound_and_default():
    b = theory.theta_upper_bound(0.2, TOPO.lambda_n, 0.05, 1.0)
    d = theory.default_theta(0.2, TOPO.lambda_n, 0.05, 1.0)
    assert 0 < d < b


def test_lemma1_terms_positive_and_decrease_in_T():
    x = _inputs()
    t1 = theory.lemma1_bound(x, 1000)
    t2 = theory.lemma1_bound(x, 100_000)
    assert t2 < t1
    terms = theory.lemma1_terms(x, 1000)
    assert set(terms) == {"I", "II", "III", "IV"}
    assert all(v >= 0 for v in terms.values())


def test_lemma1_rejects_invalid_theta():
    with pytest.raises(ValueError):
        theory.lemma1_terms(_inputs(theta=0.99, p=0.1), 1000)


def test_term_I_scales_inverse_T():
    x = _inputs()
    a = theory.lemma1_terms(x, 1000)["I"]
    b = theory.lemma1_terms(x, 2000)["I"]
    assert a / b == pytest.approx(2.0)


def test_sparsification_noise_vanishes_at_p1():
    """At p=1 the (1/p - 1) compression-noise factors vanish: (IV) == 0."""
    x = _inputs(p=1.0, theta=0.5)
    terms = theory.lemma1_terms(x, 1000)
    assert terms["IV"] == pytest.approx(0.0, abs=1e-12)


def test_corollary3_requirements():
    assert theory.min_iterations_for_rate(8, TOPO.beta) > 0
    g = theory.default_gamma(8, 10_000)
    assert 0 < g < 1


def test_dcdsgd_threshold_formula():
    ln = -0.5
    expected = 4 * (1 - ln) ** 2 / (4 * (1 - ln) ** 2 + (1 - abs(ln)) ** 2)
    assert theory.dcdsgd_min_p(ln) == pytest.approx(expected)


@given(p=st.floats(0.05, 1.0), gamma=st.floats(1e-4, 0.5),
       lam=st.floats(-0.9, 0.9))
@settings(max_examples=100, deadline=None)
def test_default_theta_always_valid(p, gamma, lam):
    """Corollary 3's theta choice always satisfies Lemma 1's bound."""
    th = theory.default_theta(p, lam, gamma, 1.0)
    assert 0 < th < theory.theta_upper_bound(p, lam, gamma, 1.0)


@given(m1=st.integers(50, 500), scale=st.integers(2, 4))
@settings(max_examples=50, deadline=None)
def test_bound_inputs_constants(m1, scale):
    x1 = _inputs(m=m1)
    assert x1.C2 > 0 and x1.C3 > 0
    # C2 decreases with m (less sampling noise)
    x2 = _inputs(m=m1 * scale)
    assert x2.C2 < x1.C2
