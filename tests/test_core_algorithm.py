"""SDM-DSGD algorithm behaviour: convergence, consensus, baselines, Fig. 2."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, plane, sdm_dsgd, sparsifier, theory, \
    topology


# A distributed least-squares problem: node i holds (A_i, b_i); the global
# optimum x* solves sum_i A_i^T(A_i x - b_i) = 0. Non-trivial consensus
# problem with known solution — the canonical DGD test bed.
N, DIM = 8, 12


def _make_problem(seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(N, 32, DIM)) / np.sqrt(32)
    x_true = rng.normal(size=(DIM,))
    b = A @ x_true + 0.01 * rng.normal(size=(N, 32))
    A_all = A.reshape(-1, DIM)
    b_all = b.reshape(-1)
    x_star = np.linalg.lstsq(A_all, b_all, rcond=None)[0]
    return jnp.asarray(A, jnp.float32), jnp.asarray(b, jnp.float32), x_star


A_STACK, B_STACK, X_STAR = _make_problem()


def grad_fn(params_stack, batch):
    """Full-batch per-node least-squares gradient (params leaf: (N, DIM))."""
    del batch

    def one(a, b, x):
        r = a @ x - b
        return a.T @ r / a.shape[0]

    g = jax.vmap(one)(A_STACK, B_STACK, params_stack["w"])
    loss = jnp.mean((jnp.einsum("nbd,nd->nb", A_STACK, params_stack["w"])
                     - B_STACK) ** 2)
    return {"w": g}, loss


def _run(sim_cls, cfg, topo, steps=400, seed=0):
    if sim_cls is sdm_dsgd.ReferenceSimulator:
        sim = sdm_dsgd.ReferenceSimulator(topo, cfg)
    else:
        sim = baselines.DSGDReference(topo, cfg)
    params = {"w": jnp.zeros((N, DIM))}
    state = sim.init(params)
    key = jax.random.PRNGKey(seed)

    @jax.jit
    def body(state, key):
        return sim.step(state, grad_fn, None, key)

    losses = []
    for t in range(steps):
        key, sub = jax.random.split(key)
        state, loss = body(state, sub)
        losses.append(float(loss))
    return sim, state, losses


def test_sdm_dsgd_converges_to_consensus_optimum():
    topo = topology.ring(N)
    cfg = sdm_dsgd.SDMConfig(p=0.5, theta=0.5, gamma=0.3, sigma=0.0)
    cfg.validate_against(topo)
    sim, state, losses = _run(sdm_dsgd.ReferenceSimulator, cfg, topo, steps=800)
    xbar = np.asarray(sim.consensus_mean(state)["w"])
    # converges near x*
    assert np.linalg.norm(xbar - X_STAR) < 0.15 * np.linalg.norm(X_STAR)
    # consensus: node copies close to the mean
    spread = np.asarray(state.x["w"]) - xbar
    assert np.abs(spread).max() < 0.2
    assert losses[-1] < 0.2 * losses[0]


def test_dsgd_baseline_converges():
    topo = topology.ring(N)
    cfg = baselines.DSGDConfig(gamma=0.3)
    sim, state, losses = _run(baselines.DSGDReference, cfg, topo, steps=400)
    xbar = np.asarray(sim.consensus_mean(state)["w"])
    assert np.linalg.norm(xbar - X_STAR) < 0.15 * np.linalg.norm(X_STAR)


def test_figure2_dcdsgd_diverges_where_sdm_converges():
    """Fig. 2 of the paper: p=0.2, theta=1 (DC-DSGD) diverges; SDM with
    theta=0.6 < Lemma-1 bound converges on the same problem."""
    topo = topology.ring(N)

    dc = baselines.dcdsgd_config(p=0.2, gamma=0.3)
    # p=0.2 violates both Remark 1's threshold and Lemma 1's theta bound:
    assert 0.2 < theory.dcdsgd_min_p(topo.lambda_n)
    with pytest.raises(ValueError):
        dc.validate_against(topo)
    _, _, dc_losses = _run(sdm_dsgd.ReferenceSimulator, dc, topo, steps=400)

    sdm = sdm_dsgd.SDMConfig(p=0.2, theta=0.15, gamma=0.3)
    sdm.validate_against(topo)
    _, _, sdm_losses = _run(sdm_dsgd.ReferenceSimulator, sdm, topo, steps=400)

    assert not np.isfinite(dc_losses[-1]) or dc_losses[-1] > 10 * dc_losses[0]
    assert np.isfinite(sdm_losses[-1]) and sdm_losses[-1] < sdm_losses[0]


def test_gaussian_masking_still_converges_noisily():
    topo = topology.ring(N)
    cfg = sdm_dsgd.SDMConfig(p=0.5, theta=0.5, gamma=0.1, sigma=0.05,
                             clip_c=5.0)
    _, state, losses = _run(sdm_dsgd.ReferenceSimulator, cfg, topo, steps=600)
    assert np.isfinite(losses[-1])
    assert losses[-1] < 0.5 * losses[0]


def test_fixedk_mode_matches_bernoulli_statistically():
    topo = topology.ring(N)
    base = dict(p=0.5, theta=0.5, gamma=0.3, sigma=0.0)
    _, s1, l1 = _run(sdm_dsgd.ReferenceSimulator,
                     sdm_dsgd.SDMConfig(mode="bernoulli", **base), topo, 600)
    _, s2, l2 = _run(sdm_dsgd.ReferenceSimulator,
                     sdm_dsgd.SDMConfig(mode="fixedk_packed", **base), topo, 600)
    assert l2[-1] < 0.2 * l2[0]
    assert abs(l1[-1] - l2[-1]) < 0.1 * l1[0] + 0.05


def test_transmitted_elements_metric():
    """Accounting charges the WIRE PLANE (padded (rows, LANE) geometry),
    which is what the compiled transport actually permutes: 137 tree
    elements concat + pad to a 256-element plane, ONE k=ceil(p*plane)
    over the whole plane instead of per-leaf ceils."""
    params = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((37,))}
    padded = plane.ParamPlane.for_tree(params).padded_size
    assert padded == 256    # 137 -> 2 rows of LANE=128
    cfg = sdm_dsgd.SDMConfig(p=0.2)
    assert sdm_dsgd.transmitted_elements_per_step(params, cfg) == \
        round(0.2 * padded)
    cfgk = sdm_dsgd.SDMConfig(p=0.2, mode="fixedk_packed")
    assert sdm_dsgd.transmitted_elements_per_step(params, cfgk) == \
        sparsifier.num_kept(padded, 0.2)


def test_transmitted_elements_clamped_to_plane_size():
    """Pad blocks beyond the plane must not count as transmitted coords.

    A (130,) tree packs to a 256-coordinate plane; with pack_block=3
    the block view has 86 blocks (2 pad coords beyond the plane); at
    p=1.0 every block is kept so naive accounting says 258 > 256 wire
    coordinates.
    """
    params = {"tiny": jnp.zeros((130,))}
    cfg = sdm_dsgd.SDMConfig(p=1.0, mode="fixedk_packed", pack_block=3)
    assert sdm_dsgd.transmitted_elements_per_step(params, cfg) == 256
    # block-aligned planes are unaffected by the clamp
    cfg4 = sdm_dsgd.SDMConfig(p=1.0, mode="fixedk_packed", pack_block=4)
    assert sdm_dsgd.transmitted_elements_per_step(params, cfg4) == 256


def test_transmitted_elements_no_float_overshoot():
    """num_kept fix end-to-end: plane d=128, p=0.07 transmits
    ceil(8.96) = 9, not the float-overshoot 10."""
    params = {"w": jnp.zeros((100,))}
    cfg = sdm_dsgd.SDMConfig(p=0.07, mode="fixedk_packed")
    assert sdm_dsgd.transmitted_elements_per_step(params, cfg) == \
        sparsifier.num_kept(128, 0.07) == 9


def test_theta_one_p_one_reduces_to_dsgd():
    """With p=1, theta=1, sigma=0 SDM-DSGD is exactly DSGD (generalization)."""
    topo = topology.ring(N)
    cfg = sdm_dsgd.SDMConfig(p=1.0, theta=1.0, gamma=0.3, sigma=0.0)
    sim = sdm_dsgd.ReferenceSimulator(topo, cfg)
    dsgd = baselines.DSGDReference(topo, baselines.DSGDConfig(gamma=0.3))
    params = {"w": jnp.zeros((N, DIM))}
    s1, s2 = sim.init(params), dsgd.init(params)
    key = jax.random.PRNGKey(0)
    for _ in range(5):
        key, k1 = jax.random.split(key)
        s1, _ = sim.step(s1, grad_fn, None, k1)
        s2, _ = dsgd.step(s2, grad_fn, None, k1)
    # SDM's x lags one step (it applies d at the START of the next iter):
    # advance s1 once more to materialize the last differential.
    s1_adv, _ = sdm_dsgd.ReferenceSimulator(topo, cfg).advance(s1, key)
    np.testing.assert_allclose(np.asarray(s1_adv.x["w"]),
                               np.asarray(s2.x["w"]), atol=1e-4)
