"""Substrate tests: data pipeline, optimizers, checkpointing, serving."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import (TokenStream, classification_dataset,
                        node_partitioned_batches)
from repro.models import transformer, vision_small
from repro.optim import adamw, cosine_schedule, global_norm_clip, momentum, sgd
from repro.serving import Request, ServingEngine


# ---------------- data -----------------------------------------------------

def test_token_stream_deterministic_and_shifted():
    s1 = TokenStream(vocab_size=128, batch=4, seq_len=16, seed=7)
    s2 = TokenStream(vocab_size=128, batch=4, seq_len=16, seed=7)
    t1, l1 = s1.batch_at(3)
    t2, l2 = s2.batch_at(3)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(t1[:, 1:], l1[:, :-1])  # labels = shift
    assert t1.min() >= 0 and t1.max() < 128


def test_token_stream_has_learnable_structure():
    """Bigram structure: a simple bigram predictor beats uniform entropy."""
    s = TokenStream(vocab_size=32, batch=64, seq_len=64, seed=0)
    toks, labels = s.batch_at(0)
    counts = np.ones((32, 32))
    for t, l in zip(toks.reshape(-1), labels.reshape(-1)):
        counts[t, l] += 1
    probs = counts / counts.sum(1, keepdims=True)
    toks2, labels2 = s.batch_at(1)
    nll = -np.mean(np.log(probs[toks2.reshape(-1), labels2.reshape(-1)]))
    assert nll < np.log(32) * 0.95  # clearly below uniform


def test_node_partitioned_batches_shapes_and_locality():
    xs = np.arange(1000 * 4, dtype=np.float32).reshape(1000, 4)
    ys = np.arange(1000, dtype=np.int32) % 10
    it = node_partitioned_batches(xs, ys, n_nodes=5, batch_per_node=8, seed=0)
    bx, by = next(it)
    assert bx.shape == (5, 8, 4) and by.shape == (5, 8)
    # node i only samples from shard i (rows [200*i, 200*(i+1)))
    for i in range(5):
        assert ((bx[i, :, 0] >= 200 * i * 4) &
                (bx[i, :, 0] < 200 * (i + 1) * 4)).all()


# ---------------- optimizers -----------------------------------------------

@pytest.mark.parametrize("opt_name", ["sgd", "momentum", "adamw"])
def test_optimizers_minimize_quadratic(opt_name):
    opt = {"sgd": sgd(0.1), "momentum": momentum(0.05),
           "adamw": adamw(0.1)}[opt_name]
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule_shape():
    fn = cosine_schedule(1.0, warmup=10, total=100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
    assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, abs=0.02)


def test_global_norm_clip():
    g = {"a": jnp.full((4,), 3.0)}  # norm 6
    clipped, norm = global_norm_clip(g, 1.5)
    assert float(norm) == pytest.approx(6.0)
    clipped_norm = float(jnp.linalg.norm(clipped["a"]))
    assert clipped_norm == pytest.approx(1.5, rel=1e-3)


# ---------------- checkpoint ------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": (jnp.zeros((3,), jnp.int32), {"mu": jnp.ones((2,))})}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, tree)
    save_checkpoint(d, 7, jax.tree.map(lambda x: x + 1, tree))
    assert latest_step(d) == 7
    restored = restore_checkpoint(d, tree)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a) + 1, np.asarray(b)), tree, restored)
    restored3 = restore_checkpoint(d, tree, step=3)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored3)


# ---------------- paper models ----------------------------------------------

def test_paper_models_forward():
    key = jax.random.PRNGKey(0)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 784)),
                    jnp.float32)
    mlr = vision_small.mlr_init(key)
    assert vision_small.mlr_apply(mlr, x).shape == (4, 10)
    cnn = vision_small.cnn_init(key, (28, 28, 1))
    assert vision_small.cnn_apply(cnn, x, (28, 28, 1)).shape == (4, 10)
    x3 = jnp.asarray(np.random.default_rng(1).normal(size=(2, 3072)),
                     jnp.float32)
    rn = vision_small.resnet20_init(key)
    out = vision_small.resnet20_apply(rn, x3)
    assert out.shape == (2, 10)
    assert bool(jnp.isfinite(out).all())


def test_classification_dataset_learnable():
    (xtr, ytr), (xte, yte) = classification_dataset(16, 4, 2000, 500,
                                                    seed=0, class_sep=3.0)
    # nearest-centroid on train centroids gets well above chance on test
    cents = np.stack([xtr[ytr == c].mean(0) for c in range(4)])
    pred = np.argmin(((xte[:, None] - cents[None]) ** 2).sum(-1), axis=1)
    assert (pred == yte).mean() > 0.6


# ---------------- serving ---------------------------------------------------

def test_serving_engine_greedy_matches_manual_decode():
    cfg = configs.get_smoke_config("phi3-medium-14b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=32)
    prompt = list(np.random.default_rng(0).integers(0, cfg.vocab_size, 8))
    reqs = [Request(prompt=prompt, max_new_tokens=5)]
    engine.serve(reqs)
    # manual greedy reference
    cache = transformer.init_cache(cfg, 1, 32, jnp.float32)
    logits, cache = transformer.prefill(
        params, cfg, jnp.asarray([prompt], jnp.int32), cache)
    out = []
    tok = jnp.argmax(logits, -1)
    for _ in range(5):
        out.append(int(tok[0]))
        logits, cache = transformer.decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(logits, -1)
    assert reqs[0].output == out


def test_serving_engine_respects_budgets_and_eos():
    cfg = configs.get_smoke_config("rwkv6-3b")
    params = transformer.init_params(jax.random.PRNGKey(1), cfg)
    engine = ServingEngine(cfg, params, max_batch=3, max_seq=48)
    rng = np.random.default_rng(2)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 6).tolist(),
                    max_new_tokens=k) for k in (1, 4, 9)]
    engine.serve(reqs)
    assert [len(r.output) for r in reqs] == [1, 4, 9]
