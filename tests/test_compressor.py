"""The pluggable Compressor layer: registry/spec parsing, roundtrip
unbiasedness, pad-to-max-k heterogeneous payloads, exact wire-bit
accounting, accountant wiring, and the compressed push-sum invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (compressor, gradient_push, method,
                        plane as plane_mod, privacy, sdm_dsgd, sparsifier,
                        topology)


# ---------------------------------------------------------------------------
# Registry + spec parsing.
# ---------------------------------------------------------------------------

def test_registry_and_specs():
    assert set(compressor.names()) >= {"bernoulli", "fixedk", "block",
                                       "rows", "qsgd"}
    assert isinstance(compressor.make("bernoulli", p=0.3),
                      compressor.BernoulliCompressor)
    fk = compressor.make("fixedk:4", p=0.3)
    assert isinstance(fk, compressor.FixedKCompressor) and fk.block == 4
    assert compressor.make("block:256", p=0.5).block == 256
    assert compressor.make("block", p=0.5).block == 128
    q = compressor.make("qsgd:4")
    assert isinstance(q, compressor.QSGDCompressor) and q.bits == 4
    with pytest.raises(ValueError, match="registered:"):
        compressor.make("no-such-compressor")
    with pytest.raises(ValueError):
        compressor.make("qsgd:12")     # int8 wire caps at 8 bits
    with pytest.raises(ValueError):
        compressor.make("fixedk", p=0.0)


def test_sdm_config_selects_compressor_by_name():
    cases = [("bernoulli", "bernoulli", 1),
             ("fixedk", "fixedk_packed", 1),
             ("fixedk:64", "fixedk_packed", 64),
             ("block:8", "fixedk_packed", 8),
             ("rows", "fixedk_rows", 1),
             ("qsgd:4", "qsgd", 1)]
    for spec, mode, block in cases:
        cfg = sdm_dsgd.SDMConfig(compressor=spec, p=0.25, theta=0.3)
        assert cfg.mode == mode, spec
        if mode == "fixedk_packed":
            assert cfg.pack_block == block
    assert sdm_dsgd.SDMConfig(compressor="qsgd:4", theta=0.3).qsgd_bits == 4
    with pytest.raises(ValueError, match="registered:"):
        sdm_dsgd.SDMConfig(compressor="zip")
    # compressor_of resolves either spelling to the same object type
    c1 = sdm_dsgd.compressor_of(sdm_dsgd.SDMConfig(compressor="block:8"))
    c2 = sdm_dsgd.compressor_of(
        sdm_dsgd.SDMConfig(mode="fixedk_packed", pack_block=8))
    assert c1 == c2


# ---------------------------------------------------------------------------
# Roundtrip semantics.
# ---------------------------------------------------------------------------

def _x(shape=(13, 7), seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape),
                       jnp.float32)


@pytest.mark.parametrize("spec", ["bernoulli", "fixedk", "fixedk:4",
                                  "rows", "qsgd:8"])
def test_roundtrip_unbiased(spec):
    x = _x()
    comp = compressor.make(spec, p=0.4)
    keys = jax.random.split(jax.random.PRNGKey(1), 3000)
    mean = jnp.mean(jax.vmap(
        lambda k: comp.decompress(comp.compress(k, x)))(keys), axis=0)
    tol = 0.01 if spec.startswith("qsgd") else 0.12
    assert float(jnp.max(jnp.abs(mean - x))) < tol
    # payload is shape-static: same shapes for any key
    p1 = comp.compress(keys[0], x)
    p2 = comp.compress(keys[1], x)
    assert jax.tree.map(jnp.shape, p1) == jax.tree.map(jnp.shape, p2)


def test_fixedk_exact_count_and_scale():
    x = _x((91,))
    comp = compressor.make("fixedk", p=0.3)
    pl = comp.compress(jax.random.PRNGKey(0), x)
    k = sparsifier.num_kept(91, 0.3)
    assert pl.values.shape == (k, 1) and pl.indices.shape == (k,)
    dense = comp.decompress(pl)
    assert int(jnp.sum(dense != 0)) == k
    np.testing.assert_allclose(
        np.asarray(dense)[np.asarray(pl.indices)].ravel(),
        np.asarray(pl.values).ravel(), rtol=1e-6)


def test_qsgd_levels_bounded_int8():
    x = _x((257,), seed=3) * 100.0
    # b=8: unpacked int8 wire, levels within +-s
    comp8 = compressor.make("qsgd:8")
    pl8 = comp8.compress(jax.random.PRNGKey(2), x)
    assert pl8.values.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(pl8.values.astype(jnp.int32)))) <= \
        2 ** (8 - 1) - 1
    # b=4: SUB-BYTE u8-packed wire (2 levels per byte); decompressed
    # levels still within +-s of the scale
    comp = compressor.make("qsgd:4")
    pl = comp.compress(jax.random.PRNGKey(2), x)
    s = 2 ** (4 - 1) - 1
    assert pl.values.dtype == jnp.uint8
    assert pl.values.shape == (-(-257 // 2),)   # ceil(d/2) bytes
    levels = comp.decompress(pl) * s / pl.scale
    assert float(jnp.max(jnp.abs(levels))) <= s + 1e-4
    # zero input compresses to an exactly-zero payload (consensus is a
    # fixed point of the compressed dynamics)
    z = comp.compress(jax.random.PRNGKey(2), jnp.zeros((5,)))
    assert float(jnp.max(jnp.abs(comp.decompress(z)))) == 0.0


# ---------------------------------------------------------------------------
# Heterogeneous per-node p: pad-to-max-k payloads.
# ---------------------------------------------------------------------------

def test_hetp_pad_to_max_k():
    p = (0.1, 0.3, 0.5)
    comp = compressor.make("fixedk", p=p)
    x = _x((91,))
    kmax = sparsifier.num_kept(91, 0.5)
    for node in range(3):
        pl = comp.compress(jax.random.PRNGKey(0), x, node=node)
        # ONE static wire shape for every node...
        assert pl.values.shape == (kmax, 1)
        # ...but each node's informative payload is its own k_i
        k_i = sparsifier.num_kept(91, p[node])
        dense = comp.decompress(pl)
        assert int(jnp.sum(dense != 0)) == k_i
        assert comp.wire_elements((91,), node=node) == k_i
    with pytest.raises(ValueError, match="node"):
        comp.compress(jax.random.PRNGKey(0), x)
    # accounting with no node named charges the worst-case (max-p) node
    assert comp.wire_elements((91,)) == kmax


def test_hetp_fixedk_reference_runs_and_accounts():
    topo = topology.ring(4)
    cfg = sdm_dsgd.SDMConfig(p=(0.2, 0.3, 0.4, 0.5), theta=0.2, gamma=0.2,
                             mode="fixedk_packed")
    sim = method.get("sdm-dsgd").make_reference(topo, cfg)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(4, 16, 8)) / 3.0, jnp.float32)
    x_true = rng.normal(size=(8,))
    b = jnp.asarray(np.asarray(a) @ x_true
                    + 0.01 * rng.normal(size=(4, 16)), jnp.float32)

    def grad_fn(params, batch):
        del batch
        g = jax.vmap(lambda w, aa, bb: aa.T @ (aa @ w - bb) / 16.0)(
            params["w"], a, b)
        loss = jnp.mean((jnp.einsum("nbd,nd->nb", a, params["w"]) - b) ** 2)
        return {"w": g}, loss

    state = sim.init({"w": jnp.zeros((4, 8))})
    key = jax.random.PRNGKey(0)
    step = jax.jit(lambda s, k: sim.step(s, grad_fn, None, k))
    losses = []
    for _ in range(200):
        key, sub = jax.random.split(key)
        state, loss = step(state, sub)
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0]
    # per-node accounting matches each node's own k OVER THE WIRE PLANE
    # (the padded (rows, LANE) buffer the transport actually draws on);
    # the RDP accountant still charges the worst-case node
    params = {"w": jnp.zeros((8,))}
    plane_d = plane_mod.ParamPlane.for_tree(params).padded_size
    per_node = [sdm_dsgd.transmitted_elements_per_step(params, cfg, i)
                for i in range(4)]
    assert per_node == [sparsifier.num_kept(plane_d, pi) for pi in cfg.p]
    pp = privacy.PrivacyParams.from_compressor(
        sdm_dsgd.compressor_of(cfg), G=1.0, m=100, tau=0.1, sigma=1.0)
    assert pp.p_worst == 0.5


# ---------------------------------------------------------------------------
# Wire accounting in bits.
# ---------------------------------------------------------------------------

def test_wire_bits_accounting():
    d = 1024
    shape = (d,)
    fk = compressor.make("fixedk", p=0.25)
    k = sparsifier.num_kept(d, 0.25)
    assert fk.wire_bits(shape, index_sync=True) == k * 32
    assert fk.wire_bits(shape) == k * 32 + k * 10   # ceil(log2 1024) = 10
    q = compressor.make("qsgd:4")
    assert q.wire_bits(shape) == d * 4 + 32          # + the norm scalar
    bern = compressor.make("bernoulli", p=0.25)
    assert bern.wire_bits(shape, index_sync=True) == 256 * 32
    # the companion metric threads through the config layer
    params = {"w": jnp.zeros((d,))}
    cfg = sdm_dsgd.SDMConfig(compressor="fixedk", p=0.25)
    assert sdm_dsgd.transmitted_bits_per_step(params, cfg) == k * 32
    assert sdm_dsgd.transmitted_bits_per_step(
        params, cfg, index_sync=False) == k * 32 + k * 10
    cfg_q = sdm_dsgd.SDMConfig(compressor="qsgd:4")
    assert sdm_dsgd.transmitted_bits_per_step(params, cfg_q) == d * 4 + 32
    # method-level: dense baselines fall back to elements * 32
    meth = method.get("dsgd")
    from repro.core import baselines
    assert method.transmitted_bits(meth, params,
                                   baselines.DSGDConfig()) == d * 32


def test_privacy_params_from_compressor():
    base = dict(G=2.0, m=50, tau=0.1, sigma=1.2)
    pp = privacy.PrivacyParams.from_compressor(
        compressor.make("fixedk", p=0.3), **base)
    assert pp.p == 0.3
    het = privacy.PrivacyParams.from_compressor(
        compressor.make("fixedk", p=(0.1, 0.4)), **base)
    assert het.p_worst == 0.4
    q = privacy.PrivacyParams.from_compressor(compressor.make("qsgd"), **base)
    assert q.p == 1.0    # quantizers release every coordinate


# ---------------------------------------------------------------------------
# Compressed push-sum: conservation + consensus within tolerance.
# ---------------------------------------------------------------------------

def _pure_gossip(cfg, topo, stack, steps):
    sim = method.get("gradient-push").make_reference(topo, cfg)
    state = sim.init(stack)
    zero_grad = lambda p, b: (jax.tree.map(jnp.zeros_like, p), 0.0)
    key = jax.random.PRNGKey(0)
    step = jax.jit(lambda s, k: sim.step(s, zero_grad, None, k))
    for _ in range(steps):
        key, sub = jax.random.split(key)
        state, _ = step(state, sub)
    return sim, state


def test_compressed_push_sum_consensus():
    """Error-compensated compressed push-sum on a directed graph:
    sum x / sum w stays EXACTLY mass-conserved under compression, and the
    per-node de-biased estimates land within tolerance of the
    uncompressed push-sum limit."""
    topo = topology.directed_erdos_renyi(6, 0.3, seed=2)
    rng = np.random.default_rng(0)
    stack = {"w": jnp.asarray(rng.normal(size=(6, 5)), jnp.float32)}
    mean0 = np.mean(np.asarray(stack["w"]), axis=0)

    sim_u, st_u = _pure_gossip(
        gradient_push.GradientPushConfig(gamma=0.0), topo, stack, 80)
    z_u = np.asarray(sim_u.eval_params(st_u)["w"])
    assert np.max(np.abs(z_u - mean0)) < 1e-5      # uncompressed limit

    cfg_c = gradient_push.GradientPushConfig(
        gamma=0.0, compressor="fixedk", p=0.4)   # default CHOCO chi
    sim_c, st_c = _pure_gossip(cfg_c, topo, stack, 80)
    # mass conservation survives compression bit-exactly
    cons = np.asarray(sim_c.consensus(st_c)["w"])
    np.testing.assert_allclose(cons, mean0, atol=1e-4)
    # de-biased estimates within tolerance of the uncompressed consensus
    z_c = np.asarray(sim_c.eval_params(st_c)["w"])
    assert np.max(np.abs(z_c - mean0)) < 0.05
    # compressed state carries the public-copy machinery
    assert st_c.xhat is not None and st_c.s is not None
    assert st_u.xhat is None and st_u.s is None


def test_compressed_push_state_fields():
    meth = method.get("gradient-push")
    plain = gradient_push.GradientPushConfig()
    comp = gradient_push.GradientPushConfig(compressor="fixedk", p=0.2)
    assert method.state_fields_of(meth, plain) == meth.state_fields
    extra = method.state_fields_of(meth, comp)
    assert ("xhat", method.PLANE) in extra and ("s", method.PLANE) in extra
    x = {"w": jax.ShapeDtypeStruct((4, 7), jnp.float32)}
    # public copy + neighbour sum are WIRE PLANES: (n, rows, LANE) f32
    sds = method.state_shape_dtype(meth, x, comp)
    assert sds.xhat[0].shape == (4, 1, plane_mod.LANE)
    assert sds.s[0].shape == (4, 1, plane_mod.LANE)
    sds_plain = method.state_shape_dtype(meth, x, plain)
    assert sds_plain.xhat is None and sds_plain.s is None
    # wire accounting: compressed push transmits the p-fraction OF THE
    # PLANE + mass
    params = {"w": jnp.zeros((100,))}
    plane_d = plane_mod.ParamPlane.for_tree(params).padded_size   # 128
    assert meth.transmitted_elements(params, plain) == plane_d + 1
    assert meth.transmitted_elements(params, comp) == \
        sparsifier.num_kept(plane_d, 0.2) + 1
    bits = method.transmitted_bits(meth, params, comp)
    k = sparsifier.num_kept(plane_d, 0.2)
    assert bits == k * 32 + k * 7 + 32   # values + explicit idx + mass


@pytest.mark.parametrize("rounds", [2, 3])
def test_compressed_push_on_time_varying_schedules(rounds):
    """Replica-correct compressed push-sum runs on B-connected
    time-varying sequences (the pre-replica code had to REJECT them):
    sum x / sum w stays mass-conserved to float tolerance on every P(t),
    and with sigma=0 the per-node de-biased estimates converge to the
    exact initial mean."""
    from repro.core import gossip
    seq = gossip.sequence_by_name(f"matchings:{rounds}", 6, seed=0)
    cfg = gradient_push.GradientPushConfig(
        gamma=0.0, sigma=0.0, compressor="fixedk", p=0.4)
    sim = method.get("gradient-push").make_reference(seq, cfg)
    assert sim.replica_exact    # genuinely time-varying -> replica path
    rng = np.random.default_rng(1)
    stack = {"w": jnp.asarray(rng.normal(size=(6, 5)), jnp.float32)}
    mean0 = np.mean(np.asarray(stack["w"]), axis=0)
    state = sim.init(stack)
    zero_grad = lambda p, b: (jax.tree.map(jnp.zeros_like, p), 0.0)
    key = jax.random.PRNGKey(0)
    step = jax.jit(lambda s, k: sim.step(s, zero_grad, None, k))
    for t in range(240):
        key, sub = jax.random.split(key)
        state, _ = step(state, sub)
        if t % 60 == 0:   # conservation holds at EVERY step, not just the end
            cons = np.asarray(sim.consensus(state)["w"])
            np.testing.assert_allclose(cons, mean0, atol=1e-4)
    cons = np.asarray(sim.consensus(state)["w"])
    np.testing.assert_allclose(cons, mean0, atol=1e-4)
    # per-node de-biased estimates reach the exact mean (consensus)
    z = np.asarray(sim.eval_params(state)["w"])
    assert np.max(np.abs(z - mean0)) < 5e-3
    # uncompressed push-sum stays exact on time-varying sequences too
    method.get("gradient-push").make_reference(
        seq, gradient_push.GradientPushConfig())


def test_error_feedback_rejected_with_qsgd():
    """EF's p-scaling undoes the sparsifiers' 1/p amplification; the
    quantizer has none, so the combination would discard (1-p) of every
    update — reject it."""
    with pytest.raises(ValueError, match="sparsifier"):
        sdm_dsgd.SDMConfig(compressor="qsgd", error_feedback=True)


def test_new_family_rides_generic_payload_transport():
    """README's 'Adding a compressor' contract: a freshly registered
    family reaches SDM-DSGD with NO sdm_dsgd-side mapping — it resolves
    to mode='payload' and runs through gossip.exchange_payload."""
    import dataclasses as dc

    @jax.tree_util.register_static
    @dc.dataclass(frozen=True)
    class SignCompressor(compressor.Compressor):
        """1-bit sign + per-leaf l1/d magnitude (signSGD-style)."""
        name: str = dc.field(default="sign", init=False, repr=False)

        def compress(self, key, x, *, node=None):
            mag = jnp.mean(jnp.abs(x.astype(jnp.float32)))
            return compressor.Payload(
                values=jnp.sign(x).astype(jnp.int8), scale=mag,
                shape=tuple(x.shape), meta=("sign",))

        def decompress(self, pl):
            return pl.scale * pl.values.astype(jnp.float32)

        def wire_elements(self, shape, node=None):
            return int(np.prod(shape))

        def wire_bits(self, shape, *, value_bits=32, index_sync=False,
                      node=None):
            return int(np.prod(shape)) + 32

    compressor.register("sign", lambda p, arg=None: SignCompressor(p=p))
    try:
        cfg = sdm_dsgd.SDMConfig(compressor="sign", p=0.5, theta=0.4,
                                 gamma=0.1)
        assert cfg.mode == "payload"
        assert isinstance(sdm_dsgd.compressor_of(cfg), SignCompressor)
        params = {"w": jnp.zeros((64,))}
        # plane convention: the payload is the padded (1, LANE) plane
        assert sdm_dsgd.transmitted_bits_per_step(params, cfg) == \
            plane_mod.LANE + 32
        # a short reference run actually exercises the payload roundtrip
        sim = method.get("sdm-dsgd").make_reference(topology.ring(4), cfg)
        state = sim.init({"w": jnp.zeros((4, 8))})
        zero_grad = lambda p_, b: (jax.tree.map(jnp.zeros_like, p_), 0.0)
        for _ in range(3):
            state, _ = sim.step(state, zero_grad, None, jax.random.PRNGKey(0))
        assert all(bool(jnp.all(jnp.isfinite(x)))
                   for x in jax.tree.leaves(state.x))
    finally:
        compressor._FAMILIES.pop("sign", None)


def test_sdm_coercion_carries_compressor_to_push():
    sdm = sdm_dsgd.SDMConfig(compressor="fixedk:2", p=0.3, theta=0.4,
                             gamma=0.05, sigma=0.0)
    gp = method.get("gradient-push").coerce_config(sdm)
    assert gp.compressor == "fixedk:2" and gp.p == 0.3
    # legacy mode-only configs still coerce to uncompressed push-sum
    gp2 = method.get("gradient-push").coerce_config(
        sdm_dsgd.SDMConfig(mode="fixedk_packed", p=0.3, theta=0.4))
    assert gp2.compressor is None
