"""Exactness guarantee of replica-correct time-varying gossip.

Two halves of the PR-4 contract:

* PROPERTY: the time-varying SDM reference equals an EXPLICIT dense
  W(t) simulator — a from-scratch oracle that tracks only (x, d) and
  mixes with the full current-round matrix, no incremental state — for
  sequence lengths L in {2, 3}, dense and fixedk-packed payloads,
  homogeneous and heterogeneous per-node p.
* REGRESSION: static-schedule trajectories are byte-for-byte stable
  (golden loss values; regenerated ONCE at PR 5 when sparsifier draws
  moved to wire-plane granularity), so the replica machinery is provably
  elided on the fast path.

Plus unit coverage of the union-schedule compiler and the per-link
schedule-aware accounting it feeds.
"""
import pathlib
import sys
from fractions import Fraction

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (gossip, gradient_push, method as method_mod,
                        plane as plane_mod, sdm_dsgd, sparsifier, topology)

sys.path.insert(0, str(pathlib.Path(__file__).parent / "helpers"))
from dense_oracle import sdm_dense_wt_oracle  # noqa: E402

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# Union-schedule compiler.
# ---------------------------------------------------------------------------

def test_union_schedule_covers_every_round_edge():
    seq = gossip.sequence_by_name("matchings:3", 8, seed=1)
    useq = gossip.union_schedule(seq)
    assert useq.length == 3 and useq.n_nodes == 8
    union_edges = {e for rnd in useq.rounds for e in rnd.perm}
    for t, sched in enumerate(seq.schedules):
        for rnd in sched.rounds:
            for edge in rnd.perm:
                assert edge in union_edges
                # the union round carries round t's weight on that edge
                urnd = next(u for u in useq.rounds if u.shift == rnd.shift)
                dst = edge[1]
                assert urnd.recv_weights[t][dst] == rnd.recv_weights[dst]
    # replica slots: one per union shift; weights vanish on inactive rounds
    for urnd in useq.rounds:
        for t in range(3):
            active = {e[1] for r in seq.schedules[t].rounds
                      if r.shift == urnd.shift for e in r.perm}
            for dst in range(8):
                if dst not in active:
                    assert urnd.recv_weights[t][dst] == 0.0


def test_needs_replicas_and_weight_invariance():
    assert not gossip.needs_replicas(gossip.sequence_by_name("ring", 8))
    seq = gossip.sequence_by_name("matchings:2", 8, seed=0)
    assert gossip.needs_replicas(seq)
    # a repeated identical schedule is weight-invariant: replicas elided
    ring = gossip.sequence_by_name("ring", 8).schedules[0]
    rep = gossip.ScheduleSequence(name="ring-rep", n_nodes=8,
                                  schedules=(ring, ring))
    assert rep.length == 2 and not gossip.needs_replicas(rep)


def test_mean_out_degree():
    ring = gossip.sequence_by_name("ring", 8)
    assert gossip.mean_out_degree(ring) == 2
    seq = gossip.sequence_by_name("matchings:2", 8, seed=0)
    # perfect matchings: every node transmits on exactly one edge a round
    assert gossip.mean_out_degree(seq) == 1
    # the replica transport delivers over the union every round
    useq = gossip.union_schedule(seq)
    union_edges = sum(len(r.perm) for r in useq.rounds)
    assert gossip.mean_out_degree(seq, union=True) == \
        Fraction(union_edges, 8) > 1


# ---------------------------------------------------------------------------
# PROPERTY: reference == explicit dense W(t) oracle.
# ---------------------------------------------------------------------------

def _problem(n, dim=16, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(n, 8, dim)) / 3.0, jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)
    stack = {"w": jnp.asarray(rng.normal(size=(n, dim)) * 0.3, jnp.float32)}

    def grad_stack(x):
        return jax.vmap(lambda w, aa, bb: aa.T @ (aa @ w - bb) / 8.0)(
            x, a, b)

    return stack, grad_stack


@pytest.mark.parametrize("rounds", [2, 3])
@pytest.mark.parametrize("mode,het", [("bernoulli", False),
                                      ("fixedk_packed", False),
                                      ("fixedk_packed", True)])
def test_time_varying_reference_equals_dense_oracle(rounds, mode, het):
    n = 6
    seq = gossip.sequence_by_name(f"matchings:{rounds}", n, seed=rounds)
    p = tuple(0.2 + 0.1 * (i % 3) for i in range(n)) if het else 0.3
    cfg = sdm_dsgd.SDMConfig(p=p, theta=0.2, gamma=0.15, sigma=0.0,
                             mode=mode)
    sim = method_mod.get("sdm-dsgd").make_reference(seq, cfg)
    assert sim.replica_exact
    stack, grad_stack = _problem(n, seed=rounds)
    state = sim.init(stack)
    for _ in range(8):
        state, _ = sim.advance(state, KEY)
        state = sim.commit(state, {"w": grad_stack(state.x["w"])}, KEY)
    ref = np.asarray(state.x["w"])
    oracle = sdm_dense_wt_oracle(seq, cfg, stack["w"], grad_stack, 8, KEY)
    assert float(np.max(np.abs(ref - oracle))) <= 1e-6


# ---------------------------------------------------------------------------
# REGRESSION: static trajectories byte-for-byte unchanged from PR 3.
# ---------------------------------------------------------------------------

# Golden loss sequences on the deterministic micro-problem below; the
# replica machinery must be elided on static schedules so these
# reproduce EXACTLY. REGENERATED at PR 5: the wire-plane transport draws
# sparsifier bits at PLANE granularity (one draw over the padded
# (rows, LANE) buffer instead of per leaf), which — exactly like the
# PR-1 break when draws moved to the canonical LANE-padded shape —
# changed trajectories once; they are byte-stable from here on.
_GOLDEN = {
    "sdm_ring4_fixedk": ([0.8207862377, 0.8122178316, 0.789454937,
                          0.7885785699, 0.7895878553, 0.7811986804,
                          0.7827057838, 0.7814177275, 0.787466526,
                          0.7879382968], 1.2856959104537964),
    "sdm_ring4_bernoulli": ([0.8207862377, 0.8118773699, 0.8107442856,
                             0.8062922955, 0.7979011536, 0.7980082631,
                             0.7842214108, 0.7939969301, 0.807949543,
                             0.804894805], 1.2652111053466797),
    "gp_dring4_fixedk": ([0.8207862377, 0.7841868401, 0.7529057264,
                          0.7272599936, 0.7051187158, 0.686771512,
                          0.6751340628, 0.6677007675, 0.6625115871,
                          0.6598061323], 0.655038595199585),
}

_GOLDEN_CASES = {
    "sdm_ring4_fixedk": (
        "sdm-dsgd", lambda: topology.ring(4),
        lambda: sdm_dsgd.SDMConfig(p=0.3, theta=0.2, gamma=0.2, sigma=0.5,
                                   clip_c=2.0, mode="fixedk_packed")),
    "sdm_ring4_bernoulli": (
        "sdm-dsgd", lambda: topology.ring(4),
        lambda: sdm_dsgd.SDMConfig(p=0.3, theta=0.2, gamma=0.2, sigma=0.5,
                                   clip_c=2.0, mode="bernoulli")),
    "gp_dring4_fixedk": (
        "gradient-push", lambda: topology.directed_ring(4),
        lambda: gradient_push.GradientPushConfig(gamma=0.2,
                                                 compressor="fixedk", p=0.3)),
}


@pytest.mark.parametrize("name", sorted(_GOLDEN))
def test_static_trajectories_unchanged_from_pr3(name):
    meth_name, topo_fn, cfg_fn = _GOLDEN_CASES[name]
    meth = method_mod.get(meth_name)
    topo = topo_fn()
    sim = meth.make_reference(topo, meth.coerce_config(cfg_fn()))

    rng = np.random.default_rng(3)
    n, dim = 4, 24
    a = jnp.asarray(rng.normal(size=(n, 12, dim)) / 4.0, jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, 12)), jnp.float32)
    w0 = jnp.asarray(rng.normal(size=(dim,)) * 0.1, jnp.float32)
    stack = {"w": jnp.broadcast_to(w0, (n, dim))}

    def grad_fn(params, batch):
        del batch

        def one(w, aa, bb):
            r = aa @ w - bb
            return aa.T @ r / aa.shape[0], jnp.mean(r * r)

        g, loss = jax.vmap(one)(params["w"], a, b)
        return {"w": g}, jnp.mean(loss)

    state = sim.init(stack)
    losses = []
    for t in range(10):
        state, loss = sim.step(state, grad_fn, None, jax.random.fold_in(KEY, t))
        losses.append(float(loss))
    golden_losses, golden_csum = _GOLDEN[name]
    np.testing.assert_array_equal(np.float32(losses),
                                  np.float32(golden_losses))
    assert float(jnp.sum(sim.consensus(state)["w"])) == \
        pytest.approx(golden_csum, abs=0.0)


# ---------------------------------------------------------------------------
# Per-link schedule-aware accounting (satellite: mean over the L rounds).
# ---------------------------------------------------------------------------

def test_schedule_aware_accounting():
    params = {"w": jnp.zeros((100,))}
    cfg = sdm_dsgd.SDMConfig(p=0.3, theta=0.2, mode="fixedk_packed")
    # plane convention: the 100-element tree pads to one (1, LANE) plane
    # and ONE k = ceil(p * plane) ceil covers the whole tree
    d = plane_mod.ParamPlane.for_tree(params).padded_size
    assert d == plane_mod.LANE
    k = sparsifier.num_kept(d, 0.3)
    # legacy (no schedule): one payload per step, unchanged
    assert sdm_dsgd.transmitted_elements_per_step(params, cfg) == k
    # static ring: out-degree 2
    ring = gossip.sequence_by_name("ring", 8)
    assert sdm_dsgd.transmitted_elements_per_step(params, cfg,
                                                  seq=ring) == 2 * k
    # time-varying: the replica transport pays union-graph degree
    seq = gossip.sequence_by_name("matchings:2", 8, seed=0)
    udeg = gossip.mean_out_degree(seq, union=True)
    assert sdm_dsgd.transmitted_elements_per_step(params, cfg, seq=seq) == \
        round(k * udeg)
    assert sdm_dsgd.transmitted_bits_per_step(params, cfg, seq=seq) == \
        round(k * 32 * udeg)
    # full-state DSGD follows the CURRENT round's graph: matchings rounds
    # have out-degree 1 (vs the static ring's 2) — the mean over L rounds
    from repro.core import baselines
    dsgd = method_mod.get("dsgd")
    dcfg = baselines.DSGDConfig()
    assert method_mod.transmitted_elements(dsgd, params, dcfg,
                                           seq=ring) == 2 * d
    assert method_mod.transmitted_elements(dsgd, params, dcfg, seq=seq) == d
    # push-sum: compressed payload rides the union graph, the mass scalar
    # the current-round graph
    gp = method_mod.get("gradient-push")
    gcfg = gradient_push.GradientPushConfig(compressor="fixedk", p=0.3)
    assert method_mod.transmitted_elements(gp, params, gcfg, seq=seq) == \
        round(k * udeg + 1)
    # node=i uses the node's OWN out-degree where it varies: star hub
    # transmits on 3 out-edges, each leaf on 1 (network mean 3/2)
    star = gossip.sequence_by_name("star", 4)
    assert sdm_dsgd.transmitted_elements_per_step(params, cfg, 0,
                                                  seq=star) == 3 * k
    assert sdm_dsgd.transmitted_elements_per_step(params, cfg, 1,
                                                  seq=star) == k
    assert sdm_dsgd.transmitted_elements_per_step(params, cfg, seq=star) == \
        round(Fraction(3, 2) * k)


def test_union_schedule_rejects_duplicate_shifts():
    """A hand-built schedule with two same-shift rounds is legal for the
    static executors (they sum deliveries) but would silently drop one
    round's weights in the union weight table — must error instead."""
    ring = gossip.sequence_by_name("ring", 4).schedules[0]
    dup = gossip.PermuteSchedule(
        name="dup", n_nodes=4, self_weights=ring.self_weights,
        rounds=(ring.rounds[0], ring.rounds[0]))
    other = gossip.sequence_by_name("matchings:2", 4, seed=0).schedules[0]
    seq = gossip.ScheduleSequence(name="bad", n_nodes=4,
                                  schedules=(dup, other))
    with pytest.raises(ValueError, match="duplicate"):
        gossip.union_schedule(seq)


def test_het_p_mean_rounds_once():
    """Satellite: node=None het-p accounting takes the EXACT-Fraction
    mean and rounds once — per-node-round-then-round-again can drift."""
    # engineered so fractional halves survive the plane padding: the
    # 30-element tree pads to a LANE=128 plane, and p = k/256 budgets
    # give exact per-node counts of k/2 — .5 cases where round-per-node
    # vs round-the-mean visibly differ under half-even rounding.
    params = {"w": jnp.zeros((30,))}
    d = plane_mod.ParamPlane.for_tree(params).padded_size       # 128
    cfg = sdm_dsgd.SDMConfig(p=(0.33984375,) * 3, theta=0.1)
    exact = Fraction("0.33984375") * d      # 43.5 exactly
    assert sdm_dsgd.transmitted_elements_per_step(params, cfg) == round(exact)
    # a genuinely drifting case: exact per-node 19.5, 31.5, 43.5
    ps = (0.15234375, 0.24609375, 0.33984375)
    cfg2 = sdm_dsgd.SDMConfig(p=ps, theta=0.05)
    mean_exact = sum(Fraction(repr(p)) for p in ps) * d / 3   # 31.5 exactly
    got = sdm_dsgd.transmitted_elements_per_step(params, cfg2)
    assert got == round(mean_exact)
    # old convention: round each then round the mean — can differ from
    # the tree-level convention; the Fraction path CANNOT.
    per_node = [sdm_dsgd.transmitted_elements_per_step(params, cfg2, i)
                for i in range(3)]
    assert per_node == [round(Fraction(repr(p)) * d) for p in ps]
