"""Overlapped wire-compute transport: parity, oracle, and staleness.

``cfg.overlap=True`` double-buffers the wire planes so the exchange for
round t+1 rides under round t's gradient computation; neighbours mix
one-step-stale public copies. The "overlap" group of
helpers/method_parity_check.py (subprocess, 8 fake devices) checks, for
SDM-DSGD (dense / packed / qsgd / fused-qsgdf wire), the fused 2-buffer
executor, and compressed gradient-push:

  * reference executor == shard_map distributed executor (bit-close);
  * the SDM reference == an EXPLICIT dense delayed-mixing oracle
    (helpers/dense_oracle.sdm_dense_overlap_oracle) — the semantics are
    pinned from scratch, not against the implementation itself;
  * the compiled permute count does NOT grow vs the non-overlapped
    step (the buffer reuses the same exchange, one step early);
  * the trajectory genuinely DIVERGES from overlap=off under the same
    seed — the staleness is real, not a dead flag.

The virtual-clock side (round time max(compute, tx) instead of the sum)
is covered here directly via the in-process simulator.
"""
import pathlib
import subprocess
import sys

import pytest

HELPER = pathlib.Path(__file__).parent / "helpers" / "method_parity_check.py"
SRC = str(pathlib.Path(__file__).parent.parent / "src")


@pytest.mark.slow
def test_overlap_parity_sweep():
    out = subprocess.run(
        [sys.executable, str(HELPER), "overlap"], capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    cases = []
    for line in out.stdout.splitlines():
        if not line.startswith("CASE "):
            continue
        toks = line.split()
        case = {"id": toks[1]}
        for k, v in zip(toks[2::2], toks[3::2]):
            case[k] = v
        cases.append(case)
    assert len(cases) == 6, out.stdout
    for c in cases:
        err, scale = float(c["MAXERR"]), float(c["SCALE"])
        assert scale > 0.01, c           # the run actually moved
        tol = 1e-3 if "qsgd" in c["id"] else 1e-4
        assert err < tol * max(scale, 1.0), c
        assert c["HAS_CPERM"] == "True", c
        # same wire structure as overlap=off: no extra permutes
        assert int(c["CPERM"]) == int(c["EXPECTED_CPERM"]), c
        # one-step staleness changes the trajectory (> float-noise, well
        # below divergence — the consensus dynamics stay contractive)
        div = float(c["STALE_DIVERGENCE"])
        assert 1e-6 < div < 1.0, c
        if "ORACLE_MAXERR" in c:
            assert float(c["ORACLE_MAXERR"]) <= 1e-5, c
        if "WIRE_ELEMS" in c:
            assert c["WIRE_ELEMS"] == c["EXPECTED_WIRE_ELEMS"], c
            assert int(c["SORT_COUNT"]) <= int(c["MAX_SORTS"]), c


def test_sim_runner_overlap_hides_wire():
    """Virtual clock: with cfg.overlap a node's round time is
    max(compute, transmit) instead of the sum, so simulated seconds
    strictly drop whenever transmission is nonzero."""
    import jax
    import jax.numpy as jnp

    from repro.core import SDMConfig, topology
    from repro.data import classification_dataset, node_partitioned_batches
    from repro.models import vision_small
    from repro.sim import simulate

    n = 4
    (x_tr, y_tr), _ = classification_dataset(16, 4, 200, 40, seed=0)
    p0 = vision_small.mlr_init(jax.random.PRNGKey(0), 16, 4)
    stack = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n,) + p.shape), p0)
    grad_fn = vision_small.make_stacked_grad_fn(vision_small.mlr_apply)
    batches = node_partitioned_batches(x_tr, y_tr, n, 8, seed=0)

    def run(overlap):
        cfg = SDMConfig(p=0.4, theta=0.3, gamma=0.1, sigma=0.0,
                        clip_c=5.0, overlap=overlap)
        return simulate(topo=topology.ring(n), algorithm="sdm-dsgd",
                        sdm_cfg=cfg, params_stack=stack, grad_fn=grad_fn,
                        batches=batches, rounds=6, scenario="no-fault",
                        seed=0)

    r_off, r_on = run(False), run(True)
    assert r_on.sim_seconds < r_off.sim_seconds
    assert r_on.rounds == r_off.rounds == 6
