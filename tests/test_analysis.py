"""The static auditor audits itself.

Three layers:

* **in-process unit tests** of the two jaxpr analyzers on tiny traced
  functions (no collectives, so the 1-device pytest process suffices):
  key-reuse / clean-split discrimination, fold_in non-consumption,
  scan-invariant-key detection, and the padded-draw-shape rule.
* **the broken fixture** (tests/fixtures/broken_method.py), traced on a
  4-node fake mesh in a subprocess: the analyzer must report EXACTLY
  the two seeded findings — one ``tainted-collective`` (un-noised wire)
  and one ``key-reuse`` (noise key consumed twice) — and nothing else.
  This regression-proofs the PR-1 bug class end to end.
* **the CLI quick matrix** (``python -m repro.analysis --quick``): zero
  findings, zero new violations, exit 0 on clean main — the same gate
  CI runs over the full matrix.
"""
import json
import pathlib
import subprocess
import sys

import pytest

HELPER = pathlib.Path(__file__).parent / "helpers" / "analysis_check.py"
REPO = pathlib.Path(__file__).parent.parent
SRC = str(REPO / "src")
ENV = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
       "JAX_PLATFORMS": "cpu"}


# ---------------------------------------------------------------- unit layer

def _trace(fn, *args):
    import jax

    return jax.make_jaxpr(fn)(*args)


def _prng(fn, *args, **kw):
    from repro.analysis import prng_lint

    return prng_lint.analyze_prng(_trace(fn, *args), **kw)


def test_prng_clean_split_has_no_findings():
    import jax

    def good(key):
        k1, k2 = jax.random.split(key)
        return jax.random.normal(k1, (4,)) + jax.random.normal(k2, (4,))

    rep = _prng(good, jax.random.PRNGKey(0))
    assert rep["findings"] == []
    assert rep["n_draws"] == 2


def test_prng_flags_double_draw():
    import jax

    def bad(key):
        return jax.random.normal(key, (4,)) + jax.random.uniform(key, (4,))

    rep = _prng(bad, jax.random.PRNGKey(0))
    kinds = [f["kind"] for f in rep["findings"]]
    assert kinds == ["key-reuse"]


def test_prng_flags_draw_then_split():
    import jax

    def bad(key):
        x = jax.random.normal(key, (4,))
        k1, _ = jax.random.split(key)
        return x + jax.random.normal(k1, (4,))

    rep = _prng(bad, jax.random.PRNGKey(0))
    kinds = [f["kind"] for f in rep["findings"]]
    assert kinds == ["key-reuse"]


def test_prng_fold_in_children_are_distinct():
    import jax

    def good(key):
        a = jax.random.normal(jax.random.fold_in(key, 0), (4,))
        b = jax.random.normal(jax.random.fold_in(key, 1), (4,))
        return a + b

    assert _prng(good, jax.random.PRNGKey(0))["findings"] == []


def test_prng_reconstructed_fold_is_reuse():
    import jax

    def bad(key):
        # two independent reconstructions of the SAME derived key
        a = jax.random.normal(jax.random.fold_in(key, 3), (4,))
        b = jax.random.normal(jax.random.fold_in(key, 3), (4,))
        return a + b

    kinds = [f["kind"] for f in _prng(bad, jax.random.PRNGKey(0))["findings"]]
    assert kinds == ["key-reuse"]


def test_prng_scan_invariant_key_flagged():
    import jax

    def bad(key):
        def body(c, _):
            return c + jax.random.normal(key, ()), None

        out, _ = jax.lax.scan(body, 0.0, None, length=3)
        return out

    kinds = [f["kind"] for f in _prng(bad, jax.random.PRNGKey(0))["findings"]]
    assert "scan-invariant-key" in kinds


def test_prng_loop_folded_key_is_clean():
    import jax

    def good(key):
        def body(c, i):
            return c + jax.random.normal(jax.random.fold_in(key, i), ()), None

        import jax.numpy as jnp

        out, _ = jax.lax.scan(body, 0.0, jnp.arange(3))
        return out

    assert _prng(good, jax.random.PRNGKey(0))["findings"] == []


def test_prng_padded_draw_shape():
    import jax

    def bad(key):
        return jax.random.normal(key, (4, 128))

    rep = _prng(bad, jax.random.PRNGKey(0), allowed_shapes=[(2, 128)])
    kinds = [f["kind"] for f in rep["findings"]]
    assert "padded-draw-shape" in kinds
    # the canonical shape itself is fine
    def good(key):
        return jax.random.normal(key, (2, 128))

    assert _prng(good, jax.random.PRNGKey(0),
                 allowed_shapes=[(2, 128)])["findings"] == []


def test_taint_sanitize_clears_and_release_is_recorded():
    import jax

    from repro.analysis import jaxpr_taint
    from repro.core import tagging

    def step(x, data):
        g = data * x
        g = tagging.sanitize(g)
        loss = tagging.declared_release((data ** 2).sum(), label="loss")
        return g.sum() + loss

    import jax.numpy as jnp

    jaxpr = jax.make_jaxpr(step)(jnp.ones(4), jnp.ones(4))
    rep = jaxpr_taint.analyze_taint(jaxpr, {1: "data"})
    assert rep["findings"] == []
    assert rep["n_sanitize_sites"] == 1
    assert [r["label"] for r in rep["releases"]] == ["loss"]


def test_expected_permutes_contract():
    from repro.analysis import wire_audit
    from repro.core import gossip, topology

    ring = gossip.ensure_sequence(
        gossip.schedule_from_topology(topology.ring(4)))
    r = ring.schedules[0].n_rounds
    assert wire_audit.expected_permutes("sdm-dsgd", "bernoulli", ring) == r
    assert wire_audit.expected_permutes("sdm-dsgd", "qsgd:4", ring) == 2 * r
    assert wire_audit.expected_permutes("allreduce", "-", ring) == 0
    assert wire_audit.expected_permutes("gradient-push", "fixedk", ring) \
        == 3 * r


# ------------------------------------------------------------- fixture layer

@pytest.mark.slow
def test_broken_fixture_flags_exactly_the_seeded_bugs():
    out = subprocess.run([sys.executable, str(HELPER)], capture_output=True,
                         text=True, env=ENV, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rep = json.loads(out.stdout.splitlines()[-1])

    taint_kinds = [f["kind"] for f in rep["taint"]]
    prng_kinds = [f["kind"] for f in rep["prng"]]
    assert taint_kinds == ["tainted-collective"], rep["taint"]
    assert prng_kinds == ["key-reuse"], rep["prng"]
    # both events of the reuse land in the fixture, not the library
    events = rep["prng"][0]["events"]
    assert len(events) == 2
    assert all("broken_method.py" in e for e in events)
    # nothing pretended to sanitize
    assert rep["n_sanitize_sites"] == 0
    assert rep["n_draws"] == 2


# ----------------------------------------------------------------- CLI layer

@pytest.mark.slow
def test_cli_quick_matrix_is_clean(tmp_path):
    report = tmp_path / "LINT_report.json"
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--quick", "--devices", "4",
         "--out", str(report)],
        capture_output=True, text=True, env=ENV, timeout=1200)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-3000:]
    rep = json.loads(report.read_text())
    assert rep["new_violations"] == []
    assert rep["summary"]["fail"] == 0 and rep["summary"]["error"] == 0
    assert rep["summary"]["pass"] == rep["n_configs"] > 0
    # privacy-claiming configs each sanitized exactly once and declared
    # exactly one release (the loss metric)
    for row in rep["configs"]:
        if not row["expect_taint"]:
            assert row["n_sanitize_sites"] == 1, row["id"]
            assert len(row["releases"]) == 1, row["id"]
