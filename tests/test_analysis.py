"""The static auditor audits itself.

Three layers:

* **in-process unit tests** of the jaxpr analyzers on tiny traced
  functions (no collectives, so the 1-device pytest process suffices):
  key-reuse / clean-split discrimination, fold_in non-consumption,
  scan-invariant-key detection, the padded-draw-shape rule, the
  sensitivity certifier's bound propagation, the noise-scale extractor,
  the overlap token pass, and the integer-range certificate.
* **the fixtures**, each traced on a 4-node fake mesh in a subprocess:
  - tests/fixtures/broken_method.py: the QUALITATIVE analyzer must
    report EXACTLY the two seeded findings — one ``tainted-collective``
    (un-noised wire) and one ``key-reuse`` (noise key consumed twice) —
    and nothing else (the PR-1 bug class end to end);
  - tests/fixtures/miscalibrated_method.py: qualitatively clean, but
    the QUANTITATIVE certifier must report exactly one
    ``unclipped-sanitize`` and one ``noise-scale-mismatch``.
* **the CLI quick matrix** (``python -m repro.analysis --quick``): zero
  findings, zero new violations, exit 0 on clean main — the same gate
  CI runs over the full matrix — plus the per-config privacy
  certificate block and the ``--only``/``--pass`` selectors.
"""
import json
import pathlib
import subprocess
import sys

import pytest

HELPER = pathlib.Path(__file__).parent / "helpers" / "analysis_check.py"
CERT_HELPER = pathlib.Path(__file__).parent / "helpers" / "certifier_check.py"
REPO = pathlib.Path(__file__).parent.parent
SRC = str(REPO / "src")
ENV = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
       "JAX_PLATFORMS": "cpu"}


# ---------------------------------------------------------------- unit layer

def _trace(fn, *args):
    import jax

    return jax.make_jaxpr(fn)(*args)


def _prng(fn, *args, **kw):
    from repro.analysis import prng_lint

    return prng_lint.analyze_prng(_trace(fn, *args), **kw)


def test_prng_clean_split_has_no_findings():
    import jax

    def good(key):
        k1, k2 = jax.random.split(key)
        return jax.random.normal(k1, (4,)) + jax.random.normal(k2, (4,))

    rep = _prng(good, jax.random.PRNGKey(0))
    assert rep["findings"] == []
    assert rep["n_draws"] == 2


def test_prng_flags_double_draw():
    import jax

    def bad(key):
        return jax.random.normal(key, (4,)) + jax.random.uniform(key, (4,))

    rep = _prng(bad, jax.random.PRNGKey(0))
    kinds = [f["kind"] for f in rep["findings"]]
    assert kinds == ["key-reuse"]


def test_prng_flags_draw_then_split():
    import jax

    def bad(key):
        x = jax.random.normal(key, (4,))
        k1, _ = jax.random.split(key)
        return x + jax.random.normal(k1, (4,))

    rep = _prng(bad, jax.random.PRNGKey(0))
    kinds = [f["kind"] for f in rep["findings"]]
    assert kinds == ["key-reuse"]


def test_prng_fold_in_children_are_distinct():
    import jax

    def good(key):
        a = jax.random.normal(jax.random.fold_in(key, 0), (4,))
        b = jax.random.normal(jax.random.fold_in(key, 1), (4,))
        return a + b

    assert _prng(good, jax.random.PRNGKey(0))["findings"] == []


def test_prng_reconstructed_fold_is_reuse():
    import jax

    def bad(key):
        # two independent reconstructions of the SAME derived key
        a = jax.random.normal(jax.random.fold_in(key, 3), (4,))
        b = jax.random.normal(jax.random.fold_in(key, 3), (4,))
        return a + b

    kinds = [f["kind"] for f in _prng(bad, jax.random.PRNGKey(0))["findings"]]
    assert kinds == ["key-reuse"]


def test_prng_scan_invariant_key_flagged():
    import jax

    def bad(key):
        def body(c, _):
            return c + jax.random.normal(key, ()), None

        out, _ = jax.lax.scan(body, 0.0, None, length=3)
        return out

    kinds = [f["kind"] for f in _prng(bad, jax.random.PRNGKey(0))["findings"]]
    assert "scan-invariant-key" in kinds


def test_prng_loop_folded_key_is_clean():
    import jax

    def good(key):
        def body(c, i):
            return c + jax.random.normal(jax.random.fold_in(key, i), ()), None

        import jax.numpy as jnp

        out, _ = jax.lax.scan(body, 0.0, jnp.arange(3))
        return out

    assert _prng(good, jax.random.PRNGKey(0))["findings"] == []


def test_prng_padded_draw_shape():
    import jax

    def bad(key):
        return jax.random.normal(key, (4, 128))

    rep = _prng(bad, jax.random.PRNGKey(0), allowed_shapes=[(2, 128)])
    kinds = [f["kind"] for f in rep["findings"]]
    assert "padded-draw-shape" in kinds
    # the canonical shape itself is fine
    def good(key):
        return jax.random.normal(key, (2, 128))

    assert _prng(good, jax.random.PRNGKey(0),
                 allowed_shapes=[(2, 128)])["findings"] == []


def test_taint_sanitize_clears_and_release_is_recorded():
    import jax

    from repro.analysis import jaxpr_taint
    from repro.core import tagging

    def step(x, data):
        g = data * x
        g = tagging.sanitize(g)
        loss = tagging.declared_release((data ** 2).sum(), label="loss")
        return g.sum() + loss

    import jax.numpy as jnp

    jaxpr = jax.make_jaxpr(step)(jnp.ones(4), jnp.ones(4))
    rep = jaxpr_taint.analyze_taint(jaxpr, {1: "data"})
    assert rep["findings"] == []
    assert rep["n_sanitize_sites"] == 1
    assert [r["label"] for r in rep["releases"]] == ["loss"]


def test_expected_permutes_contract():
    from repro.analysis import wire_audit
    from repro.core import gossip, topology

    ring = gossip.ensure_sequence(
        gossip.schedule_from_topology(topology.ring(4)))
    r = ring.schedules[0].n_rounds
    assert wire_audit.expected_permutes("sdm-dsgd", "bernoulli", ring) == r
    assert wire_audit.expected_permutes("sdm-dsgd", "qsgd:4", ring) == 2 * r
    assert wire_audit.expected_permutes("allreduce", "-", ring) == 0
    assert wire_audit.expected_permutes("gradient-push", "fixedk", ring) \
        == 3 * r


# ------------------------------------------------- certifier unit layer

def test_sensitivity_clean_clip_noise_sanitize():
    import jax

    from repro.analysis import sensitivity
    from repro.core import clipping, tagging

    def step(x, data, key):
        g = data * x
        g = clipping.clip_tree(g, 0.5)
        g = g + 0.5 * jax.random.normal(key, g.shape)
        g = tagging.sanitize(g)
        return tagging.wire_payload(g)

    import jax.numpy as jnp

    jaxpr = jax.make_jaxpr(step)(jnp.ones(4), jnp.ones(4),
                                 jax.random.PRNGKey(0))
    rep = sensitivity.analyze_sensitivity(jaxpr, {1: "data"}, clip_c=0.5)
    assert rep["findings"] == []
    (site,) = rep["sanitize_sites"]
    assert site["coord_bound"] == pytest.approx(0.5)
    assert site["l2_bound"] == pytest.approx(0.5 * 2.0)   # sqrt(4) coords
    assert rep["wire_coord_bound"] == 0.0


def test_sensitivity_flags_unclipped_and_exceeding():
    import jax
    import jax.numpy as jnp

    from repro.analysis import sensitivity
    from repro.core import clipping, tagging

    def unclipped(x, data, key):
        g = data * x
        return tagging.sanitize(g + jax.random.normal(key, g.shape))

    jaxpr = jax.make_jaxpr(unclipped)(jnp.ones(4), jnp.ones(4),
                                      jax.random.PRNGKey(0))
    rep = sensitivity.analyze_sensitivity(jaxpr, {1: "data"}, clip_c=0.5)
    assert [f["kind"] for f in rep["findings"]] == ["unclipped-sanitize"]

    def exceeding(x, data, key):
        a = clipping.clip_tree(data * x, 0.5)
        b = clipping.clip_tree(data + x, 0.5)
        return tagging.sanitize(a + b + jax.random.normal(key, a.shape))

    jaxpr = jax.make_jaxpr(exceeding)(jnp.ones(4), jnp.ones(4),
                                      jax.random.PRNGKey(0))
    rep = sensitivity.analyze_sensitivity(jaxpr, {1: "data"}, clip_c=0.5)
    kinds = [f["kind"] for f in rep["findings"]]
    assert kinds == ["sensitivity-exceeds-clip"], rep["findings"]
    assert rep["findings"][0]["bound"] == pytest.approx(1.0)


def test_sensitivity_flags_clip_mismatch_and_wire():
    import jax
    import jax.numpy as jnp

    from repro.analysis import sensitivity
    from repro.core import clipping, tagging

    def step(x, data):
        g = clipping.clip_tree(data * x, 0.3)     # config says 0.5
        return tagging.wire_payload(g)            # pre-noise on the wire

    jaxpr = jax.make_jaxpr(step)(jnp.ones(4), jnp.ones(4))
    rep = sensitivity.analyze_sensitivity(jaxpr, {1: "data"}, clip_c=0.5)
    kinds = sorted(f["kind"] for f in rep["findings"])
    assert kinds == ["clip-bound-mismatch", "wire-sensitivity"]
    assert rep["wire_coord_bound"] == pytest.approx(0.3)


def test_calibration_extracts_and_cross_checks_sigma():
    import jax
    import jax.numpy as jnp

    from repro.analysis import calibration
    from repro.core import tagging

    def noisy(x, key):
        return tagging.sanitize(x + 2.0 * jax.random.normal(key, x.shape))

    jaxpr = jax.make_jaxpr(noisy)(jnp.ones(4), jax.random.PRNGKey(0))
    rep = calibration.analyze_calibration(jaxpr, expected_sigma=2.0,
                                          expected_clip=None)
    assert rep["findings"] == []
    (site,) = rep["sanitize_sites"]
    assert site["extracted_sigma"] == pytest.approx(2.0, rel=1e-4)

    rep = calibration.analyze_calibration(jaxpr, expected_sigma=1.0,
                                          expected_clip=None)
    assert [f["kind"] for f in rep["findings"]] == ["noise-scale-mismatch"]
    assert rep["findings"][0]["accountant_sigma"] == 1.0


def test_calibration_flags_missing_noise():
    import jax
    import jax.numpy as jnp

    from repro.analysis import calibration
    from repro.core import tagging

    def no_noise(x):
        return tagging.sanitize(x * 3.0)   # sanitize with no Gaussian

    jaxpr = jax.make_jaxpr(no_noise)(jnp.ones(4))
    rep = calibration.analyze_calibration(jaxpr, expected_sigma=1.0,
                                          expected_clip=None)
    assert [f["kind"] for f in rep["findings"]] == ["noise-scale-unextracted"]

    def no_sanitize(x):
        return x * 3.0

    jaxpr = jax.make_jaxpr(no_sanitize)(jnp.ones(4))
    rep = calibration.analyze_calibration(jaxpr, expected_sigma=1.0,
                                          expected_clip=None)
    assert [f["kind"] for f in rep["findings"]] == ["missing-noise"]


def _overlap_report(body, overlap=True):
    import jax
    import jax.numpy as jnp

    from repro.analysis import calibration

    def train(x0, nb0):
        return jax.lax.scan(body, (x0, nb0), None, length=3)

    jaxpr = jax.make_jaxpr(train)(jnp.ones(4), jnp.zeros(4))
    return calibration.analyze_overlap(jaxpr, overlap=overlap)


def test_overlap_one_step_stale_buffer_is_ok():
    from repro.core import tagging

    def body(c, _):
        x, nb = c
        fresh = tagging.pending_buffer(x * 0.5)   # this round's exchange
        x = x + nb                                # consume LAST round's
        return (x, fresh), None

    rep = _overlap_report(body)
    assert rep["findings"] == []
    assert rep["verdict"] == "ok"
    assert rep["n_pending"] == 1


def test_overlap_same_round_read_is_flagged():
    from repro.core import tagging

    def body(c, _):
        x, nb = c
        fresh = tagging.pending_buffer(x * 0.5)
        x = x + fresh                             # staleness 0, not 1
        return (x, fresh), None

    rep = _overlap_report(body)
    assert rep["verdict"] == "hazard"
    assert "pending-same-round-read" in {f["kind"] for f in rep["findings"]}


def test_overlap_dropped_buffer_is_flagged():
    from repro.core import tagging

    def body(c, _):
        x, nb = c
        tagging.pending_buffer(x * 0.5)           # minted, never carried
        return (x + nb, nb), None

    rep = _overlap_report(body)
    assert rep["verdict"] == "hazard"
    assert "pending-not-carried" in {f["kind"] for f in rep["findings"]}


def test_overlap_self_dependence_is_flagged():
    from repro.core import tagging

    def body(c, _):
        x, nb = c
        fresh = tagging.pending_buffer(nb * 0.5)  # depends on the OLD one
        return (x + nb, fresh), None

    rep = _overlap_report(body)
    assert rep["verdict"] == "hazard"
    assert "pending-self-dependence" in {f["kind"] for f in rep["findings"]}


def test_overlap_tag_discipline():
    from repro.core import tagging

    def untagged(c, _):
        x, nb = c
        return (x + nb, x * 0.5), None

    rep = _overlap_report(untagged, overlap=True)
    assert [f["kind"] for f in rep["findings"]] == ["overlap-untagged"]

    def tagged(c, _):
        x, nb = c
        fresh = tagging.pending_buffer(x * 0.5)
        return (x + nb, fresh), None

    rep = _overlap_report(tagged, overlap=False)
    assert [f["kind"] for f in rep["findings"]] == ["pending-without-overlap"]


def test_qsgd_range_certificate():
    from repro.analysis import sensitivity

    for bits, fused in ((2, True), (4, True), (4, False), (8, True)):
        cert = sensitivity.qsgd_range_certificate(
            bits, fused=fused, plane_elems=256)
        assert cert["findings"] == [], (bits, fused)
        assert cert["wire_dtype"] == "u8"
    cert = sensitivity.qsgd_range_certificate(8, fused=False,
                                              plane_elems=256)
    assert cert["findings"] == []
    assert cert["wire_dtype"] == "s8"
    assert cert["q_range"] == [-127.0, 127.0]
    # 4-bit fused: two fields per byte + the 4 norm tail bytes
    cert = sensitivity.qsgd_range_certificate(4, fused=True,
                                              plane_elems=256)
    assert cert["payload_bytes"] == 256 // 2 + 4
    # a broken quantizer (levels beyond the representable field) FAILS
    cert = sensitivity.qsgd_range_certificate(8, fused=False,
                                              plane_elems=256, levels=200)
    assert [f["kind"] for f in cert["findings"]] == [
        "int-range-overflow", "int-range-overflow"]


# ------------------------------------------------------------- fixture layer

@pytest.mark.slow
def test_broken_fixture_flags_exactly_the_seeded_bugs():
    out = subprocess.run([sys.executable, str(HELPER)], capture_output=True,
                         text=True, env=ENV, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rep = json.loads(out.stdout.splitlines()[-1])

    taint_kinds = [f["kind"] for f in rep["taint"]]
    prng_kinds = [f["kind"] for f in rep["prng"]]
    assert taint_kinds == ["tainted-collective"], rep["taint"]
    assert prng_kinds == ["key-reuse"], rep["prng"]
    # both events of the reuse land in the fixture, not the library
    events = rep["prng"][0]["events"]
    assert len(events) == 2
    assert all("broken_method.py" in e for e in events)
    # nothing pretended to sanitize
    assert rep["n_sanitize_sites"] == 0
    assert rep["n_draws"] == 2


@pytest.mark.slow
def test_miscalibrated_fixture_flags_exactly_the_seeded_bugs():
    out = subprocess.run([sys.executable, str(CERT_HELPER)],
                         capture_output=True, text=True, env=ENV,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rep = json.loads(out.stdout.splitlines()[-1])

    # qualitatively CLEAN: the wire is tagged, keys split, no reuse —
    # the taint/prng/overlap passes must stay silent...
    assert rep["taint"] == [], rep["taint"]
    assert rep["prng"] == [], rep["prng"]
    assert rep["overlap"] == [], rep["overlap"]
    # ...while the QUANTITATIVE certifier reports exactly the two
    # seeded miscalibrations, both anchored in the fixture's trace.
    sens_kinds = [f["kind"] for f in rep["sensitivity"]]
    calib_kinds = [f["kind"] for f in rep["calibration"]]
    assert sens_kinds == ["unclipped-sanitize"], rep["sensitivity"]
    assert calib_kinds == ["noise-scale-mismatch"], rep["calibration"]
    (mismatch,) = rep["calibration"]
    assert mismatch["accountant_sigma"] == 1.0
    assert mismatch["jaxpr_sigma"] == [pytest.approx(1.3, rel=1e-4)]
    # the certificate still extracts the constants it DID find
    (noise,) = rep["extracted_noise"]
    assert noise["extracted_sigma"] == pytest.approx(1.3, rel=1e-4)
    (clip,) = rep["clip_sites"]
    assert clip["bound"] == 1.0
    (bound,) = rep["sanitize_bounds"]
    assert bound["coord_bound"] is None     # unbounded: the seeded bug


# ----------------------------------------------------------------- CLI layer

@pytest.mark.slow
def test_cli_quick_matrix_is_clean(tmp_path):
    report = tmp_path / "LINT_report.json"
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--quick", "--devices", "4",
         "--out", str(report)],
        capture_output=True, text=True, env=ENV, timeout=1200)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-3000:]
    rep = json.loads(report.read_text())
    assert rep["new_violations"] == []
    assert rep["summary"]["fail"] == 0 and rep["summary"]["error"] == 0
    assert rep["summary"]["pass"] == rep["n_configs"] > 0
    # privacy-claiming configs each sanitized exactly once and declared
    # exactly one release (the loss metric)
    for row in rep["configs"]:
        if not row["expect_taint"]:
            assert row["n_sanitize_sites"] == 1, row["id"]
            assert len(row["releases"]) == 1, row["id"]
    # the privacy certificate: per-config quantitative constants
    for row in rep["configs"]:
        cert = row["certificate"]
        acc = cert["accountant"]
        if row["expect_taint"]:
            continue
        # proved sensitivity at the sanitize site == the declared C
        (site,) = cert["sanitize_bounds"]
        assert site["coord_bound"] == pytest.approx(acc["clip_c"]), row["id"]
        assert site["l2_bound"] == pytest.approx(acc["G"]), row["id"]
        # extracted noise std == the accountant's sigma
        (noise,) = cert["extracted_noise"]
        assert noise["extracted_sigma"] == pytest.approx(
            acc["sigma"], rel=1e-4), row["id"]
        # nothing data-dependent on the wire after sanitization
        assert cert["wire_coord_bound"] == 0.0, row["id"]
        # overlap configs prove the one-step-stale double buffer
        expect_verdict = "ok" if "+ov" in row["id"] else "n/a"
        assert cert["overlap"]["verdict"] == expect_verdict, row["id"]
        if "qsgd" in row["id"]:
            assert cert["integer_ranges"] is not None, row["id"]


@pytest.mark.slow
def test_cli_selectors(tmp_path):
    report = tmp_path / "LINT_report.json"
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--devices", "4",
         "--only", "sdm-dsgd/ring4/fixedk_packed/sigma1",
         "--pass", "sensitivity", "--pass", "calibration",
         "--out", str(report)],
        capture_output=True, text=True, env=ENV, timeout=1200)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-3000:]
    rep = json.loads(report.read_text())
    assert rep["passes"] == ["sensitivity", "calibration"]
    (row,) = rep["configs"]
    assert row["id"] == "sdm-dsgd/ring4/fixedk_packed/sigma1"
    assert row["passes"] == ["calibration", "sensitivity"]
    # selected passes ran and proved their constants...
    assert row["certificate"]["sanitize_bounds"], row
    assert row["certificate"]["extracted_noise"], row
    # ...unselected passes stayed off
    assert row["taint"] == [] and row["n_sanitize_sites"] == 0
    assert row["certificate"]["overlap"] is None
    # sharding partitions the matrix without overlap
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--devices", "4",
         "--quick", "--shard", "1/2", "--pass", "wire",
         "--out", str(report)],
        capture_output=True, text=True, env=ENV, timeout=1200)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-3000:]
    rep = json.loads(report.read_text())
    assert rep["shard"] == "1/2"
    assert 0 < rep["n_configs"] < 8    # a strict subset of the quick set
