"""Dry-run integration smoke: one cheap (arch x shape) per step kind
lowers + compiles on the 512-device production mesh, in a subprocess
(XLA device-count faking must precede jax init)."""
import pathlib
import subprocess
import sys

import pytest

SRC = str(pathlib.Path(__file__).parent.parent / "src")

SCRIPT = """
import sys
from repro.launch.dryrun import build_case
rec = build_case({arch!r}, {shape!r}, "single_pod", "sdm_dsgd_fused",
                 "fixedk_rows", out_root="", verbose=False, probes=False)
assert rec["status"] == "ok", rec
assert rec["n_devices"] == 256
assert rec["flops"] > 0 and rec["collective_bytes"]["total"] > 0
assert rec["memory"]["peak_memory_in_bytes"] > 0
print("DRYRUN_OK", rec["arch"], rec["shape"])
"""


def _run(arch, shape):
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(arch=arch, shape=shape)],
        capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DRYRUN_OK" in out.stdout


@pytest.mark.slow
def test_dryrun_decode_case():
    _run("rwkv6-3b", "long_500k")   # cheapest decode case


PAIR_SCRIPT = """
from repro.launch.dryrun import build_case
rec = build_case("gemma2-2b", "train_4k", {mesh!r}, {method!r}, "bernoulli",
                 out_root="", verbose=False, probes=False, smoke=True,
                 compressor={comp!r}, topology={topology!r})
assert rec["status"] == "ok", rec
print("PAIR_OK", {method!r}, {comp!r}, {topology!r})
"""


@pytest.mark.slow
@pytest.mark.parametrize("method,comp,topology,mesh", [
    ("gradient-push", "fixedk", "ring", "1x1"),  # compressed push-sum state
    ("sdm-dsgd", "qsgd:8", "ring", "1x1"),       # int8 payload transport
    ("sdm-dsgd-fused", "block:128", "ring", "1x1"),  # block gran, fused step
    ("dsgd", "fixedk", "ring", "1x1"),     # compressor ignored by full-state
    # time-varying replica transport: the union-exchange path (no
    # lax.switch on delivery; REPLICA state leaves) must stay lowerable
    # on the container jax's full-manual shard_map fallback — needs a
    # real multi-node mesh, a 1-node mesh degenerates matchings away
    ("sdm-dsgd", "fixedk", "matchings:2", "4x1"),
])
def test_dryrun_method_compressor_pair(method, comp, topology, mesh):
    """The CI (method x compressor) loop's representative pairs: every
    pair must at least lower + compile on the smoke mesh."""
    out = subprocess.run(
        [sys.executable, "-c", PAIR_SCRIPT.format(
            method=method, comp=comp, topology=topology, mesh=mesh)],
        capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PAIR_OK" in out.stdout


@pytest.mark.slow
def test_dryrun_skip_case():
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.launch.dryrun import build_case;"
         "rec = build_case('phi3-medium-14b','long_500k','single_pod',"
         "'sdm_dsgd','bernoulli',out_root='',verbose=False,probes=False);"
         "assert rec['status']=='skipped', rec; print('SKIP_OK')"],
        capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SKIP_OK" in out.stdout
