"""Beyond-paper extension: error-feedback sparsification (EF-SDM-DSGD)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sdm_dsgd, topology

N, DIM = 8, 12


def _problem(seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(N, 32, DIM)) / np.sqrt(32)
    x_true = rng.normal(size=(DIM,))
    b = A @ x_true + 0.01 * rng.normal(size=(N, 32))
    return jnp.asarray(A, jnp.float32), jnp.asarray(b, jnp.float32), x_true


A_S, B_S, X_TRUE = _problem()


def grad_fn(params_stack, batch):
    del batch

    def one(a, b, x):
        return a.T @ (a @ x - b) / a.shape[0]

    g = jax.vmap(one)(A_S, B_S, params_stack["w"])
    loss = jnp.mean((jnp.einsum("nbd,nd->nb", A_S, params_stack["w"])
                     - B_S) ** 2)
    return {"w": g}, loss


def _run(cfg, steps=700, seed=0):
    topo = topology.ring(N)
    sim = sdm_dsgd.ReferenceSimulator(topo, cfg)
    state = sim.init({"w": jnp.zeros((N, DIM))})
    key = jax.random.PRNGKey(seed)

    @jax.jit
    def body(state, key):
        return sim.step(state, grad_fn, None, key)

    for _ in range(steps):
        key, sub = jax.random.split(key)
        state, loss = body(state, sub)
    return float(loss), state


def test_ef_instability_documents_why_the_paper_needs_unbiasedness():
    """NEGATIVE RESULT (kept as a regression-pinned finding): error
    feedback with a contractive mask*d compressor is UNSTABLE inside
    differential-coded gossip. Unlike plain EF-SGD (gradient-only), the
    SDM-DSGD differential d = theta*(Wx - x - gamma*g) carries the
    CONSENSUS correction; p-scaling it slows mixing ~p-fold while
    disagreement keeps being injected, so the residual accumulates and
    the iterates drift. This is structural support for the paper's
    insistence on UNBIASED sparsification (Definition 2 + Lemma 1):
    short horizons look fine, long horizons diverge.
    """
    base = dict(p=0.05, theta=0.1, gamma=0.3)
    short, _ = _run(sdm_dsgd.SDMConfig(error_feedback=True, **base),
                    steps=400, seed=0)
    long_, state = _run(sdm_dsgd.SDMConfig(error_feedback=True, **base),
                        steps=2500, seed=0)
    assert np.isfinite(short) and short < 4.0      # short horizon: trains
    assert long_ > 2 * short                        # long horizon: drifts
    # the same budget with the paper's unbiased sparsifier stays stable
    stable, _ = _run(sdm_dsgd.SDMConfig(**base), steps=2500, seed=0)
    assert np.isfinite(stable) and stable < 0.5


def test_ef_state_threading():
    cfg = sdm_dsgd.SDMConfig(p=0.25, theta=0.2, gamma=0.1,
                             error_feedback=True)
    _, state = _run(cfg, steps=5)
    assert state.e is not None
    # residual is nonzero after sparsified rounds
    assert float(jnp.abs(state.e["w"]).max()) > 0

    cfg2 = sdm_dsgd.SDMConfig(p=0.25, theta=0.2, gamma=0.1)
    _, state2 = _run(cfg2, steps=5)
    assert state2.e is None


def test_ef_identity_at_p1():
    """With p=1 nothing is dropped; EF residual stays exactly zero."""
    cfg = sdm_dsgd.SDMConfig(p=1.0, theta=0.5, gamma=0.1,
                             error_feedback=True)
    _, state = _run(cfg, steps=5)
    np.testing.assert_allclose(np.asarray(state.e["w"]), 0.0, atol=1e-7)
