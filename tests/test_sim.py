"""Edge-fleet simulator: determinism, fault semantics, time model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PrivacyParams, SDMConfig, topology
from repro.data import classification_dataset, node_partitioned_batches
from repro.models import vision_small
from repro.sim import (Distribution, EventQueue, Fleet, FleetSpec,
                       SCENARIOS, VirtualClock, parse_scenario, simulate)

N = 6


def _testbed(seed=0):
    topo = topology.ring(N)
    (xtr, ytr), _ = classification_dataset(16, 3, 600, 100, seed=seed)
    p0 = vision_small.mlr_init(jax.random.PRNGKey(seed), 16, 3)
    stack = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (N,) + p.shape), p0)
    grad_fn = vision_small.make_stacked_grad_fn(vision_small.mlr_apply)
    batches = node_partitioned_batches(xtr, ytr, N, 8, seed=seed)
    return topo, stack, grad_fn, batches


def _run(scenario, rounds=24, algorithm="sdm-dsgd", seed=0, **kw):
    topo, stack, grad_fn, batches = _testbed(seed=seed)
    cfg = SDMConfig(p=0.4, theta=0.3, gamma=0.1, sigma=0.0)
    return simulate(topo=topo, algorithm=algorithm, sdm_cfg=cfg,
                    params_stack=stack, grad_fn=grad_fn, batches=batches,
                    rounds=rounds, scenario=scenario, seed=seed, **kw)


# ---- virtual clock / event queue ------------------------------------------

def test_clock_rejects_backwards_time():
    clock = VirtualClock()
    clock.advance_to(2.0)
    with pytest.raises(ValueError, match="backwards"):
        clock.advance_to(1.0)


def test_equal_time_events_order_by_insertion():
    q = EventQueue()
    q.push(1.0, "b")
    q.push(1.0, "a")
    q.push(0.5, "c")
    clock = VirtualClock()
    out = clock.drain(q, until=2.0)
    assert [e.kind for e in out] == ["c", "b", "a"]
    assert [e.seq for e in out] == [2, 0, 1]
    assert clock.now == pytest.approx(1.0)


def test_drain_respects_horizon():
    q = EventQueue()
    q.push(1.0, "x")
    q.push(3.0, "y")
    clock = VirtualClock()
    assert [e.kind for e in clock.drain(q, until=2.0)] == ["x"]
    assert len(q) == 1


# ---- fleet model -----------------------------------------------------------

def test_distribution_parse_grammar():
    assert Distribution.parse("const:2.5").sample(
        np.random.default_rng(0)) == 2.5
    assert Distribution.parse(3).kind == "const"
    with pytest.raises(ValueError, match="unknown distribution"):
        Distribution.parse("zipf:1")
    with pytest.raises(ValueError, match="arg"):
        Distribution.parse("uniform:1")


def test_scenario_grammar_and_presets():
    spec = parse_scenario("q=0.8,deadline=1.5,straggle=0.25x8,"
                          "dropout=0.05,churn=0.02:5")
    assert spec.participation_q == 0.8
    assert spec.deadline == 1.5
    assert spec.straggler_frac == 0.25 and spec.straggler_slowdown == 8.0
    assert spec.dropout == 0.05
    assert spec.churn == 0.02 and spec.churn_min_down == 5
    assert not SCENARIOS["no-fault"].faulty
    assert parse_scenario("STRAGGLER") is SCENARIOS["straggler"]
    with pytest.raises(ValueError, match="unknown scenario key"):
        parse_scenario("latency=1")
    with pytest.raises(ValueError, match="q must be"):
        parse_scenario("q=0")


def test_fleet_is_deterministic_per_seed():
    a = Fleet(8, "dropout", seed=5)
    b = Fleet(8, "dropout", seed=5)
    np.testing.assert_array_equal(a.bandwidth, b.bandwidth)
    for _ in range(20):
        pa = a.sample_participants()
        np.testing.assert_array_equal(pa, b.sample_participants())
        np.testing.assert_array_equal(a.sample_dropouts(pa),
                                      b.sample_dropouts(pa))
    c = Fleet(8, "dropout", seed=6)
    assert not np.array_equal(a.bandwidth, c.bandwidth)


def test_participation_never_drops_below_two():
    fleet = Fleet(4, "q=0.01", seed=0)
    for _ in range(50):
        assert int(fleet.sample_participants().sum()) >= 2


def test_churn_keeps_two_nodes_up():
    fleet = Fleet(4, "churn=0.9:1", seed=0)
    for t in range(100):
        fleet.churn_step(t)
        assert int(fleet.up.sum()) >= 2


# ---- the simulator ---------------------------------------------------------

@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_every_scenario_trains(scenario):
    res = _run(scenario)
    r = res.result
    assert res.rounds == 24
    assert r.losses[-1] < r.losses[0]
    # the virtual clock moves forward and the per-round column lines up
    assert len(r.sim_time_s) == 24
    assert all(b >= a for a, b in zip(r.sim_time_s, r.sim_time_s[1:]))
    assert res.sim_seconds == pytest.approx(r.sim_time_s[-1])
    # wire accounting is cumulative and only counts delivered payloads
    assert all(b >= a for a, b in zip(r.comm_bits, r.comm_bits[1:]))


@pytest.mark.parametrize("scenario", ["straggler", "dropout", "churn"])
def test_same_seed_replays_bit_identically(scenario):
    r1 = _run(scenario, rounds=16)
    r2 = _run(scenario, rounds=16)
    assert r1.trace_signature == r2.trace_signature
    for a, b in zip(jax.tree.leaves(r1.final_params),
                    jax.tree.leaves(r2.final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert r1.result.losses == r2.result.losses
    assert r1.result.comm_bits == r2.result.comm_bits


def test_different_seed_changes_the_trace():
    r1 = _run("dropout", rounds=16, seed=0)
    r2 = _run("dropout", rounds=16, seed=1)
    assert r1.trace_signature != r2.trace_signature


def test_straggler_scenario_counts_and_bounds_rounds():
    res = _run("straggler")
    assert res.straggler_rounds > 0
    # the deadline closes every round: simulated time is bounded by it
    deadline = SCENARIOS["straggler"].deadline
    assert res.sim_seconds <= res.rounds * deadline + 1e-9
    # a withheld payload is never charged: strictly fewer wire bits than
    # the same fleet with no deadline
    free = _run("straggle=0.25x6")         # same stragglers, no deadline
    assert res.result.comm_bits[-1] < free.result.comm_bits[-1]
    assert res.sim_seconds < free.sim_seconds


def test_dropout_scenario_counts_dead_nodes():
    res = _run("dropout")
    assert res.dropout_rounds > 0
    kinds = {ev.kind for ev in res.trace}
    assert "drop" in kinds and "round-close" in kinds


def test_churn_recompiles_membership_segments():
    res = _run("churn=0.2:3", rounds=20)
    assert res.recompiles >= 1
    kinds = [ev.kind for ev in res.trace]
    assert "recompile" in kinds
    assert ("leave" in kinds) or ("join" in kinds)
    # membership changes never abort training
    assert res.result.losses[-1] < res.result.losses[0]


def test_absolute_state_methods_degrade_stragglers():
    """dsgd has no differential buffer: stragglers fall out of the round
    instead of going stale, and the run still trains."""
    res = _run("straggler", algorithm="dsgd")
    assert res.straggler_rounds > 0
    assert res.result.losses[-1] < res.result.losses[0]


def test_round_close_events_match_rounds():
    res = _run("no-fault", rounds=12)
    closes = [ev for ev in res.trace if ev.kind == "round-close"]
    assert len(closes) == 12
    assert [dict(ev.data)["t"] for ev in closes] == list(range(12))
    times = [ev.time for ev in res.trace]
    assert all(b >= a - 1e-12 for a, b in zip(times, times[1:]))


def test_partial_participation_amplifies_privacy():
    pp = PrivacyParams(G=5.0, m=100, tau=8 / 100, p=0.4, sigma=2.0)
    full = _run("no-fault", rounds=10, privacy=pp)
    part = _run("q=0.5", rounds=10, privacy=pp)
    assert len(full.result.epsilons) == len(part.result.epsilons) == 10
    assert part.result.epsilons[-1] < full.result.epsilons[-1]
    # exactly the q^2 subsampled-RDP factor on the eps-part
    eps_t = 1.0
    assert (part.result.epsilons[-1] - eps_t / 2) == pytest.approx(
        0.25 * (full.result.epsilons[-1] - eps_t / 2), rel=1e-9)


def test_target_loss_records_simulated_seconds():
    res = _run("no-fault", rounds=24, target_loss=1e9)
    assert res.rounds_to_target == 1
    assert res.time_to_target == pytest.approx(res.result.sim_time_s[0])
    never = _run("no-fault", rounds=8, target_loss=-1.0)
    assert never.time_to_target is None and never.rounds_to_target is None


def test_topology_spec_string_and_node_mismatch():
    topo, stack, grad_fn, batches = _testbed()
    cfg = SDMConfig(p=0.4, theta=0.3, gamma=0.1, sigma=0.0)
    res = simulate(topo="ring", algorithm="sdm-dsgd", sdm_cfg=cfg,
                   params_stack=stack, grad_fn=grad_fn, batches=batches,
                   rounds=4, scenario="no-fault", seed=0)
    assert res.rounds == 4
    with pytest.raises(ValueError, match="nodes"):
        simulate(topo=topology.ring(4), algorithm="sdm-dsgd", sdm_cfg=cfg,
                 params_stack=stack, grad_fn=grad_fn, batches=batches,
                 rounds=2)


def test_segment_cap_bounds_compiled_sequence_length():
    """max_segment caps how long one compiled ScheduleSequence gets; the
    run still covers every round across segments."""
    res = _run("dropout", rounds=9, max_segment=4)
    assert res.rounds == 9
    assert res.recompiles >= 2      # ceil(9/4) - 1 segments after the first


def test_no_fault_matches_base_topology_weights():
    """Full-participation rounds mix with the BASE graph's own weights —
    the sim introduces no masking artifacts when nothing faults."""
    from repro.core import gossip

    topo = topology.ring(N)
    seq = gossip.sequence_from_active_sets(topo, [range(N)] * 3)
    for s in seq.schedules:
        np.testing.assert_array_equal(s.dense_weights(), topo.weights)
    with pytest.raises(ValueError, match="active set"):
        gossip.sequence_from_active_sets(topo, [])


def test_fleet_spec_validation():
    with pytest.raises(ValueError, match="slowdown"):
        FleetSpec(straggler_slowdown=0.5)
    with pytest.raises(ValueError, match="deadline"):
        FleetSpec(deadline=0.0)
    with pytest.raises(ValueError, match="dropout"):
        FleetSpec(dropout=1.5)
    with pytest.raises(ValueError, match="min-down"):
        FleetSpec(churn_min_down=0)
