"""Sparsifier S(.) properties: Definition 2 and Lemma 1 of §3."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sparsifier


def test_values_are_scaled_or_zero():
    key = jax.random.PRNGKey(0)
    x = jnp.ones((4, 257))
    out = sparsifier.bernoulli_sparsify(key, x, 0.3)
    vals = np.unique(np.asarray(out))
    assert all(np.isclose(v, 0.0) or np.isclose(v, 1.0 / 0.3, rtol=1e-5)
               for v in vals)


def test_unbiasedness_statistical():
    """E[S(x)] = x (Lemma 1.i), checked by averaging many masks."""
    x = jnp.array(np.random.default_rng(0).normal(size=(64,)), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(1), 4000)
    outs = jax.vmap(lambda k: sparsifier.bernoulli_sparsify(k, x, 0.25))(keys)
    np.testing.assert_allclose(np.asarray(outs.mean(0)), np.asarray(x),
                               atol=0.25)


def test_variance_matches_lemma1():
    """Var(S(x)) = (1/p - 1)||x||^2 (summed over coordinates)."""
    p = 0.4
    x = jnp.array(np.random.default_rng(2).normal(size=(128,)), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(3), 8000)
    outs = np.asarray(
        jax.vmap(lambda k: sparsifier.bernoulli_sparsify(k, x, p))(keys))
    emp_var = outs.var(axis=0).sum()
    pred = float(sparsifier.sparsifier_variance(x, p))
    assert emp_var == pytest.approx(pred, rel=0.1)


def test_p_one_identity():
    x = jnp.arange(10.0)
    out = sparsifier.bernoulli_sparsify(jax.random.PRNGKey(0), x, 1.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_fixedk_exact_count():
    x = jnp.array(np.random.default_rng(4).normal(size=(1000,)), jnp.float32)
    out = sparsifier.fixedk_sparsify(jax.random.PRNGKey(5), x, 0.2)
    assert int((np.asarray(out) != 0).sum()) == 200


def test_fixedk_unbiased_statistical():
    x = jnp.array(np.random.default_rng(6).normal(size=(50,)), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(7), 4000)
    outs = jax.vmap(lambda k: sparsifier.fixedk_sparsify(k, x, 0.3))(keys)
    np.testing.assert_allclose(np.asarray(outs.mean(0)), np.asarray(x),
                               atol=0.25)


def test_fixedk_pack_unpack_roundtrip():
    d = 333
    x = jnp.array(np.random.default_rng(8).normal(size=(d,)), jnp.float32)
    k = sparsifier.num_kept(d, 0.25)
    idx = sparsifier.fixedk_indices(jax.random.PRNGKey(9), d, k)
    dense = sparsifier.fixedk_unpack(sparsifier.fixedk_pack(x, idx, d), idx, d)
    # kept coordinates scaled by exactly d/k, others zero
    mask = np.zeros(d, bool)
    mask[np.asarray(idx)] = True
    np.testing.assert_allclose(np.asarray(dense)[mask],
                               np.asarray(x)[mask] * (d / k), rtol=1e-6)
    assert (np.asarray(dense)[~mask] == 0).all()


def test_fixedk_indices_distinct_and_regenerable():
    idx1 = sparsifier.fixedk_indices(jax.random.PRNGKey(10), 500, 100)
    idx2 = sparsifier.fixedk_indices(jax.random.PRNGKey(10), 500, 100)
    np.testing.assert_array_equal(np.asarray(idx1), np.asarray(idx2))
    assert len(np.unique(np.asarray(idx1))) == 100


@given(d=st.integers(1, 2048), p=st.floats(0.01, 1.0))
@settings(max_examples=200, deadline=None)
def test_num_kept_properties(d, p):
    k = sparsifier.num_kept(d, p)
    assert 1 <= k <= d
    assert k >= p * d - 1e-9  # ceil


def test_num_kept_exact_ceil_sweep():
    """k == ceil(p*d) in EXACT arithmetic for every short-decimal p.

    Regression for the float-overshoot bug: 100 * 0.07 ==
    7.000000000000001 in binary, so a naive ceil returned 8 where the
    contract says ceil(0.07 * 100) = 7.
    """
    import math
    from fractions import Fraction

    ps = ["0.01", "0.02", "0.05", "0.07", "0.1", "0.125", "0.2", "0.25",
          "0.3", "1/3", "0.35", "0.5", "0.7", "0.75", "0.9", "0.99", "1.0"]
    for p_str in ps:
        p_exact = Fraction(p_str) if "/" in p_str else Fraction(p_str)
        p = float(p_exact)
        for d in range(1, 513):
            expected = max(1, min(d, math.ceil(p_exact * d)))
            assert sparsifier.num_kept(d, p) == expected, (d, p_str)


def test_num_kept_overshoot_regression():
    assert sparsifier.num_kept(100, 0.07) == 7
    assert sparsifier.num_kept(1000, 0.07) == 70
    assert sparsifier.num_kept(100, 0.29) == 29
    # beyond float precision: 1e8 * 0.07 == 7000000.000000001 and the ulp
    # there defeats decimal-rounding workarounds; exact arithmetic holds.
    assert sparsifier.num_kept(100_000_000, 0.07) == 7_000_000
    assert sparsifier.num_kept(10**12, 0.07) == 7 * 10**10


@given(p=st.sampled_from([0.1, 0.25, 0.5, 0.9]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_sparsify_support_subset_property(p, seed):
    """S(x) is supported on a subset of supp(x) and scales by 1/p."""
    x = jnp.array(np.random.default_rng(seed % 100).normal(size=(64,)),
                  jnp.float32)
    out = np.asarray(
        sparsifier.bernoulli_sparsify(jax.random.PRNGKey(seed), x, p))
    xs = np.asarray(x)
    nz = out != 0
    np.testing.assert_allclose(out[nz], xs[nz] / p, rtol=1e-5)
