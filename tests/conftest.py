"""Shared test configuration.

Registers the offline `hypothesis` fallback (helpers/hypothesis_fallback)
when the real package is not importable, so property-test modules collect
and run in hermetic containers. With hypothesis installed (the [test]
extra, as in CI) this is a no-op and the real engine is used.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent / "helpers"))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import hypothesis_fallback

    sys.modules["hypothesis"] = hypothesis_fallback
    sys.modules["hypothesis.strategies"] = hypothesis_fallback.strategies
