"""Deliberately-broken distributed step: the analyzer's negative fixture.

Reproduces the PR-1 bug class on purpose, twice over:

* **key reuse** — the per-(node, round) key is consumed by TWO draws
  (noise and sparsifier mask), so mask bits and privacy noise are
  correlated; ``prng_lint`` must report exactly one ``key-reuse``.
* **un-noised wire** — the sparsified differential goes on the wire
  WITHOUT the Gaussian mask (no ``masked_grad``/``sanitize`` between
  the raw gradient and the ppermute), so ``jaxpr_taint`` must report
  exactly one ``tainted-collective``.

The transport itself is the vetted ``gossip.exchange`` (wire-tagged),
so no ``untagged-wire`` finding rides along: the test pins the finding
set to exactly these two kinds. Never executed — only traced.
"""
import jax
import jax.numpy as jnp

from repro.core import gossip


def broken_step(x, a, b, *, axis_name, schedule, base_key, step,
                gamma=0.2, sigma=1.0, p=0.25):
    """One un-private gossip step over a least-squares gradient."""
    r = a @ x - b
    g = a.T @ r / a.shape[0]                       # raw gradient (tainted)

    me = jax.lax.axis_index(axis_name)
    key = gossip.node_round_key(base_key, me, step)
    noise = sigma * jax.random.normal(key, g.shape)        # draw 1
    mask = jax.random.bernoulli(key, p, g.shape)           # draw 2: BUG —
    # same key consumed twice; mask bits and noise are correlated.

    d = jnp.where(mask, g, 0.0)
    # BUG: the differential ships without the noise — the sanitizer
    # (masked_grad's clip -> + sigma*normal) never ran on the wire path.
    nbr = gossip.exchange(schedule, d, axis_name, step=step)
    return x - gamma * (g + 1e-6 * noise) + 0.0 * nbr
