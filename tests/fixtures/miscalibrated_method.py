"""Deliberately-MIScalibrated distributed step: the certifier's negative
fixture.

Every QUALITATIVE invariant holds — the wire payload is sanitize-tagged
before the vetted ``gossip.exchange``, keys split cleanly, the clip tag
carries the config's C — so the taint/prng/wire passes all come back
empty. What's wrong is QUANTITATIVE, twice over:

* **unclipped residual** — a ``0.05 * g`` raw-gradient correction is
  added AFTER ``clip_tree``, so the value the noise lands on has no
  provable coordinate bound; ``analyze_sensitivity`` must report exactly
  one ``unclipped-sanitize``.
* **noise-scale drift** — the Gaussian mask ships ``1.3 * sigma`` while
  the accountant charges ``sigma``; ``analyze_calibration`` must report
  exactly one ``noise-scale-mismatch`` (jaxpr 1.3 vs accountant 1.0).

This is the bug class no execution-based test can see: the trajectory
is plausible, the wire is tagged, epsilon is simply wrong. Never
executed — only traced.
"""
import jax

from repro.core import clipping, gossip, tagging


def miscalibrated_step(x, a, b, *, axis_name, schedule, base_key, step,
                       gamma=0.2, sigma=1.0, clip_c=1.0):
    """One gossip step whose privacy constants disagree with the code."""
    r = a @ x - b
    g = a.T @ r / a.shape[0]                       # raw gradient (tainted)

    me = jax.lax.axis_index(axis_name)
    key = gossip.node_round_key(base_key, me, step)

    clipped = clipping.clip_tree(g, clip_c)
    # BUG 1: un-clipped residual rides along after the clip — the
    # sanitize operand's sensitivity is unbounded.
    pre_noise = clipped + 0.05 * g
    # BUG 2: the mask std is 1.3*sigma but the accountant charges sigma.
    noise = (1.3 * sigma) * jax.random.normal(key, g.shape)
    d = tagging.sanitize(pre_noise + noise, label="miscalibrated")

    nbr = gossip.exchange(schedule, d, axis_name, step=step)
    return x - gamma * (g + 0.0 * nbr)
