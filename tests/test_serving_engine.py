"""Continuous-batching serving: paged KV cache, flash decode, ingest.

Covers the serving tentpole's correctness surface:
  * paged-cache allocator invariants (disjoint ownership, trash page
    never allocated, exact free-list accounting) and no cross-slot data
    leakage after page recycling, property-tested over random
    admission/retirement schedules,
  * paged flash decode == naive paged reference == an independent numpy
    oracle, incl. sliding window, softcap, and empty (seq_len 0) rows,
  * THE ragged-prompt pin: batched serving of unequal-length prompts
    equals serving each request one-at-a-time (the seed's static engine
    conditioned shorter rows on their right-padding),
  * checkpoint ingest: consensus-average of a real decentralized train
    run's stacked replicas, push-sum de-bias, and greedy determinism
    across two engine instantiations of the ingested model.
"""
import math
import os
import random
from collections import namedtuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.checkpoint import load_flat, save_checkpoint
from repro.models import transformer
from repro.serving import (PagedKVCache, Request, ServingEngine,
                           StaticServingEngine)
from repro.serving.ingest import ingest_checkpoint


def _cfg(name):
    return configs.get_smoke_config(name)


def _params(name, seed=0):
    cfg = _cfg(name)
    return cfg, transformer.init_params(jax.random.PRNGKey(seed), cfg)


def _ragged_requests(cfg, *, lens, budgets, seed=1):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, n).tolist(),
                    max_new_tokens=m, eos_id=None)
            for n, m in zip(lens, budgets)]


def _one_at_a_time(cfg, params, requests, max_seq):
    outs = []
    for r in requests:
        r1 = Request(prompt=list(r.prompt), max_new_tokens=r.max_new_tokens,
                     eos_id=r.eos_id)
        StaticServingEngine(cfg, params, max_batch=1,
                            max_seq=max_seq).serve([r1])
        outs.append(r1.output)
    return outs


# ---------------------------------------------------------------------------
# Paged-cache allocator invariants (property test over schedules).
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_paged_cache_alloc_free_invariants(seed):
    cfg = _cfg("phi3-medium-14b")
    rng = random.Random(seed)
    kv = PagedKVCache(cfg, max_batch=4, max_seq=32, page_size=4,
                      n_pages=rng.choice([10, 16, 32]))
    live = {}
    for _ in range(30):
        admit = rng.random() < 0.6 or not live
        if admit and len(live) < kv.max_batch:
            slot = rng.choice([s for s in range(kv.max_batch)
                               if s not in live])
            n_tok = rng.randint(1, kv.max_seq)
            if not kv.can_admit(n_tok):
                with pytest.raises(ValueError):
                    kv.alloc(slot, n_tok)
                continue
            kv.alloc(slot, n_tok)
            live[slot] = n_tok
            # double-alloc on an occupied slot must refuse
            with pytest.raises(ValueError):
                kv.alloc(slot, 1)
        elif live:
            slot = rng.choice(list(live))
            kv.release(slot)
            del live[slot]
            assert kv.owned(slot) == ()
            assert not np.asarray(kv._tables[slot]).any()

        # accounting: in-use == sum of per-slot charges, free+used == pool
        assert kv.pages_in_use() == sum(
            kv.pages_needed(n) for n in live.values())
        assert kv.pages_in_use() + len(kv._free) == kv.n_pages
        # ownership: page 0 never handed out, no page owned twice
        owned = [p for s in live for p in kv.owned(s)]
        assert 0 not in owned
        assert len(owned) == len(set(owned))
        # block tables point at owned pages only (rest at trash)
        for s, n in live.items():
            row = np.asarray(kv._tables[s])
            need = kv.pages_needed(n)
            assert set(row[:need]) == set(kv.owned(s))
            assert not row[need:].any()
    with pytest.raises(ValueError):
        kv.alloc(0 if 0 not in live else
                 next(s for s in range(4) if s not in live), kv.max_seq + 1)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_paged_cache_no_cross_slot_leakage_after_recycle(seed):
    """Each live slot reads back exactly the data written at its
    admission, no matter how many other slots were admitted/retired
    (and its pages recycled) in between."""
    cfg = _cfg("phi3-medium-14b")
    kv_h, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    rng = random.Random(seed)
    kv = PagedKVCache(cfg, max_batch=3, max_seq=16, page_size=4, n_pages=8)
    attn_slots = [si for si in kv.pages]
    live = {}          # slot -> (fill_value, length)
    fill = 0
    for _ in range(14):
        if (rng.random() < 0.6 or not live) and len(live) < kv.max_batch \
                and kv.can_admit(12):
            slot = rng.choice([s for s in range(kv.max_batch)
                               if s not in live])
            length = rng.randint(1, 12)
            kv.alloc(slot, length)
            fill += 1
            # padded prefill: the tail beyond `length` is junk that must
            # be routed to the trash page, never into owned pages
            Lp = length + rng.choice([0, 3])
            k = np.full((cfg.n_periods, 1, Lp, kv_h, hd), fill, np.float32)
            k[:, :, length:] = -99.0
            kv.write_prompt(slot, {si: (jnp.asarray(k), jnp.asarray(-k))
                                   for si in attn_slots}, length)
            live[slot] = (fill, length)
        elif live:
            slot = rng.choice(list(live))
            kv.release(slot)
            del live[slot]
        for slot, (val, length) in live.items():
            got = kv.gather_dense(slot, length)
            for si, (gk, gv) in got.items():
                assert np.all(np.asarray(gk) == val), \
                    f"slot {slot} k leaked (want fill {val})"
                assert np.all(np.asarray(gv) == -val)


# ---------------------------------------------------------------------------
# Flash decode == naive reference == independent numpy oracle.
# ---------------------------------------------------------------------------

def _numpy_paged_attention(q, k_pages, v_pages, tbl, seq_lens, window,
                           softcap):
    b, h, dh = q.shape
    _, page, kvh, _ = k_pages.shape
    group = h // kvh
    out = np.zeros_like(q, dtype=np.float64)
    for i in range(b):
        L = int(seq_lens[i])
        if L == 0:
            continue
        k = np.stack([k_pages[tbl[i, p // page], p % page]
                      for p in range(L)])          # (L, kvh, dh)
        v = np.stack([v_pages[tbl[i, p // page], p % page]
                      for p in range(L)])
        for hh in range(h):
            kvh_i = hh // group
            s = (k[:, kvh_i] @ q[i, hh]) / math.sqrt(dh)
            if softcap is not None:
                s = softcap * np.tanh(s / softcap)
            if window is not None:
                s[np.arange(L) <= (L - 1) - window] = -np.inf
            p_ = np.exp(s - s.max())
            out[i, hh] = (p_ / p_.sum()) @ v[:, kvh_i]
    return out


@pytest.mark.parametrize("window,softcap", [(None, None), (6, None),
                                            (None, 5.0), (6, 5.0)])
def test_flash_vs_naive_paged_decode_equivalence(window, softcap):
    from repro.kernels.flash_attn.decode import paged_attention
    rng = np.random.default_rng(3)
    b, kvh, group, dh, page, n_pages, n_blocks = 5, 2, 3, 32, 4, 24, 4
    h = kvh * group
    q = rng.normal(size=(b, h, dh)).astype(np.float32)
    k_pages = rng.normal(size=(n_pages + 1, page, kvh, dh)).astype(np.float32)
    v_pages = rng.normal(size=(n_pages + 1, page, kvh, dh)).astype(np.float32)
    # disjoint per-row page ownership, like the real allocator; trailing
    # blocks of short rows point at the trash page 0 (full of junk)
    perm = rng.permutation(np.arange(1, n_pages + 1))
    seq_lens = np.array([0, 1, 7, 16, 10], np.int32)
    tbl = np.zeros((b, n_blocks), np.int32)
    nxt = 0
    for i in range(b):
        need = -(-max(int(seq_lens[i]), 1) // page)
        tbl[i, :need] = perm[nxt:nxt + need]
        nxt += need

    oracle = _numpy_paged_attention(q, k_pages, v_pages, tbl, seq_lens,
                                    window, softcap)
    ref = paged_attention(jnp.asarray(q), jnp.asarray(k_pages),
                          jnp.asarray(v_pages), jnp.asarray(tbl),
                          jnp.asarray(seq_lens), window=window,
                          softcap=softcap, use_kernel=False)
    ker = paged_attention(jnp.asarray(q), jnp.asarray(k_pages),
                          jnp.asarray(v_pages), jnp.asarray(tbl),
                          jnp.asarray(seq_lens), window=window,
                          softcap=softcap, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(ref), oracle, atol=2e-5)
    np.testing.assert_allclose(np.asarray(ker), oracle, atol=2e-5)
    # empty row contributes exactly nothing on both paths
    assert not np.asarray(ref)[0].any() and not np.asarray(ker)[0].any()


# ---------------------------------------------------------------------------
# THE ragged pin: batched == one-at-a-time.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["phi3-medium-14b", "gemma2-2b"])
def test_ragged_batched_matches_one_at_a_time(arch):
    cfg, params = _params(arch)
    lens, budgets = (3, 9, 5, 12, 7), (6, 3, 8, 5, 4)
    want = _one_at_a_time(cfg, params,
                          _ragged_requests(cfg, lens=lens, budgets=budgets),
                          max_seq=64)
    engines = [
        StaticServingEngine(cfg, params, max_batch=5, max_seq=64),
        ServingEngine(cfg, params, max_batch=3, max_seq=64, page_size=4),
        ServingEngine(cfg, params, max_batch=3, max_seq=64, page_size=4,
                      use_flash=True),
    ]
    for eng in engines:
        reqs = _ragged_requests(cfg, lens=lens, budgets=budgets)
        eng.serve(reqs)
        assert [r.output for r in reqs] == want, type(eng).__name__
    # continuous engines ran genuinely paged: fewer pages than dense
    stats = engines[1].last_stats
    assert 0 < stats.pages_peak < stats.pages_dense_equiv


def test_ragged_recurrent_matches_one_at_a_time():
    """Recurrent mixers can't mask away right-padding (state pollution):
    the static engine groups equal lengths, the continuous engine
    prefills at exact length. Both must match sequential serving."""
    cfg, params = _params("rwkv6-3b")
    lens, budgets = (4, 7, 4, 9), (5, 3, 6, 4)
    want = _one_at_a_time(cfg, params,
                          _ragged_requests(cfg, lens=lens, budgets=budgets),
                          max_seq=48)
    for eng in (StaticServingEngine(cfg, params, max_batch=4, max_seq=48),
                ServingEngine(cfg, params, max_batch=2, max_seq=48,
                              page_size=8)):
        reqs = _ragged_requests(cfg, lens=lens, budgets=budgets)
        eng.serve(reqs)
        assert [r.output for r in reqs] == want, type(eng).__name__


def test_continuous_more_requests_than_slots_recycles():
    """Queue 3x the slot count with wildly uneven budgets: every request
    completes correctly through slot recycling, and the page pool stays
    within its (sub-dense) bound."""
    cfg, params = _params("phi3-medium-14b")
    lens = (3, 6, 2, 8, 4, 5)
    budgets = (12, 1, 7, 2, 9, 3)
    want = _one_at_a_time(cfg, params,
                          _ragged_requests(cfg, lens=lens, budgets=budgets),
                          max_seq=32)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=32, page_size=4,
                        n_pages=2 * (32 // 4))  # exactly 2 dense rows
    reqs = _ragged_requests(cfg, lens=lens, budgets=budgets)
    eng.serve(reqs)
    assert [r.output for r in reqs] == want
    assert eng.last_stats.pages_peak <= 2 * (32 // 4)


# ---------------------------------------------------------------------------
# Checkpoint ingest.
# ---------------------------------------------------------------------------

def test_ingest_consensus_and_deterministic_serving(tmp_path):
    """Real decentralized train run -> npz -> ingest: the served model is
    the replica mean, and two fresh engines decode it identically."""
    from repro.core import SDMConfig, topology
    from repro.data import TokenStream
    from repro.train.trainer import run_decentralized

    cfg, params = _params("phi3-medium-14b")
    n = 3
    stack = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n,) + p.shape), params)
    stream = TokenStream(vocab_size=cfg.vocab_size, batch=n * 2,
                         seq_len=16, seed=0)

    def one_loss(p, tokens, labels):
        logits, aux = transformer.forward(p, cfg, tokens)
        return transformer.lm_loss(logits, labels, cfg.vocab_size, aux)

    def grad_fn(ps, batch):
        toks, labs = batch
        losses, grads = jax.vmap(jax.value_and_grad(one_loss))(
            ps, toks, labs)
        return grads, jnp.mean(losses)

    def batches():
        t = 0
        while True:
            toks, labs = stream.batch_at(t)
            yield (jnp.asarray(toks).reshape(n, 2, -1),
                   jnp.asarray(labs).reshape(n, 2, -1))
            t += 1

    ck = str(tmp_path / "ck")
    run_decentralized(
        topo=topology.ring(n), algorithm="sdm-dsgd",
        sdm_cfg=SDMConfig(p=0.4, theta=0.3, gamma=0.05, sigma=0.0),
        params_stack=stack, grad_fn=grad_fn, batches=batches(),
        steps=3, checkpoint_dir=ck, checkpoint_every=3)

    served, report = ingest_checkpoint(ck, cfg)
    assert report.n_nodes == n and not report.debiased
    assert np.isfinite(report.max_disagreement)

    # oracle: plain mean over the stacked replicas, straight off the npz
    flat = load_flat(os.path.join(ck, "step_00000003.npz"))
    np.testing.assert_allclose(
        np.asarray(served["embed"]),
        flat["x/embed"].astype(np.float64).mean(axis=0), rtol=1e-6)

    reqs = lambda: _ragged_requests(cfg, lens=(5, 9, 3), budgets=(6, 4, 7))
    outs = []
    for _ in range(2):  # two independent instantiations
        rs = ServingEngine(cfg, served, max_batch=2, max_seq=32,
                           page_size=4).serve(reqs())
        outs.append([r.output for r in rs])
    assert outs[0] == outs[1]
    rs = StaticServingEngine(cfg, served, max_batch=3,
                             max_seq=32).serve(reqs())
    assert [r.output for r in rs] == outs[0]


def test_ingest_pushsum_debias_and_raw_params(tmp_path):
    """x_i = w_i * theta with varying w must de-bias back to theta
    exactly (zero disagreement); a raw params checkpoint ingests
    unchanged."""
    cfg, params = _params("phi3-medium-14b")
    n = 4
    w = np.array([0.5, 1.0, 1.5, 2.0], np.float32)
    State = namedtuple("State", ["x", "w", "step"])
    x = jax.tree.map(
        lambda p: jnp.asarray(w.reshape((n,) + (1,) * p.ndim) * p[None]),
        params)
    save_checkpoint(str(tmp_path / "ps"), 5,
                    State(x=x, w=jnp.asarray(w), step=jnp.asarray(5)))
    served, report = ingest_checkpoint(str(tmp_path / "ps"), cfg)
    assert report.debiased and report.n_nodes == n
    assert report.max_disagreement < 1e-6
    np.testing.assert_allclose(np.asarray(served["embed"]),
                               np.asarray(params["embed"]), atol=1e-6)

    save_checkpoint(str(tmp_path / "raw"), 1, params)
    served2, report2 = ingest_checkpoint(str(tmp_path / "raw"), cfg)
    assert report2.n_nodes == 1 and report2.prefix == ""
    for a, b in zip(jax.tree.leaves(served2), jax.tree.leaves(params)):
        assert jnp.array_equal(a, b)
