"""Per-architecture smoke tests: reduced configs, one forward + train step
on CPU, asserting output shapes and finiteness — required deliverable (f).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer

ARCHES = sorted(configs.ALIASES)
B, S = 2, 32


def _context_for(cfg, batch):
    if cfg.family == "audio":
        return jnp.ones((batch, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.01
    if cfg.family == "vlm":
        return jnp.ones((batch, cfg.n_image_tokens, cfg.d_model), jnp.float32) * 0.01
    return None


def _make(arch):
    cfg = configs.get_smoke_config(arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    return cfg, params, tokens


@pytest.mark.parametrize("arch", ARCHES)
def test_forward_shapes_and_finiteness(arch):
    cfg, params, tokens = _make(arch)
    logits, aux = transformer.forward(params, cfg, tokens,
                                      context=_context_for(cfg, B))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHES)
def test_one_train_step_reduces_loss_structurally(arch):
    """grad step runs, params change, loss stays finite."""
    cfg, params, tokens = _make(arch)
    labels = jnp.roll(tokens, -1, axis=1)
    ctx = _context_for(cfg, B)

    def loss_fn(p):
        logits, aux = transformer.forward(p, cfg, tokens, context=ctx)
        return transformer.lm_loss(logits, labels, cfg.vocab_size, aux)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0
    new = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = loss_fn(new)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ARCHES)
def test_prefill_then_decode_matches_forward(arch):
    """Teacher-forced decode over the cache reproduces the train forward
    logits — the strongest cache-correctness invariant."""
    cfg, params, tokens = _make(arch)
    ctx = _context_for(cfg, B)
    full_logits, _ = transformer.forward(params, cfg, tokens, context=ctx)

    prompt = tokens[:, : S // 2]
    cache = transformer.init_cache(cfg, B, S, jnp.float32)
    logits_p, cache = transformer.prefill(params, cfg, prompt, cache,
                                          context=ctx)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, S // 2 - 1]),
        rtol=2e-2, atol=2e-3)

    # decode the second half teacher-forced; compare each step's logits
    enc_ctx = transformer.encode_context(params, cfg, ctx)
    logits_steps = []
    for t in range(S // 2, S):
        logits_t, cache = transformer.decode_step(params, cfg, tokens[:, t],
                                                  cache, context=enc_ctx)
        logits_steps.append(logits_t)
    for i, lt in enumerate(logits_steps[:-1]):
        np.testing.assert_allclose(
            np.asarray(lt), np.asarray(full_logits[:, S // 2 + i]),
            rtol=2e-2, atol=2e-3,
            err_msg=f"{arch}: decode step {i} mismatch")


@pytest.mark.parametrize("arch", ARCHES)
def test_param_axes_tree_matches_params(arch):
    cfg = configs.get_smoke_config(arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    axes = transformer.param_axes(cfg)
    jax.tree.map(lambda p, a: None, params, axes)  # same structure or raises
    for p, a in zip(jax.tree.leaves(params),
                    jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))):
        assert p.ndim == len(a), (p.shape, a)


def test_full_configs_match_assignment():
    """The exact full configs: layer counts, dims, vocab, family features."""
    c = configs.get_config("gemma2-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (26, 2304, 8, 4, 9216, 256000)
    assert c.logit_softcap == 30.0 and c.sliding_window == 4096

    c = configs.get_config("granite-moe-1b-a400m")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k) == (24, 1024, 32, 8)

    c = configs.get_config("qwen1.5-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == (64, 5120, 40, 27392)
    assert c.qkv_bias

    c = configs.get_config("jamba-v0.1-52b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k) == (32, 4096, 16, 2)
    mixers = [s.mixer for s in c.period]
    assert mixers.count("attn") == 1 and mixers.count("mamba") == 7

    c = configs.get_config("qwen3-moe-30b-a3b")
    assert (c.n_layers, c.n_experts, c.top_k) == (48, 128, 8)

    c = configs.get_config("whisper-large-v3")
    assert (c.n_layers, c.n_encoder_layers, c.d_model) == (32, 32, 1280)

    c = configs.get_config("llama-3.2-vision-11b")
    assert (c.n_layers, c.d_model, c.n_kv_heads) == (40, 4096, 8)
    assert sum(s.cross_attn for s in c.period) * c.n_periods == 8

    c = configs.get_config("phi3-medium-14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (40, 5120, 40, 10)

    c = configs.get_config("rwkv6-3b")
    assert (c.n_layers, c.d_model) == (32, 2560) and c.is_attention_free

    c = configs.get_config("chatglm3-6b")
    assert (c.n_layers, c.d_model, c.n_kv_heads) == (28, 4096, 2)
    assert c.rope_fraction == 0.5
