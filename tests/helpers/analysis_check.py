"""Subprocess body for tests/test_analysis.py: trace the deliberately
broken fixture method on a 4-node fake host mesh and run the taint and
PRNG passes on it. Prints one JSON object on stdout.

Must run in its own process: the device-count fake below has to land
before jax initializes.
"""
import json
import os
import pathlib
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from fixtures.broken_method import broken_step  # noqa: E402
from repro import compat  # noqa: E402
from repro.analysis import jaxpr_taint, prng_lint  # noqa: E402
from repro.core import gossip, topology  # noqa: E402

N, DIM, BATCH = 4, 64, 8


def main() -> int:
    seq = gossip.ensure_sequence(
        gossip.schedule_from_topology(topology.ring(N)))
    rng = np.random.default_rng(0)
    x_st = jnp.asarray(rng.normal(size=(N, DIM)), jnp.float32)
    a_st = jnp.asarray(rng.normal(size=(N, BATCH, DIM)), jnp.float32)
    b_st = jnp.asarray(rng.normal(size=(N, BATCH)), jnp.float32)
    base_key = jax.random.PRNGKey(7)
    mesh = compat.make_mesh((N,), ("data",))

    def dist(x_st, a_st, b_st):
        def inner(x, a, b):
            x, a, b = (jnp.squeeze(v, 0) for v in (x, a, b))
            out = broken_step(x, a, b, axis_name="data", schedule=seq,
                              base_key=base_key, step=jnp.int32(0))
            return out[None]

        return compat.shard_map(inner, mesh=mesh,
                                in_specs=(P("data"), P("data"), P("data")),
                                out_specs=P("data"),
                                axis_names={"data"},
                                check_vma=False)(x_st, a_st, b_st)

    jaxpr = jax.make_jaxpr(dist)(x_st, a_st, b_st)
    taint = jaxpr_taint.analyze_taint(jaxpr, {1: "data", 2: "data"})
    prng = prng_lint.analyze_prng(jaxpr)
    print(json.dumps({
        "taint": taint["findings"],
        "releases": taint["releases"],
        "n_sanitize_sites": taint["n_sanitize_sites"],
        "prng": prng["findings"],
        "n_draws": prng["n_draws"],
    }))
    return 0


if __name__ == "__main__":
    sys.exit(main())
