"""Minimal deterministic stand-in for `hypothesis` (offline fallback).

This container cannot `pip install hypothesis`; rather than erroring 4
test modules at collection, tests/conftest.py registers this module as
``sys.modules["hypothesis"]`` when the real package is absent. It
implements exactly the API surface the test-suite uses:

    from hypothesis import given, settings, strategies as st
    st.integers / st.floats / st.sampled_from / st.booleans

``@given`` draws a deterministic pseudo-random sample of examples (seeded
from the test's qualified name, so failures reproduce) and runs the test
body once per example. ``@settings(max_examples=N)`` is honoured but
capped by REPRO_FALLBACK_MAX_EXAMPLES (default 10) to keep offline runs
fast; CI installs the real hypothesis via `pip install -e .[test]` and
gets the full adaptive search + shrinking.
"""
from __future__ import annotations

import os
import random
import types
import zlib

_MAX_EXAMPLES_CAP = int(os.environ.get("REPRO_FALLBACK_MAX_EXAMPLES", "10"))


class _Strategy:
    def __init__(self, draw):
        self._draw = draw  # rng -> value


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(
        lambda rng: min_value + (max_value - min_value) * rng.random())


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def settings(max_examples: int = 20, deadline=None, **_kwargs):
    def decorate(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return decorate


def given(**strategies):
    def decorate(fn):
        def wrapper(*args, **kwargs):
            n = min(getattr(fn, "_fallback_max_examples", 20),
                    _MAX_EXAMPLES_CAP)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(max(n, 1)):
                drawn = {name: s._draw(rng)
                         for name, s in strategies.items()}
                fn(*args, **kwargs, **drawn)

        # NOT functools.wraps: that sets __wrapped__, which would make
        # pytest read the original signature and demand the given-params
        # as fixtures.
        for attr in ("__name__", "__qualname__", "__module__", "__doc__"):
            setattr(wrapper, attr, getattr(fn, attr))
        return wrapper

    return decorate


strategies = types.SimpleNamespace(
    integers=integers, floats=floats, sampled_from=sampled_from,
    booleans=booleans)
