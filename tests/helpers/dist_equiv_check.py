"""Subprocess helper: distributed shard_map SDM-DSGD == dense-W reference.

Run with 8 fake host devices; prints `MAXERR <float>` lines that
tests/test_distributed.py asserts on. Must set XLA_FLAGS before jax import.

Usage: dist_equiv_check.py [mode] [topology]
  mode:     bernoulli | fixedk_packed | fixedk_rows
  topology: ring8 (default) | torus2x2 | er8 | star4 | complete4 | ...
            (name prefix selects the family, digits select the node count)
"""
import re
import sys

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import baselines, gossip, sdm_dsgd, topology  # noqa: E402

MODE = sys.argv[1] if len(sys.argv) > 1 else "bernoulli"
TOPO_SPEC = sys.argv[2] if len(sys.argv) > 2 else "ring8"


def parse_topology(spec: str) -> topology.Topology:
    m = re.fullmatch(r"([a-z]+)(\d+(?:x\d+)?)", spec)
    family, size = m.group(1), m.group(2)
    if family == "torus":
        rows, cols = (int(v) for v in size.split("x"))
        return topology.torus_2d(rows, cols)
    return topology.by_name(family, int(size))


topo = parse_topology(TOPO_SPEC)
N, DIM = topo.n_nodes, 96
schedule = gossip.schedule_from_topology(topo)

rng = np.random.default_rng(0)
A = jnp.asarray(rng.normal(size=(N, 16, DIM)) / 4.0, jnp.float32)
B = jnp.asarray(rng.normal(size=(N, 16)), jnp.float32)

cfg = sdm_dsgd.SDMConfig(p=0.25, theta=0.15, gamma=0.2, sigma=0.0,
                         clip_c=1.0, mode=MODE)
cfg.validate_against(topo)

params0 = {"w": jnp.asarray(rng.normal(size=(DIM,)) * 0.1, jnp.float32)}
params_stack = {"w": jnp.broadcast_to(params0["w"], (N, DIM))}


def node_grad(w, a, b):
    r = a @ w - b
    return {"w": a.T @ r / a.shape[0]}


def grad_fn_stacked(params, batch):
    del batch
    g = jax.vmap(lambda w, a, b: node_grad(w, a, b)["w"])(params["w"], A, B)
    return {"w": g}, None


# ---------------- reference ------------------------------------------------
sim = sdm_dsgd.ReferenceSimulator(topo, cfg)
ref_state = sim.init(params_stack)
base_key = jax.random.PRNGKey(42)
STEPS = 12
for t in range(STEPS):
    ref_state, _ = sim.advance(ref_state, base_key)
    grads, _ = grad_fn_stacked(ref_state.x, None)
    ref_state = sim.commit(ref_state, grads, base_key)

# ---------------- distributed ----------------------------------------------
mesh = compat.make_mesh((N,), ("data",))


def dist_train(params_stack, a_stack, b_stack):
    def inner(p, a, b):
        p = jax.tree.map(lambda v: jnp.squeeze(v, 0), p)
        a, b = jnp.squeeze(a, 0), jnp.squeeze(b, 0)
        me = jax.lax.axis_index("data")
        state = sdm_dsgd.init_distributed_state(
            p, schedule.self_weight_of(me))

        def body(state, _):
            state = sdm_dsgd.distributed_advance(
                state, base_key=base_key, axis_name="data", cfg=cfg,
                schedule=schedule)
            g = node_grad(state.x["w"], a, b)
            state = sdm_dsgd.distributed_commit(
                state, g, base_key=base_key, axis_name="data", cfg=cfg,
                schedule=schedule)
            return state, None

        state, _ = jax.lax.scan(body, state, None, length=STEPS)
        return jax.tree.map(lambda v: v[None], state.x)

    return compat.shard_map(inner, mesh=mesh,
                            in_specs=(P("data"), P("data"), P("data")),
                            out_specs=P("data"), axis_names={"data"},
                            check_vma=False)(params_stack, a_stack, b_stack)


dist_x = jax.jit(dist_train)(params_stack, A, B)
err = float(jnp.max(jnp.abs(dist_x["w"] - ref_state.x["w"])))
scale = float(jnp.max(jnp.abs(ref_state.x["w"])))
print(f"MAXERR {err}")
print(f"SCALE {scale}")


# ---------------- fused (2-buffer) step == unfused, shifted by advance ------
def dist_train_fused(params_stack, a_stack, b_stack):
    def inner(p, a, b):
        p = jax.tree.map(lambda v: jnp.squeeze(v, 0), p)
        a, b = jnp.squeeze(a, 0), jnp.squeeze(b, 0)
        me = jax.lax.axis_index("data")
        state = sdm_dsgd.init_fused_state(p, schedule.self_weight_of(me))

        def body(state, _):
            g = node_grad(state.x["w"], a, b)
            state = sdm_dsgd.distributed_step_fused(
                state, g, base_key=base_key, axis_name="data", cfg=cfg,
                schedule=schedule)
            return state, None

        state, _ = jax.lax.scan(body, state, None, length=STEPS)
        return jax.tree.map(lambda v: v[None], state.x)

    return compat.shard_map(inner, mesh=mesh,
                            in_specs=(P("data"), P("data"), P("data")),
                            out_specs=P("data"), axis_names={"data"},
                            check_vma=False)(params_stack, a_stack, b_stack)


# after STEPS fused steps, x already includes S(d_STEPS); the unfused
# reference needs one more advance to match.
ref2 = sim.advance(ref_state, base_key)[0]
fused_x = jax.jit(dist_train_fused)(params_stack, A, B)
err_f = float(jnp.max(jnp.abs(fused_x["w"] - ref2.x["w"])))
print(f"MAXERR_FUSED {err_f}")

# HLO must contain collective-permute (the gossip) when lowered.
hlo = jax.jit(dist_train).lower(params_stack, A, B).compile().as_text()
print(f"HAS_CPERM {'collective-permute' in hlo}")

# Packed modes: the largest collective-permute payload on the wire must be
# exactly the fixed-k fraction, not the dense differential.
if MODE in ("fixedk_packed", "fixedk_rows"):
    from repro.core import sparsifier

    payload = 0
    for line in hlo.splitlines():
        # Result shapes precede the op name; sync lowering emits
        # `= f32[k,b]{..} collective-permute(`, async emits a tuple
        # `= (f32[k,b]{..}, f32[k,b]{..}) collective-permute-start(`.
        # Operand shapes (inside the call parens) must not count, so
        # only scan the text before the op name.
        for op in (" collective-permute(", " collective-permute-start("):
            if op in line:
                result_part = line.split(op)[0]
                for shape_str in re.findall(r"f32\[([\d,]*)\]", result_part):
                    dims = [int(v) for v in shape_str.split(",") if v]
                    payload = max(payload, int(np.prod(dims or [1])))
    kb = sparsifier.num_kept(DIM, cfg.p)
    print(f"WIRE_ELEMS {payload}")
    print(f"EXPECTED_WIRE_ELEMS {kb}")
