"""Subprocess helper: reference executor == shard_map distributed executor
for every registered method, on any topology (static / directed /
time-varying), dense and packed payloads — the table-driven sweep behind
tests/test_distributed.py.

Run with 8 fake host devices; prints per-case lines

    CASE <id> MAXERR <f> SCALE <f> HAS_CPERM <b> [WIRE_ELEMS <i>
         EXPECTED_WIRE_ELEMS <i> SORT_COUNT <i> MAX_SORTS <i>]

that the test asserts on. Must set XLA_FLAGS before jax import.

Usage: method_parity_check.py GROUP     (GROUP in CASES)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import re  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import (baselines, gossip, gradient_push, method as  # noqa: E402
                        method_mod, sdm_dsgd, sparsifier, topology)  # noqa: E402

DIM = 96
STEPS = 12
BASE_KEY = jax.random.PRNGKey(42)

# (method, topology spec, gossip mode) — mode "-" for full-state methods.
# "sdm-dsgd:het" marks the heterogeneous per-node-p variant. For
# gradient-push a non-"-" mode is a COMPRESSOR SPEC (repro.core.compressor):
# the error-compensated compressed push-sum variant rides the generic
# exchange_payload transport. "qsgd" cases exercise the int8 quantizer.
CASES = {
    "sdm_core": [
        ("sdm-dsgd", "ring8", "bernoulli"),
        ("sdm-dsgd", "ring8", "fixedk_packed"),
        ("sdm-dsgd", "ring8", "fixedk_rows"),
        ("sdm-dsgd", "torus2x2", "bernoulli"),
        ("sdm-dsgd", "torus2x2", "fixedk_packed"),
        ("sdm-dsgd", "er8", "fixedk_packed"),
        ("sdm-dsgd", "star4", "bernoulli"),
    ],
    "sdm_variants": [
        ("sdm-dsgd-fused", "ring8", "fixedk_rows"),
        ("sdm-dsgd-fused", "torus2x2", "fixedk_packed"),
        ("dc-dsgd", "torus2x2", "bernoulli"),
        ("dc-dsgd", "ring8", "fixedk_packed"),
        ("sdm-dsgd", "matchings8x3", "bernoulli"),
        ("sdm-dsgd", "matchings8x3", "fixedk_packed"),
        ("sdm-dsgd:het", "ring8", "bernoulli"),
    ],
    "baselines": [
        ("dsgd", "ring8", "-"),
        ("dsgd", "er8", "-"),
        ("dsgd", "matchings8x3", "-"),
        ("gradient-push", "dring8", "-"),
        ("gradient-push", "der8", "-"),
        ("allreduce", "ring8", "-"),
        ("allreduce", "er8", "-"),
    ],
    "compressed": [
        ("gradient-push", "dring8", "bernoulli"),
        ("gradient-push", "dring8", "fixedk"),
        ("gradient-push", "der8", "fixedk"),
        ("gradient-push", "der8", "qsgd"),
        ("sdm-dsgd", "ring8", "qsgd"),
        ("sdm-dsgd:het", "ring8", "fixedk_packed"),
        ("sdm-dsgd:het", "torus2x2", "fixedk_packed"),
    ],
    # Replica-correct time-varying gossip: genuinely varying W(t) runs the
    # union-graph replica transport. SDM cases additionally check the
    # reference against an EXPLICIT dense W(t) oracle (no incremental
    # state); compressed gradient-push cases additionally check the
    # sum x / sum w mass-conservation invariant on P(t); all cases check
    # per-link schedule-aware wire accounting against the HLO payload.
    "time_varying": [
        ("sdm-dsgd", "matchings8x2", "bernoulli"),
        ("sdm-dsgd", "matchings8x2", "fixedk_packed"),
        ("sdm-dsgd", "matchings8x2", "qsgd"),
        ("sdm-dsgd-fused", "matchings8x2", "fixedk_packed"),
        ("gradient-push", "matchings8x2", "bernoulli"),
        ("gradient-push", "matchings8x2", "fixedk"),
        ("gradient-push", "matchings8x2", "qsgd"),
    ],
}

# wire bits per element of each HLO dtype that can cross a permute
DTYPE_BITS = {"f32": 32, "bf16": 16, "f16": 16, "s32": 32, "u32": 32,
              "s8": 8, "u8": 8, "pred": 8}


def parse_seq(spec: str) -> gossip.ScheduleSequence:
    m = re.fullmatch(r"matchings(\d+)x(\d+)", spec)
    if m:
        n, rounds = int(m.group(1)), int(m.group(2))
        return gossip.sequence_from_topologies(
            topology.random_matchings(n, rounds, seed=0),
            name=spec)
    m = re.fullmatch(r"([a-z]+)(\d+(?:x\d+)?)", spec)
    family, size = m.group(1), m.group(2)
    if family == "torus":
        rows, cols = (int(v) for v in size.split("x"))
        topo = topology.torus_2d(rows, cols)
    else:
        topo = topology.by_name(family, int(size))
    return gossip.ensure_sequence(gossip.schedule_from_topology(topo))


def make_cfg(meth_key: str, meth, mode: str, n: int):
    if meth.config_cls is sdm_dsgd.SDMConfig:
        p = tuple(0.15 + 0.05 * (i % 4) for i in range(n)) \
            if meth_key.endswith(":het") else 0.25
        return meth.coerce_config(sdm_dsgd.SDMConfig(
            p=p, theta=0.15, gamma=0.2, sigma=0.0, clip_c=1.0, mode=mode))
    if meth.config_cls is gradient_push.GradientPushConfig:
        # a non-"-" mode is a compressor spec: the error-compensated
        # compressed push-sum variant
        return gradient_push.GradientPushConfig(
            gamma=0.2, compressor=None if mode == "-" else mode, p=0.25)
    return baselines.DSGDConfig(gamma=0.2)


def debias(meth_name: str, x_tree, state):
    if meth_name == "gradient-push":
        return gradient_push._debias(x_tree, state.w)
    return x_tree


def sdm_oracle_x(seq, cfg, params_stack, a_stack, b_stack, node_grad,
                 steps: int) -> np.ndarray:
    """EXPLICIT dense W(t) simulator (the shared ``dense_oracle`` helper):
    no incremental state whatsoever — the acceptance oracle the
    replica-correct reference must match bit-comparably (<= 1e-6)."""
    from dense_oracle import sdm_dense_wt_oracle   # sibling module

    grad_stack = lambda x: jax.vmap(
        lambda w, a, b: node_grad(w, a, b)["w"])(x, a_stack, b_stack)
    return sdm_dense_wt_oracle(seq, cfg, params_stack["w"], grad_stack,
                               steps, BASE_KEY)


def push_conservation_probe(seq, mode: str) -> "tuple[float, float]":
    """(mass_err, z_err) of compressed push-sum PURE GOSSIP on ``seq``.

    gamma=0, sigma=0: sum x / sum w must stay the exact initial mean at
    every step (mass conservation on time-varying P(t)) and every node's
    de-biased estimate must converge to it.
    """
    cfg = gradient_push.GradientPushConfig(
        gamma=0.0, sigma=0.0, compressor=mode, p=0.4)
    sim = method_mod.get("gradient-push").make_reference(seq, cfg)
    rng = np.random.default_rng(5)
    stack = {"w": jnp.asarray(rng.normal(size=(seq.n_nodes, 6)), jnp.float32)}
    mean0 = np.mean(np.asarray(stack["w"]), axis=0)
    state = sim.init(stack)
    zero_grad = lambda p, b: (jax.tree.map(jnp.zeros_like, p), 0.0)
    key = jax.random.PRNGKey(0)
    step = jax.jit(lambda s, k: sim.step(s, zero_grad, None, k))
    mass_err = 0.0
    for _ in range(200):
        key, sub = jax.random.split(key)
        state, _ = step(state, sub)
        cons = np.asarray(sim.consensus(state)["w"])
        mass_err = max(mass_err, float(np.max(np.abs(cons - mean0))))
    z = np.asarray(sim.eval_params(state)["w"])
    return mass_err, float(np.max(np.abs(z - mean0)))


def run_case(meth_key: str, topo_spec: str, mode: str) -> None:
    case_id = f"{meth_key}/{topo_spec}/{mode}"
    meth_name = meth_key.split(":")[0]
    meth = method_mod.get(meth_name)
    seq = parse_seq(topo_spec)
    n = seq.n_nodes
    cfg = make_cfg(meth_key, meth, mode, n)

    rng = np.random.default_rng(0)
    a_stack = jnp.asarray(rng.normal(size=(n, 16, DIM)) / 4.0, jnp.float32)
    b_stack = jnp.asarray(rng.normal(size=(n, 16)), jnp.float32)
    params0 = jnp.asarray(rng.normal(size=(DIM,)) * 0.1, jnp.float32)
    params_stack = {"w": jnp.broadcast_to(params0, (n, DIM))}

    def node_grad(w, a, b):
        r = a @ w - b
        return {"w": a.T @ r / a.shape[0]}

    def grad_fn_stacked(params, batch):
        del batch
        g = jax.vmap(lambda w, a, b: node_grad(w, a, b)["w"])(
            params["w"], a_stack, b_stack)
        return {"w": g}, jnp.float32(0.0)

    # ---------------- reference executor -----------------------------
    sim = meth.make_reference(seq, cfg)
    state = sim.init(params_stack)
    sdm_like = hasattr(sim, "advance")
    for _ in range(STEPS):
        if sdm_like:
            # drive the two phases directly with the shared BASE_KEY so
            # sparsifier seeds match the distributed executor bit-for-bit
            state, _ = sim.advance(state, BASE_KEY)
            grads, _ = grad_fn_stacked(state.x, None)
            state = sim.commit(state, grads, BASE_KEY)
        else:
            state, _ = sim.step(state, grad_fn_stacked, None, BASE_KEY)
    if meth_name == "sdm-dsgd-fused":
        # the fused distributed state already folded in the NEXT advance
        state, _ = sim.advance(state, BASE_KEY)
    ref_x = np.asarray(debias(meth_name, state.x, state)["w"])

    # ---------------- distributed executor ---------------------------
    mesh = compat.make_mesh((n,), ("data",))
    ex = meth.make_distributed(seq, cfg, "data")

    def dist_train(params_stack, a_st, b_st):
        def inner(p, a, b):
            p = jax.tree.map(lambda v: jnp.squeeze(v, 0), p)
            a, b = jnp.squeeze(a, 0), jnp.squeeze(b, 0)
            me = jax.lax.axis_index("data")
            state = ex.init(p, me)

            def body(state, _):
                state, _ = ex.step(
                    state,
                    lambda pp: (node_grad(pp["w"], a, b), jnp.float32(0.0)),
                    base_key=BASE_KEY)
                return state, None

            state, _ = jax.lax.scan(body, state, None, length=STEPS)
            z = debias(meth_name, state.x, state)
            return jax.tree.map(lambda v: v[None], z)

        return compat.shard_map(inner, mesh=mesh,
                                in_specs=(P("data"), P("data"), P("data")),
                                out_specs=P("data"), axis_names={"data"},
                                check_vma=False)(params_stack, a_st, b_st)

    compiled = jax.jit(dist_train).lower(params_stack, a_stack,
                                         b_stack).compile()
    dist_x = np.asarray(compiled(params_stack, a_stack, b_stack)["w"])

    err = float(np.max(np.abs(dist_x - ref_x)))
    scale = float(np.max(np.abs(ref_x)))
    hlo = compiled.as_text()
    line = (f"CASE {case_id} MAXERR {err} SCALE {scale} "
            f"HAS_CPERM {'collective-permute' in hlo}")

    def permute_payloads():
        """(f32_elems, bits) of every collective-permute result in the HLO."""
        out = []
        for hline in hlo.splitlines():
            # Result shapes precede the op name; sync lowering emits
            # `= f32[k,b]{..} collective-permute(`, async a tuple form.
            for op in (" collective-permute(", " collective-permute-start("):
                if op in hline:
                    result_part = hline.split(op)[0]
                    f32_elems, bits = 0, 0
                    for dt, shape_str in re.findall(
                            r"(f32|bf16|f16|s32|u32|s8|u8|pred)\[([\d,]*)\]",
                            result_part):
                        dims = [int(v) for v in shape_str.split(",") if v]
                        elems = int(np.prod(dims or [1]))
                        if dt == "f32":
                            f32_elems = max(f32_elems, elems)
                        bits += elems * DTYPE_BITS[dt]
                    out.append((f32_elems, bits))
        return out

    if mode in ("fixedk_packed", "fixedk_rows"):
        payload = max((p_ for p_, _ in permute_payloads()), default=0)
        # het-p pads the wire payload to the max-k across nodes
        p_worst = max(cfg.p) if isinstance(cfg.p, tuple) else cfg.p
        kb = sparsifier.num_kept(DIM, p_worst)
        # Satellite check: ONE batched sender top_k per (leaf, branch) +
        # one for the node's own indices — not one sort per shift round.
        # The replica transport is branch-free: exactly one batched union
        # draw + the own-index draw, regardless of sequence length.
        max_sorts = 2 if gossip.needs_replicas(seq) else 1 + seq.length
        sorts = hlo.count(" sort(") + hlo.count(" sort.")
        line += (f" WIRE_ELEMS {payload} EXPECTED_WIRE_ELEMS {kb}"
                 f" SORT_COUNT {sorts} MAX_SORTS {max_sorts}")
    elif mode.split(":")[0] in ("fixedk", "block", "qsgd"):
        # compressed gradient-push / sdm qsgd: the exchange_payload
        # transport. Assert the largest single wire payload stays at the
        # compressed size: k*32 value bits for fixed-k (indices ship as a
        # separate equal-sized s32 leaf — the explicit index overhead),
        # 8 bits/coord for the int8 quantizer. (bernoulli ships the dense
        # masked tensor, nothing to bound.)
        max_bits = max((b for _, b in permute_payloads()), default=0)
        if mode.split(":")[0] == "qsgd":
            exp_bits = DIM * 8
        else:
            exp_bits = sparsifier.num_kept(DIM, 0.25) * 32
        line += f" WIRE_BITS {max_bits} MAX_WIRE_BITS {exp_bits}"

    if seq.length > 1 and mode != "-":
        # ---- replica-correct time-varying checks ----------------------
        from fractions import Fraction
        useq = gossip.union_schedule(seq)
        union_deg = Fraction(sum(len(r.perm) for r in useq.rounds), n)
        round_deg = Fraction(
            sum(sum(len(r.perm) for r in s.rounds) for s in seq.schedules),
            n * seq.length)
        base_mode = mode.split(":")[0]
        if base_mode in ("fixedk", "block") or \
                mode in ("fixedk_packed", "fixedk_rows"):
            pay = sparsifier.num_kept(DIM, 0.25)
        elif base_mode == "qsgd":
            pay = DIM
        else:                      # bernoulli: informative expectation p*d
            pay = Fraction(repr(0.25)) * DIM
        # schedule-aware per-link accounting vs an independent
        # re-derivation: payload x union-degree (replica transport), plus
        # the mass scalar on the current-round graph for push-sum.
        params_el = {"w": jnp.zeros((DIM,), jnp.float32)}
        acc = method_mod.transmitted_elements(meth, params_el, cfg, seq=seq)
        if meth_name == "gradient-push":
            exp_acc = round(pay * union_deg + round_deg)
        else:
            exp_acc = round(pay * union_deg)
        # ...and vs the HLO: the replica transport is switch-free, so the
        # compiled step must carry the payload over EXACTLY one
        # collective-permute per union round.
        pls = permute_payloads()
        if base_mode == "qsgd":
            pperms = sum(1 for f, b in pls if b >= DIM * 8)
        elif isinstance(pay, Fraction):          # dense bernoulli payload
            pperms = sum(1 for f, _ in pls if f == DIM)
        else:
            pperms = sum(1 for f, _ in pls if f == pay)
        line += (f" ACC_ELEMS {acc} EXPECTED_ACC_ELEMS {exp_acc}"
                 f" PAYLOAD_PERMS {pperms} UNION_ROUNDS {useq.n_replicas}")
        if meth_name == "sdm-dsgd":
            # the reference must equal an EXPLICIT dense W(t) simulator
            ox = sdm_oracle_x(seq, cfg, params_stack, a_stack, b_stack,
                              node_grad, STEPS)
            line += f" ORACLE_MAXERR {float(np.max(np.abs(ox - ref_x)))}"
        if meth_name == "gradient-push":
            m_err, z_err = push_conservation_probe(seq, mode)
            line += f" MASS_ERR {m_err} Z_ERR {z_err}"
    print(line, flush=True)


def main() -> None:
    group = sys.argv[1]
    for meth_key, topo_spec, mode in CASES[group]:
        run_case(meth_key, topo_spec, mode)


if __name__ == "__main__":
    main()
