"""Subprocess helper: reference executor == shard_map distributed executor
for every registered method, on any topology (static / directed /
time-varying), dense and packed payloads — the table-driven sweep behind
tests/test_distributed.py.

Run with 8 fake host devices; prints per-case lines

    CASE <id> MAXERR <f> SCALE <f> HAS_CPERM <b> [WIRE_ELEMS <i>
         EXPECTED_WIRE_ELEMS <i> SORT_COUNT <i> MAX_SORTS <i>]

that the test asserts on. Must set XLA_FLAGS before jax import.

Usage: method_parity_check.py GROUP     (GROUP in CASES)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import re  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import (baselines, gossip, gradient_push, method as  # noqa: E402
                        method_mod, sdm_dsgd, sparsifier, topology)  # noqa: E402

DIM = 96
STEPS = 12
BASE_KEY = jax.random.PRNGKey(42)

# (method, topology spec, gossip mode) — mode "-" for full-state methods.
# "sdm-dsgd:het" marks the heterogeneous per-node-p variant. For
# gradient-push a non-"-" mode is a COMPRESSOR SPEC (repro.core.compressor):
# the error-compensated compressed push-sum variant rides the generic
# exchange_payload transport. "qsgd" cases exercise the int8 quantizer.
CASES = {
    "sdm_core": [
        ("sdm-dsgd", "ring8", "bernoulli"),
        ("sdm-dsgd", "ring8", "fixedk_packed"),
        ("sdm-dsgd", "ring8", "fixedk_rows"),
        ("sdm-dsgd", "torus2x2", "bernoulli"),
        ("sdm-dsgd", "torus2x2", "fixedk_packed"),
        ("sdm-dsgd", "er8", "fixedk_packed"),
        ("sdm-dsgd", "star4", "bernoulli"),
    ],
    "sdm_variants": [
        ("sdm-dsgd-fused", "ring8", "fixedk_rows"),
        ("sdm-dsgd-fused", "torus2x2", "fixedk_packed"),
        ("dc-dsgd", "torus2x2", "bernoulli"),
        ("dc-dsgd", "ring8", "fixedk_packed"),
        ("sdm-dsgd", "matchings8x3", "bernoulli"),
        ("sdm-dsgd", "matchings8x3", "fixedk_packed"),
        ("sdm-dsgd:het", "ring8", "bernoulli"),
    ],
    "baselines": [
        ("dsgd", "ring8", "-"),
        ("dsgd", "er8", "-"),
        ("dsgd", "matchings8x3", "-"),
        ("gradient-push", "dring8", "-"),
        ("gradient-push", "der8", "-"),
        ("allreduce", "ring8", "-"),
        ("allreduce", "er8", "-"),
    ],
    "compressed": [
        ("gradient-push", "dring8", "bernoulli"),
        ("gradient-push", "dring8", "fixedk"),
        ("gradient-push", "der8", "fixedk"),
        ("gradient-push", "der8", "qsgd"),
        ("sdm-dsgd", "ring8", "qsgd"),
        ("sdm-dsgd:het", "ring8", "fixedk_packed"),
        ("sdm-dsgd:het", "torus2x2", "fixedk_packed"),
    ],
}

# wire bits per element of each HLO dtype that can cross a permute
DTYPE_BITS = {"f32": 32, "bf16": 16, "f16": 16, "s32": 32, "u32": 32,
              "s8": 8, "u8": 8, "pred": 8}


def parse_seq(spec: str) -> gossip.ScheduleSequence:
    m = re.fullmatch(r"matchings(\d+)x(\d+)", spec)
    if m:
        n, rounds = int(m.group(1)), int(m.group(2))
        return gossip.sequence_from_topologies(
            topology.random_matchings(n, rounds, seed=0),
            name=spec)
    m = re.fullmatch(r"([a-z]+)(\d+(?:x\d+)?)", spec)
    family, size = m.group(1), m.group(2)
    if family == "torus":
        rows, cols = (int(v) for v in size.split("x"))
        topo = topology.torus_2d(rows, cols)
    else:
        topo = topology.by_name(family, int(size))
    return gossip.ensure_sequence(gossip.schedule_from_topology(topo))


def make_cfg(meth_key: str, meth, mode: str, n: int):
    if meth.config_cls is sdm_dsgd.SDMConfig:
        p = tuple(0.15 + 0.05 * (i % 4) for i in range(n)) \
            if meth_key.endswith(":het") else 0.25
        return meth.coerce_config(sdm_dsgd.SDMConfig(
            p=p, theta=0.15, gamma=0.2, sigma=0.0, clip_c=1.0, mode=mode))
    if meth.config_cls is gradient_push.GradientPushConfig:
        # a non-"-" mode is a compressor spec: the error-compensated
        # compressed push-sum variant
        return gradient_push.GradientPushConfig(
            gamma=0.2, compressor=None if mode == "-" else mode, p=0.25)
    return baselines.DSGDConfig(gamma=0.2)


def debias(meth_name: str, x_tree, state):
    if meth_name == "gradient-push":
        return gradient_push._debias(x_tree, state.w)
    return x_tree


def run_case(meth_key: str, topo_spec: str, mode: str) -> None:
    case_id = f"{meth_key}/{topo_spec}/{mode}"
    meth_name = meth_key.split(":")[0]
    meth = method_mod.get(meth_name)
    seq = parse_seq(topo_spec)
    n = seq.n_nodes
    cfg = make_cfg(meth_key, meth, mode, n)

    rng = np.random.default_rng(0)
    a_stack = jnp.asarray(rng.normal(size=(n, 16, DIM)) / 4.0, jnp.float32)
    b_stack = jnp.asarray(rng.normal(size=(n, 16)), jnp.float32)
    params0 = jnp.asarray(rng.normal(size=(DIM,)) * 0.1, jnp.float32)
    params_stack = {"w": jnp.broadcast_to(params0, (n, DIM))}

    def node_grad(w, a, b):
        r = a @ w - b
        return {"w": a.T @ r / a.shape[0]}

    def grad_fn_stacked(params, batch):
        del batch
        g = jax.vmap(lambda w, a, b: node_grad(w, a, b)["w"])(
            params["w"], a_stack, b_stack)
        return {"w": g}, jnp.float32(0.0)

    # ---------------- reference executor -----------------------------
    sim = meth.make_reference(seq, cfg)
    state = sim.init(params_stack)
    sdm_like = hasattr(sim, "advance")
    for _ in range(STEPS):
        if sdm_like:
            # drive the two phases directly with the shared BASE_KEY so
            # sparsifier seeds match the distributed executor bit-for-bit
            state, _ = sim.advance(state, BASE_KEY)
            grads, _ = grad_fn_stacked(state.x, None)
            state = sim.commit(state, grads, BASE_KEY)
        else:
            state, _ = sim.step(state, grad_fn_stacked, None, BASE_KEY)
    if meth_name == "sdm-dsgd-fused":
        # the fused distributed state already folded in the NEXT advance
        state, _ = sim.advance(state, BASE_KEY)
    ref_x = np.asarray(debias(meth_name, state.x, state)["w"])

    # ---------------- distributed executor ---------------------------
    mesh = compat.make_mesh((n,), ("data",))
    ex = meth.make_distributed(seq, cfg, "data")

    def dist_train(params_stack, a_st, b_st):
        def inner(p, a, b):
            p = jax.tree.map(lambda v: jnp.squeeze(v, 0), p)
            a, b = jnp.squeeze(a, 0), jnp.squeeze(b, 0)
            me = jax.lax.axis_index("data")
            state = ex.init(p, me)

            def body(state, _):
                state, _ = ex.step(
                    state,
                    lambda pp: (node_grad(pp["w"], a, b), jnp.float32(0.0)),
                    base_key=BASE_KEY)
                return state, None

            state, _ = jax.lax.scan(body, state, None, length=STEPS)
            z = debias(meth_name, state.x, state)
            return jax.tree.map(lambda v: v[None], z)

        return compat.shard_map(inner, mesh=mesh,
                                in_specs=(P("data"), P("data"), P("data")),
                                out_specs=P("data"), axis_names={"data"},
                                check_vma=False)(params_stack, a_st, b_st)

    compiled = jax.jit(dist_train).lower(params_stack, a_stack,
                                         b_stack).compile()
    dist_x = np.asarray(compiled(params_stack, a_stack, b_stack)["w"])

    err = float(np.max(np.abs(dist_x - ref_x)))
    scale = float(np.max(np.abs(ref_x)))
    hlo = compiled.as_text()
    line = (f"CASE {case_id} MAXERR {err} SCALE {scale} "
            f"HAS_CPERM {'collective-permute' in hlo}")

    def permute_payloads():
        """(f32_elems, bits) of every collective-permute result in the HLO."""
        out = []
        for hline in hlo.splitlines():
            # Result shapes precede the op name; sync lowering emits
            # `= f32[k,b]{..} collective-permute(`, async a tuple form.
            for op in (" collective-permute(", " collective-permute-start("):
                if op in hline:
                    result_part = hline.split(op)[0]
                    f32_elems, bits = 0, 0
                    for dt, shape_str in re.findall(
                            r"(f32|bf16|f16|s32|u32|s8|u8|pred)\[([\d,]*)\]",
                            result_part):
                        dims = [int(v) for v in shape_str.split(",") if v]
                        elems = int(np.prod(dims or [1]))
                        if dt == "f32":
                            f32_elems = max(f32_elems, elems)
                        bits += elems * DTYPE_BITS[dt]
                    out.append((f32_elems, bits))
        return out

    if mode in ("fixedk_packed", "fixedk_rows"):
        payload = max((p_ for p_, _ in permute_payloads()), default=0)
        # het-p pads the wire payload to the max-k across nodes
        p_worst = max(cfg.p) if isinstance(cfg.p, tuple) else cfg.p
        kb = sparsifier.num_kept(DIM, p_worst)
        # Satellite check: ONE batched sender top_k per (leaf, branch) +
        # one for the node's own indices — not one sort per shift round.
        sorts = hlo.count(" sort(") + hlo.count(" sort.")
        line += (f" WIRE_ELEMS {payload} EXPECTED_WIRE_ELEMS {kb}"
                 f" SORT_COUNT {sorts} MAX_SORTS {1 + seq.length}")
    elif mode.split(":")[0] in ("fixedk", "block", "qsgd"):
        # compressed gradient-push / sdm qsgd: the exchange_payload
        # transport. Assert the largest single wire payload stays at the
        # compressed size: k*32 value bits for fixed-k (indices ship as a
        # separate equal-sized s32 leaf — the explicit index overhead),
        # 8 bits/coord for the int8 quantizer. (bernoulli ships the dense
        # masked tensor, nothing to bound.)
        max_bits = max((b for _, b in permute_payloads()), default=0)
        if mode.split(":")[0] == "qsgd":
            exp_bits = DIM * 8
        else:
            exp_bits = sparsifier.num_kept(DIM, 0.25) * 32
        line += f" WIRE_BITS {max_bits} MAX_WIRE_BITS {exp_bits}"
    print(line, flush=True)


def main() -> None:
    group = sys.argv[1]
    for meth_key, topo_spec, mode in CASES[group]:
        run_case(meth_key, topo_spec, mode)


if __name__ == "__main__":
    main()
