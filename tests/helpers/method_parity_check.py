"""Subprocess helper: reference executor == shard_map distributed executor
for every registered method, on any topology (static / directed /
time-varying), dense and packed payloads — the table-driven sweep behind
tests/test_distributed.py.

Run with 8 fake host devices; prints per-case lines

    CASE <id> MAXERR <f> SCALE <f> HAS_CPERM <b> [WIRE_ELEMS <i>
         EXPECTED_WIRE_ELEMS <i> SORT_COUNT <i> MAX_SORTS <i> ...]

that the test asserts on. Must set XLA_FLAGS before jax import.

All wire expectations are PLANE-aware (PR 5): the transport compresses
the zero-padded (rows, LANE) wire plane of the whole differential, so
payload sizes, top-k counts, and accounting derive from the plane
geometry (``repro.core.plane``), not per-leaf shapes. The ``plane``
group runs a MULTI-LEAF parameter tree and asserts the tentpole
acceptance criterion: the compiled step carries exactly R
collective-permutes per exchange — leaf-count-independent — and the
static wire-bit accounting equals the HLO payload bits (including the
packed sub-byte qsgd u8 lanes).

Usage: method_parity_check.py GROUP     (GROUP in CASES)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import re  # noqa: E402
import sys  # noqa: E402
from fractions import Fraction  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import (baselines, gossip, gradient_push, method as  # noqa: E402
                        method_mod, plane as plane_mod, sdm_dsgd,  # noqa: E402
                        sparsifier, topology)  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402

# One wire-plane row-count > 1 (DIM = 5 * plane.LANE, so the padded plane
# IS the tree: accounting, payload, and legacy intuitions coincide while
# rows-mode top-k still selects among 5 rows).
DIM = 5 * plane_mod.LANE
STEPS = 12
BASE_KEY = jax.random.PRNGKey(42)

# (method, topology spec, gossip mode) — mode "-" for full-state methods.
# "sdm-dsgd:het" marks the heterogeneous per-node-p variant. For
# gradient-push a non-"-" mode is a COMPRESSOR SPEC (repro.core.compressor):
# the error-compensated compressed push-sum variant rides the generic
# exchange_payload transport. "qsgd" cases exercise the int8 quantizer,
# "qsgd:4" the u8-packed sub-byte wire.
CASES = {
    "sdm_core": [
        ("sdm-dsgd", "ring8", "bernoulli"),
        ("sdm-dsgd", "ring8", "fixedk_packed"),
        ("sdm-dsgd", "ring8", "fixedk_rows"),
        ("sdm-dsgd", "torus2x2", "bernoulli"),
        ("sdm-dsgd", "torus2x2", "fixedk_packed"),
        ("sdm-dsgd", "er8", "fixedk_packed"),
        ("sdm-dsgd", "star4", "bernoulli"),
    ],
    "sdm_variants": [
        ("sdm-dsgd-fused", "ring8", "fixedk_rows"),
        ("sdm-dsgd-fused", "torus2x2", "fixedk_packed"),
        ("dc-dsgd", "torus2x2", "bernoulli"),
        ("dc-dsgd", "ring8", "fixedk_packed"),
        ("sdm-dsgd", "matchings8x3", "bernoulli"),
        ("sdm-dsgd", "matchings8x3", "fixedk_packed"),
        ("sdm-dsgd:het", "ring8", "bernoulli"),
    ],
    "baselines": [
        ("dsgd", "ring8", "-"),
        ("dsgd", "er8", "-"),
        ("dsgd", "matchings8x3", "-"),
        ("gradient-push", "dring8", "-"),
        ("gradient-push", "der8", "-"),
        ("allreduce", "ring8", "-"),
        ("allreduce", "er8", "-"),
    ],
    "compressed": [
        ("gradient-push", "dring8", "bernoulli"),
        ("gradient-push", "dring8", "fixedk"),
        ("gradient-push", "der8", "fixedk"),
        ("gradient-push", "der8", "qsgd"),
        ("gradient-push", "dring8", "qsgdf:4"),
        ("sdm-dsgd", "ring8", "qsgd"),
        ("sdm-dsgd", "ring8", "qsgd:4"),
        ("sdm-dsgd:het", "ring8", "fixedk_packed"),
        ("sdm-dsgd:het", "torus2x2", "fixedk_packed"),
    ],
    # Replica-correct time-varying gossip: genuinely varying W(t) runs the
    # union-graph replica transport. SDM cases additionally check the
    # reference against an EXPLICIT dense W(t) oracle (no incremental
    # state); compressed gradient-push cases additionally check the
    # sum x / sum w mass-conservation invariant on P(t); all cases check
    # per-link schedule-aware wire accounting against the HLO payload.
    "time_varying": [
        ("sdm-dsgd", "matchings8x2", "bernoulli"),
        ("sdm-dsgd", "matchings8x2", "fixedk_packed"),
        ("sdm-dsgd", "matchings8x2", "qsgd"),
        ("sdm-dsgd-fused", "matchings8x2", "fixedk_packed"),
        ("gradient-push", "matchings8x2", "bernoulli"),
        ("gradient-push", "matchings8x2", "fixedk"),
        ("gradient-push", "matchings8x2", "qsgd"),
    ],
    # The wire-plane tentpole: a MULTI-LEAF tree (5 leaves, padded plane)
    # must compile to exactly R collective-permutes per exchange, with
    # HLO payload bits equal to the static accounting (fixedk + packed
    # sub-byte qsgd), while reference<->distributed parity holds.
    "plane": [
        ("sdm-dsgd", "ring8", "fixedk_packed"),
        ("sdm-dsgd", "star4", "bernoulli"),
        ("sdm-dsgd-fused", "ring8", "fixedk_rows"),
        ("sdm-dsgd", "ring8", "qsgd:4"),
        ("sdm-dsgd", "ring8", "qsgdf:4"),
        ("dsgd", "ring8", "-"),
        ("gradient-push", "dring8", "fixedk"),
    ],
    # OVERLAPPED transport (":ov" = cfg.overlap=True): one-step-stale
    # neighbour mixing with the wire exchanged under compute. Parity must
    # hold reference<->distributed, the SDM reference must equal the
    # EXPLICIT delayed-mixing dense oracle, and the trajectory must
    # genuinely DIVERGE from overlap=off (the staleness is real, not a
    # no-op flag).
    "overlap": [
        ("sdm-dsgd:ov", "ring8", "bernoulli"),
        ("sdm-dsgd:ov", "ring8", "fixedk_packed"),
        ("sdm-dsgd:ov", "ring8", "qsgd:4"),
        ("sdm-dsgd:ov", "ring8", "qsgdf:4"),
        ("sdm-dsgd-fused:ov", "ring8", "fixedk_packed"),
        ("gradient-push:ov", "dring8", "fixedk"),
    ],
}

# Multi-leaf parameter tree for the "plane" group: mixed ranks/sizes,
# total 994 elements -> one (8, 128) plane with 30 pad zeros.
PLANE_SHAPES = {"emb": (9, 33), "w1": (64, 7), "b1": (71,),
                "w2": (3, 5, 11), "b2": (13,)}


def parse_seq(spec: str) -> gossip.ScheduleSequence:
    m = re.fullmatch(r"matchings(\d+)x(\d+)", spec)
    if m:
        n, rounds = int(m.group(1)), int(m.group(2))
        return gossip.sequence_from_topologies(
            topology.random_matchings(n, rounds, seed=0),
            name=spec)
    m = re.fullmatch(r"([a-z]+)(\d+(?:x\d+)?)", spec)
    family, size = m.group(1), m.group(2)
    if family == "torus":
        rows, cols = (int(v) for v in size.split("x"))
        topo = topology.torus_2d(rows, cols)
    else:
        topo = topology.by_name(family, int(size))
    return gossip.ensure_sequence(gossip.schedule_from_topology(topo))


def make_cfg(meth_key: str, meth, mode: str, n: int):
    overlap = meth_key.endswith(":ov")
    if meth.config_cls is sdm_dsgd.SDMConfig:
        p = tuple(0.15 + 0.05 * (i % 4) for i in range(n)) \
            if meth_key.endswith(":het") else 0.25
        if mode.startswith("qsgd:") or mode.split(":")[0] == "qsgdf":
            return meth.coerce_config(sdm_dsgd.SDMConfig(
                p=p, theta=0.15, gamma=0.2, sigma=0.0, clip_c=1.0,
                compressor=mode, overlap=overlap))
        return meth.coerce_config(sdm_dsgd.SDMConfig(
            p=p, theta=0.15, gamma=0.2, sigma=0.0, clip_c=1.0, mode=mode,
            overlap=overlap))
    if meth.config_cls is gradient_push.GradientPushConfig:
        # a non-"-" mode is a compressor spec: the error-compensated
        # compressed push-sum variant
        return gradient_push.GradientPushConfig(
            gamma=0.2, compressor=None if mode == "-" else mode, p=0.25,
            overlap=overlap)
    return baselines.DSGDConfig(gamma=0.2)


def debias(meth_name: str, x_tree, state):
    if meth_name == "gradient-push":
        return gradient_push._debias(x_tree, state.w)
    return x_tree


def sdm_oracle_x(seq, cfg, params_stack, a_stack, b_stack, node_grad,
                 steps: int) -> np.ndarray:
    """EXPLICIT dense W(t) simulator (the shared ``dense_oracle`` helper):
    no incremental state whatsoever — the acceptance oracle the
    replica-correct reference must match bit-comparably (<= 1e-6)."""
    from dense_oracle import sdm_dense_wt_oracle   # sibling module

    grad_stack = lambda x: jax.vmap(
        lambda w, a, b: node_grad(w, a, b)["w"])(x, a_stack, b_stack)
    return sdm_dense_wt_oracle(seq, cfg, params_stack["w"], grad_stack,
                               steps, BASE_KEY)


def push_conservation_probe(seq, mode: str) -> "tuple[float, float]":
    """(mass_err, z_err) of compressed push-sum PURE GOSSIP on ``seq``.

    gamma=0, sigma=0: sum x / sum w must stay the exact initial mean at
    every step (mass conservation on time-varying P(t)) and every node's
    de-biased estimate must converge to it.
    """
    cfg = gradient_push.GradientPushConfig(
        gamma=0.0, sigma=0.0, compressor=mode, p=0.4)
    sim = method_mod.get("gradient-push").make_reference(seq, cfg)
    rng = np.random.default_rng(5)
    stack = {"w": jnp.asarray(rng.normal(size=(seq.n_nodes, 6)), jnp.float32)}
    mean0 = np.mean(np.asarray(stack["w"]), axis=0)
    state = sim.init(stack)
    zero_grad = lambda p, b: (jax.tree.map(jnp.zeros_like, p), 0.0)
    key = jax.random.PRNGKey(0)
    step = jax.jit(lambda s, k: sim.step(s, zero_grad, None, k))
    mass_err = 0.0
    for _ in range(200):
        key, sub = jax.random.split(key)
        state, _ = step(state, sub)
        cons = np.asarray(sim.consensus(state)["w"])
        mass_err = max(mass_err, float(np.max(np.abs(cons - mean0))))
    z = np.asarray(sim.eval_params(state)["w"])
    return mass_err, float(np.max(np.abs(z - mean0)))


def plane_payload_expectations(spec_plane, mode: str, cfg):
    """(expected max f32 payload elems, blocks) at plane granularity."""
    (rows, lane), = spec_plane.plane_shapes()
    if mode == "fixedk_rows":
        return sparsifier.num_kept(rows, cfg.p) * lane
    d = rows * lane
    p_worst = max(cfg.p) if isinstance(cfg.p, tuple) else cfg.p
    block = getattr(cfg, "pack_block", 1)
    nb = -(-d // block)
    return sparsifier.num_kept(nb, p_worst) * block


# The permute-count contract lives with the static auditor now; the
# parity sweep asserts the SAME expectation the lint matrix enforces.
from repro.analysis.wire_audit import expected_permutes  # noqa: E402


def run_case(meth_key: str, topo_spec: str, mode: str,
             param_shapes=None, group: str = "") -> None:
    case_id = f"{meth_key}/{topo_spec}/{mode}"
    meth_name = meth_key.split(":")[0]
    meth = method_mod.get(meth_name)
    seq = parse_seq(topo_spec)
    n = seq.n_nodes
    cfg = make_cfg(meth_key, meth, mode, n)

    rng = np.random.default_rng(0)
    if param_shapes is None:
        # single-leaf least-squares problem (the historical anchor)
        a_stack = jnp.asarray(rng.normal(size=(n, 16, DIM)) / 4.0,
                              jnp.float32)
        b_stack = jnp.asarray(rng.normal(size=(n, 16)), jnp.float32)
        params0 = jnp.asarray(rng.normal(size=(DIM,)) * 0.1, jnp.float32)
        params_stack = {"w": jnp.broadcast_to(params0, (n, DIM))}

        def node_grad(w, a, b):
            r = a @ w - b
            return {"w": a.T @ r / a.shape[0]}

        def grads_of(tree, a, b):
            return node_grad(tree["w"], a, b)
    else:
        # multi-leaf quadratic: grad = x - t_i (per-node targets), so
        # parity is meaningful on an arbitrary pytree.
        a_stack = jax.tree.map(
            lambda s: jnp.asarray(rng.normal(size=(n,) + s), jnp.float32),
            param_shapes,
            is_leaf=lambda v: isinstance(v, tuple) and all(
                isinstance(e, int) for e in v))
        b_stack = jnp.zeros((n, 1), jnp.float32)
        p0 = jax.tree.map(lambda t: 0.1 * t[0] + 0.05, a_stack)
        params_stack = jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (n,) + v.shape), p0)

        def grads_of(tree, targets, b):
            del b
            return jax.tree.map(jnp.subtract, tree, targets)

    def grad_fn_stacked(params, batch):
        del batch
        g = jax.vmap(grads_of)(params, a_stack, b_stack)
        return g, jnp.float32(0.0)

    # ---------------- reference executor -----------------------------
    sim = meth.make_reference(seq, cfg)
    state = sim.init(params_stack)
    sdm_like = hasattr(sim, "advance")
    for _ in range(STEPS):
        if sdm_like:
            # drive the two phases directly with the shared BASE_KEY so
            # sparsifier seeds match the distributed executor bit-for-bit
            state, _ = sim.advance(state, BASE_KEY)
            grads, _ = grad_fn_stacked(state.x, None)
            state = sim.commit(state, grads, BASE_KEY)
        else:
            state, _ = sim.step(state, grad_fn_stacked, None, BASE_KEY)
    if meth_name == "sdm-dsgd-fused":
        # the fused distributed state already folded in the NEXT advance
        state, _ = sim.advance(state, BASE_KEY)
    ref_x = jax.tree.map(np.asarray, debias(meth_name, state.x, state))

    # ---------------- distributed executor ---------------------------
    mesh = compat.make_mesh((n,), ("data",))
    ex = meth.make_distributed(seq, cfg, "data")

    def dist_train(params_stack, a_st, b_st):
        def inner(p, a, b):
            p = jax.tree.map(lambda v: jnp.squeeze(v, 0), p)
            a = jax.tree.map(lambda v: jnp.squeeze(v, 0), a)
            b = jnp.squeeze(b, 0)
            me = jax.lax.axis_index("data")
            state = ex.init(p, me)

            def body(state, _):
                state, _ = ex.step(
                    state,
                    lambda pp: (grads_of(pp, a, b), jnp.float32(0.0)),
                    base_key=BASE_KEY)
                return state, None

            state, _ = jax.lax.scan(body, state, None, length=STEPS)
            z = debias(meth_name, state.x, state)
            return jax.tree.map(lambda v: v[None], z)

        return compat.shard_map(inner, mesh=mesh,
                                in_specs=(P("data"), P("data"), P("data")),
                                out_specs=P("data"), axis_names={"data"},
                                check_vma=False)(params_stack, a_st, b_st)

    compiled = jax.jit(dist_train).lower(params_stack, a_stack,
                                         b_stack).compile()
    dist_x = jax.tree.map(np.asarray,
                          compiled(params_stack, a_stack, b_stack))

    err = max(float(np.max(np.abs(d_ - r_)))
              for d_, r_ in zip(jax.tree.leaves(dist_x),
                                jax.tree.leaves(ref_x)))
    scale = max(float(np.max(np.abs(r_))) for r_ in jax.tree.leaves(ref_x))
    hlo = compiled.as_text()
    line = (f"CASE {case_id} MAXERR {err} SCALE {scale} "
            f"HAS_CPERM {'collective-permute' in hlo}")

    payloads = hlo_analysis.permute_payloads(hlo)
    per_node = jax.tree.map(lambda v: v[0], params_stack)
    spec_plane = plane_mod.ParamPlane.for_tree(per_node)
    (p_rows, p_lane), = spec_plane.plane_shapes()
    plane_elems = p_rows * p_lane

    if mode in ("fixedk_packed", "fixedk_rows"):
        payload = max((pl["elems"].get("f32", 0) for pl in payloads),
                      default=0)
        # PLANE-granular payload: one top-k over the whole padded plane
        kb = plane_payload_expectations(spec_plane, mode, cfg)
        # Satellite check: ONE batched sender top_k per (plane, branch) +
        # one for the node's own indices — not one sort per shift round,
        # not one per pytree leaf. The replica transport is branch-free.
        max_sorts = 2 if gossip.needs_replicas(seq) else 1 + seq.length
        sorts = hlo.count(" sort(") + hlo.count(" sort.")
        line += (f" WIRE_ELEMS {payload} EXPECTED_WIRE_ELEMS {kb}"
                 f" SORT_COUNT {sorts} MAX_SORTS {max_sorts}")
    elif mode.split(":")[0] in ("fixedk", "block", "qsgd", "qsgdf"):
        # compressed gradient-push / sdm qsgd: the exchange_payload
        # transport. Assert the largest single wire payload stays at the
        # compressed size: k*32 value bits for fixed-k (indices ship as a
        # separate s32 leaf — the explicit index overhead), bits/coord
        # (u8-PACKED below a byte) for the quantizer. (bernoulli ships
        # the dense masked plane, nothing to bound.)
        max_bits = max((pl["bits"] for pl in payloads), default=0)
        base = mode.split(":")[0]
        if base == "qsgd":
            qbits = int(mode.split(":")[1]) if ":" in mode else 8
            factor = 8 // qbits if qbits in (2, 4) else 1
            exp_bits = (-(-plane_elems // factor)) * factor * qbits \
                if factor > 1 else plane_elems * qbits
        elif base == "qsgdf":
            # fused single-buffer format: packed bytes + the 4 norm
            # tail bytes ride ONE u8 permute
            qbits = int(mode.split(":")[1]) if ":" in mode else 4
            factor = 8 // qbits if qbits in (2, 4) else 1
            exp_bits = (-(-plane_elems // factor) + 4) * 8
        else:
            nb = plane_elems
            exp_bits = sparsifier.num_kept(nb, 0.25) * 32
        line += f" WIRE_BITS {max_bits} MAX_WIRE_BITS {exp_bits}"

    if group == "plane":
        # tentpole acceptance: exactly R permutes per exchange,
        # leaf-count-independent, and (for value-payload transports)
        # accounting == HLO payload bits.
        cperm = hlo_analysis.collective_permute_count(hlo)
        line += (f" CPERM {cperm}"
                 f" EXPECTED_CPERM {expected_permutes(meth_name, mode, seq)}"
                 f" N_LEAVES {len(jax.tree.leaves(params_stack))}")
        if meth_name.startswith("sdm-dsgd") and mode != "bernoulli":
            hlo_bits = sum(pl["bits"] for pl in payloads)
            acc_bits = sdm_dsgd.transmitted_bits_per_step(
                per_node, cfg, seq=seq)
            line += f" HLO_BITS {hlo_bits} ACC_BITS {acc_bits}"
        if meth_name == "dsgd":
            hlo_bits = sum(pl["bits"] for pl in payloads)
            acc_bits = method_mod.transmitted_bits(meth, per_node, cfg,
                                                   seq=seq)
            line += f" HLO_BITS {hlo_bits} ACC_BITS {acc_bits}"

    if group == "overlap":
        # the double buffer reuses the same exchange one step early, so
        # the permute count must NOT grow vs the non-overlapped step
        cperm = hlo_analysis.collective_permute_count(hlo)
        line += (f" CPERM {cperm} EXPECTED_CPERM "
                 f"{expected_permutes(meth_name, mode, seq)}")
        # the staleness is real: same seed, overlap off, must diverge
        cfg_off = make_cfg(meth_key[:-3], meth, mode, n)
        sim_off = meth.make_reference(seq, cfg_off)
        st = sim_off.init(params_stack)
        for _ in range(STEPS):
            if hasattr(sim_off, "advance"):
                st, _ = sim_off.advance(st, BASE_KEY)
                g_off, _ = grad_fn_stacked(st.x, None)
                st = sim_off.commit(st, g_off, BASE_KEY)
            else:
                st, _ = sim_off.step(st, grad_fn_stacked, None, BASE_KEY)
        if meth_name == "sdm-dsgd-fused":
            st, _ = sim_off.advance(st, BASE_KEY)
        off_x = jax.tree.map(np.asarray, debias(meth_name, st.x, st))
        div = max(float(np.max(np.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(off_x),
                                  jax.tree.leaves(ref_x)))
        line += f" STALE_DIVERGENCE {div}"
        if meth_name == "sdm-dsgd":
            # the reference must equal the EXPLICIT delayed-mixing oracle
            from dense_oracle import sdm_dense_overlap_oracle   # sibling

            grad_stack = lambda x: jax.vmap(
                lambda w, a, b: node_grad(w, a, b)["w"])(x, a_stack,
                                                         b_stack)
            ox = sdm_dense_overlap_oracle(seq, cfg, params_stack["w"],
                                          grad_stack, STEPS, BASE_KEY)
            line += (f" ORACLE_MAXERR "
                     f"{float(np.max(np.abs(ox - ref_x['w'])))}")

    if seq.length > 1 and mode != "-":
        # ---- replica-correct time-varying checks ----------------------
        useq = gossip.union_schedule(seq)
        union_deg = Fraction(sum(len(r.perm) for r in useq.rounds), n)
        round_deg = Fraction(
            sum(sum(len(r.perm) for r in s.rounds) for s in seq.schedules),
            n * seq.length)
        base_mode = mode.split(":")[0]
        if base_mode in ("fixedk", "block") or \
                mode in ("fixedk_packed", "fixedk_rows"):
            pay = sparsifier.num_kept(plane_elems, 0.25)
        elif base_mode == "qsgd":
            pay = plane_elems
        else:                      # bernoulli: informative expectation p*d
            pay = Fraction(repr(0.25)) * plane_elems
        # schedule-aware per-link accounting vs an independent
        # re-derivation: payload x union-degree (replica transport), plus
        # the mass scalar on the current-round graph for push-sum.
        acc = method_mod.transmitted_elements(meth, per_node, cfg, seq=seq)
        if meth_name == "gradient-push":
            exp_acc = round(pay * union_deg + round_deg)
        else:
            exp_acc = round(pay * union_deg)
        # ...and vs the HLO: the replica transport is switch-free, so the
        # compiled step must carry the payload over EXACTLY one
        # collective-permute per union round.
        if base_mode == "qsgd":
            pperms = sum(1 for pl in payloads
                         if pl["bits"] >= plane_elems * 8)
        elif isinstance(pay, Fraction):          # dense bernoulli payload
            pperms = sum(1 for pl in payloads
                         if pl["elems"].get("f32", 0) == plane_elems)
        else:
            pperms = sum(1 for pl in payloads
                         if pl["elems"].get("f32", 0) == pay)
        line += (f" ACC_ELEMS {acc} EXPECTED_ACC_ELEMS {exp_acc}"
                 f" PAYLOAD_PERMS {pperms} UNION_ROUNDS {useq.n_replicas}")
        if meth_name == "sdm-dsgd":
            # the reference must equal an EXPLICIT dense W(t) simulator
            ox = sdm_oracle_x(seq, cfg, params_stack, a_stack, b_stack,
                              node_grad, STEPS)
            line += f" ORACLE_MAXERR {float(np.max(np.abs(ox - ref_x['w'])))}"
        if meth_name == "gradient-push":
            m_err, z_err = push_conservation_probe(seq, mode)
            line += f" MASS_ERR {m_err} Z_ERR {z_err}"
    print(line, flush=True)


def main() -> None:
    group = sys.argv[1]
    for meth_key, topo_spec, mode in CASES[group]:
        run_case(meth_key, topo_spec, mode,
                 param_shapes=PLANE_SHAPES if group == "plane" else None,
                 group=group)


if __name__ == "__main__":
    main()
