"""Subprocess helper: the full production train step (shard_map node axis
+ GSPMD model axis) EXECUTES on a 2x2 fake mesh with a smoke config and
the loss decreases. Exercises node gossip + TP sharding + remat together.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.core.sdm_dsgd import SDMConfig  # noqa: E402
from repro.data import TokenStream  # noqa: E402
from repro.launch.mesh import make_mesh_by_name  # noqa: E402
from repro.train import steps as steps_mod  # noqa: E402

mesh = make_mesh_by_name("2x2")  # data=2 nodes, model=2
cfg = dataclasses.replace(configs.get_smoke_config("gemma2-2b"), remat=True)

for algorithm in ("sdm_dsgd", "sdm_dsgd_fused", "dsgd", "allreduce",
                  "gradient-push", "dc-dsgd"):
    tc = steps_mod.DistributedTrainConfig(
        model=cfg,
        # dc-dsgd pins theta=1; keep p above Remark 1's validity threshold
        sdm=SDMConfig(p=0.95 if algorithm == "dc-dsgd" else 0.5,
                      theta=0.3, gamma=0.3, sigma=0.0, clip_c=1.0,
                      mode="fixedk_rows" if "fused" in algorithm
                      else "bernoulli"),
        topology="dring" if algorithm == "gradient-push" else "ring",
        method=algorithm, param_dtype=jnp.float32)
    state = steps_mod.init_distributed_state(tc, mesh, jax.random.PRNGKey(0))
    step = jax.jit(steps_mod.make_distributed_train(tc, mesh))
    stream = TokenStream(vocab_size=cfg.vocab_size, batch=4, seq_len=32,
                         seed=0)
    losses = []
    for t in range(6):
        tok, lab = stream.batch_at(t)
        state, loss = step(state, jnp.asarray(tok), jnp.asarray(lab))
        losses.append(float(loss))
    assert all(jnp.isfinite(jnp.asarray(losses))), (algorithm, losses)
    assert losses[-1] < losses[0], (algorithm, losses)
    print(f"ALGO_OK {algorithm} {losses[0]:.3f}->{losses[-1]:.3f}")
