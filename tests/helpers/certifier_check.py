"""Subprocess body for tests/test_analysis.py: trace the deliberately
MIScalibrated fixture method on a 4-node fake host mesh and run ALL the
analysis passes on it. Prints one JSON object on stdout.

Must run in its own process: the device-count fake below has to land
before jax initializes.
"""
import json
import os
import pathlib
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from fixtures.miscalibrated_method import miscalibrated_step  # noqa: E402
from repro import compat  # noqa: E402
from repro.analysis import (calibration, jaxpr_taint,  # noqa: E402
                            prng_lint, sensitivity)
from repro.core import gossip, topology  # noqa: E402

N, DIM, BATCH = 4, 64, 8
SIGMA, CLIP_C = 1.0, 1.0


def main() -> int:
    seq = gossip.ensure_sequence(
        gossip.schedule_from_topology(topology.ring(N)))
    rng = np.random.default_rng(0)
    x_st = jnp.asarray(rng.normal(size=(N, DIM)), jnp.float32)
    a_st = jnp.asarray(rng.normal(size=(N, BATCH, DIM)), jnp.float32)
    b_st = jnp.asarray(rng.normal(size=(N, BATCH)), jnp.float32)
    base_key = jax.random.PRNGKey(7)
    mesh = compat.make_mesh((N,), ("data",))

    def dist(x_st, a_st, b_st):
        def inner(x, a, b):
            x, a, b = (jnp.squeeze(v, 0) for v in (x, a, b))
            out = miscalibrated_step(
                x, a, b, axis_name="data", schedule=seq,
                base_key=base_key, step=jnp.int32(0),
                sigma=SIGMA, clip_c=CLIP_C)
            return out[None]

        return compat.shard_map(inner, mesh=mesh,
                                in_specs=(P("data"), P("data"), P("data")),
                                out_specs=P("data"),
                                axis_names={"data"},
                                check_vma=False)(x_st, a_st, b_st)

    jaxpr = jax.make_jaxpr(dist)(x_st, a_st, b_st)
    taint = jaxpr_taint.analyze_taint(jaxpr, {1: "data", 2: "data"})
    prng = prng_lint.analyze_prng(jaxpr)
    sens = sensitivity.analyze_sensitivity(
        jaxpr, {1: "data", 2: "data"}, clip_c=CLIP_C)
    calib = calibration.analyze_calibration(
        jaxpr, expected_sigma=SIGMA, expected_clip=CLIP_C)
    ovl = calibration.analyze_overlap(jaxpr, overlap=False)
    print(json.dumps({
        "taint": taint["findings"],
        "prng": prng["findings"],
        "sensitivity": sens["findings"],
        "calibration": calib["findings"],
        "overlap": ovl["findings"],
        "sanitize_bounds": sens["sanitize_sites"],
        "extracted_noise": calib["sanitize_sites"],
        "clip_sites": sens["clip_sites"],
    }))
    return 0


if __name__ == "__main__":
    sys.exit(main())
