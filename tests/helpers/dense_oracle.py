"""The EXPLICIT dense W(t) oracle for time-varying SDM-DSGD.

A from-scratch simulator of Algorithm 1 that tracks ONLY (x, d) — no
incremental neighbour sum, no replicas — and mixes with the full dense
matrix of the current round each step. This is the acceptance oracle the
replica-correct reference must match bit-comparably; it lives in ONE
place so the parity sweep and the exactness property test cannot drift
onto different semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gossip, plane as plane_mod, sdm_dsgd


def sdm_dense_wt_oracle(seq, cfg, x0, grad_stack, steps: int,
                        base_key) -> np.ndarray:
    """Run ``steps`` iterations on the stacked (n, ...) single-leaf state.

    ``grad_stack(x) -> (n, ...) gradients``; the sparsifier draws use the
    reference executor's exact key schedule (bucket 0 of ``base_key``,
    ``node_round_key`` per node and step) over the zero-padded WIRE
    PLANE — the plane-granular convention the transport draws at — and
    the gradient passes through the shared ``masked_grad``
    (noise/clipping are not the semantics under test). Returns the final
    public-copy stack.
    """
    n = seq.n_nodes
    comp = sdm_dsgd.compressor_of(cfg)
    ws = jnp.asarray(seq.weights_stack(), jnp.float32)
    x = x0
    d = jnp.zeros_like(x)
    spec = plane_mod.ParamPlane.for_tree(
        jax.ShapeDtypeStruct(tuple(x0.shape[1:]), jnp.float32), buckets=None)
    bucket_key = jax.random.fold_in(base_key, 0)
    for t in range(steps):
        keys = jax.vmap(
            lambda i: gossip.node_round_key(bucket_key, i, t))(jnp.arange(n))

        def one(i, k, v):
            pl = spec.pack(v)[0]
            out = comp.decompress(comp.compress(k, pl, node=i))
            return spec.unpack((out,))

        sd = jax.vmap(one)(jnp.arange(n), keys, d)
        x = x + sd
        g = grad_stack(x)
        g = sdm_dsgd.masked_grad({"w": g}, base_key, sigma=cfg.sigma,
                                 clip_c=cfg.clip_c)["w"]
        m = jnp.einsum("ij,j...->i...", ws[t % seq.length], x)
        y = (1.0 - cfg.theta) * x + cfg.theta * (m - cfg.gamma * g)
        d = y - x
    return np.asarray(x)


def sdm_dense_overlap_oracle(seq, cfg, x0, grad_stack, steps: int,
                             base_key) -> np.ndarray:
    """The OVERLAPPED-transport oracle: delayed (one-step-stale) mixing.

    Same from-scratch simulator as ``sdm_dense_wt_oracle`` but the
    commit mixes each node's CURRENT self copy with its neighbours'
    PREVIOUS-round public copies — the semantics the ``cfg.overlap``
    double-buffered transport implements by exchanging the next round's
    wire while this round's gradient computes:

        m_i(t) = W_ii x_i(t) + sum_{j != i} W_ij x_j(t - 1)

    (x_j(-1) = x_j(0): the first round has no stale buffer, matching
    the executor's S(0) = 0 initialization). Tracks only (x, d, xprev).
    """
    n = seq.n_nodes
    comp = sdm_dsgd.compressor_of(cfg)
    ws = jnp.asarray(seq.weights_stack(), jnp.float32)
    x = x0
    d = jnp.zeros_like(x)
    xprev = x0
    spec = plane_mod.ParamPlane.for_tree(
        jax.ShapeDtypeStruct(tuple(x0.shape[1:]), jnp.float32), buckets=None)
    bucket_key = jax.random.fold_in(base_key, 0)
    for t in range(steps):
        keys = jax.vmap(
            lambda i: gossip.node_round_key(bucket_key, i, t))(jnp.arange(n))

        def one(i, k, v):
            pl = spec.pack(v)[0]
            out = comp.decompress(comp.compress(k, pl, node=i))
            return spec.unpack((out,))

        sd = jax.vmap(one)(jnp.arange(n), keys, d)
        x = x + sd
        g = grad_stack(x)
        g = sdm_dsgd.masked_grad({"w": g}, base_key, sigma=cfg.sigma,
                                 clip_c=cfg.clip_c)["w"]
        w_t = ws[t % seq.length]
        diag = jnp.diagonal(w_t)
        offd = w_t - jnp.diag(diag)
        m = diag[:, None] * x + jnp.einsum("ij,j...->i...", offd, xprev)
        y = (1.0 - cfg.theta) * x + cfg.theta * (m - cfg.gamma * g)
        d = y - x
        xprev = x       # what neighbours mix at the NEXT commit
    return np.asarray(x)
