"""The unified Method registry: lookup, config coercion, directed
push-sum consensus, time-varying schedules, heterogeneous per-node p."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (baselines, gossip, gradient_push, method,
                        plane as plane_mod, privacy, sdm_dsgd, topology)


# ---------------------------------------------------------------------------
# Registry surface.
# ---------------------------------------------------------------------------

def test_registry_has_required_methods():
    names = method.names()
    assert len(names) >= 4
    for required in ("sdm-dsgd", "sdm-dsgd-fused", "dsgd", "gradient-push"):
        assert required in names
    for name in names:
        m = method.get(name)
        assert m.config_cls is not None and m.state_cls is not None
        assert callable(m.make_reference) and callable(m.make_distributed)


def test_registry_aliases_and_errors():
    assert method.get("sdm_dsgd").name == "sdm-dsgd"
    assert method.get("SDM-DSGD").name == "sdm-dsgd"
    assert method.get("dc_dsgd").name == "dc-dsgd"
    assert method.get("push_sum").name == "gradient-push"
    with pytest.raises(KeyError, match="registered:"):
        method.get("no-such-method")


def test_config_coercion_replaces_as_sdm():
    """dsgd/dc-dsgd/gradient-push derive their configs from SDMConfig at
    the registry boundary — the old DSGDConfig.as_sdm shim is gone."""
    assert not hasattr(baselines.DSGDConfig(), "as_sdm")
    sdm = sdm_dsgd.SDMConfig(p=0.3, theta=0.4, gamma=0.05, sigma=1.5,
                             clip_c=2.0)
    d = method.get("dsgd").coerce_config(sdm)
    assert isinstance(d, baselines.DSGDConfig)
    assert (d.gamma, d.sigma, d.clip_c) == (0.05, 1.5, 2.0)
    # DC-DSGD is the SDM registration with theta pinned to 1
    dc = method.get("dc-dsgd").coerce_config(sdm)
    assert isinstance(dc, sdm_dsgd.SDMConfig) and dc.theta == 1.0
    assert dc.p == 0.3
    gp = method.get("gradient-push").coerce_config(sdm)
    assert isinstance(gp, gradient_push.GradientPushConfig)
    assert gp.sigma == 1.5
    # already-native configs pass through untouched
    assert method.get("dsgd").coerce_config(d) is d
    with pytest.raises(TypeError):
        method.get("sdm-dsgd").coerce_config(d)


def test_state_templates_per_method():
    x = {"w": jax.ShapeDtypeStruct((4, 7), jnp.float32)}
    sds = method.state_shape_dtype(method.get("gradient-push"), x)
    assert sds.w.shape == (4,) and sds.step.shape == (4,)
    assert sds.x["w"].shape == (4, 7)
    sds2 = method.state_shape_dtype(method.get("dsgd"), x)
    assert not hasattr(sds2, "s") and sds2.x["w"].shape == (4, 7)


# ---------------------------------------------------------------------------
# Directed graphs + push-sum de-biasing.
# ---------------------------------------------------------------------------

def test_directed_topology_column_stochastic():
    topo = topology.directed_erdos_renyi(7, 0.3, seed=3)
    w = topo.weights
    np.testing.assert_allclose(w.sum(axis=0), 1.0, atol=1e-9)
    # genuinely asymmetric: NOT row-stochastic (what push-sum corrects)
    assert not np.allclose(w.sum(axis=1), 1.0)
    with pytest.raises(ValueError, match="columns"):
        topology.DirectedTopology(name="bad", n_nodes=2,
                                  adjacency=np.array([[0, 1], [0, 0]]),
                                  weights=np.array([[1.0, 0.7], [0.0, 0.7]]))


def test_push_sum_debiased_mean_converges():
    """Pure push-sum gossip (gamma=0) on an asymmetric directed graph:
    every node's de-biased z_i converges to the exact initial average."""
    topo = topology.directed_erdos_renyi(6, 0.3, seed=2)
    meth = method.get("gradient-push")
    sim = meth.make_reference(topo, gradient_push.GradientPushConfig(gamma=0.0))
    rng = np.random.default_rng(0)
    stack = {"w": jnp.asarray(rng.normal(size=(6, 5)), jnp.float32)}
    state = sim.init(stack)
    zero_grad = lambda p, b: (jax.tree.map(jnp.zeros_like, p), 0.0)
    key = jax.random.PRNGKey(0)
    for _ in range(60):
        state, _ = sim.step(state, zero_grad, None, key)
    mean0 = np.mean(np.asarray(stack["w"]), axis=0)
    z = np.asarray(sim.eval_params(state)["w"])
    # push weights genuinely diverged from 1 (the bias being corrected)...
    assert np.max(np.abs(np.asarray(state.w) - 1.0)) > 0.1
    # ...yet every node's de-biased estimate hits the true average
    assert np.max(np.abs(z - mean0)) < 1e-5
    # and the mass-conservation invariant holds exactly
    cons = np.asarray(sim.consensus(state)["w"])
    np.testing.assert_allclose(cons, mean0, atol=1e-5)


def test_plain_mixing_on_directed_graph_is_biased():
    """Sanity for WHY push-sum exists: averaging x without the w
    correction on an uneven-out-degree directed graph does not reach
    the true mean (the constant-degree directed ring happens to be
    doubly stochastic, so use an asymmetric ER graph)."""
    topo = topology.directed_erdos_renyi(6, 0.3, seed=2)
    w = topo.weights
    assert not np.allclose(w.sum(axis=1), 1.0)
    x = np.asarray(np.arange(6, dtype=np.float64))
    for _ in range(300):
        x = w @ x
    assert np.max(np.abs(x - 2.5)) > 0.05   # true mean is 2.5


# ---------------------------------------------------------------------------
# Time-varying schedule sequences.
# ---------------------------------------------------------------------------

def test_schedule_sequence_properties():
    seq = gossip.sequence_by_name("matchings:3", 8, seed=1)
    assert seq.length == 3 and seq.n_nodes == 8
    ws = seq.weights_stack()
    np.testing.assert_allclose(ws.sum(axis=1), 1.0, atol=1e-9)
    np.testing.assert_allclose(ws.sum(axis=2), 1.0, atol=1e-9)
    # the union over one cycle connects the graph with these seeds
    union = sum((s.dense_weights() != 0).astype(int) for s in seq.schedules)
    assert topology._is_connected((union - np.diag(np.diag(union)) > 0))
    # self_weight_of indexes the right round
    sw = np.asarray([s.self_weights for s in seq.schedules])
    got = float(seq.self_weight_of(jnp.int32(2), jnp.int32(4)))
    assert got == pytest.approx(sw[4 % 3, 2])


def test_dense_weights_roundtrip():
    for topo in (topology.ring(6), topology.torus_2d(2, 3),
                 topology.star(5), topology.directed_ring(6)):
        sched = gossip.schedule_from_topology(topo)
        np.testing.assert_allclose(sched.dense_weights(), topo.weights,
                                   atol=1e-12)


def test_static_spec_is_length_one_sequence():
    seq = gossip.sequence_by_name("ring", 8)
    assert seq.length == 1
    assert gossip.ensure_sequence(seq.schedules[0]).length == 1


def test_replica_state_templates_on_time_varying_schedules():
    """Genuinely time-varying schedules grow the REPLICA state leaves
    (per union-round public-copy slots); static schedules elide them.
    Compressed push-sum on matchings — REJECTED before the replica
    rework — now builds reference, state templates, and replica stacks."""
    seq = gossip.sequence_by_name("matchings:2", 8, seed=0)
    ring = gossip.sequence_by_name("ring", 8)
    r = gossip.union_schedule(seq).n_replicas

    meth = method.get("sdm-dsgd")
    cfg = sdm_dsgd.SDMConfig(p=0.25, theta=0.2)
    assert method.state_fields_of(meth, cfg, ring) == meth.state_fields
    tv = method.state_fields_of(meth, cfg, seq)
    assert ("xhat", method.REPLICA) in tv
    x = {"w": jax.ShapeDtypeStruct((8, 7), jnp.float32)}
    # replica slots stack WIRE PLANES: (n, r, rows, LANE) f32
    lane = plane_mod.LANE
    sds = method.state_shape_dtype(meth, x, cfg, seq=seq)
    assert sds.xhat[0].shape == (8, r, 1, lane)
    assert sds.s[0].shape == (8, 1, lane)
    assert method.state_shape_dtype(meth, x, cfg, seq=ring).xhat is None

    # compressed gradient-push: xhat_nb replica stack only when BOTH
    # compressed and time-varying
    gp = method.get("gradient-push")
    gcfg = gradient_push.GradientPushConfig(compressor="fixedk", p=0.25)
    assert ("xhat_nb", method.REPLICA) in method.state_fields_of(
        gp, gcfg, seq)
    assert ("xhat_nb", method.REPLICA) not in method.state_fields_of(
        gp, gcfg, ring)
    assert ("xhat_nb", method.REPLICA) not in method.state_fields_of(
        gp, gradient_push.GradientPushConfig(), seq)
    gsds = method.state_shape_dtype(gp, x, gcfg, seq=seq)
    assert gsds.xhat_nb[0].shape == (8, r, 1, lane)

    # stacked init materializes the replica stacks at the shared
    # (plane-packed) x_0 — the first 7 plane coords carry x_0, the pad
    # is zero
    stack = {"w": jnp.ones((8, 7), jnp.float32)}
    st = meth.init_stacked(stack, seq, cfg)
    assert st.xhat[0].shape == (8, r, 1, lane)
    np.testing.assert_array_equal(
        np.asarray(st.xhat[0]).reshape(8, r, lane)[:, :, :7], 1.0)
    np.testing.assert_array_equal(
        np.asarray(st.xhat[0]).reshape(8, r, lane)[:, :, 7:], 0.0)
    gst = gp.init_stacked(stack, seq, gp.coerce_config(gcfg))
    assert gst.xhat_nb[0].shape == (8, r, 1, lane)
    # reference construction no longer rejects the combination
    gp.make_reference(seq, gcfg)


# ---------------------------------------------------------------------------
# Heterogeneous per-node p.
# ---------------------------------------------------------------------------

def test_sdm_config_per_node_p():
    cfg = sdm_dsgd.SDMConfig(p=(0.1, 0.2, 0.4), theta=0.05)
    assert cfg.p_min == 0.1 and cfg.p_max == 0.4
    assert float(cfg.p_of(2)) == pytest.approx(0.4)
    # fixed-k modes now take per-node p too (pad-to-max-k payloads)...
    cfg_k = sdm_dsgd.SDMConfig(p=(0.1, 0.2), mode="fixedk_packed")
    assert cfg_k.p_max == 0.2
    # ...but rows mode keeps static per-leaf row counts
    with pytest.raises(ValueError, match="pad-to-max-k"):
        sdm_dsgd.SDMConfig(p=(0.1, 0.2), mode="fixedk_rows")
    with pytest.raises(ValueError):
        sdm_dsgd.SDMConfig(p=(0.1, 0.0))


def test_per_node_p_length_must_match_graph():
    """A too-short p tuple must error, not silently clamp on the gather
    (which would hand every extra node the LAST node's sparsity and
    privacy budget)."""
    cfg = sdm_dsgd.SDMConfig(p=(0.2, 0.3), theta=0.1)
    with pytest.raises(ValueError, match="2 entries for 8 nodes"):
        method.get("sdm-dsgd").make_reference(topology.ring(8), cfg)
    sdm_dsgd.check_per_node_p(cfg, 2)        # matching length passes
    sdm_dsgd.check_per_node_p(sdm_dsgd.SDMConfig(p=0.2), 8)  # scalar: any n


def test_transmitted_elements_per_node_p():
    params = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((37,))}
    # plane convention: 137 tree elements pad to a 256-coordinate plane
    d = plane_mod.ParamPlane.for_tree(params).padded_size
    cfg = sdm_dsgd.SDMConfig(p=(0.1, 0.2, 0.3), theta=0.05)
    per_node = [sdm_dsgd.transmitted_elements_per_step(params, cfg, i)
                for i in range(3)]
    assert per_node == [round(0.1 * d), round(0.2 * d), round(0.3 * d)]
    # node=None: the across-node mean, so total = mean * n as before
    mean = sdm_dsgd.transmitted_elements_per_step(params, cfg)
    assert mean == round(sum(per_node) / 3)


def test_privacy_accountant_worst_case_p():
    base = dict(G=5.0, m=100, tau=0.1, sigma=1.2, delta=1e-5)
    het = privacy.PrivacyParams(p=(0.1, 0.3, 0.2), **base)
    worst = privacy.PrivacyParams(p=0.3, **base)
    assert het.p_worst == 0.3
    alpha = privacy.rdp_alpha(1.0, 1e-5)
    assert privacy.per_step_rdp(het, alpha) == \
        pytest.approx(privacy.per_step_rdp(worst, alpha))
    assert privacy.epsilon_sdm(het, 100, 1.0) == \
        pytest.approx(privacy.epsilon_sdm(worst, 100, 1.0))
    # the REVERSED design leaks as 1/p: the sparsest node dominates
    sparsest = privacy.PrivacyParams(p=0.1, **base)
    assert privacy.epsilon_alternative(het, 100, 1.0) == \
        pytest.approx(privacy.epsilon_alternative(sparsest, 100, 1.0))
    with pytest.raises(ValueError):
        privacy.PrivacyParams(p=(0.1, 1.2), **base)


def test_het_p_reference_training_runs():
    """End-to-end: per-node budgets through the reference executor."""
    topo = topology.ring(4)
    cfg = sdm_dsgd.SDMConfig(p=(0.2, 0.3, 0.4, 0.5), theta=0.25, gamma=0.2)
    cfg.validate_against(topo)
    sim = method.get("sdm-dsgd").make_reference(topo, cfg)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(4, 16, 8)) / 3.0, jnp.float32)
    x_true = rng.normal(size=(8,))
    b = jnp.asarray(np.asarray(a) @ x_true
                    + 0.01 * rng.normal(size=(4, 16)), jnp.float32)

    def grad_fn(params, batch):
        del batch
        g = jax.vmap(lambda w, aa, bb: aa.T @ (aa @ w - bb) / 16.0)(
            params["w"], a, b)
        loss = jnp.mean((jnp.einsum("nbd,nd->nb", a, params["w"]) - b) ** 2)
        return {"w": g}, loss

    state = sim.init({"w": jnp.zeros((4, 8))})
    key = jax.random.PRNGKey(0)
    step = jax.jit(lambda s, k: sim.step(s, grad_fn, None, k))
    losses = []
    for _ in range(300):
        key, sub = jax.random.split(key)
        state, loss = step(state, sub)
        losses.append(float(loss))
    assert losses[-1] < 0.2 * losses[0]


# ---------------------------------------------------------------------------
# Degenerate single-node mesh (the CI registration smoke path).
# ---------------------------------------------------------------------------

def test_single_node_topologies_degenerate():
    for spec in ("ring", "er", "dring", "matchings:3"):
        seq = gossip.sequence_by_name(spec, 1)
        assert seq.n_nodes == 1 and seq.schedules[0].n_rounds == 0
        assert seq.schedules[0].self_weights == (1.0,)


# ---------------------------------------------------------------------------
# Stale-gossip state surgery (the edge-fleet simulator's straggler path).
# ---------------------------------------------------------------------------

def _sdm_state(n=4, d=5, seed=0):
    meth = method.get("sdm-dsgd")
    cfg = meth.coerce_config(sdm_dsgd.SDMConfig(p=0.5, theta=0.3,
                                                gamma=0.1, sigma=0.0))
    sim = meth.make_reference(topology.ring(n), cfg)
    key = jax.random.PRNGKey(seed)
    stack = {"w": jax.random.normal(key, (n, d))}
    state = sim.init(stack)
    # give the differential something nonzero to withhold
    d_tree = jax.tree.map(
        lambda v: jnp.arange(v.size, dtype=v.dtype).reshape(v.shape) + 1.0,
        state.d)
    return meth, state._replace(d=d_tree)


def test_stale_capable_is_the_d_field():
    assert method.stale_capable(method.get("sdm-dsgd"))
    assert method.stale_capable(method.get("dc-dsgd"))
    assert not method.stale_capable(method.get("dsgd"))
    assert not method.stale_capable(method.get("gradient-push"))


def test_withhold_then_defer_is_lossless():
    meth, state = _sdm_state()
    send = np.array([True, False, True, False])
    masked, withheld = method.withhold_differential(meth, state,
                                                    send_mask=send)
    md = jax.tree.leaves(masked.d)[0]
    wd = jax.tree.leaves(withheld)[0]
    # withheld rows are zeroed on the wire copy and preserved aside
    assert not np.any(np.asarray(md)[1]) and not np.any(np.asarray(md)[3])
    np.testing.assert_array_equal(np.asarray(md)[0],
                                  np.asarray(jax.tree.leaves(state.d)[0])[0])
    np.testing.assert_array_equal(np.asarray(wd)[1],
                                  np.asarray(jax.tree.leaves(state.d)[0])[1])
    # masked + withheld == original, elementwise (nothing is ever lost)
    restored = method.defer_differential(meth, masked, withheld)
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(restored.d)[0]),
                                  np.asarray(jax.tree.leaves(state.d)[0]))
    # x is untouched by the surgery
    np.testing.assert_array_equal(np.asarray(masked.x["w"]),
                                  np.asarray(state.x["w"]))


def test_withhold_rejects_absolute_state_methods():
    meth = method.get("dsgd")
    sim = meth.make_reference(topology.ring(4),
                              meth.coerce_config(baselines.DSGDConfig()))
    state = sim.init({"w": jnp.ones((4, 3))})
    with pytest.raises(ValueError, match="differential"):
        method.withhold_differential(meth, state,
                                     send_mask=np.ones(4, bool))


def test_select_node_rows_freezes_per_node():
    meth, state = _sdm_state()
    moved = jax.tree.map(lambda v: v + 100.0, state.x)
    stepped = state._replace(x=moved, step=state.step + 1)
    keep = np.array([True, False, True, False])
    merged = method.select_node_rows(keep, stepped, state)
    out = np.asarray(merged.x["w"])
    np.testing.assert_array_equal(out[0], np.asarray(moved["w"])[0])
    np.testing.assert_array_equal(out[1], np.asarray(state.x["w"])[1])
    np.testing.assert_array_equal(out[3], np.asarray(state.x["w"])[3])
    # the scalar step counter takes the on-state (it is schedule-global)
    assert int(merged.step) == int(stepped.step)
