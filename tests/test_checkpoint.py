"""Flat-key npz checkpointing: roundtrips, latest-step, trainer wiring."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def _mixed_tree():
    """Nested dict/tuple/NamedTuple pytree with mixed dtypes."""
    from repro.core import baselines

    state = baselines.DSGDState(
        x={"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
           "b": jnp.ones((3,), jnp.bfloat16)},
        step=jnp.asarray(7, jnp.int32))
    return {"state": state,
            "extras": (np.float64(2.5), jnp.zeros((4,), jnp.int8))}


def test_npz_roundtrip_mixed_dtypes(tmp_path):
    tree = _mixed_tree()
    path = save_checkpoint(str(tmp_path), 12, tree)
    assert os.path.basename(path) == "step_00000012.npz"
    restored = restore_checkpoint(str(tmp_path), tree)
    for got, want in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        assert np.asarray(got).dtype == np.asarray(want).dtype
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32))


def test_restore_casts_to_exemplar_dtype(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.ones((2,), jnp.float32)})
    restored = restore_checkpoint(str(tmp_path),
                                  {"w": jnp.ones((2,), jnp.bfloat16)})
    assert restored["w"].dtype == jnp.bfloat16


def test_latest_step_and_explicit_step(tmp_path):
    assert latest_step(str(tmp_path / "missing")) is None
    for s in (5, 20, 10):
        save_checkpoint(str(tmp_path), s, {"v": np.full((2,), float(s))})
    assert latest_step(str(tmp_path)) == 20
    assert restore_checkpoint(str(tmp_path),
                              {"v": np.zeros(2)})["v"][0] == 20.0
    assert restore_checkpoint(str(tmp_path), {"v": np.zeros(2)},
                              step=5)["v"][0] == 5.0
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "missing"), {"v": np.zeros(2)})


def test_trainer_emits_checkpoints_and_eval_rows(tmp_path):
    """run_decentralized with checkpoint_every + eval_every writes the
    expected step files and accuracy rows, and the last checkpoint
    restores into the live state's treedef."""
    from repro.core import SDMConfig, topology
    from repro.data import classification_dataset, node_partitioned_batches
    from repro.models import vision_small
    from repro.train.trainer import run_decentralized

    n = 4
    (xtr, ytr), (xte, yte) = classification_dataset(16, 3, 400, 100, seed=0)
    p0 = vision_small.mlr_init(jax.random.PRNGKey(0), 16, 3)
    stack = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n,) + p.shape), p0)
    eval_fn = vision_small.make_eval_fn(vision_small.mlr_apply,
                                        jnp.asarray(xte), jnp.asarray(yte))
    res = run_decentralized(
        topo=topology.ring(n), algorithm="sdm-dsgd",
        sdm_cfg=SDMConfig(p=0.4, theta=0.3, gamma=0.1, sigma=0.0),
        params_stack=stack,
        grad_fn=vision_small.make_stacked_grad_fn(vision_small.mlr_apply),
        batches=node_partitioned_batches(xtr, ytr, n, 8, seed=0),
        steps=30, eval_fn=eval_fn, eval_every=10,
        checkpoint_dir=str(tmp_path), checkpoint_every=10)
    assert sorted(os.listdir(tmp_path)) == [
        "step_00000010.npz", "step_00000020.npz", "step_00000030.npz"]
    assert latest_step(str(tmp_path)) == 30
    assert len(res.eval_accuracy) == 3
    assert all(0.0 <= a <= 1.0 for a in res.eval_accuracy)
    # a fresh init state is a valid exemplar for the saved trainer state
    from repro.core import method as method_mod
    meth = method_mod.get("sdm-dsgd")
    sim = meth.make_reference(
        topology.ring(n), meth.coerce_config(
            SDMConfig(p=0.4, theta=0.3, gamma=0.1, sigma=0.0)))
    exemplar = sim.init(stack)
    restored = restore_checkpoint(str(tmp_path), exemplar)
    assert jax.tree.structure(restored) == jax.tree.structure(exemplar)
    assert not any(np.isnan(np.asarray(v)).any()
                   for v in jax.tree.leaves(restored))
