"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis.

Kernels execute in interpret mode (CPU container; TPU is the target).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.sdm_update import ref as sdm_ref
from repro.kernels.sdm_update.ops import sdm_update
from repro.kernels.sdm_update.sdm_update import LANE, sdm_update_pallas


# --------------------------------------------------------------------------
# sdm_update
# --------------------------------------------------------------------------

def _operands(rows, seed=0):
    rng = np.random.default_rng(seed)
    shape = (rows, LANE)
    f = lambda: jnp.asarray(rng.normal(size=shape), jnp.float32)
    bits = lambda: jnp.asarray(
        rng.integers(0, 2**32, size=shape, dtype=np.uint32))
    return f(), f(), f(), f(), bits(), bits(), bits()


SDM_KW = dict(p=0.25, theta=0.4, gamma=0.05, sigma=0.7, clip_c=1.5,
              self_w=1.0 / 3.0)


@pytest.mark.parametrize("rows,block_rows", [(8, 8), (16, 8), (64, 32)])
def test_sdm_update_matches_ref(rows, block_rows):
    ops = _operands(rows)
    out_k = sdm_update_pallas(*ops, block_rows=block_rows, interpret=True,
                              **SDM_KW)
    out_r = sdm_ref.sdm_update_ref(*ops, **SDM_KW)
    for a, b, name in zip(out_k, out_r, ("x_new", "s_new", "sd")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-6, err_msg=name)


@pytest.mark.parametrize("kw", [
    dict(SDM_KW, sigma=0.0),            # no noise branch
    dict(SDM_KW, clip_c=None),          # no clip branch
    dict(SDM_KW, p=1.0),                # no sparsification
    dict(SDM_KW, theta=1.0),            # DC-DSGD corner
])
def test_sdm_update_branch_configs(kw):
    ops = _operands(8, seed=3)
    out_k = sdm_update_pallas(*ops, block_rows=8, interpret=True, **kw)
    out_r = sdm_ref.sdm_update_ref(*ops, **kw)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-6)


def test_sdm_update_semantics():
    """Kernel implements Algorithm 1's algebra: check against hand-computed
    dense formulas (not just the ref module)."""
    ops = _operands(8, seed=5)
    x, s, nb, g, mb, n1, n2 = ops
    kw = dict(SDM_KW, sigma=0.0, clip_c=None, p=1.0)
    x2, s2, sd = sdm_update_pallas(*ops, block_rows=8, interpret=True, **kw)
    s_new = s + nb
    y = (1 - kw["theta"]) * x + kw["theta"] * (
        kw["self_w"] * x + s_new - kw["gamma"] * g)
    np.testing.assert_allclose(np.asarray(sd), np.asarray(y - x), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(x2), np.asarray(y), rtol=1e-5,
                               atol=1e-6)


def test_sdm_update_pytree_wrapper():
    tree = {"a": jnp.ones((3, 5)), "b": jnp.arange(7.0)}
    zeros = jax.tree.map(jnp.zeros_like, tree)
    key = jax.random.PRNGKey(0)
    x2, s2, sd = sdm_update(tree, zeros, zeros, tree, key, use_kernel=True,
                            block_rows=8, **SDM_KW)
    xr, sr, sdr = sdm_update(tree, zeros, zeros, tree, key, use_kernel=False,
                             **SDM_KW)
    for t1, t2 in ((x2, xr), (s2, sr), (sd, sdr)):
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6), t1, t2)


@given(seed=st.integers(0, 2**31 - 1),
       p=st.sampled_from([0.1, 0.5, 1.0]),
       theta=st.floats(0.05, 1.0),
       sigma=st.sampled_from([0.0, 0.5]))
@settings(max_examples=25, deadline=None)
def test_sdm_update_property_sweep(seed, p, theta, sigma):
    ops = _operands(8, seed=seed % 1000)
    kw = dict(p=p, theta=theta, gamma=0.01, sigma=sigma, clip_c=2.0,
              self_w=0.5)
    out_k = sdm_update_pallas(*ops, block_rows=8, interpret=True, **kw)
    out_r = sdm_ref.sdm_update_ref(*ops, **kw)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

def _qkv(b, sq, skv, h, kvh, dh, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, sq, h, dh)), dtype) * 0.5
    k = jnp.asarray(rng.normal(size=(b, skv, kvh, dh)), dtype) * 0.5
    v = jnp.asarray(rng.normal(size=(b, skv, kvh, dh)), dtype) * 0.5
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,skv,dh", [(128, 128, 64), (256, 384, 128),
                                       (128, 160, 32)])
def test_flash_matches_ref_shapes_dtypes(sq, skv, dh, dtype):
    q, k, v = _qkv(2, sq, skv, 4, 4, dh, dtype)
    out = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                          use_kernel=True, interpret=True)
    ref = flash_attention(q, k, v, causal=False, use_kernel=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None),
    (True, 64, None),        # gemma2 sliding window
    (True, None, 50.0),      # gemma2 attn softcap
    (True, 64, 50.0),
])
def test_flash_masking_variants(causal, window, softcap):
    q, k, v = _qkv(1, 256, 256, 2, 2, 64, jnp.float32, seed=7)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, use_kernel=True, interpret=True)
    ref = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_gqa_groups():
    q, k, v = _qkv(2, 128, 128, 8, 2, 64, jnp.float32, seed=9)
    out = flash_attention(q, k, v, causal=True, use_kernel=True,
                          interpret=True)
    ref = flash_attention(q, k, v, causal=True, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_matches_model_sdpa():
    """Cross-validate the kernel against the model's _sdpa (independent)."""
    from repro.models.layers import _sdpa
    b, s, h, dh = 2, 128, 4, 64
    q, k, v = _qkv(b, s, s, h, h, dh, jnp.float32, seed=11)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    ref = _sdpa(q, k, v, q_positions=pos, kv_positions=pos, causal=True,
                window=None, softcap_val=None)
    out = flash_attention(q, k, v, causal=True, use_kernel=True,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-5)


@given(seed=st.integers(0, 10_000),
       sq=st.sampled_from([128, 256]),
       skv=st.sampled_from([128, 192, 320]),
       causal=st.booleans())
@settings(max_examples=15, deadline=None)
def test_flash_property_sweep(seed, sq, skv, causal):
    q, k, v = _qkv(1, sq, skv, 2, 1, 64, jnp.float32, seed=seed)
    if causal and sq > skv:
        skv = sq  # causal requires kv covers q positions in this harness
        q, k, v = _qkv(1, sq, skv, 2, 1, 64, jnp.float32, seed=seed)
    out = flash_attention(q, k, v, causal=causal, use_kernel=True,
                          interpret=True)
    ref = flash_attention(q, k, v, causal=causal, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-5)
