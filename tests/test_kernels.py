"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis.

Kernels execute in interpret mode (CPU container; TPU is the target).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.sdm_update import ref as sdm_ref
from repro.kernels.sdm_update.ops import sdm_update
from repro.kernels.sdm_update.sdm_update import LANE, sdm_update_pallas


# --------------------------------------------------------------------------
# sdm_update
# --------------------------------------------------------------------------

def _operands(rows, seed=0):
    rng = np.random.default_rng(seed)
    shape = (rows, LANE)
    f = lambda: jnp.asarray(rng.normal(size=shape), jnp.float32)
    bits = lambda: jnp.asarray(
        rng.integers(0, 2**32, size=shape, dtype=np.uint32))
    return f(), f(), f(), f(), bits(), bits(), bits()


SDM_KW = dict(p=0.25, theta=0.4, gamma=0.05, sigma=0.7, clip_c=1.5,
              self_w=1.0 / 3.0)


@pytest.mark.parametrize("rows,block_rows", [(8, 8), (16, 8), (64, 32)])
def test_sdm_update_matches_ref(rows, block_rows):
    ops = _operands(rows)
    out_k = sdm_update_pallas(*ops, block_rows=block_rows, interpret=True,
                              **SDM_KW)
    out_r = sdm_ref.sdm_update_ref(*ops, **SDM_KW)
    for a, b, name in zip(out_k, out_r, ("x_new", "s_new", "sd")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-6, err_msg=name)


@pytest.mark.parametrize("kw", [
    dict(SDM_KW, sigma=0.0),            # no noise branch
    dict(SDM_KW, clip_c=None),          # no clip branch
    dict(SDM_KW, p=1.0),                # no sparsification
    dict(SDM_KW, theta=1.0),            # DC-DSGD corner
])
def test_sdm_update_branch_configs(kw):
    ops = _operands(8, seed=3)
    out_k = sdm_update_pallas(*ops, block_rows=8, interpret=True, **kw)
    out_r = sdm_ref.sdm_update_ref(*ops, **kw)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-6)


def test_sdm_update_semantics():
    """Kernel implements Algorithm 1's algebra: check against hand-computed
    dense formulas (not just the ref module)."""
    ops = _operands(8, seed=5)
    x, s, nb, g, mb, n1, n2 = ops
    kw = dict(SDM_KW, sigma=0.0, clip_c=None, p=1.0)
    x2, s2, sd = sdm_update_pallas(*ops, block_rows=8, interpret=True, **kw)
    s_new = s + nb
    y = (1 - kw["theta"]) * x + kw["theta"] * (
        kw["self_w"] * x + s_new - kw["gamma"] * g)
    np.testing.assert_allclose(np.asarray(sd), np.asarray(y - x), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(x2), np.asarray(y), rtol=1e-5,
                               atol=1e-6)


def test_sdm_update_pytree_wrapper():
    tree = {"a": jnp.ones((3, 5)), "b": jnp.arange(7.0)}
    zeros = jax.tree.map(jnp.zeros_like, tree)
    key = jax.random.PRNGKey(0)
    x2, s2, sd = sdm_update(tree, zeros, zeros, tree, key, use_kernel=True,
                            block_rows=8, **SDM_KW)
    xr, sr, sdr = sdm_update(tree, zeros, zeros, tree, key, use_kernel=False,
                             **SDM_KW)
    for t1, t2 in ((x2, xr), (s2, sr), (sd, sdr)):
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6), t1, t2)


@given(seed=st.integers(0, 2**31 - 1),
       p=st.sampled_from([0.1, 0.5, 1.0]),
       theta=st.floats(0.05, 1.0),
       sigma=st.sampled_from([0.0, 0.5]))
@settings(max_examples=25, deadline=None)
def test_sdm_update_property_sweep(seed, p, theta, sigma):
    ops = _operands(8, seed=seed % 1000)
    kw = dict(p=p, theta=theta, gamma=0.01, sigma=sigma, clip_c=2.0,
              self_w=0.5)
    out_k = sdm_update_pallas(*ops, block_rows=8, interpret=True, **kw)
    out_r = sdm_ref.sdm_update_ref(*ops, **kw)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

def _qkv(b, sq, skv, h, kvh, dh, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, sq, h, dh)), dtype) * 0.5
    k = jnp.asarray(rng.normal(size=(b, skv, kvh, dh)), dtype) * 0.5
    v = jnp.asarray(rng.normal(size=(b, skv, kvh, dh)), dtype) * 0.5
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,skv,dh", [(128, 128, 64), (256, 384, 128),
                                       (128, 160, 32)])
def test_flash_matches_ref_shapes_dtypes(sq, skv, dh, dtype):
    q, k, v = _qkv(2, sq, skv, 4, 4, dh, dtype)
    out = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                          use_kernel=True, interpret=True)
    ref = flash_attention(q, k, v, causal=False, use_kernel=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None),
    (True, 64, None),        # gemma2 sliding window
    (True, None, 50.0),      # gemma2 attn softcap
    (True, 64, 50.0),
])
def test_flash_masking_variants(causal, window, softcap):
    q, k, v = _qkv(1, 256, 256, 2, 2, 64, jnp.float32, seed=7)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, use_kernel=True, interpret=True)
    ref = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_gqa_groups():
    q, k, v = _qkv(2, 128, 128, 8, 2, 64, jnp.float32, seed=9)
    out = flash_attention(q, k, v, causal=True, use_kernel=True,
                          interpret=True)
    ref = flash_attention(q, k, v, causal=True, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_matches_model_sdpa():
    """Cross-validate the kernel against the model's _sdpa (independent)."""
    from repro.models.layers import _sdpa
    b, s, h, dh = 2, 128, 4, 64
    q, k, v = _qkv(b, s, s, h, h, dh, jnp.float32, seed=11)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    ref = _sdpa(q, k, v, q_positions=pos, kv_positions=pos, causal=True,
                window=None, softcap_val=None)
    out = flash_attention(q, k, v, causal=True, use_kernel=True,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-5)


@given(seed=st.integers(0, 10_000),
       sq=st.sampled_from([128, 256]),
       skv=st.sampled_from([128, 192, 320]),
       causal=st.booleans())
@settings(max_examples=15, deadline=None)
def test_flash_property_sweep(seed, sq, skv, causal):
    q, k, v = _qkv(1, sq, skv, 2, 1, 64, jnp.float32, seed=seed)
    if causal and sq > skv:
        skv = sq  # causal requires kv covers q positions in this harness
        q, k, v = _qkv(1, sq, skv, 2, 1, 64, jnp.float32, seed=seed)
    out = flash_attention(q, k, v, causal=causal, use_kernel=True,
                          interpret=True)
    ref = flash_attention(q, k, v, causal=causal, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-5)


# --------------------------------------------------------------------------
# wire_compress: fused quantize+pack / gather+pack vs oracles
# --------------------------------------------------------------------------

from repro.core.compressor import FusedQSGDCompressor, QSGDCompressor  # noqa: E402
from repro.kernels import wire_compress  # noqa: E402


def _plane(rows, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(rows, LANE)), jnp.float32)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("rows", [8, 16, 64])
def test_qsgd_pack_kernel_bitequal_ref(bits, rows):
    """Pallas kernel byte image == pure-jnp oracle, bit for bit."""
    xf = _plane(rows, seed=bits)
    u = jax.random.uniform(jax.random.PRNGKey(rows + bits), xf.shape)
    norm = jnp.sqrt(jnp.sum(jnp.square(xf)))
    out_k = wire_compress.qsgd_pack(xf, u, norm, bits=bits, use_kernel=True)
    out_r = wire_compress.qsgd_pack(xf, u, norm, bits=bits, use_kernel=False)
    assert out_k.dtype == jnp.uint8 and out_k.shape == out_r.shape
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_qsgd_pack_kernel_bitequal_unfused_compressor(bits):
    """Fused byte image == the unfused QSGDCompressor pack, same key."""
    xf = _plane(8, seed=17)
    key = jax.random.PRNGKey(5)
    comp = QSGDCompressor(p=1.0, bits=bits)
    pay = comp.compress(key, xf)
    u = jax.random.uniform(key, xf.shape)
    norm = jnp.sqrt(jnp.sum(jnp.square(xf)))
    fused = wire_compress.qsgd_pack(xf, u, norm, bits=bits)
    if bits == 8:
        # unfused b=8 ships signed int8 q; fused ships offset (q + s) u8
        unfused = (np.asarray(pay.values).astype(np.int32)
                   .reshape(-1) + comp.levels).astype(np.uint8)
    else:
        unfused = np.asarray(pay.values).reshape(-1)
    np.testing.assert_array_equal(np.asarray(fused), unfused)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(71,), (3, 5, 11), (9, 33)])
def test_qsgd_pack_ref_path_odd_shapes(bits, shape):
    """Non-plane shapes route to the oracle and still decode exactly."""
    rng = np.random.default_rng(1)
    xf = jnp.asarray(rng.normal(size=shape), jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(2), shape)
    norm = jnp.sqrt(jnp.sum(jnp.square(xf)))
    data = wire_compress.qsgd_pack(xf, u, norm, bits=bits)
    tail = jax.lax.bitcast_convert_type(norm, jnp.uint8)
    buf = jnp.concatenate([data, tail])
    dec = wire_compress.qsgd_decode_ref(buf, shape, bits=bits)
    comp = QSGDCompressor(p=1.0, bits=bits)
    pay = comp.compress(jax.random.PRNGKey(2), xf)
    np.testing.assert_array_equal(np.asarray(dec),
                                  np.asarray(comp.decompress(pay)))


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_fused_compressor_roundtrip_bitequal_qsgd(bits):
    """FusedQSGDCompressor decompress(compress(x)) == qsgd's, bitwise,
    and matches the qsgd_decode_ref oracle on the same buffer."""
    xf = _plane(16, seed=23)
    key = jax.random.PRNGKey(9)
    fused = FusedQSGDCompressor(p=1.0, bits=bits)
    plain = QSGDCompressor(p=1.0, bits=bits)
    fp = fused.compress(key, xf)
    assert fp.scale is None and fp.values.dtype == jnp.uint8
    out_f = fused.decompress(fp)
    out_p = plain.decompress(plain.compress(key, xf))
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_p))
    out_o = wire_compress.qsgd_decode_ref(fp.values, fp.shape, bits=bits)
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_o))


def test_fused_compressor_wire_bits_inherited():
    for bits in (2, 4, 8):
        f = FusedQSGDCompressor(p=1.0, bits=bits)
        q = QSGDCompressor(p=1.0, bits=bits)
        for shape in ((8, 128), (71,), (3, 5, 11)):
            assert f.wire_bits(shape) == q.wire_bits(shape)
            # single-buffer format: the payload byte count IS the charge
            d = int(np.prod(shape))
            k = wire_compress.pack_factor(bits)
            assert f.wire_bits(shape) == (-(-d // k)) * 8 + 32


def test_fused_compressor_rejects_odd_bits():
    with pytest.raises(ValueError):
        FusedQSGDCompressor(p=1.0, bits=3)


@pytest.mark.parametrize("kb,scale", [(4, 2.5), (16, 1.0)])
def test_fixedk_gather_pack_kernel_matches_ref(kb, scale):
    rng = np.random.default_rng(kb)
    db = jnp.asarray(rng.normal(size=(64, LANE)), jnp.float32)
    idx = jnp.asarray(rng.choice(64, size=kb, replace=False), jnp.int32)
    out_k = wire_compress.fixedk_gather_pack(db, idx, scale=scale,
                                             use_kernel=True)
    out_r = wire_compress.fixedk_gather_pack(db, idx, scale=scale,
                                             use_kernel=False)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@given(seed=st.integers(0, 10_000), bits=st.sampled_from([2, 4, 8]),
       rows=st.sampled_from([8, 24, 40]))
@settings(max_examples=15, deadline=None)
def test_qsgd_pack_property_sweep(seed, bits, rows):
    rng = np.random.default_rng(seed)
    xf = jnp.asarray(rng.normal(size=(rows, LANE)), jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(seed), xf.shape)
    norm = jnp.sqrt(jnp.sum(jnp.square(xf)))
    out_k = wire_compress.qsgd_pack(xf, u, norm, bits=bits, use_kernel=True)
    out_r = wire_compress.qsgd_pack(xf, u, norm, bits=bits, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
